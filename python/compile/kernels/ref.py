"""Pure-jnp reference oracle for every L1 kernel.

This module is the correctness ground truth: Pallas kernels (nvfp4.py,
sr.py, rht.py, hcp.py) are asserted allclose against these functions in
python/tests/, and the Rust quant substrate (rust/src/quant/) is checked
against golden fixtures dumped from here.

NVFP4 numerics follow App. C.4 of the paper exactly:

  global encode scale   s_enc      = (6 * 448) / amax(X)           (Def C.1)
  local decode scale    s_dec_b    = amax_b / 6                    (Def C.3)
  stored block scale    s_e4m3_b   = e4m3(s_dec_b * s_enc)         (Eq. 41)
  effective enc scale   s_enc_b    = 1 / (fp32(s_e4m3_b) * s_dec)  (Eq. 42)
  quantized element     x_hat_i    = q_e2m1(x_i * s_enc_b)         (Eq. 43)
  dequantized element   x_dq_i     = x_hat_i * fp32(s_e4m3_b) * s_dec

All float8 arithmetic is *emulated* in f32 (frexp-based) so the lowered HLO
contains no f8 dtypes — xla_extension 0.5.1 (the runtime backend) predates
reliable f8 support on the CPU PJRT plugin.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# --------------------------------------------------------------------------
# Format constants
# --------------------------------------------------------------------------

E2M1_MAX = 6.0          # largest magnitude representable in FP4 E2M1
E4M3_MAX = 448.0        # largest magnitude representable in FP8 E4M3
E4M3_MIN_NORMAL_EXP = -6   # smallest normal exponent (2^-6)
E4M3_MANT_BITS = 3
BLOCK = 16              # NVFP4 micro-block length (1x16)

# The 8 non-negative E2M1 code points.
E2M1_VALUES = (0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0)


# --------------------------------------------------------------------------
# E2M1 rounding (RTN round-half-even, floor, stochastic)
# --------------------------------------------------------------------------

def e2m1_rtn(v):
    """Round-to-nearest-even onto the E2M1 lattice. |v| is clamped to 6.

    The lattice spacing is 0.5 on [0,2), 1.0 on [2,4), 2.0 on [4,6];
    jnp.round is round-half-to-even, which on a uniformly spaced sub-lattice
    coincides with IEEE RTN-even on the format's mantissa bit.
    """
    a = jnp.abs(v)
    s = jnp.sign(v)
    a = jnp.minimum(a, E2M1_MAX)
    r = jnp.where(
        a < 2.0,
        jnp.round(a * 2.0) * 0.5,
        jnp.where(a < 4.0, jnp.round(a), jnp.round(a * 0.5) * 2.0),
    )
    return s * r


def e2m1_floor(v):
    """Round-toward-zero onto the E2M1 lattice (used by stochastic rounding)."""
    a = jnp.minimum(jnp.abs(v), E2M1_MAX)
    s = jnp.sign(v)
    r = jnp.where(
        a < 2.0,
        jnp.floor(a * 2.0) * 0.5,
        jnp.where(a < 4.0, jnp.floor(a), jnp.floor(a * 0.5) * 2.0),
    )
    return s * r


def e2m1_spacing(a):
    """Lattice spacing at magnitude ``a`` (for the upward neighbour)."""
    return jnp.where(a < 2.0, 0.5, jnp.where(a < 4.0, 1.0, 2.0))


def e2m1_sr(v, u):
    """Stochastic rounding onto the E2M1 lattice.

    ``u`` are uniforms in [0,1) with the same shape as ``v``. E[e2m1_sr(v,U)]
    == clamp(v) for v within range (unbiasedness — property-tested).
    """
    a = jnp.minimum(jnp.abs(v), E2M1_MAX)
    s = jnp.sign(v)
    lo = jnp.where(
        a < 2.0,
        jnp.floor(a * 2.0) * 0.5,
        jnp.where(a < 4.0, jnp.floor(a), jnp.floor(a * 0.5) * 2.0),
    )
    step = e2m1_spacing(lo)  # spacing *above* lo (at 2/4 boundaries: next gap)
    hi = jnp.minimum(lo + step, E2M1_MAX)
    frac = jnp.where(hi > lo, (a - lo) / (hi - lo), 0.0)
    r = jnp.where(u < frac, hi, lo)
    return s * r


# --------------------------------------------------------------------------
# E4M3 emulation (f32 arithmetic only)
# --------------------------------------------------------------------------

def e4m3_rtn(v):
    """Round-to-nearest-even onto the FP8 E4M3 lattice, saturating at 448.

    Uses frexp for an exact exponent so no f8 dtype appears in the HLO.
    Zero maps to zero. Subnormals (exp < -6) round on the fixed 2^-9 grid.
    """
    a = jnp.abs(v)
    s = jnp.sign(v)
    # frexp: a = m * 2^e with m in [0.5, 1)  =>  floor(log2 a) = e - 1
    _, e = jnp.frexp(jnp.where(a > 0, a, 1.0))
    e = e - 1
    e = jnp.maximum(e, E4M3_MIN_NORMAL_EXP)
    step = jnp.exp2((e - E4M3_MANT_BITS).astype(jnp.float32))
    r = jnp.round(a / step) * step
    r = jnp.minimum(r, E4M3_MAX)
    return jnp.where(a == 0.0, 0.0, s * r)


# --------------------------------------------------------------------------
# NVFP4 two-level microscaling (App. C.4)
# --------------------------------------------------------------------------

def _blocked(x):
    """Reshape (..., N) -> (..., N/BLOCK, BLOCK). N must divide by BLOCK."""
    assert x.shape[-1] % BLOCK == 0, f"last dim {x.shape[-1]} % {BLOCK} != 0"
    return x.reshape(*x.shape[:-1], x.shape[-1] // BLOCK, BLOCK)


def nvfp4_scales(x):
    """Compute (s_enc global, s_dec global, stored e4m3 block decode scales).

    Returns (s_enc: scalar, s_dec: scalar, s_e4m3: (..., N/BLOCK)).
    """
    xb = _blocked(x)
    amax = jnp.max(jnp.abs(x))
    # Guard the all-zero tensor: any finite scale works, everything encodes 0.
    s_enc = jnp.where(amax > 0, (E2M1_MAX * E4M3_MAX) / amax, 1.0)
    s_dec = 1.0 / s_enc
    amax_b = jnp.max(jnp.abs(xb), axis=-1)
    s_dec_b = amax_b / E2M1_MAX
    s_e4m3 = e4m3_rtn(s_dec_b * s_enc)
    return s_enc, s_dec, s_e4m3


def nvfp4_quant_dequant(x, rounding="rtn", u=None):
    """Fake-quantize ``x`` through NVFP4: quantize then dequantize in f32.

    rounding: "rtn" (forward path) or "sr" (backward path; ``u`` uniforms
    required, same shape as x).

    This is exactly the paper's ablation methodology (App. C.3): values and
    scales are bit-faithful NVFP4, the subsequent GEMM runs in high precision.
    """
    s_enc, s_dec, s_e4m3 = nvfp4_scales(x)
    xb = _blocked(x)
    # Effective per-block encode scale (Eq. 42); blocks whose stored scale
    # quantized to zero (amax_b == 0, or underflow) encode/decode to zero.
    denom = s_e4m3 * s_dec
    s_enc_b = jnp.where(denom > 0, 1.0 / jnp.maximum(denom, 1e-45), 0.0)
    scaled = xb * s_enc_b[..., None]
    if rounding == "rtn":
        q = e2m1_rtn(scaled)
    elif rounding == "sr":
        assert u is not None
        q = e2m1_sr(scaled, _blocked(u))
    else:  # pragma: no cover - config error
        raise ValueError(f"unknown rounding {rounding!r}")
    deq = q * (s_e4m3 * s_dec)[..., None]
    return deq.reshape(x.shape)


def nvfp4_quant_dequant_2d(x, rounding="rtn", u=None, tile=16):
    """2D (tile x BLOCK) block scaling used for weights in the NVIDIA recipe.

    Rows are grouped into ``tile``-row bands; each band shares its block
    scales (computed from the band's amax per 16-column block). Implemented
    by folding the row band into the block dimension.
    """
    m = x.shape[-2]
    pad = (-m) % tile
    if pad:
        x_p = jnp.concatenate(
            [x, jnp.zeros((*x.shape[:-2], pad, x.shape[-1]), x.dtype)], axis=-2
        )
    else:
        x_p = x
    mp = x_p.shape[-2]
    # (..., mp/tile, tile, N/BLOCK, BLOCK) -> amax over (tile, BLOCK)
    xb = x_p.reshape(*x_p.shape[:-2], mp // tile, tile, x.shape[-1] // BLOCK, BLOCK)
    amax = jnp.max(jnp.abs(x_p))
    s_enc = jnp.where(amax > 0, (E2M1_MAX * E4M3_MAX) / amax, 1.0)
    s_dec = 1.0 / s_enc
    amax_b = jnp.max(jnp.abs(xb), axis=(-3, -1), keepdims=True)  # tile rows + block
    s_dec_b = amax_b / E2M1_MAX
    s_e4m3 = e4m3_rtn(s_dec_b * s_enc)
    denom = s_e4m3 * s_dec
    s_enc_b = jnp.where(denom > 0, 1.0 / jnp.maximum(denom, 1e-45), 0.0)
    scaled = xb * s_enc_b
    if rounding == "rtn":
        q = e2m1_rtn(scaled)
    else:
        assert u is not None
        u_p = (
            jnp.concatenate(
                [u, jnp.zeros((*u.shape[:-2], pad, u.shape[-1]), u.dtype)], axis=-2
            )
            if pad
            else u
        )
        ub = u_p.reshape(xb.shape)
        q = e2m1_sr(scaled, ub)
    deq = (q * (s_e4m3 * s_dec)).reshape(x_p.shape)
    return deq[..., :m, :]


def ftz_ratio(x):
    """Flush-to-zero ratio: fraction of nonzero inputs that quantize to 0."""
    deq = nvfp4_quant_dequant(x)
    nz = x != 0.0
    flushed = jnp.logical_and(nz, deq == 0.0)
    return jnp.sum(flushed) / jnp.maximum(jnp.sum(nz), 1)


# --------------------------------------------------------------------------
# MXFP4 baseline (power-of-two E8M0 block scales, Quartet-style)
# --------------------------------------------------------------------------

def mxfp4_quant_dequant(x):
    """MXFP4: 32-wide blocks, power-of-two (E8M0) decode scales, no global.

    OCP MX spec semantics: shared exponent = floor(log2(amax)) - emax_elem
    (emax of E2M1 is 2), i.e. s_dec = 2^(floor(log2 amax) - 2). Block values
    land in [0, 8)·s_dec, so magnitudes in (6, 8)·s_dec saturate to 6 —
    the clamping NVFP4's finer e4m3 scale avoids.
    """
    blk = 32
    assert x.shape[-1] % blk == 0
    xb = x.reshape(*x.shape[:-1], x.shape[-1] // blk, blk)
    amax_b = jnp.max(jnp.abs(xb), axis=-1, keepdims=True)
    # frexp: amax = m * 2^e, m in [0.5,1) => floor(log2 amax) = e - 1
    _, e = jnp.frexp(jnp.where(amax_b > 0, amax_b, 1.0))
    s_dec_b = jnp.exp2((e - 1 - 2).astype(jnp.float32))
    q = e2m1_rtn(xb / s_dec_b)
    deq = jnp.where(amax_b > 0, q * s_dec_b, 0.0)
    return deq.reshape(x.shape)


# --------------------------------------------------------------------------
# Randomized Hadamard Transform (backward path, Wgrad only)
# --------------------------------------------------------------------------

def fwht(x):
    """Fast Walsh–Hadamard transform over the last dim (power of 2).

    Unnormalized: fwht(fwht(x)) == n * x. Orthogonal up to sqrt(n).
    """
    n = x.shape[-1]
    assert n & (n - 1) == 0, f"FWHT size {n} not a power of 2"
    lead = x.shape[:-1]
    y = x.reshape(-1, n)
    h = 1
    while h < n:
        y = y.reshape(-1, n // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2).reshape(-1, n)
        h *= 2
    return y.reshape(*lead, n)


def rht(x, signs):
    """Randomized Hadamard: orthonormal H @ diag(signs) @ x over last dim.

    ``signs`` in {-1, +1}, shape (n,). Inverse is rht_inv.
    """
    n = x.shape[-1]
    return fwht(x * signs) / jnp.sqrt(jnp.asarray(n, x.dtype))


def rht_inv(y, signs):
    n = y.shape[-1]
    return signs * fwht(y) / jnp.sqrt(jnp.asarray(n, y.dtype))


# --------------------------------------------------------------------------
# Hot-Channel Patch oracle (Sec. 4 + App. A/B)
# --------------------------------------------------------------------------

def hcp_scores(dx, dw):
    """Channel importance score, Eq. (2): s_j = mean|ΔX_:,j| + mean|ΔW_j,:|.

    dx: (M, K) activation residual (channels along K);
    dw: (K, N) weight residual (channels along K).
    Returns (K,) scores.
    """
    sx = jnp.mean(jnp.abs(dx), axis=tuple(range(dx.ndim - 1)))
    sw = jnp.mean(jnp.abs(dw), axis=tuple(range(1, dw.ndim)))
    return sx + sw


def topk_channels(scores, k):
    """Indices of the k largest scores (the hot-channel set I).

    Sort-based (not lax.top_k): the runtime's XLA 0.5.1 HLO parser predates
    the TopK custom attribute, while `sort` round-trips fine.
    """
    return jnp.argsort(-scores)[:k]


def hcp_matmul(x, w, k, order="o2", target="b", rounding="rtn", u=None, idx=None):
    """Reference patched matmul: Y ≈ x @ w with NVFP4 fake quant + HCP.

    x: (M, K), w: (K, N), channels along K. k = |I| patched channels.
    order: "o2" (both-sided on I), "o1a"/"o1w" (single-sided first order),
    or "none" (plain quantized baseline). ``target`` narrows o2 to W/A/B.
    Returns (y, idx) — idx is the channel set used (given or computed).
    """
    xq = nvfp4_quant_dequant(x, rounding=rounding, u=u)
    wq = nvfp4_quant_dequant_2d(w.T).T  # 2D scaling along w's K-dim blocks
    dx = x - xq
    dw = w - wq
    if idx is None:
        idx = topk_channels(hcp_scores(dx, dw), k)
    y = xq @ wq
    if order == "o2":
        if target in ("b", "a"):
            y = y + dx[:, idx] @ wq[idx, :]
        if target in ("b", "w"):
            y = y + xq[:, idx] @ dw[idx, :]
    elif order == "o1a":
        # full activation patch on I: replaces X̂_I with X_I against Ŵ
        y = y + dx[:, idx] @ wq[idx, :]
    elif order == "o1w":
        y = y + xq[:, idx] @ dw[idx, :]
    elif order == "none":
        pass
    else:  # pragma: no cover
        raise ValueError(order)
    return y, idx


# --------------------------------------------------------------------------
# Diagnostics oracles (Sec. 3 definitions)
# --------------------------------------------------------------------------

def kurtosis(x):
    """Excess kurtosis (Eq. 1) of the flattened tensor."""
    x = x.reshape(-1).astype(jnp.float32)
    mu = jnp.mean(x)
    d = x - mu
    var = jnp.mean(d * d)
    m4 = jnp.mean(d**4)
    return m4 / jnp.maximum(var * var, 1e-30) - 3.0


def block_kurtosis(x, bm=16, bn=16):
    """Per-(bm x bn)-block excess kurtosis map of a 2D tensor (Fig. 4)."""
    m, n = x.shape
    mm, nn = (m // bm) * bm, (n // bn) * bn
    xb = x[:mm, :nn].reshape(mm // bm, bm, nn // bn, bn).transpose(0, 2, 1, 3)
    xb = xb.reshape(mm // bm, nn // bn, bm * bn)
    mu = jnp.mean(xb, axis=-1, keepdims=True)
    d = xb - mu
    var = jnp.mean(d * d, axis=-1)
    m4 = jnp.mean(d**4, axis=-1)
    return m4 / jnp.maximum(var * var, 1e-30) - 3.0


def topk_magnitude(x, k=3):
    """Top-k |x| over the flattened tensor (Fig. 6a / 21). Sort-based —
    see topk_channels for why lax.top_k is avoided."""
    return -jnp.sort(-jnp.abs(x).reshape(-1))[:k]


def channel_topk_magnitude(x, k=3):
    """Per-channel max magnitude, then top-k channels (Fig. 3 hot channels)."""
    cm = jnp.max(jnp.abs(x), axis=tuple(range(x.ndim - 1)))
    idx = jnp.argsort(-cm)[:k]
    return cm[idx], idx


def softmax_entropy(logits):
    """Mean post-softmax entropy over the last axis (Fig. 7)."""
    p = jax.nn.softmax(logits, axis=-1)
    h = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-30)), axis=-1)
    return jnp.mean(h)


def cosine_alignment(w_up, w_gate):
    """Mean |cos| row alignment between W_up and W_gate (Fig. 8)."""
    num = jnp.abs(jnp.sum(w_up * w_gate, axis=-1))
    den = jnp.linalg.norm(w_up, axis=-1) * jnp.linalg.norm(w_gate, axis=-1)
    return jnp.mean(num / jnp.maximum(den, 1e-30))


def quant_mse(x, rounding="rtn", u=None):
    """Mean squared NVFP4 quantization error of a tensor (Fig. 32)."""
    deq = nvfp4_quant_dequant(x, rounding=rounding, u=u)
    return jnp.mean((x - deq) ** 2)
