"""L1 Pallas kernels: NVFP4 quantize-dequantize (1D and 2D block scaling).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's Blackwell
tensor-core quantization (TransformerEngine NVFP4) maps to TPU as a VMEM
row-tile kernel. Each grid step owns a (block_rows, N) VMEM tile containing
an integer number of 1x16 scale blocks; the global encode scale rides in as
a (1,1) scalar block (computed in a separate amax pass, mirroring the
paper's Implementation note on memory traffic in App. C.4).

Kernels MUST run with interpret=True: on CPU PJRT, real Mosaic lowering
emits custom-calls the runtime cannot execute. The in-kernel math reuses
the jnp lattice helpers from ref.py so kernel-vs-oracle tests isolate the
*blocking/scaling structure*, which is what the kernel owns.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# All pallas_call sites in this repo go through this flag so the AOT path
# can assert interpret mode is on.
INTERPRET = True


def _pick_block_rows(m: int, preferred: int = 8) -> int:
    """Largest divisor of m that is <= preferred (VMEM sublane tiling)."""
    for b in range(min(preferred, m), 0, -1):
        if m % b == 0:
            return b
    return 1


def _qdq_kernel(x_ref, senc_ref, o_ref, *, rounding):
    """One (bm, N) tile: per-1x16-block scales + E2M1 RTN quant-dequant."""
    x = x_ref[...]
    s_enc = senc_ref[0, 0]
    s_dec = 1.0 / s_enc
    bm, n = x.shape
    xb = x.reshape(bm, n // ref.BLOCK, ref.BLOCK)
    amax_b = jnp.max(jnp.abs(xb), axis=-1)
    s_e4m3 = ref.e4m3_rtn(amax_b / ref.E2M1_MAX * s_enc)
    denom = s_e4m3 * s_dec
    s_enc_b = jnp.where(denom > 0, 1.0 / jnp.maximum(denom, 1e-45), 0.0)
    scaled = xb * s_enc_b[..., None]
    q = ref.e2m1_rtn(scaled)
    deq = q * (s_e4m3 * s_dec)[..., None]
    o_ref[...] = deq.reshape(bm, n)


def _qdq_sr_kernel(x_ref, u_ref, senc_ref, o_ref):
    """Stochastic-rounding variant (backward path)."""
    x = x_ref[...]
    u = u_ref[...]
    s_enc = senc_ref[0, 0]
    s_dec = 1.0 / s_enc
    bm, n = x.shape
    xb = x.reshape(bm, n // ref.BLOCK, ref.BLOCK)
    ub = u.reshape(bm, n // ref.BLOCK, ref.BLOCK)
    amax_b = jnp.max(jnp.abs(xb), axis=-1)
    s_e4m3 = ref.e4m3_rtn(amax_b / ref.E2M1_MAX * s_enc)
    denom = s_e4m3 * s_dec
    s_enc_b = jnp.where(denom > 0, 1.0 / jnp.maximum(denom, 1e-45), 0.0)
    scaled = xb * s_enc_b[..., None]
    q = ref.e2m1_sr(scaled, ub)
    deq = q * (s_e4m3 * s_dec)[..., None]
    o_ref[...] = deq.reshape(bm, n)


def nvfp4_qdq(x, *, rounding: str = "rtn", u=None, block_rows: int = 8):
    """NVFP4 fake-quantize a 2D tensor with 1x16 block scaling (Pallas).

    Matches ref.nvfp4_quant_dequant exactly (asserted in tests).
    """
    assert x.ndim == 2, x.shape
    m, n = x.shape
    assert n % ref.BLOCK == 0, (m, n)
    bm = _pick_block_rows(m, block_rows)
    amax = jnp.max(jnp.abs(x))
    s_enc = jnp.where(amax > 0, (ref.E2M1_MAX * ref.E4M3_MAX) / amax, 1.0)
    s_enc = s_enc.reshape(1, 1).astype(jnp.float32)
    grid = (m // bm,)
    x_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))
    s_spec = pl.BlockSpec((1, 1), lambda i: (0, 0))
    out_spec = pl.BlockSpec((bm, n), lambda i: (i, 0))
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)
    if rounding == "rtn":
        return pl.pallas_call(
            functools.partial(_qdq_kernel, rounding="rtn"),
            grid=grid,
            in_specs=[x_spec, s_spec],
            out_specs=out_spec,
            out_shape=out_shape,
            interpret=INTERPRET,
        )(x.astype(jnp.float32), s_enc)
    assert u is not None and u.shape == x.shape
    return pl.pallas_call(
        _qdq_sr_kernel,
        grid=grid,
        in_specs=[x_spec, x_spec, s_spec],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=INTERPRET,
    )(x.astype(jnp.float32), u.astype(jnp.float32), s_enc)


def _qdq2d_kernel(x_ref, senc_ref, o_ref, *, tile):
    """One (tile, N) row band sharing 2D (tile x 16) block scales."""
    x = x_ref[...]
    s_enc = senc_ref[0, 0]
    s_dec = 1.0 / s_enc
    bm, n = x.shape
    xb = x.reshape(bm, n // ref.BLOCK, ref.BLOCK)
    # 2D scaling: amax over the whole (tile x BLOCK) brick.
    amax_b = jnp.max(jnp.abs(xb), axis=(0, 2))  # (n/BLOCK,)
    s_e4m3 = ref.e4m3_rtn(amax_b / ref.E2M1_MAX * s_enc)
    denom = s_e4m3 * s_dec
    s_enc_b = jnp.where(denom > 0, 1.0 / jnp.maximum(denom, 1e-45), 0.0)
    scaled = xb * s_enc_b[None, :, None]
    q = ref.e2m1_rtn(scaled)
    deq = q * (s_e4m3 * s_dec)[None, :, None]
    o_ref[...] = deq.reshape(bm, n)


def nvfp4_qdq_2d(x, *, tile: int = 16):
    """NVFP4 fake-quantize with 2D (tile x 16) weight block scaling (Pallas).

    Matches ref.nvfp4_quant_dequant_2d. Rows are padded to the tile size.
    """
    assert x.ndim == 2
    m, n = x.shape
    assert n % ref.BLOCK == 0
    pad = (-m) % tile
    x_p = jnp.concatenate([x, jnp.zeros((pad, n), x.dtype)]) if pad else x
    mp = x_p.shape[0]
    amax = jnp.max(jnp.abs(x_p))
    s_enc = jnp.where(amax > 0, (ref.E2M1_MAX * ref.E4M3_MAX) / amax, 1.0)
    s_enc = s_enc.reshape(1, 1).astype(jnp.float32)
    out = pl.pallas_call(
        functools.partial(_qdq2d_kernel, tile=tile),
        grid=(mp // tile,),
        in_specs=[
            pl.BlockSpec((tile, n), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, n), jnp.float32),
        interpret=INTERPRET,
    )(x_p.astype(jnp.float32), s_enc)
    return out[:m, :]
