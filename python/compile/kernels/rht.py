"""L1 Pallas kernel: Randomized Hadamard Transform (backward / Wgrad path).

The FWHT butterfly runs entirely inside one VMEM row tile: for a (bm, n)
block the kernel performs log2(n) reshape-free butterfly stages. On real
TPU hardware each stage is a lane shuffle within the 8x128 register tile
(n <= 128) or a VMEM-local permutation; here (interpret=True) it lowers to
plain HLO slices/concats.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref
from .nvfp4 import INTERPRET, _pick_block_rows


def _rht_kernel(x_ref, sign_ref, o_ref, *, inverse):
    x = x_ref[...]
    s = sign_ref[...].reshape(-1)
    bm, n = x.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(n, jnp.float32))
    if not inverse:
        y = x * s[None, :]
    else:
        y = x
    h = 1
    while h < n:
        y = y.reshape(bm, n // (2 * h), 2, h)
        a = y[:, :, 0, :]
        b = y[:, :, 1, :]
        y = jnp.stack([a + b, a - b], axis=2).reshape(bm, n)
        h *= 2
    y = y * scale
    if inverse:
        y = y * s[None, :]
    o_ref[...] = y


def rht(x, signs, *, inverse: bool = False, block_rows: int = 8):
    """Orthonormal randomized Hadamard over the last dim (Pallas kernel).

    Matches ref.rht / ref.rht_inv. x: (M, N) with N a power of two;
    signs: (N,) in {-1, +1}.
    """
    assert x.ndim == 2
    m, n = x.shape
    assert n & (n - 1) == 0, f"RHT size {n} not a power of 2"
    bm = _pick_block_rows(m, block_rows)
    import functools

    return pl.pallas_call(
        functools.partial(_rht_kernel, inverse=inverse),
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(x.astype(jnp.float32), signs.astype(jnp.float32).reshape(1, n))
