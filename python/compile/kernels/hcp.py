"""L1 Pallas kernel: fused Hot-Channel Patch GEMM (S-O2-B, Alg. 1).

Single-kernel (S) mode fuses the three contractions of the patched product

    Y = X̂ Ŵ + ΔX_I Ŵ_I + X̂_I ΔW_I

into one grid so the MXU sees one logical GEMM over the concatenated
channel dimension [K ; k ; k] — the hardware-efficient "concat" trick of
Alg. 1 — without materializing the concatenated operands in HBM.

Dual-kernel (D) mode (Tab. 4 / Tab. 5 "pre-fuse") runs the base GEMM and
the residual correction as separate pallas_calls, mirroring the unfused
Triton pipeline the paper benchmarks against.

Tiling: grid (M/bm, N/bn); each step owns a (bm, K)+(bm, k) LHS stripe and
a (K, bn)+(k, bn) RHS stripe in VMEM and writes one (bm, bn) output tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .nvfp4 import INTERPRET, _pick_block_rows


def _fused_kernel(xq_ref, wq_ref, dxg_ref, wqg_ref, xqg_ref, dwg_ref, o_ref):
    xq = xq_ref[...]
    wq = wq_ref[...]
    acc = jnp.dot(xq, wq, preferred_element_type=jnp.float32)
    acc = acc + jnp.dot(dxg_ref[...], wqg_ref[...], preferred_element_type=jnp.float32)
    acc = acc + jnp.dot(xqg_ref[...], dwg_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc


def _base_kernel(xq_ref, wq_ref, o_ref):
    o_ref[...] = jnp.dot(xq_ref[...], wq_ref[...], preferred_element_type=jnp.float32)


def _residual_kernel(dxg_ref, wqg_ref, xqg_ref, dwg_ref, o_ref):
    acc = jnp.dot(dxg_ref[...], wqg_ref[...], preferred_element_type=jnp.float32)
    acc = acc + jnp.dot(xqg_ref[...], dwg_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = acc


def _tiles(m, n, bm, bn):
    bm = _pick_block_rows(m, bm)
    bn = _pick_block_rows(n, bn)
    return bm, bn


def hcp_gemm_fused(xq, wq, dxg, wqg, xqg, dwg, *, bm: int = 8, bn: int = 128):
    """Single-kernel (S-mode) patched GEMM.

    xq: (M, K) quantized activations; wq: (K, N) quantized weights;
    dxg: (M, k) gathered hot-channel activation residuals;
    wqg: (k, N) gathered quantized weight rows;
    xqg: (M, k) gathered quantized activation columns;
    dwg: (k, N) gathered weight residual rows.
    Returns (M, N) f32.
    """
    m, kdim = xq.shape
    _, n = wq.shape
    bm, bn = _tiles(m, n, bm, bn)
    grid = (m // bm, n // bn)
    lhs = lambda i, j: (i, 0)
    rhs = lambda i, j: (0, j)
    return pl.pallas_call(
        _fused_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, kdim), lhs),
            pl.BlockSpec((kdim, bn), rhs),
            pl.BlockSpec((bm, dxg.shape[1]), lhs),
            pl.BlockSpec((wqg.shape[0], bn), rhs),
            pl.BlockSpec((bm, xqg.shape[1]), lhs),
            pl.BlockSpec((dwg.shape[0], bn), rhs),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=INTERPRET,
    )(
        xq.astype(jnp.float32),
        wq.astype(jnp.float32),
        dxg.astype(jnp.float32),
        wqg.astype(jnp.float32),
        xqg.astype(jnp.float32),
        dwg.astype(jnp.float32),
    )


def hcp_gemm_dual(xq, wq, dxg, wqg, xqg, dwg, *, bm: int = 8, bn: int = 128):
    """Dual-kernel (D-mode): base GEMM and residual GEMM as separate calls."""
    m, kdim = xq.shape
    _, n = wq.shape
    bm, bn = _tiles(m, n, bm, bn)
    grid = (m // bm, n // bn)
    lhs = lambda i, j: (i, 0)
    rhs = lambda i, j: (0, j)
    out_spec = pl.BlockSpec((bm, bn), lambda i, j: (i, j))
    out_shape = jax.ShapeDtypeStruct((m, n), jnp.float32)
    base = pl.pallas_call(
        _base_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((bm, kdim), lhs), pl.BlockSpec((kdim, bn), rhs)],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=INTERPRET,
    )(xq.astype(jnp.float32), wq.astype(jnp.float32))
    resid = pl.pallas_call(
        _residual_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, dxg.shape[1]), lhs),
            pl.BlockSpec((wqg.shape[0], bn), rhs),
            pl.BlockSpec((bm, xqg.shape[1]), lhs),
            pl.BlockSpec((dwg.shape[0], bn), rhs),
        ],
        out_specs=out_spec,
        out_shape=out_shape,
        interpret=INTERPRET,
    )(
        dxg.astype(jnp.float32),
        wqg.astype(jnp.float32),
        xqg.astype(jnp.float32),
        dwg.astype(jnp.float32),
    )
    return base + resid
