"""L2 quantized-training primitives: the CHON linear layer.

Implements the paper's computational workflow (Fig. 9) as a
``jax.custom_vjp`` so one linear layer carries the whole recipe:

  forward (Fprop):   Y  = Q_rtn(X) @ Q_rtn2d(W)      [+ HCP compensation]
  backward (Dgrad):  dX = Q_sr(dY) @ Q(W)^T
  backward (Wgrad):  dW = Q_sr(H·X)^T @ Q_sr(H·dY)   [RHT along the
                                                      contraction dim]

Quantizers are NVFP4 fake-quant (bit-faithful values + scales, high
precision GEMM — the paper's own ablation methodology, App. C.3), FP8
(per-tensor e4m3) for the FP8 baseline, or identity for BF16.

Gradients use the straight-through estimator for the fake-quant itself;
gradient *tensors* are re-quantized per the recipe before the backward
GEMMs, which is what distinguishes Dgrad/Wgrad precision in Fig. 9.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels import hcp as hcp_kernels
from .kernels import nvfp4 as nvfp4_kernels
from .kernels import ref
from .kernels import rht as rht_kernels


class OpQuant(NamedTuple):
    """Per-operator quantization config (hashable; nondiff custom_vjp arg).

    mode: "bf16" | "fp8" | "nvfp4"
    scaling_2d: 2D (16x16) block scaling for weights (NVIDIA recipe (ii))
    sr: stochastic rounding for backward-pass tensors
    rht: randomized Hadamard transform on the Wgrad contraction dim
    hcp_frac: fraction of channels patched by HCP (0 disables; paper: 9.09%)
    use_pallas: route fwd activation quant + HCP GEMM through the L1
        Pallas kernels (numerically identical to the jnp oracle)
    """

    mode: str = "nvfp4"
    scaling_2d: bool = True
    sr: bool = True
    rht: bool = True
    hcp_frac: float = 0.0
    use_pallas: bool = False


BF16 = OpQuant(mode="bf16")
FP8 = OpQuant(mode="fp8")


def _qdq_act(x2, cfg: OpQuant, *, u=None):
    """Quantize-dequantize a 2D activation/grad (1D 1x16 block scaling)."""
    if cfg.mode == "bf16":
        return x2
    if cfg.mode == "fp8":
        amax = jnp.max(jnp.abs(x2))
        s = jnp.where(amax > 0, ref.E4M3_MAX / amax, 1.0)
        return ref.e4m3_rtn(x2 * s) / s
    if u is not None:
        return ref.nvfp4_quant_dequant(x2, rounding="sr", u=u)
    if cfg.use_pallas:
        return nvfp4_kernels.nvfp4_qdq(x2)
    return ref.nvfp4_quant_dequant(x2)


def _qdq_weight(w, cfg: OpQuant):
    """Quantize-dequantize a (K, N) weight; block scales along K."""
    if cfg.mode == "bf16":
        return w
    if cfg.mode == "fp8":
        amax = jnp.max(jnp.abs(w))
        s = jnp.where(amax > 0, ref.E4M3_MAX / amax, 1.0)
        return ref.e4m3_rtn(w * s) / s
    if cfg.scaling_2d:
        if cfg.use_pallas:
            return nvfp4_kernels.nvfp4_qdq_2d(w.T).T
        return ref.nvfp4_quant_dequant_2d(w.T).T
    if cfg.use_pallas:
        return nvfp4_kernels.nvfp4_qdq(w.T).T
    return ref.nvfp4_quant_dequant(w.T).T


def _hcp_k(cfg: OpQuant, kdim: int) -> int:
    if cfg.mode != "nvfp4" or cfg.hcp_frac <= 0.0:
        return 0
    return max(1, int(round(cfg.hcp_frac * kdim)))


def _forward_2d(x2, w, cfg: OpQuant):
    """Quantized forward product on flattened (M, K) @ (K, N)."""
    if cfg.mode == "bf16":
        return x2 @ w
    xq = _qdq_act(x2, cfg)
    wq = _qdq_weight(w, cfg)
    k = _hcp_k(cfg, x2.shape[-1])
    if k == 0:
        return xq @ wq
    dx = x2 - xq
    dw = w - wq
    idx = ref.topk_channels(ref.hcp_scores(dx, dw), k)
    if cfg.use_pallas:
        return hcp_kernels.hcp_gemm_fused(
            xq, wq, dx[:, idx], wq[idx, :], xq[:, idx], dw[idx, :]
        )
    return xq @ wq + dx[:, idx] @ wq[idx, :] + xq[:, idx] @ dw[idx, :]


def _bwd_quant(g2, cfg: OpQuant, key):
    """Backward-tensor quantization: SR if enabled, else RTN (1D scaling)."""
    if cfg.mode != "nvfp4":
        return _qdq_act(g2, cfg)
    if cfg.sr:
        u = jax.random.uniform(key, g2.shape, jnp.float32)
        return ref.nvfp4_quant_dequant(g2, rounding="sr", u=u)
    return ref.nvfp4_quant_dequant(g2)


def _maybe_rht(a2, b2, cfg: OpQuant, key):
    """Apply the orthonormal RHT along the (power-of-2) contraction dim of
    Wgrad: dW = (H·X)^T (H·dY) == X^T dY exactly before quantization."""
    m = a2.shape[0]
    if not cfg.rht or cfg.mode != "nvfp4" or (m & (m - 1)) != 0:
        return a2, b2
    signs = jnp.where(
        jax.random.bernoulli(key, 0.5, (m,)), 1.0, -1.0
    ).astype(jnp.float32)
    # Transform columns (the contraction dim): work on transposed views.
    if cfg.use_pallas:
        ar = rht_kernels.rht(a2.T, signs).T
        br = rht_kernels.rht(b2.T, signs).T
    else:
        ar = ref.rht(a2.T, signs).T
        br = ref.rht(b2.T, signs).T
    return ar, br


def qlinear(x, w, key, cfg: OpQuant):
    """Quantized linear y = x @ w with the CHON fwd/bwd recipe.

    x: (..., K); w: (K, N); key: PRNG key consumed by SR/RHT in backward.
    """
    return _qlinear(x, w, key, cfg)


import functools


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def _qlinear(x, w, key, cfg):
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    y = _forward_2d(x2, w, cfg)
    return y.reshape(*lead, w.shape[-1])


def _qlinear_fwd(x, w, key, cfg):
    return _qlinear(x, w, key, cfg), (x, w, key)


def _qlinear_bwd(cfg, res, gy):
    x, w, key = res
    lead = x.shape[:-1]
    kdim, n = w.shape
    x2 = x.reshape(-1, kdim)
    g2 = gy.reshape(-1, n).astype(jnp.float32)
    k_dgrad, k_wgrad_a, k_wgrad_b, k_rht = jax.random.split(key, 4)
    if cfg.mode == "bf16":
        dx = (g2 @ w.T).reshape(x.shape)
        dw = x2.T @ g2
        return dx, dw, None
    # Dgrad: dX = Q(dY) Q(W)^T
    gq = _bwd_quant(g2, cfg, k_dgrad)
    wq = _qdq_weight(w, cfg)
    dx = (gq @ wq.T).reshape(x.shape)
    # Wgrad: dW = Q(H X)^T Q(H dY) — RHT diffuses sparse outliers (App. C.3)
    xr, gr = _maybe_rht(x2.astype(jnp.float32), g2, cfg, k_rht)
    xrq = _bwd_quant(xr, cfg, k_wgrad_a)
    grq = _bwd_quant(gr, cfg, k_wgrad_b)
    dw = xrq.T @ grq
    return dx, dw, None


_qlinear.defvjp(_qlinear_fwd, _qlinear_bwd)


def fp8_qdq(x):
    """Per-tensor FP8 (e4m3) fake quantization, exposed for diagnostics."""
    amax = jnp.max(jnp.abs(x))
    s = jnp.where(amax > 0, ref.E4M3_MAX / amax, 1.0)
    return ref.e4m3_rtn(x * s) / s
