"""L2 Gated Linear Attention block (Yang et al. 2024; paper App. E.7).

Recurrence per head (Eq. 49–50):

    λ_t = σ(gk_t)^{1/γ}                        (log-sigmoid gate, γ=16)
    S_t = diag(λ_t) S_{t-1} + k_t v_tᵀ
    o_t = (q_t / √d_k)ᵀ S_t
    y_t = σ(g_t) ⊙ o_t                         (output gate, Eq. 48)

The asymmetric 1/√d_k scaling is applied to q only (the paper's §E.7
"Scaling Asymmetry" note — the k-projection's compensating magnitude
growth is one of the outlier mechanisms the diagnostics track).

All six projections (q, k, v, gk, g, o) are quantized linears; the o
projection and gk projection are the post-QK / gating protection targets
of the CHON recipe.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import quant

GLA_OPS = ("attn.q", "attn.k", "attn.v", "attn.gk", "attn.g", "attn.o")


def gla_attention(x, p, keys, cfgs, *, n_heads, gate_gamma=16.0,
                  collect=None, tag=""):
    """One GLA attention sub-block.

    x: (B, T, D). p: dict with wq/wk/wv/wgk (D, D), wg (D, D), wo (D, D),
    gk_bias (D,). Head dims d_k = d_v = D / n_heads.
    Returns (B, T, D).
    """
    b, t, d = x.shape
    h = n_heads
    dk = d // h

    q = quant.qlinear(x, p["wq"], keys["attn.q"], cfgs["attn.q"])
    k = quant.qlinear(x, p["wk"], keys["attn.k"], cfgs["attn.k"])
    v = quant.qlinear(x, p["wv"], keys["attn.v"], cfgs["attn.v"])
    gk = quant.qlinear(x, p["wgk"], keys["attn.gk"], cfgs["attn.gk"]) + p["gk_bias"]
    g = quant.qlinear(x, p["wg"], keys["attn.g"], cfgs["attn.g"])

    if collect is not None:
        collect[f"{tag}attn.q"] = q
        collect[f"{tag}attn.k"] = k
        collect[f"{tag}attn.v"] = v
        collect[f"{tag}attn.gk"] = gk
        collect[f"{tag}attn.g"] = g

    def split(z):
        return z.reshape(b, t, h, dk).transpose(1, 0, 2, 3)  # (T, B, H, dk)

    qh = split(q) / jnp.sqrt(jnp.asarray(dk, jnp.float32))
    kh = split(k)
    vh = split(v)
    # Decay: λ = exp(log σ(gk) / γ) = σ(gk)^{1/γ}  (App. E.7 Eq. 50)
    lam = jnp.exp(jax.nn.log_sigmoid(split(gk)) / gate_gamma)

    s0 = jnp.zeros((b, h, dk, dk), jnp.float32)

    def step(s, inp):
        q_t, k_t, v_t, lam_t = inp
        s = s * lam_t[..., None] + k_t[..., None] * v_t[..., None, :]
        o_t = jnp.einsum("bhd,bhdv->bhv", q_t, s)
        return s, o_t

    _, o = jax.lax.scan(step, s0, (qh, kh, vh, lam))
    o = o.transpose(1, 0, 2, 3).reshape(b, t, d)  # (B, T, D)
    o = o * jax.nn.sigmoid(g)
    y = quant.qlinear(o, p["wo"], keys["attn.o"], cfgs["attn.o"])
    if collect is not None:
        collect[f"{tag}attn.o"] = y
    return y


def gla_attention_ref(x, p, *, n_heads, gate_gamma=16.0):
    """Unquantized O(T²) reference (materialized decay products) for tests.

    Computes o_t = Σ_{i<=t} (∏_{j=i+1..t} λ_j) ⊙-weighted ⟨q_t, k_i⟩ v_i
    directly; must match the scan implementation with BF16 ops.
    """
    b, t, d = x.shape
    h = n_heads
    dk = d // h
    q = (x @ p["wq"]).reshape(b, t, h, dk) / jnp.sqrt(jnp.asarray(dk, jnp.float32))
    k = (x @ p["wk"]).reshape(b, t, h, dk)
    v = (x @ p["wv"]).reshape(b, t, h, dk)
    gk = (x @ p["wgk"] + p["gk_bias"]).reshape(b, t, h, dk)
    g = x @ p["wg"]
    lam = jnp.exp(jax.nn.log_sigmoid(gk) / gate_gamma)
    # cumulative log-decay along time: L_t = Σ_{j<=t} log λ_j
    loglam = jnp.log(jnp.maximum(lam, 1e-38))
    cum = jnp.cumsum(loglam, axis=1)  # (B,T,H,dk)
    outs = []
    for ti in range(t):
        # weights for source i <= ti: exp(cum_t - cum_i) elementwise on dk
        w_ti = jnp.exp(cum[:, ti : ti + 1] - cum[:, : ti + 1])  # (B,ti+1,H,dk)
        kk = k[:, : ti + 1] * w_ti
        scores = jnp.einsum("bhd,bihd->bih", q[:, ti], kk)
        o_t = jnp.einsum("bih,bihd->bhd", scores, v[:, : ti + 1])
        outs.append(o_t)
    o = jnp.stack(outs, axis=1).reshape(b, t, d)
    o = o * jax.nn.sigmoid(g)
    return o @ p["wo"]
