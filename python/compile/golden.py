"""Dump cross-language golden fixtures: the Python oracle's outputs for
deterministic inputs, consumed by the Rust test `golden_parity` to prove
the two NVFP4 implementations agree bit-for-bit (fake-quant path).

Run as part of `make artifacts`:  python -m compile.golden --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax.numpy as jnp
import numpy as np

from .kernels import ref


def _fmt(vals) -> str:
    return " ".join(repr(float(v)) for v in np.asarray(vals).reshape(-1))


def cases():
    rng = np.random.default_rng(0xC0DE)
    out = []

    # e2m1 rtn over a dense ramp + random values
    ramp = np.linspace(-7, 7, 113).astype(np.float32)
    out.append(("e2m1_rtn", ramp, ref.e2m1_rtn(jnp.array(ramp))))

    # e4m3 rtn over log-spaced magnitudes
    mags = np.concatenate(
        [
            np.geomspace(1e-5, 500, 77).astype(np.float32),
            -np.geomspace(1e-3, 448, 33).astype(np.float32),
            np.zeros(1, np.float32),
        ]
    )
    out.append(("e4m3_rtn", mags, ref.e4m3_rtn(jnp.array(mags))))

    # nvfp4 fake-quant: gaussian, heavy-tail, spiky, tiny-scale
    for name, x in [
        ("nvfp4_gauss", rng.normal(0, 2, 256).astype(np.float32)),
        ("nvfp4_heavy", rng.standard_t(2, 256).astype(np.float32) * 3),
        ("nvfp4_spiky", np.where(rng.random(256) < 0.02, 500.0, 0.05).astype(np.float32)),
        ("nvfp4_tiny", rng.normal(0, 1e-4, 256).astype(np.float32)),
    ]:
        out.append((name, x, ref.nvfp4_quant_dequant(jnp.array(x).reshape(1, -1))))

    # 2d weight scaling
    w = rng.normal(0, 1, (32, 64)).astype(np.float32)
    out.append(("nvfp4_2d", w, ref.nvfp4_quant_dequant_2d(jnp.array(w))))

    # mxfp4
    x = rng.normal(0, 1.5, 256).astype(np.float32)
    out.append(("mxfp4", x, ref.mxfp4_quant_dequant(jnp.array(x).reshape(1, -1))))

    # fwht (unnormalized)
    h = rng.normal(0, 1, 64).astype(np.float32)
    out.append(("fwht", h, ref.fwht(jnp.array(h).reshape(1, -1))))

    # kurtosis scalar
    k = rng.normal(0, 1, 4096).astype(np.float32)
    k[7] = 40.0
    out.append(("kurtosis", k, jnp.array([ref.kurtosis(jnp.array(k))])))
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    path = os.path.join(args.out, "golden_quant.txt")
    with open(path, "w") as f:
        for name, x, y in cases():
            f.write(f"case {name}\n")
            f.write(f"in {_fmt(x)}\n")
            f.write(f"out {_fmt(y)}\n")
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
