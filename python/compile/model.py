"""L2 model orchestrator: GLA / SA language models, training, diagnostics.

Defines the jax functions that aot.py lowers to HLO-text artifacts:

  init_fn    (seed)                                   -> params
  train_fn   (params, m, v, step, tokens, tgts, seed) -> (params', m', v',
                                                          loss, gnorm, lr)
  eval_fn    (params, tokens, tgts)                   -> (loss, acc)
  fwd_fn     (params, tokens)                         -> logits
  diag_fn    (params, tokens, seed)                   -> (metric vector,
                                                          channel-mag maps)

The diag vector's slot names come from ``diag_schema`` and are written to
the artifact manifest so the Rust monitor decodes the longitudinal series
without any Python on the request path.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import layers, quant, recipe as recipe_mod
from .gla import GLA_OPS, gla_attention
from .kernels import ref
from .softmax_attn import SA_OPS, softmax_attention


class ModelConfig(NamedTuple):
    name: str = "tiny_gla"
    arch: str = "gla"            # "gla" | "sa"
    vocab: int = 256
    d_model: int = 64
    n_layers: int = 2
    n_heads: int = 2
    d_ff: int = 176              # ~2.75x, multiple of 16
    seq_len: int = 64
    batch: int = 4
    gate_gamma: float = 16.0
    qk_norm: bool = True


class HyperConfig(NamedTuple):
    peak_lr: float = 1e-3
    warmup: int = 50
    total_steps: int = 400
    weight_decay: float = 0.1
    clip: float = 1.0
    b1: float = 0.9
    b2: float = 0.95


MLP_OPS = ("mlp.up", "mlp.gate", "mlp.down")


def arch_ops(arch: str) -> tuple[str, ...]:
    base = GLA_OPS if arch == "gla" else SA_OPS
    return tuple(base) + MLP_OPS


# --------------------------------------------------------------------------
# Parameters
# --------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Initialize the parameter pytree (dict-of-lists, deterministic order)."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    ks = iter(jax.random.split(key, 4 + cfg.n_layers * 16))

    def dense(shape, scale=0.02):
        return jax.random.normal(next(ks), shape, jnp.float32) * scale

    out_scale = 0.02 / (2.0 * cfg.n_layers) ** 0.5
    layers_p = []
    for _ in range(cfg.n_layers):
        p = {
            "attn_norm": jnp.ones((d,), jnp.float32),
            "ffn_norm": jnp.ones((d,), jnp.float32),
            "wq": dense((d, d)),
            "wk": dense((d, d)),
            "wv": dense((d, d)),
            "wo": dense((d, d), out_scale),
            "w_up": dense((d, f)),
            "w_gate": dense((d, f)),
            "w_down": dense((f, d), out_scale),
        }
        if cfg.arch == "gla":
            p["wgk"] = dense((d, d))
            p["wg"] = dense((d, d))
            # Spread initial decays: biases in [0, 3] -> λ ∈ (0.96, 0.996)
            p["gk_bias"] = jnp.linspace(0.0, 3.0, d, dtype=jnp.float32)
        else:
            dk = d // cfg.n_heads
            p["q_norm"] = jnp.ones((dk,), jnp.float32)
            p["k_norm"] = jnp.ones((dk,), jnp.float32)
        layers_p.append(p)
    return {
        "embed": dense((v, d)),
        "layers": layers_p,
        "final_norm": jnp.ones((d,), jnp.float32),
        "lm_head": dense((d, v)),
    }


def zeros_like_tree(tree):
    return jax.tree_util.tree_map(jnp.zeros_like, tree)


def param_count(cfg: ModelConfig) -> int:
    shapes = jax.eval_shape(lambda k: init_params(cfg, k), jax.random.PRNGKey(0))
    total = 0
    for leaf in jax.tree_util.tree_leaves(shapes):
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
    return total


# --------------------------------------------------------------------------
# Forward
# --------------------------------------------------------------------------

def _op_param_map(arch: str) -> dict[str, str]:
    m = {
        "attn.q": "wq", "attn.k": "wk", "attn.v": "wv", "attn.o": "wo",
        "mlp.up": "w_up", "mlp.gate": "w_gate", "mlp.down": "w_down",
    }
    if arch == "gla":
        m.update({"attn.gk": "wgk", "attn.g": "wg"})
    return m


def forward(params, tokens, key, cfg: ModelConfig, rcp, collect=None,
            op_cfg_override=None):
    """LM forward pass. tokens: (B, T) int32 -> logits (B, T, V).

    op_cfg_override: optional (arch, layer, n_layers, op) -> OpQuant used by
    the Tab. 3 single-operator sensitivity runs.
    """
    ops = arch_ops(cfg.arch)
    x = layers.embed(tokens, params["embed"])
    for li, p in enumerate(params["layers"]):
        if op_cfg_override is None:
            cfgs = recipe_mod.layer_cfgs(rcp, cfg.arch, li, cfg.n_layers, ops)
        else:
            cfgs = {op: op_cfg_override(cfg.arch, li, cfg.n_layers, op)
                    for op in ops}
        keys = {
            op: jax.random.fold_in(key, li * 131 + oi)
            for oi, op in enumerate(ops)
        }
        tag = f"L{li}."
        h = layers.rmsnorm(x, p["attn_norm"])
        if cfg.arch == "gla":
            attn_keys = {k: keys[k] for k in GLA_OPS}
            attn_cfgs = {k: cfgs[k] for k in GLA_OPS}
            a = gla_attention(
                h, p, attn_keys, attn_cfgs, n_heads=cfg.n_heads,
                gate_gamma=cfg.gate_gamma, collect=collect, tag=tag,
            )
        else:
            attn_keys = {k: keys[k] for k in SA_OPS}
            attn_cfgs = {k: cfgs[k] for k in SA_OPS}
            a = softmax_attention(
                h, p, attn_keys, attn_cfgs, n_heads=cfg.n_heads,
                qk_norm=cfg.qk_norm, collect=collect, tag=tag,
            )
        x = x + a
        h = layers.rmsnorm(x, p["ffn_norm"])
        ffn_keys = {k.split(".")[1]: keys[k] for k in MLP_OPS}
        ffn_cfgs = {k.split(".")[1]: cfgs[k] for k in MLP_OPS}
        x = x + layers.swiglu_ffn(
            h, p, ffn_keys, ffn_cfgs, collect=collect, tag=tag
        )
    x = layers.rmsnorm(x, params["final_norm"])
    return layers.lm_head(x, params["lm_head"])


def loss_fn(params, tokens, targets, key, cfg, rcp, op_cfg_override=None):
    logits = forward(params, tokens, key, cfg, rcp,
                     op_cfg_override=op_cfg_override)
    return layers.cross_entropy(logits, targets)


# --------------------------------------------------------------------------
# Training / eval steps (the AOT units)
# --------------------------------------------------------------------------

def make_train_fn(cfg: ModelConfig, rcp, hyper: HyperConfig,
                  op_cfg_override=None):
    """Build train_step(params, m, v, step, tokens, targets, seed)."""

    def train_step(params, m, v, step, tokens, targets, seed):
        key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
        key = jax.random.fold_in(key, step)
        loss, grads = jax.value_and_grad(loss_fn)(
            params, tokens, targets, key, cfg, rcp, op_cfg_override
        )
        grads, gnorm = layers.clip_by_global_norm(grads, hyper.clip)
        lr = layers.cosine_lr(step, hyper.peak_lr, hyper.warmup,
                              hyper.total_steps)
        params, m, v = layers.adamw_update(
            params, grads, m, v, step, lr=lr, b1=hyper.b1, b2=hyper.b2,
            weight_decay=hyper.weight_decay,
        )
        return params, m, v, loss, gnorm, lr

    return train_step


def make_eval_fn(cfg: ModelConfig, rcp):
    def eval_step(params, tokens, targets):
        key = jax.random.PRNGKey(0)  # fwd path has no stochastic ops
        logits = forward(params, tokens, key, cfg, rcp)
        loss = layers.cross_entropy(logits, targets)
        pred = jnp.argmax(logits, axis=-1)
        acc = jnp.mean((pred == targets).astype(jnp.float32))
        return loss, acc

    return eval_step


def make_fwd_fn(cfg: ModelConfig, rcp):
    def fwd(params, tokens):
        key = jax.random.PRNGKey(0)
        return forward(params, tokens, key, cfg, rcp)

    return fwd


# --------------------------------------------------------------------------
# Diagnostics (the Sec. 3 longitudinal monitor payload)
# --------------------------------------------------------------------------

ACT_STATS = ("kurt", "top1", "top3", "ftz", "qmse", "bkmin", "bkavg", "bkmax")
WT_STATS = ("kurt", "ftz", "qmse")


def diag_schema(cfg: ModelConfig) -> list[str]:
    """Names for every slot of the diag metric vector, in order."""
    ops = arch_ops(cfg.arch)
    names = []
    for li in range(cfg.n_layers):
        for op in ops:
            for s in ACT_STATS:
                names.append(f"L{li}.{op}.act.{s}")
        for op in ops:
            for s in WT_STATS:
                names.append(f"L{li}.{op}.wt.{s}")
        names.append(f"L{li}.mlp.alignment")
        if cfg.arch == "sa":
            names.append(f"L{li}.attn.presoftmax.kurt")
            names.append(f"L{li}.attn.presoftmax.max")
            names.append(f"L{li}.attn.postsoftmax.entropy")
    return names


# map op name -> collect tag used inside the blocks
_COLLECT_KEY = {
    "attn.q": "attn.q", "attn.k": "attn.k", "attn.v": "attn.v",
    "attn.gk": "attn.gk", "attn.g": "attn.g", "attn.o": "attn.o",
    "mlp.up": "mlp.u", "mlp.gate": "mlp.g", "mlp.down": "mlp.d",
}


def _act_stats(a):
    a2 = a.reshape(-1, a.shape[-1]).astype(jnp.float32)
    top = ref.topk_magnitude(a2, 3)
    # 16x16 block kurtosis map (Fig. 4): min/avg/max summary in-graph
    bk = ref.block_kurtosis(a2)
    return [
        ref.kurtosis(a2),
        top[0],
        top[2],
        ref.ftz_ratio(a2),
        ref.quant_mse(a2),
        jnp.min(bk),
        jnp.mean(bk),
        jnp.max(bk),
    ]


def _wt_stats(w):
    w2 = w.reshape(-1, w.shape[-1]).astype(jnp.float32)
    return [ref.kurtosis(w2), ref.ftz_ratio(w2), ref.quant_mse(w2)]


def make_diag_fn(cfg: ModelConfig, rcp):
    """diag(params, tokens, seed) -> (metrics, chan_o, chan_up[, chan_gk])."""
    ops = arch_ops(cfg.arch)
    pmap = _op_param_map(cfg.arch)

    def diag(params, tokens, seed):
        key = jax.random.fold_in(jax.random.PRNGKey(1), seed)
        collect: dict = {}
        forward(params, tokens, key, cfg, rcp, collect=collect)
        vals = []
        chan_o, chan_up, chan_gk = [], [], []
        for li in range(cfg.n_layers):
            tag = f"L{li}."
            for op in ops:
                vals.extend(_act_stats(collect[tag + _COLLECT_KEY[op]]))
            for op in ops:
                vals.extend(_wt_stats(params["layers"][li][pmap[op]]))
            vals.append(
                ref.cosine_alignment(
                    params["layers"][li]["w_up"].T,
                    params["layers"][li]["w_gate"].T,
                )
            )
            if cfg.arch == "sa":
                import numpy as _np

                pre = collect[tag + "attn.presoftmax"]
                post = collect[tag + "attn.postsoftmax"]
                t = pre.shape[-1]
                # concrete numpy mask: traced boolean indexing is not allowed
                mask = _np.tril(_np.ones((t, t), bool))
                flat = pre.reshape(-1, t, t)
                sel = flat[:, mask]  # causal-valid logits only
                vals.append(ref.kurtosis(sel))
                vals.append(jnp.max(jnp.abs(pre)))
                p = post
                h = -jnp.sum(p * jnp.log(jnp.maximum(p, 1e-30)), axis=-1)
                vals.append(jnp.mean(h))
            # per-channel max |act| maps (Fig. 3 hot channels)
            co = collect[tag + "attn.o"]
            cu = collect[tag + "mlp.u"]
            chan_o.append(jnp.max(jnp.abs(co.reshape(-1, co.shape[-1])), axis=0))
            chan_up.append(jnp.max(jnp.abs(cu.reshape(-1, cu.shape[-1])), axis=0))
            if cfg.arch == "gla":
                cg = collect[tag + "attn.gk"]
                chan_gk.append(
                    jnp.max(jnp.abs(cg.reshape(-1, cg.shape[-1])), axis=0)
                )
        metrics = jnp.stack([jnp.asarray(v, jnp.float32) for v in vals])
        outs = [metrics, jnp.stack(chan_o), jnp.stack(chan_up)]
        if cfg.arch == "gla":
            outs.append(jnp.stack(chan_gk))
        return tuple(outs)

    return diag
