"""L2 shared layers: RMSNorm, SwiGLU FFN, embeddings, AdamW, schedules.

Everything here is recipe-aware: linear projections route through
``quant.qlinear`` with a per-operator OpQuant resolved by the recipe
(embeddings, norms and the LM head always stay in high precision, per the
NVIDIA NVFP4 recipe and App. C.3 "Sensitive Ops in higher precision").
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import quant


def rmsnorm(x, gamma, eps: float = 1e-6):
    """RMSNorm with learnable scale γ (the Fig. 29 analysis object)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * gamma


def swiglu_ffn(x, p, keys, cfgs, collect=None, tag=""):
    """SwiGLU FFN: (x W_up) ⊙ Swish(x W_gate) W_down (Sec. 3.2).

    p: dict with w_up (D,F), w_gate (D,F), w_down (F,D).
    keys/cfgs: per-op PRNG keys and OpQuant configs keyed 'up','gate','down'.
    collect: optional dict to stash probe activations into (diag path).
    """
    up = quant.qlinear(x, p["w_up"], keys["up"], cfgs["up"])
    gate = quant.qlinear(x, p["w_gate"], keys["gate"], cfgs["gate"])
    act = up * jax.nn.silu(gate)
    down = quant.qlinear(act, p["w_down"], keys["down"], cfgs["down"])
    if collect is not None:
        collect[f"{tag}mlp.u"] = up
        collect[f"{tag}mlp.g"] = gate
        collect[f"{tag}mlp.d"] = down
    return down


def embed(tokens, table):
    """Token embedding lookup (always high precision)."""
    return table[tokens]


def lm_head(x, w):
    """Vocabulary projection (always high precision — final-layer rule)."""
    return x @ w


def cross_entropy(logits, targets):
    """Mean next-token cross-entropy. logits: (B,T,V); targets: (B,T)."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


# --------------------------------------------------------------------------
# AdamW + cosine schedule (in-graph training substrate)
# --------------------------------------------------------------------------

def cosine_lr(step, peak_lr, warmup, total, min_ratio: float = 0.1):
    """Linear warmup then cosine decay to min_ratio * peak (paper setup)."""
    step = step.astype(jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup, 1)
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, peak_lr * cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))


def clip_by_global_norm(tree, max_norm):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), gn


def adamw_update(params, grads, m, v, step, *, lr, b1=0.9, b2=0.95,
                 eps=1e-8, weight_decay=0.1):
    """One AdamW step (decoupled weight decay; paper hyperparameters)."""
    step_f = step.astype(jnp.float32) + 1.0
    bc1 = 1.0 - b1**step_f
    bc2 = 1.0 - b2**step_f

    def upd(p, g, m_, v_):
        m2 = b1 * m_ + (1 - b1) * g
        v2 = b2 * v_ + (1 - b2) * g * g
        mhat = m2 / bc1
        vhat = v2 / bc2
        p2 = p - lr * (mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p)
        return p2, m2, v2

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(m)
    flat_v = jax.tree_util.tree_leaves(v)
    out = [upd(p, g, m_, v_) for p, g, m_, v_ in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_p, new_m, new_v
