"""CHON / NVFP4 / BF16 recipe configs and per-operator precision assignment.

A Recipe is the Tab. 2 ablation unit. ``op_quant`` maps (recipe, layer,
op) -> OpQuant, encoding:

  * last-N-layer protection (NVIDIA recipe (i); "Last4" discussion §F.2)
  * post-QK protection (CHON): W_o + W_gk for LA, W_v for SA in BF16
  * SR / RHT / 2D-scaling toggles (recipe (iii)/(ii))
  * HCP channel fraction (paper: 9.09% of channels)
"""

from __future__ import annotations

from typing import NamedTuple

from .quant import BF16, OpQuant


class Recipe(NamedTuple):
    name: str = "nvfp4"
    mode: str = "nvfp4"          # bf16 | fp8 | nvfp4
    sr: bool = True              # stochastic rounding in backward
    rht: bool = True             # randomized Hadamard on Wgrad
    scaling_2d: bool = True      # 2D weight block scaling
    hcp_frac: float = 0.0        # HCP patched-channel fraction
    protect_last: int = 0        # keep last N layers fully BF16
    post_qk: bool = False        # protect post-QK ops (W_o/W_gk LA, W_v SA)
    use_pallas: bool = False     # route through L1 Pallas kernels


# The Tab. 2 ablation grid (+ baselines used by Tab. 1 / Tab. 8).
# protect_last is expressed in layers; aot.py clamps it to n_layers - 1.
HCP_FRAC = 0.0909  # 9.09% of channels (App. C.1)


def recipes(protect_last: int = 1) -> dict[str, Recipe]:
    pl = protect_last
    return {
        "bf16": Recipe("bf16", mode="bf16"),
        "fp8": Recipe("fp8", mode="fp8"),
        # NVIDIA et al. 2025 baseline recipe
        "nvfp4": Recipe("nvfp4", protect_last=pl),
        # full CHON = NVFP4 + HCP + post-QK protection
        "chon": Recipe(
            "chon", hcp_frac=HCP_FRAC, protect_last=pl, post_qk=True,
            use_pallas=True,
        ),
        "chon_no_sr": Recipe(
            "chon_no_sr", sr=False, hcp_frac=HCP_FRAC, protect_last=pl,
            post_qk=True,
        ),
        "chon_no_rht": Recipe(
            "chon_no_rht", rht=False, hcp_frac=HCP_FRAC, protect_last=pl,
            post_qk=True,
        ),
        "chon_no_2d": Recipe(
            "chon_no_2d", scaling_2d=False, hcp_frac=HCP_FRAC,
            protect_last=pl, post_qk=True,
        ),
        "chon_no_sr_rht": Recipe(
            "chon_no_sr_rht", sr=False, rht=False, hcp_frac=HCP_FRAC,
            protect_last=pl, post_qk=True,
        ),
        "chon_no_last4": Recipe(
            "chon_no_last4", hcp_frac=HCP_FRAC, protect_last=0, post_qk=True,
        ),
        # HCP without post-QK protection and without RHT
        # (Tab. 2 row "w/o chon, rht")
        "hcp_no_postqk_rht": Recipe(
            "hcp_no_postqk_rht", rht=False, hcp_frac=HCP_FRAC, protect_last=pl,
        ),
        # NVFP4 + HCP only (isolates HCP's contribution)
        "nvfp4_hcp": Recipe("nvfp4_hcp", hcp_frac=HCP_FRAC, protect_last=pl),
    }


# post-QK sensitive operators per architecture (Tab. 3 / Fig. 2)
POST_QK_OPS = {
    "gla": ("attn.o", "attn.gk"),
    "sa": ("attn.v",),
}


def op_quant(recipe: Recipe, arch: str, layer: int, n_layers: int,
             op: str) -> OpQuant:
    """Resolve the OpQuant for one linear operator in one layer."""
    if recipe.mode == "bf16":
        return BF16
    if recipe.protect_last > 0 and layer >= n_layers - recipe.protect_last:
        return BF16
    if recipe.post_qk and op in POST_QK_OPS.get(arch, ()):
        return BF16
    return OpQuant(
        mode=recipe.mode,
        scaling_2d=recipe.scaling_2d,
        sr=recipe.sr,
        rht=recipe.rht,
        hcp_frac=recipe.hcp_frac,
        use_pallas=recipe.use_pallas,
    )


def layer_cfgs(recipe: Recipe, arch: str, layer: int, n_layers: int,
               ops: tuple[str, ...]) -> dict[str, OpQuant]:
    return {op: op_quant(recipe, arch, layer, n_layers, op) for op in ops}


def sensitivity_recipe(base: Recipe, quantize_only: str) -> Recipe:
    """Tab. 3 operator-sensitivity mode: marker recipe that quantizes a
    single operator, everything else BF16 (resolved in op_quant_single)."""
    return base._replace(name=f"only_{quantize_only.replace('.', '_')}")


def op_quant_single(recipe: Recipe, target_op: str, op: str) -> OpQuant:
    """Per-op resolution for the single-operator sensitivity ablation."""
    if op != target_op:
        return BF16
    return OpQuant(
        mode=recipe.mode, scaling_2d=recipe.scaling_2d, sr=recipe.sr,
        rht=recipe.rht, hcp_frac=recipe.hcp_frac, use_pallas=False,
    )
