"""L2 Softmax Attention block (Qwen-style MHA, RoPE, optional QK-norm).

This is the SA comparator of the paper's architecture study: the softmax
normalization constraint is the outlier source (Sec. 3.2, Fig. 7), the
value projection is the sensitive post-QK operator (Tab. 3). The diag path
collects pre-softmax logits and post-softmax probabilities so the monitor
can track pre-softmax kurtosis / max and post-softmax entropy.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from . import quant
from .layers import rmsnorm

SA_OPS = ("attn.q", "attn.k", "attn.v", "attn.o")


def rope(x, *, base: float = 10000.0):
    """Rotary position embedding over head dim pairs. x: (B, T, H, dk)."""
    b, t, h, dk = x.shape
    half = dk // 2
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    inv = base ** (-jnp.arange(half, dtype=jnp.float32) / half)
    ang = pos * inv  # (T, half)
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def softmax_attention(x, p, keys, cfgs, *, n_heads, qk_norm=True,
                      collect=None, tag=""):
    """One causal MHA sub-block. x: (B, T, D); returns (B, T, D)."""
    b, t, d = x.shape
    h = n_heads
    dk = d // h

    q = quant.qlinear(x, p["wq"], keys["attn.q"], cfgs["attn.q"])
    k = quant.qlinear(x, p["wk"], keys["attn.k"], cfgs["attn.k"])
    v = quant.qlinear(x, p["wv"], keys["attn.v"], cfgs["attn.v"])
    if collect is not None:
        collect[f"{tag}attn.q"] = q
        collect[f"{tag}attn.k"] = k
        collect[f"{tag}attn.v"] = v

    qh = q.reshape(b, t, h, dk)
    kh = k.reshape(b, t, h, dk)
    vh = v.reshape(b, t, h, dk)
    if qk_norm:
        # Qwen3-style per-head RMS QK normalization (outlier suppressor).
        qh = rmsnorm(qh, p["q_norm"])
        kh = rmsnorm(kh, p["k_norm"])
    qh = rope(qh)
    kh = rope(kh)

    logits = jnp.einsum("bihd,bjhd->bhij", qh, kh) / jnp.sqrt(
        jnp.asarray(dk, jnp.float32)
    )
    mask = jnp.tril(jnp.ones((t, t), bool))
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    if collect is not None:
        # Probe only the causal-valid entries for entropy/kurtosis stats.
        collect[f"{tag}attn.presoftmax"] = jnp.where(mask[None, None], logits, 0.0)
        collect[f"{tag}attn.postsoftmax"] = probs
    o = jnp.einsum("bhij,bjhd->bihd", probs, vh).reshape(b, t, d)
    y = quant.qlinear(o, p["wo"], keys["attn.o"], cfgs["attn.o"])
    if collect is not None:
        collect[f"{tag}attn.o"] = y
    return y
