"""AOT pipeline: lower every (model, recipe) unit to HLO **text** artifacts.

HLO text (NOT ``lowered.compile()`` / ``.serialize()``) is the interchange
format: jax >= 0.5 emits HloModuleProtos with 64-bit instruction ids that
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/load_hlo.

Each artifact ``<name>.hlo.txt`` ships with ``<name>.manifest.txt``
describing its positional inputs/outputs (flattened pytree order — the
order PJRT sees) plus model/recipe metadata, so the Rust coordinator is
fully self-describing at runtime. ``artifacts/index.txt`` lists everything.

Usage (from python/):  python -m compile.aot --out ../artifacts [--set full]
"""

from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from . import recipe as recipe_mod
from .model import HyperConfig, ModelConfig

# --------------------------------------------------------------------------
# Build matrix
# --------------------------------------------------------------------------

MODELS = {
    # tiny: ablation workhorse (Tab. 2/3, Figs. 5-8, 12, 26/27, 32)
    "tiny_gla": ModelConfig(
        name="tiny_gla", arch="gla", vocab=256, d_model=64, n_layers=2,
        n_heads=2, d_ff=176, seq_len=64, batch=4,
    ),
    "tiny_sa": ModelConfig(
        name="tiny_sa", arch="sa", vocab=256, d_model=64, n_layers=2,
        n_heads=2, d_ff=176, seq_len=64, batch=4,
    ),
    # small: the end-to-end example scale (examples/train_gla_e2e)
    "small_gla": ModelConfig(
        name="small_gla", arch="gla", vocab=512, d_model=128, n_layers=4,
        n_heads=4, d_ff=352, seq_len=128, batch=8,
    ),
    "small_sa": ModelConfig(
        name="small_sa", arch="sa", vocab=512, d_model=128, n_layers=4,
        n_heads=4, d_ff=352, seq_len=128, batch=8,
    ),
}

HYPERS = {
    "tiny_gla": HyperConfig(peak_lr=1e-3, warmup=40, total_steps=300),
    "tiny_sa": HyperConfig(peak_lr=1e-3, warmup=40, total_steps=300),
    "small_gla": HyperConfig(peak_lr=8e-4, warmup=60, total_steps=400),
    "small_sa": HyperConfig(peak_lr=8e-4, warmup=60, total_steps=400),
}

# Which recipes get a train artifact per model (Tab. 2 grid on tiny_gla).
TRAIN_RECIPES = {
    "tiny_gla": [
        "bf16", "fp8", "nvfp4", "nvfp4_hcp", "chon", "chon_no_sr",
        "chon_no_rht", "chon_no_2d", "chon_no_sr_rht", "chon_no_last4",
        "hcp_no_postqk_rht",
    ],
    "tiny_sa": ["bf16", "fp8", "nvfp4", "chon"],
    "small_gla": ["bf16", "fp8", "nvfp4", "chon"],
    "small_sa": ["bf16", "nvfp4", "chon"],
}

# Single-operator sensitivity (Tab. 3): nvfp4 on one op, BF16 elsewhere.
SENSITIVITY_MODELS = ("tiny_gla", "tiny_sa")

SETS = {
    # "test": the minimum for `make test` + examples/quickstart
    "test": {"models": ["tiny_gla"], "train": ["bf16", "nvfp4", "chon"],
             "sensitivity": False},
    # "core": everything the Tab. 2 ablation + diagnostics need
    "core": {"models": ["tiny_gla", "tiny_sa"], "train": None,
             "sensitivity": True},
    # "full": core + the e2e small models
    "full": {"models": list(MODELS), "train": None, "sensitivity": True},
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _flat_names(tree, prefix):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = prefix + jax.tree_util.keystr(path)
        out.append((name, leaf))
    return out


def _dtype_tag(x):
    return {"float32": "f32", "int32": "i32", "uint32": "u32"}.get(
        str(jnp.asarray(x).dtype), str(jnp.asarray(x).dtype)
    )


def _aval_line(kind, i, name, leaf):
    arr = jnp.asarray(leaf) if not hasattr(leaf, "shape") else leaf
    dims = ",".join(str(d) for d in arr.shape) if len(arr.shape) else "scalar"
    dt = {"float32": "f32", "int32": "i32", "uint32": "u32"}.get(
        str(arr.dtype), str(arr.dtype)
    )
    return f"{kind} {i} {name} {dt} {dims}"


def emit(out_dir, name, fn, example_args, arg_names, meta, metrics=None):
    """Lower fn at example_args; write <name>.hlo.txt + manifest."""
    t0 = time.time()
    lowered = jax.jit(fn, keep_unused=True).lower(*example_args)
    text = to_hlo_text(lowered)
    with open(os.path.join(out_dir, f"{name}.hlo.txt"), "w") as f:
        f.write(text)
    lines = [f"artifact {name}"]
    for k, v in meta.items():
        lines.append(f"{k} {v}")
    idx = 0
    for arg, aname in zip(example_args, arg_names):
        for n, leaf in _flat_names(arg, aname):
            lines.append(_aval_line("input", idx, n, leaf))
            idx += 1
    out_shape = jax.eval_shape(fn, *example_args)
    idx = 0
    for n, leaf in _flat_names(out_shape, "out"):
        lines.append(_aval_line("output", idx, n, leaf))
        idx += 1
    if metrics:
        for m in metrics:
            lines.append(f"metric {m}")
    with open(os.path.join(out_dir, f"{name}.manifest.txt"), "w") as f:
        f.write("\n".join(lines) + "\n")
    dt = time.time() - t0
    print(f"  {name}: {len(text)/1e6:.2f} MB HLO in {dt:.1f}s", flush=True)
    return name


def model_meta(cfg: ModelConfig, hyper: HyperConfig, kind, recipe_name):
    return {
        "kind": kind,
        "model": cfg.name,
        "arch": cfg.arch,
        "recipe": recipe_name,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "d_ff": cfg.d_ff,
        "seq_len": cfg.seq_len,
        "batch": cfg.batch,
        "total_steps": hyper.total_steps,
        "warmup": hyper.warmup,
        "peak_lr": hyper.peak_lr,
    }


def make_init_fn(cfg: ModelConfig):
    def init(seed):
        return model_mod.init_params(
            cfg, jax.random.fold_in(jax.random.PRNGKey(0), seed)
        )

    return init


def build(out_dir: str, which: str) -> list[str]:
    sel = SETS[which]
    os.makedirs(out_dir, exist_ok=True)
    emitted = []
    for mname in sel["models"]:
        cfg = MODELS[mname]
        hyper = HYPERS[mname]
        protect = 1 if cfg.n_layers <= 4 else 4
        rcps = recipe_mod.recipes(protect_last=protect)
        train_list = sel["train"] or TRAIN_RECIPES[mname]

        params_shapes = jax.eval_shape(
            lambda k: model_mod.init_params(cfg, k), jax.random.PRNGKey(0)
        )
        params_ex = jax.tree_util.tree_map(
            lambda s: jnp.zeros(s.shape, s.dtype), params_shapes
        )
        mopt = model_mod.zeros_like_tree(params_ex)
        tokens = jnp.zeros((cfg.batch, cfg.seq_len), jnp.int32)
        step = jnp.int32(0)
        seed = jnp.int32(0)

        print(f"[{mname}] params={model_mod.param_count(cfg):,}", flush=True)

        emitted.append(emit(
            out_dir, f"init_{mname}", make_init_fn(cfg),
            (seed,), ("seed",),
            model_meta(cfg, hyper, "init", "-"),
        ))

        # diag artifacts: flagship recipe + bf16 comparison
        for rname in ("chon", "bf16"):
            emitted.append(emit(
                out_dir, f"diag_{mname}_{rname}",
                model_mod.make_diag_fn(cfg, rcps[rname]),
                (params_ex, tokens, seed), ("params", "tokens", "seed"),
                model_meta(cfg, hyper, "diag", rname),
                metrics=model_mod.diag_schema(cfg),
            ))
        emitted.append(emit(
            out_dir, f"fwd_{mname}",
            model_mod.make_fwd_fn(cfg, rcps["chon"]),
            (params_ex, tokens), ("params", "tokens"),
            model_meta(cfg, hyper, "fwd", "chon"),
        ))
        for rname in sorted(set(train_list) & {"bf16", "fp8", "nvfp4", "chon"}):
            emitted.append(emit(
                out_dir, f"eval_{mname}_{rname}",
                model_mod.make_eval_fn(cfg, rcps[rname]),
                (params_ex, tokens, tokens), ("params", "tokens", "targets"),
                model_meta(cfg, hyper, "eval", rname),
            ))

        # train artifacts
        for rname in train_list:
            emitted.append(emit(
                out_dir, f"train_{mname}_{rname}",
                model_mod.make_train_fn(cfg, rcps[rname], hyper),
                (params_ex, mopt, mopt, step, tokens, tokens, seed),
                ("params", "m", "v", "step", "tokens", "targets", "seed"),
                model_meta(cfg, hyper, "train", rname),
            ))

        # single-operator sensitivity (Tab. 3)
        if sel["sensitivity"] and mname in SENSITIVITY_MODELS:
            base = rcps["nvfp4"]._replace(protect_last=0)
            for op in model_mod.arch_ops(cfg.arch):
                tag = op.replace(".", "_")

                def override(arch, layer, n_layers, o, _target=op):
                    return recipe_mod.op_quant_single(base, _target, o)

                emitted.append(emit(
                    out_dir, f"train_{mname}_only_{tag}",
                    model_mod.make_train_fn(cfg, base, hyper,
                                            op_cfg_override=override),
                    (params_ex, mopt, mopt, step, tokens, tokens, seed),
                    ("params", "m", "v", "step", "tokens", "targets", "seed"),
                    model_meta(cfg, hyper, "train", f"only_{tag}"),
                ))
    with open(os.path.join(out_dir, "index.txt"), "w") as f:
        f.write("\n".join(emitted) + "\n")
    return emitted


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="test", choices=list(SETS))
    args = ap.parse_args()
    t0 = time.time()
    emitted = build(args.out, args.set)
    print(f"emitted {len(emitted)} artifacts in {time.time()-t0:.0f}s")


if __name__ == "__main__":
    main()
