"""NVFP4 microscaling properties: error bounds, FTZ, 2D scaling, MXFP4."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref


def _randn(shape, seed=0, scale=1.0):
    return jnp.array(np.random.default_rng(seed).normal(0, scale, shape), jnp.float32)


def test_roundtrip_relative_error_bound():
    """Per-element error <= half lattice gap at block amax: |e| <= amax_b/8
    (gap at the top of the E2M1 range is 2 out of 6) plus e4m3 scale error."""
    x = _randn((64, 256), seed=1, scale=3.0)
    d = ref.nvfp4_quant_dequant(x)
    xb = np.asarray(x).reshape(64, 16, 16)
    db = np.asarray(d).reshape(64, 16, 16)
    amax_b = np.abs(xb).max(-1, keepdims=True)
    # gap/2 = amax/6 * 2 / 2 = amax/6; e4m3 scale rel error <= 2^-4 -> pad.
    bound = amax_b / 6.0 * (1 + 2.0**-3) + 1e-7
    assert np.all(np.abs(xb - db) <= bound)


def test_zero_tensor():
    x = jnp.zeros((8, 32), jnp.float32)
    d = ref.nvfp4_quant_dequant(x)
    assert float(jnp.max(jnp.abs(d))) == 0.0
    assert float(ref.ftz_ratio(x)) == 0.0


def test_single_outlier_saturates_its_block_only():
    x = np.full((1, 64), 0.01, np.float32)
    x[0, 5] = 1000.0  # hot element in block 0
    d = np.asarray(ref.nvfp4_quant_dequant(jnp.array(x)))
    # Other blocks (16..64) keep their small values representable.
    assert np.all(np.abs(d[0, 16:] - 0.01) / 0.01 < 0.25)
    # Block 0's small values flush to zero (they're < amax/6/2 of the block).
    assert np.all(d[0, :5] == 0.0)
    assert d[0, 5] == pytest.approx(1000.0, rel=0.07)


def test_ftz_increases_with_dynamic_range():
    rng = np.random.default_rng(7)
    base = rng.normal(0, 1, (32, 256)).astype(np.float32)
    mild = base.copy()
    spiky = base.copy()
    spiky[:, 0] *= 300.0  # inject per-block outliers -> small values flushed
    f_mild = float(ref.ftz_ratio(jnp.array(mild)))
    f_spiky = float(ref.ftz_ratio(jnp.array(spiky)))
    assert f_spiky > f_mild


def test_scales_storable_in_e4m3():
    """Stored block scales must lie in the representable e4m3 range (Rmk C.2)."""
    x = _randn((16, 256), seed=3, scale=50.0)
    _, _, s = ref.nvfp4_scales(x)
    s = np.asarray(s)
    assert np.all(s <= 448.0)
    assert np.all(s >= 0.0)


def test_2d_equals_1d_when_tile_is_one():
    x = _randn((32, 64), seed=4)
    a = ref.nvfp4_quant_dequant_2d(x, tile=1)
    b = ref.nvfp4_quant_dequant(x)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=0)


def test_2d_coarser_than_1d():
    """16x16 shared scales can't be more accurate than per-row scales."""
    x = _randn((64, 256), seed=5, scale=2.0)
    e1 = float(jnp.mean((x - ref.nvfp4_quant_dequant(x)) ** 2))
    e2 = float(jnp.mean((x - ref.nvfp4_quant_dequant_2d(x)) ** 2))
    assert e2 >= e1 * 0.999


def test_2d_handles_row_padding():
    x = _randn((19, 64), seed=6)
    d = ref.nvfp4_quant_dequant_2d(x, tile=16)
    assert d.shape == x.shape
    assert np.isfinite(np.asarray(d)).all()


def test_sr_unbiased_through_full_pipeline():
    x = _randn((4, 64), seed=8)
    n = 3000
    rng = np.random.default_rng(9)
    acc = np.zeros((4, 64), np.float64)
    for i in range(n):
        u = jnp.array(rng.random((4, 64)).astype(np.float32))
        acc += np.asarray(ref.nvfp4_quant_dequant(x, rounding="sr", u=u))
    mean = acc / n
    # bias should be well under the RTN error scale
    err = np.abs(mean - np.asarray(x))
    amax_b = np.abs(np.asarray(x)).reshape(4, 4, 16).max(-1, keepdims=True)
    np.testing.assert_array_less(err, np.broadcast_to(amax_b / 6, (4, 4, 16)).reshape(4, 64) + 0.02)


def test_mxfp4_roundtrip():
    x = _randn((8, 128), seed=10, scale=2.0)
    d = ref.mxfp4_quant_dequant(x)
    assert d.shape == x.shape
    # power-of-two scales: lattice error <= s_dec (half the top gap of 2),
    # clamp error <= 2*s_dec for magnitudes in (6,8)*s_dec; s_dec <= amax/4.
    xb = np.asarray(x).reshape(8, 4, 32)
    amax_b = np.abs(xb).max(-1, keepdims=True)
    db = np.asarray(d).reshape(8, 4, 32)
    assert np.all(np.abs(xb - db) <= amax_b / 2.0 + 1e-7)


def test_nvfp4_beats_mxfp4_on_gaussian():
    """Two-level scaling should (on average) beat power-of-two block scales."""
    x = _randn((64, 512), seed=11, scale=1.7)
    e_nv = float(jnp.mean((x - ref.nvfp4_quant_dequant(x)) ** 2))
    e_mx = float(jnp.mean((x - ref.mxfp4_quant_dequant(x)) ** 2))
    assert e_nv < e_mx


@settings(max_examples=25, deadline=None)
@given(
    rows=st.integers(1, 33),
    blocks=st.integers(1, 8),
    scale=st.floats(1e-3, 1e3),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_roundtrip_bounded(rows, blocks, scale, seed):
    """Sweep shapes/scales: dequant error bounded, no NaN/Inf, lattice-valued."""
    n = blocks * 16
    x = jnp.array(
        np.random.default_rng(seed).normal(0, scale, (rows, n)).astype(np.float32)
    )
    d = ref.nvfp4_quant_dequant(x)
    assert np.isfinite(np.asarray(d)).all()
    xb = np.asarray(x).reshape(rows, blocks, 16)
    db = np.asarray(d).reshape(rows, blocks, 16)
    amax_b = np.abs(xb).max(-1, keepdims=True)
    assert np.all(np.abs(xb - db) <= amax_b / 6.0 * (1 + 2.0**-3) + 1e-30)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), heavy=st.booleans())
def test_hypothesis_ftz_in_unit_range(seed, heavy):
    rng = np.random.default_rng(seed)
    x = rng.standard_t(2 if heavy else 50, (16, 64)).astype(np.float32)
    f = float(ref.ftz_ratio(jnp.array(x)))
    assert 0.0 <= f <= 1.0
