"""L2 model tests: GLA recurrence vs O(T²) reference, shapes, training."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import gla, layers, model, quant, recipe


CFG = model.ModelConfig()  # tiny_gla defaults
RCPS = recipe.recipes(protect_last=1)


def _params(cfg=CFG, seed=0):
    return model.init_params(cfg, jax.random.PRNGKey(seed))


def _tokens(cfg=CFG, seed=0):
    rng = np.random.default_rng(seed)
    t = rng.integers(0, cfg.vocab, (cfg.batch, cfg.seq_len), dtype=np.int32)
    return jnp.array(t)


def test_gla_scan_matches_quadratic_reference():
    rng = np.random.default_rng(0)
    b, t, d, h = 2, 16, 32, 2
    x = jnp.array(rng.normal(0, 1, (b, t, d)).astype(np.float32))
    p = {
        "wq": jnp.array(rng.normal(0, 0.2, (d, d)).astype(np.float32)),
        "wk": jnp.array(rng.normal(0, 0.2, (d, d)).astype(np.float32)),
        "wv": jnp.array(rng.normal(0, 0.2, (d, d)).astype(np.float32)),
        "wgk": jnp.array(rng.normal(0, 0.2, (d, d)).astype(np.float32)),
        "wg": jnp.array(rng.normal(0, 0.2, (d, d)).astype(np.float32)),
        "wo": jnp.array(rng.normal(0, 0.2, (d, d)).astype(np.float32)),
        "gk_bias": jnp.zeros((d,), jnp.float32),
    }
    keys = {op: jax.random.PRNGKey(i) for i, op in enumerate(gla.GLA_OPS)}
    cfgs = {op: quant.BF16 for op in gla.GLA_OPS}
    got = gla.gla_attention(x, p, keys, cfgs, n_heads=h)
    want = gla.gla_attention_ref(x, p, n_heads=h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_forward_shapes_gla_and_sa():
    for arch in ("gla", "sa"):
        cfg = CFG._replace(arch=arch, name=f"t_{arch}")
        p = model.init_params(cfg, jax.random.PRNGKey(0))
        logits = model.forward(
            p, _tokens(cfg), jax.random.PRNGKey(1), cfg, RCPS["bf16"]
        )
        assert logits.shape == (cfg.batch, cfg.seq_len, cfg.vocab)
        assert np.isfinite(np.asarray(logits)).all()


@pytest.mark.parametrize("rname", ["bf16", "fp8", "nvfp4", "chon"])
def test_loss_finite_all_recipes(rname):
    p = _params()
    loss = model.loss_fn(
        p, _tokens(), _tokens(seed=1), jax.random.PRNGKey(0), CFG, RCPS[rname]
    )
    assert np.isfinite(float(loss))
    # random init: loss near ln(vocab)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0


def test_quantized_loss_close_to_bf16_at_init():
    p = _params()
    tk, tg = _tokens(), _tokens(seed=1)
    lb = float(model.loss_fn(p, tk, tg, jax.random.PRNGKey(0), CFG, RCPS["bf16"]))
    ln = float(model.loss_fn(p, tk, tg, jax.random.PRNGKey(0), CFG, RCPS["nvfp4"]))
    assert abs(lb - ln) / lb < 0.05


def test_train_step_decreases_loss():
    hyper = model.HyperConfig(peak_lr=2e-3, warmup=5, total_steps=60)
    ts = jax.jit(model.make_train_fn(CFG, RCPS["nvfp4"], hyper))
    p = _params()
    m = model.zeros_like_tree(p)
    v = model.zeros_like_tree(p)
    rng = np.random.default_rng(3)
    losses = []
    for step in range(30):
        tk = jnp.array(
            rng.integers(0, CFG.vocab, (CFG.batch, CFG.seq_len), dtype=np.int32)
        )
        # learnable: predict same token (degenerate but fine for smoke)
        tg = tk
        p, m, v, loss, gnorm, lr = ts(
            p, m, v, jnp.int32(step), tk, tg, jnp.int32(0)
        )
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 1.0, losses[::6]
    assert all(np.isfinite(losses))


def test_post_qk_protection_resolution():
    r = RCPS["chon"]
    # GLA: o and gk protected
    assert recipe.op_quant(r, "gla", 0, 4, "attn.o").mode == "bf16"
    assert recipe.op_quant(r, "gla", 0, 4, "attn.gk").mode == "bf16"
    assert recipe.op_quant(r, "gla", 0, 4, "attn.q").mode == "nvfp4"
    # SA: v protected
    assert recipe.op_quant(r, "sa", 0, 4, "attn.v").mode == "bf16"
    assert recipe.op_quant(r, "sa", 0, 4, "attn.o").mode == "nvfp4"
    # last layer protected (protect_last=1)
    assert recipe.op_quant(r, "gla", 3, 4, "mlp.up").mode == "bf16"
    # nvfp4 baseline has no post-qk protection
    assert recipe.op_quant(RCPS["nvfp4"], "gla", 0, 4, "attn.o").mode == "nvfp4"


def test_single_op_sensitivity_resolution():
    base = RCPS["nvfp4"]
    assert recipe.op_quant_single(base, "attn.v", "attn.v").mode == "nvfp4"
    assert recipe.op_quant_single(base, "attn.v", "attn.q").mode == "bf16"


def test_bf16_gradients_match_autodiff():
    """qlinear BF16 path must be gradient-exact vs plain matmul model."""
    p = _params()
    tk, tg = _tokens(), _tokens(seed=2)

    def loss_q(p):
        return model.loss_fn(p, tk, tg, jax.random.PRNGKey(0), CFG, RCPS["bf16"])

    g = jax.grad(loss_q)(p)
    # finite-difference check one scalar direction
    leaf = g["layers"][0]["wq"]
    eps = 1e-3
    p2 = jax.tree_util.tree_map(lambda x: x, p)
    p2["layers"][0]["wq"] = p["layers"][0]["wq"].at[0, 0].add(eps)
    df = (float(loss_q(p2)) - float(loss_q(p))) / eps
    assert abs(df - float(leaf[0, 0])) < 5e-2, (df, float(leaf[0, 0]))


def test_diag_schema_matches_output_length():
    for arch in ("gla", "sa"):
        cfg = CFG._replace(arch=arch)
        p = model.init_params(cfg, jax.random.PRNGKey(0))
        d = model.make_diag_fn(cfg, RCPS["chon"])
        outs = d(p, _tokens(cfg), jnp.int32(0))
        assert outs[0].shape[0] == len(model.diag_schema(cfg))
        n_maps = 3 if arch == "gla" else 2
        assert len(outs) == 1 + n_maps


def test_cosine_lr_schedule():
    lr0 = float(layers.cosine_lr(jnp.int32(0), 1e-3, 10, 100))
    lrw = float(layers.cosine_lr(jnp.int32(10), 1e-3, 10, 100))
    lre = float(layers.cosine_lr(jnp.int32(100), 1e-3, 10, 100))
    assert lr0 < 1e-4
    assert abs(lrw - 1e-3) < 1e-6
    assert abs(lre - 1e-4) < 1e-6  # min_ratio 0.1


def test_param_count_sane():
    n = model.param_count(CFG)
    assert 100_000 < n < 200_000
