"""HCP theory checks: Lemmas A.3–A.9, MSE ordering (Thm A.12), scoring."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


def _pair(m=32, kdim=128, n=64, seed=0, scale=2.0):
    rng = np.random.default_rng(seed)
    x = jnp.array(rng.normal(0, scale, (m, kdim)).astype(np.float32))
    w = jnp.array(rng.normal(0, 1, (kdim, n)).astype(np.float32))
    return x, w


def _mses(x, w, k, idx=None):
    y_true = np.asarray(x @ w)
    out = {}
    for order in ("none", "o1a", "o1w", "o2"):
        y, idx = ref.hcp_matmul(x, w, k, order=order, idx=idx)
        out[order] = float(np.mean((np.asarray(y) - y_true) ** 2))
    return out


def test_mse_ordering_matches_theorem():
    """MSE(O2) < MSE(O1 single-sided) < MSE(baseline) on all-channel patch.

    With I = all channels, Lemma A.4/A.5 are exact: o1a error = -ΔWᵀX,
    o2 error = +ΔWᵀΔX, baseline stacks all three terms.
    """
    x, w = _pair(seed=1)
    k = x.shape[1]  # patch everything -> lemma regime
    m = _mses(x, w, k, idx=jnp.arange(k))
    assert m["o2"] < m["o1a"] < m["none"]
    assert m["o2"] < m["o1w"] < m["none"]


def test_mse_ordering_with_topk_patch():
    """With a partial (top-k) patch the ordering still holds on average."""
    x, w = _pair(seed=2)
    m = _mses(x, w, k=24)
    assert m["o2"] <= m["o1a"] + 1e-9 or m["o2"] <= m["o1w"] + 1e-9
    assert m["o2"] < m["none"]


def test_error_decomposition_exact():
    """Prop 4.1: ŴᵀX̂ = WᵀX + WᵀΔX' + ΔW'ᵀX + ΔW'ᵀΔX' with Δ' = q - full.

    (Using X̂ = X + ΔX' convention of Sec. 4; ref stores Δ = X - X̂.)
    """
    x, w = _pair(m=16, kdim=64, n=32, seed=3)
    xq = ref.nvfp4_quant_dequant(x)
    wq = ref.nvfp4_quant_dequant_2d(w.T).T
    dxp = xq - x
    dwp = wq - w
    lhs = np.asarray(xq @ wq)
    rhs = np.asarray(x @ w + x @ dwp + dxp @ w + dxp @ dwp)
    np.testing.assert_allclose(lhs, rhs, atol=1e-3)


def test_second_order_residual_identity():
    """Lemma A.5 / Eq. (3): full patch output == WᵀX - ΔWᵀΔX exactly."""
    x, w = _pair(m=8, kdim=32, n=16, seed=4)
    xq = ref.nvfp4_quant_dequant(x)
    wq = ref.nvfp4_quant_dequant_2d(w.T).T
    dx, dw = x - xq, w - wq
    y, _ = ref.hcp_matmul(x, w, k=32, idx=jnp.arange(32))
    want = np.asarray(x @ w - dx @ dw)
    np.testing.assert_allclose(np.asarray(y), want, atol=1e-3)


def test_scores_find_planted_hot_channel():
    rng = np.random.default_rng(5)
    x = rng.normal(0, 1, (64, 128)).astype(np.float32)
    w = rng.normal(0, 1, (128, 32)).astype(np.float32)
    x[:, 77] *= 80.0  # plant an activation hot channel
    w[13, :] *= 60.0  # plant a weight hot channel
    x, w = jnp.array(x), jnp.array(w)
    xq = ref.nvfp4_quant_dequant(x)
    wq = ref.nvfp4_quant_dequant_2d(w.T).T
    s = ref.hcp_scores(x - xq, w - wq)
    top = set(np.asarray(ref.topk_channels(s, 4)).tolist())
    assert 77 in top
    assert 13 in top


def test_more_patched_channels_monotone_mse():
    """MSE decreases (weakly) as the patch set grows along the score order."""
    x, w = _pair(seed=6, scale=3.0)
    y_true = np.asarray(x @ w)
    xq = ref.nvfp4_quant_dequant(x)
    wq = ref.nvfp4_quant_dequant_2d(w.T).T
    order = np.asarray(
        ref.topk_channels(ref.hcp_scores(x - xq, w - wq), x.shape[1])
    )
    prev = None
    for k in (0, 8, 32, 128):
        idx = jnp.array(order[:k], jnp.int32) if k else None
        y, _ = ref.hcp_matmul(x, w, k, order="o2" if k else "none", idx=idx)
        mse = float(np.mean((np.asarray(y) - y_true) ** 2))
        if prev is not None:
            assert mse <= prev * 1.001
        prev = mse


def test_precomputed_indices_equal_fresh_selection():
    """Alg. 1 right panel: reusing cached indices == recomputing them when
    the distribution hasn't changed."""
    x, w = _pair(seed=7)
    y1, idx = ref.hcp_matmul(x, w, 16)
    y2, _ = ref.hcp_matmul(x, w, 16, idx=idx)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_dual_side_beats_single_side_under_heavy_tails():
    """Fig. 32-style claim: B-target recovers more than A- or W-only when
    both operands carry outliers."""
    rng = np.random.default_rng(8)
    x = rng.standard_t(2, (64, 128)).astype(np.float32) * 2
    w = rng.standard_t(2, (128, 64)).astype(np.float32)
    x, w = jnp.array(x), jnp.array(w)
    y_true = np.asarray(x @ w)

    def mse(order, target="b"):
        y, _ = ref.hcp_matmul(x, w, 16, order=order, target=target)
        return float(np.mean((np.asarray(y) - y_true) ** 2))

    both = mse("o2", "b")
    a_only = mse("o2", "a")
    w_only = mse("o2", "w")
    assert both <= a_only + 1e-9
    assert both <= w_only + 1e-9
