"""Diagnostics oracles: kurtosis, entropy, alignment, top-k, block stats."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


def test_gaussian_kurtosis_near_zero():
    x = jnp.array(np.random.default_rng(0).normal(0, 1, 200_000), jnp.float32)
    assert abs(float(ref.kurtosis(x))) < 0.15


def test_laplace_kurtosis_near_three():
    x = jnp.array(np.random.default_rng(1).laplace(0, 1, 200_000), jnp.float32)
    assert abs(float(ref.kurtosis(x)) - 3.0) < 0.5


def test_uniform_kurtosis_negative():
    x = jnp.array(np.random.default_rng(2).uniform(-1, 1, 100_000), jnp.float32)
    assert float(ref.kurtosis(x)) == pytest.approx(-1.2, abs=0.1)


def test_outlier_raises_kurtosis():
    rng = np.random.default_rng(3)
    x = rng.normal(0, 1, 10_000).astype(np.float32)
    k0 = float(ref.kurtosis(jnp.array(x)))
    x[0] = 100.0
    k1 = float(ref.kurtosis(jnp.array(x)))
    assert k1 > k0 + 100


def test_block_kurtosis_localizes_outlier():
    rng = np.random.default_rng(4)
    x = rng.normal(0, 1, (64, 64)).astype(np.float32)
    x[3, 5] = 100.0  # block (0, 0)
    bk = np.asarray(ref.block_kurtosis(jnp.array(x)))
    assert bk.shape == (4, 4)
    assert bk[0, 0] == bk.max()
    assert bk[0, 0] > 50
    # other blocks stay near gaussian
    others = np.delete(bk.reshape(-1), 0)
    assert np.all(np.abs(others) < 3)


def test_block_kurtosis_truncates_ragged_edges():
    x = jnp.array(np.random.default_rng(5).normal(0, 1, (33, 50)), jnp.float32)
    bk = ref.block_kurtosis(x)
    assert bk.shape == (2, 3)


def test_topk_magnitude():
    x = jnp.array([[1.0, -7.0], [3.0, 0.5]])
    np.testing.assert_allclose(np.asarray(ref.topk_magnitude(x, 3)), [7.0, 3.0, 1.0])


def test_channel_topk_magnitude():
    x = np.ones((8, 4), np.float32)
    x[2, 1] = -50.0
    x[5, 3] = 20.0
    vals, idx = ref.channel_topk_magnitude(jnp.array(x), 2)
    np.testing.assert_allclose(np.asarray(vals), [50.0, 20.0])
    np.testing.assert_array_equal(np.asarray(idx), [1, 3])


def test_softmax_entropy_bounds():
    # uniform logits -> ln(n); one-hot-ish -> ~0
    n = 64
    uni = jnp.zeros((4, n))
    assert float(ref.softmax_entropy(uni)) == pytest.approx(np.log(n), rel=1e-5)
    sharp = jnp.zeros((4, n)).at[:, 0].set(100.0)
    assert float(ref.softmax_entropy(sharp)) < 1e-3


def test_entropy_decreases_as_logits_sharpen():
    """Fig. 7 mechanism: larger pre-softmax max -> lower entropy."""
    rng = np.random.default_rng(6)
    base = rng.normal(0, 1, (16, 128)).astype(np.float32)
    ent = [
        float(ref.softmax_entropy(jnp.array(base * t))) for t in (1.0, 2.0, 4.0, 8.0)
    ]
    assert all(a > b for a, b in zip(ent, ent[1:]))


def test_cosine_alignment_identical_and_orthogonal():
    w = jnp.array(np.random.default_rng(7).normal(0, 1, (32, 64)), jnp.float32)
    assert float(ref.cosine_alignment(w, w)) == pytest.approx(1.0, abs=1e-5)
    # random pairs: near zero on average
    w2 = jnp.array(np.random.default_rng(8).normal(0, 1, (32, 64)), jnp.float32)
    assert float(ref.cosine_alignment(w, w2)) < 0.3


def test_quant_mse_scales_quadratically():
    x = jnp.array(np.random.default_rng(9).normal(0, 1, (32, 64)), jnp.float32)
    m1 = float(ref.quant_mse(x))
    m2 = float(ref.quant_mse(x * 10.0))
    assert m2 == pytest.approx(m1 * 100.0, rel=0.05)
