"""E2M1 lattice unit tests: RTN tie behaviour, floor, SR unbiasedness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref


ALL_CODES = sorted({s * v for v in ref.E2M1_VALUES for s in (1.0, -1.0)})


def test_code_points_are_fixed_points():
    v = jnp.array(ALL_CODES, jnp.float32)
    np.testing.assert_array_equal(np.asarray(ref.e2m1_rtn(v)), np.array(ALL_CODES))
    np.testing.assert_array_equal(np.asarray(ref.e2m1_floor(v)), np.array(ALL_CODES))


@pytest.mark.parametrize(
    "x,expected",
    [
        (0.25, 0.0),   # tie -> even mantissa (0.0)
        (0.75, 1.0),   # tie -> 1.0 (m=0)
        (1.25, 1.0),
        (1.75, 2.0),
        (2.5, 2.0),
        (3.5, 4.0),
        (5.0, 4.0),
        (0.26, 0.5),
        (5.01, 6.0),
        (100.0, 6.0),  # clamp
        (-2.5, -2.0),
        (-100.0, -6.0),
    ],
)
def test_rtn_ties_to_even(x, expected):
    assert float(ref.e2m1_rtn(jnp.float32(x))) == expected


@pytest.mark.parametrize(
    "x,expected",
    [(0.49, 0.0), (0.99, 0.5), (1.99, 1.5), (2.99, 2.0), (3.99, 3.0), (5.99, 4.0)],
)
def test_floor_rounds_toward_zero(x, expected):
    assert float(ref.e2m1_floor(jnp.float32(x))) == expected
    assert float(ref.e2m1_floor(jnp.float32(-x))) == -expected


def test_rtn_maps_to_lattice_everywhere():
    rng = np.random.default_rng(0)
    v = rng.uniform(-8, 8, size=4096).astype(np.float32)
    out = np.asarray(ref.e2m1_rtn(jnp.array(v)))
    assert set(np.unique(out)).issubset(set(ALL_CODES))


def test_rtn_is_nearest():
    rng = np.random.default_rng(1)
    v = rng.uniform(-6, 6, size=2048).astype(np.float32)
    out = np.asarray(ref.e2m1_rtn(jnp.array(v)))
    codes = np.array(ALL_CODES)
    nearest = np.min(np.abs(v[:, None] - codes[None, :]), axis=1)
    np.testing.assert_allclose(np.abs(v - out), nearest, atol=1e-6)


def test_sr_unbiased():
    """E[SR(v)] == v within a tight CI for in-range values."""
    rng = np.random.default_rng(2)
    v = jnp.array([0.3, 1.2, 2.7, 4.5, -0.7, -3.3], jnp.float32)
    n = 40000
    u = jnp.array(rng.random((n, 6)).astype(np.float32))
    samples = ref.e2m1_sr(jnp.broadcast_to(v, (n, 6)), u)
    mean = np.asarray(jnp.mean(samples, axis=0))
    # SE of the mean is < step/sqrt(n) ~ 0.01; allow 4 sigma.
    np.testing.assert_allclose(mean, np.asarray(v), atol=0.04)


def test_sr_lands_on_neighbours_only():
    rng = np.random.default_rng(3)
    v = rng.uniform(-6, 6, size=2048).astype(np.float32)
    u = rng.random(2048).astype(np.float32)
    out = np.asarray(ref.e2m1_sr(jnp.array(v), jnp.array(u)))
    codes = np.array(ALL_CODES)
    # every output is a code point within one lattice gap of the input
    assert set(np.round(np.unique(out), 4)).issubset(set(codes))
    assert np.all(np.abs(out - v) <= 2.0 + 1e-6)


def test_e4m3_basics():
    assert float(ref.e4m3_rtn(jnp.float32(448.0))) == 448.0
    assert float(ref.e4m3_rtn(jnp.float32(1e9))) == 448.0  # saturate
    assert float(ref.e4m3_rtn(jnp.float32(0.0))) == 0.0
    # 3 mantissa bits at exponent 4: step = 2^(4-3) = 2, lattice {16, 18, ...};
    # |-17.3| is nearer 18.
    assert float(ref.e4m3_rtn(jnp.float32(-17.3))) == -18.0


def test_e4m3_nearest_on_lattice():
    # Build the positive e4m3 lattice explicitly and check nearest-ness.
    codes = [0.0]
    for e in range(-6, 9):
        for m in range(8):
            val = (1 + m / 8) * 2.0**e
            if val <= 448.0:
                codes.append(val)
    for m in range(1, 8):  # subnormals
        codes.append(m / 8 * 2.0**-6)
    codes = np.unique(np.array(codes, np.float32))
    rng = np.random.default_rng(4)
    v = (rng.uniform(0.001, 500, size=1024)).astype(np.float32)
    out = np.asarray(ref.e4m3_rtn(jnp.array(v)))
    for vi, oi in zip(v, out):
        if vi >= 448.0:
            assert oi == 448.0
            continue
        d = np.abs(codes - vi)
        best = d.min()
        assert abs(oi - vi) <= best + 1e-5 * vi, (vi, oi)
