"""Pallas kernels (interpret=True) vs the pure-jnp oracle, swept shapes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import hcp, nvfp4, ref, rht


def _randn(shape, seed=0, scale=1.0):
    return jnp.array(np.random.default_rng(seed).normal(0, scale, shape), jnp.float32)


@settings(max_examples=20, deadline=None)
@given(
    rows=st.sampled_from([1, 2, 3, 8, 16, 24, 40]),
    blocks=st.sampled_from([1, 2, 4, 8]),
    scale=st.sampled_from([1e-2, 1.0, 37.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qdq_kernel_matches_ref(rows, blocks, scale, seed):
    x = _randn((rows, blocks * 16), seed=seed, scale=scale)
    got = nvfp4.nvfp4_qdq(x)
    want = ref.nvfp4_quant_dequant(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=12, deadline=None)
@given(
    rows=st.sampled_from([5, 16, 17, 48]),
    blocks=st.sampled_from([1, 4]),
    seed=st.integers(0, 2**31 - 1),
)
def test_qdq2d_kernel_matches_ref(rows, blocks, seed):
    x = _randn((rows, blocks * 16), seed=seed)
    got = nvfp4.nvfp4_qdq_2d(x)
    want = ref.nvfp4_quant_dequant_2d(x)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qdq_sr_kernel_matches_ref():
    x = _randn((16, 64), seed=1)
    u = jnp.array(np.random.default_rng(2).random((16, 64)).astype(np.float32))
    got = nvfp4.nvfp4_qdq(x, rounding="sr", u=u)
    want = ref.nvfp4_quant_dequant(x, rounding="sr", u=u)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_qdq_kernel_zero_input():
    x = jnp.zeros((8, 32), jnp.float32)
    assert float(jnp.max(jnp.abs(nvfp4.nvfp4_qdq(x)))) == 0.0


@settings(max_examples=10, deadline=None)
@given(
    rows=st.sampled_from([1, 4, 8, 24]),
    logn=st.sampled_from([4, 5, 6, 7]),
    seed=st.integers(0, 2**31 - 1),
)
def test_rht_kernel_matches_ref(rows, logn, seed):
    n = 2**logn
    x = _randn((rows, n), seed=seed)
    rng = np.random.default_rng(seed + 1)
    s = jnp.array(rng.choice([-1.0, 1.0], n).astype(np.float32))
    got = rht.rht(x, s)
    want = ref.rht(x, s)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_rht_kernel_inverse_roundtrip():
    x = _randn((8, 128), seed=3)
    s = jnp.array(np.random.default_rng(4).choice([-1.0, 1.0], 128).astype(np.float32))
    y = rht.rht(x, s)
    back = rht.rht(y, s, inverse=True)
    np.testing.assert_allclose(np.asarray(back), np.asarray(x), atol=1e-4)


def test_rht_preserves_energy():
    x = _randn((8, 64), seed=5)
    s = jnp.array(np.random.default_rng(6).choice([-1.0, 1.0], 64).astype(np.float32))
    y = rht.rht(x, s)
    np.testing.assert_allclose(
        float(jnp.sum(y * y)), float(jnp.sum(x * x)), rtol=1e-5
    )


def test_rht_diffuses_outliers():
    """A single spike spreads to ~uniform magnitude ±1/sqrt(n) of its mass."""
    n = 128
    x = np.zeros((1, n), np.float32)
    x[0, 17] = 100.0
    s = jnp.array(np.random.default_rng(7).choice([-1.0, 1.0], n).astype(np.float32))
    y = np.asarray(rht.rht(jnp.array(x), s))
    assert np.max(np.abs(y)) <= 100.0 / np.sqrt(n) + 1e-4


@settings(max_examples=8, deadline=None)
@given(
    m=st.sampled_from([4, 8, 16]),
    kdim=st.sampled_from([32, 64]),
    n=st.sampled_from([16, 48]),
    k=st.sampled_from([1, 4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hcp_fused_and_dual_match_oracle(m, kdim, n, k, seed):
    x = _randn((m, kdim), seed=seed, scale=2.0)
    w = _randn((kdim, n), seed=seed + 1)
    xq = ref.nvfp4_quant_dequant(x)
    wq = ref.nvfp4_quant_dequant_2d(w.T).T
    dx, dw = x - xq, w - wq
    idx = ref.topk_channels(ref.hcp_scores(dx, dw), k)
    want, _ = ref.hcp_matmul(x, w, k, idx=idx)
    args = (xq, wq, dx[:, idx], wq[idx, :], xq[:, idx], dw[idx, :])
    got_f = hcp.hcp_gemm_fused(*args)
    got_d = hcp.hcp_gemm_dual(*args)
    np.testing.assert_allclose(np.asarray(got_f), np.asarray(want), atol=1e-4)
    np.testing.assert_allclose(np.asarray(got_d), np.asarray(want), atol=1e-4)


def test_hcp_fused_reduces_error_vs_baseline():
    x = _randn((32, 128), seed=11, scale=3.0)
    w = _randn((128, 64), seed=12)
    y_true = np.asarray(x @ w)
    y_base, _ = ref.hcp_matmul(x, w, 0, order="none")
    y_hcp, _ = ref.hcp_matmul(x, w, 16)
    e_base = np.mean((np.asarray(y_base) - y_true) ** 2)
    e_hcp = np.mean((np.asarray(y_hcp) - y_true) ** 2)
    assert e_hcp < e_base
