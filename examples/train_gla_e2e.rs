//! End-to-end driver (deliverable (b) / EXPERIMENTS.md §E2E): train a GLA
//! model under the full CHON recipe on the synthetic corpus for a few
//! hundred steps, with periodic eval, longitudinal diagnostics, cloze
//! scoring, and a BF16 reference run for the loss-gap readout.
//!
//!   cargo run --release --example train_gla_e2e [model] [steps]
//!
//! Defaults: model=small_gla if its artifacts exist (else tiny_gla),
//! steps from the artifact's schedule. Proves all three layers compose:
//! Pallas kernels (in the CHON HLO) -> JAX model -> Rust coordinator.

use anyhow::Result;

use chon::config::RunConfig;
use chon::coordinator::{evalsuite, loss_gap_pct, Trainer};
use chon::runtime::LoadedArtifact;

fn main() -> Result<()> {
    chon::util::logger::init();
    let args: Vec<String> = std::env::args().skip(1).collect();
    let artifacts = std::path::PathBuf::from("artifacts");
    let model = args.first().cloned().unwrap_or_else(|| {
        if artifacts.join("train_small_gla_chon.manifest.txt").exists() {
            "small_gla".to_string()
        } else {
            "tiny_gla".to_string()
        }
    });
    let steps: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0);

    let mut cfg = RunConfig::default();
    cfg.artifacts = artifacts;
    cfg.model = model.clone();
    cfg.recipe = "chon".into();
    cfg.steps = steps;
    cfg.diag_every = 25;
    cfg.eval_every = 50;
    cfg.log_every = 10;
    cfg.out_dir = "runs".into();

    println!("=== E2E: {} / chon ===", model);
    let mut tr = Trainer::new(cfg.clone())?;
    let n = if steps > 0 { steps } else { tr.total_steps };
    let t0 = std::time::Instant::now();
    tr.train(n)?;
    let wall = t0.elapsed().as_secs_f64();
    let (eval_loss, eval_acc) = tr.evaluate(4)?;
    let chon_loss = tr.log.tail_mean_loss(10).unwrap();
    let dir = tr.write_outputs()?;

    // loss curve summary (every ~n/10 steps)
    println!("\nloss curve (step, loss):");
    let stride = (n / 10).max(1);
    for r in tr.log.records.iter().step_by(stride) {
        println!("  {:5}  {:.4}", r.step, r.loss);
    }
    println!(
        "\nchon: {n} steps in {wall:.0}s ({:.0} ms/step); final loss {chon_loss:.4}; \
         eval loss {eval_loss:.4} acc {eval_acc:.3}",
        tr.log.mean_step_ms()
    );

    // cloze downstream scoring
    let fwd = LoadedArtifact::load(&cfg.artifacts, &format!("fwd_{model}"))?;
    let cloze = evalsuite::cloze_accuracy(&fwd, &tr.state.params, cfg.seed)?;
    println!("cloze accuracy (fact completion): {cloze:.3}");

    // hot-channel persistence readout (Sec. 3.3)
    for (comp, series) in tr.monitor.hot_channel_persistence(8) {
        if let (Some(first), Some(last)) = (series.first(), series.last()) {
            println!(
                "hot-channel persistence {comp}: {:.2} (early) -> {:.2} (late)",
                first.1, last.1
            );
        }
    }

    // BF16 reference for the headline loss gap (Tab. 2's metric)
    println!("\n=== BF16 reference run ===");
    let mut cfg_b = cfg.clone();
    cfg_b.recipe = "bf16".into();
    cfg_b.diag_every = 0;
    cfg_b.eval_every = 0;
    let mut trb = Trainer::new(cfg_b)?;
    trb.train(n)?;
    let bf16_loss = trb.log.tail_mean_loss(10).unwrap();
    trb.write_outputs()?;
    println!(
        "\nHEADLINE: bf16 {bf16_loss:.4} vs chon {chon_loss:.4} -> loss gap {:+.3}%",
        loss_gap_pct(chon_loss, bf16_loss)
    );
    println!("outputs in {}", dir.display());
    Ok(())
}
