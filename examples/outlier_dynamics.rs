//! Outlier-dynamics study (Sec. 3, Figs. 3/5/6/26/32): trains tiny GLA
//! under NVFP4+CHON with high-frequency diagnostics and prints the
//! longitudinal trajectories the paper reports: activation/weight
//! kurtosis, FTZ, top-1 magnitudes, quantization MSE, gk-gate growth and
//! the transition from drifting spikes to persistent hot channels.
//!
//!   cargo run --release --example outlier_dynamics [steps]

use anyhow::Result;

use chon::config::RunConfig;
use chon::coordinator::Trainer;

fn show(label: &str, series: &[(usize, f32)]) {
    if series.is_empty() {
        return;
    }
    print!("{label:<34}");
    for (_, v) in series.iter().take(8) {
        print!(" {v:>9.4}");
    }
    println!();
}

fn main() -> Result<()> {
    chon::util::logger::init();
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);

    let mut cfg = RunConfig::default();
    cfg.model = "tiny_gla".into();
    cfg.recipe = "chon".into();
    cfg.diag_every = (steps / 8).max(1);
    cfg.eval_every = 0;
    cfg.log_every = steps / 4;
    cfg.out_dir = "runs".into();

    let mut tr = Trainer::new(cfg)?;
    tr.train(steps)?;
    let m = &tr.monitor;
    let probes: Vec<usize> = m.records.iter().map(|r| r.step).collect();
    println!("\nprobes at steps {probes:?}\n");

    println!("-- per-tensor trajectories (Fig. 5 / 26 / 32 analogues) --");
    show("act kurtosis (mean)", &m.series_mean_matching(".act.kurt"));
    show("wt kurtosis (mean)", &m.series_mean_matching(".wt.kurt"));
    show("act FTZ (mean)", &m.series_mean_matching(".act.ftz"));
    show("wt FTZ (mean)", &m.series_mean_matching(".wt.ftz"));
    show("act qMSE (mean)", &m.series_mean_matching(".act.qmse"));
    show("wt qMSE (mean)", &m.series_mean_matching(".wt.qmse"));

    println!("\n-- gating as outlier source (Fig. 6b / 28 analogue) --");
    show("gk top-1 |act| L0", &m.series("L0.attn.gk.act.top1").unwrap_or_default());
    show("o  top-1 |act| L0", &m.series("L0.attn.o.act.top1").unwrap_or_default());
    show("up top-1 |act| L0", &m.series("L0.mlp.up.act.top1").unwrap_or_default());

    println!("\n-- SwiGLU alignment (Fig. 8 analogue) --");
    show("cos(W_up, W_gate) L0", &m.series("L0.mlp.alignment").unwrap_or_default());

    println!("\n-- drifting spikes -> fixed hot channels (Fig. 3 / 22) --");
    for (comp, series) in m.hot_channel_persistence(8) {
        print!("jaccard overlap {comp:<22}");
        for (_, j) in &series {
            print!(" {j:>5.2}");
        }
        println!();
    }

    let dir = tr.write_outputs()?;
    println!("\nfull series written to {}", dir.display());
    Ok(())
}
