//! HCP configuration study (Fig. 11 / Fig. 13): quantization MSE of the
//! patched linear product vs number of patched columns, for the six
//! Tab. 4 configurations, under Gaussian and Laplace activation priors,
//! across hidden sizes.
//!
//!   cargo run --release --example hcp_mse_sim [hidden_sizes...]
//!
//! Writes runs/hcp_mse_sim.csv. The expected shape (paper Fig. 11):
//! every config improves on the unpatched baseline, *-O2-B dominates,
//! and MSE decreases monotonically with patch size.

use std::io::Write;

use anyhow::Result;

use chon::hcp::modes::{baseline, apply, HcpConfig, QuantizedPair};
use chon::hcp::{scores, top_k};
use chon::util::ndarray::{matmul, Mat};
use chon::util::prng::Rng;

fn run_prior(
    prior: &str,
    hidden: usize,
    out: &mut impl Write,
) -> Result<()> {
    let m = 64; // token rows
    let n = 64; // output features
    let mut rng = Rng::new(0xC0FFEE ^ hidden as u64);
    let x = Mat::from_fn(m, hidden, |_, _| match prior {
        "gaussian" => rng.normal() * 2.0,
        _ => rng.laplace(2.0),
    });
    let w = Mat::from_fn(hidden, n, |_, _| rng.normal() * 0.5);
    let truth = matmul(&x, &w);
    let q = QuantizedPair::new(&x, &w);
    let order = top_k(&scores(&q.dx, &q.dw), hidden);
    let base_mse = baseline(&q).mse(&truth);
    println!("\n[{prior}, hidden {hidden}] baseline MSE {base_mse:.3e}");
    println!(
        "{:<10} {:>8} {:>12} {:>10}",
        "config", "k", "MSE", "vs base"
    );
    for (name, cfg) in HcpConfig::taxonomy() {
        for frac in [0.02f64, 0.05, 0.0909, 0.25] {
            let k = ((hidden as f64 * frac).round() as usize).max(1);
            let idx = &order[..k];
            let mse = apply(cfg, &q, idx).mse(&truth);
            println!(
                "{:<10} {:>8} {:>12.3e} {:>9.1}%",
                name,
                k,
                mse,
                (mse / base_mse - 1.0) * 100.0
            );
            writeln!(out, "{prior},{hidden},{name},{k},{mse:.6e},{base_mse:.6e}")?;
        }
    }
    Ok(())
}

fn main() -> Result<()> {
    chon::util::logger::init();
    let sizes: Vec<usize> = {
        let args: Vec<usize> = std::env::args()
            .skip(1)
            .filter_map(|s| s.parse().ok())
            .collect();
        if args.is_empty() {
            vec![512, 1024, 2048]
        } else {
            args
        }
    };
    std::fs::create_dir_all("runs")?;
    let mut f = std::io::BufWriter::new(std::fs::File::create("runs/hcp_mse_sim.csv")?);
    writeln!(f, "prior,hidden,config,k,mse,baseline_mse")?;
    for prior in ["gaussian", "laplace"] {
        for &h in &sizes {
            run_prior(prior, h, &mut f)?;
        }
    }
    println!("\nwritten runs/hcp_mse_sim.csv");
    Ok(())
}
