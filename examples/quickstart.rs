//! Quickstart: the minimal public-API tour.
//!
//!   cargo run --release --example quickstart
//!
//! Loads the AOT'd GLA model (init + fwd artifacts), runs a forward pass
//! on a real prompt through the PJRT runtime, and shows the Rust-side
//! NVFP4 substrate quantizing the logits tensor — the whole three-layer
//! stack in ~60 lines.

use anyhow::{Context, Result};

use chon::data::tokenizer::Tokenizer;
use chon::diagnostics;
use chon::quant::nvfp4;
use chon::runtime::{HostTensor, LoadedArtifact};

fn main() -> Result<()> {
    chon::util::logger::init();
    let dir = std::path::Path::new("artifacts");

    // 1. Load the AOT artifacts (HLO text -> PJRT executable).
    let init = LoadedArtifact::load(dir, "init_tiny_gla")
        .context("run `make artifacts` first")?;
    let fwd = LoadedArtifact::load(dir, "fwd_tiny_gla")?;
    let man = &fwd.manifest;
    let (batch, seq, vocab) = (
        man.meta_usize("batch")?,
        man.meta_usize("seq_len")?,
        man.meta_usize("vocab")?,
    );
    println!(
        "model {} ({} arch), vocab {vocab}",
        man.meta_str("model"),
        man.meta_str("arch")
    );

    // 2. Initialize parameters on-device (deterministic in the seed).
    let params = init.run(&[HostTensor::scalar_i32(42)])?;
    println!("initialized {} parameter tensors", params.len());

    // 3. Tokenize a prompt and run the forward pass.
    let tok = Tokenizer::byte_level();
    let prompt = "kato is ";
    let ids: Vec<i32> = tok
        .encode(prompt)
        .iter()
        .map(|&t| (t % vocab as u32) as i32)
        .collect();
    let mut tokens = vec![32i32; batch * seq];
    tokens[..ids.len()].copy_from_slice(&ids);
    let mut inputs = params;
    inputs.push(HostTensor::i32(vec![batch, seq], tokens));
    let out = fwd.run(&inputs)?;
    let logits = &out[0];
    println!("logits shape {:?}", logits.shape);

    // 4. Greedy next-token prediction at the prompt boundary.
    let pos = ids.len() - 1;
    let row = &logits.f32_data[pos * vocab..(pos + 1) * vocab];
    let (argmax, best) = row
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .unwrap();
    println!(
        "next-token prediction after {prompt:?}: byte {argmax} ({:?}) logit {best:.2}",
        (argmax as u8) as char
    );

    // 5. The Rust NVFP4 substrate: quantize the logits row, report error.
    let padded: Vec<f32> = row
        .iter()
        .copied()
        .chain(std::iter::repeat(0.0))
        .take(row.len().div_ceil(16) * 16)
        .collect();
    let q = nvfp4::quantize(&padded, nvfp4::Rounding::Rtn, None);
    println!(
        "NVFP4: {} f32 bytes -> {} packed bytes; qMSE {:.2e}; FTZ {:.3}; kurtosis {:.2}",
        padded.len() * 4,
        q.storage_bytes(),
        nvfp4::quant_mse(&padded),
        nvfp4::ftz_ratio(&padded),
        diagnostics::kurtosis(&padded),
    );
    println!("quickstart OK");
    Ok(())
}
