//! Integration smoke tests for the native pure-Rust backend: the whole
//! coordinator stack (trainer, monitor, eval, checkpointing) must run on
//! a fresh offline checkout — no artifacts/ directory, no libxla — and be
//! bit-reproducible for a fixed seed.

use std::path::PathBuf;

use chon::config::RunConfig;
use chon::coordinator::evalsuite;
use chon::coordinator::Trainer;
use chon::runtime::{backend_for, HostTensor};

/// A run config pointing at a deliberately nonexistent artifacts dir —
/// the native backend must never touch it.
fn native_cfg(model: &str, recipe: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = model.into();
    cfg.recipe = recipe.into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.out_dir = std::env::temp_dir().join("chon_native_it_runs");
    cfg
}

/// The paper's transient->persistent hot-channel claim gets a regression
/// guard: train tiny_gla with the chon recipe for 50 steps and require a
/// decreasing loss plus non-empty hot-channel persistence series.
#[test]
fn chon_training_decreases_loss_and_tracks_hot_channels() {
    let mut cfg = native_cfg("tiny_gla", "chon");
    cfg.diag_every = 10;
    let mut tr = Trainer::new(cfg).unwrap();
    tr.train(50).unwrap();

    let first = tr.log.records[0].loss;
    let last = tr.log.final_loss().unwrap();
    assert!(tr.log.records.iter().all(|r| r.loss.is_finite()));
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    assert!(last < first - 0.5, "descent too weak: {first} -> {last}");

    assert_eq!(tr.monitor.records.len(), 5, "one probe per 10 steps");
    assert!(!tr.monitor.names.is_empty());
    let persistence = tr.monitor.hot_channel_persistence(8);
    assert!(!persistence.is_empty(), "no hot-channel series");
    for (comp, series) in &persistence {
        assert!(!series.is_empty(), "{comp}: empty series");
        for &(_, j) in series {
            assert!((0.0..=1.0).contains(&j), "{comp}: jaccard {j}");
        }
    }
    // GLA exposes the gk map — the paper's headline component
    assert!(persistence.iter().any(|(c, _)| c == "attn_gk"));
    // kurtosis series exists for a known metric slot
    assert!(tr.monitor.series("L0.attn.gk.act.kurt").is_some());
}

#[test]
fn fixed_seed_is_bit_reproducible_and_seed_sensitive() {
    let mk = |seed: u64| {
        let mut cfg = native_cfg("tiny_gla", "chon");
        cfg.seed = seed;
        let mut tr = Trainer::new(cfg).unwrap();
        tr.train(6).unwrap();
        tr
    };
    let a = mk(3);
    let b = mk(3);
    let c = mk(4);
    for (x, y) in a.log.records.iter().zip(&b.log.records) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "loss must be bitwise equal");
    }
    for (p, q) in a.state.params.iter().zip(&b.state.params) {
        assert_eq!(p.f32_data, q.f32_data, "params must be bitwise equal");
    }
    assert_ne!(
        a.log.final_loss().unwrap().to_bits(),
        c.log.final_loss().unwrap().to_bits(),
        "different seed must change the run"
    );
}

#[test]
fn eval_and_checkpoint_roundtrip() {
    let mut tr = Trainer::new(native_cfg("tiny_gla", "bf16")).unwrap();
    tr.train(5).unwrap();
    let (loss, acc) = tr.evaluate(2).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!((0.0..=1.0).contains(&acc));

    let ckpt_dir = std::env::temp_dir().join("chon_native_it_ckpt");
    let path = tr.save_checkpoint_to(&ckpt_dir).unwrap();
    let before: Vec<f32> = tr.state.params[0].f32_data.clone();
    tr.train(2).unwrap();
    assert_ne!(tr.state.params[0].f32_data, before);
    tr.load_params(&path).unwrap();
    assert_eq!(tr.state.params[0].f32_data, before);
}

#[test]
fn sensitivity_recipe_trains() {
    // Tab. 3 mode: exactly one quantized operator
    let mut tr = Trainer::new(native_cfg("tiny_gla", "only_attn_q")).unwrap();
    tr.train(3).unwrap();
    assert!(tr.log.final_loss().unwrap().is_finite());
}

#[test]
fn softmax_attention_model_trains() {
    let mut tr = Trainer::new(native_cfg("tiny_sa", "nvfp4")).unwrap();
    tr.train(12).unwrap();
    let first = tr.log.records[0].loss;
    let last = tr.log.final_loss().unwrap();
    assert!(last < first - 0.2, "tiny_sa no descent: {first} -> {last}");
}

#[test]
fn fwd_executable_supports_cloze_eval() {
    // the eval-suite path (fwd logits + cloze scoring) works natively
    let backend = backend_for("native").unwrap();
    let dir = PathBuf::from("/nonexistent/chon_artifacts");
    let init = backend.load(&dir, "init_tiny_gla").unwrap();
    let params = init.run(&[HostTensor::scalar_i32(0)]).unwrap();
    let fwd = backend.load(&dir, "fwd_tiny_gla").unwrap();
    let acc = evalsuite::cloze_accuracy(fwd.as_ref(), &params, 0).unwrap();
    assert!((0.0..=1.0).contains(&acc), "cloze accuracy {acc}");
}

#[test]
fn unknown_model_or_recipe_fails_loudly() {
    assert!(Trainer::new(native_cfg("tiny_mamba", "chon")).is_err());
    assert!(Trainer::new(native_cfg("tiny_gla", "fp2")).is_err());
}
