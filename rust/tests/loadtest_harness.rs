//! Loadtest harness contracts that hold without spawning subprocesses:
//! schedule determinism, the workload driver against an in-process
//! server, the latency-injection hook the CI gate-validation test rides
//! on, and the end-to-end summary → gate pipeline.

use std::path::PathBuf;

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::loadtest::scenarios::{poisson_schedule, run_workload, Req, Schedule};
use chon::loadtest::summary::{self, Summary};
use chon::serve::{client, ModelRegistry, RegistryOpts, ServeOpts, Server};

fn train_checkpoint(tag: &str, steps: usize) -> PathBuf {
    let root = std::env::temp_dir().join(format!("chon_lth_ckpt_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = "tiny_gla".into();
    cfg.recipe = "chon".into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.seed = 7;
    cfg.out_dir = std::env::temp_dir().join("chon_lth_runs");
    let mut tr = Trainer::new(cfg).unwrap();
    tr.train(steps).unwrap();
    tr.save_checkpoint_to(&root).unwrap()
}

fn start_server(ckpt: &PathBuf) -> (u16, std::thread::JoinHandle<String>) {
    let mut registry = ModelRegistry::new(RegistryOpts {
        max_batch: 4,
        max_wait_us: 2000,
        ..RegistryOpts::default()
    });
    registry.register("default", ckpt).unwrap();
    let server = Server::bind(
        registry,
        &ServeOpts { port: 0, http_port: None, ..ServeOpts::default() },
    )
    .unwrap();
    let port = server.port();
    let h = std::thread::spawn(move || server.run().unwrap());
    (port, h)
}

/// Same seed, same schedule — the reproducibility contract `--seed`
/// promises and `schedule_digest` pins in summary.json.
#[test]
fn schedules_are_a_pure_function_of_the_seed() {
    let a = poisson_schedule(42, 64, 9_000.0, 8);
    let b = poisson_schedule(42, 64, 9_000.0, 8);
    assert_eq!(a.digest(), b.digest());
    for (x, y) in a.reqs.iter().zip(&b.reqs) {
        assert_eq!((x.at_us, &x.prompt, x.max_tokens), (y.at_us, &y.prompt, y.max_tokens));
    }
    assert_ne!(a.digest(), poisson_schedule(43, 64, 9_000.0, 8).digest());
}

/// The workload driver completes a mixed GEN/SGEN schedule against a
/// real server with zero failures, and session turns stay ordered
/// (worker pinning) — the server would reject a busy session otherwise.
#[test]
fn run_workload_completes_mixed_schedule_against_live_server() {
    let ckpt = train_checkpoint("workload", 12);
    let (port, h) = start_server(&ckpt);

    let mut reqs = Vec::new();
    for i in 0..6u64 {
        reqs.push(Req {
            at_us: i * 500,
            prompt: format!("prompt {i} "),
            max_tokens: 5,
            model: None,
            session: None,
        });
    }
    for turn in 0..2u64 {
        for s in 0..2u64 {
            reqs.push(Req {
                at_us: 3_000 + turn * 4_000 + s * 500,
                prompt: "more words ".into(),
                max_tokens: 4,
                model: None,
                session: Some(format!("lth_{s}")),
            });
        }
    }
    let total = reqs.len();
    let schedule = Schedule { reqs, workers: 4 };
    let (report, first_err) = run_workload(port, &schedule, 0);
    assert_eq!(first_err, None);
    assert_eq!(report.requests_ok(), total);
    assert_eq!(report.failures, 0);
    assert_eq!(report.empty_responses, 0);
    assert!(report.wall_s > 0.0);
    // sorted ascending, ready for percentile_of
    assert!(report.latencies_ms.windows(2).all(|w| w[0] <= w[1]));

    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap();
}

/// `--inject-latency-ms` must shift every recorded latency — it's the
/// lever CI uses to prove the SLO gate actually fails on regressions,
/// so if it silently stopped injecting, the negative CI test would go
/// green for the wrong reason.
#[test]
fn injected_latency_is_visible_in_the_report() {
    let ckpt = train_checkpoint("inject", 12);
    let (port, h) = start_server(&ckpt);
    let reqs: Vec<Req> = (0..3)
        .map(|i| Req {
            at_us: i * 500,
            prompt: "the ".into(),
            max_tokens: 4,
            model: None,
            session: None,
        })
        .collect();
    let schedule = Schedule { reqs, workers: 2 };
    let (clean, _) = run_workload(port, &schedule, 0);
    let (slow, _) = run_workload(port, &schedule, 60);
    assert_eq!(clean.requests_ok(), 3);
    assert_eq!(slow.requests_ok(), 3);
    assert!(
        slow.latencies_ms[0] >= 60.0,
        "every injected latency is at least the injection: {:?}",
        slow.latencies_ms
    );
    assert!(
        slow.latencies_ms[0] > clean.latencies_ms[2],
        "injected floor exceeds the clean maximum"
    );

    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap();
}

/// End-to-end gate pipeline on disk: write a summary, self-check passes;
/// regress one percentile past both thresholds, the gate reports it.
#[test]
fn summary_files_roundtrip_through_the_gate() {
    let dir = std::env::temp_dir().join("chon_lth_gate");
    let _ = std::fs::remove_dir_all(&dir);

    let schedule = poisson_schedule(7, 8, 1_000.0, 2);
    let report = chon::serve::client::LoadReport {
        latencies_ms: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0],
        tokens: 32,
        wall_s: 0.5,
        ..Default::default()
    };
    let usage = chon::loadtest::resources::Usage {
        peak_rss_bytes: 32 << 20,
        cpu_ticks: 50,
        samples: 10,
    };
    let result = chon::loadtest::summary::ScenarioResult::from_parts(
        "poisson",
        "stochastic",
        &report,
        Default::default(),
        &usage,
        schedule.digest(),
        vec![("requests_total>=8".into(), true)],
    );
    assert!(result.ok);
    let base = Summary { seed: 7, quick: true, scenarios: vec![result.clone()] };
    let base_path = dir.join("baseline.json");
    base.write(&base_path).unwrap();

    // unchanged rerun passes
    let reread = Summary::read(&base_path).unwrap();
    assert_eq!(reread.scenarios[0].schedule_digest, schedule.digest());
    assert!(summary::check(&base, &reread, 50.0, 20.0).is_empty());

    // a 10x p99 regression (and past the absolute floor) fails
    let mut bad = base.clone();
    bad.scenarios[0].latency.p99_ms = base.scenarios[0].latency.p99_ms * 10.0 + 200.0;
    let violations = summary::check(&base, &bad, 50.0, 20.0);
    assert!(
        violations.iter().any(|v| v.contains("p99")),
        "expected a p99 violation, got {violations:?}"
    );
}
