//! Integration tests for the data-parallel native training path: the
//! whole point of the per-sequence grad + fixed-tree allreduce design is
//! that `--shards N` is a pure scheduling knob — loss trajectories and
//! final parameters must be bit-identical for every N.

#![allow(clippy::field_reassign_with_default)]

use std::path::PathBuf;

use chon::config::RunConfig;
use chon::coordinator::Trainer;

fn shard_cfg(recipe: &str, shards: usize, seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = "tiny_gla".into();
    cfg.recipe = recipe.into();
    cfg.shards = shards;
    cfg.seed = seed;
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.out_dir = std::env::temp_dir().join("chon_shard_it_runs");
    cfg
}

fn run(recipe: &str, shards: usize, steps: usize) -> Trainer {
    let mut tr = Trainer::new(shard_cfg(recipe, shards, 9)).unwrap();
    tr.train(steps).unwrap();
    tr
}

/// The headline acceptance property, at trainer level and under the full
/// chon recipe (SR + RHT + HCP all active): every shard count walks the
/// identical loss trajectory, bit for bit.
#[test]
fn shards_n_matches_shards_1_bitwise() {
    let base = run("chon", 1, 6);
    for shards in [2, 4, 64] {
        let tr = run("chon", shards, 6);
        for (a, b) in base.log.records.iter().zip(&tr.log.records) {
            assert_eq!(
                a.loss.to_bits(),
                b.loss.to_bits(),
                "step {} diverged at shards={shards}",
                a.step
            );
        }
        for (p, q) in base.state.params.iter().zip(&tr.state.params) {
            assert_eq!(p.f32_data, q.f32_data, "params diverged at shards={shards}");
        }
        for (p, q) in base.state.m.iter().zip(&tr.state.m) {
            assert_eq!(p.f32_data, q.f32_data, "Adam m diverged at shards={shards}");
        }
    }
}

/// Sharded training still descends (the parallel path is a real training
/// path, not just a determinism fixture).
#[test]
fn sharded_training_descends() {
    let tr = run("bf16", 2, 25);
    let first = tr.log.records[0].loss;
    let last = tr.log.final_loss().unwrap();
    assert!(tr.log.records.iter().all(|r| r.loss.is_finite()));
    assert!(last < first - 0.5, "no descent at shards=2: {first} -> {last}");
}

/// Sharded runs stay seed-reproducible and seed-sensitive, like the
/// unsharded engine before them.
#[test]
fn sharded_runs_are_seed_reproducible() {
    let mk = |seed: u64| {
        let mut tr = Trainer::new(shard_cfg("chon", 2, seed)).unwrap();
        tr.train(4).unwrap();
        tr
    };
    let a = mk(3);
    let b = mk(3);
    let c = mk(4);
    for (x, y) in a.log.records.iter().zip(&b.log.records) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits());
    }
    assert_ne!(
        a.log.final_loss().unwrap().to_bits(),
        c.log.final_loss().unwrap().to_bits()
    );
}
