//! Model-registry invariants: multi-model serving must be *bitwise*
//! invisible per model, across both front ends and the whole model
//! lifecycle.
//!
//! * isolation: a server with several resident models answers each model
//!   exactly like a dedicated single-model server, the same checkpoint
//!   registered under two names answers identically under both, and the
//!   default route is the first registered model.
//! * LRU unload→reload: with `--max-resident-models 1`, alternating
//!   traffic (including a named session that survives its model being
//!   unloaded in between turns) matches dedicated servers byte for byte.
//! * hot reload: republishing a checkpoint (higher step, bumped
//!   `generation` in meta.toml) is picked up mid-serve without a
//!   restart; the served bytes match a server freshly bound to the
//!   republished checkpoint.
//! * unknown models are clean errors: `ERR unknown model` on the line
//!   protocol, 404 on HTTP.
//! * no head-of-line blocking: a slow-loading model (injected load
//!   delay) never stalls a resident model's requests — loads run on the
//!   lifecycle thread, routing is a lock-free snapshot read.
//! * no silent request loss: requests still queued when their model is
//!   LRU-unloaded get an explicit retryable rejection (`TokenEvent::
//!   Retry`, counted in `retry_rejects`), never dropped.
//! * idle reload: a republished checkpoint is picked up with zero
//!   generate traffic — the reactor timer tick drives the probe.
//! * observation is side-effect-free: `/stats` and `/metrics` reads
//!   never initiate a load or reload; only the timer tick (and real
//!   generate traffic) may trigger the probe.

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::serve::{
    client, GenRequest, ModelRegistry, RegistryOpts, ReplySink, ServeOpts, Server,
    TokenEvent,
};
use chon::util::json::Json;

mod common;
use common::http_request;

fn native_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = "tiny_gla".into();
    cfg.recipe = "chon".into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.seed = seed;
    cfg.out_dir = std::env::temp_dir().join("chon_registry_runs");
    cfg
}

/// Train `steps` steps with `seed` and publish a checkpoint under a
/// fresh per-tag parent dir. Returns (parent, concrete checkpoint dir).
fn train_checkpoint(tag: &str, steps: usize, seed: u64) -> (PathBuf, PathBuf) {
    let root = std::env::temp_dir().join(format!("chon_registry_ckpt_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    let mut tr = Trainer::new(native_cfg(seed)).unwrap();
    tr.train(steps).unwrap();
    let ckpt = tr.save_checkpoint_to(&root).unwrap();
    (root, ckpt)
}

fn start_server(
    entries: &[(&str, &Path)],
    reg_opts: RegistryOpts,
) -> (u16, u16, JoinHandle<String>) {
    let mut registry = ModelRegistry::new(reg_opts);
    for (name, dir) in entries {
        registry.register(name, dir).expect("register model");
    }
    let opts = ServeOpts {
        port: 0,
        http_port: Some(0),
        ..ServeOpts::default()
    };
    let server = Server::bind(registry, &opts).expect("bind");
    let port = server.port();
    let http_port = server.http_port().expect("http enabled");
    let h = std::thread::spawn(move || server.run().expect("server run"));
    (port, http_port, h)
}

fn stop(port: u16, h: JoinHandle<String>) -> String {
    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap()
}

/// One counter value out of a `k=v ...` stats line.
fn stat_of(line: &str, key: &str) -> u64 {
    line.split_whitespace()
        .find_map(|kv| kv.strip_prefix(&format!("{key}=")))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no {key}= in {line:?}"))
}

// ------------------------------------------------------------- http glue

/// Generate over HTTP with an optional model key; returns the assembled
/// text of a 200-status NDJSON stream.
fn http_generate(http_port: u16, model: Option<&str>, prompt: &str, n: usize) -> String {
    let model_field = match model {
        Some(m) => format!(", \"model\": \"{m}\""),
        None => String::new(),
    };
    let body = format!(
        "{{\"prompt\": \"{prompt}\", \"max_tokens\": {n}{model_field}}}"
    );
    let (status, raw) = http_request(http_port, "POST", "/generate", &body);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&raw));
    let mut bytes = Vec::new();
    for line in String::from_utf8(raw).unwrap().lines() {
        let doc = Json::parse(line).unwrap();
        if let Some(piece) = doc.get("piece").and_then(|v| v.as_str()) {
            bytes.extend(
                chon::serve::protocol::unescape_bytes(piece).unwrap(),
            );
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

/// The per-model generation counter out of `GET /stats`.
fn model_generation(http_port: u16, name: &str) -> u64 {
    let (status, body) = http_request(http_port, "GET", "/stats", "");
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    doc.get("per_model")
        .and_then(|m| m.get(name))
        .and_then(|m| m.get("generation"))
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("no per_model.{name}.generation in stats")) as u64
}

// ------------------------------------------------------------ isolation

/// A ≥2-resident-model server answers each model bitwise like a
/// dedicated single-model server — across TCP and HTTP, under aliasing
/// (same checkpoint twice), with interleaved traffic, and via the
/// default route. Unknown models fail clean on both front ends.
#[test]
fn multi_model_serving_is_bitwise_isolated() {
    let (_root_a, ckpt_a) = train_checkpoint("iso_a", 20, 7);
    let (_root_b, ckpt_b) = train_checkpoint("iso_b", 20, 13);
    let prompts = ["the quick ", "hello worl", "zqx jw vv "];

    // dedicated single-model references
    let mut ref_a = Vec::new();
    let mut ref_b = Vec::new();
    {
        let (port, _, h) = start_server(
            &[("default", ckpt_a.as_path())],
            RegistryOpts::default(),
        );
        for p in &prompts {
            ref_a.push(client::generate_once("127.0.0.1", port, p, 12, 0.0).unwrap().0);
        }
        stop(port, h);
        let (port, _, h) = start_server(
            &[("default", ckpt_b.as_path())],
            RegistryOpts::default(),
        );
        for p in &prompts {
            ref_b.push(client::generate_once("127.0.0.1", port, p, 12, 0.0).unwrap().0);
        }
        stop(port, h);
    }

    // one server, three names over two checkpoints (alias shares ckpt_a)
    let (port, http_port, h) = start_server(
        &[
            ("alpha", ckpt_a.as_path()),
            ("beta", ckpt_b.as_path()),
            ("alias", ckpt_a.as_path()),
        ],
        RegistryOpts::default(),
    );
    for (i, p) in prompts.iter().enumerate() {
        // interleave models so both stay resident and traffic mixes
        let a =
            client::generate_once_for("127.0.0.1", port, Some("alpha"), p, 12, 0.0)
                .unwrap()
                .0;
        let b =
            client::generate_once_for("127.0.0.1", port, Some("beta"), p, 12, 0.0)
                .unwrap()
                .0;
        let ali =
            client::generate_once_for("127.0.0.1", port, Some("alias"), p, 12, 0.0)
                .unwrap()
                .0;
        let def = client::generate_once("127.0.0.1", port, p, 12, 0.0).unwrap().0;
        assert_eq!(a, ref_a[i], "alpha diverged from its dedicated server");
        assert_eq!(b, ref_b[i], "beta diverged from its dedicated server");
        assert_eq!(ali, ref_a[i], "alias of the same checkpoint diverged");
        assert_eq!(def, ref_a[i], "default route must hit the first model");
        // HTTP routes through the same registry
        assert_eq!(http_generate(http_port, Some("beta"), p, 12), ref_b[i]);
        assert_eq!(http_generate(http_port, Some("alpha"), p, 12), ref_a[i]);
    }

    // unknown model: ERR on the line protocol, 404 on HTTP
    let err = client::generate_once_for("127.0.0.1", port, Some("nope"), "hi ", 4, 0.0)
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown model"), "{err}");
    let (status, body) = http_request(
        http_port,
        "POST",
        "/generate",
        r#"{"prompt": "hi ", "max_tokens": 4, "model": "nope"}"#,
    );
    assert_eq!(status, 404, "{}", String::from_utf8_lossy(&body));

    let stats = stop(port, h);
    assert_eq!(stat_of(&stats, "models"), 3);
}

// ------------------------------------------------------- LRU unload/load

/// With a one-model residency budget, alternating traffic forces an
/// unload+reload per turn — outputs (including a named session whose
/// model is unloaded between its turns) stay bitwise those of dedicated
/// servers, and the lifecycle counters prove the churn really happened.
#[test]
fn lru_unload_reload_is_bitwise_identical() {
    let (_root_a, ckpt_a) = train_checkpoint("lru_a", 20, 7);
    let (_root_b, ckpt_b) = train_checkpoint("lru_b", 20, 13);
    let turns = ["turn zero ", "turn one ", "turn two "];

    // dedicated reference: one server per model, a named session on A
    let mut ref_sess = Vec::new();
    let mut ref_b = Vec::new();
    {
        let (port, _, h) = start_server(
            &[("default", ckpt_a.as_path())],
            RegistryOpts::default(),
        );
        for p in &turns {
            ref_sess.push(
                client::generate_session_once("127.0.0.1", port, "conv", p, 8, 0.0)
                    .unwrap()
                    .0,
            );
        }
        stop(port, h);
        let (port, _, h) = start_server(
            &[("default", ckpt_b.as_path())],
            RegistryOpts::default(),
        );
        for p in &turns {
            ref_b.push(client::generate_once("127.0.0.1", port, p, 8, 0.0).unwrap().0);
        }
        stop(port, h);
    }

    let (port, _, h) = start_server(
        &[("alpha", ckpt_a.as_path()), ("beta", ckpt_b.as_path())],
        RegistryOpts { max_resident_models: 1, ..RegistryOpts::default() },
    );
    for (i, p) in turns.iter().enumerate() {
        // session turn on alpha, then a beta request that evicts alpha
        let s = client::generate_session_once_for(
            "127.0.0.1",
            port,
            Some("alpha"),
            "conv",
            p,
            8,
            0.0,
        )
        .unwrap()
        .0;
        assert_eq!(
            s, ref_sess[i],
            "alpha session lost context across an LRU unload"
        );
        let b =
            client::generate_once_for("127.0.0.1", port, Some("beta"), p, 8, 0.0)
                .unwrap()
                .0;
        assert_eq!(b, ref_b[i], "beta diverged under the residency budget");
    }
    let stats = stop(port, h);
    assert_eq!(stat_of(&stats, "resident_models"), 1, "{stats}");
    assert!(
        stat_of(&stats, "model_unloads") >= 4,
        "alternating traffic under max_resident_models=1 must unload: {stats}"
    );
    assert!(stat_of(&stats, "model_loads") >= 5, "{stats}");
}

// ------------------------------------------------------------ hot reload

/// A live server picks up a republished checkpoint (new generation in
/// meta.toml) on the next admission: the served bytes match a server
/// freshly bound to the republished directory, and the per-model
/// generation in /stats moves.
#[test]
fn hot_reload_picks_up_republished_checkpoint() {
    let (root, ckpt1) = train_checkpoint("reload", 8, 11);
    let prompt = "the quick ";

    // watch the *parent*: that is what a deployment points serve at
    let (port, http_port, h) = start_server(
        &[("live", root.as_path())],
        RegistryOpts { reload_poll_ms: 0, ..RegistryOpts::default() },
    );
    let out_old =
        client::generate_once_for("127.0.0.1", port, Some("live"), prompt, 12, 0.0)
            .unwrap()
            .0;
    assert!(!out_old.is_empty());
    assert_eq!(model_generation(http_port, "live"), 1);

    // republish: resume the run, train further, save into the same parent
    let mut tr = Trainer::new(native_cfg(11)).unwrap();
    tr.restore(&ckpt1).unwrap();
    tr.train(6).unwrap();
    let ckpt2 = tr.save_checkpoint_to(&root).unwrap();
    assert_ne!(ckpt1, ckpt2, "republish should land at a new step dir");

    // next admission serves the new weights — no restart
    let out_new =
        client::generate_once_for("127.0.0.1", port, Some("live"), prompt, 12, 0.0)
            .unwrap()
            .0;
    assert_eq!(model_generation(http_port, "live"), 2);

    // reference: a fresh server bound after the republish
    let (port2, _, h2) = start_server(
        &[("default", root.as_path())],
        RegistryOpts::default(),
    );
    let ref_new = client::generate_once("127.0.0.1", port2, prompt, 12, 0.0)
        .unwrap()
        .0;
    stop(port2, h2);
    assert_eq!(
        out_new, ref_new,
        "hot reload served different bytes than a fresh bind"
    );

    let stats = stop(port, h);
    assert!(stat_of(&stats, "model_reloads") >= 1, "{stats}");
}

// ------------------------------------------- concurrent-load isolation

/// A slow-loading model must never stall a resident model. Loads run on
/// the lifecycle thread and routing is a lock-free snapshot read, so
/// with a 1.5 s load delay injected into the lifecycle thread, requests
/// to the already-resident model complete in normal time *while* the
/// cold model's load is in flight — and both models still answer
/// bitwise like dedicated servers.
#[test]
fn slow_model_load_does_not_stall_resident_models() {
    let (_root_a, ckpt_a) = train_checkpoint("stall_a", 20, 7);
    let (_root_b, ckpt_b) = train_checkpoint("stall_b", 20, 13);
    let prompt = "the quick ";

    // dedicated references, no delay
    let (port, _, h) =
        start_server(&[("default", ckpt_a.as_path())], RegistryOpts::default());
    let ref_a = client::generate_once("127.0.0.1", port, prompt, 12, 0.0).unwrap().0;
    stop(port, h);
    let (port, _, h) =
        start_server(&[("default", ckpt_b.as_path())], RegistryOpts::default());
    let ref_b = client::generate_once("127.0.0.1", port, prompt, 12, 0.0).unwrap().0;
    stop(port, h);

    const DELAY_MS: u64 = 1500;
    let (port, _, h) = start_server(
        &[("alpha", ckpt_a.as_path()), ("beta", ckpt_b.as_path())],
        RegistryOpts { load_delay_ms: DELAY_MS, ..RegistryOpts::default() },
    );
    // warm alpha (its own lazy load pays the injected delay once)
    let warm =
        client::generate_once_for("127.0.0.1", port, Some("alpha"), prompt, 12, 0.0)
            .unwrap()
            .0;
    assert_eq!(warm, ref_a);

    // kick off beta: its load now sleeps DELAY_MS on the lifecycle thread
    let t_beta = Instant::now();
    let beta = std::thread::spawn(move || {
        client::generate_once_for("127.0.0.1", port, Some("beta"), prompt, 12, 0.0)
            .unwrap()
            .0
    });
    std::thread::sleep(Duration::from_millis(150)); // let beta enter Loading

    // alpha keeps answering at full speed while beta loads
    let mut worst = Duration::ZERO;
    for _ in 0..3 {
        let t0 = Instant::now();
        let a = client::generate_once_for(
            "127.0.0.1",
            port,
            Some("alpha"),
            prompt,
            12,
            0.0,
        )
        .unwrap()
        .0;
        worst = worst.max(t0.elapsed());
        assert_eq!(a, ref_a, "resident model corrupted by a concurrent load");
    }
    assert!(
        worst < Duration::from_millis(DELAY_MS - 300),
        "resident-model request took {worst:?} while another model loaded \
         (head-of-line blocking)"
    );

    let out_b = beta.join().unwrap();
    assert!(
        t_beta.elapsed() >= Duration::from_millis(DELAY_MS),
        "load delay hook did not fire"
    );
    assert_eq!(out_b, ref_b, "slow-loaded model served wrong bytes");
    stop(port, h);
}

// ------------------------------------------------------- idle reload probe

/// A republished checkpoint is picked up with *zero* generate traffic:
/// the reactor's timer tick drives the reload probe, so an idle model
/// converges to the new generation on its own — no request needed to
/// trigger it (and no `/stats` scrape either: observation is
/// side-effect-free; the `/stats` polling below is reads only).
#[test]
fn reload_probe_fires_without_generate_traffic() {
    let (root, ckpt1) = train_checkpoint("idle_reload", 8, 11);
    let prompt = "the quick ";
    let (port, http_port, h) = start_server(
        &[("live", root.as_path())],
        RegistryOpts { reload_poll_ms: 0, ..RegistryOpts::default() },
    );
    // make the model resident, then go quiet
    let _ = client::generate_once_for("127.0.0.1", port, Some("live"), prompt, 8, 0.0)
        .unwrap();
    assert_eq!(model_generation(http_port, "live"), 1);

    let mut tr = Trainer::new(native_cfg(11)).unwrap();
    tr.restore(&ckpt1).unwrap();
    tr.train(6).unwrap();
    let ckpt2 = tr.save_checkpoint_to(&root).unwrap();
    assert_ne!(ckpt1, ckpt2, "republish should land at a new step dir");

    // no generate traffic from here on — only /stats reads
    let deadline = Instant::now() + Duration::from_secs(20);
    while model_generation(http_port, "live") != 2 {
        assert!(
            Instant::now() < deadline,
            "idle server never picked up the republish"
        );
        std::thread::sleep(Duration::from_millis(200));
    }

    // and the reloaded weights are really what is served
    let out =
        client::generate_once_for("127.0.0.1", port, Some("live"), prompt, 12, 0.0)
            .unwrap()
            .0;
    let (port2, _, h2) =
        start_server(&[("default", root.as_path())], RegistryOpts::default());
    let ref_new = client::generate_once("127.0.0.1", port2, prompt, 12, 0.0)
        .unwrap()
        .0;
    stop(port2, h2);
    assert_eq!(out, ref_new, "idle reload served stale bytes");

    let stats = stop(port, h);
    assert!(stat_of(&stats, "model_reloads") >= 1, "{stats}");
}

// ---------------------------------------------------- unload retry drain

/// LRU unload must not drop still-queued requests on the floor: whatever
/// is waiting in the victim's queue when it is evicted gets an explicit
/// retryable rejection (`TokenEvent::Retry`, counted in
/// `retry_rejects`) — never a hang, never silence. The in-flight
/// generation still finishes normally.
#[test]
fn lru_unload_rejects_queued_requests_retryably() {
    let (_root_a, ckpt_a) = train_checkpoint("retry_a", 8, 7);
    let (_root_b, ckpt_b) = train_checkpoint("retry_b", 8, 13);

    // max_batch 1 keeps requests behind the active one *queued* in the
    // batcher channel; the injected load delay keeps alpha's queue alive
    // until beta's load completes and evicts alpha.
    let mut reg = ModelRegistry::new(RegistryOpts {
        max_resident_models: 1,
        max_batch: 1,
        load_delay_ms: 400,
        ..RegistryOpts::default()
    });
    reg.register("alpha", &ckpt_a).unwrap();
    reg.register("beta", &ckpt_b).unwrap();

    let request = |prompt: &str, n: usize| {
        let (tx, rx) = mpsc::channel();
        (
            GenRequest {
                prompt: prompt.into(),
                max_tokens: n,
                temp: 0.0,
                session: None,
                reply: ReplySink::channel(tx),
                cancel: Arc::new(AtomicBool::new(false)),
                queued_at: Instant::now(),
            },
            rx,
        )
    };
    // block until the terminal event on one receiver
    let outcome = |rx: &mpsc::Receiver<TokenEvent>| loop {
        match rx.recv_timeout(Duration::from_secs(120)).expect("reply hung") {
            TokenEvent::Token(_) => continue,
            ev => break ev,
        }
    };

    // make alpha resident (its first load pays the injected delay)
    let (req, rx) = request("warm ", 4);
    reg.submit(Some("alpha"), req).unwrap();
    assert!(matches!(outcome(&rx), TokenEvent::Done { .. }));

    // a serial pile-up on alpha: one active, the rest queued behind it
    let mut rxs = Vec::new();
    for i in 0..6 {
        let (req, rx) = request(&format!("busy {i} "), 256);
        reg.submit(Some("alpha"), req).unwrap();
        rxs.push(rx);
    }
    std::thread::sleep(Duration::from_millis(50)); // first request goes active

    // beta's slow load evicts alpha under the residency budget while
    // alpha's queue is still populated
    let (req_b, rx_b) = request("beta ", 4);
    reg.submit(Some("beta"), req_b).unwrap();

    let mut done = 0u64;
    let mut retried = 0u64;
    for rx in &rxs {
        match outcome(rx) {
            TokenEvent::Done { .. } => done += 1,
            TokenEvent::Retry(why) => {
                assert!(why.contains("unloaded"), "unexpected retry reason: {why}");
                retried += 1;
            }
            ev => panic!("unexpected terminal event: {ev:?}"),
        }
    }
    assert!(done >= 1, "the in-flight generation must finish, not be dropped");
    assert!(
        retried >= 1,
        "queued requests vanished silently across the LRU unload \
         ({done} done, {retried} retried of 6)"
    );
    assert!(
        matches!(outcome(&rx_b), TokenEvent::Done { .. }),
        "beta request lost"
    );

    let line = reg.stats_line();
    assert_eq!(stat_of(&line, "retry_rejects"), retried, "{line}");
    reg.shutdown();
}

// --------------------------------------------- side-effect-free scrapes

/// Observation must never mutate: with a republished checkpoint sitting
/// on disk, any number of `stats_json()` / `stats_line()` /
/// `metrics_text()` reads must NOT initiate the reload — the loaded
/// generation stays put. Only an explicit probe nudge (what the
/// reactor's 1 Hz tick sends) picks the republish up. This pins the
/// `/stats`-triggers-reload bug closed.
#[test]
fn stats_and_metrics_never_initiate_loads() {
    let (root, ckpt1) = train_checkpoint("obs_pin", 8, 11);
    let reg = {
        let mut reg = ModelRegistry::new(RegistryOpts {
            reload_poll_ms: 0,
            ..RegistryOpts::default()
        });
        reg.register("live", &root).unwrap();
        reg
    };

    // make the model resident with one real generation
    let (tx, rx) = mpsc::channel();
    reg.submit(
        Some("live"),
        GenRequest {
            prompt: "warm ".into(),
            max_tokens: 4,
            temp: 0.0,
            session: None,
            reply: ReplySink::channel(tx),
            cancel: Arc::new(AtomicBool::new(false)),
            queued_at: Instant::now(),
        },
    )
    .unwrap();
    loop {
        match rx.recv_timeout(Duration::from_secs(120)).expect("reply hung") {
            TokenEvent::Token(_) => continue,
            TokenEvent::Done { .. } => break,
            ev => panic!("unexpected terminal event: {ev:?}"),
        }
    }
    assert_eq!(reg.loaded_generation("live"), Some(1));

    // republish on disk: generation 2 is now waiting to be noticed
    let mut tr = Trainer::new(native_cfg(11)).unwrap();
    tr.restore(&ckpt1).unwrap();
    tr.train(6).unwrap();
    let ckpt2 = tr.save_checkpoint_to(&root).unwrap();
    assert_ne!(ckpt1, ckpt2, "republish should land at a new step dir");

    // hammer every observation surface; none of them may trigger the
    // reload (the lifecycle thread is idle, so any bump it was going to
    // make would land well within this window)
    let until = Instant::now() + Duration::from_millis(1200);
    while Instant::now() < until {
        let _ = reg.stats_json();
        let _ = reg.stats_line();
        let _ = reg.metrics_text();
        assert_eq!(
            reg.loaded_generation("live"),
            Some(1),
            "an observation read initiated a reload"
        );
        std::thread::sleep(Duration::from_millis(25));
    }

    // the explicit probe (what the reactor tick calls) does pick it up
    reg.poll_reloads();
    let deadline = Instant::now() + Duration::from_secs(20);
    while reg.loaded_generation("live") != Some(2) {
        assert!(
            Instant::now() < deadline,
            "poll_reloads() never picked up the republish"
        );
        std::thread::sleep(Duration::from_millis(50));
        reg.poll_reloads();
    }
    reg.shutdown();
}
