//! Model-registry invariants: multi-model serving must be *bitwise*
//! invisible per model, across both front ends and the whole model
//! lifecycle.
//!
//! * isolation: a server with several resident models answers each model
//!   exactly like a dedicated single-model server, the same checkpoint
//!   registered under two names answers identically under both, and the
//!   default route is the first registered model.
//! * LRU unload→reload: with `--max-resident-models 1`, alternating
//!   traffic (including a named session that survives its model being
//!   unloaded in between turns) matches dedicated servers byte for byte.
//! * hot reload: republishing a checkpoint (higher step, bumped
//!   `generation` in meta.toml) is picked up mid-serve without a
//!   restart; the served bytes match a server freshly bound to the
//!   republished checkpoint.
//! * unknown models are clean errors: `ERR unknown model` on the line
//!   protocol, 404 on HTTP.

use std::path::{Path, PathBuf};
use std::thread::JoinHandle;

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::serve::{client, ModelRegistry, RegistryOpts, ServeOpts, Server};
use chon::util::json::Json;

mod common;
use common::http_request;

fn native_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = "tiny_gla".into();
    cfg.recipe = "chon".into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.seed = seed;
    cfg.out_dir = std::env::temp_dir().join("chon_registry_runs");
    cfg
}

/// Train `steps` steps with `seed` and publish a checkpoint under a
/// fresh per-tag parent dir. Returns (parent, concrete checkpoint dir).
fn train_checkpoint(tag: &str, steps: usize, seed: u64) -> (PathBuf, PathBuf) {
    let root = std::env::temp_dir().join(format!("chon_registry_ckpt_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    let mut tr = Trainer::new(native_cfg(seed)).unwrap();
    tr.train(steps).unwrap();
    let ckpt = tr.save_checkpoint_to(&root).unwrap();
    (root, ckpt)
}

fn start_server(
    entries: &[(&str, &Path)],
    reg_opts: RegistryOpts,
) -> (u16, u16, JoinHandle<String>) {
    let mut registry = ModelRegistry::new(reg_opts);
    for (name, dir) in entries {
        registry.register(name, dir).expect("register model");
    }
    let opts = ServeOpts {
        port: 0,
        http_port: Some(0),
        workers: 10,
        ..ServeOpts::default()
    };
    let server = Server::bind(registry, &opts).expect("bind");
    let port = server.port();
    let http_port = server.http_port().expect("http enabled");
    let h = std::thread::spawn(move || server.run().expect("server run"));
    (port, http_port, h)
}

fn stop(port: u16, h: JoinHandle<String>) -> String {
    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap()
}

/// One counter value out of a `k=v ...` stats line.
fn stat_of(line: &str, key: &str) -> u64 {
    line.split_whitespace()
        .find_map(|kv| kv.strip_prefix(&format!("{key}=")))
        .and_then(|v| v.parse().ok())
        .unwrap_or_else(|| panic!("no {key}= in {line:?}"))
}

// ------------------------------------------------------------- http glue

/// Generate over HTTP with an optional model key; returns the assembled
/// text of a 200-status NDJSON stream.
fn http_generate(http_port: u16, model: Option<&str>, prompt: &str, n: usize) -> String {
    let model_field = match model {
        Some(m) => format!(", \"model\": \"{m}\""),
        None => String::new(),
    };
    let body = format!(
        "{{\"prompt\": \"{prompt}\", \"max_tokens\": {n}{model_field}}}"
    );
    let (status, raw) = http_request(http_port, "POST", "/generate", &body);
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&raw));
    let mut bytes = Vec::new();
    for line in String::from_utf8(raw).unwrap().lines() {
        let doc = Json::parse(line).unwrap();
        if let Some(piece) = doc.get("piece").and_then(|v| v.as_str()) {
            bytes.extend(
                chon::serve::protocol::unescape_bytes(piece).unwrap(),
            );
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

/// The per-model generation counter out of `GET /stats`.
fn model_generation(http_port: u16, name: &str) -> u64 {
    let (status, body) = http_request(http_port, "GET", "/stats", "");
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    doc.get("per_model")
        .and_then(|m| m.get(name))
        .and_then(|m| m.get("generation"))
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("no per_model.{name}.generation in stats")) as u64
}

// ------------------------------------------------------------ isolation

/// A ≥2-resident-model server answers each model bitwise like a
/// dedicated single-model server — across TCP and HTTP, under aliasing
/// (same checkpoint twice), with interleaved traffic, and via the
/// default route. Unknown models fail clean on both front ends.
#[test]
fn multi_model_serving_is_bitwise_isolated() {
    let (_root_a, ckpt_a) = train_checkpoint("iso_a", 20, 7);
    let (_root_b, ckpt_b) = train_checkpoint("iso_b", 20, 13);
    let prompts = ["the quick ", "hello worl", "zqx jw vv "];

    // dedicated single-model references
    let mut ref_a = Vec::new();
    let mut ref_b = Vec::new();
    {
        let (port, _, h) = start_server(
            &[("default", ckpt_a.as_path())],
            RegistryOpts::default(),
        );
        for p in &prompts {
            ref_a.push(client::generate_once("127.0.0.1", port, p, 12, 0.0).unwrap().0);
        }
        stop(port, h);
        let (port, _, h) = start_server(
            &[("default", ckpt_b.as_path())],
            RegistryOpts::default(),
        );
        for p in &prompts {
            ref_b.push(client::generate_once("127.0.0.1", port, p, 12, 0.0).unwrap().0);
        }
        stop(port, h);
    }

    // one server, three names over two checkpoints (alias shares ckpt_a)
    let (port, http_port, h) = start_server(
        &[
            ("alpha", ckpt_a.as_path()),
            ("beta", ckpt_b.as_path()),
            ("alias", ckpt_a.as_path()),
        ],
        RegistryOpts::default(),
    );
    for (i, p) in prompts.iter().enumerate() {
        // interleave models so both stay resident and traffic mixes
        let a =
            client::generate_once_for("127.0.0.1", port, Some("alpha"), p, 12, 0.0)
                .unwrap()
                .0;
        let b =
            client::generate_once_for("127.0.0.1", port, Some("beta"), p, 12, 0.0)
                .unwrap()
                .0;
        let ali =
            client::generate_once_for("127.0.0.1", port, Some("alias"), p, 12, 0.0)
                .unwrap()
                .0;
        let def = client::generate_once("127.0.0.1", port, p, 12, 0.0).unwrap().0;
        assert_eq!(a, ref_a[i], "alpha diverged from its dedicated server");
        assert_eq!(b, ref_b[i], "beta diverged from its dedicated server");
        assert_eq!(ali, ref_a[i], "alias of the same checkpoint diverged");
        assert_eq!(def, ref_a[i], "default route must hit the first model");
        // HTTP routes through the same registry
        assert_eq!(http_generate(http_port, Some("beta"), p, 12), ref_b[i]);
        assert_eq!(http_generate(http_port, Some("alpha"), p, 12), ref_a[i]);
    }

    // unknown model: ERR on the line protocol, 404 on HTTP
    let err = client::generate_once_for("127.0.0.1", port, Some("nope"), "hi ", 4, 0.0)
        .unwrap_err()
        .to_string();
    assert!(err.contains("unknown model"), "{err}");
    let (status, body) = http_request(
        http_port,
        "POST",
        "/generate",
        r#"{"prompt": "hi ", "max_tokens": 4, "model": "nope"}"#,
    );
    assert_eq!(status, 404, "{}", String::from_utf8_lossy(&body));

    let stats = stop(port, h);
    assert_eq!(stat_of(&stats, "models"), 3);
}

// ------------------------------------------------------- LRU unload/load

/// With a one-model residency budget, alternating traffic forces an
/// unload+reload per turn — outputs (including a named session whose
/// model is unloaded between its turns) stay bitwise those of dedicated
/// servers, and the lifecycle counters prove the churn really happened.
#[test]
fn lru_unload_reload_is_bitwise_identical() {
    let (_root_a, ckpt_a) = train_checkpoint("lru_a", 20, 7);
    let (_root_b, ckpt_b) = train_checkpoint("lru_b", 20, 13);
    let turns = ["turn zero ", "turn one ", "turn two "];

    // dedicated reference: one server per model, a named session on A
    let mut ref_sess = Vec::new();
    let mut ref_b = Vec::new();
    {
        let (port, _, h) = start_server(
            &[("default", ckpt_a.as_path())],
            RegistryOpts::default(),
        );
        for p in &turns {
            ref_sess.push(
                client::generate_session_once("127.0.0.1", port, "conv", p, 8, 0.0)
                    .unwrap()
                    .0,
            );
        }
        stop(port, h);
        let (port, _, h) = start_server(
            &[("default", ckpt_b.as_path())],
            RegistryOpts::default(),
        );
        for p in &turns {
            ref_b.push(client::generate_once("127.0.0.1", port, p, 8, 0.0).unwrap().0);
        }
        stop(port, h);
    }

    let (port, _, h) = start_server(
        &[("alpha", ckpt_a.as_path()), ("beta", ckpt_b.as_path())],
        RegistryOpts { max_resident_models: 1, ..RegistryOpts::default() },
    );
    for (i, p) in turns.iter().enumerate() {
        // session turn on alpha, then a beta request that evicts alpha
        let s = client::generate_session_once_for(
            "127.0.0.1",
            port,
            Some("alpha"),
            "conv",
            p,
            8,
            0.0,
        )
        .unwrap()
        .0;
        assert_eq!(
            s, ref_sess[i],
            "alpha session lost context across an LRU unload"
        );
        let b =
            client::generate_once_for("127.0.0.1", port, Some("beta"), p, 8, 0.0)
                .unwrap()
                .0;
        assert_eq!(b, ref_b[i], "beta diverged under the residency budget");
    }
    let stats = stop(port, h);
    assert_eq!(stat_of(&stats, "resident_models"), 1, "{stats}");
    assert!(
        stat_of(&stats, "model_unloads") >= 4,
        "alternating traffic under max_resident_models=1 must unload: {stats}"
    );
    assert!(stat_of(&stats, "model_loads") >= 5, "{stats}");
}

// ------------------------------------------------------------ hot reload

/// A live server picks up a republished checkpoint (new generation in
/// meta.toml) on the next admission: the served bytes match a server
/// freshly bound to the republished directory, and the per-model
/// generation in /stats moves.
#[test]
fn hot_reload_picks_up_republished_checkpoint() {
    let (root, ckpt1) = train_checkpoint("reload", 8, 11);
    let prompt = "the quick ";

    // watch the *parent*: that is what a deployment points serve at
    let (port, http_port, h) = start_server(
        &[("live", root.as_path())],
        RegistryOpts { reload_poll_ms: 0, ..RegistryOpts::default() },
    );
    let out_old =
        client::generate_once_for("127.0.0.1", port, Some("live"), prompt, 12, 0.0)
            .unwrap()
            .0;
    assert!(!out_old.is_empty());
    assert_eq!(model_generation(http_port, "live"), 1);

    // republish: resume the run, train further, save into the same parent
    let mut tr = Trainer::new(native_cfg(11)).unwrap();
    tr.restore(&ckpt1).unwrap();
    tr.train(6).unwrap();
    let ckpt2 = tr.save_checkpoint_to(&root).unwrap();
    assert_ne!(ckpt1, ckpt2, "republish should land at a new step dir");

    // next admission serves the new weights — no restart
    let out_new =
        client::generate_once_for("127.0.0.1", port, Some("live"), prompt, 12, 0.0)
            .unwrap()
            .0;
    assert_eq!(model_generation(http_port, "live"), 2);

    // reference: a fresh server bound after the republish
    let (port2, _, h2) = start_server(
        &[("default", root.as_path())],
        RegistryOpts::default(),
    );
    let ref_new = client::generate_once("127.0.0.1", port2, prompt, 12, 0.0)
        .unwrap()
        .0;
    stop(port2, h2);
    assert_eq!(
        out_new, ref_new,
        "hot reload served different bytes than a fresh bind"
    );

    let stats = stop(port, h);
    assert!(stat_of(&stats, "model_reloads") >= 1, "{stats}");
}
