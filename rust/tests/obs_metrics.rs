//! Property tests for the obs metric primitives, checked against plain
//! sorted-vector oracles:
//!
//! * quantile: for any recorded multiset, `quantile(q)` equals the
//!   bucket upper bound of the true rank-`ceil(q·n)` order statistic —
//!   so the estimate `u` of a true value `p >= 2` always satisfies
//!   `p <= u < 2p` (factor-of-two resolution), and `u == 1` for
//!   `p <= 1`.
//! * merge: snapshot-merge is exactly "record the concatenation".
//! * boundaries: exact powers of two are their own upper bound; one
//!   past a bound moves up a bucket; values beyond the last finite
//!   bound saturate into +Inf.
//! * exposition: the rendered text is structurally valid 0.0.4 —
//!   HELP/TYPE per family, cumulative non-decreasing `le` buckets
//!   ending at `_count`, parseable sample lines, escaped labels.

use chon::obs::expo::{escape_label, Expo, CONTENT_TYPE};
use chon::obs::metrics::{
    bucket_bound, bucket_idx, HistSnapshot, Histogram, N_BUCKETS, N_FINITE,
};

/// Deterministic xorshift64* PRNG — keeps the property tests
/// reproducible without pulling in a rand crate.
struct Rng(u64);

impl Rng {
    fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }
}

/// The oracle: true order statistic at Prometheus rank `ceil(q·n)`.
fn oracle_rank(sorted: &[u64], q: f64) -> u64 {
    let n = sorted.len() as u64;
    let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
    sorted[(rank - 1) as usize]
}

#[test]
fn quantile_matches_sorted_vec_oracle() {
    let mut rng = Rng(0x9e37_79b9_7f4a_7c15);
    for round in 0..50 {
        let n = 1 + (rng.next_u64() % 400) as usize;
        let h = Histogram::new();
        let mut vals = Vec::with_capacity(n);
        for _ in 0..n {
            // mixed magnitudes: 0 .. 2^25 (stay below the +Inf bucket,
            // whose estimate saturates by design — tested separately)
            let mag = rng.next_u64() % (N_FINITE as u64);
            let v = rng.next_u64() % (1u64 << mag).max(1);
            h.record(v);
            vals.push(v);
        }
        vals.sort_unstable();
        let snap = h.snapshot();
        assert_eq!(snap.count(), n as u64);
        assert_eq!(snap.sum, vals.iter().sum::<u64>());
        for q in [0.0, 0.25, 0.5, 0.9, 0.99, 0.999, 1.0] {
            let p = oracle_rank(&vals, q);
            let u = snap.quantile(q);
            // exact: the estimate is the bucket bound of the true order
            // statistic (bucketing is monotone, so ranks line up)
            assert_eq!(
                u,
                bucket_bound(bucket_idx(p)),
                "round {round} q={q}: oracle {p} -> estimate {u}"
            );
            // and therefore within the advertised factor-of-two band
            if p <= 1 {
                assert_eq!(u, 1, "round {round} q={q}");
            } else {
                assert!(
                    u >= p && u < 2 * p,
                    "round {round} q={q}: p={p} u={u} outside [p, 2p)"
                );
            }
        }
    }
}

#[test]
fn merge_is_recording_the_concatenation() {
    let mut rng = Rng(42);
    for _ in 0..20 {
        let (ha, hb, hall) = (Histogram::new(), Histogram::new(), Histogram::new());
        for _ in 0..(rng.next_u64() % 200) {
            let v = rng.next_u64() % (1u64 << (rng.next_u64() % 28)).max(1);
            ha.record(v);
            hall.record(v);
        }
        for _ in 0..(rng.next_u64() % 200) {
            let v = rng.next_u64() % (1u64 << (rng.next_u64() % 28)).max(1);
            hb.record(v);
            hall.record(v);
        }
        let mut merged = ha.snapshot();
        merged.merge(&hb.snapshot());
        assert_eq!(merged, hall.snapshot());
        // merging an empty snapshot is the identity
        let before = merged.clone();
        merged.merge(&HistSnapshot::default());
        assert_eq!(merged, before);
    }
}

#[test]
fn bucket_boundaries_are_inclusive_upper_bounds() {
    for i in 0..N_FINITE {
        // an exact power of two reports itself as its own quantile
        let h = Histogram::new();
        h.record(bucket_bound(i));
        assert_eq!(h.snapshot().quantile(0.5), bucket_bound(i), "2^{i}");
        // one past the bound lands one bucket up (or saturates)
        let h = Histogram::new();
        h.record(bucket_bound(i) + 1);
        let want = if i + 1 < N_FINITE {
            bucket_bound(i + 1)
        } else {
            bucket_bound(N_FINITE - 1) * 2 // +Inf reports saturated 2x
        };
        assert_eq!(h.snapshot().quantile(0.5), want, "2^{i}+1");
    }
}

#[test]
fn empty_single_and_saturated() {
    // empty: every quantile is 0
    let empty = HistSnapshot::default();
    assert_eq!(empty.count(), 0);
    for q in [0.0, 0.5, 1.0] {
        assert_eq!(empty.quantile(q), 0);
    }

    // single sample: every quantile reports its bucket
    let h = Histogram::new();
    h.record(300);
    let s = h.snapshot();
    assert_eq!(s.count(), 1);
    assert_eq!(s.sum, 300);
    for q in [0.0, 0.5, 0.99, 1.0] {
        let u = s.quantile(q);
        assert!((300..600).contains(&u), "q{q} -> {u}");
    }

    // beyond the last finite bound: +Inf bucket, saturated estimate
    let h = Histogram::new();
    h.record(u64::MAX);
    let s = h.snapshot();
    assert_eq!(s.buckets[N_FINITE], 1);
    assert_eq!(s.quantile(0.5), bucket_bound(N_FINITE - 1) * 2);
}

#[test]
fn exposition_is_structurally_valid() {
    let h = Histogram::new();
    for v in [1u64, 7, 7, 900, 1 << 20] {
        h.record(v);
    }
    let mut e = Expo::new();
    e.family("chon_stage_latency_us", "histogram", "Stage latency (µs).");
    e.histogram(
        "chon_stage_latency_us",
        &[("model", "al\"pha"), ("stage", "decode_token")],
        &h.snapshot(),
    );
    e.family("chon_requests_total", "counter", "Requests admitted.");
    e.sample("chon_requests_total", &[("model", "al\"pha")], 5);
    e.family("chon_reactor_open_conns", "gauge", "Open connections.");
    e.sample("chon_reactor_open_conns", &[], 2);
    let text = e.finish();

    assert_eq!(CONTENT_TYPE, "text/plain; version=0.0.4");

    // each family has HELP then TYPE, in order, before its first sample
    // (sample lines start at column 0; comment lines start with '#')
    for (name, kind) in [
        ("chon_stage_latency_us", "histogram"),
        ("chon_requests_total", "counter"),
        ("chon_reactor_open_conns", "gauge"),
    ] {
        let help = text.find(&format!("# HELP {name} ")).expect(name);
        let ty = text.find(&format!("# TYPE {name} {kind}\n")).expect(name);
        let first_sample = text
            .lines()
            .scan(0usize, |pos, l| {
                let at = *pos;
                *pos += l.len() + 1;
                Some((at, l))
            })
            .find(|(_, l)| !l.starts_with('#') && l.starts_with(name))
            .map(|(at, _)| at)
            .expect(name);
        assert!(help < ty && ty < first_sample, "{name} family ordering");
    }

    // every non-comment line is `name[{labels}] value` with numeric value
    let mut cum = 0u64;
    let mut bucket_lines = 0usize;
    for line in text.lines() {
        if line.starts_with('#') {
            continue;
        }
        let (series, value) = line.rsplit_once(' ').expect("sample line");
        let v: f64 = value.parse().expect("numeric value");
        if series.starts_with("chon_stage_latency_us_bucket{") {
            assert!(series.contains("le=\""), "{line}");
            // escaped label value survives intact
            assert!(series.contains("model=\"al\\\"pha\""), "{line}");
            let c = v as u64;
            assert!(c >= cum, "cumulative buckets must not decrease: {line}");
            cum = c;
            bucket_lines += 1;
        }
    }
    assert_eq!(bucket_lines, N_BUCKETS);
    assert_eq!(cum, 5, "last bucket (le=+Inf) must equal the count");
    assert!(text.contains(
        "chon_stage_latency_us_count{model=\"al\\\"pha\",stage=\"decode_token\"} 5\n"
    ));
    assert!(escape_label("a\\b\"c\nd") == "a\\\\b\\\"c\\nd");
}
