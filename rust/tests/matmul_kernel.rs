//! Property tests for the packed matmul microkernel: random (possibly
//! ragged) shapes against a naive f64 triple-loop reference, bitwise
//! serial/parallel identity at every thread count, and the degenerate
//! shapes (1×N, N×1, empty) the tiling edges must survive. Also the
//! packed-NVFP4 quant kernel: in-kernel decode must be bitwise
//! `matmul` on the dequantized matrix at both SIMD levels (CI runs
//! this file in release under `CHON_SIMD=scalar` and `CHON_SIMD=avx2`).

use chon::quant::nvfp4::PackedQuantMat;
use chon::util::ndarray::{
    matmul, matmul_into, matmul_packed, matmul_par, matmul_quant_packed_with, Mat,
    PackedMat, SimdLevel,
};
use chon::util::prng::Rng;
use chon::util::proptest::{check, Gen};

/// Random GEMM problem: shapes land on and around the MR=4 / NR=16 /
/// KC=256 tile edges, including the small-m fallback path.
#[derive(Clone, Debug)]
struct Problem {
    m: usize,
    k: usize,
    n: usize,
    seed: u64,
}

struct ProblemGen;

impl Gen for ProblemGen {
    type Value = Problem;

    fn generate(&self, rng: &mut Rng) -> Problem {
        // mix exact tile multiples with off-by-one raggedness: b-1..=b+1
        let edge = |rng: &mut Rng, bases: &[usize]| {
            let b = bases[rng.below(bases.len())];
            (b + rng.below(3)).saturating_sub(1).max(1)
        };
        Problem {
            m: edge(rng, &[1, 4, 8, 9, 16, 33]),
            k: edge(rng, &[1, 15, 16, 64, 255, 256, 300]),
            n: edge(rng, &[1, 15, 16, 17, 32, 48]),
            seed: rng.next_u64(),
        }
    }

    fn shrink(&self, v: &Problem) -> Vec<Problem> {
        let mut out = Vec::new();
        for (m, k, n) in [
            (v.m / 2, v.k, v.n),
            (v.m, v.k / 2, v.n),
            (v.m, v.k, v.n / 2),
        ] {
            if m >= 1 && k >= 1 && n >= 1 && (m, k, n) != (v.m, v.k, v.n) {
                out.push(Problem { m, k, n, seed: v.seed });
            }
        }
        out
    }
}

fn rand_mat(r: usize, c: usize, seed: u64) -> Mat {
    let mut rng = Rng::new(seed);
    Mat::from_fn(r, c, |_, _| rng.normal())
}

fn naive(a: &Mat, b: &Mat) -> Mat {
    let mut out = Mat::zeros(a.rows, b.cols);
    for i in 0..a.rows {
        for j in 0..b.cols {
            let mut acc = 0.0f64;
            for kk in 0..a.cols {
                acc += a.at(i, kk) as f64 * b.at(kk, j) as f64;
            }
            *out.at_mut(i, j) = acc as f32;
        }
    }
    out
}

fn close(got: &Mat, want: &Mat, k: usize) -> bool {
    // f32 chains vs an f64 reference: error grows with the chain length
    let tol = 1e-5 * (k as f32).sqrt().max(1.0) * 8.0;
    got.data
        .iter()
        .zip(&want.data)
        .all(|(x, y)| (x - y).abs() <= tol * (1.0 + y.abs()))
}

#[test]
fn packed_kernel_matches_naive_reference() {
    check("matmul vs naive", 0xA1, 60, &ProblemGen, |p| {
        let a = rand_mat(p.m, p.k, p.seed ^ 1);
        let b = rand_mat(p.k, p.n, p.seed ^ 2);
        close(&matmul(&a, &b), &naive(&a, &b), p.k)
    });
}

#[test]
fn parallel_is_bit_identical_to_serial_at_every_thread_count() {
    check("matmul_par == matmul", 0xB2, 40, &ProblemGen, |p| {
        let a = rand_mat(p.m, p.k, p.seed ^ 3);
        let b = rand_mat(p.k, p.n, p.seed ^ 4);
        let s = matmul(&a, &b);
        (1..=8).all(|t| matmul_par(&a, &b, t).data == s.data)
    });
}

/// The packed-weight cache contract: consuming a `PackedMat` must be
/// *bitwise* `matmul` for every ragged shape — on both sides of the
/// small-m dispatch edge — and the panels must be reusable across many
/// left-hand sides (that reuse is the whole point of the cache).
#[test]
fn prepacked_b_is_bit_identical_to_matmul() {
    check("matmul_packed == matmul", 0xE5, 60, &ProblemGen, |p| {
        let b = rand_mat(p.k, p.n, p.seed ^ 8);
        let pb = PackedMat::pack(&b);
        if (pb.rows(), pb.cols()) != (p.k, p.n) {
            return false;
        }
        (0..3).all(|i| {
            let a = rand_mat(p.m, p.k, p.seed ^ (9 + i));
            matmul_packed(&a, &pb).data == matmul(&a, &b).data
        })
    });
}

#[test]
fn prepacked_b_degenerate_shapes() {
    let b = rand_mat(7, 5, 20);
    let pb = PackedMat::pack(&b);
    assert_eq!(matmul_packed(&Mat::zeros(0, 7), &pb).data.len(), 0);
    let pb0 = PackedMat::pack(&Mat::zeros(0, 5));
    let out = matmul_packed(&rand_mat(9, 0, 21), &pb0);
    assert_eq!((out.rows, out.cols), (9, 5));
    assert!(out.data.iter().all(|&v| v == 0.0));
    let pbn = PackedMat::pack(&Mat::zeros(7, 0));
    assert_eq!(matmul_packed(&rand_mat(9, 7, 22), &pbn).data.len(), 0);
}

#[test]
fn accumulate_adds_on_top_of_existing_contents() {
    check("matmul_into accumulate", 0xC3, 40, &ProblemGen, |p| {
        let a = rand_mat(p.m, p.k, p.seed ^ 5);
        let b = rand_mat(p.k, p.n, p.seed ^ 6);
        let once = matmul(&a, &b);
        let mut out = once.clone();
        matmul_into(&a, &b, &mut out, true);
        out.data
            .iter()
            .zip(&once.data)
            .all(|(x, y)| (x - 2.0 * y).abs() <= 1e-3 * (1.0 + y.abs()))
    });
}

#[test]
fn vector_shapes_and_empty_dims() {
    // 1×N (vector-matrix), N×1 (matrix-vector), both at once
    let a = rand_mat(1, 64, 1);
    let b = rand_mat(64, 48, 2);
    assert!(close(&matmul(&a, &b), &naive(&a, &b), 64));
    let a = rand_mat(48, 64, 3);
    let b = rand_mat(64, 1, 4);
    assert!(close(&matmul(&a, &b), &naive(&a, &b), 64));
    let a = rand_mat(1, 16, 5);
    let b = rand_mat(16, 1, 6);
    assert!(close(&matmul(&a, &b), &naive(&a, &b), 16));

    // empty on every axis: no panics, correct (possibly empty) output
    let a = Mat::zeros(0, 7);
    let b = rand_mat(7, 5, 7);
    assert_eq!(matmul(&a, &b).data.len(), 0);
    let a = rand_mat(9, 0, 8);
    let b = Mat::zeros(0, 5);
    let out = matmul(&a, &b);
    assert_eq!((out.rows, out.cols), (9, 5));
    assert!(out.data.iter().all(|&v| v == 0.0));
    let a = rand_mat(9, 7, 9);
    let b = Mat::zeros(7, 0);
    assert_eq!(matmul(&a, &b).data.len(), 0);
    assert_eq!(matmul_par(&a, &b, 4).data.len(), 0);

    // accumulate over k == 0 must leave the output untouched
    let a = rand_mat(9, 0, 10);
    let b = Mat::zeros(0, 5);
    let mut out = rand_mat(9, 5, 11);
    let before = out.data.clone();
    matmul_into(&a, &b, &mut out, true);
    assert_eq!(out.data, before);
}

/// The packed-NVFP4 compute contract: decoding e2m1 codes + e4m3 scales
/// *inside* the microkernel must be bitwise `matmul` against the fully
/// dequantized matrix, for every ragged shape (k not a multiple of the
/// 16-value scale block or of KC included) and at BOTH SIMD levels. On
/// hosts without AVX2 the Avx2 request downgrades to scalar, so the
/// check degrades to scalar==scalar rather than silently skipping.
#[test]
fn nvfp4_packed_kernel_is_bitwise_dequantized_matmul() {
    check("quant kernel == matmul(deq)", 0xF6, 60, &ProblemGen, |p| {
        let w = rand_mat(p.k, p.n, p.seed ^ 16);
        let q = PackedQuantMat::pack(&w);
        if (q.rows(), q.cols()) != (p.k, p.n) {
            return false;
        }
        let a = rand_mat(p.m, p.k, p.seed ^ 17);
        let want = matmul(&a, &q.dequantize_mat());
        [SimdLevel::Scalar, SimdLevel::Avx2].iter().all(|&lvl| {
            matmul_quant_packed_with(&a, &q, 1, lvl).data == want.data
        })
    });
}

/// Scalar and AVX2 packed-quant kernels must agree bitwise at every
/// thread count 1..=8 — this is what makes `--packed-compute` output
/// independent of the serving host's SIMD level and thread budget.
#[test]
fn nvfp4_scalar_and_avx2_agree_at_every_thread_count() {
    check("quant kernel simd x threads", 0xF7, 40, &ProblemGen, |p| {
        let w = rand_mat(p.k, p.n, p.seed ^ 18);
        let q = PackedQuantMat::pack(&w);
        let a = rand_mat(p.m, p.k, p.seed ^ 19);
        let reference = matmul_quant_packed_with(&a, &q, 1, SimdLevel::Scalar);
        (1..=8).all(|t| {
            [SimdLevel::Scalar, SimdLevel::Avx2].iter().all(|&lvl| {
                matmul_quant_packed_with(&a, &q, t, lvl).data == reference.data
            })
        })
    });
}

#[test]
fn transpose_matches_reference_on_ragged_tiles() {
    check(
        "blocked transpose",
        0xD4,
        40,
        &ProblemGen,
        |p| {
            let a = rand_mat(p.m.max(1), p.k.max(1), p.seed ^ 7);
            let t = a.transpose();
            if (t.rows, t.cols) != (a.cols, a.rows) {
                return false;
            }
            (0..a.rows).all(|r| (0..a.cols).all(|c| t.at(c, r) == a.at(r, c)))
        },
    );
}
