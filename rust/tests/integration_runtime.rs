//! Integration tests over the PJRT runtime + coordinator, driven against
//! the real AOT artifacts in artifacts/ (built by `make artifacts`).
//!
//! Requirements (documented, not silently skipped):
//!   * build with `--features pjrt` (otherwise the whole file is compiled
//!     out — the native-engine coverage lives in native_backend.rs); the
//!     feature additionally needs the `xla` dependency uncommented in
//!     Cargo.toml plus libxla installed — see rust/README.md;
//!   * an artifacts/ directory (run `make artifacts` first).
//!
//! Every test is `#[ignore]`d so a plain `cargo test` run can't report
//! green while executing zero of them; run explicitly with
//! `cargo test --features pjrt -- --ignored`. Missing artifacts then FAIL
//! loudly instead of masking zero coverage.

#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::runtime::{HostTensor, LoadedArtifact, Manifest};

fn fast_compile_flags() {
    // compile time >> run time for these tiny tests on 1 core
    if std::env::var_os("XLA_FLAGS").is_none() {
        std::env::set_var("XLA_FLAGS", "--xla_backend_optimization_level=0");
    }
}

fn artifacts_dir() -> PathBuf {
    fast_compile_flags();
    for base in ["artifacts", "../artifacts", "../../artifacts"] {
        let p = Path::new(base);
        if p.join("index.txt").exists() {
            return p.to_path_buf();
        }
    }
    panic!("artifacts/ required for --ignored pjrt tests: run `make artifacts`");
}

fn run_cfg(dir: &Path, recipe: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "pjrt".into();
    cfg.artifacts = dir.to_path_buf();
    cfg.model = "tiny_gla".into();
    cfg.recipe = recipe.into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.out_dir = std::env::temp_dir().join("chon_it_runs");
    cfg
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn manifest_parses_for_every_artifact() {
    let dir = artifacts_dir();
    let index = std::fs::read_to_string(dir.join("index.txt")).unwrap();
    let mut checked = 0;
    for name in index.lines().filter(|l| !l.is_empty()) {
        let m = Manifest::load(&dir, name).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(!m.inputs.is_empty() || m.meta_str("kind") == "init", "{name}");
        assert!(!m.outputs.is_empty(), "{name}");
        assert!(m.hlo_path(&dir).exists(), "{name} missing HLO");
        checked += 1;
    }
    assert!(checked >= 5, "only {checked} artifacts");
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn init_artifact_is_deterministic_and_seed_sensitive() {
    let dir = artifacts_dir();
    let init = LoadedArtifact::load(&dir, "init_tiny_gla").unwrap();
    let a = init.run(&[HostTensor::scalar_i32(0)]).unwrap();
    let b = init.run(&[HostTensor::scalar_i32(0)]).unwrap();
    let c = init.run(&[HostTensor::scalar_i32(1)]).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert_eq!(x.f32_data, y.f32_data, "same seed must reproduce");
    }
    let any_diff = a
        .iter()
        .zip(&c)
        .any(|(x, y)| x.f32_data != y.f32_data);
    assert!(any_diff, "different seed must differ");
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn fwd_artifact_produces_finite_logits() {
    let dir = artifacts_dir();
    let init = LoadedArtifact::load(&dir, "init_tiny_gla").unwrap();
    let fwd = LoadedArtifact::load(&dir, "fwd_tiny_gla").unwrap();
    let params = init.run(&[HostTensor::scalar_i32(7)]).unwrap();
    let man = &fwd.manifest;
    let batch = man.meta_usize("batch").unwrap();
    let seq = man.meta_usize("seq_len").unwrap();
    let vocab = man.meta_usize("vocab").unwrap();
    let mut inputs = params;
    inputs.push(HostTensor::i32(
        vec![batch, seq],
        (0..batch * seq).map(|i| (i % vocab) as i32).collect(),
    ));
    let out = fwd.run(&inputs).unwrap();
    assert_eq!(out[0].shape, vec![batch, seq, vocab]);
    assert!(out[0].f32_data.iter().all(|v| v.is_finite()));
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn shape_mismatch_is_reported_not_crashed() {
    let dir = artifacts_dir();
    let fwd = LoadedArtifact::load(&dir, "fwd_tiny_gla").unwrap();
    let bad = vec![HostTensor::scalar_i32(0)];
    let err = fwd.run(&bad).unwrap_err().to_string();
    assert!(err.contains("inputs"), "{err}");
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn training_decreases_loss_bf16() {
    let dir = artifacts_dir();
    let mut tr = Trainer::new(run_cfg(&dir, "bf16")).unwrap();
    tr.train(25).unwrap();
    let first = tr.log.records[0].loss;
    let last = tr.log.final_loss().unwrap();
    assert!(
        last < first - 0.3,
        "loss did not decrease: {first} -> {last}"
    );
    assert!(tr.log.records.iter().all(|r| r.loss.is_finite()));
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn training_quantized_tracks_bf16_early() {
    let dir = artifacts_dir();
    let mut a = Trainer::new(run_cfg(&dir, "bf16")).unwrap();
    let mut b = Trainer::new(run_cfg(&dir, "nvfp4")).unwrap();
    a.train(10).unwrap();
    b.train(10).unwrap();
    let la = a.log.final_loss().unwrap();
    let lb = b.log.final_loss().unwrap();
    assert!((la - lb).abs() / la < 0.1, "bf16 {la} vs nvfp4 {lb}");
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn diag_and_monitor_roundtrip() {
    let dir = artifacts_dir();
    let mut cfg = run_cfg(&dir, "chon");
    cfg.diag_every = 2;
    let mut tr = Trainer::new(cfg).unwrap();
    tr.train(6).unwrap();
    assert_eq!(tr.monitor.records.len(), 3);
    assert!(!tr.monitor.names.is_empty());
    // every metric value finite
    for r in &tr.monitor.records {
        assert!(r.values.iter().all(|v| v.is_finite()));
        assert_eq!(r.channel_maps.len(), 3); // gla: attn_o, mlp_up, attn_gk
    }
    // kurtosis series exists for a known slot
    assert!(tr
        .monitor
        .series("L0.attn.gk.act.kurt")
        .is_some());
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn checkpoint_roundtrip_through_trainer() {
    let dir = artifacts_dir();
    let mut tr = Trainer::new(run_cfg(&dir, "bf16")).unwrap();
    tr.train(3).unwrap();
    let ckpt_dir = std::env::temp_dir().join("chon_it_ckpt");
    let path = tr.save_checkpoint_to(&ckpt_dir).unwrap();
    let before: Vec<f32> = tr.state.params[0].f32_data.clone();
    tr.train(2).unwrap();
    assert_ne!(tr.state.params[0].f32_data, before);
    tr.load_params(&path).unwrap();
    assert_eq!(tr.state.params[0].f32_data, before);
}

#[test]
#[ignore = "needs pjrt artifacts (make artifacts)"]
fn eval_artifact_consistent_with_train_loss() {
    let dir = artifacts_dir();
    let mut cfg = run_cfg(&dir, "bf16");
    cfg.eval_every = 0;
    let mut tr = Trainer::new(cfg).unwrap();
    tr.train(15).unwrap();
    let (eval_loss, acc) = tr.evaluate(2).unwrap();
    let train_loss = tr.log.final_loss().unwrap();
    assert!(
        (eval_loss - train_loss).abs() < 1.0,
        "eval {eval_loss} vs train {train_loss}"
    );
    assert!((0.0..=1.0).contains(&acc));
}
