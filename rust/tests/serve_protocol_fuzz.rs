//! Property/fuzz tests for the serve wire surfaces: the TCP line
//! protocol (escaping, request parsing) and the hand-rolled HTTP/1.1
//! request parser. The parsers sit on the untrusted side of the server,
//! so the properties are blunt: never panic on garbage, reject rather
//! than misread truncated/oversized frames, and round-trip every valid
//! frame exactly — including frames split at arbitrary byte boundaries.

use chon::serve::http::{self, Parsed};
use chon::serve::protocol::{self, Request};
use chon::util::prng::Rng;

// ------------------------------------------------------------- escaping

/// Arbitrary byte vectors survive escape → unescape exactly, and the
/// escaped form is always single-line printable ASCII.
#[test]
fn escape_roundtrips_arbitrary_bytes() {
    let mut rng = Rng::new(0xE5C);
    for _ in 0..500 {
        let n = rng.below(200);
        let bytes: Vec<u8> = (0..n).map(|_| rng.below(256) as u8).collect();
        let esc = protocol::escape_bytes(&bytes);
        assert!(
            esc.bytes().all(|b| (0x20..=0x7e).contains(&b)),
            "escape produced non-printable output for {bytes:?}"
        );
        assert_eq!(
            protocol::unescape_bytes(&esc).unwrap(),
            bytes,
            "round-trip mismatch"
        );
    }
}

/// Unescaping random printable garbage (heavy on backslashes) never
/// panics; truncating a valid escaped string mid-escape errors rather
/// than silently decoding to something else.
#[test]
fn unescape_survives_garbage_and_truncation() {
    let mut rng = Rng::new(0xBAD);
    for _ in 0..500 {
        let n = rng.below(64);
        let s: String = (0..n)
            .map(|_| {
                if rng.below(3) == 0 {
                    '\\'
                } else {
                    (0x20 + rng.below(0x5f) as u8) as char
                }
            })
            .collect();
        // must not panic; Ok or Err both acceptable
        let _ = protocol::unescape_bytes(&s);
    }
    // truncations of a valid escape stream: every prefix is Ok or Err,
    // and a prefix ending inside an escape sequence is an error
    let full = protocol::escape_bytes(&[0x00, 0xFF, b'\\', b'\n', 0x07]);
    for cut in 0..full.len() {
        let prefix = &full[..cut];
        let res = protocol::unescape_bytes(prefix);
        if prefix.ends_with('\\') {
            assert!(res.is_err(), "dangling backslash accepted: {prefix:?}");
        }
        if let Ok(bytes) = res {
            // whatever decoded must re-encode to the same prefix
            assert_eq!(protocol::escape_bytes(&bytes), prefix);
        }
    }
}

// ------------------------------------------------------ line requests

fn random_prompt(rng: &mut Rng, max_chars: usize) -> String {
    let n = 1 + rng.below(max_chars);
    (0..n)
        .map(|_| match rng.below(6) {
            0 => char::from_u32(rng.below(0x20) as u32).unwrap(),
            1 => char::from_u32(0xA0 + rng.below(0x500) as u32).unwrap_or('ß'),
            2 => '\u{1F600}',
            _ => (0x20 + rng.below(0x5f) as u8) as char,
        })
        .collect()
}

fn random_sid(rng: &mut Rng) -> String {
    const OK: &[u8] = b"abcdefghijklmnopqrstuvwxyzABCDEFGHIJKLMNOPQRSTUVWXYZ0123456789._-";
    let n = 1 + rng.below(protocol::MAX_SESSION_ID_LEN);
    let mut s = String::new();
    // first char alphanumeric (ids may not start with '.' or '-')
    s.push(OK[rng.below(62)] as char);
    for _ in 1..n {
        s.push(OK[rng.below(OK.len())] as char);
    }
    s
}

/// Every rendered GEN/SGEN line parses back to exactly the request that
/// produced it.
#[test]
fn random_valid_gen_lines_roundtrip() {
    let mut rng = Rng::new(0x6E2);
    for _ in 0..400 {
        let max_tokens = 1 + rng.below(protocol::MAX_GEN_TOKENS);
        let temp = if rng.below(2) == 0 {
            0.0
        } else {
            rng.uniform() * protocol::MAX_TEMP
        };
        let prompt = random_prompt(&mut rng, 80);
        // a third of the lines carry a MODEL routing prefix (model names
        // share the session-id grammar)
        let want_model = if rng.below(3) == 0 {
            Some(random_sid(&mut rng))
        } else {
            None
        };
        let (line, want_session) = if rng.below(2) == 0 {
            (
                protocol::format_gen_for(
                    want_model.as_deref(),
                    max_tokens,
                    temp,
                    &prompt,
                ),
                None,
            )
        } else {
            let sid = random_sid(&mut rng);
            (
                protocol::format_sgen_for(
                    want_model.as_deref(),
                    &sid,
                    max_tokens,
                    temp,
                    &prompt,
                ),
                Some(sid),
            )
        };
        match protocol::parse_request(line.trim_end()) {
            Ok(Request::Gen {
                max_tokens: mt,
                temp: t,
                prompt: p,
                session,
                model,
            }) => {
                assert_eq!(mt, max_tokens);
                assert_eq!(t.to_bits(), temp.to_bits(), "temp drifted");
                assert_eq!(p, prompt);
                assert_eq!(session, want_session);
                assert_eq!(model, want_model);
            }
            other => panic!("valid line {line:?} parsed to {other:?}"),
        }
    }
}

/// Random mutations (truncation, byte splices, doubled frames) of valid
/// request lines never panic the parser, and oversized frames always
/// reject.
#[test]
fn mutated_and_oversized_lines_never_panic() {
    let mut rng = Rng::new(0x517);
    for _ in 0..600 {
        let base = match rng.below(4) {
            0 => protocol::format_gen(8, 0.5, &random_prompt(&mut rng, 40)),
            1 => protocol::format_sgen(
                &random_sid(&mut rng),
                8,
                0.0,
                &random_prompt(&mut rng, 40),
            ),
            2 => "STATS\n".to_string(),
            _ => "PING\n".to_string(),
        };
        let mut bytes = base.into_bytes();
        match rng.below(3) {
            0 => {
                // truncate
                let cut = rng.below(bytes.len() + 1);
                bytes.truncate(cut);
            }
            1 => {
                // splice random bytes (keep it valid UTF-8 by using ASCII)
                for _ in 0..1 + rng.below(4) {
                    if bytes.is_empty() {
                        break;
                    }
                    let at = rng.below(bytes.len());
                    bytes[at] = rng.below(0x80) as u8;
                }
            }
            _ => {
                // duplicate the frame into itself
                let copy = bytes.clone();
                let at = rng.below(bytes.len() + 1);
                bytes.splice(at..at, copy);
            }
        }
        if let Ok(s) = String::from_utf8(bytes) {
            // must not panic; the Result content is unconstrained
            let _ = protocol::parse_request(s.trim_end_matches('\n'));
        }
    }
    // oversized prompt: over the cap even when every byte is benign
    let huge = format!(
        "GEN 5 0.0\t{}",
        "a".repeat(protocol::MAX_PROMPT_BYTES + 1)
    );
    assert!(protocol::parse_request(&huge).is_err());
    // oversized max_tokens / bad numbers
    assert!(protocol::parse_request("GEN 100000 0.0\thi").is_err());
    assert!(protocol::parse_request("GEN 5 1e99\thi").is_err());
    assert!(protocol::parse_request("GEN 18446744073709551617 0\thi").is_err());
}

// -------------------------------------------------------------- http

fn random_http_request(rng: &mut Rng) -> (Vec<u8>, String, String, Vec<u8>) {
    let method = ["GET", "POST", "HEAD"][rng.below(3)].to_string();
    let path = format!(
        "/{}",
        (0..rng.below(30))
            .map(|_| (b'a' + rng.below(26) as u8) as char)
            .collect::<String>()
    );
    let n_headers = rng.below(5);
    let mut head = format!("{method} {path} HTTP/1.1\r\n");
    for i in 0..n_headers {
        head.push_str(&format!("X-H{i}: v{}\r\n", rng.below(1000)));
    }
    let body: Vec<u8> = if method == "POST" {
        (0..rng.below(200)).map(|_| rng.below(256) as u8).collect()
    } else {
        Vec::new()
    };
    if !body.is_empty() {
        head.push_str(&format!("Content-Length: {}\r\n", body.len()));
    }
    head.push_str("\r\n");
    let mut raw = head.into_bytes();
    raw.extend_from_slice(&body);
    (raw, method, path, body)
}

/// A valid request fed one byte at a time is Partial at every proper
/// prefix and parses completely (with the exact consumed count) at the
/// end — the incremental parser survives any read-boundary split.
#[test]
fn http_parser_handles_any_split_boundary() {
    let mut rng = Rng::new(0x477);
    for _ in 0..60 {
        let (raw, method, path, body) = random_http_request(&mut rng);
        for cut in 0..raw.len() {
            match http::parse_request(&raw[..cut]) {
                Ok(Parsed::Partial) => {}
                Ok(Parsed::Complete(..)) => {
                    panic!("complete on a proper prefix of {method} {path}")
                }
                Err(e) => panic!(
                    "prefix {cut} of valid {method} {path} rejected: {}",
                    e.message
                ),
            }
        }
        match http::parse_request(&raw) {
            Ok(Parsed::Complete(req, consumed)) => {
                assert_eq!(consumed, raw.len());
                assert_eq!(req.method, method);
                assert_eq!(req.target, path);
                assert_eq!(req.body, body);
            }
            _ => panic!("full valid request did not parse"),
        }
    }
}

/// Two concatenated (pipelined) requests parse one at a time with exact
/// consumed offsets.
#[test]
fn http_pipelined_requests_parse_in_sequence() {
    let mut rng = Rng::new(0x999);
    for _ in 0..40 {
        let (a, am, ap, ab) = random_http_request(&mut rng);
        let (b, bm, bp, bb) = random_http_request(&mut rng);
        let mut both = a.clone();
        both.extend_from_slice(&b);
        let Ok(Parsed::Complete(ra, ca)) = http::parse_request(&both) else {
            panic!("first pipelined request lost");
        };
        assert_eq!(ca, a.len());
        assert_eq!((ra.method, ra.target, ra.body), (am, ap, ab));
        let Ok(Parsed::Complete(rb, cb)) = http::parse_request(&both[ca..])
        else {
            panic!("second pipelined request lost");
        };
        assert_eq!(ca + cb, both.len());
        assert_eq!((rb.method, rb.target, rb.body), (bm, bp, bb));
    }
}

/// Random byte soup never panics the HTTP parser, and unbounded header
/// sections / bodies are rejected instead of buffered forever.
#[test]
fn http_parser_survives_garbage_and_enforces_caps() {
    let mut rng = Rng::new(0xF00D);
    for _ in 0..400 {
        let n = rng.below(300);
        let soup: Vec<u8> = (0..n)
            .map(|_| match rng.below(8) {
                0 => b'\r',
                1 => b'\n',
                2 => b' ',
                3 => b':',
                _ => rng.below(256) as u8,
            })
            .collect();
        // must not panic; any of Partial/Complete/Err is acceptable
        let _ = http::parse_request(&soup);
    }
    // header section growing without a terminator trips the cap
    let mut endless = b"GET / HTTP/1.1\r\n".to_vec();
    while endless.len() <= http::MAX_HEAD_BYTES {
        endless.extend_from_slice(b"X-Pad: aaaaaaaaaaaaaaaaaaaaaaaa\r\n");
    }
    assert!(http::parse_request(&endless).is_err());
    // a declared body over the cap rejects before any body bytes arrive
    let big = format!(
        "POST /g HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
        http::MAX_BODY_BYTES + 1
    );
    assert!(http::parse_request(big.as_bytes()).is_err());
    // mutations of a valid head: truncate/splice, never panic
    for _ in 0..300 {
        let (mut raw, ..) = random_http_request(&mut rng);
        match rng.below(2) {
            0 => {
                let cut = rng.below(raw.len() + 1);
                raw.truncate(cut);
            }
            _ => {
                for _ in 0..1 + rng.below(6) {
                    if raw.is_empty() {
                        break;
                    }
                    let at = rng.below(raw.len());
                    raw[at] = rng.below(256) as u8;
                }
            }
        }
        let _ = http::parse_request(&raw);
    }
}
