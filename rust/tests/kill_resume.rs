//! Kill-and-resume mid-stream, as a tier-1 contract (not just a chaos
//! scenario): SIGKILL a real `chon serve` process while a generation is
//! streaming, restart it on the same checkpoint + spill directory, and
//! require a named session that was spilled before the kill to continue
//! bit-identically to a server that was never interrupted.
//!
//! Dogfoods the loadtest supervisor (`loadtest::proc::ServerProc`) so
//! the harness's own spawn/banner-scan/SIGKILL plumbing is covered by
//! the tier-1 suite too. The server binary is the real release artifact
//! via `CARGO_BIN_EXE_chon`.

use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::time::{Duration, Instant};

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::loadtest::proc::{ServeSpec, ServerProc};
use chon::serve::{client, protocol};

fn train_checkpoint(tag: &str, steps: usize) -> PathBuf {
    let root = std::env::temp_dir().join(format!("chon_kr_ckpt_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = "tiny_gla".into();
    cfg.recipe = "chon".into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.seed = 7;
    cfg.out_dir = std::env::temp_dir().join("chon_kr_runs");
    let mut tr = Trainer::new(cfg).unwrap();
    tr.train(steps).unwrap();
    tr.save_checkpoint_to(&root).unwrap()
}

/// Poll a counter family on the server's /metrics until it reaches
/// `min` (panics past the deadline — the precondition never held).
fn wait_counter(server: &ServerProc, family: &str, min: f64) {
    let deadline = Instant::now() + Duration::from_secs(15);
    loop {
        if let Ok(body) = server.scrape_metrics() {
            if client::metric_total(&body, family).unwrap_or(0.0) >= min {
                return;
            }
        }
        assert!(
            Instant::now() < deadline,
            "{family} never reached {min}; server log:\n{}",
            server.log_tail()
        );
        std::thread::sleep(Duration::from_millis(50));
    }
}

#[test]
fn sigkill_mid_stream_then_restart_resumes_sessions_bit_identically() {
    let ckpt = train_checkpoint("midstream", 12);
    let bin = PathBuf::from(env!("CARGO_BIN_EXE_chon"));
    let out = std::env::temp_dir().join("chon_kr_it");
    let _ = std::fs::remove_dir_all(&out);
    std::fs::create_dir_all(&out).unwrap();

    let spec = ServeSpec {
        checkpoint: Some(ckpt.clone()),
        max_resident_sessions: 1, // the second session's check-in evicts the first
        spill_dir: Some(out.join("spill")),
        ..Default::default()
    };
    let (p1, p2) = ("the quick brown ", "and then the ");

    // --- incarnation 1: seed kr_a, force it to spill, then die loudly ---
    let mut server1 = ServerProc::spawn(&bin, &spec, &out.join("serve1.log")).unwrap();
    let mut conn = client::open_conn("127.0.0.1", server1.port).unwrap();
    let (a1, n1, _) = client::generate_session_on(&mut conn, "kr_a", p1, 8, 0.0).unwrap();
    assert_eq!(n1, 8);
    let (_b1, _, _) = client::generate_session_on(&mut conn, "kr_b", p1, 8, 0.0).unwrap();
    // the spill must be on disk BEFORE the kill, or the restart has
    // nothing to resume from — wait for the eviction to be observable
    wait_counter(&server1, "chon_session_evictions_total", 1.0);

    // start a long generation and SIGKILL with tokens provably in flight
    let mut raw = client::open_conn("127.0.0.1", server1.port).unwrap();
    raw.write_all(protocol::format_gen(64, 0.0, "some long stream ").as_bytes())
        .unwrap();
    let mut reader = BufReader::new(raw.try_clone().unwrap());
    let mut line = String::new();
    let mut toks = 0;
    while toks < 2 {
        line.clear();
        assert!(
            reader.read_line(&mut line).unwrap() > 0,
            "stream ended before the kill point"
        );
        if line.starts_with("TOK ") {
            toks += 1;
        }
        assert!(!line.starts_with("ERR "), "mid-stream request failed: {line}");
    }
    server1.kill_hard().unwrap();
    // the killed server's socket surfaces the crash (EOF or reset), not a hang
    line.clear();
    let ended = reader.read_line(&mut line).map(|n| n == 0).unwrap_or(true);
    assert!(ended, "expected EOF/reset after SIGKILL, got {line:?}");

    // --- incarnation 2: same checkpoint dir, same spill dir ---
    let mut server2 = ServerProc::spawn(&bin, &spec, &out.join("serve2.log")).unwrap();
    let mut conn2 = client::open_conn("127.0.0.1", server2.port).unwrap();
    let (a2, n2, _) =
        client::generate_session_on(&mut conn2, "kr_a", p2, 8, 0.0).unwrap();
    assert_eq!(n2, 8);
    // and it really came from the spill file, not a fresh session
    wait_counter(&server2, "chon_session_reloads_total", 1.0);
    server2.stop().unwrap();

    // --- reference: one uninterrupted server, its own spill dir ---
    let ref_spec = ServeSpec {
        checkpoint: Some(ckpt),
        spill_dir: Some(out.join("ref_spill")),
        ..Default::default()
    };
    let mut reference =
        ServerProc::spawn(&bin, &ref_spec, &out.join("serve_ref.log")).unwrap();
    let mut rconn = client::open_conn("127.0.0.1", reference.port).unwrap();
    let (ra1, _, _) =
        client::generate_session_on(&mut rconn, "kr_a", p1, 8, 0.0).unwrap();
    let (_rb1, _, _) =
        client::generate_session_on(&mut rconn, "kr_b", p1, 8, 0.0).unwrap();
    let (ra2, _, _) =
        client::generate_session_on(&mut rconn, "kr_a", p2, 8, 0.0).unwrap();
    reference.stop().unwrap();

    assert_eq!(a1, ra1, "first turn must match before the crash even matters");
    assert_eq!(
        a2, ra2,
        "continuation after SIGKILL + restart must be bit-identical to an \
         uninterrupted server"
    );
}
