//! Shared integration-test glue (Cargo's `tests/common/mod.rs` pattern —
//! each test crate pulls this in with `mod common;`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Minimal HTTP client: one request, Connection: close, returns
/// (status, body-after-dechunking-if-chunked). Deliberately independent
/// of `serve::http` so the tests exercise the server's framing with a
/// second implementation.
pub fn http_request(port: u16, method: &str, path: &str, body: &str) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("no header terminator");
    let head = String::from_utf8_lossy(&raw[..head_end]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    let chunked = head.to_ascii_lowercase().contains("transfer-encoding: chunked");
    let mut body_bytes = raw[head_end + 4..].to_vec();
    if chunked {
        body_bytes = dechunk(&body_bytes);
    }
    (status, body_bytes)
}

/// A keep-alive HTTP/1.1 client: many requests on one connection, each
/// response framed by Content-Length or the chunked terminator (never by
/// EOF). Like `http_request`, deliberately independent of `serve::http`.
pub struct KeepAliveClient {
    s: TcpStream,
    buf: Vec<u8>,
}

#[allow(dead_code)] // each test crate compiles common/ separately
impl KeepAliveClient {
    pub fn connect(port: u16) -> KeepAliveClient {
        let s = TcpStream::connect(("127.0.0.1", port)).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(120))).ok();
        KeepAliveClient { s, buf: Vec::new() }
    }

    fn send(&mut self, method: &str, path: &str, body: &str) {
        let req = format!(
            "{method} {path} HTTP/1.1\r\nHost: t\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        );
        self.s.write_all(req.as_bytes()).unwrap();
    }

    /// One request-response round trip; the connection stays open.
    pub fn request(&mut self, method: &str, path: &str, body: &str) -> (u16, Vec<u8>) {
        self.send(method, path, body);
        self.read_response()
    }

    /// True pipelining: write every request before reading any response;
    /// responses come back in order on the same connection.
    pub fn pipeline(
        &mut self,
        requests: &[(&str, &str, String)],
    ) -> Vec<(u16, Vec<u8>)> {
        for (method, path, body) in requests {
            self.send(method, path, body);
        }
        requests.iter().map(|_| self.read_response()).collect()
    }

    fn fill(&mut self) {
        let mut tmp = [0u8; 4096];
        let n = self.s.read(&mut tmp).expect("read response");
        assert!(n > 0, "server closed connection mid-response");
        self.buf.extend_from_slice(&tmp[..n]);
    }

    fn read_response(&mut self) -> (u16, Vec<u8>) {
        // read until the head is complete
        let head_end = loop {
            if let Some(p) = self.buf.windows(4).position(|w| w == b"\r\n\r\n") {
                break p;
            }
            self.fill();
        };
        let head = String::from_utf8_lossy(&self.buf[..head_end]).to_string();
        let status: u16 = head
            .split_whitespace()
            .nth(1)
            .and_then(|s| s.parse().ok())
            .expect("status code");
        let lower = head.to_ascii_lowercase();
        let chunked = lower.contains("transfer-encoding: chunked");
        let body_start = head_end + 4;
        if chunked {
            // read until the whole chunk stream (0-chunk + CRLF) framed
            let (body, consumed) = loop {
                if let Some(r) = try_dechunk(&self.buf[body_start..]) {
                    break r;
                }
                self.fill();
            };
            self.buf.drain(..body_start + consumed);
            (status, body)
        } else {
            let len: usize = lower
                .lines()
                .find_map(|l| l.strip_prefix("content-length:"))
                .map(|v| v.trim().parse().expect("content-length"))
                .unwrap_or(0);
            while self.buf.len() < body_start + len {
                self.fill();
            }
            let body = self.buf[body_start..body_start + len].to_vec();
            self.buf.drain(..body_start + len);
            (status, body)
        }
    }
}

/// Dechunk a buffer that may be incomplete: Some((body, bytes_consumed))
/// once the terminating 0-chunk is present, None to read more.
#[allow(dead_code)]
fn try_dechunk(b: &[u8]) -> Option<(Vec<u8>, usize)> {
    let mut out = Vec::new();
    let mut pos = 0usize;
    loop {
        let eol = b[pos..].windows(2).position(|w| w == b"\r\n")? + pos;
        let size =
            usize::from_str_radix(std::str::from_utf8(&b[pos..eol]).ok()?.trim(), 16)
                .ok()?;
        let data = eol + 2;
        if b.len() < data + size + 2 {
            return None;
        }
        if size == 0 {
            return Some((out, data + 2));
        }
        out.extend_from_slice(&b[data..data + size]);
        pos = data + size + 2;
    }
}

fn dechunk(mut b: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let Some(eol) = b.windows(2).position(|w| w == b"\r\n") else {
            panic!("chunk size line missing");
        };
        let size = usize::from_str_radix(
            std::str::from_utf8(&b[..eol]).unwrap().trim(),
            16,
        )
        .unwrap();
        b = &b[eol + 2..];
        if size == 0 {
            return out;
        }
        out.extend_from_slice(&b[..size]);
        b = &b[size + 2..]; // skip chunk + CRLF
    }
}
