//! Shared integration-test glue (Cargo's `tests/common/mod.rs` pattern —
//! each test crate pulls this in with `mod common;`).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::Duration;

/// Minimal HTTP client: one request, Connection: close, returns
/// (status, body-after-dechunking-if-chunked). Deliberately independent
/// of `serve::http` so the tests exercise the server's framing with a
/// second implementation.
pub fn http_request(port: u16, method: &str, path: &str, body: &str) -> (u16, Vec<u8>) {
    let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\
         Content-Length: {}\r\n\r\n{body}",
        body.len()
    );
    s.write_all(req.as_bytes()).unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).unwrap();
    let head_end = raw
        .windows(4)
        .position(|w| w == b"\r\n\r\n")
        .expect("no header terminator");
    let head = String::from_utf8_lossy(&raw[..head_end]).to_string();
    let status: u16 = head
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .expect("status code");
    let chunked = head.to_ascii_lowercase().contains("transfer-encoding: chunked");
    let mut body_bytes = raw[head_end + 4..].to_vec();
    if chunked {
        body_bytes = dechunk(&body_bytes);
    }
    (status, body_bytes)
}

fn dechunk(mut b: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    loop {
        let Some(eol) = b.windows(2).position(|w| w == b"\r\n") else {
            panic!("chunk size line missing");
        };
        let size = usize::from_str_radix(
            std::str::from_utf8(&b[..eol]).unwrap().trim(),
            16,
        )
        .unwrap();
        b = &b[eol + 2..];
        if size == 0 {
            return out;
        }
        out.extend_from_slice(&b[..size]);
        b = &b[size + 2..]; // skip chunk + CRLF
    }
}
