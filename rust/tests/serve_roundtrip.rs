//! End-to-end train → checkpoint → serve tests: a short native training
//! run writes a checkpoint dir, `Engine::load` validates it, a `Server`
//! answers generation requests over loopback TCP, and greedy outputs are
//! deterministic — independent of how requests are batched.

use std::path::PathBuf;
use std::thread::JoinHandle;

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::serve::{client, Engine, ModelRegistry, RegistryOpts, ServeOpts, Server};

fn native_cfg(model: &str, recipe: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = model.into();
    cfg.recipe = recipe.into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.seed = 7;
    cfg.out_dir = std::env::temp_dir().join("chon_serve_it_runs");
    cfg
}

/// Train `steps` steps and write a checkpoint dir under a per-test root.
fn train_checkpoint(tag: &str, steps: usize) -> PathBuf {
    let root = std::env::temp_dir().join(format!("chon_serve_ckpt_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    let mut tr = Trainer::new(native_cfg("tiny_gla", "chon")).unwrap();
    tr.train(steps).unwrap();
    tr.save_checkpoint_to(&root).unwrap()
}

fn start_server(ckpt: &PathBuf, max_batch: usize) -> (u16, JoinHandle<String>) {
    let mut registry = ModelRegistry::new(RegistryOpts {
        max_batch,
        max_wait_us: 3000,
        ..RegistryOpts::default()
    });
    registry.register("default", ckpt).expect("register checkpoint");
    let opts = ServeOpts {
        port: 0,            // ephemeral
        http_port: Some(0), // ephemeral
        ..ServeOpts::default()
    };
    let server = Server::bind(registry, &opts).expect("bind");
    let port = server.port();
    let h = std::thread::spawn(move || server.run().expect("server run"));
    (port, h)
}

#[test]
fn train_serve_roundtrip_is_deterministic() {
    let ckpt = train_checkpoint("roundtrip", 20);
    let (port, h) = start_server(&ckpt, 4);

    let (a, n, _) =
        client::generate_once("127.0.0.1", port, "the quick ", 12, 0.0).unwrap();
    let (b, _, _) =
        client::generate_once("127.0.0.1", port, "the quick ", 12, 0.0).unwrap();
    assert_eq!(n, 12);
    assert!(!a.is_empty());
    assert_eq!(a, b, "greedy generation must be deterministic");

    // a third request on a different prompt also completes cleanly (a
    // barely-trained byte model may legitimately converge to the same
    // continuation, so only determinism is asserted above)
    let (c, nc, _) =
        client::generate_once("127.0.0.1", port, "zqx jw vv ", 12, 0.0).unwrap();
    assert_eq!(nc, 12);
    assert!(!c.is_empty());

    client::send_shutdown("127.0.0.1", port).unwrap();
    let stats = h.join().unwrap();
    assert!(stats.contains("requests=3"), "{stats}");
}

#[test]
fn greedy_output_identical_at_batch_1_and_8() {
    let ckpt = train_checkpoint("batch", 20);

    // batch size 1: a dedicated server that can never coalesce
    let (port1, h1) = start_server(&ckpt, 1);
    let (solo, _, _) =
        client::generate_once("127.0.0.1", port1, "hello worl", 16, 0.0).unwrap();
    client::send_shutdown("127.0.0.1", port1).unwrap();
    h1.join().unwrap();

    // batch size 8: fire 8 identical requests concurrently
    let (port8, h8) = start_server(&ckpt, 8);
    let mut outs: Vec<String> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                s.spawn(move || {
                    client::generate_once("127.0.0.1", port8, "hello worl", 16, 0.0)
                        .unwrap()
                        .0
                })
            })
            .collect();
        for hh in handles {
            outs.push(hh.join().unwrap());
        }
    });
    let stats = client::fetch_stats("127.0.0.1", port8).unwrap();
    client::send_shutdown("127.0.0.1", port8).unwrap();
    h8.join().unwrap();

    for (i, o) in outs.iter().enumerate() {
        assert_eq!(o, &solo, "batched output {i} diverged from batch-1 output");
    }
    assert!(stats.contains("requests=8"), "{stats}");
}

#[test]
fn serve_works_without_optimizer_state() {
    // an inference-only checkpoint copy (optim.ckpt deleted) still serves
    let ckpt = train_checkpoint("nooptim", 6);
    std::fs::remove_file(ckpt.join("optim.ckpt")).unwrap();
    let eng = Engine::load(&ckpt).unwrap();
    assert_eq!(eng.meta.step, 6);
    // ... but a Trainer resume must fail loudly instead of resetting Adam
    let mut tr = Trainer::new(native_cfg("tiny_gla", "chon")).unwrap();
    let err = tr.restore(&ckpt).unwrap_err().to_string();
    assert!(err.contains("optimizer state"), "{err}");
}

#[test]
fn corrupt_and_mismatched_checkpoints_fail_loudly() {
    let ckpt = train_checkpoint("corrupt", 4);

    // sanity: pristine dir loads
    Engine::load(&ckpt).unwrap();

    // truncated params file
    let params = ckpt.join("params.ckpt");
    let bytes = std::fs::read(&params).unwrap();
    std::fs::write(&params, &bytes[..bytes.len() / 3]).unwrap();
    assert!(Engine::load(&ckpt).is_err(), "truncated params must not load");
    std::fs::write(&params, &bytes).unwrap();

    // metadata claiming a different model -> layout validation trips
    // (tiny_sa has fewer parameter tensors than the stored tiny_gla set)
    let meta = ckpt.join("meta.toml");
    let text = std::fs::read_to_string(&meta).unwrap();
    std::fs::write(&meta, text.replace("tiny_gla", "tiny_sa")).unwrap();
    let err = Engine::load(&ckpt).unwrap_err();
    assert!(
        format!("{err:#}").contains("parameter tensors"),
        "wrong-model load must name the mismatch: {err:#}"
    );
    std::fs::write(&meta, &text).unwrap();

    // metadata claiming an unknown recipe
    std::fs::write(&meta, text.replace("recipe = \"chon\"", "recipe = \"fp2\"")).unwrap();
    let err = Engine::load(&ckpt).unwrap_err();
    assert!(format!("{err:#}").contains("recipe"), "{err:#}");
    std::fs::write(&meta, &text).unwrap();

    // garbage magic
    std::fs::write(&params, b"NOTACKPTxxxxxxxx").unwrap();
    assert!(Engine::load(&ckpt).is_err());
    std::fs::write(&params, &bytes).unwrap();

    // missing tokenizer
    let tok = ckpt.join("tokenizer.txt");
    let tok_text = std::fs::read_to_string(&tok).unwrap();
    std::fs::remove_file(&tok).unwrap();
    assert!(Engine::load(&ckpt).is_err(), "missing tokenizer must not load");
    std::fs::write(&tok, tok_text).unwrap();

    // after all restorations the dir loads again
    Engine::load(&ckpt).unwrap();
}

#[test]
fn trainer_restore_resumes_optimizer_and_step() {
    let mut tr = Trainer::new(native_cfg("tiny_gla", "chon")).unwrap();
    tr.train(8).unwrap();
    let root = std::env::temp_dir().join("chon_serve_ckpt_resume");
    let _ = std::fs::remove_dir_all(&root);
    let ckpt = tr.save_checkpoint_to(&root).unwrap();
    let m_before = tr.state.m[1].f32_data.clone();

    let mut tr2 = Trainer::new(native_cfg("tiny_gla", "chon")).unwrap();
    tr2.restore(&ckpt).unwrap();
    assert_eq!(tr2.state.step, 8);
    assert_eq!(tr2.state.m[1].f32_data, m_before, "Adam m must survive");
    assert_eq!(tr2.state.params[0].f32_data, tr.state.params[0].f32_data);

    // recipe mismatch is an explicit error (not a silent reset)
    let mut tr3 = Trainer::new(native_cfg("tiny_gla", "bf16")).unwrap();
    let err = tr3.restore(&ckpt).unwrap_err().to_string();
    assert!(err.contains("recipe"), "{err}");
    // ...while param-only transplants stay allowed across recipes
    tr3.load_params(&ckpt).unwrap();
    assert_eq!(tr3.state.params[0].f32_data, tr.state.params[0].f32_data);
}
