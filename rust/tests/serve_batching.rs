//! Batcher coalescing invariants (every client gets exactly its own
//! completion, batching never changes outputs) and tokenizer round-trip
//! properties.

use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::time::{Duration, Instant};

use chon::data::corpus::{Corpus, CorpusConfig};
use chon::data::tokenizer::Tokenizer;
use chon::runtime::native::model::init_params;
use chon::runtime::native::model_cfg;
use chon::runtime::native::recipe::recipe;
use chon::serve::{Engine, GenRequest, ReplySink, RequestBatcher, StoreOpts, TokenEvent};
use chon::util::prng::Rng;
use chon::util::proptest::{check, Gen};

fn test_engine() -> Engine {
    let cfg = model_cfg("tiny_gla").unwrap();
    let mut params = init_params(&cfg, 9);
    // init_params zeroes lm_head (uniform logits) — that would make every
    // greedy completion identical; give the head deterministic random
    // weight so prompts actually diverge
    let mut rng = Rng::new(77);
    let head = params.last_mut().unwrap();
    rng.fill_normal(&mut head.f32_data, 0.3);
    Engine::from_parts(cfg, recipe("chon").unwrap(), Tokenizer::byte_level(), &params)
}

/// Greedy reference generation straight on the engine (no batcher).
fn reference_completion(engine: &Engine, prompt: &str, n: usize) -> Vec<u8> {
    let toks = engine.tokenizer.encode(prompt);
    let mut sess = engine.new_session();
    let logits = engine.prefill(&mut sess, &toks);
    let mut rng = Rng::new(0);
    let mut last = engine.sample(&logits, 0.0, &mut rng);
    let mut out = engine.tokenizer.decode_bytes(&[last]);
    for _ in 1..n {
        let l = engine.decode_step(&mut [&mut sess], &[last]);
        last = engine.sample(l.row(0), 0.0, &mut rng);
        out.extend(engine.tokenizer.decode_bytes(&[last]));
    }
    out
}

fn drain(rx: &Receiver<TokenEvent>) -> (Vec<u8>, usize) {
    let mut bytes = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_secs(60)).expect("token event") {
            TokenEvent::Token(p) => bytes.extend(p),
            TokenEvent::Done { n_tokens, .. } => return (bytes, n_tokens),
            TokenEvent::Error(e) => panic!("generation failed: {e}"),
            TokenEvent::Retry(e) => panic!("unexpected retry: {e}"),
        }
    }
}

/// N concurrent clients with distinct prompts each receive exactly the
/// completion of *their* prompt — byte-for-byte what a lone engine
/// produces — no matter how the batcher interleaves them.
#[test]
fn concurrent_clients_get_their_own_completion() {
    let max_tokens = 10;
    let prompts: Vec<String> =
        (0..6).map(|i| format!("prompt number {i} says ")).collect();
    let expected: Vec<Vec<u8>> = {
        let eng = test_engine();
        prompts
            .iter()
            .map(|p| reference_completion(&eng, p, max_tokens))
            .collect()
    };
    // distinct prompts should produce distinct continuations; if the
    // untrained model ever collapses them the per-client equality check
    // below still validates content, it just can't catch cross-wiring
    if expected.iter().all(|e| e == &expected[0]) {
        eprintln!("warning: all reference completions identical (weak fixture)");
    }

    let batcher = RequestBatcher::spawn(
        test_engine(),
        4,
        Duration::from_micros(2000),
        0,
        StoreOpts::default(),
    )
    .unwrap();
    let mut receivers = Vec::new();
    for p in &prompts {
        let (tx, rx) = channel();
        batcher
            .submitter()
            .send(GenRequest {
                prompt: p.clone(),
                max_tokens,
                temp: 0.0,
                session: None,
                reply: ReplySink::channel(tx),
                cancel: Arc::new(AtomicBool::new(false)),
                queued_at: Instant::now(),
            })
            .unwrap();
        receivers.push(rx);
    }
    for (i, rx) in receivers.iter().enumerate() {
        let (text, n) = drain(rx);
        assert_eq!(n, max_tokens);
        assert_eq!(
            text, expected[i],
            "client {i} got someone else's (or a batch-dependent) completion"
        );
    }
    assert!(
        batcher.stats.mean_batch() > 1.0,
        "6 concurrent requests should coalesce (mean batch {})",
        batcher.stats.mean_batch()
    );
    batcher.shutdown();
}

/// Random valid-UTF-8 strings drawn from ASCII, control bytes and
/// multi-byte scripts; shrinks by halving.
struct StringGen {
    max_chars: usize,
}

impl Gen for StringGen {
    type Value = String;

    fn generate(&self, rng: &mut Rng) -> String {
        let n = rng.below(self.max_chars + 1);
        (0..n)
            .map(|_| match rng.below(8) {
                0 => char::from_u32(rng.below(0x20) as u32).unwrap(), // controls
                1 => char::from_u32(0xA0 + rng.below(0x500) as u32).unwrap_or('ß'),
                2 => char::from_u32(0x4E00 + rng.below(0x100) as u32).unwrap_or('中'),
                3 => '\u{1F600}', // 4-byte emoji
                _ => (0x20 + rng.below(0x5F) as u8) as char, // printable ascii
            })
            .collect()
    }

    fn shrink(&self, v: &String) -> Vec<String> {
        let chars: Vec<char> = v.chars().collect();
        if chars.len() <= 1 {
            return Vec::new();
        }
        vec![
            chars[..chars.len() / 2].iter().collect(),
            chars[chars.len() / 2..].iter().collect(),
        ]
    }
}

#[test]
fn tokenizer_roundtrip_property_byte_level() {
    let tok = Tokenizer::byte_level();
    check(
        "byte-level decode∘encode == id",
        11,
        300,
        &StringGen { max_chars: 120 },
        |s| tok.decode(&tok.encode(s)) == *s,
    );
}

#[test]
fn tokenizer_roundtrip_property_trained() {
    let corpus = Corpus::new(CorpusConfig::default());
    let tok = Tokenizer::train(&corpus.generate(20_000, 0), 384);
    assert!(!tok.merges.is_empty());
    check(
        "trained decode∘encode == id",
        13,
        200,
        &StringGen { max_chars: 120 },
        |s| tok.decode(&tok.encode(s)) == *s,
    );
}

/// The serialized tokenizer (what checkpoints store) encodes identically
/// to the in-memory one — the serve path sees the same token stream the
/// trainer saw.
#[test]
fn tokenizer_text_format_preserves_encoding_property() {
    let corpus = Corpus::new(CorpusConfig::default());
    let tok = Tokenizer::train(&corpus.generate(20_000, 1), 320);
    let back = Tokenizer::from_text(&tok.to_text()).unwrap();
    check(
        "from_text(to_text) encodes identically",
        17,
        200,
        &StringGen { max_chars: 80 },
        |s| back.encode(s) == tok.encode(s),
    );
}
