//! Serve v2 invariant suite: every scale feature must be *bitwise*
//! invisible in greedy outputs.
//!
//! * cross-session prefill batching: a max-batch-8 server with 8
//!   concurrent ragged prompts produces, per prompt, exactly the bytes a
//!   max-batch-1 server produces for it alone.
//! * paged session cache + LRU eviction: a named session's generations
//!   are identical whether its pages stayed resident, were evicted to
//!   disk and reloaded, or the whole server ran with
//!   `--max-resident-sessions 1`.
//! * HTTP front end: `POST /generate` streams the same tokens the line
//!   protocol streams, through the same batcher; `GET /stats` works.
//! * data-stream checkpointing: a resumed training run's per-step losses
//!   are bit-identical to an uninterrupted run's.

use std::path::{Path, PathBuf};
use std::sync::atomic::AtomicBool;
use std::sync::mpsc::{channel, Receiver};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::data::tokenizer::Tokenizer;
use chon::runtime::native::model::init_params;
use chon::runtime::native::model_cfg;
use chon::runtime::native::recipe::recipe;
use chon::serve::{
    client, protocol, Engine, GenRequest, ModelRegistry, RegistryOpts,
    ReplySink, RequestBatcher, ServeOpts, Server, SessionStore, StoreOpts,
    TokenEvent,
};
use chon::util::json::Json;
use chon::util::prng::Rng;

mod common;
use common::{http_request, KeepAliveClient};

fn native_cfg(model: &str, recipe: &str, seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = model.into();
    cfg.recipe = recipe.into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.seed = seed;
    cfg.out_dir = std::env::temp_dir().join("chon_serve_inv_runs");
    cfg
}

/// Train `steps` steps and write a checkpoint dir under a per-test root.
fn train_checkpoint(tag: &str, steps: usize) -> PathBuf {
    let root = std::env::temp_dir().join(format!("chon_serve_inv_ckpt_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    let mut tr = Trainer::new(native_cfg("tiny_gla", "chon", 7)).unwrap();
    tr.train(steps).unwrap();
    tr.save_checkpoint_to(&root).unwrap()
}

fn start_server(
    ckpt: &Path,
    (opts_in, reg_opts): (ServeOpts, RegistryOpts),
) -> (Server, u16) {
    let mut registry = ModelRegistry::new(reg_opts);
    registry.register("default", ckpt).expect("register checkpoint");
    let server = Server::bind(registry, &opts_in).expect("bind");
    let port = server.port();
    (server, port)
}

fn run_server(server: Server) -> JoinHandle<String> {
    std::thread::spawn(move || server.run().expect("server run"))
}

fn serve_opts(max_batch: usize, max_resident: usize) -> (ServeOpts, RegistryOpts) {
    (
        ServeOpts {
            port: 0,
            http_port: Some(0),
            ..ServeOpts::default()
        },
        RegistryOpts {
            max_batch,
            max_wait_us: 5000,
            store_opts: StoreOpts {
                max_resident_sessions: max_resident,
                ..StoreOpts::default()
            },
            ..RegistryOpts::default()
        },
    )
}

// ---------------------------------------------------------------- prefill

/// 8 concurrent ragged prompts on a max-batch-8 server reproduce, byte
/// for byte, what a max-batch-1 server produces for each prompt alone —
/// prefill batching and decode batching change nothing but throughput.
#[test]
fn prefill_batched_server_is_bit_identical_at_batch_1_and_8() {
    let ckpt = train_checkpoint("prefill", 20);
    let prompts: Vec<String> = (0..8)
        .map(|i| format!("{} prompt number {i} ", "pad ".repeat(i)))
        .collect();

    // batch-1 server: nothing can coalesce
    let (srv1, port1) = start_server(&ckpt, serve_opts(1, 0));
    let h1 = run_server(srv1);
    let solo: Vec<String> = prompts
        .iter()
        .map(|p| {
            client::generate_once("127.0.0.1", port1, p, 12, 0.0).unwrap().0
        })
        .collect();
    client::send_shutdown("127.0.0.1", port1).unwrap();
    h1.join().unwrap();

    // batch-8 server: fire all prompts concurrently so prefill coalesces
    let (srv8, port8) = start_server(&ckpt, serve_opts(8, 0));
    let h8 = run_server(srv8);
    let mut outs: Vec<(usize, String)> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = prompts
            .iter()
            .enumerate()
            .map(|(i, p)| {
                s.spawn(move || {
                    let out =
                        client::generate_once("127.0.0.1", port8, p, 12, 0.0)
                            .unwrap()
                            .0;
                    (i, out)
                })
            })
            .collect();
        for h in handles {
            outs.push(h.join().unwrap());
        }
    });
    let stats = client::fetch_stats("127.0.0.1", port8).unwrap();
    client::send_shutdown("127.0.0.1", port8).unwrap();
    h8.join().unwrap();

    for (i, out) in outs {
        assert_eq!(
            out, solo[i],
            "prompt {i} diverged between batch-1 and batch-8 servers"
        );
    }
    // the batched server must actually have coalesced prefill steps
    let batched: u64 = stats
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix("prefill_batched_steps="))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    assert!(batched > 0, "no prefill steps coalesced: {stats}");
}

// --------------------------------------------------------------- eviction

fn test_engine() -> Engine {
    let cfg = model_cfg("tiny_gla").unwrap();
    let mut params = init_params(&cfg, 9);
    // init_params zeroes lm_head (uniform logits); random head weight
    // makes prompts actually diverge
    let mut rng = Rng::new(77);
    let head = params.last_mut().unwrap();
    rng.fill_normal(&mut head.f32_data, 0.3);
    Engine::from_parts(cfg, recipe("chon").unwrap(), Tokenizer::byte_level(), &params)
}

fn drain(rx: &Receiver<TokenEvent>) -> Vec<u8> {
    let mut bytes = Vec::new();
    loop {
        match rx.recv_timeout(Duration::from_secs(60)).expect("token event") {
            TokenEvent::Token(p) => bytes.extend(p),
            TokenEvent::Done { .. } => return bytes,
            TokenEvent::Error(e) => panic!("generation failed: {e}"),
            TokenEvent::Retry(e) => panic!("unexpected retry: {e}"),
        }
    }
}

/// One sequential turn against a named session; waits for completion.
fn session_turn(b: &RequestBatcher, sid: &str, prompt: &str, n: usize) -> Vec<u8> {
    let (tx, rx) = channel();
    b.submitter()
        .send(GenRequest {
            prompt: prompt.into(),
            max_tokens: n,
            temp: 0.0,
            session: Some(sid.into()),
            reply: ReplySink::channel(tx),
            cancel: Arc::new(AtomicBool::new(false)),
            queued_at: Instant::now(),
        })
        .unwrap();
    drain(&rx)
}

/// Greedy outputs of interleaved named sessions are bit-identical whether
/// their state stayed resident (unlimited store) or was evicted to disk
/// and reloaded between every turn (max_resident_sessions = 1).
#[test]
fn evict_then_reload_is_bit_identical_to_resident() {
    let turns: Vec<(&str, String)> = (0..6)
        .map(|t| {
            let sid = if t % 2 == 0 { "alpha" } else { "beta" };
            (sid, format!("turn {t} text "))
        })
        .collect();

    let run = |opts: StoreOpts| -> Vec<Vec<u8>> {
        let b = RequestBatcher::spawn(
            test_engine(),
            4,
            Duration::from_micros(500),
            0,
            opts,
        )
        .unwrap();
        let outs: Vec<Vec<u8>> = turns
            .iter()
            .map(|(sid, prompt)| session_turn(&b, sid, prompt, 8))
            .collect();
        b.shutdown();
        outs
    };

    let resident = run(StoreOpts::default());
    let evicting = run(StoreOpts {
        max_resident_sessions: 1,
        ..StoreOpts::default()
    });
    assert_eq!(
        resident, evicting,
        "evict+reload changed a greedy generation"
    );

    // and the evicting run must actually have spilled and reloaded
    let b = RequestBatcher::spawn(
        test_engine(),
        4,
        Duration::from_micros(500),
        0,
        StoreOpts { max_resident_sessions: 1, ..StoreOpts::default() },
    )
    .unwrap();
    for (sid, prompt) in &turns {
        session_turn(&b, sid, prompt, 8);
    }
    let ev = b.stats.evictions.load(std::sync::atomic::Ordering::Relaxed);
    let rl = b.stats.reloads.load(std::sync::atomic::Ordering::Relaxed);
    b.shutdown();
    assert!(ev >= 2, "expected evictions under max_resident=1, got {ev}");
    assert!(rl >= 2, "expected reloads under max_resident=1, got {rl}");
}

/// A failed reload (corrupt spill file) surfaces as an error on every
/// attempt — it must never silently turn the next request into a fresh
/// empty session — and the session recovers once the bytes are back.
#[test]
fn failed_spill_reload_does_not_silently_reset_the_session() {
    let eng = test_engine();
    let dir = std::env::temp_dir().join("chon_inv_spill_dir");
    let _ = std::fs::remove_dir_all(&dir);
    let mut store = SessionStore::new(StoreOpts {
        max_resident_sessions: 1,
        max_kv_tokens: 0,
        spill_dir: Some(dir.clone()),
    })
    .unwrap();
    let mut a = eng.new_session();
    eng.prefill(&mut a, &[97, 98, 99]);
    store.put("a", a, &eng).unwrap();
    store.put("b", eng.new_session(), &eng).unwrap(); // evicts "a"
    let spill = dir.join("a.sess");
    let orig = std::fs::read(&spill).unwrap();
    let mut corrupt = orig.clone();
    corrupt.push(0);
    std::fs::write(&spill, &corrupt).unwrap();
    assert!(store.take("a", &eng).is_err(), "corrupt blob must error");
    assert!(
        store.take("a", &eng).is_err(),
        "the id must stay tracked after a failed reload, not become None"
    );
    std::fs::write(&spill, &orig).unwrap();
    let back = store.take("a", &eng).unwrap().expect("session recovered");
    assert_eq!(back.pos, 3, "recovered session kept its context");
}

/// Same invariant through the full TCP server: a whole server running
/// with --max-resident-sessions 1 answers named-session traffic
/// identically to an unlimited one.
#[test]
fn server_with_max_resident_1_matches_unlimited() {
    let ckpt = train_checkpoint("evict_srv", 20);
    let transcript = |max_resident: usize| -> (Vec<String>, String) {
        let (srv, port) = start_server(&ckpt, serve_opts(4, max_resident));
        let h = run_server(srv);
        let mut outs = Vec::new();
        for t in 0..6 {
            let sid = if t % 2 == 0 { "sess_x" } else { "sess_y" };
            let prompt = format!("hello {t} ");
            let (text, n, _) = client::generate_session_once(
                "127.0.0.1",
                port,
                sid,
                &prompt,
                10,
                0.0,
            )
            .unwrap();
            assert_eq!(n, 10);
            outs.push(text);
        }
        let stats = client::fetch_stats("127.0.0.1", port).unwrap();
        client::send_shutdown("127.0.0.1", port).unwrap();
        h.join().unwrap();
        (outs, stats)
    };

    let (unlimited, _) = transcript(0);
    let (constrained, stats) = transcript(1);
    assert_eq!(
        unlimited, constrained,
        "--max-resident-sessions 1 changed greedy outputs"
    );
    let evictions: u64 = stats
        .split_whitespace()
        .find_map(|kv| kv.strip_prefix("evictions="))
        .and_then(|v| v.parse().ok())
        .unwrap_or(0);
    assert!(evictions > 0, "constrained server never evicted: {stats}");
}

// ------------------------------------------------------------------- http

/// The HTTP front end streams the same tokens as the line protocol (same
/// batcher, same engine), and /stats + /shutdown work.
#[test]
fn http_generate_matches_line_protocol() {
    let ckpt = train_checkpoint("http", 20);
    let (srv, port) = start_server(&ckpt, serve_opts(4, 0));
    let http_port = srv.http_port().expect("http enabled");
    let h = run_server(srv);

    let (line_text, n, _) =
        client::generate_once("127.0.0.1", port, "the quick ", 12, 0.0).unwrap();
    assert_eq!(n, 12);

    let (status, body) = http_request(
        http_port,
        "POST",
        "/generate",
        r#"{"prompt": "the quick ", "max_tokens": 12}"#,
    );
    assert_eq!(status, 200, "{}", String::from_utf8_lossy(&body));
    // NDJSON: {"piece": "<escaped>"} per token then {"done": ...}
    let mut bytes = Vec::new();
    let mut done = false;
    let mut n_tokens = 0u64;
    for line in String::from_utf8(body).unwrap().lines() {
        let doc = Json::parse(line).unwrap();
        if let Some(piece) = doc.get("piece").and_then(|v| v.as_str()) {
            bytes.extend(protocol::unescape_bytes(piece).unwrap());
        } else {
            assert!(doc.get("done").is_some(), "unexpected line {line}");
            n_tokens =
                doc.get("n_tokens").and_then(|v| v.as_f64()).unwrap() as u64;
            done = true;
        }
    }
    assert!(done, "stream never finished");
    assert_eq!(n_tokens, 12);
    assert_eq!(
        String::from_utf8_lossy(&bytes),
        line_text,
        "HTTP and line protocol produced different tokens"
    );

    // named sessions work over HTTP too and share the store
    let (s1, b1) = http_request(
        http_port,
        "POST",
        "/generate",
        r#"{"prompt": "hi ", "max_tokens": 4, "session": "web1"}"#,
    );
    assert_eq!(s1, 200, "{}", String::from_utf8_lossy(&b1));

    // stats: JSON with the batching counters
    let (status, body) = http_request(http_port, "GET", "/stats", "");
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    assert!(doc.get("requests").and_then(|v| v.as_f64()).unwrap() >= 3.0);
    assert!(doc.get("prefill_tokens").and_then(|v| v.as_f64()).unwrap() > 0.0);
    assert_eq!(
        doc.get("resident_sessions").and_then(|v| v.as_f64()),
        Some(1.0),
        "web1 should be resident"
    );

    // request-level errors are clean 4xx JSON
    let (status, _) = http_request(http_port, "POST", "/generate", "{}");
    assert_eq!(status, 400);
    let (status, _) = http_request(http_port, "GET", "/nope", "");
    assert_eq!(status, 404);
    let (status, _) = http_request(http_port, "PUT", "/generate", "");
    assert_eq!(status, 405);

    // graceful drain over HTTP
    let (status, _) = http_request(http_port, "POST", "/shutdown", "");
    assert_eq!(status, 200);
    h.join().unwrap();
}

// -------------------------------------------------------------- front end

/// N generations pipelined on ONE keep-alive HTTP connection (mixed
/// models) are byte-identical to the same N requests on N fresh
/// Connection:close connections: the reactor's per-connection request
/// queue changes scheduling, never bytes.
#[test]
fn http_keepalive_pipelining_matches_fresh_connections() {
    let ckpt = train_checkpoint("keepalive", 20);
    let (opts, reg_opts) = serve_opts(4, 0);
    let mut registry = ModelRegistry::new(reg_opts);
    registry.register("default", &ckpt).expect("register default");
    registry.register("alt", &ckpt).expect("register alt");
    let server = Server::bind(registry, &opts).expect("bind");
    let port = server.port();
    let http_port = server.http_port().expect("http enabled");
    let h = run_server(server);

    let reqs: Vec<(&str, &str, String)> = (0..6)
        .map(|i| {
            let model = if i % 2 == 0 { "default" } else { "alt" };
            (
                "POST",
                "/generate",
                format!(
                    r#"{{"prompt": "pipe {i} ", "max_tokens": 6, "model": "{model}"}}"#
                ),
            )
        })
        .collect();

    // reference: one fresh connection per request
    let fresh: Vec<(u16, Vec<u8>)> = reqs
        .iter()
        .map(|(m, p, b)| http_request(http_port, m, p, b))
        .collect();
    for (status, body) in &fresh {
        assert_eq!(*status, 200, "{}", String::from_utf8_lossy(body));
    }

    // all six requests written before any response is read
    let mut pipelined_client = KeepAliveClient::connect(http_port);
    let pipelined = pipelined_client.pipeline(&reqs);
    assert_eq!(
        pipelined, fresh,
        "pipelined keep-alive responses diverged from fresh connections"
    );

    // and sequential keep-alive round trips match too
    let mut seq_client = KeepAliveClient::connect(http_port);
    for (i, (m, p, b)) in reqs.iter().enumerate() {
        let got = seq_client.request(m, p, b);
        assert_eq!(got, fresh[i], "keep-alive round trip {i} diverged");
    }

    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap();
}

/// A line-protocol client dribbling its request byte by byte and an HTTP
/// client parked mid-headers never stall other connections — and the
/// dribbled request still completes bit-exactly once it arrives.
#[test]
fn slowloris_clients_do_not_stall_other_requests() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::TcpStream;

    let ckpt = train_checkpoint("slowloris", 20);
    let (srv, port) = start_server(&ckpt, serve_opts(4, 0));
    let http_port = srv.http_port().expect("http enabled");
    let h = run_server(srv);

    let reference = client::generate_once("127.0.0.1", port, "slow drip ", 6, 0.0)
        .unwrap()
        .0;

    // park an HTTP connection mid-header line for the whole test
    let mut stuck = TcpStream::connect(("127.0.0.1", http_port)).unwrap();
    stuck
        .write_all(b"POST /generate HTTP/1.1\r\nHost: t\r\nContent-Le")
        .unwrap();

    // dribble the same GEN request a few bytes at a time, interleaving
    // full-speed requests that must complete while the drip is partial
    let mut slow = TcpStream::connect(("127.0.0.1", port)).unwrap();
    let line = protocol::format_gen(6, 0.0, "slow drip ");
    for (i, chunk) in line.as_bytes().chunks(3).enumerate() {
        slow.write_all(chunk).unwrap();
        slow.flush().unwrap();
        if i % 3 == 0 {
            let (text, n, _) =
                client::generate_once("127.0.0.1", port, "slow drip ", 6, 0.0)
                    .unwrap();
            assert_eq!(n, 6);
            assert_eq!(text, reference, "fast request diverged mid-drip");
        }
    }

    // the dribbled request streams back the exact same bytes
    let mut reader = BufReader::new(slow.try_clone().unwrap());
    let mut bytes = Vec::new();
    let mut resp = String::new();
    loop {
        resp.clear();
        assert!(reader.read_line(&mut resp).unwrap() > 0, "connection died");
        let l = resp.trim_end_matches(['\r', '\n']);
        if let Some(piece) = l.strip_prefix("TOK ") {
            bytes.extend(protocol::unescape_bytes(piece).unwrap());
        } else if l.starts_with("DONE ") {
            break;
        } else {
            panic!("unexpected response line {l:?}");
        }
    }
    assert_eq!(
        String::from_utf8_lossy(&bytes),
        reference,
        "dribbled request produced different bytes"
    );

    drop(stuck); // the half-sent HTTP request just goes away
    let (status, _) = http_request(http_port, "GET", "/stats", "");
    assert_eq!(status, 200);

    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap();
}

/// A write-all-then-shutdown batch client: every pipelined GEN line is
/// written before the client half-closes, so the server sees EOF with
/// the whole backlog still buffered. Every request must be served, in
/// order and byte-identical to fresh connections, before the server
/// closes — the half-close neither truncates the in-flight stream nor
/// discards the buffered pipeline (the threaded front end's read_line
/// loop served every line received before EOF).
#[test]
fn half_closed_batch_client_gets_every_buffered_response() {
    use std::io::{BufRead, BufReader, Write};
    use std::net::{Shutdown, TcpStream};

    let ckpt = train_checkpoint("halfclose", 20);
    let (srv, port) = start_server(&ckpt, serve_opts(4, 0));
    let h = run_server(srv);

    let prompts: Vec<String> =
        (0..3).map(|i| format!("batch eof {i} ")).collect();
    let solo: Vec<String> = prompts
        .iter()
        .map(|p| client::generate_once("127.0.0.1", port, p, 8, 0.0).unwrap().0)
        .collect();

    let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(120))).ok();
    let mut batch = String::new();
    for p in &prompts {
        batch.push_str(&protocol::format_gen(8, 0.0, p));
    }
    s.write_all(batch.as_bytes()).unwrap();
    s.shutdown(Shutdown::Write).unwrap();

    // responses come back in request order, then a clean EOF
    let mut reader = BufReader::new(s);
    let mut outs: Vec<String> = Vec::new();
    let mut bytes: Vec<u8> = Vec::new();
    let mut resp = String::new();
    loop {
        resp.clear();
        if reader.read_line(&mut resp).unwrap() == 0 {
            break; // server closed after draining the backlog
        }
        let l = resp.trim_end_matches(['\r', '\n']);
        if let Some(piece) = l.strip_prefix("TOK ") {
            bytes.extend(protocol::unescape_bytes(piece).unwrap());
        } else if l.starts_with("DONE ") {
            outs.push(String::from_utf8_lossy(&bytes).to_string());
            bytes.clear();
        } else {
            panic!("unexpected response line {l:?}");
        }
    }
    assert_eq!(
        outs.len(),
        prompts.len(),
        "half-closed batch client lost responses: got {outs:?}"
    );
    for (i, out) in outs.iter().enumerate() {
        assert_eq!(
            *out, solo[i],
            "pipelined response {i} diverged from a fresh connection"
        );
    }

    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap();
}

/// Soak: ~1k idle connections parked on the reactor change nothing —
/// concurrent generations stay byte-identical and every idle connection
/// survives the run.
#[test]
fn idle_connection_soak_leaves_serving_undisturbed() {
    let ckpt = train_checkpoint("idle_soak", 20);
    let (srv, port) = start_server(&ckpt, serve_opts(4, 0));
    let h = run_server(srv);

    let baseline = client::generate_once("127.0.0.1", port, "soak ", 8, 0.0)
        .unwrap()
        .0;

    // both ends of every idle conn live in this test process (2 fds
    // each); size the fleet to the limit we can actually get
    let limit = chon::serve::reactor::raise_nofile_limit(8192).unwrap_or(1024);
    let n = ((limit as usize).saturating_sub(256) / 2).min(1000);
    assert!(n >= 64, "not enough fd headroom for the soak (limit {limit})");
    let mut fleet =
        client::IdleFleet::open("127.0.0.1", port, n).expect("open idle fleet");

    for i in 0..3 {
        let (text, ntok, _) =
            client::generate_once("127.0.0.1", port, "soak ", 8, 0.0).unwrap();
        assert_eq!(ntok, 8);
        assert_eq!(text, baseline, "generation {i} diverged under {n} idle conns");
    }
    assert_eq!(fleet.check_alive(), n, "idle connections were dropped");
    drop(fleet);

    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap();
}

/// Over-`--max-conns` accepts are refused loudly, not silently: the line
/// protocol sees `ERR busy` then EOF, the HTTP front end sees a 503, the
/// rejections are counted in `chon_conns_rejected_total`, and the server
/// accepts again as soon as the held connections go away.
#[test]
fn over_capacity_accepts_get_busy_rejects_then_recover() {
    let ckpt = train_checkpoint("busy", 12);
    let (base_opts, reg_opts) = serve_opts(4, 0);
    let opts = ServeOpts { max_conns: 2, ..base_opts };
    let (srv, port) = start_server(&ckpt, (opts, reg_opts));
    let http_port = srv.http_port().expect("http enabled");
    let h = run_server(srv);

    // fill the cap with two parked line connections (ping proves the
    // reactor adopted them, not just the kernel backlog)
    let mut held1 = client::open_conn("127.0.0.1", port).unwrap();
    client::ping(&mut held1).unwrap();
    let mut held2 = client::open_conn("127.0.0.1", port).unwrap();
    client::ping(&mut held2).unwrap();

    // third line connection: ERR busy, then EOF — never a silent close
    let over = client::open_conn("127.0.0.1", port).unwrap();
    let mut reader = std::io::BufReader::new(over);
    let mut line = String::new();
    std::io::BufRead::read_line(&mut reader, &mut line).unwrap();
    assert!(
        line.starts_with("ERR busy"),
        "expected a busy shed notice, got {line:?}"
    );
    line.clear();
    assert_eq!(
        std::io::BufRead::read_line(&mut reader, &mut line).unwrap(),
        0,
        "rejected connection must be closed after the notice"
    );

    // HTTP front end shares the same cap and sheds with a 503
    let (status, body) = http_request(http_port, "GET", "/stats", "");
    assert_eq!(status, 503, "{}", String::from_utf8_lossy(&body));
    assert!(
        String::from_utf8_lossy(&body).contains("busy"),
        "503 body should say why: {}",
        String::from_utf8_lossy(&body)
    );

    // free the cap; the reactor notices the closes on its next wakeup
    drop(held1);
    drop(held2);
    let deadline = Instant::now() + Duration::from_secs(15);
    let metrics = loop {
        match client::fetch_metrics("127.0.0.1", http_port) {
            Ok(body) => break body,
            Err(e) => assert!(
                Instant::now() < deadline,
                "server never recovered after the held conns closed: {e:#}"
            ),
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    let rejected =
        client::metric_total(&metrics, "chon_conns_rejected_total").unwrap_or(0.0);
    assert!(
        rejected >= 2.0,
        "expected >= 2 counted rejections (1 line + 1 http), got {rejected}"
    );

    // and normal service resumed
    let (_, n, _) =
        client::generate_once("127.0.0.1", port, "after the storm ", 4, 0.0).unwrap();
    assert_eq!(n, 4);

    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap();
}

// ----------------------------------------------------------------- resume

/// A resumed run's per-step losses are bit-identical to an uninterrupted
/// run's: the data-stream position checkpoint fast-forwards the pipeline
/// past already-consumed batches.
#[test]
fn resumed_run_losses_bit_identical_to_uninterrupted() {
    let total = 10usize;
    let split = 6usize;

    // uninterrupted reference
    let mut full = Trainer::new(native_cfg("tiny_gla", "chon", 11)).unwrap();
    full.train(total).unwrap();
    let full_losses: Vec<u32> =
        full.log.records.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(full_losses.len(), total);

    // interrupted at `split`, checkpointed, resumed in a fresh process
    // image (fresh Trainer), trained to `total`
    let root = std::env::temp_dir().join("chon_serve_inv_resume");
    let _ = std::fs::remove_dir_all(&root);
    let mut first = Trainer::new(native_cfg("tiny_gla", "chon", 11)).unwrap();
    first.train(split).unwrap();
    let ckpt = first.save_checkpoint_to(&root).unwrap();
    let first_losses: Vec<u32> =
        first.log.records.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(&first_losses[..], &full_losses[..split], "prefix diverged");

    let mut resumed = Trainer::new(native_cfg("tiny_gla", "chon", 11)).unwrap();
    resumed.restore(&ckpt).unwrap();
    assert_eq!(resumed.state.step, split);
    resumed.train(total - split).unwrap();
    let resumed_losses: Vec<u32> =
        resumed.log.records.iter().map(|r| r.loss.to_bits()).collect();
    assert_eq!(
        &resumed_losses[..],
        &full_losses[split..],
        "resumed losses diverged from the uninterrupted run \
         (data-stream fast-forward broken?)"
    );
}
