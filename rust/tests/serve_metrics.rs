//! `GET /metrics` end-to-end: under concurrent generate load the scrape
//! returns valid Prometheus text with populated per-model per-stage
//! latency histograms and reactor health gauges, counters are monotone
//! across scrapes, `/stats` keeps its existing JSON fields, and
//! `HEAD /metrics` honors the no-body contract.

use std::path::{Path, PathBuf};
use std::thread::JoinHandle;

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::serve::{client, ModelRegistry, RegistryOpts, ServeOpts, Server};
use chon::util::json::Json;

mod common;
use common::http_request;

fn native_cfg(seed: u64) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = "tiny_gla".into();
    cfg.recipe = "chon".into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.seed = seed;
    cfg.out_dir = std::env::temp_dir().join("chon_serve_metrics_runs");
    cfg
}

fn train_checkpoint(tag: &str, steps: usize, seed: u64) -> (PathBuf, PathBuf) {
    let root = std::env::temp_dir().join(format!("chon_serve_metrics_{tag}"));
    let _ = std::fs::remove_dir_all(&root);
    let mut tr = Trainer::new(native_cfg(seed)).unwrap();
    tr.train(steps).unwrap();
    let ckpt = tr.save_checkpoint_to(&root).unwrap();
    (root, ckpt)
}

fn start_server(
    entries: &[(&str, &Path)],
    reg_opts: RegistryOpts,
) -> (u16, u16, JoinHandle<String>) {
    let mut registry = ModelRegistry::new(reg_opts);
    for (name, dir) in entries {
        registry.register(name, dir).expect("register model");
    }
    let opts = ServeOpts { port: 0, http_port: Some(0), ..ServeOpts::default() };
    let server = Server::bind(registry, &opts).expect("bind");
    let port = server.port();
    let http_port = server.http_port().expect("http enabled");
    let h = std::thread::spawn(move || server.run().expect("server run"));
    (port, http_port, h)
}

/// `chon_stage_latency_us_count` for one (model, stage) pair.
fn stage_count(body: &str, model: &str, stage: &str) -> f64 {
    client::metric_value(
        body,
        &format!(
            "chon_stage_latency_us_count{{model=\"{model}\",stage=\"{stage}\"}}"
        ),
    )
    .unwrap_or_else(|| panic!("no {stage} count for {model}"))
}

/// Fire `per_thread` generations from each of `threads` concurrent
/// clients against the line protocol; every request must succeed.
fn concurrent_load(port: u16, threads: usize, per_thread: usize, max_tokens: usize) {
    let handles: Vec<_> = (0..threads)
        .map(|t| {
            std::thread::spawn(move || {
                for i in 0..per_thread {
                    let prompt = format!("load {t} {i} ");
                    let (text, n, _) = client::generate_once_for(
                        "127.0.0.1",
                        port,
                        Some("alpha"),
                        &prompt,
                        max_tokens,
                        0.0,
                    )
                    .expect("generate under load");
                    assert!(n > 0 && !text.is_empty());
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn metrics_scrape_under_concurrent_load() {
    let (_root, ckpt) = train_checkpoint("load", 8, 11);
    let (port, http_port, h) =
        start_server(&[("alpha", ckpt.as_path())], RegistryOpts::default());

    const THREADS: usize = 4;
    const PER_THREAD: usize = 3;
    const TOKENS: usize = 8;

    concurrent_load(port, THREADS, PER_THREAD, TOKENS);
    let m1 = client::fetch_metrics("127.0.0.1", http_port).unwrap();
    concurrent_load(port, THREADS, PER_THREAD, TOKENS);
    let m2 = client::fetch_metrics("127.0.0.1", http_port).unwrap();

    let requests = (THREADS * PER_THREAD) as f64;

    // per-model stage histograms are populated with plausible counts:
    // queue-wait once per request, prefill once per admitted group
    // (every request prefills, groups may batch several), decode once
    // per *batched* step — so at least one request's worth of steps
    // (the prefill emits token 1, decode makes the other TOKENS-1)
    for m in [&m1, &m2] {
        assert!(stage_count(m, "alpha", "queue_wait") >= requests);
        assert!(stage_count(m, "alpha", "prefill") >= 1.0);
        assert!(stage_count(m, "alpha", "decode_token") >= TOKENS as f64 - 1.0);
        // the reactor flushed generation bytes at least once per request
        assert!(stage_count(m, "alpha", "write_flush") >= 1.0);
        // histogram structure: cumulative buckets, sum, count all render
        assert!(m.contains("# TYPE chon_stage_latency_us histogram"));
        assert!(m.contains(
            "chon_stage_latency_us_bucket{model=\"alpha\",stage=\"prefill\",le=\"+Inf\"}"
        ));
        assert!(m.contains("chon_stage_latency_us_sum{model=\"alpha\",stage=\"prefill\"}"));

        // connection spans and reactor health gauges
        assert!(client::metric_value(m, "chon_conn_stage_us_count{stage=\"accept\"}")
            .is_some_and(|v| v >= 1.0));
        assert!(client::metric_value(m, "chon_conn_stage_us_count{stage=\"parse\"}")
            .is_some_and(|v| v >= 1.0));
        for gauge in [
            "chon_reactor_tick_lag_us",
            "chon_reactor_mailbox_depth",
            "chon_reactor_open_conns",
            "chon_reactor_outbuf_highwater_bytes",
        ] {
            assert!(client::metric_value(m, gauge).is_some(), "{gauge} missing");
        }

        // ServeStats-derived counters carry the model label
        assert!(client::metric_value(m, "chon_requests_total{model=\"alpha\"}")
            .is_some_and(|v| v >= requests));
        assert!(client::metric_value(m, "chon_model_resident{model=\"alpha\"}")
            .is_some_and(|v| v == 1.0));
    }

    // monotone across scrapes: counters strictly increase under load,
    // stage histogram counts never decrease
    client::assert_metrics_progress(&m1, &m2).unwrap();
    for stage in ["queue_wait", "prefill", "decode_token", "write_flush"] {
        assert!(
            stage_count(&m2, "alpha", stage) >= stage_count(&m1, "alpha", stage),
            "{stage} count decreased across scrapes"
        );
    }
    assert!(
        client::metric_value(&m2, "chon_requests_total{model=\"alpha\"}").unwrap()
            >= 2.0 * requests
    );

    // the same body serves over the test's independent HTTP client, and
    // HEAD returns headers only
    let (status, body) = http_request(http_port, "GET", "/metrics", "");
    assert_eq!(status, 200);
    assert!(String::from_utf8(body).unwrap().contains("chon_requests_total"));
    let (status, body) = http_request(http_port, "HEAD", "/metrics", "");
    assert_eq!(status, 200);
    assert!(body.is_empty(), "HEAD /metrics must not carry a body");

    // /stats keeps its existing JSON surface next to /metrics
    let (status, body) = http_request(http_port, "GET", "/stats", "");
    assert_eq!(status, 200);
    let doc = Json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
    for field in ["requests", "tokens", "models", "per_model"] {
        assert!(doc.get(field).is_some(), "/stats lost field {field:?}");
    }
    assert!(
        doc.get("requests").and_then(|v| v.as_f64()).unwrap() >= 2.0 * requests
    );

    let stats = stop_line(port, h);
    assert!(stats.contains("requests="));
}

fn stop_line(port: u16, h: JoinHandle<String>) -> String {
    client::send_shutdown("127.0.0.1", port).unwrap();
    h.join().unwrap()
}
