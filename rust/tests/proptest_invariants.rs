//! Property-based invariants over the quant/HCP/data substrates, driven
//! by the in-repo mini property-test harness (util::proptest).

use chon::data::corpus::{Corpus, CorpusConfig};
use chon::data::tokenizer::Tokenizer;
use chon::diagnostics;
use chon::hcp;
use chon::quant::{e2m1, nvfp4, rht};
use chon::util::ndarray::{matmul, Mat};
use chon::util::prng::Rng;
use chon::util::proptest::{check, Gen, PairGen, RangeGen, VecGen};

fn vecgen(scale: f32) -> VecGen {
    VecGen { min_blocks: 1, max_blocks: 16, quantum: 16, scale }
}

#[test]
fn prop_dequant_error_bounded_per_block() {
    // |x - dq(q(x))| <= amax_block/6 * (1 + 2^-3) elementwise, any dist.
    check("nvfp4 error bound", 11, 200, &vecgen(2.0), |x| {
        let d = nvfp4::fake_quant(x, nvfp4::Rounding::Rtn, None);
        x.chunks(16).zip(d.chunks(16)).all(|(xb, db)| {
            let amax = xb.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let bound = amax / 6.0 * 1.125 + 1e-7;
            xb.iter().zip(db).all(|(a, b)| (a - b).abs() <= bound)
        })
    });
}

#[test]
fn prop_fake_quant_idempotent() {
    // quantizing an already-quantized tensor is a fixed point
    check("nvfp4 idempotent", 12, 100, &vecgen(3.0), |x| {
        let d1 = nvfp4::fake_quant(x, nvfp4::Rounding::Rtn, None);
        let d2 = nvfp4::fake_quant(&d1, nvfp4::Rounding::Rtn, None);
        d1.iter()
            .zip(&d2)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * a.abs().max(1e-20))
    });
}

#[test]
fn prop_quantize_pack_roundtrip_equals_fake_quant() {
    check("pack roundtrip", 13, 100, &vecgen(1.0), |x| {
        let q = nvfp4::quantize(x, nvfp4::Rounding::Rtn, None);
        let deq = nvfp4::dequantize(&q);
        let fq = nvfp4::fake_quant(x, nvfp4::Rounding::Rtn, None);
        deq.iter()
            .zip(&fq)
            .all(|(a, b)| (a - b).abs() <= 1e-6 * b.abs().max(1e-20))
    });
}

#[test]
fn prop_ftz_in_unit_interval_and_scale_invariant_direction() {
    check("ftz range", 14, 150, &vecgen(1.0), |x| {
        let f = nvfp4::ftz_ratio(x);
        (0.0..=1.0).contains(&f)
    });
}

#[test]
fn prop_storage_is_half_byte_per_element_plus_scales() {
    check(
        "storage size",
        15,
        50,
        &RangeGen { lo: 1, hi: 64 },
        |&blocks| {
            let x = vec![1.0f32; blocks * 16];
            let q = nvfp4::quantize(&x, nvfp4::Rounding::Rtn, None);
            q.storage_bytes() == blocks * 8 + blocks + 4
        },
    );
}

#[test]
fn prop_sr_stays_on_neighbouring_lattice_points() {
    check("sr neighbours", 16, 100, &vecgen(2.0), |x| {
        let mut rng = Rng::new(9);
        let d = nvfp4::fake_quant(x, nvfp4::Rounding::Sr, Some(&mut rng));
        let r = nvfp4::fake_quant(x, nvfp4::Rounding::Rtn, None);
        // SR result within one max lattice gap of the RTN result
        x.chunks(16)
            .zip(d.chunks(16))
            .zip(r.chunks(16))
            .all(|((xb, db), _rb)| {
                let amax = xb.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                // one lattice gap (<= 2 in scaled space) plus the e4m3
                // block-scale rounding slack (rel err <= 2^-3)
                let gap = amax / 3.0 * 1.125 + 1e-7;
                xb.iter().zip(db).all(|(a, b)| (a - b).abs() <= gap)
            })
    });
}

#[test]
fn prop_fwht_involution_and_energy() {
    check(
        "fwht involution",
        17,
        60,
        &RangeGen { lo: 1, hi: 8 },
        |&logn| {
            let n = 1usize << logn;
            let mut rng = Rng::new(logn as u64);
            let x: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            let mut y = x.clone();
            rht::fwht_inplace(&mut y);
            rht::fwht_inplace(&mut y);
            y.iter()
                .zip(&x)
                .all(|(a, b)| (a / n as f32 - b).abs() < 1e-3)
        },
    );
}

#[test]
fn prop_rht_preserves_wgrad_product() {
    // (HX)^T(HdY) == X^T dY for any sizes (before quantization)
    check(
        "rht wgrad identity",
        18,
        30,
        &PairGen(RangeGen { lo: 2, hi: 6 }, RangeGen { lo: 1, hi: 8 }),
        |&(logm, cols)| {
            let m = 1usize << logm;
            let mut rng = Rng::new((logm * 31 + cols) as u64);
            let x = Mat::from_fn(m, cols, |_, _| rng.normal());
            let dy = Mat::from_fn(m, cols, |_, _| rng.normal());
            let s = rht::random_signs(m, &mut rng);
            let xr = rht::rht(&x.transpose(), &s).transpose();
            let dyr = rht::rht(&dy.transpose(), &s).transpose();
            let want = matmul(&x.transpose(), &dy);
            let got = matmul(&xr.transpose(), &dyr);
            want.data
                .iter()
                .zip(&got.data)
                .all(|(a, b)| (a - b).abs() < 1e-3 * a.abs().max(1.0))
        },
    );
}

#[test]
fn prop_hcp_o2b_never_worse_than_baseline() {
    check(
        "hcp beats baseline",
        19,
        25,
        &RangeGen { lo: 1, hi: 8 },
        |&kblocks| {
            let kdim = kblocks * 16;
            let mut rng = Rng::new(kblocks as u64 ^ 0xAB);
            let x = Mat::from_fn(16, kdim, |_, _| rng.student_t(3));
            let w = Mat::from_fn(kdim, 16, |_, _| rng.normal());
            let truth = matmul(&x, &w);
            let cfg = chon::hcp::modes::HcpConfig {
                mode: chon::hcp::modes::Mode::Single,
                order: chon::hcp::modes::Order::O2,
                target: chon::hcp::modes::Target::Both,
            };
            let q = chon::hcp::modes::QuantizedPair::new(&x, &w);
            let idx = hcp::top_k(&hcp::scores(&q.dx, &q.dw), (kdim / 8).max(1));
            let patched = chon::hcp::modes::apply(cfg, &q, &idx).mse(&truth);
            let base = chon::hcp::modes::baseline(&q).mse(&truth);
            patched <= base * 1.0001
        },
    );
}

#[test]
fn prop_top_k_is_subset_and_sorted_by_score() {
    check(
        "top_k ordering",
        20,
        100,
        &RangeGen { lo: 1, hi: 200 },
        |&n| {
            let mut rng = Rng::new(n as u64);
            let scores: Vec<f64> = (0..n).map(|_| rng.uniform() as f64).collect();
            let k = (n / 3).max(1);
            let idx = hcp::top_k(&scores, k);
            if idx.len() != k.min(n) {
                return false;
            }
            // every selected >= every unselected
            let min_sel = idx.iter().map(|&i| scores[i]).fold(f64::INFINITY, f64::min);
            (0..n)
                .filter(|i| !idx.contains(i))
                .all(|i| scores[i] <= min_sel + 1e-15)
        },
    );
}

#[test]
fn prop_tokenizer_roundtrip_lossless() {
    let corpus = Corpus::new(CorpusConfig::default());
    let tok = Tokenizer::train(&corpus.generate(10_000, 0), 384);
    check(
        "tokenizer roundtrip",
        21,
        40,
        &RangeGen { lo: 1, hi: 5000 },
        |&seed| {
            let s = corpus.generate(1 + seed % 2000, seed as u64);
            tok.decode(&tok.encode(&s)) == s
        },
    );
}

#[test]
fn prop_kurtosis_invariant_to_affine_transform() {
    check("kurtosis affine invariance", 22, 80, &vecgen(1.0), |x| {
        if x.len() < 32 {
            return true;
        }
        let k1 = diagnostics::kurtosis(x);
        let y: Vec<f32> = x.iter().map(|&v| 3.0 * v + 7.0).collect();
        let k2 = diagnostics::kurtosis(&y);
        (k1 - k2).abs() < 1e-2 * k1.abs().max(1.0)
    });
}

#[test]
fn prop_e2m1_rtn_minimizes_distance() {
    check(
        "e2m1 nearest",
        23,
        60,
        &RangeGen { lo: 0, hi: 14000 },
        |&i| {
            let v = -7.0 + (i as f32) / 1000.0;
            let q = e2m1::rtn(v);
            let clamped = v.clamp(-6.0, 6.0);
            (0u8..16)
                .map(e2m1::decode)
                .all(|c| (q - clamped).abs() <= (c - clamped).abs() + 1e-6)
        },
    );
}
