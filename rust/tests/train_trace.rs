//! Training-telemetry contracts: telemetry must be pure observation
//! (bit-identical loss trajectory with everything on vs everything
//! off), a resume-appended trace must replay to exactly an
//! uninterrupted run's step series, and a SIGKILLed training process
//! must leave a trace that parses, scrapes, and `chon tail`s.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Command, Stdio};
use std::time::{Duration, Instant};

use chon::config::RunConfig;
use chon::coordinator::Trainer;
use chon::obs::trace;
use chon::obs::train::{MetricsServer, TrainObs};

fn cfg_for(out: &Path, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = "tiny_gla".into();
    cfg.recipe = "chon".into();
    cfg.steps = steps;
    cfg.seed = 9;
    cfg.diag_every = 4;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.out_dir = out.to_path_buf();
    cfg
}

/// The pinned acceptance property: attaching the full telemetry stack
/// (gauges, live scrape listener, trace, incremental CSV) must not
/// perturb training — the loss trajectory is compared bit for bit.
#[test]
fn telemetry_does_not_change_the_bits() {
    let root = std::env::temp_dir().join("chon_tt_bits");
    let _ = std::fs::remove_dir_all(&root);

    let mut plain = Trainer::new(cfg_for(&root.join("plain"), 10)).unwrap();
    plain.train(10).unwrap();

    let mut full = Trainer::new(cfg_for(&root.join("full"), 10)).unwrap();
    let obs = TrainObs::new(full.spans.clone());
    obs.set_build_info("native", "chon");
    full.set_obs(obs.clone());
    full.enable_run_outputs().unwrap();
    let mut srv = MetricsServer::serve("127.0.0.1", 0, obs).unwrap();
    full.train(10).unwrap();
    srv.stop();
    let dir = full.write_outputs().unwrap();

    let bits = |t: &Trainer| -> Vec<u32> {
        t.log.records.iter().map(|m| m.loss.to_bits()).collect()
    };
    assert_eq!(bits(&plain), bits(&full));

    // and the trace's loss series equals the in-memory log's
    let ev = trace::read_events(&dir.join(trace::TRACE_FILE)).unwrap();
    let series = trace::loss_series(&trace::logical_view(&ev));
    assert_eq!(series.len(), 10);
    for (m, &(step, loss)) in full.log.records.iter().zip(&series) {
        assert_eq!(m.step as u64, step);
        assert_eq!(m.loss as f64, loss);
    }
}

/// Crash + resume: train 6 steps, checkpoint, 2 more steps, "crash"
/// (no run_end), then resume from the checkpoint into the same run dir.
/// The appended trace's *logical* step series must equal an
/// uninterrupted run's exactly — resumed training is bit-identical, and
/// `logical_view` collapses the pre-crash steps the resume replays.
#[test]
fn resume_appended_trace_matches_uninterrupted_run() {
    let root = std::env::temp_dir().join("chon_tt_resume");
    let _ = std::fs::remove_dir_all(&root);

    let mut a = Trainer::new(cfg_for(&root.join("a"), 12)).unwrap();
    a.enable_run_outputs().unwrap();
    a.train(12).unwrap();
    let dir_a = a.write_outputs().unwrap();
    let ev_a = trace::read_events(&dir_a.join(trace::TRACE_FILE)).unwrap();
    let series_a = trace::loss_series(&trace::logical_view(&ev_a));
    assert_eq!(series_a.len(), 12);

    let ckpt = root.join("ckpt");
    let mut b = Trainer::new(cfg_for(&root.join("b"), 12)).unwrap();
    b.enable_run_outputs().unwrap();
    b.train(6).unwrap();
    b.save_checkpoint_to(&ckpt).unwrap();
    b.train(2).unwrap();
    drop(b); // simulated crash: no write_outputs, no run_end

    let mut cfg = cfg_for(&root.join("b"), 12);
    cfg.resume = Some(ckpt.clone());
    let mut b2 = Trainer::new(cfg).unwrap();
    b2.restore(&ckpt).unwrap();
    assert_eq!(b2.state.step, 6);
    b2.enable_run_outputs().unwrap();
    b2.train(6).unwrap();
    let dir_b = b2.write_outputs().unwrap();

    let ev_b = trace::read_events(&dir_b.join(trace::TRACE_FILE)).unwrap();
    // the raw trace carries the overlap (steps 7-8 appear twice) plus
    // the resume marker; the logical view deduplicates to A's series
    let view = trace::logical_view(&ev_b);
    let series_b = trace::loss_series(&view);
    assert_eq!(series_a, series_b, "resume must replay A's exact losses");
    let steps: Vec<u64> = series_b.iter().map(|&(s, _)| s).collect();
    assert_eq!(steps, (1..=12).collect::<Vec<u64>>(), "each step once");
    let count = |k: &str| view.iter().filter(|e| trace::kind(e) == Some(k)).count();
    assert_eq!(count("resume"), 1);
    assert_eq!(count("run_end"), 1);
}

/// Resuming at a step the trace never reached must be refused — the
/// gap would be indistinguishable from lost data.
#[test]
fn resume_past_end_of_trace_is_refused() {
    let root = std::env::temp_dir().join("chon_tt_gap");
    let _ = std::fs::remove_dir_all(&root);

    // checkpoint from a run that traced nothing after step 8
    let ckpt = root.join("ckpt");
    let mut a = Trainer::new(cfg_for(&root.join("run"), 12)).unwrap();
    a.enable_run_outputs().unwrap();
    a.train(4).unwrap();
    drop(a);
    let mut b = Trainer::new(cfg_for(&root.join("other"), 12)).unwrap();
    b.train(8).unwrap();
    b.save_checkpoint_to(&ckpt).unwrap();

    let mut cfg = cfg_for(&root.join("run"), 12);
    cfg.resume = Some(ckpt.clone());
    let mut c = Trainer::new(cfg).unwrap();
    c.restore(&ckpt).unwrap();
    let err = c.enable_run_outputs().unwrap_err().to_string();
    assert!(err.contains("refusing to append across the gap"), "{err}");
}

fn bin() -> &'static str {
    env!("CARGO_BIN_EXE_chon")
}

fn http_get(port: u16, path: &str) -> String {
    let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: t\r\nConnection: close\r\n\r\n").unwrap();
    let mut buf = String::new();
    let _ = s.read_to_string(&mut buf);
    buf
}

fn metric_value(body: &str, series: &str) -> Option<f64> {
    body.lines()
        .find(|l| l.starts_with(series) && l[series.len()..].starts_with(' '))
        .and_then(|l| l.rsplit(' ').next())
        .and_then(|v| v.parse().ok())
}

/// End to end against the real binary: live /metrics and /progress off
/// a running `chon train`, monotone step gauge across scrapes, then
/// SIGKILL mid-run — the trace must parse (≤1 torn line), reproduce the
/// loss series up to the last completed step, and `chon tail` must
/// summarize it and export a Chrome trace.
#[test]
fn sigkilled_train_leaves_scrapeable_trace_for_tail() {
    let out = std::env::temp_dir().join("chon_tt_kill");
    let _ = std::fs::remove_dir_all(&out);
    // grab a free port for the trainer's metrics listener
    let port = {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap().port()
    };
    let mut child = Command::new(bin())
        .args([
            "train",
            "--steps",
            "5000",
            "--diag-every",
            "5",
            "--log-every",
            "0",
            "--seed",
            "11",
            "--out-dir",
            out.to_str().unwrap(),
            "--metrics-port",
            &port.to_string(),
        ])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .unwrap();

    // poll /metrics until the step gauge moves past 5 (listener is up
    // before training starts; connection refusals just mean "not yet")
    let deadline = Instant::now() + Duration::from_secs(120);
    let mut first = 0.0f64;
    loop {
        assert!(Instant::now() < deadline, "trainer never reached step 5");
        if TcpStream::connect(("127.0.0.1", port)).is_ok() {
            let body = http_get(port, "/metrics");
            if let Some(v) = metric_value(&body, "chon_train_step") {
                if v >= 5.0 {
                    first = v;
                    assert!(
                        body.contains("chon_build_info{"),
                        "build info gauge missing"
                    );
                    assert!(
                        body.contains("chon_train_phase_us_bucket{"),
                        "phase histograms missing"
                    );
                    break;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    // a later scrape sees a step at least as large (monotone progress)
    std::thread::sleep(Duration::from_millis(200));
    let body = http_get(port, "/metrics");
    let second = metric_value(&body, "chon_train_step").unwrap();
    assert!(second >= first, "step went backwards: {first} -> {second}");
    let progress = http_get(port, "/progress");
    assert!(progress.contains("\"step\":"), "no /progress JSON: {progress}");

    // SIGKILL mid-run: no flush, no run_end, at most one torn line
    child.kill().unwrap();
    child.wait().unwrap();

    let run_dir = out.join("tiny_gla_chon");
    let ev = trace::read_events(&run_dir.join(trace::TRACE_FILE)).unwrap();
    let series = trace::loss_series(&trace::logical_view(&ev));
    assert!(
        series.len() as f64 >= first,
        "trace has {} steps, scrape saw {first}",
        series.len()
    );
    assert!(trace::last_step(&ev).unwrap() >= 5);
    assert_eq!(
        ev.iter().filter(|e| trace::kind(e) == Some("run_end")).count(),
        0,
        "a SIGKILLed run must not have a run_end"
    );

    // `chon tail` summarizes the torn trace and exports a Chrome trace
    let chrome = out.join("phases.json");
    let tail = Command::new(bin())
        .args([
            "tail",
            run_dir.to_str().unwrap(),
            "--chrome-trace",
            chrome.to_str().unwrap(),
        ])
        .output()
        .unwrap();
    let stdout = String::from_utf8_lossy(&tail.stdout);
    assert!(tail.status.success(), "tail failed: {stdout}");
    assert!(stdout.contains("steps:"), "no summary line: {stdout}");
    assert!(stdout.contains("interrupted"), "missing interrupted marker: {stdout}");
    let doc = std::fs::read_to_string(&chrome).unwrap();
    assert!(doc.contains("traceEvents"), "not a Chrome trace: {doc}");
}
