//! Edge-case unit tests for the quant substrate: exhaustive E4M3 codec
//! coverage, ragged 2D weight scaling, and top-k tie determinism.

use chon::hcp;
use chon::quant::{e2m1, e4m3, nvfp4};
use chon::util::ndarray::Mat;
use chon::util::prng::Rng;

/// Exhaustive roundtrip over all 256 E4M3 codes. The two saturating codes
/// (|value| = 480 in the plain-E4M3 reading; NaN in the fn variant) must
/// clamp to ±448; -0 normalizes to +0; every other code is a fixed point
/// of encode∘decode at the value level.
#[test]
fn e4m3_all_256_codes_roundtrip() {
    let mut exact = 0;
    for code in 0u8..=255 {
        let v = e4m3::decode(code);
        assert!(v.is_finite(), "code {code:#x} decoded to {v}");
        let back = e4m3::decode(e4m3::encode(v));
        if v.abs() > e4m3::E4M3_MAX {
            // 0x7f / 0xff: the fn-variant NaN slot, saturates on re-encode
            assert_eq!(back.abs(), e4m3::E4M3_MAX, "code {code:#x}");
            assert_eq!(back.signum(), v.signum(), "code {code:#x}");
        } else if v == 0.0 {
            // +0 and -0 both normalize to the +0 code
            assert_eq!(back, 0.0, "code {code:#x}");
        } else {
            assert_eq!(back, v, "code {code:#x}: {v} -> {back}");
            // value-level fixed point: rtn must not move a lattice point
            assert_eq!(e4m3::rtn(v), v, "code {code:#x} not an rtn fixed point");
            exact += 1;
        }
    }
    // 256 codes minus {+0, -0, +480, -480}
    assert_eq!(exact, 252, "unexpected number of exact roundtrips");
}

/// Every encode output must be one of the 256 codes that decodes back to
/// the rtn of the input (encode is total over finite f32).
#[test]
fn e4m3_encode_matches_rtn_on_random_inputs() {
    let mut rng = Rng::new(11);
    for _ in 0..5000 {
        let v = (rng.uniform() - 0.5) * 1200.0;
        let q = e4m3::rtn(v);
        assert_eq!(e4m3::decode(e4m3::encode(v)), q, "v={v}");
    }
}

/// 2D weight scaling with a ragged last band (rows % tile != 0): the last
/// band shares scales across fewer rows but must still be exact w.r.t. a
/// direct per-brick reference computation.
#[test]
fn fake_quant_2d_handles_ragged_last_band() {
    let (rows, cols, tile) = (37usize, 48usize, 16usize); // 37 = 2*16 + 5
    let mut rng = Rng::new(7);
    let w = Mat::from_fn(rows, cols, |_, _| rng.normal() * 2.0);
    let got = nvfp4::fake_quant_mat_2d(&w, tile);
    assert_eq!((got.rows, got.cols), (rows, cols));

    // reference: quantize each (band x 16) brick independently with the
    // same global scale
    let amax = w.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
    let s_enc = nvfp4::global_enc_scale(amax);
    let s_dec = 1.0 / s_enc;
    for band0 in (0..rows).step_by(tile) {
        let band_end = (band0 + tile).min(rows);
        for b in 0..cols / nvfp4::BLOCK {
            let mut amax_b = 0.0f32;
            for r in band0..band_end {
                for c in b * nvfp4::BLOCK..(b + 1) * nvfp4::BLOCK {
                    amax_b = amax_b.max(w.at(r, c).abs());
                }
            }
            let s_e4m3 = e4m3::rtn(amax_b / e2m1::E2M1_MAX * s_enc);
            let denom = s_e4m3 * s_dec;
            let s_enc_b = if denom > 0.0 { 1.0 / denom } else { 0.0 };
            for r in band0..band_end {
                for c in b * nvfp4::BLOCK..(b + 1) * nvfp4::BLOCK {
                    let want = e2m1::rtn(w.at(r, c) * s_enc_b) * s_e4m3 * s_dec;
                    assert_eq!(got.at(r, c), want, "({r},{c})");
                }
            }
        }
    }

    // the ragged band (rows 32..37) must NOT share scales with rows 16..32:
    // plant a spike in the ragged band and check containment
    let mut w2 = w.clone();
    *w2.at_mut(rows - 1, 0) = 1000.0;
    let q2 = nvfp4::fake_quant_mat_2d(&w2, tile);
    // a full-tile row far above is quantized identically in its brick
    // unless the global amax changed its scale — compare error magnitude
    let err_top: f32 = (0..tile)
        .map(|r| (q2.at(r, 0) - w2.at(r, 0)).abs())
        .fold(0.0, f32::max);
    assert!(
        err_top < 1000.0 / e2m1::E2M1_MAX,
        "spike in ragged band leaked a huge error into the first band"
    );
}

/// rows < tile: a single partial band must behave like tile = rows.
#[test]
fn fake_quant_2d_single_partial_band() {
    let mut rng = Rng::new(9);
    let w = Mat::from_fn(5, 32, |_, _| rng.normal());
    let a = nvfp4::fake_quant_mat_2d(&w, 16);
    let b = nvfp4::fake_quant_mat_2d(&w, 5);
    assert_eq!(a.data, b.data, "partial band != explicit tile");
}

/// top_k under tied scores: deterministic, lower index first, and stable
/// across repeated calls.
#[test]
fn top_k_deterministic_under_ties() {
    let scores = vec![2.0f64, 5.0, 5.0, 1.0, 5.0, 0.0, 2.0];
    let a = hcp::top_k(&scores, 4);
    assert_eq!(a, vec![1, 2, 4, 0], "ties must break toward lower index");
    for _ in 0..100 {
        assert_eq!(hcp::top_k(&scores, 4), a, "top_k not deterministic");
    }
    // all-equal scores: identity prefix
    let flat = vec![3.0f64; 8];
    assert_eq!(hcp::top_k(&flat, 3), vec![0, 1, 2]);
    // k > len truncates without panic
    assert_eq!(hcp::top_k(&flat, 99).len(), 8);
    // NaN-free scores with infinities still order
    let inf = vec![f64::INFINITY, 1.0, f64::INFINITY];
    assert_eq!(hcp::top_k(&inf, 2), vec![0, 2]);
}

/// e2m1 exhaustive: every 4-bit code decodes to a lattice fixed point and
/// pack/unpack is lossless at odd lengths.
#[test]
fn e2m1_codes_and_odd_packing() {
    for code in 0u8..16 {
        let v = e2m1::decode(code);
        assert_eq!(e2m1::rtn(v), v, "code {code} not a fixed point");
        assert!(v.abs() <= e2m1::E2M1_MAX);
    }
    for n in [1usize, 2, 15, 16, 17, 31] {
        let codes: Vec<u8> = (0..n).map(|i| (i % 16) as u8).collect();
        let packed = e2m1::pack(&codes);
        assert_eq!(packed.len(), n.div_ceil(2));
        assert_eq!(e2m1::unpack(&packed, n), codes, "n={n}");
    }
}
