//! Cross-language parity: the Rust quant substrate must match the Python
//! oracle (python/compile/kernels/ref.py) on golden fixtures.
//!
//! The fixture is CHECKED IN at tests/fixtures/golden_quant.txt (generated
//! once via `python -m compile.golden --out rust/tests/fixtures`), so this
//! test always runs — no artifacts build required. A freshly regenerated
//! artifacts/golden_quant.txt (from `make artifacts`) takes precedence as
//! an override, which keeps the fixture honest against oracle drift.

use std::path::{Path, PathBuf};

use chon::diagnostics;
use chon::quant::{e2m1, e4m3, mxfp4, nvfp4, rht};
use chon::util::ndarray::Mat;

/// Fixture resolution: artifacts override first, then the checked-in copy.
fn fixture_path() -> PathBuf {
    for base in ["artifacts", "../artifacts"] {
        let p = Path::new(base).join("golden_quant.txt");
        if p.exists() {
            return p;
        }
    }
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests/fixtures/golden_quant.txt")
}

struct Case {
    name: String,
    input: Vec<f32>,
    output: Vec<f32>,
}

fn parse_cases(text: &str) -> Vec<Case> {
    let mut cases = Vec::new();
    let mut name = String::new();
    let mut input = Vec::new();
    for line in text.lines() {
        if let Some(n) = line.strip_prefix("case ") {
            name = n.to_string();
        } else if let Some(v) = line.strip_prefix("in ") {
            input = v.split(' ').map(|s| s.parse().unwrap()).collect();
        } else if let Some(v) = line.strip_prefix("out ") {
            cases.push(Case {
                name: name.clone(),
                input: input.clone(),
                output: v.split(' ').map(|s| s.parse().unwrap()).collect(),
            });
        }
    }
    cases
}

fn assert_close(name: &str, got: &[f32], want: &[f32], atol: f32, rtol: f32) {
    assert_eq!(got.len(), want.len(), "{name}: length");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "{name}[{i}]: got {g}, want {w} (in={})",
            got.len()
        );
    }
}

#[test]
fn golden_parity_with_python_oracle() {
    let path = fixture_path();
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("missing golden fixture {}: {e}", path.display()));
    let cases = parse_cases(&text);
    assert!(cases.len() >= 8, "expected >= 8 golden cases");
    for c in &cases {
        match c.name.as_str() {
            "e2m1_rtn" => {
                let got: Vec<f32> = c.input.iter().map(|&v| e2m1::rtn(v)).collect();
                assert_close(&c.name, &got, &c.output, 0.0, 0.0);
            }
            "e4m3_rtn" => {
                let got: Vec<f32> = c.input.iter().map(|&v| e4m3::rtn(v)).collect();
                assert_close(&c.name, &got, &c.output, 0.0, 1e-6);
            }
            n if n.starts_with("nvfp4_2d") => {
                let w = Mat::from_vec(32, 64, c.input.clone());
                let got = nvfp4::fake_quant_mat_2d(&w, 16);
                assert_close(&c.name, &got.data, &c.output, 1e-7, 1e-5);
            }
            n if n.starts_with("nvfp4") => {
                let got = nvfp4::fake_quant(&c.input, nvfp4::Rounding::Rtn, None);
                assert_close(&c.name, &got, &c.output, 1e-7, 1e-5);
            }
            "mxfp4" => {
                let got = mxfp4::fake_quant(&c.input);
                assert_close(&c.name, &got, &c.output, 1e-7, 1e-5);
            }
            "fwht" => {
                let mut got = c.input.clone();
                rht::fwht_inplace(&mut got);
                assert_close(&c.name, &got, &c.output, 1e-4, 1e-5);
            }
            "kurtosis" => {
                let got = diagnostics::kurtosis(&c.input) as f32;
                assert!(
                    (got - c.output[0]).abs() <= 1e-3 * c.output[0].abs().max(1.0),
                    "kurtosis: {got} vs {}",
                    c.output[0]
                );
            }
            other => panic!("unknown golden case {other}"),
        }
    }
    println!("golden parity: {} cases OK", cases.len());
}

#[test]
fn checked_in_fixture_is_present_and_complete() {
    // The committed fixture itself (not an artifacts override) must parse
    // and cover every case family — a green run can't mask zero coverage.
    let p = Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/golden_quant.txt");
    let text = std::fs::read_to_string(&p)
        .unwrap_or_else(|e| panic!("checked-in fixture missing at {}: {e}", p.display()));
    let cases = parse_cases(&text);
    assert!(cases.len() >= 8, "fixture has only {} cases", cases.len());
    for family in ["e2m1_rtn", "e4m3_rtn", "nvfp4", "nvfp4_2d", "mxfp4", "fwht", "kurtosis"] {
        assert!(
            cases.iter().any(|c| c.name.starts_with(family)),
            "fixture missing case family {family}"
        );
    }
}
