//! `cargo bench` — regenerates every table and figure of the paper's
//! evaluation on this testbed (custom harness; criterion is not in the
//! offline vendor set).
//!
//! Usage:
//!   cargo bench                 # run everything
//!   cargo bench -- tab5 fig11   # run selected benches
//!   CHON_BENCH_STEPS=300 cargo bench -- tab2
//!
//! Benches that need trained models train the tiny configs in-process
//! (a few seconds each at the default 120 steps); results are written to
//! runs/bench/*.csv and printed in the paper's table/figure layout.
//!
//! Backend: the benches run on whatever `CHON_BENCH_BACKEND` selects
//! (default native — fully offline). With `--features pjrt` and a built
//! artifacts/ directory, set CHON_BENCH_BACKEND=pjrt to bench the XLA
//! path instead.

#![allow(
    clippy::needless_range_loop,
    clippy::field_reassign_with_default,
    clippy::uninlined_format_args
)]

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use chon::bench::{time_auto, time_fn, BenchEntry, Table};
use chon::config::RunConfig;
use chon::coordinator::{ablation, evalsuite, Monitor, Trainer};
use chon::diagnostics;
use chon::hcp;
use chon::hcp::modes::{apply, baseline, HcpConfig, QuantizedPair};
use chon::hcp::pipeline;
use chon::quant::{fp8_fake_quant, mxfp4, nvfp4, rht};
use chon::runtime::native;
use chon::util::ndarray::{matmul, matmul_par, matmul_quant_packed_with, Mat, SimdLevel};
use chon::util::prng::Rng;

/// On a single-core CPU testbed, XLA's LLVM passes dominate (minutes per
/// nvfp4-family artifact). Benches trade step time for compile time by
/// defaulting to backend optimization level 0 — set XLA_FLAGS yourself to
/// override (perf step-time numbers in EXPERIMENTS.md §Perf were measured
/// separately at full optimization).
fn fast_compile_flags() {
    if std::env::var_os("XLA_FLAGS").is_none() {
        std::env::set_var("XLA_FLAGS", "--xla_backend_optimization_level=0");
    }
}

fn bench_backend() -> String {
    std::env::var("CHON_BENCH_BACKEND").unwrap_or_else(|_| "native".into())
}

fn steps_budget() -> usize {
    std::env::var("CHON_BENCH_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(60)
}

fn out_dir() -> PathBuf {
    let p = PathBuf::from("runs/bench");
    std::fs::create_dir_all(&p).ok();
    p
}

/// Whether a (model, recipe) can run on the selected backend.
fn model_available(model: &str) -> bool {
    if bench_backend() == "native" {
        return native::model_cfg(model).is_ok();
    }
    Path::new("artifacts")
        .join(format!("train_{model}_bf16.manifest.txt"))
        .exists()
}

fn run_cfg(model: &str, recipe: &str) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.backend = bench_backend();
    cfg.model = model.into();
    cfg.recipe = recipe.into();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.out_dir = out_dir();
    cfg
}

/// Train with periodic diagnostics; returns the trainer (monitor filled).
fn diag_run(model: &str, recipe: &str, steps: usize, probes: usize) -> Result<Trainer> {
    let mut cfg = run_cfg(model, recipe);
    cfg.diag_every = (steps / probes).max(1);
    let mut tr = Trainer::new(cfg)?;
    tr.diagnose()?; // step-0 probe
    tr.train(steps)?;
    Ok(tr)
}

fn series_str(s: &[(usize, f32)]) -> String {
    s.iter()
        .map(|(_, v)| format!("{v:>8.3}"))
        .collect::<Vec<_>>()
        .join(" ")
}

// ------------------------------------------------------------------
// Tables
// ------------------------------------------------------------------

/// Tab. 2: recipe ablation grid (final loss + gap vs BF16).
fn tab2() -> Result<()> {
    let steps = steps_budget();
    let mut recipes = if bench_backend() == "native" {
        native::available_recipes()
    } else {
        let mut found = Vec::new();
        let rd = std::fs::read_dir("artifacts")
            .context("artifacts missing (run `make artifacts`)")?;
        for e in rd {
            let name = e?.file_name().to_string_lossy().to_string();
            if let Some(r) = name
                .strip_prefix("train_tiny_gla_")
                .and_then(|r| r.strip_suffix(".manifest.txt"))
            {
                if !r.starts_with("only_") {
                    found.push(r.to_string());
                }
            }
        }
        found
    };
    recipes.sort_by_key(|r| (r != "bf16", r.clone()));
    let base = run_cfg("tiny_gla", "bf16");
    let rows = ablation::table2(&base, &recipes, steps, 10)?;
    ablation::print_table2(&rows);
    ablation::write_table2(&rows, &out_dir().join("table2.csv"))?;
    let entries: Vec<BenchEntry> = rows
        .iter()
        .map(|r| {
            BenchEntry::val(
                format!("tab2/{}/final_loss", r.recipe),
                r.final_loss as f64,
                "loss",
            )
        })
        .collect();
    chon::bench::write_report(&out_dir().join("table2.json"), "tab2", &entries)?;
    Ok(())
}

/// Tab. 3: operator sensitivity (both architectures).
fn tab3() -> Result<()> {
    let steps = steps_budget();
    for model in ["tiny_gla", "tiny_sa"] {
        let mut ops = if bench_backend() == "native" {
            native::sensitivity_ops_for(model)?
        } else {
            let mut found = Vec::new();
            let rd = std::fs::read_dir("artifacts")
                .context("artifacts missing (run `make artifacts`)")?;
            for e in rd {
                let name = e?.file_name().to_string_lossy().to_string();
                if let Some(rest) = name
                    .strip_prefix(&format!("train_{model}_only_"))
                    .and_then(|r| r.strip_suffix(".manifest.txt"))
                {
                    found.push(rest.replacen('_', ".", 1));
                }
            }
            found
        };
        if ops.is_empty() {
            println!("tab3: no sensitivity artifacts for {model} (need --set core/full)");
            continue;
        }
        ops.sort();
        println!("\n== Tab. 3 ({model}) ==");
        let base = run_cfg(model, "bf16");
        let rows = ablation::table3(&base, &ops, steps, 10)?;
        ablation::print_table3(&rows);
        ablation::write_table3(&rows, &out_dir().join(format!("table3_{model}.csv")))?;
        let entries: Vec<BenchEntry> = rows
            .iter()
            .map(|r| {
                BenchEntry::val(
                    format!("tab3/{model}/{}/delta_loss", r.op),
                    r.delta_loss,
                    "loss",
                )
            })
            .collect();
        chon::bench::write_report(
            &out_dir().join(format!("table3_{model}.json")),
            "tab3",
            &entries,
        )?;
    }
    Ok(())
}

/// Tab. 1/8 substitute: downstream eval across recipes.
fn tab1() -> Result<()> {
    let steps = steps_budget().max(100);
    let base = run_cfg("tiny_gla", "bf16");
    let recipes: Vec<String> = ["bf16", "fp8", "nvfp4", "chon"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    let rows = evalsuite::run_suite(&base, &recipes, steps)?;
    evalsuite::print_suite(&rows);
    let mut f = std::fs::File::create(out_dir().join("table1.csv"))?;
    writeln!(f, "recipe,cloze_acc,heldout_loss,heldout_acc")?;
    for r in &rows {
        writeln!(
            f,
            "{},{:.4},{:.4},{:.4}",
            r.recipe, r.cloze_acc, r.heldout_loss, r.heldout_acc
        )?;
    }
    let mut entries = Vec::new();
    for r in &rows {
        entries.push(BenchEntry::val(
            format!("tab1/{}/heldout_loss", r.recipe),
            r.heldout_loss as f64,
            "loss",
        ));
        // stored as error so every report value stays lower-is-better
        entries.push(BenchEntry::val(
            format!("tab1/{}/cloze_err", r.recipe),
            1.0 - r.cloze_acc,
            "err",
        ));
    }
    chon::bench::write_report(&out_dir().join("table1.json"), "tab1", &entries)?;
    Ok(())
}

/// Tab. 5: HCP kernel overhead — pre-fuse stage sum vs post-fuse kernel,
/// as a ratio of the step (Fprop+Dgrad+Wgrad GEMM) time.
fn tab5() -> Result<()> {
    let shapes = [(2048usize, 2048usize), (1024, 2048), (4096, 2048), (2048, 4096)];
    let m = 256; // token rows
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let mut table = Table::new(&[
        "Shape (WxX)", "Fprop ms", "Deq", "Gthr", "Resid", "Cat", "Sum", "Fused",
        "Pre-fuse %", "Post-fuse %",
    ]);
    let mut csv = std::fs::File::create(out_dir().join("table5.csv"))?;
    writeln!(
        csv,
        "k,n,fprop_ms,deq_ms,gthr_ms,resid_ms,cat_ms,sum_ms,fused_ms,prefuse_pct,postfuse_pct"
    )?;
    let mut entries = Vec::new();
    for (kdim, n) in shapes {
        let mut rng = Rng::new(kdim as u64 ^ n as u64);
        let x = Mat::from_fn(m, kdim, |_, _| rng.normal());
        let w = Mat::from_fn(kdim, n, |_, _| rng.normal() * 0.2);
        let hot = (kdim as f64 * 0.0909) as usize;
        let idx: Vec<usize> = (0..hot).map(|i| i * (kdim / hot)).collect();

        // GEMM step time (Fprop; Dgrad/Wgrad have the same flops here)
        let t_gemm = time_auto(300.0, || {
            std::hint::black_box(matmul_par(&x, &w, threads));
        });
        let step_ms = t_gemm.median_ms * 3.0; // Fprop + Dgrad + Wgrad

        // pre-fuse pipeline: measure each stage
        let mut st_acc = pipeline::StageTimes::default();
        let reps = 5;
        for _ in 0..reps {
            let (_, _, st) = pipeline::prefuse(&x, &w, &idx);
            st_acc.dequant_ms += st.dequant_ms;
            st_acc.gather_ms += st.gather_ms;
            st_acc.residual_ms += st.residual_ms;
            st_acc.concat_ms += st.concat_ms;
        }
        let d = reps as f64;
        let (deq, gth, res, cat) = (
            st_acc.dequant_ms / d,
            st_acc.gather_ms / d,
            st_acc.residual_ms / d,
            st_acc.concat_ms / d,
        );
        let sum = deq + gth + res + cat;

        // post-fuse single pass
        let t_fused = time_auto(200.0, || {
            std::hint::black_box(pipeline::postfuse(&x, &w, &idx));
        });
        let fused = t_fused.median_ms;

        let pre_pct = sum / (step_ms + sum) * 100.0;
        let post_pct = fused / (step_ms + fused) * 100.0;
        table.row(&[
            format!("{kdim}x{n}"),
            format!("{:.2}", t_gemm.median_ms),
            format!("{deq:.2}"),
            format!("{gth:.2}"),
            format!("{res:.2}"),
            format!("{cat:.2}"),
            format!("{sum:.2}"),
            format!("{fused:.2}"),
            format!("{pre_pct:.2}%"),
            format!("{post_pct:.2}%"),
        ]);
        writeln!(
            csv,
            "{kdim},{n},{:.3},{deq:.3},{gth:.3},{res:.3},{cat:.3},{sum:.3},{fused:.3},{pre_pct:.2},{post_pct:.2}",
            t_gemm.median_ms
        )?;
        entries.push(BenchEntry::ms(format!("tab5/{kdim}x{n}/fprop"), t_gemm.median_ms));
        entries.push(BenchEntry::ms(format!("tab5/{kdim}x{n}/prefuse"), sum));
        entries.push(BenchEntry::ms(format!("tab5/{kdim}x{n}/fused"), fused));
    }
    println!("\n== Tab. 5: HCP kernel overhead (pre-fuse vs post-fuse) ==");
    table.print();
    chon::bench::write_report(&out_dir().join("table5.json"), "tab5", &entries)?;
    Ok(())
}

// ------------------------------------------------------------------
// Figures
// ------------------------------------------------------------------

/// Fig. 1 + Fig. 17: per-component activation kurtosis, GLA vs SA.
fn fig1() -> Result<()> {
    let steps = steps_budget();
    println!("\n== Fig. 1: activation kurtosis GLA vs Qwen-style SA ==");
    let mut csv = std::fs::File::create(out_dir().join("fig1.csv"))?;
    writeln!(csv, "arch,component,act_kurtosis")?;
    for model in ["tiny_gla", "tiny_sa"] {
        if !model_available(model) {
            println!("  (skip {model}: not available on this backend)");
            continue;
        }
        let tr = diag_run(model, "bf16", steps, 2)?;
        let last = tr.monitor.records.last().unwrap();
        println!("[{model}]");
        let mut attn = Vec::new();
        let mut mlp = Vec::new();
        for (name, v) in tr.monitor.names.iter().zip(&last.values) {
            if name.ends_with(".act.kurt") {
                let comp = name.trim_end_matches(".act.kurt");
                println!("  {comp:<18} {v:>8.3}");
                writeln!(csv, "{model},{comp},{v}")?;
                if comp.contains("attn") {
                    attn.push(*v);
                } else {
                    mlp.push(*v);
                }
            }
        }
        let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
        println!("  mean attn {:.3} | mean mlp {:.3}", mean(&attn), mean(&mlp));
    }
    Ok(())
}

/// Fig. 3/19/22: hot-channel maps early vs late + persistence.
fn fig3() -> Result<()> {
    let steps = steps_budget();
    let tr = diag_run("tiny_gla", "chon", steps, 8)?;
    println!("\n== Fig. 3: drifting spikes -> persistent hot channels ==");
    let m = &tr.monitor;
    for (comp, series) in m.hot_channel_persistence(8) {
        println!(
            "{comp:<10} overlap(t, t-1): {}",
            series
                .iter()
                .map(|(s, j)| format!("{s}:{j:.2}"))
                .collect::<Vec<_>>()
                .join(" ")
        );
    }
    // top-5 channel ids at first vs last probe per component
    let first = m.records.first().unwrap();
    let last = m.records.last().unwrap();
    for mi in 0..first.channel_maps.len() {
        let name = &first.channel_maps[mi].0;
        let flat = |r: &chon::coordinator::DiagRecord| -> Vec<f32> {
            r.channel_maps[mi].1.iter().flatten().copied().collect()
        };
        let h0: Vec<usize> = diagnostics::hot_channels(&flat(first), 5)
            .iter()
            .map(|&(i, _)| i)
            .collect();
        let h1: Vec<usize> = diagnostics::hot_channels(&flat(last), 5)
            .iter()
            .map(|&(i, _)| i)
            .collect();
        println!(
            "{name:<10} top-5 @step{}: {h0:?}  @step{}: {h1:?}",
            first.step, last.step
        );
    }
    tr.monitor.write_channel_csvs(&out_dir(), "fig3")?;
    Ok(())
}

/// Fig. 4/18: block-level kurtosis min/avg/max, SA vs LA.
fn fig4() -> Result<()> {
    let steps = steps_budget();
    println!("\n== Fig. 4: 16x16 block kurtosis (min/avg/max) ==");
    let mut csv = std::fs::File::create(out_dir().join("fig4.csv"))?;
    writeln!(csv, "arch,component,bk_min,bk_avg,bk_max")?;
    for model in ["tiny_gla", "tiny_sa"] {
        if !model_available(model) {
            continue;
        }
        let tr = diag_run(model, "bf16", steps, 2)?;
        let last = tr.monitor.records.last().unwrap();
        println!(
            "[{model}]  {:<20} {:>8} {:>8} {:>8}",
            "component", "min", "avg", "max"
        );
        let names = &tr.monitor.names;
        for (i, name) in names.iter().enumerate() {
            if let Some(comp) = name.strip_suffix(".act.bkavg") {
                let minv = last.values[names
                    .iter()
                    .position(|n| n == &format!("{comp}.act.bkmin"))
                    .unwrap()];
                let maxv = last.values[names
                    .iter()
                    .position(|n| n == &format!("{comp}.act.bkmax"))
                    .unwrap()];
                let avg = last.values[i];
                println!("  {comp:<20} {minv:>8.2} {avg:>8.2} {maxv:>8.2}");
                writeln!(csv, "{model},{comp},{minv},{avg},{maxv}")?;
            }
        }
    }
    println!("(expected: LA avg lower than SA, but max spikes persist in both)");
    Ok(())
}

/// Fig. 5: per-tensor kurtosis evolution (weights vs activations).
fn fig5() -> Result<()> {
    let steps = steps_budget();
    println!("\n== Fig. 5: kurtosis evolution over training ==");
    for model in ["tiny_gla", "tiny_sa"] {
        if !model_available(model) {
            continue;
        }
        let tr = diag_run(model, "bf16", steps, 8)?;
        println!(
            "[{model}] act kurt: {}",
            series_str(&tr.monitor.series_mean_matching(".act.kurt"))
        );
        println!(
            "[{model}] wt  kurt: {}",
            series_str(&tr.monitor.series_mean_matching(".wt.kurt"))
        );
        write_series_csv(&tr.monitor, &out_dir().join(format!("fig5_{model}.csv")))?;
    }
    Ok(())
}

/// Fig. 6: top-k magnitude evolution; gk top-1 under BF16/NVFP4/CHON.
fn fig6() -> Result<()> {
    let steps = steps_budget();
    println!("\n== Fig. 6: top-k magnitude evolution ==");
    let mut csv = std::fs::File::create(out_dir().join("fig6.csv"))?;
    writeln!(csv, "recipe,step,gk_top1,o_top1,up_top1,mean_top1,mean_top3")?;
    for recipe in ["bf16", "nvfp4", "chon"] {
        let tr = diag_run("tiny_gla", recipe, steps, 8)?;
        let m = &tr.monitor;
        let gk = m.series("L0.attn.gk.act.top1").unwrap_or_default();
        let o = m.series("L0.attn.o.act.top1").unwrap_or_default();
        let up = m.series("L0.mlp.up.act.top1").unwrap_or_default();
        let t1 = m.series_mean_matching(".act.top1");
        let t3 = m.series_mean_matching(".act.top3");
        println!("[{recipe}] gk top1: {}", series_str(&gk));
        for i in 0..gk.len() {
            writeln!(
                csv,
                "{recipe},{},{},{},{},{},{}",
                gk[i].0, gk[i].1, o[i].1, up[i].1, t1[i].1, t3[i].1
            )?;
        }
    }
    println!("(gk magnitudes dominating o/up reproduces the Fig. 6b shape)");
    Ok(())
}

/// Fig. 7: softmax-induced instability (SA only).
fn fig7() -> Result<()> {
    let steps = steps_budget();
    if !model_available("tiny_sa") {
        println!("fig7: tiny_sa not available on this backend");
        return Ok(());
    }
    let tr = diag_run("tiny_sa", "bf16", steps, 8)?;
    let m = &tr.monitor;
    println!("\n== Fig. 7: softmax instability (tiny_sa) ==");
    println!(
        "pre-softmax kurt: {}",
        series_str(&m.series_mean_matching("presoftmax.kurt"))
    );
    println!(
        "pre-softmax max : {}",
        series_str(&m.series_mean_matching("presoftmax.max"))
    );
    println!(
        "post-softmax H  : {}",
        series_str(&m.series_mean_matching("postsoftmax.entropy"))
    );
    write_series_csv(m, &out_dir().join("fig7.csv"))?;
    Ok(())
}

/// Fig. 8: SwiGLU weight alignment dynamics, GLA vs SA.
fn fig8() -> Result<()> {
    let steps = steps_budget();
    println!("\n== Fig. 8: SwiGLU W_up/W_gate cosine alignment ==");
    for model in ["tiny_gla", "tiny_sa"] {
        if !model_available(model) {
            continue;
        }
        let tr = diag_run(model, "bf16", steps, 8)?;
        println!(
            "[{model}] alignment: {}",
            series_str(&tr.monitor.series_mean_matching("mlp.alignment"))
        );
    }
    Ok(())
}

/// Fig. 11/13: HCP config MSE sweep.
fn fig11() -> Result<()> {
    println!("\n== Fig. 11: HCP config MSE vs patched columns ==");
    let mut csv = std::fs::File::create(out_dir().join("fig11.csv"))?;
    writeln!(csv, "prior,hidden,config,k,mse,base_mse")?;
    let mut entries = Vec::new();
    for prior in ["gaussian", "laplace"] {
        for hidden in [512usize, 1024] {
            let m = 64;
            let n = 64;
            let mut rng = Rng::new(hidden as u64);
            let x = Mat::from_fn(m, hidden, |_, _| match prior {
                "gaussian" => rng.normal() * 2.0,
                _ => rng.laplace(2.0),
            });
            let w = Mat::from_fn(hidden, n, |_, _| rng.normal() * 0.5);
            let truth = matmul(&x, &w);
            let q = QuantizedPair::new(&x, &w);
            let order = hcp::top_k(&hcp::scores(&q.dx, &q.dw), hidden);
            let base = baseline(&q).mse(&truth);
            print!("[{prior} {hidden}] base {base:.2e} |");
            for (name, cfg) in HcpConfig::taxonomy() {
                let k = (hidden as f64 * 0.0909) as usize;
                let mse = apply(cfg, &q, &order[..k]).mse(&truth);
                print!(" {name} {:.1}%", (mse / base - 1.0) * 100.0);
                writeln!(csv, "{prior},{hidden},{name},{k},{mse:.6e},{base:.6e}")?;
                entries.push(BenchEntry::val(
                    format!("fig11/{prior}_{hidden}/{name}"),
                    mse,
                    "mse",
                ));
            }
            println!();
        }
    }
    println!("(expected shape: O2-B lowest, W/A single-sided in between, all < baseline)");
    chon::bench::write_report(&out_dir().join("fig11.json"), "fig11", &entries)?;
    Ok(())
}

/// Fig. 26/27: FTZ dynamics, activations vs weights, across recipes.
fn fig26() -> Result<()> {
    let steps = steps_budget();
    println!("\n== Fig. 26/27: flush-to-zero dynamics ==");
    let mut csv = std::fs::File::create(out_dir().join("fig26.csv"))?;
    writeln!(csv, "recipe,step,act_ftz,wt_ftz,gate_ftz")?;
    for recipe in ["bf16", "nvfp4", "chon"] {
        let tr = diag_run("tiny_gla", recipe, steps, 8)?;
        let m = &tr.monitor;
        let act = m.series_mean_matching(".act.ftz");
        let wt = m.series_mean_matching(".wt.ftz");
        let gate = m.series_mean_matching("attn.g.act.ftz");
        println!("[{recipe}] act FTZ: {}", series_str(&act));
        println!("[{recipe}] wt  FTZ: {}", series_str(&wt));
        for i in 0..act.len() {
            writeln!(csv, "{recipe},{},{},{},{}", act[i].0, act[i].1, wt[i].1, gate[i].1)?;
        }
    }
    println!("(expected: act FTZ >> wt FTZ; CHON pulls act FTZ toward BF16)");
    Ok(())
}

/// Fig. 32: quantization-error MSE dynamics, act vs weight.
fn fig32() -> Result<()> {
    let steps = steps_budget();
    println!("\n== Fig. 32: quantization error dynamics ==");
    let mut csv = std::fs::File::create(out_dir().join("fig32.csv"))?;
    writeln!(csv, "model,step,act_qmse,wt_qmse,ratio")?;
    for model in ["tiny_gla", "tiny_sa"] {
        if !model_available(model) {
            continue;
        }
        let tr = diag_run(model, "bf16", steps, 8)?;
        let m = &tr.monitor;
        let act = m.series_mean_matching(".act.qmse");
        let wt = m.series_mean_matching(".wt.qmse");
        println!("[{model}] act qMSE: {}", series_str(&act));
        println!("[{model}] wt  qMSE: {}", series_str(&wt));
        for i in 0..act.len() {
            let ratio = act[i].1 / wt[i].1.max(1e-12);
            writeln!(csv, "{model},{},{},{},{ratio}", act[i].0, act[i].1, wt[i].1)?;
        }
        if let (Some(a), Some(w)) = (act.last(), wt.last()) {
            println!(
                "[{model}] final act/wt error ratio: {:.1}x (paper: 1-2 orders)",
                a.1 / w.1.max(1e-12)
            );
        }
    }
    Ok(())
}

/// Fig. 29/30/31: RMSNorm gamma distributions + lm_head superposition.
fn fig29() -> Result<()> {
    use chon::diagnostics::gamma::{gamma_depth_slope, gamma_stats, weight_overlap};
    let steps = steps_budget();
    println!("\n== Fig. 29/30: RMSNorm gamma | Fig. 31: weight overlap ==");
    for model in ["tiny_gla", "tiny_sa"] {
        if !model_available(model) {
            continue;
        }
        for recipe in ["bf16", "nvfp4"] {
            let mut tr = Trainer::new(run_cfg(model, recipe))?;
            tr.train(steps)?;
            let mut layer_means = Vec::new();
            let mut frac_above = Vec::new();
            let mut lm_head: Option<Mat> = None;
            for (name, t) in tr.state.names.iter().zip(&tr.state.params) {
                if name.contains("_norm'") || name.ends_with("norm']") {
                    let s = gamma_stats(&t.f32_data);
                    // per-layer norms: "params['layers'][i]" (pjrt) or
                    // "params['L<i>']" (native)
                    if name.contains("layers") || name.contains("['L") {
                        layer_means.push(s.mean);
                        frac_above.push(s.frac_above_one);
                    }
                }
                if name.contains("lm_head") {
                    lm_head = Some(Mat::from_vec(t.shape[0], t.shape[1], t.f32_data.clone()));
                }
            }
            let mean_frac =
                frac_above.iter().sum::<f64>() / frac_above.len().max(1) as f64;
            let overlap = lm_head
                .as_ref()
                .map(|w| weight_overlap(&w.transpose())) // vocab rows
                .unwrap_or(0.0);
            println!(
                "[{model}/{recipe}] gamma>1 frac {mean_frac:.3}; depth slope {:+.4}; lm_head overlap {overlap:.5}",
                gamma_depth_slope(&layer_means)
            );
        }
    }
    println!("(expected: SA gamma > LA gamma; NVFP4 overlap <= BF16 overlap)");
    Ok(())
}

/// Fig. 15c substitute: fine-tuning loss-gap trajectory.
fn fig15() -> Result<()> {
    use chon::coordinator::finetune;
    let steps = steps_budget();
    let base = run_cfg("tiny_gla", "bf16");
    let pts = finetune::finetune_gap_study(&base, "nvfp4", steps, steps, (steps / 5).max(1))?;
    finetune::print_gap_trajectory("nvfp4", &pts);
    Ok(())
}

/// Format comparison: NVFP4 vs MXFP4 vs FP8 MSE across distributions
/// (supports the §C.4 microscaling discussion).
fn formats() -> Result<()> {
    println!("\n== Format MSE comparison (NVFP4 / MXFP4 / FP8) ==");
    let mut table = Table::new(&["distribution", "NVFP4", "MXFP4", "FP8"]);
    let mut rng = Rng::new(0xF0);
    let n = 65536;
    let dists: Vec<(&str, Vec<f32>)> = vec![
        ("gaussian", (0..n).map(|_| rng.normal()).collect()),
        ("laplace", (0..n).map(|_| rng.laplace(1.0)).collect()),
        ("student-t(3)", (0..n).map(|_| rng.student_t(3)).collect()),
        ("spiky(1:300x)", {
            let mut v: Vec<f32> = (0..n).map(|_| rng.normal()).collect();
            for i in (0..n).step_by(512) {
                v[i] *= 300.0;
            }
            v
        }),
    ];
    for (name, x) in &dists {
        let mse_nv = nvfp4::quant_mse(x);
        let mse_mx = mxfp4::quant_mse(x);
        let d8 = fp8_fake_quant(x);
        let mse_8: f64 = x
            .iter()
            .zip(&d8)
            .map(|(&a, &b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / x.len() as f64;
        table.row(&[
            name.to_string(),
            format!("{mse_nv:.3e}"),
            format!("{mse_mx:.3e}"),
            format!("{mse_8:.3e}"),
        ]);
    }
    table.print();
    Ok(())
}

/// Perf microbenches for EXPERIMENTS.md §Perf (L3 substrate hot paths).
/// Also persists the medians as a versioned JSON report
/// (runs/bench/perf.json) — CI diffs it against the checked-in baseline
/// via `chon bench-diff` and fails on >25% regressions.
fn perf() -> Result<()> {
    println!("\n== L3 perf microbenches ==");
    let mut table = Table::new(&["kernel", "size", "median ms", "throughput"]);
    let mut entries: Vec<BenchEntry> = Vec::new();
    let mut record = |name: &str, median_ms: f64| {
        entries.push(BenchEntry::ms(name, median_ms));
    };
    let mut rng = Rng::new(1);
    let x: Vec<f32> = (0..1 << 20).map(|_| rng.normal()).collect();

    let t = time_auto(400.0, || {
        std::hint::black_box(nvfp4::fake_quant(&x, nvfp4::Rounding::Rtn, None));
    });
    record("nvfp4_fake_quant_1m", t.median_ms);
    table.row(&[
        "nvfp4 fake_quant".into(),
        "1M f32".into(),
        format!("{:.2}", t.median_ms),
        format!("{:.0} MB/s", 4.0 * x.len() as f64 / t.median_ms / 1e3),
    ]);

    let t = time_auto(400.0, || {
        std::hint::black_box(nvfp4::quantize(&x, nvfp4::Rounding::Rtn, None));
    });
    record("nvfp4_quantize_pack_1m", t.median_ms);
    table.row(&[
        "nvfp4 quantize(pack)".into(),
        "1M f32".into(),
        format!("{:.2}", t.median_ms),
        format!("{:.0} MB/s", 4.0 * x.len() as f64 / t.median_ms / 1e3),
    ]);

    let t = time_auto(400.0, || {
        std::hint::black_box(diagnostics::kurtosis(&x));
    });
    record("kurtosis_1m", t.median_ms);
    table.row(&[
        "kurtosis".into(),
        "1M f32".into(),
        format!("{:.2}", t.median_ms),
        format!("{:.2} GB/s", 4.0 * x.len() as f64 / t.median_ms / 1e6),
    ]);

    let mat = Mat::from_vec(1024, 1024, x[..1 << 20].to_vec());
    let signs = rht::random_signs(1024, &mut rng);
    let t = time_auto(400.0, || {
        std::hint::black_box(rht::rht(&mat, &signs));
    });
    record("rht_1024", t.median_ms);
    table.row(&[
        "rht 1024".into(),
        "1024x1024".into(),
        format!("{:.2}", t.median_ms),
        format!("{:.2} GB/s", 4.0 * mat.data.len() as f64 / t.median_ms / 1e6),
    ]);

    let a = Mat::from_fn(512, 512, |_, _| rng.normal());
    let b = Mat::from_fn(512, 512, |_, _| rng.normal());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let flops = 2.0 * 512f64.powi(3);

    // packed microkernel, single lane
    let t = time_auto(400.0, || {
        std::hint::black_box(matmul(&a, &b));
    });
    record("matmul_512", t.median_ms);
    table.row(&[
        "matmul (packed)".into(),
        "512^3".into(),
        format!("{:.2}", t.median_ms),
        format!("{:.1} GFLOP/s", flops / t.median_ms / 1e6),
    ]);

    // same kernel over row bands on the persistent pool
    let t = time_auto(400.0, || {
        std::hint::black_box(matmul_par(&a, &b, threads));
    });
    record("matmul_par_512", t.median_ms);
    table.row(&[
        format!("matmul_par x{threads}"),
        "512^3".into(),
        format!("{:.2}", t.median_ms),
        format!("{:.1} GFLOP/s", flops / t.median_ms / 1e6),
    ]);

    // in-register NVFP4 dequant GEMM: weights stay packed (4-bit codes +
    // e4m3 scales) and decode inside the microkernel. Both SIMD levels
    // are timed for the log; the recorded entry is the level runtime
    // dispatch picks on this host, i.e. what `--packed-compute` serves.
    {
        let q = nvfp4::PackedQuantMat::pack(&b);
        let mut timed = [0.0f64; 2];
        for (i, lvl) in [SimdLevel::Scalar, SimdLevel::Avx2].iter().enumerate() {
            let t = time_auto(400.0, || {
                std::hint::black_box(matmul_quant_packed_with(&a, &q, 1, *lvl));
            });
            timed[i] = t.median_ms;
            table.row(&[
                format!("matmul nvfp4 ({lvl:?})"),
                "512^3".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.1} GFLOP/s", flops / t.median_ms / 1e6),
            ]);
        }
        let detected = chon::util::ndarray::simd_level_name();
        let med = if detected == "avx2" { timed[1] } else { timed[0] };
        record("matmul_nvfp4_512", med);
    }

    // blocked transpose (every backward GEMM transposes an operand)
    let t = time_auto(300.0, || {
        std::hint::black_box(mat.transpose());
    });
    record("transpose_1024", t.median_ms);
    table.row(&[
        "transpose".into(),
        "1024x1024".into(),
        format!("{:.2}", t.median_ms),
        format!("{:.2} GB/s", 4.0 * mat.data.len() as f64 / t.median_ms / 1e6),
    ]);

    // pool dispatch overhead: 256 empty tasks through the worker pool —
    // the per-call cost matmul_par no longer pays as thread spawns
    let pool = chon::util::pool::global();
    let t = time_auto(100.0, || {
        pool.run(256, |i| {
            std::hint::black_box(i);
        });
    });
    record("pool_fanout_256", t.median_ms);
    table.row(&[
        format!("pool fanout x{}", pool.lanes()),
        "256 tasks".into(),
        format!("{:.3}", t.median_ms),
        format!("{:.1} µs/task", t.median_ms * 1e3 / 256.0),
    ]);

    // end-to-end train-step timing on the selected backend
    if model_available("tiny_gla") {
        for recipe in ["bf16", "chon"] {
            let mut tr = Trainer::new(run_cfg("tiny_gla", recipe))?;
            tr.train(12)?;
            // median over post-warmup steps — the gate diffs median_ms, and
            // a mean would let one cold/hiccuped step fail CI spuriously
            let mut walls: Vec<f64> =
                tr.log.records.iter().skip(1).map(|r| r.wall_ms).collect();
            walls.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = walls[walls.len() / 2];
            record(&format!("train_step_{recipe}"), med);
            table.row(&[
                format!("train step ({recipe})"),
                "tiny_gla".into(),
                format!("{med:.1}"),
                format!(
                    "{:.0} tok/s",
                    (tr.batch * tr.seq_len) as f64 / med * 1e3
                ),
            ]);
        }
        // data-parallel scaling: same step, batch sharded over the pool
        if bench_backend() == "native" {
            let mut cfg = run_cfg("tiny_gla", "chon");
            cfg.shards = 4;
            let mut tr = Trainer::new(cfg)?;
            tr.train(12)?;
            let mut walls: Vec<f64> =
                tr.log.records.iter().skip(1).map(|r| r.wall_ms).collect();
            walls.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let med = walls[walls.len() / 2];
            record("train_step_chon_shards4", med);
            table.row(&[
                "train step (chon, 4 shards)".into(),
                "tiny_gla".into(),
                format!("{med:.1}"),
                format!("{:.0} tok/s", (tr.batch * tr.seq_len) as f64 / med * 1e3),
            ]);
        }

        // decode throughput of the serve engine (batch 1 vs max batch)
        for batch in [1usize, 8] {
            let cfg = chon::runtime::native::model_cfg("tiny_gla")?;
            let params = chon::runtime::native::model::init_params(&cfg, 1);
            let eng = chon::serve::Engine::from_parts(
                cfg,
                chon::runtime::native::recipe::recipe("chon")?,
                chon::data::tokenizer::Tokenizer::byte_level(),
                &params,
            );
            let mut sessions: Vec<chon::serve::Session> =
                (0..batch).map(|_| eng.new_session()).collect();
            let toks: Vec<u32> = (0..batch as u32).map(|i| 97 + i).collect();
            let t = time_auto(300.0, || {
                let mut refs: Vec<&mut chon::serve::Session> =
                    sessions.iter_mut().collect();
                std::hint::black_box(eng.decode_step(&mut refs, &toks));
            });
            record(&format!("serve_decode_b{batch}"), t.median_ms);
            table.row(&[
                format!("serve decode (b={batch})"),
                "tiny_gla/chon".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", batch as f64 / t.median_ms * 1e3),
            ]);
        }

        // cross-session batched prefill: 8 ragged prompts in one pass
        {
            let cfg = chon::runtime::native::model_cfg("tiny_gla")?;
            let params = chon::runtime::native::model::init_params(&cfg, 1);
            let eng = chon::serve::Engine::from_parts(
                cfg,
                chon::runtime::native::recipe::recipe("chon")?,
                chon::data::tokenizer::Tokenizer::byte_level(),
                &params,
            );
            let prompts: Vec<Vec<u32>> = (0..8usize)
                .map(|i| {
                    (0..10 + i).map(|j| 97 + ((i + j) % 24) as u32).collect()
                })
                .collect();
            let n_tokens: usize = prompts.iter().map(|p| p.len()).sum();
            let t = time_auto(300.0, || {
                let mut sessions: Vec<chon::serve::Session> =
                    (0..prompts.len()).map(|_| eng.new_session()).collect();
                let mut refs: Vec<&mut chon::serve::Session> =
                    sessions.iter_mut().collect();
                let ps: Vec<&[u32]> =
                    prompts.iter().map(|p| p.as_slice()).collect();
                std::hint::black_box(eng.prefill_batch(&mut refs, &ps));
            });
            record("serve_prefill_batch8", t.median_ms);
            table.row(&[
                "serve prefill (8 prompts)".into(),
                "tiny_gla/chon".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", n_tokens as f64 / t.median_ms * 1e3),
            ]);
        }

        // packed-weight-cache decode: the engine's per-load PackedMat
        // panels feed every GEMM here (bitwise identical to unpacked —
        // this entry tracks the speed of the cached path specifically)
        {
            let cfg = chon::runtime::native::model_cfg("tiny_gla")?;
            let params = chon::runtime::native::model::init_params(&cfg, 1);
            let eng = chon::serve::Engine::from_parts(
                cfg,
                chon::runtime::native::recipe::recipe("chon")?,
                chon::data::tokenizer::Tokenizer::byte_level(),
                &params,
            );
            let batch = 4usize;
            let mut sessions: Vec<chon::serve::Session> =
                (0..batch).map(|_| eng.new_session()).collect();
            let toks: Vec<u32> = (0..batch as u32).map(|i| 97 + i).collect();
            let t = time_auto(300.0, || {
                let mut refs: Vec<&mut chon::serve::Session> =
                    sessions.iter_mut().collect();
                std::hint::black_box(eng.decode_step(&mut refs, &toks));
            });
            record("serve_decode_packed_weights", t.median_ms);
            table.row(&[
                format!("serve decode packed-W (b={batch})"),
                "tiny_gla/chon".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", batch as f64 / t.median_ms * 1e3),
            ]);
        }

        // --packed-compute decode: NVFP4 layers served straight from the
        // 4-bit codes (in-register dequant GEMM). "nvfp4" has hcp_frac=0
        // (pure packed kernel); "chon" adds the hot-channel f32 side-GEMM
        // on top, so the pair isolates the split's cost. The packed entry
        // is gated against staying under serve_decode_packed_weights —
        // same checkpoint, memory-bound regime, smaller resident operand.
        for (recipe, entry) in [
            ("nvfp4", "serve_decode_nvfp4_packed"),
            ("chon", "serve_decode_nvfp4_hot_split"),
        ] {
            let cfg = chon::runtime::native::model_cfg("tiny_gla")?;
            let params = chon::runtime::native::model::init_params(&cfg, 1);
            let eng = chon::serve::Engine::from_parts_mode(
                cfg,
                chon::runtime::native::recipe::recipe(recipe)?,
                chon::data::tokenizer::Tokenizer::byte_level(),
                &params,
                true,
            );
            let batch = 4usize;
            let mut sessions: Vec<chon::serve::Session> =
                (0..batch).map(|_| eng.new_session()).collect();
            let toks: Vec<u32> = (0..batch as u32).map(|i| 97 + i).collect();
            let t = time_auto(300.0, || {
                let mut refs: Vec<&mut chon::serve::Session> =
                    sessions.iter_mut().collect();
                std::hint::black_box(eng.decode_step(&mut refs, &toks));
            });
            record(entry, t.median_ms);
            table.row(&[
                format!("serve decode nvfp4 (b={batch})"),
                format!("tiny_gla/{recipe}"),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", batch as f64 / t.median_ms * 1e3),
            ]);
        }

        // observability overhead: the same b=8 decode with the full
        // metrics path on — one stage-histogram record per step plus the
        // --obs-outliers per-row HCP taps (hit counters + residual-energy
        // sums inside the quantized GEMM). The baseline gate diffs this
        // entry like any other: instrumentation must stay near-free
        // relative to serve_decode_b8.
        {
            let cfg = chon::runtime::native::model_cfg("tiny_gla")?;
            let params = chon::runtime::native::model::init_params(&cfg, 1);
            let mut eng = chon::serve::Engine::from_parts(
                cfg,
                chon::runtime::native::recipe::recipe("chon")?,
                chon::data::tokenizer::Tokenizer::byte_level(),
                &params,
            );
            let taps = eng.build_outlier_obs();
            eng.attach_outlier_obs(taps);
            let mobs = chon::obs::ModelObs::default();
            let batch = 8usize;
            let mut sessions: Vec<chon::serve::Session> =
                (0..batch).map(|_| eng.new_session()).collect();
            let toks: Vec<u32> = (0..batch as u32).map(|i| 97 + i).collect();
            let t = time_auto(300.0, || {
                let t0 = std::time::Instant::now();
                let mut refs: Vec<&mut chon::serve::Session> =
                    sessions.iter_mut().collect();
                std::hint::black_box(eng.decode_step(&mut refs, &toks));
                mobs.decode_token.record_elapsed(t0.elapsed());
            });
            record("serve_metrics_overhead", t.median_ms);
            table.row(&[
                format!("serve decode +metrics (b={batch})"),
                "tiny_gla/chon".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", batch as f64 / t.median_ms * 1e3),
            ]);
        }

        // two-model registry: one greedy request per model per iteration
        // through the full submit→batcher→reply path
        {
            use std::sync::atomic::AtomicBool;
            use std::sync::mpsc::channel;
            use std::sync::Arc;
            let mk = |seed: u64| -> Result<chon::serve::Engine> {
                let cfg = chon::runtime::native::model_cfg("tiny_gla")?;
                let params =
                    chon::runtime::native::model::init_params(&cfg, seed);
                Ok(chon::serve::Engine::from_parts(
                    cfg,
                    chon::runtime::native::recipe::recipe("chon")?,
                    chon::data::tokenizer::Tokenizer::byte_level(),
                    &params,
                ))
            };
            let mut reg = chon::serve::ModelRegistry::new(
                chon::serve::RegistryOpts::default(),
            );
            reg.register_engine("a", mk(1)?)?;
            reg.register_engine("b", mk(2)?)?;
            let one = |model: &str| {
                let (tx, rx) = channel();
                reg.submit(
                    Some(model),
                    chon::serve::GenRequest {
                        prompt: "the quick ".into(),
                        max_tokens: 8,
                        temp: 0.0,
                        session: None,
                        reply: chon::serve::ReplySink::channel(tx),
                        cancel: Arc::new(AtomicBool::new(false)),
                        queued_at: std::time::Instant::now(),
                    },
                )
                .expect("submit");
                loop {
                    match rx.recv().expect("reply") {
                        chon::serve::TokenEvent::Done { .. } => break,
                        chon::serve::TokenEvent::Error(e) => panic!("{e}"),
                        chon::serve::TokenEvent::Retry(e) => panic!("retry: {e}"),
                        chon::serve::TokenEvent::Token(_) => {}
                    }
                }
            };
            let t = time_auto(300.0, || {
                one("a");
                one("b");
            });
            record("serve_two_models", t.median_ms);
            table.row(&[
                "serve 2 models (8 tok each)".into(),
                "tiny_gla/chon".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", 16.0 / t.median_ms * 1e3),
            ]);
            reg.shutdown();
        }

        // paged long-context decode: SA sessions deep into their KV pages
        {
            let cfg = chon::runtime::native::model_cfg("tiny_sa")?;
            let params = chon::runtime::native::model::init_params(&cfg, 1);
            let eng = chon::serve::Engine::from_parts(
                cfg,
                chon::runtime::native::recipe::recipe("chon")?,
                chon::data::tokenizer::Tokenizer::byte_level(),
                &params,
            );
            let long: Vec<u32> =
                (0..256).map(|i| 97 + (i % 24) as u32).collect();
            let batch = 4usize;
            let mut sessions: Vec<chon::serve::Session> =
                (0..batch).map(|_| eng.new_session()).collect();
            for s in sessions.iter_mut() {
                eng.prefill(s, &long);
            }
            let toks: Vec<u32> = (0..batch as u32).map(|i| 97 + i).collect();
            // fixed iteration count: each step grows the cache, so an
            // adaptive budget would time a moving target
            let t = time_fn(2, 30, || {
                let mut refs: Vec<&mut chon::serve::Session> =
                    sessions.iter_mut().collect();
                std::hint::black_box(eng.decode_step(&mut refs, &toks));
            });
            record("serve_decode_paged", t.median_ms);
            table.row(&[
                format!("serve decode paged (b={batch}, ctx 256)"),
                "tiny_sa/chon".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", batch as f64 / t.median_ms * 1e3),
            ]);
        }

        // the epoll reactor front end under connection load: (a) full
        // round-trip generation latency with ~1k idle connections parked
        // on the event loop (idle conns must cost ~nothing), (b) eight
        // generations pipelined on one keep-alive HTTP connection
        {
            use std::io::{Read as _, Write as _};
            let cfg = chon::runtime::native::model_cfg("tiny_gla")?;
            let params = chon::runtime::native::model::init_params(&cfg, 1);
            let eng = chon::serve::Engine::from_parts(
                cfg,
                chon::runtime::native::recipe::recipe("chon")?,
                chon::data::tokenizer::Tokenizer::byte_level(),
                &params,
            );
            let mut reg = chon::serve::ModelRegistry::new(
                chon::serve::RegistryOpts::default(),
            );
            reg.register_engine("default", eng)?;
            let opts = chon::serve::ServeOpts {
                port: 0,
                http_port: Some(0),
                ..chon::serve::ServeOpts::default()
            };
            let server = chon::serve::Server::bind(reg, &opts)?;
            let port = server.port();
            let http_port = server.http_port().expect("http enabled");
            let h = std::thread::spawn(move || server.run());

            // (a) park an idle fleet, then time full TCP round trips
            let limit =
                chon::serve::reactor::raise_nofile_limit(4096).unwrap_or(1024);
            let n = ((limit.saturating_sub(256) / 2) as usize).min(1000);
            let fleet = chon::serve::client::IdleFleet::open("127.0.0.1", port, n)?;
            let t = time_fn(2, 20, || {
                chon::serve::client::generate_once(
                    "127.0.0.1",
                    port,
                    "the quick ",
                    8,
                    0.0,
                )
                .expect("generate");
            });
            record("serve_idle_1k_conns", t.median_ms);
            table.row(&[
                format!("serve gen ({n} idle conns)"),
                "tiny_gla/chon".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", 8.0 / t.median_ms * 1e3),
            ]);
            drop(fleet);

            // (b) 8 generations pipelined on one keep-alive connection
            let body = r#"{"prompt": "the quick ", "max_tokens": 8}"#;
            let req = format!(
                "POST /generate HTTP/1.1\r\nHost: b\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            );
            let t = time_fn(1, 10, || {
                let mut s = std::net::TcpStream::connect(("127.0.0.1", http_port))
                    .expect("connect");
                s.set_nodelay(true).ok();
                for _ in 0..8 {
                    s.write_all(req.as_bytes()).expect("write");
                }
                // each chunked response ends with the 0-length terminator
                let mut buf = Vec::new();
                let mut tmp = [0u8; 4096];
                loop {
                    let done = buf
                        .windows(7)
                        .filter(|&w| w == b"\r\n0\r\n\r\n")
                        .count();
                    if done >= 8 {
                        break;
                    }
                    let k = s.read(&mut tmp).expect("read");
                    assert!(k > 0, "server closed keep-alive connection");
                    buf.extend_from_slice(&tmp[..k]);
                }
            });
            record("serve_keepalive_pipeline8", t.median_ms);
            table.row(&[
                "serve keep-alive pipeline (8 gens)".into(),
                "tiny_gla/chon".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.0} tok/s", 64.0 / t.median_ms * 1e3),
            ]);

            chon::serve::client::send_shutdown("127.0.0.1", port)?;
            let _ = h.join();
        }

        // loadtest harness: generating + digesting a 16k-request Poisson
        // schedule (the seeded-reproducibility path every scenario pays
        // before it touches the network)
        {
            let t = time_fn(3, 20, || {
                let s = chon::loadtest::scenarios::poisson_schedule(
                    7, 16_384, 5_000.0, 16,
                );
                std::hint::black_box(s.digest());
            });
            record("loadtest_schedule_16k", t.median_ms);
            table.row(&[
                "loadtest schedule gen+digest (16k reqs)".into(),
                "-".into(),
                format!("{:.2}", t.median_ms),
                format!("{:.1} Mreq/s", 16.384 / t.median_ms),
            ]);
        }
    }
    table.print();
    let json_path = out_dir().join("perf.json");
    chon::bench::write_report(&json_path, "perf", &entries)?;
    println!("perf report written to {}", json_path.display());
    Ok(())
}

fn write_series_csv(m: &Monitor, path: &Path) -> Result<()> {
    m.write_csv(path)?;
    Ok(())
}

// ------------------------------------------------------------------

type BenchFn = fn() -> Result<()>;

fn main() {
    chon::util::logger::init();
    fast_compile_flags();
    let registry: Vec<(&str, &str, BenchFn)> = vec![
        ("tab1", "downstream eval across recipes (Tab. 1/8)", tab1),
        ("tab2", "recipe ablation grid (Tab. 2, Fig. 12)", tab2),
        ("tab3", "operator sensitivity (Tab. 3, Fig. 14)", tab3),
        ("tab5", "HCP kernel overhead (Tab. 5)", tab5),
        ("fig1", "activation kurtosis GLA vs SA (Fig. 1/17)", fig1),
        ("fig3", "hot-channel maps + persistence (Fig. 3/19/22)", fig3),
        ("fig4", "block kurtosis min/avg/max (Fig. 4/18)", fig4),
        ("fig5", "kurtosis evolution (Fig. 5)", fig5),
        ("fig6", "top-k magnitude evolution (Fig. 6/20/21/28)", fig6),
        ("fig7", "softmax instability (Fig. 7)", fig7),
        ("fig8", "SwiGLU alignment (Fig. 8)", fig8),
        ("fig11", "HCP config MSE sweep (Fig. 11/13)", fig11),
        ("fig15", "fine-tuning gap trajectory (Fig. 15c)", fig15),
        ("fig26", "FTZ dynamics (Fig. 26/27)", fig26),
        ("fig29", "RMSNorm gamma + superposition (Fig. 29/30/31)", fig29),
        ("fig32", "quant error dynamics (Fig. 32)", fig32),
        ("formats", "NVFP4 vs MXFP4 vs FP8 MSE", formats),
        ("perf", "L3 hot-path microbenches (§Perf)", perf),
    ];
    let args: Vec<String> = std::env::args()
        .skip(1)
        .filter(|a| !a.starts_with('-'))
        .collect();
    let selected: Vec<&(&str, &str, BenchFn)> = if args.is_empty() {
        registry.iter().collect()
    } else {
        registry
            .iter()
            .filter(|(name, _, _)| args.iter().any(|a| name.contains(a.as_str())))
            .collect()
    };
    if selected.is_empty() {
        eprintln!("no bench matches {args:?}; available:");
        for (name, desc, _) in &registry {
            eprintln!("  {name:<8} {desc}");
        }
        std::process::exit(1);
    }
    let t0 = std::time::Instant::now();
    let mut failed = 0;
    for (name, desc, f) in selected {
        println!("\n########## bench {name} — {desc} ##########");
        let t = std::time::Instant::now();
        match f() {
            Ok(()) => println!("[bench {name} done in {:.1}s]", t.elapsed().as_secs_f64()),
            Err(e) => {
                failed += 1;
                eprintln!("[bench {name} FAILED: {e:#}]");
            }
        }
    }
    println!(
        "\nall benches finished in {:.0}s ({failed} failed)",
        t0.elapsed().as_secs_f64()
    );
    if failed > 0 {
        std::process::exit(1);
    }
}
