//! CHON — Compensated Hot-channel Optimization for NVFP4.
//!
//! Reproduction of "Dissecting Outlier Dynamics in LLM NVFP4 Pretraining"
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * L3 (this crate): training coordinator, pluggable execution backends,
//!   diagnostics monitor, HCP engine, synthetic-data pipeline, benches,
//!   and the batched inference server (`serve`, the train→checkpoint→
//!   serve path).
//! * L2 (python/compile): JAX GLA / Softmax-Attention models with the CHON
//!   quantized-training recipe, AOT-lowered to `artifacts/*.hlo.txt`.
//! * L1 (python/compile/kernels): Pallas kernels (NVFP4 quantizer, fused
//!   HCP GEMM, RHT) inlined into the lowered HLO (interpret=True).
//!
//! Execution is backend-pluggable (`runtime::Backend`):
//!
//! * `native` (default) — the tiny GLA/SA training step in pure Rust over
//!   the `util::ndarray` + `quant` + `hcp` substrates; offline,
//!   deterministic, needs no artifacts and no libxla.
//! * `pjrt` (`--features pjrt`) — the binary loads AOT HLO text via the
//!   PJRT C API (`xla` crate) and drives training/eval/diagnostics.
//!   Python never runs on the request path.

// Style-only lints relaxed crate-wide: the numeric substrate is written
// index-style on purpose (mirrors the blocked/banded kernel structure).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::field_reassign_with_default,
    clippy::manual_memcpy,
    clippy::type_complexity,
    clippy::uninlined_format_args
)]

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod diagnostics;
pub mod hcp;
pub mod loadtest;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod util;
