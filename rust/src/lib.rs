//! CHON — Compensated Hot-channel Optimization for NVFP4.
//!
//! Reproduction of "Dissecting Outlier Dynamics in LLM NVFP4 Pretraining"
//! as a three-layer Rust + JAX + Pallas system:
//!
//! * L3 (this crate): training coordinator, PJRT runtime, diagnostics
//!   monitor, HCP engine, synthetic-data pipeline, benches.
//! * L2 (python/compile): JAX GLA / Softmax-Attention models with the CHON
//!   quantized-training recipe, AOT-lowered to `artifacts/*.hlo.txt`.
//! * L1 (python/compile/kernels): Pallas kernels (NVFP4 quantizer, fused
//!   HCP GEMM, RHT) inlined into the lowered HLO (interpret=True).
//!
//! Python never runs on the request path: the binary loads HLO text via
//! the PJRT C API (`xla` crate) and drives training/eval/diagnostics.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod diagnostics;
pub mod hcp;
pub mod quant;
pub mod runtime;
pub mod util;
