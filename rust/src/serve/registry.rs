//! The model registry: many named checkpoints behind one server.
//!
//! `chon serve --model NAME=CKPT_DIR ...` registers any number of models;
//! each resident model owns its own engine thread (`RequestBatcher`) and
//! its own named-session store, so prefill/decode batching never mixes
//! models and session ids are namespaced per model. On top of that the
//! registry adds three lifecycle behaviors:
//!
//! * **Lazy loading + LRU unload** — engines load on a model's first
//!   request; past `--max-resident-models`, the least-recently-used
//!   resident model is unloaded (its engine thread drained and dropped,
//!   its idle sessions parked in their store — resident or spilled — so
//!   a later reload continues every conversation bit-exactly).
//! * **Hot reload** — every `Trainer` save stamps `meta.toml` with a
//!   monotonic `generation`; the registry re-probes a model's checkpoint
//!   directory (at most every `reload_poll_ms`) on admission, and when
//!   the resolved directory or its generation changes it loads the new
//!   weights *first*, then drains the old engine. In-flight generations
//!   finish on the old weights; everything not yet admitted (including
//!   requests still queued at swap time) runs on the new ones. That is
//!   the train→serve continuous-deployment loop: `chon train` republishes
//!   into the watched directory and a live server picks it up without a
//!   restart.
//! * **Per-model + aggregate stats** — each model keeps a cumulative
//!   `ServeStats` that survives unload/reload; `STATS` (line) stays the
//!   aggregate one-liner, `GET /stats` adds a per-model breakdown with
//!   residency, step and generation.
//!
//! Concurrency model: one mutex around the whole slot table. Submits are
//! cheap under it (a channel send); loads, unloads and hot reloads run
//! under it too, which serializes them against all routing — simple and
//! correct, at the cost of head-of-line blocking while an engine swaps.
//! Known limitation (see ROADMAP): requests still queued on a model when
//! it is chosen as an LRU *unload* victim are rejected with a retryable
//! error (a hot reload re-submits them instead, since the replacement
//! engine exists).

use std::path::{Path, PathBuf};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::runtime::ckptdir::{self, CheckpointMeta};
use crate::serve::batcher::{GenRequest, RequestBatcher, ServeStats, TokenEvent};
use crate::serve::engine::Engine;
use crate::serve::pages::{SessionStore, StoreOpts};
use crate::serve::protocol::valid_model_name;
use crate::util::json::Json;
use crate::{info, warn};

/// Registry knobs (`chon serve` flags that are per-model rather than
/// per-listener).
#[derive(Clone, Debug)]
pub struct RegistryOpts {
    /// max sessions coalesced into one decode batch (per model)
    pub max_batch: usize,
    /// how long a fresh batch waits for companions (microseconds)
    pub max_wait_us: u64,
    /// temperature-sampling seed
    pub seed: u64,
    /// session-cache template; a user-chosen `spill_dir` gets a
    /// `<dir>/<model>` subdirectory per model so session ids cannot
    /// collide across models (the auto temp dir is unique per store)
    pub store_opts: StoreOpts,
    /// max models resident (engine loaded) at once; 0 = unlimited
    pub max_resident_models: usize,
    /// min milliseconds between checkpoint-dir generation probes per
    /// model (0 = probe on every admission; tests use this)
    pub reload_poll_ms: u64,
}

impl Default for RegistryOpts {
    fn default() -> Self {
        RegistryOpts {
            max_batch: 8,
            max_wait_us: 2000,
            seed: 0,
            store_opts: StoreOpts::default(),
            max_resident_models: 0,
            reload_poll_ms: 500,
        }
    }
}

/// Why a submission could not be routed. The front ends map these to
/// distinct wire errors (unknown model is the client's fault — 404/ERR;
/// a load failure or stopped registry is the server's — 5xx/ERR).
#[derive(Debug)]
pub enum SubmitError {
    UnknownModel(String),
    Load(anyhow::Error),
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownModel(name) => {
                write!(f, "unknown model {name:?}")
            }
            SubmitError::Load(e) => write!(f, "model failed to load: {e:#}"),
            SubmitError::Stopped => write!(f, "server stopped"),
        }
    }
}

/// Identity of the engine a slot currently (or last) served.
#[derive(Clone, Debug, PartialEq)]
struct LoadedFrom {
    /// the concrete checkpoint dir `resolve` picked inside the watched
    /// path (a republish at a higher step changes this)
    resolved: PathBuf,
    generation: u64,
}

struct Slot {
    name: String,
    /// the watched checkpoint path as registered (dir or parent of
    /// dirs); None for preloaded in-memory engines, which therefore can
    /// be neither reloaded nor unloaded (pinned resident)
    dir: Option<PathBuf>,
    batcher: Option<RequestBatcher>,
    /// session store parked across unloads so conversations survive
    parked: Option<SessionStore>,
    /// cumulative counters, surviving unload/reload
    stats: std::sync::Arc<ServeStats>,
    /// identity of the currently/last loaded engine
    loaded: Option<LoadedFrom>,
    /// checkpoint metadata snapshot (refreshed on every load/probe)
    meta: CheckpointMeta,
    /// LRU stamp (registry clock value of the last routed request)
    last_used: u64,
    /// earliest next generation probe (hot-reload poll throttle; doubles
    /// as the retry throttle after a failed load when `load_failed`)
    next_probe: Instant,
    /// the last load attempt failed — gates the cheap fast-fail below so
    /// a broken checkpoint is re-read at most once per poll window
    /// instead of on every submit (each retry holds the registry lock)
    load_failed: bool,
}

impl Slot {
    fn resident(&self) -> bool {
        self.batcher.is_some()
    }
}

struct Inner {
    slots: Vec<Slot>,
    clock: u64,
    model_loads: u64,
    model_unloads: u64,
    model_reloads: u64,
    stopped: bool,
}

/// The registry itself. Built (and populated via `register*`) before the
/// server starts, then shared behind an `Arc` by every connection
/// handler.
pub struct ModelRegistry {
    inner: Mutex<Inner>,
    opts: RegistryOpts,
}

/// Resolve a watched path to its concrete checkpoint dir + metadata.
fn probe(dir: &Path) -> Result<(PathBuf, CheckpointMeta)> {
    let resolved = ckptdir::resolve(dir)?;
    let meta = ckptdir::load_meta(&resolved)?;
    Ok((resolved, meta))
}

impl ModelRegistry {
    pub fn new(opts: RegistryOpts) -> ModelRegistry {
        ModelRegistry {
            inner: Mutex::new(Inner {
                slots: Vec::new(),
                clock: 0,
                model_loads: 0,
                model_unloads: 0,
                model_reloads: 0,
                stopped: false,
            }),
            opts,
        }
    }

    /// Per-model session-store options: a shared user spill dir gets a
    /// per-model subdirectory so spill files never collide across models.
    fn store_opts_for(&self, name: &str) -> StoreOpts {
        let mut so = self.opts.store_opts.clone();
        if let Some(dir) = so.spill_dir.take() {
            so.spill_dir = Some(dir.join(name));
        }
        so
    }

    /// The one place an engine thread is spawned from `RegistryOpts` —
    /// initial load, LRU reload and hot reload must all batch identically.
    fn spawn_batcher(
        &self,
        engine: Engine,
        store: SessionStore,
        stats: std::sync::Arc<ServeStats>,
    ) -> RequestBatcher {
        RequestBatcher::spawn_with(
            engine,
            self.opts.max_batch,
            Duration::from_micros(self.opts.max_wait_us),
            self.opts.seed,
            store,
            stats,
        )
    }

    /// Register a named checkpoint directory. Engines stay lazily loaded
    /// (nothing is kept resident here), but registration validates the
    /// FULL checkpoint — `Engine::load` is run once and dropped — so a
    /// truncated params file, tensor-shape mismatch or vocab drift fails
    /// the `chon serve` startup with a non-zero exit instead of starting
    /// a "healthy" server that 500s every request (the pre-registry
    /// bind-time guard, preserved). Peak memory stays one model: the
    /// validation engines are loaded sequentially and freed.
    pub fn register(&mut self, name: &str, dir: &Path) -> Result<()> {
        if !valid_model_name(name) {
            bail!(
                "bad model name {name:?} (want 1..=64 of [A-Za-z0-9._-], \
                 not starting with '.' or '-')"
            );
        }
        let inner = self.inner.get_mut().expect("registry poisoned");
        if inner.slots.iter().any(|s| s.name == name) {
            bail!("model {name:?} registered twice");
        }
        let (resolved, meta) = probe(dir)
            .with_context(|| format!("registering model {name:?} from {}", dir.display()))?;
        drop(Engine::load(&resolved).with_context(|| {
            format!("validating model {name:?} from {}", resolved.display())
        })?);
        inner.slots.push(Slot {
            name: name.to_string(),
            dir: Some(dir.to_path_buf()),
            batcher: None,
            parked: None,
            stats: std::sync::Arc::new(ServeStats::default()),
            loaded: None,
            meta,
            last_used: 0,
            next_probe: Instant::now(),
            load_failed: false,
        });
        Ok(())
    }

    /// Register an already-built in-memory engine (tests, embedding).
    /// Pinned resident: with no backing directory it can be neither
    /// hot-reloaded nor unloaded.
    pub fn register_engine(&mut self, name: &str, engine: Engine) -> Result<()> {
        if !valid_model_name(name) {
            bail!("bad model name {name:?}");
        }
        let store = SessionStore::new(self.store_opts_for(name))?;
        let inner = self.inner.get_mut().expect("registry poisoned");
        if inner.slots.iter().any(|s| s.name == name) {
            bail!("model {name:?} registered twice");
        }
        let meta = engine.meta.clone();
        let stats = std::sync::Arc::new(ServeStats::default());
        let batcher = self.spawn_batcher(engine, store, stats.clone());
        inner.model_loads += 1;
        inner.slots.push(Slot {
            name: name.to_string(),
            dir: None,
            batcher: Some(batcher),
            parked: None,
            stats,
            loaded: Some(LoadedFrom {
                resolved: PathBuf::new(),
                generation: meta.generation,
            }),
            meta,
            last_used: 0,
            next_probe: Instant::now(),
            load_failed: false,
        });
        Ok(())
    }

    /// Names in registration order (index 0 is the default model).
    pub fn model_names(&self) -> Vec<String> {
        let g = self.inner.lock().expect("registry poisoned");
        g.slots.iter().map(|s| s.name.clone()).collect()
    }

    /// The generation of a model's currently-loaded engine (None when
    /// unknown name or never loaded). Tests and `/stats` use this.
    pub fn loaded_generation(&self, name: &str) -> Option<u64> {
        let g = self.inner.lock().expect("registry poisoned");
        g.slots
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.loaded.as_ref())
            .map(|l| l.generation)
    }

    /// Route one request: resolve the model name (None = default = first
    /// registered), hot-reload if its checkpoint was republished, load it
    /// if not resident (evicting the LRU model past the budget), and hand
    /// the request to its engine thread.
    pub fn submit(
        &self,
        model: Option<&str>,
        req: GenRequest,
    ) -> std::result::Result<(), SubmitError> {
        let mut g = self.inner.lock().expect("registry poisoned");
        if g.stopped {
            return Err(SubmitError::Stopped);
        }
        let idx = match model {
            Some(name) => g
                .slots
                .iter()
                .position(|s| s.name == name)
                .ok_or_else(|| SubmitError::UnknownModel(name.to_string()))?,
            None => {
                if g.slots.is_empty() {
                    return Err(SubmitError::UnknownModel("<default>".into()));
                }
                0
            }
        };
        g.clock += 1;
        let clock = g.clock;
        g.slots[idx].last_used = clock;
        self.maybe_hot_reload(&mut g, idx);
        self.ensure_resident(&mut g, idx).map_err(SubmitError::Load)?;
        let batcher = g.slots[idx].batcher.as_ref().expect("resident after load");
        batcher
            .submitter()
            .send(req)
            .map_err(|_| SubmitError::Stopped)
    }

    /// Probe the slot's checkpoint dir (throttled) and swap engines when
    /// its generation moved. Load-the-new-first ordering: a failed load
    /// keeps serving the old weights (warned, retried at the next probe
    /// window) instead of leaving the model down.
    fn maybe_hot_reload(&self, g: &mut Inner, idx: usize) {
        let now = Instant::now();
        let poll = Duration::from_millis(self.opts.reload_poll_ms);
        {
            let slot = &g.slots[idx];
            if slot.batcher.is_none() || slot.dir.is_none() || now < slot.next_probe {
                return;
            }
        }
        g.slots[idx].next_probe = now + poll;
        let dir = g.slots[idx].dir.clone().expect("checked above");
        let (resolved, meta) = match probe(&dir) {
            Ok(p) => p,
            Err(e) => {
                warn!(
                    "model {}: checkpoint probe failed ({e:#}); serving \
                     current weights",
                    g.slots[idx].name
                );
                return;
            }
        };
        let current = LoadedFrom { resolved: resolved.clone(), generation: meta.generation };
        if g.slots[idx].loaded.as_ref() == Some(&current) {
            return;
        }
        let engine = match Engine::load(&resolved) {
            Ok(e) => e,
            Err(e) => {
                warn!(
                    "model {}: republished checkpoint {} failed to load \
                     ({e:#}); serving previous generation",
                    g.slots[idx].name,
                    resolved.display()
                );
                return;
            }
        };
        // drain the old engine (in-flight generations finish on the old
        // weights), then move its session store under the new one
        let name = g.slots[idx].name.clone();
        let (store, leftovers) = g.slots[idx]
            .batcher
            .take()
            .expect("resident checked above")
            .shutdown();
        let store = match store {
            Some(s) => s,
            None => match SessionStore::new(self.store_opts_for(&name)) {
                Ok(s) => s,
                Err(e) => {
                    warn!("model {name}: session store lost in reload: {e:#}");
                    g.slots[idx].loaded = None;
                    for req in leftovers {
                        let _ = req
                            .reply
                            .send(TokenEvent::Error("model reload failed".into()));
                    }
                    return;
                }
            },
        };
        let batcher =
            self.spawn_batcher(engine, store, g.slots[idx].stats.clone());
        // queued-but-unadmitted requests continue on the new weights
        for req in leftovers {
            let _ = batcher.submitter().send(req);
        }
        info!(
            "model {name}: hot-reloaded {} (generation {} -> {}, step {})",
            resolved.display(),
            g.slots[idx].loaded.as_ref().map(|l| l.generation).unwrap_or(0),
            meta.generation,
            meta.step
        );
        g.slots[idx].batcher = Some(batcher);
        g.slots[idx].loaded = Some(current);
        g.slots[idx].meta = meta;
        g.model_reloads += 1;
    }

    /// Load the slot's engine if it is not resident, unloading LRU
    /// victims while over the `max_resident_models` budget. Ordering and
    /// failure behavior: the new engine is loaded *before* any victim is
    /// evicted (a broken checkpoint never churns a healthy model out of
    /// residency), and a failed load arms a fast-fail window of
    /// `reload_poll_ms` so retries hit the disk at most once per window
    /// instead of on every submit (each attempt holds the registry lock).
    fn ensure_resident(&self, g: &mut Inner, idx: usize) -> Result<()> {
        if g.slots[idx].resident() {
            return Ok(());
        }
        let name = g.slots[idx].name.clone();
        if g.slots[idx].load_failed && Instant::now() < g.slots[idx].next_probe {
            bail!(
                "model {name:?} failed to load recently; retrying after \
                 the probe window"
            );
        }
        let dir = g.slots[idx]
            .dir
            .clone()
            .expect("non-resident slots have a dir");
        let loaded = probe(&dir).and_then(|(resolved, meta)| {
            let engine = Engine::load(&resolved)?;
            Ok((resolved, meta, engine))
        });
        let (resolved, meta, engine) = match loaded {
            Ok(l) => l,
            Err(e) => {
                g.slots[idx].load_failed = true;
                g.slots[idx].next_probe = Instant::now()
                    + Duration::from_millis(self.opts.reload_poll_ms);
                return Err(e)
                    .with_context(|| format!("loading model {name:?}"));
            }
        };
        if self.opts.max_resident_models > 0 {
            while g.slots.iter().filter(|s| s.resident()).count()
                >= self.opts.max_resident_models
            {
                // victim: least-recently-used resident model that *can*
                // be reloaded later (has a backing dir) and is not the
                // one we are loading
                let victim = g
                    .slots
                    .iter()
                    .enumerate()
                    .filter(|(i, s)| *i != idx && s.resident() && s.dir.is_some())
                    .min_by_key(|(_, s)| s.last_used)
                    .map(|(i, _)| i);
                let Some(v) = victim else {
                    break; // everything resident is pinned; stay over budget
                };
                self.unload(g, v);
            }
        }
        let store = match g.slots[idx].parked.take() {
            Some(s) => s,
            None => SessionStore::new(self.store_opts_for(&name))?,
        };
        let batcher =
            self.spawn_batcher(engine, store, g.slots[idx].stats.clone());
        info!(
            "model {name}: loaded {} (generation {}, step {})",
            resolved.display(),
            meta.generation,
            meta.step
        );
        g.slots[idx].batcher = Some(batcher);
        g.slots[idx].loaded =
            Some(LoadedFrom { resolved, generation: meta.generation });
        g.slots[idx].meta = meta;
        g.slots[idx].next_probe =
            Instant::now() + Duration::from_millis(self.opts.reload_poll_ms);
        g.slots[idx].load_failed = false;
        g.model_loads += 1;
        Ok(())
    }

    /// Drain and drop one resident engine, parking its session store.
    fn unload(&self, g: &mut Inner, idx: usize) {
        let Some(batcher) = g.slots[idx].batcher.take() else {
            return;
        };
        let (store, leftovers) = batcher.shutdown();
        g.slots[idx].parked = store;
        for req in leftovers {
            // no replacement engine exists to take these (unlike a hot
            // reload); reject retryably rather than resurrect the model
            // we were asked to evict
            let _ = req.reply.send(TokenEvent::Error(format!(
                "model {} was unloaded under --max-resident-models; retry",
                g.slots[idx].name
            )));
        }
        info!("model {}: unloaded (LRU)", g.slots[idx].name);
        g.model_unloads += 1;
    }

    /// Drain every engine and reject everything still queued. Idempotent.
    pub fn shutdown(&self) {
        let mut g = self.inner.lock().expect("registry poisoned");
        g.stopped = true;
        for i in 0..g.slots.len() {
            if let Some(batcher) = g.slots[i].batcher.take() {
                let (store, leftovers) = batcher.shutdown();
                g.slots[i].parked = store;
                for req in leftovers {
                    let _ = req
                        .reply
                        .send(TokenEvent::Error("server shutting down".into()));
                }
            }
        }
    }

    /// The one-line aggregate STATS payload (all models summed, plus the
    /// registry's own lifecycle counters).
    pub fn stats_line(&self) -> String {
        let g = self.inner.lock().expect("registry poisoned");
        let merged = ServeStats::merged(g.slots.iter().map(|s| s.stats.as_ref()));
        format!(
            "{} models={} resident_models={} model_loads={} \
             model_unloads={} model_reloads={}",
            merged.snapshot_line(),
            g.slots.len(),
            g.slots.iter().filter(|s| s.resident()).count(),
            g.model_loads,
            g.model_unloads,
            g.model_reloads,
        )
    }

    /// The `GET /stats` payload: the aggregate counters at the top level
    /// (field-compatible with the single-model servers of PR 2–4), plus
    /// registry counters (`models` is the registered count) and a
    /// per-model breakdown under `"per_model"`.
    pub fn stats_json(&self) -> Json {
        let g = self.inner.lock().expect("registry poisoned");
        let merged = ServeStats::merged(g.slots.iter().map(|s| s.stats.as_ref()));
        let Json::Obj(mut fields) = merged.snapshot_json() else {
            unreachable!("snapshot_json is an object");
        };
        let n = |v: u64| Json::Num(v as f64);
        fields.push(("models".into(), n(g.slots.len() as u64)));
        fields.push((
            "resident_models".into(),
            n(g.slots.iter().filter(|s| s.resident()).count() as u64),
        ));
        fields.push(("model_loads".into(), n(g.model_loads)));
        fields.push(("model_unloads".into(), n(g.model_unloads)));
        fields.push(("model_reloads".into(), n(g.model_reloads)));
        let per_model: Vec<(String, Json)> = g
            .slots
            .iter()
            .map(|s| {
                let Json::Obj(mut mf) = s.stats.snapshot_json() else {
                    unreachable!()
                };
                mf.push(("resident".into(), Json::Bool(s.resident())));
                mf.push(("model".into(), Json::Str(s.meta.model.clone())));
                mf.push(("recipe".into(), Json::Str(s.meta.recipe.clone())));
                mf.push(("step".into(), n(s.meta.step as u64)));
                mf.push((
                    "generation".into(),
                    n(s.loaded
                        .as_ref()
                        .map(|l| l.generation)
                        .unwrap_or(s.meta.generation)),
                ));
                (s.name.clone(), Json::Obj(mf))
            })
            .collect();
        fields.push(("per_model".into(), Json::Obj(per_model)));
        Json::Obj(fields)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::Tokenizer;
    use crate::runtime::native::model::{init_params, model_cfg};
    use crate::runtime::native::recipe::recipe;
    use std::sync::atomic::AtomicBool;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn test_engine(seed: u64) -> Engine {
        let cfg = model_cfg("tiny_gla").unwrap();
        let params = init_params(&cfg, seed);
        Engine::from_parts(
            cfg,
            recipe("chon").unwrap(),
            Tokenizer::byte_level(),
            &params,
        )
    }

    fn greedy(reg: &ModelRegistry, model: Option<&str>, prompt: &str) -> Vec<u8> {
        let (tx, rx) = channel();
        reg.submit(
            model,
            GenRequest {
                prompt: prompt.into(),
                max_tokens: 6,
                temp: 0.0,
                session: None,
                reply: tx,
                cancel: Arc::new(AtomicBool::new(false)),
            },
        )
        .unwrap();
        let mut bytes = Vec::new();
        loop {
            match rx.recv_timeout(Duration::from_secs(30)).expect("event") {
                TokenEvent::Token(p) => bytes.extend(p),
                TokenEvent::Done { .. } => return bytes,
                TokenEvent::Error(e) => panic!("unexpected error: {e}"),
            }
        }
    }

    #[test]
    fn preloaded_engines_route_by_name_and_reject_unknown() {
        let mut reg = ModelRegistry::new(RegistryOpts::default());
        reg.register_engine("alpha", test_engine(3)).unwrap();
        reg.register_engine("beta", test_engine(4)).unwrap();
        assert_eq!(reg.model_names(), vec!["alpha", "beta"]);

        let a = greedy(&reg, Some("alpha"), "hello ");
        let d = greedy(&reg, None, "hello ");
        assert_eq!(a, d, "default must route to the first registered model");

        let (tx, _rx) = channel();
        let err = reg
            .submit(
                Some("nope"),
                GenRequest {
                    prompt: "x".into(),
                    max_tokens: 1,
                    temp: 0.0,
                    session: None,
                    reply: tx,
                    cancel: Arc::new(AtomicBool::new(false)),
                },
            )
            .unwrap_err();
        assert!(matches!(err, SubmitError::UnknownModel(_)), "{err}");
        reg.shutdown();
    }

    #[test]
    fn duplicate_and_invalid_names_rejected() {
        let mut reg = ModelRegistry::new(RegistryOpts::default());
        reg.register_engine("a", test_engine(1)).unwrap();
        assert!(reg.register_engine("a", test_engine(2)).is_err());
        assert!(reg
            .register("bad/name", Path::new("/nonexistent"))
            .is_err());
        assert!(reg.register("ok", Path::new("/nonexistent")).is_err());
        reg.shutdown();
    }

    #[test]
    fn stats_line_aggregates_models() {
        let mut reg = ModelRegistry::new(RegistryOpts::default());
        reg.register_engine("a", test_engine(1)).unwrap();
        reg.register_engine("b", test_engine(2)).unwrap();
        greedy(&reg, Some("a"), "one ");
        greedy(&reg, Some("b"), "two ");
        // counters are synced by the engine threads after Done; both
        // requests completed, so requests= must already read 2
        let line = reg.stats_line();
        assert!(line.contains("requests=2"), "{line}");
        assert!(line.contains("models=2"), "{line}");
        assert!(line.contains("resident_models=2"), "{line}");
        let json = reg.stats_json();
        let per = json.get("per_model").expect("per_model present");
        assert!(per.get("a").is_some(), "{}", json.render());
        assert!(per.get("b").is_some(), "{}", json.render());
        assert_eq!(json.get("models").and_then(|v| v.as_f64()), Some(2.0));
        reg.shutdown();
    }
}
