//! The model registry: many named checkpoints behind one server.
//!
//! `chon serve --model NAME=CKPT_DIR ...` registers any number of models;
//! each resident model owns its own engine thread (`RequestBatcher`) and
//! its own named-session store, so prefill/decode batching never mixes
//! models and session ids are namespaced per model. On top of that the
//! registry adds three lifecycle behaviors:
//!
//! * **Lazy loading + LRU unload** — engines load on a model's first
//!   request; past `--max-resident-models`, the least-recently-used
//!   resident model is unloaded (its engine thread drained and dropped,
//!   its idle sessions parked in their store — resident or spilled — so
//!   a later reload continues every conversation bit-exactly). Requests
//!   still queued on the victim complete with `TokenEvent::Retry`
//!   (counted in `ServeStats::retry_rejects`) — never silently dropped.
//! * **Hot reload** — every `Trainer` save stamps `meta.toml` with a
//!   monotonic `generation`; the registry re-probes a model's checkpoint
//!   directory (at most every `reload_poll_ms`) on admission *and* from
//!   the server's timer tick ([`ModelRegistry::poll_reloads`]), so an
//!   idle model notices a republish without traffic. When the resolved
//!   directory or its generation changes the lifecycle thread loads the
//!   new weights *first*, then drains the old engine. In-flight
//!   generations finish on the old weights; everything not yet admitted
//!   (including requests queued during the swap) runs on the new ones.
//! * **Per-model + aggregate stats** — each model keeps a cumulative
//!   `ServeStats` that survives unload/reload; `STATS` (line) stays the
//!   aggregate one-liner, `GET /stats` adds a per-model breakdown with
//!   residency, step and generation.
//!
//! Concurrency model (the head-of-line-blocking fix): routing reads an
//! immutable snapshot — an `Arc<Vec<Arc<ModelEntry>>>` swapped wholesale
//! on registration — so `submit` never takes a registry-wide lock. Each
//! entry carries a tiny [`Route`] mutex held only for a channel send or
//! a queue push. Every slow operation (`Engine::load`, engine drains,
//! LRU eviction) runs on one background *lifecycle* thread that owns
//! every `RequestBatcher` handle; a submit that finds its model cold
//! queues on the entry (`Route::Loading`) and nudges the lifecycle
//! thread, so a multi-second model load never stalls requests routed to
//! models that are already resident.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::obs::ModelObs;
use crate::runtime::ckptdir::{self, CheckpointMeta};
use crate::serve::batcher::{GenRequest, RequestBatcher, ServeStats, TokenEvent};
use crate::serve::engine::Engine;
use crate::serve::pages::{SessionStore, StoreOpts};
use crate::serve::protocol::{valid_model_name, RETRY_SHUTDOWN};
use crate::util::json::Json;
use crate::{info, warn};

/// Registry knobs (`chon serve` flags that are per-model rather than
/// per-listener).
#[derive(Clone, Debug)]
pub struct RegistryOpts {
    /// max sessions coalesced into one decode batch (per model)
    pub max_batch: usize,
    /// how long a fresh batch waits for companions (microseconds)
    pub max_wait_us: u64,
    /// temperature-sampling seed
    pub seed: u64,
    /// session-cache template; a user-chosen `spill_dir` gets a
    /// `<dir>/<model>` subdirectory per model so session ids cannot
    /// collide across models (the auto temp dir is unique per store)
    pub store_opts: StoreOpts,
    /// max models resident (engine loaded) at once; 0 = unlimited
    pub max_resident_models: usize,
    /// min milliseconds between checkpoint-dir generation probes per
    /// model (0 = probe on every admission; tests use this)
    pub reload_poll_ms: u64,
    /// test hook: artificial delay injected before every `Engine::load`
    /// on the lifecycle thread, to pin that a slow load never stalls
    /// routing to resident models (0 = off)
    pub load_delay_ms: u64,
    /// this server's metric tree (stage histograms, reactor gauges).
    /// Defaults to a fresh registry so in-process test servers stay
    /// isolated; the `chon serve` binary passes `obs::global()`.
    pub obs: Arc<crate::obs::Registry>,
    /// sample per-request HCP hot-channel hits + residual energy into
    /// the metric tree (`--obs-outliers`)
    pub obs_outliers: bool,
    /// serve NVFP4 layers from packed 4-bit codes with the in-register
    /// dequant GEMM + hot-channel side-GEMM (`--packed-compute`)
    pub packed_compute: bool,
}

impl Default for RegistryOpts {
    fn default() -> Self {
        RegistryOpts {
            max_batch: 8,
            max_wait_us: 2000,
            seed: 0,
            store_opts: StoreOpts::default(),
            max_resident_models: 0,
            reload_poll_ms: 500,
            load_delay_ms: 0,
            obs: crate::obs::Registry::new(),
            obs_outliers: false,
            packed_compute: false,
        }
    }
}

/// Why a submission could not be routed. The front ends map these to
/// distinct wire errors (unknown model is the client's fault — 404/ERR;
/// a load failure or stopped registry is the server's — 5xx/ERR).
#[derive(Debug)]
pub enum SubmitError {
    UnknownModel(String),
    Load(anyhow::Error),
    Stopped,
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownModel(name) => {
                write!(f, "unknown model {name:?}")
            }
            SubmitError::Load(e) => write!(f, "model failed to load: {e:#}"),
            SubmitError::Stopped => write!(f, "server stopped"),
        }
    }
}

/// Identity of the engine a slot currently (or last) served.
#[derive(Clone, Debug, PartialEq)]
struct LoadedFrom {
    /// the concrete checkpoint dir `resolve` picked inside the watched
    /// path (a republish at a higher step changes this)
    resolved: PathBuf,
    generation: u64,
}

/// Where requests for a model go right now. Held under a per-entry
/// mutex for a channel send / queue push only — never across IO.
enum Route {
    /// engine thread is up: hand the request straight to its queue
    Resident(Sender<GenRequest>),
    /// the lifecycle thread is loading (or swapping) this model's
    /// engine; requests park here and are flushed to the new engine the
    /// moment it is up — so they run on the *new* weights
    Loading(Vec<GenRequest>),
    /// last load failed; fast-fail submits until the retry window opens
    Failed { until: Instant, error: String },
    /// registered but not resident (never loaded, or LRU-unloaded)
    Cold,
}

/// Probe/identity state, mutated only behind its own small mutex.
struct MetaState {
    /// identity of the currently/last loaded engine (None = never)
    loaded: Option<LoadedFrom>,
    /// checkpoint metadata snapshot (refreshed on every load/probe)
    meta: CheckpointMeta,
    /// earliest next generation probe (hot-reload poll throttle)
    next_probe: Instant,
}

/// One registered model in the immutable routing snapshot. The entry
/// itself never moves or reorders; all mutable state is interior.
struct ModelEntry {
    name: String,
    /// the watched checkpoint path as registered (dir or parent of
    /// dirs); None for preloaded in-memory engines, which therefore can
    /// be neither reloaded nor unloaded (pinned resident)
    dir: Option<PathBuf>,
    /// cumulative counters, surviving unload/reload
    stats: Arc<ServeStats>,
    /// stage-latency histograms (+ outlier taps), surviving reloads like
    /// `stats` — a hot reload swaps the engine thread, not the metrics
    obs: Arc<ModelObs>,
    route: Mutex<Route>,
    /// LRU stamp (registry clock value of the last routed request)
    last_used: AtomicU64,
    meta: Mutex<MetaState>,
}

type Snapshot = Arc<Vec<Arc<ModelEntry>>>;

/// State shared between the routing front and the lifecycle thread.
struct Shared {
    /// the Arc-swapped routing snapshot: readers clone the Arc under a
    /// momentary read lock; only registration writes (build-aside+swap)
    snapshot: RwLock<Snapshot>,
    opts: RegistryOpts,
    clock: AtomicU64,
    model_loads: AtomicU64,
    model_unloads: AtomicU64,
    model_reloads: AtomicU64,
    stopped: AtomicBool,
}

/// Lifecycle-thread work items. Every `Route::Loading` transition sends
/// exactly one `Load`/`Reload`, and its handler always resolves the
/// route back out of `Loading` — the invariant that keeps queued
/// requests from being stranded.
enum Cmd {
    /// load a cold/failed model and flush its queued requests
    Load(usize),
    /// swap in a republished checkpoint (entry already set to Loading)
    Reload(usize),
    /// adopt ownership of a preregistered engine's batcher handle
    Adopt(usize, RequestBatcher),
    /// probe every resident watched model for a republish
    Tick,
    /// drain every engine and exit
    Stop,
}

/// The registry itself. Built (and populated via `register*`) before the
/// server starts, then shared behind an `Arc` by every connection
/// handler.
pub struct ModelRegistry {
    shared: Arc<Shared>,
    lifecycle_tx: Sender<Cmd>,
    lifecycle: Mutex<Option<JoinHandle<()>>>,
}

/// Resolve a watched path to its concrete checkpoint dir + metadata.
fn probe(dir: &Path) -> Result<(PathBuf, CheckpointMeta)> {
    let resolved = ckptdir::resolve(dir)?;
    let meta = ckptdir::load_meta(&resolved)?;
    Ok((resolved, meta))
}

/// Reject one parked request retryably and count it.
fn reject_retry(stats: &ServeStats, req: &GenRequest, why: &str) {
    stats.retry_rejects.fetch_add(1, Ordering::Relaxed);
    let _ = req.reply.send(TokenEvent::Retry(why.to_string()));
}

impl ModelRegistry {
    pub fn new(opts: RegistryOpts) -> ModelRegistry {
        let shared = Arc::new(Shared {
            snapshot: RwLock::new(Arc::new(Vec::new())),
            opts,
            clock: AtomicU64::new(0),
            model_loads: AtomicU64::new(0),
            model_unloads: AtomicU64::new(0),
            model_reloads: AtomicU64::new(0),
            stopped: AtomicBool::new(false),
        });
        let (tx, rx) = channel();
        let shared2 = shared.clone();
        let handle = std::thread::spawn(move || lifecycle_loop(shared2, rx));
        ModelRegistry {
            shared,
            lifecycle_tx: tx,
            lifecycle: Mutex::new(Some(handle)),
        }
    }

    fn snapshot(&self) -> Snapshot {
        self.shared.snapshot.read().expect("registry poisoned").clone()
    }

    /// Append one entry to the routing snapshot (build aside + swap).
    fn push_entry(&self, entry: ModelEntry) -> Result<usize> {
        let mut g = self.shared.snapshot.write().expect("registry poisoned");
        if g.iter().any(|e| e.name == entry.name) {
            bail!("model {:?} registered twice", entry.name);
        }
        let mut next: Vec<Arc<ModelEntry>> = g.as_ref().clone();
        next.push(Arc::new(entry));
        let idx = next.len() - 1;
        *g = Arc::new(next);
        Ok(idx)
    }

    /// Register a named checkpoint directory. Engines stay lazily loaded
    /// (nothing is kept resident here), but registration validates the
    /// FULL checkpoint — `Engine::load` is run once and dropped — so a
    /// truncated params file, tensor-shape mismatch or vocab drift fails
    /// the `chon serve` startup with a non-zero exit instead of starting
    /// a "healthy" server that 500s every request (the pre-registry
    /// bind-time guard, preserved). Peak memory stays one model: the
    /// validation engines are loaded sequentially and freed.
    pub fn register(&mut self, name: &str, dir: &Path) -> Result<()> {
        if !valid_model_name(name) {
            bail!(
                "bad model name {name:?} (want 1..=64 of [A-Za-z0-9._-], \
                 not starting with '.' or '-')"
            );
        }
        let (resolved, meta) = probe(dir)
            .with_context(|| format!("registering model {name:?} from {}", dir.display()))?;
        drop(
            Engine::load_with_mode(&resolved, self.shared.opts.packed_compute).with_context(
                || format!("validating model {name:?} from {}", resolved.display()),
            )?,
        );
        self.push_entry(ModelEntry {
            name: name.to_string(),
            dir: Some(dir.to_path_buf()),
            stats: Arc::new(ServeStats::default()),
            obs: self.shared.opts.obs.model(name),
            route: Mutex::new(Route::Cold),
            last_used: AtomicU64::new(0),
            meta: Mutex::new(MetaState {
                loaded: None,
                meta,
                next_probe: Instant::now(),
            }),
        })?;
        Ok(())
    }

    /// Register an already-built in-memory engine (tests, embedding).
    /// Pinned resident: with no backing directory it can be neither
    /// hot-reloaded nor unloaded.
    pub fn register_engine(&mut self, name: &str, mut engine: Engine) -> Result<()> {
        if !valid_model_name(name) {
            bail!("bad model name {name:?}");
        }
        let store = SessionStore::new(store_opts_for(&self.shared.opts, name))?;
        let meta = engine.meta.clone();
        let stats = Arc::new(ServeStats::default());
        let obs = self.shared.opts.obs.model(name);
        obs.set_weight_bytes(engine.weight_bytes() as u64, engine.compute_mode());
        hook_outliers(&self.shared.opts, &mut engine, &obs);
        let batcher =
            spawn_batcher(&self.shared.opts, engine, store, stats.clone(), obs.clone());
        let idx = self.push_entry(ModelEntry {
            name: name.to_string(),
            dir: None,
            stats,
            obs,
            route: Mutex::new(Route::Resident(batcher.submitter())),
            last_used: AtomicU64::new(0),
            meta: Mutex::new(MetaState {
                loaded: Some(LoadedFrom {
                    resolved: PathBuf::new(),
                    generation: meta.generation,
                }),
                meta,
                next_probe: Instant::now(),
            }),
        })?;
        self.shared.model_loads.fetch_add(1, Ordering::Relaxed);
        // the lifecycle thread owns every engine handle (registration
        // happens before serving, so the channel cannot be closed yet)
        self.lifecycle_tx
            .send(Cmd::Adopt(idx, batcher))
            .map_err(|_| anyhow!("registry lifecycle thread is gone"))?;
        Ok(())
    }

    /// Names in registration order (index 0 is the default model).
    pub fn model_names(&self) -> Vec<String> {
        self.snapshot().iter().map(|e| e.name.clone()).collect()
    }

    /// The generation of a model's currently-loaded engine (None when
    /// unknown name or never loaded). Tests and `/stats` use this.
    pub fn loaded_generation(&self, name: &str) -> Option<u64> {
        self.snapshot()
            .iter()
            .find(|e| e.name == name)
            .and_then(|e| {
                e.meta
                    .lock()
                    .expect("registry poisoned")
                    .loaded
                    .as_ref()
                    .map(|l| l.generation)
            })
    }

    /// Nudge the lifecycle thread to probe every resident watched model
    /// for a republished checkpoint. The server calls this from its 1 Hz
    /// timer tick ONLY, so generation bumps surface even with zero
    /// traffic. Observation endpoints (`/stats`, `/metrics`) must never
    /// call this — scraping is side-effect-free (pinned by
    /// `stats_and_metrics_never_initiate_loads`). Never blocks.
    pub fn poll_reloads(&self) {
        let _ = self.lifecycle_tx.send(Cmd::Tick);
    }

    /// Route one request: resolve the model name (None = default = first
    /// registered), detect a republished checkpoint, and hand the
    /// request to its engine thread — or queue it on the entry while the
    /// lifecycle thread brings the engine up. Never loads an engine and
    /// never blocks on another model's lifecycle: the whole path is a
    /// snapshot read plus one per-entry mutex held for a send/push.
    pub fn submit(
        &self,
        model: Option<&str>,
        req: GenRequest,
    ) -> std::result::Result<(), SubmitError> {
        if self.shared.stopped.load(Ordering::SeqCst) {
            return Err(SubmitError::Stopped);
        }
        let snap = self.snapshot();
        let idx = match model {
            Some(name) => snap
                .iter()
                .position(|e| e.name == name)
                .ok_or_else(|| SubmitError::UnknownModel(name.to_string()))?,
            None => {
                if snap.is_empty() {
                    return Err(SubmitError::UnknownModel("<default>".into()));
                }
                0
            }
        };
        let entry = &snap[idx];
        let stamp = self.shared.clock.fetch_add(1, Ordering::SeqCst) + 1;
        entry.last_used.store(stamp, Ordering::SeqCst);
        self.maybe_trigger_reload(idx, entry);

        let mut route = entry.route.lock().expect("registry poisoned");
        match &mut *route {
            Route::Resident(tx) => {
                tx.send(req).map_err(|_| SubmitError::Stopped)?;
            }
            Route::Loading(q) => q.push(req),
            Route::Failed { until, error } if Instant::now() < *until => {
                let (name, error) = (entry.name.clone(), error.clone());
                return Err(SubmitError::Load(anyhow!(
                    "model {name:?}: {error} (retrying after the probe window)"
                )));
            }
            state => {
                // Cold, or Failed past its window: queue and ask the
                // lifecycle thread to bring the engine up
                *state = Route::Loading(vec![req]);
                drop(route);
                if self.lifecycle_tx.send(Cmd::Load(idx)).is_err() {
                    // lifecycle thread already gone (shutdown race):
                    // resolve everything queued retryably, including our
                    // own request — its terminal event has been sent
                    let mut route =
                        entry.route.lock().expect("registry poisoned");
                    if let Route::Loading(q) =
                        std::mem::replace(&mut *route, Route::Cold)
                    {
                        for r in q {
                            reject_retry(&entry.stats, &r, RETRY_SHUTDOWN);
                        }
                    }
                }
            }
        }
        Ok(())
    }

    /// Throttled checkpoint probe on the submit path: when the watched
    /// dir resolves to a new generation, flip the route to `Loading` (so
    /// this and subsequent requests run on the NEW weights) and hand the
    /// actual engine swap to the lifecycle thread.
    fn maybe_trigger_reload(&self, idx: usize, entry: &Arc<ModelEntry>) {
        if entry.dir.is_none() {
            return;
        }
        let now = Instant::now();
        let poll = Duration::from_millis(self.shared.opts.reload_poll_ms);
        let loaded = {
            let mut ms = entry.meta.lock().expect("registry poisoned");
            if now < ms.next_probe {
                return;
            }
            ms.next_probe = now + poll;
            match &ms.loaded {
                Some(l) => l.clone(),
                None => return, // cold: the load path reads the newest anyway
            }
        };
        let dir = entry.dir.as_ref().expect("checked above");
        let (resolved, meta) = match probe(dir) {
            Ok(p) => p,
            Err(e) => {
                warn!(
                    "model {}: checkpoint probe failed ({e:#}); serving \
                     current weights",
                    entry.name
                );
                return;
            }
        };
        if (LoadedFrom { resolved, generation: meta.generation }) == loaded {
            return;
        }
        let mut route = entry.route.lock().expect("registry poisoned");
        if let Route::Resident(tx) = &*route {
            let old = tx.clone();
            *route = Route::Loading(Vec::new());
            drop(route);
            if self.lifecycle_tx.send(Cmd::Reload(idx)).is_err() {
                // shutdown race: put the old engine back
                let mut route = entry.route.lock().expect("registry poisoned");
                if let Route::Loading(q) =
                    std::mem::replace(&mut *route, Route::Resident(old.clone()))
                {
                    for r in q {
                        let _ = old.send(r);
                    }
                }
            }
        }
        // Loading/Failed/Cold: a lifecycle pass is already pending (or
        // the next load will read the republished checkpoint itself)
    }

    /// Drain every engine and reject everything still queued (with the
    /// retryable contract — nothing is silently dropped). Idempotent.
    pub fn shutdown(&self) {
        self.shared.stopped.store(true, Ordering::SeqCst);
        let _ = self.lifecycle_tx.send(Cmd::Stop);
        if let Some(h) = self.lifecycle.lock().expect("registry poisoned").take() {
            let _ = h.join();
        }
        // post-join sweep: anything that raced into a Loading queue
        // after the lifecycle thread drained it gets resolved here
        for entry in self.snapshot().iter() {
            let mut route = entry.route.lock().expect("registry poisoned");
            if let Route::Loading(q) = std::mem::replace(&mut *route, Route::Cold) {
                for r in q {
                    reject_retry(&entry.stats, &r, RETRY_SHUTDOWN);
                }
            }
        }
    }

    /// The one-line aggregate STATS payload (all models summed, plus the
    /// registry's own lifecycle counters).
    pub fn stats_line(&self) -> String {
        let snap = self.snapshot();
        let merged = ServeStats::merged(snap.iter().map(|e| e.stats.as_ref()));
        format!(
            "{} models={} resident_models={} model_loads={} \
             model_unloads={} model_reloads={}",
            merged.snapshot_line(),
            snap.len(),
            snap.iter().filter(|e| e.resident()).count(),
            self.shared.model_loads.load(Ordering::Relaxed),
            self.shared.model_unloads.load(Ordering::Relaxed),
            self.shared.model_reloads.load(Ordering::Relaxed),
        )
    }

    /// The `GET /stats` payload: the aggregate counters at the top level
    /// (field-compatible with the single-model servers of PR 2–4), plus
    /// registry counters (`models` is the registered count) and a
    /// per-model breakdown under `"per_model"`.
    pub fn stats_json(&self) -> Json {
        let snap = self.snapshot();
        let merged = ServeStats::merged(snap.iter().map(|e| e.stats.as_ref()));
        let Json::Obj(mut fields) = merged.snapshot_json() else {
            unreachable!("snapshot_json is an object");
        };
        let n = |v: u64| Json::Num(v as f64);
        fields.push(("models".into(), n(snap.len() as u64)));
        fields.push((
            "resident_models".into(),
            n(snap.iter().filter(|e| e.resident()).count() as u64),
        ));
        fields.push((
            "model_loads".into(),
            n(self.shared.model_loads.load(Ordering::Relaxed)),
        ));
        fields.push((
            "model_unloads".into(),
            n(self.shared.model_unloads.load(Ordering::Relaxed)),
        ));
        fields.push((
            "model_reloads".into(),
            n(self.shared.model_reloads.load(Ordering::Relaxed)),
        ));
        let per_model: Vec<(String, Json)> = snap
            .iter()
            .map(|e| {
                let Json::Obj(mut mf) = e.stats.snapshot_json() else {
                    unreachable!()
                };
                let ms = e.meta.lock().expect("registry poisoned");
                mf.push(("resident".into(), Json::Bool(e.resident())));
                mf.push(("model".into(), Json::Str(ms.meta.model.clone())));
                mf.push(("recipe".into(), Json::Str(ms.meta.recipe.clone())));
                mf.push(("step".into(), n(ms.meta.step as u64)));
                mf.push((
                    "generation".into(),
                    n(ms.loaded
                        .as_ref()
                        .map(|l| l.generation)
                        .unwrap_or(ms.meta.generation)),
                ));
                (e.name.clone(), Json::Obj(mf))
            })
            .collect();
        fields.push(("per_model".into(), Json::Obj(per_model)));
        Json::Obj(fields)
    }

    /// This server's metric tree (stage histograms + reactor gauges).
    pub fn obs(&self) -> Arc<crate::obs::Registry> {
        self.shared.opts.obs.clone()
    }

    /// The write-flush histogram of a model (reactor-side span). `None`
    /// resolves to the default model; unknown names return None.
    pub fn model_obs(&self, model: Option<&str>) -> Option<Arc<ModelObs>> {
        let snap = self.snapshot();
        let entry = match model {
            Some(name) => snap.iter().find(|e| e.name == name)?,
            None => snap.first()?,
        };
        Some(entry.obs.clone())
    }

    /// The full `GET /metrics` body: the obs registry's families (stage
    /// histograms, reactor gauges, outlier taps) plus counter/gauge
    /// families derived from the same `ServeStats` atomics `/stats`
    /// reads, and the registry's lifecycle counters. Pure observation —
    /// never probes or loads anything.
    pub fn metrics_text(&self) -> String {
        use crate::obs::expo::Expo;
        let mut body = self.shared.opts.obs.render();
        let snap = self.snapshot();
        let mut e = Expo::new();
        let per_model: &[(&str, &str, fn(&ServeStats) -> u64)] = &[
            ("chon_requests_total", "Generation requests admitted.", |s| {
                s.requests.load(Ordering::Relaxed)
            }),
            ("chon_tokens_total", "Tokens generated.", |s| {
                s.tokens.load(Ordering::Relaxed)
            }),
            ("chon_decode_steps_total", "Batched decode steps executed.", |s| {
                s.decode_steps.load(Ordering::Relaxed)
            }),
            ("chon_prefill_tokens_total", "Prompt tokens consumed by prefill.", |s| {
                s.prefill_tokens.load(Ordering::Relaxed)
            }),
            ("chon_cancelled_total", "Queued requests dropped as cancelled.", |s| {
                s.cancelled.load(Ordering::Relaxed)
            }),
            ("chon_retry_rejects_total", "Requests rejected retryably.", |s| {
                s.retry_rejects.load(Ordering::Relaxed)
            }),
            ("chon_session_evictions_total", "Named sessions spilled to disk.", |s| {
                s.evictions.load(Ordering::Relaxed)
            }),
            ("chon_session_reloads_total", "Named sessions reloaded from disk.", |s| {
                s.reloads.load(Ordering::Relaxed)
            }),
        ];
        for (name, help, get) in per_model {
            e.family(name, "counter", help);
            for entry in snap.iter() {
                e.sample(name, &[("model", &entry.name)], get(&entry.stats));
            }
        }
        let per_model_gauges: &[(&str, &str, fn(&ServeStats) -> u64)] = &[
            ("chon_resident_sessions", "Idle named sessions in memory.", |s| {
                s.resident_sessions.load(Ordering::Relaxed)
            }),
            ("chon_resident_kv_tokens", "KV positions held by resident idle sessions.", |s| {
                s.resident_kv_tokens.load(Ordering::Relaxed)
            }),
        ];
        for (name, help, get) in per_model_gauges {
            e.family(name, "gauge", help);
            for entry in snap.iter() {
                e.sample(name, &[("model", &entry.name)], get(&entry.stats));
            }
        }
        e.family(
            "chon_model_resident",
            "gauge",
            "1 when the model's engine is loaded.",
        );
        for entry in snap.iter() {
            e.sample(
                "chon_model_resident",
                &[("model", &entry.name)],
                entry.resident() as u64,
            );
        }
        e.family("chon_models", "gauge", "Registered models.");
        e.sample("chon_models", &[], snap.len() as u64);
        e.family("chon_resident_models", "gauge", "Models with a loaded engine.");
        e.sample(
            "chon_resident_models",
            &[],
            snap.iter().filter(|e| e.resident()).count() as u64,
        );
        let lifecycle: &[(&str, &str, &AtomicU64)] = &[
            ("chon_model_loads_total", "Engine loads.", &self.shared.model_loads),
            ("chon_model_unloads_total", "LRU engine unloads.", &self.shared.model_unloads),
            ("chon_model_reloads_total", "Hot reloads onto a republished checkpoint.", &self.shared.model_reloads),
        ];
        for (name, help, ctr) in lifecycle {
            e.family(name, "counter", help);
            e.sample(name, &[], ctr.load(Ordering::Relaxed));
        }
        body.push_str(&e.finish());
        body
    }
}

impl ModelEntry {
    fn resident(&self) -> bool {
        matches!(
            *self.route.lock().expect("registry poisoned"),
            Route::Resident(_)
        )
    }
}

/// Per-model session-store options: a shared user spill dir gets a
/// per-model subdirectory so spill files never collide across models.
fn store_opts_for(opts: &RegistryOpts, name: &str) -> StoreOpts {
    let mut so = opts.store_opts.clone();
    if let Some(dir) = so.spill_dir.take() {
        so.spill_dir = Some(dir.join(name));
    }
    so
}

/// The one place an engine thread is spawned from `RegistryOpts` —
/// initial load, LRU reload and hot reload must all batch identically.
fn spawn_batcher(
    opts: &RegistryOpts,
    engine: Engine,
    store: SessionStore,
    stats: Arc<ServeStats>,
    obs: Arc<ModelObs>,
) -> RequestBatcher {
    RequestBatcher::spawn_full(
        engine,
        opts.max_batch,
        Duration::from_micros(opts.max_wait_us),
        opts.seed,
        store,
        stats,
        Some(obs),
    )
}

/// Under `--obs-outliers`, point the engine's HCP path at the model's
/// outlier taps. Taps are created once per model and survive hot
/// reloads (cumulative across engine swaps), like `ServeStats`.
fn hook_outliers(opts: &RegistryOpts, engine: &mut Engine, obs: &ModelObs) {
    if !opts.obs_outliers {
        return;
    }
    let taps = match obs.outliers.get() {
        Some(t) => t.clone(),
        None => {
            let t = engine.build_outlier_obs();
            // a racing set keeps the winner; read it back either way
            let _ = obs.outliers.set(t);
            obs.outliers.get().expect("just set").clone()
        }
    };
    engine.attach_outlier_obs(taps);
}

/// The lifecycle thread: single owner of every `RequestBatcher` handle
/// and every parked `SessionStore`. All `Engine::load`s, drains, LRU
/// evictions and hot reloads run here, strictly off the routing path.
struct Lifecycle {
    shared: Arc<Shared>,
    /// entry index -> the resident engine's handle
    batchers: HashMap<usize, RequestBatcher>,
    /// entry index -> session store parked across an unload
    parked: HashMap<usize, SessionStore>,
}

fn lifecycle_loop(shared: Arc<Shared>, rx: Receiver<Cmd>) {
    let mut lc = Lifecycle {
        shared,
        batchers: HashMap::new(),
        parked: HashMap::new(),
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Adopt(idx, batcher) => {
                lc.batchers.insert(idx, batcher);
            }
            Cmd::Load(idx) => lc.load(idx),
            Cmd::Reload(idx) => lc.reload(idx),
            Cmd::Tick => lc.tick(),
            Cmd::Stop => break,
        }
    }
    lc.drain_all();
}

impl Lifecycle {
    fn entry(&self, idx: usize) -> Arc<ModelEntry> {
        self.shared.snapshot.read().expect("registry poisoned")[idx].clone()
    }

    fn load_delay(&self) {
        let ms = self.shared.opts.load_delay_ms;
        if ms > 0 {
            std::thread::sleep(Duration::from_millis(ms));
        }
    }

    /// Resolve a `Loading` route after a failed load: flush the queue to
    /// `fallback` when an engine still exists (reload keeps serving the
    /// old weights), otherwise fast-fail the queue and arm the window.
    fn fail_loading(
        &self,
        entry: &ModelEntry,
        fallback: Option<Sender<GenRequest>>,
        error: String,
    ) {
        let until =
            Instant::now() + Duration::from_millis(self.shared.opts.reload_poll_ms);
        let mut route = entry.route.lock().expect("registry poisoned");
        let next = match &fallback {
            Some(tx) => Route::Resident(tx.clone()),
            None => Route::Failed { until, error: error.clone() },
        };
        if let Route::Loading(q) = std::mem::replace(&mut *route, next) {
            for r in q {
                match &fallback {
                    Some(tx) => {
                        let _ = tx.send(r);
                    }
                    None => reject_retry(
                        &entry.stats,
                        &r,
                        &format!("model failed to load: {error}"),
                    ),
                }
            }
        }
    }

    /// Bring a cold model's engine up and flush its queued requests.
    fn load(&mut self, idx: usize) {
        let entry = self.entry(idx);
        let name = entry.name.clone();
        let dir = match &entry.dir {
            Some(d) => d.clone(),
            None => return, // pinned engines are adopted, never loaded
        };
        self.load_delay();
        let loaded = probe(&dir).and_then(|(resolved, meta)| {
            let engine =
                Engine::load_with_mode(&resolved, self.shared.opts.packed_compute)?;
            Ok((resolved, meta, engine))
        });
        let (resolved, meta, mut engine) = match loaded {
            Ok(l) => l,
            Err(e) => {
                warn!("model {name}: load failed: {e:#}");
                self.fail_loading(&entry, None, format!("{e:#}"));
                return;
            }
        };
        self.evict_over_budget(idx);
        let store = match self.parked.remove(&idx) {
            Some(s) => s,
            None => match SessionStore::new(store_opts_for(&self.shared.opts, &name)) {
                Ok(s) => s,
                Err(e) => {
                    warn!("model {name}: session store failed: {e:#}");
                    self.fail_loading(&entry, None, format!("{e:#}"));
                    return;
                }
            },
        };
        entry
            .obs
            .set_weight_bytes(engine.weight_bytes() as u64, engine.compute_mode());
        hook_outliers(&self.shared.opts, &mut engine, &entry.obs);
        let batcher = spawn_batcher(
            &self.shared.opts,
            engine,
            store,
            entry.stats.clone(),
            entry.obs.clone(),
        );
        info!(
            "model {name}: loaded {} (generation {}, step {})",
            resolved.display(),
            meta.generation,
            meta.step
        );
        self.install(idx, &entry, batcher, resolved, meta);
        self.shared.model_loads.fetch_add(1, Ordering::Relaxed);
    }

    /// Swap a resident model onto a republished checkpoint. Load-the-
    /// new-first ordering: a failed load keeps serving the old weights
    /// (warned, retried at the next probe window) instead of leaving
    /// the model down.
    fn reload(&mut self, idx: usize) {
        let entry = self.entry(idx);
        let name = entry.name.clone();
        let Some(old) = self.batchers.get(&idx).map(|b| b.submitter()) else {
            // engine went away since the probe (evicted): plain load
            self.load(idx);
            return;
        };
        let dir = entry.dir.clone().expect("reloads require a watched dir");
        self.load_delay();
        let loaded = probe(&dir).and_then(|(resolved, meta)| {
            let engine =
                Engine::load_with_mode(&resolved, self.shared.opts.packed_compute)?;
            Ok((resolved, meta, engine))
        });
        let (resolved, meta, mut engine) = match loaded {
            Ok(l) => l,
            Err(e) => {
                warn!(
                    "model {name}: republished checkpoint failed to load \
                     ({e:#}); serving previous generation"
                );
                self.fail_loading(&entry, Some(old), format!("{e:#}"));
                return;
            }
        };
        // drain the old engine (in-flight generations finish on the old
        // weights), then move its session store under the new one
        let (store, leftovers) = self
            .batchers
            .remove(&idx)
            .expect("submitter probed above")
            .shutdown();
        let store = match store {
            Some(s) => s,
            None => match SessionStore::new(store_opts_for(&self.shared.opts, &name)) {
                Ok(s) => s,
                Err(e) => {
                    warn!("model {name}: session store lost in reload: {e:#}");
                    for r in leftovers {
                        reject_retry(&entry.stats, &r, "model reload failed");
                    }
                    self.fail_loading(&entry, None, format!("{e:#}"));
                    return;
                }
            },
        };
        entry
            .obs
            .set_weight_bytes(engine.weight_bytes() as u64, engine.compute_mode());
        hook_outliers(&self.shared.opts, &mut engine, &entry.obs);
        let batcher = spawn_batcher(
            &self.shared.opts,
            engine,
            store,
            entry.stats.clone(),
            entry.obs.clone(),
        );
        // queued-but-unadmitted requests continue on the new weights,
        // ahead of anything that queued during the swap
        for r in leftovers {
            let _ = batcher.submitter().send(r);
        }
        let prev = {
            let ms = entry.meta.lock().expect("registry poisoned");
            ms.loaded.as_ref().map(|l| l.generation).unwrap_or(0)
        };
        info!(
            "model {name}: hot-reloaded {} (generation {} -> {}, step {})",
            resolved.display(),
            prev,
            meta.generation,
            meta.step
        );
        self.install(idx, &entry, batcher, resolved, meta);
        self.shared.model_reloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Publish a freshly spawned engine: update identity, take ownership
    /// of the handle, and flush everything queued while it came up.
    fn install(
        &mut self,
        idx: usize,
        entry: &ModelEntry,
        batcher: RequestBatcher,
        resolved: PathBuf,
        meta: CheckpointMeta,
    ) {
        {
            let mut ms = entry.meta.lock().expect("registry poisoned");
            ms.loaded =
                Some(LoadedFrom { resolved, generation: meta.generation });
            ms.meta = meta;
            ms.next_probe = Instant::now()
                + Duration::from_millis(self.shared.opts.reload_poll_ms);
        }
        let tx = batcher.submitter();
        self.batchers.insert(idx, batcher);
        let mut route = entry.route.lock().expect("registry poisoned");
        if let Route::Loading(q) = std::mem::replace(&mut *route, Route::Resident(tx.clone()))
        {
            for r in q {
                let _ = tx.send(r);
            }
        }
    }

    /// Unload LRU victims while over the `max_resident_models` budget
    /// (called after a successful load, so a broken checkpoint never
    /// churns a healthy model out of residency).
    fn evict_over_budget(&mut self, keep: usize) {
        let budget = self.shared.opts.max_resident_models;
        if budget == 0 {
            return;
        }
        while self.batchers.len() >= budget {
            let snap = self.shared.snapshot.read().expect("registry poisoned").clone();
            // victim: least-recently-used resident model that *can* be
            // reloaded later (has a backing dir), is not mid-lifecycle
            // (route must read Resident), and is not the one loading
            let victim = self
                .batchers
                .keys()
                .copied()
                .filter(|&i| i != keep)
                .filter(|&i| snap[i].dir.is_some() && snap[i].resident())
                .min_by_key(|&i| snap[i].last_used.load(Ordering::SeqCst));
            let Some(v) = victim else {
                break; // everything resident is pinned; stay over budget
            };
            self.unload(v, &snap[v]);
        }
    }

    /// Drain and drop one resident engine, parking its session store.
    /// Requests still queued complete with the retryable contract — no
    /// replacement engine exists to take them (unlike a hot reload), and
    /// resurrecting the model we were asked to evict would thrash.
    fn unload(&mut self, idx: usize, entry: &ModelEntry) {
        {
            // flip the route first so racing submits queue on the entry
            // (next Load cmd) instead of into the dying channel; anything
            // already in the channel comes back in `leftovers` below
            let mut route = entry.route.lock().expect("registry poisoned");
            match &*route {
                // a racing submit's republish probe already flipped this
                // entry to Loading and queued a Cmd::Reload: keep the
                // queue — that pending pass finds no batcher, falls back
                // to a plain load, and install() flushes the queue, so
                // nothing queued is ever dropped
                Route::Loading(_) => {}
                _ => *route = Route::Cold,
            }
        }
        let Some(batcher) = self.batchers.remove(&idx) else {
            return;
        };
        let (store, leftovers) = batcher.shutdown();
        if let Some(s) = store {
            self.parked.insert(idx, s);
        }
        for r in leftovers {
            reject_retry(
                &entry.stats,
                &r,
                &format!(
                    "model {} was unloaded under --max-resident-models",
                    entry.name
                ),
            );
        }
        info!("model {}: unloaded (LRU)", entry.name);
        self.shared.model_unloads.fetch_add(1, Ordering::Relaxed);
    }

    /// Timer-driven republish probe over every resident watched model —
    /// the piece that lets an *idle* model pick up a new generation.
    fn tick(&mut self) {
        let snap = self.shared.snapshot.read().expect("registry poisoned").clone();
        let now = Instant::now();
        let poll = Duration::from_millis(self.shared.opts.reload_poll_ms);
        for (idx, entry) in snap.iter().enumerate() {
            if entry.dir.is_none() || !self.batchers.contains_key(&idx) {
                continue;
            }
            let loaded = {
                let mut ms = entry.meta.lock().expect("registry poisoned");
                if now < ms.next_probe {
                    continue;
                }
                ms.next_probe = now + poll;
                match &ms.loaded {
                    Some(l) => l.clone(),
                    None => continue,
                }
            };
            let dir = entry.dir.as_ref().expect("checked above");
            let (resolved, meta) = match probe(dir) {
                Ok(p) => p,
                Err(e) => {
                    warn!(
                        "model {}: checkpoint probe failed ({e:#}); serving \
                         current weights",
                        entry.name
                    );
                    continue;
                }
            };
            if (LoadedFrom { resolved, generation: meta.generation }) == loaded {
                continue;
            }
            // flip to Loading so requests queue for the new weights,
            // then swap inline (we ARE the lifecycle thread)
            {
                let mut route = entry.route.lock().expect("registry poisoned");
                match &*route {
                    Route::Resident(_) => *route = Route::Loading(Vec::new()),
                    _ => continue, // already mid-lifecycle
                }
            }
            self.reload(idx);
        }
    }

    /// Final drain: every queued request resolves retryably.
    fn drain_all(&mut self) {
        let snap = self.shared.snapshot.read().expect("registry poisoned").clone();
        let idxs: Vec<usize> = self.batchers.keys().copied().collect();
        for idx in idxs {
            if let Some(batcher) = self.batchers.remove(&idx) {
                let (_store, leftovers) = batcher.shutdown();
                for r in leftovers {
                    reject_retry(&snap[idx].stats, &r, RETRY_SHUTDOWN);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::Tokenizer;
    use crate::runtime::native::model::{init_params, model_cfg};
    use crate::runtime::native::recipe::recipe;
    use crate::serve::batcher::ReplySink;
    use std::sync::atomic::AtomicBool;
    use std::sync::mpsc::channel;
    use std::sync::Arc;

    fn test_engine(seed: u64) -> Engine {
        let cfg = model_cfg("tiny_gla").unwrap();
        let params = init_params(&cfg, seed);
        Engine::from_parts(
            cfg,
            recipe("chon").unwrap(),
            Tokenizer::byte_level(),
            &params,
        )
    }

    fn greedy(reg: &ModelRegistry, model: Option<&str>, prompt: &str) -> Vec<u8> {
        let (tx, rx) = channel();
        reg.submit(
            model,
            GenRequest {
                prompt: prompt.into(),
                max_tokens: 6,
                temp: 0.0,
                session: None,
                reply: ReplySink::channel(tx),
                cancel: Arc::new(AtomicBool::new(false)),
                queued_at: Instant::now(),
            },
        )
        .unwrap();
        let mut bytes = Vec::new();
        loop {
            match rx.recv_timeout(Duration::from_secs(30)).expect("event") {
                TokenEvent::Token(p) => bytes.extend(p),
                TokenEvent::Done { .. } => return bytes,
                TokenEvent::Error(e) => panic!("unexpected error: {e}"),
                TokenEvent::Retry(e) => panic!("unexpected retry: {e}"),
            }
        }
    }

    #[test]
    fn preloaded_engines_route_by_name_and_reject_unknown() {
        let mut reg = ModelRegistry::new(RegistryOpts::default());
        reg.register_engine("alpha", test_engine(3)).unwrap();
        reg.register_engine("beta", test_engine(4)).unwrap();
        assert_eq!(reg.model_names(), vec!["alpha", "beta"]);

        let a = greedy(&reg, Some("alpha"), "hello ");
        let d = greedy(&reg, None, "hello ");
        assert_eq!(a, d, "default must route to the first registered model");

        let (tx, _rx) = channel();
        let err = reg
            .submit(
                Some("nope"),
                GenRequest {
                    prompt: "x".into(),
                    max_tokens: 1,
                    temp: 0.0,
                    session: None,
                    reply: ReplySink::channel(tx),
                    cancel: Arc::new(AtomicBool::new(false)),
                    queued_at: Instant::now(),
                },
            )
            .unwrap_err();
        assert!(matches!(err, SubmitError::UnknownModel(_)), "{err}");
        reg.shutdown();
    }

    #[test]
    fn duplicate_and_invalid_names_rejected() {
        let mut reg = ModelRegistry::new(RegistryOpts::default());
        reg.register_engine("a", test_engine(1)).unwrap();
        assert!(reg.register_engine("a", test_engine(2)).is_err());
        assert!(reg
            .register("bad/name", Path::new("/nonexistent"))
            .is_err());
        assert!(reg.register("ok", Path::new("/nonexistent")).is_err());
        reg.shutdown();
    }

    #[test]
    fn stats_line_aggregates_models() {
        let mut reg = ModelRegistry::new(RegistryOpts::default());
        reg.register_engine("a", test_engine(1)).unwrap();
        reg.register_engine("b", test_engine(2)).unwrap();
        greedy(&reg, Some("a"), "one ");
        greedy(&reg, Some("b"), "two ");
        // counters are synced by the engine threads after Done; both
        // requests completed, so requests= must already read 2
        let line = reg.stats_line();
        assert!(line.contains("requests=2"), "{line}");
        assert!(line.contains("models=2"), "{line}");
        assert!(line.contains("resident_models=2"), "{line}");
        let json = reg.stats_json();
        let per = json.get("per_model").expect("per_model present");
        assert!(per.get("a").is_some(), "{}", json.render());
        assert!(per.get("b").is_some(), "{}", json.render());
        assert_eq!(json.get("models").and_then(|v| v.as_f64()), Some(2.0));
        reg.shutdown();
    }
}
