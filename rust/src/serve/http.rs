//! A hand-rolled HTTP/1.1 front end layer: request parsing + response
//! writing, no dependencies beyond std.
//!
//! The parser is *incremental*: `parse_request(buf)` inspects however
//! many bytes have arrived so far and either produces a complete request
//! (plus how many bytes it consumed, so pipelined requests keep working),
//! asks for more data, or rejects the stream. It survives partial reads
//! split at any byte boundary — `tests/serve_protocol_fuzz.rs` feeds it
//! every split point and random garbage.
//!
//! Scope (all the serve front end needs, nothing more):
//! * methods GET / POST / HEAD; request-URI up to `MAX_TARGET_BYTES`
//! * headers up to `MAX_HEAD_BYTES` total; `Content-Length` bodies up to
//!   `MAX_BODY_BYTES` (chunked *request* bodies are rejected with 501)
//! * responses: fixed-length or `Transfer-Encoding: chunked` streaming
//!   (the `POST /generate` token stream)

use std::io::Write;

/// Total cap on the request line + headers.
pub const MAX_HEAD_BYTES: usize = 8 * 1024;
/// Cap on the request-URI.
pub const MAX_TARGET_BYTES: usize = 1024;
/// Cap on a request body (`POST /generate` JSON is tiny).
pub const MAX_BODY_BYTES: usize = 64 * 1024;

/// One parsed HTTP request.
#[derive(Clone, Debug, PartialEq)]
pub struct HttpRequest {
    pub method: String,
    /// the raw request-target (path + optional query), e.g. "/stats"
    pub target: String,
    /// true for HTTP/1.0 requests — those cannot receive chunked
    /// responses, so streaming endpoints must reject them
    pub http10: bool,
    /// header (name, value) pairs; names lower-cased
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    /// First value of a header (names are stored lower-cased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let want = name.to_ascii_lowercase();
        self.headers
            .iter()
            .find(|(n, _)| *n == want)
            .map(|(_, v)| v.as_str())
    }

    /// True when the client asked to close the connection.
    pub fn wants_close(&self) -> bool {
        self.header("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false)
    }
}

/// Outcome of one incremental parse attempt.
pub enum Parsed {
    /// A complete request and the number of bytes it consumed from the
    /// front of the buffer (drain them before the next attempt).
    Complete(HttpRequest, usize),
    /// Not enough bytes yet — read more and retry.
    Partial,
}

/// HTTP-level rejection: status + message (the handler answers it and
/// closes the connection).
#[derive(Clone, Debug, PartialEq)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

fn err(status: u16, message: impl Into<String>) -> HttpError {
    HttpError { status, message: message.into() }
}

/// Find the end of the header section. Accepts CRLFCRLF (HTTP) and bare
/// LFLF (hand-typed clients); returns (headers_end, body_start).
fn find_head_end(buf: &[u8]) -> Option<(usize, usize)> {
    let mut i = 0;
    while i < buf.len() {
        if buf[i] == b'\n' {
            if buf.get(i + 1) == Some(&b'\n') {
                return Some((i, i + 2));
            }
            if buf.get(i + 1) == Some(&b'\r') && buf.get(i + 2) == Some(&b'\n') {
                return Some((i, i + 3));
            }
        }
        i += 1;
    }
    None
}

fn is_token_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric()
        || matches!(
            b,
            b'!' | b'#'
                | b'$'
                | b'%'
                | b'&'
                | b'\''
                | b'*'
                | b'+'
                | b'-'
                | b'.'
                | b'^'
                | b'_'
                | b'`'
                | b'|'
                | b'~'
        )
}

/// Incrementally parse one request from the front of `buf`.
pub fn parse_request(buf: &[u8]) -> Result<Parsed, HttpError> {
    let Some((head_end, body_start)) = find_head_end(buf) else {
        if buf.len() > MAX_HEAD_BYTES {
            return Err(err(431, "request head too large"));
        }
        return Ok(Parsed::Partial);
    };
    if head_end > MAX_HEAD_BYTES {
        return Err(err(431, "request head too large"));
    }
    let head = std::str::from_utf8(&buf[..head_end])
        .map_err(|_| err(400, "request head is not valid UTF-8"))?;
    let mut lines = head.split('\n').map(|l| l.trim_end_matches('\r'));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split(' ').filter(|p| !p.is_empty());
    let method = parts.next().unwrap_or("").to_string();
    let target = parts.next().unwrap_or("").to_string();
    let version = parts.next().unwrap_or("");
    if parts.next().is_some() {
        return Err(err(400, "malformed request line"));
    }
    if method.is_empty() || !method.bytes().all(is_token_byte) {
        return Err(err(400, "malformed method"));
    }
    if !matches!(method.as_str(), "GET" | "POST" | "HEAD") {
        return Err(err(405, format!("method {method} not supported")));
    }
    if target.is_empty() || !target.starts_with('/') {
        return Err(err(400, "malformed request target"));
    }
    if target.len() > MAX_TARGET_BYTES {
        return Err(err(414, "request target too long"));
    }
    if !matches!(version, "HTTP/1.1" | "HTTP/1.0") {
        return Err(err(505, format!("unsupported version {version:?}")));
    }

    let mut headers: Vec<(String, String)> = Vec::new();
    for line in lines {
        if line.is_empty() {
            continue;
        }
        let Some((name, value)) = line.split_once(':') else {
            return Err(err(400, format!("malformed header line {line:?}")));
        };
        let name = name.trim();
        if name.is_empty() || !name.bytes().all(is_token_byte) {
            return Err(err(400, format!("malformed header name {name:?}")));
        }
        headers.push((name.to_ascii_lowercase(), value.trim().to_string()));
    }

    if headers.iter().any(|(n, _)| n == "transfer-encoding") {
        return Err(err(501, "chunked request bodies not supported"));
    }
    let content_length = match headers.iter().find(|(n, _)| n == "content-length")
    {
        None => 0usize,
        Some((_, v)) => v
            .parse::<usize>()
            .map_err(|_| err(400, format!("bad Content-Length {v:?}")))?,
    };
    if content_length > MAX_BODY_BYTES {
        return Err(err(413, "request body too large"));
    }
    if buf.len() < body_start + content_length {
        return Ok(Parsed::Partial);
    }
    let body = buf[body_start..body_start + content_length].to_vec();
    let http10 = version == "HTTP/1.0";
    Ok(Parsed::Complete(
        HttpRequest { method, target, http10, headers, body },
        body_start + content_length,
    ))
}

fn status_reason(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        413 => "Payload Too Large",
        414 => "URI Too Long",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        503 => "Service Unavailable",
        505 => "HTTP Version Not Supported",
        _ => "Response",
    }
}

/// Write a complete fixed-length response. `head_only` (HEAD requests)
/// sends the headers with the real Content-Length but no body. Generic
/// over the sink: the threaded front end wrote straight to a
/// `TcpStream`; the epoll reactor renders into a connection's
/// in-memory out-buffer and lets readiness events drain it.
pub fn write_response<W: Write>(
    stream: &mut W,
    status: u16,
    content_type: &str,
    body: &[u8],
    head_only: bool,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\n\r\n",
        status,
        status_reason(status),
        content_type,
        body.len()
    );
    stream.write_all(head.as_bytes())?;
    if !head_only {
        stream.write_all(body)?;
    }
    stream.flush()
}

/// Start a chunked streaming response (the `POST /generate` token feed).
pub fn write_chunked_head<W: Write>(
    stream: &mut W,
    status: u16,
    content_type: &str,
) -> std::io::Result<()> {
    let head = format!(
        "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nTransfer-Encoding: chunked\r\n\r\n",
        status,
        status_reason(status),
        content_type
    );
    stream.write_all(head.as_bytes())?;
    stream.flush()
}

/// Emit one chunk (empty input is skipped — a zero-size chunk would
/// terminate the stream).
pub fn write_chunk<W: Write>(stream: &mut W, data: &[u8]) -> std::io::Result<()> {
    if data.is_empty() {
        return Ok(());
    }
    write!(stream, "{:x}\r\n", data.len())?;
    stream.write_all(data)?;
    stream.write_all(b"\r\n")?;
    stream.flush()
}

/// Terminate a chunked response.
pub fn finish_chunks<W: Write>(stream: &mut W) -> std::io::Result<()> {
    stream.write_all(b"0\r\n\r\n")?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse_full(raw: &[u8]) -> HttpRequest {
        match parse_request(raw).unwrap() {
            Parsed::Complete(req, consumed) => {
                assert_eq!(consumed, raw.len());
                req
            }
            Parsed::Partial => panic!("expected a complete request"),
        }
    }

    #[test]
    fn parses_get_and_post() {
        let req = parse_full(b"GET /stats HTTP/1.1\r\nHost: x\r\n\r\n");
        assert_eq!(req.method, "GET");
        assert_eq!(req.target, "/stats");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.body.is_empty());
        assert!(!req.http10);

        let raw =
            b"POST /generate HTTP/1.1\r\nContent-Length: 4\r\n\r\n{\"a\"";
        let req = parse_full(raw);
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"{\"a\"");
    }

    #[test]
    fn tolerates_bare_lf_heads() {
        let req = parse_full(b"GET / HTTP/1.0\nHost: y\n\n");
        assert_eq!(req.target, "/");
        assert_eq!(req.header("host"), Some("y"));
        assert!(req.http10, "1.0 must be flagged for streaming endpoints");
    }

    #[test]
    fn partial_until_complete() {
        let raw = b"POST /g HTTP/1.1\r\nContent-Length: 5\r\n\r\nhello";
        for cut in 0..raw.len() {
            match parse_request(&raw[..cut]) {
                Ok(Parsed::Partial) => {}
                other => panic!(
                    "prefix of {cut} bytes should be partial, got {:?}",
                    other.err()
                ),
            }
        }
        let req = parse_full(raw);
        assert_eq!(req.body, b"hello");
    }

    #[test]
    fn pipelined_requests_consume_exactly_one() {
        let one = b"GET /a HTTP/1.1\r\n\r\n".to_vec();
        let mut two = one.clone();
        two.extend_from_slice(b"GET /b HTTP/1.1\r\n\r\n");
        match parse_request(&two).unwrap() {
            Parsed::Complete(req, consumed) => {
                assert_eq!(req.target, "/a");
                assert_eq!(consumed, one.len());
                match parse_request(&two[consumed..]).unwrap() {
                    Parsed::Complete(req2, c2) => {
                        assert_eq!(req2.target, "/b");
                        assert_eq!(consumed + c2, two.len());
                    }
                    Parsed::Partial => panic!("second request lost"),
                }
            }
            Parsed::Partial => panic!("first request lost"),
        }
    }

    #[test]
    fn rejects_malformed_and_oversized() {
        let cases: Vec<(Vec<u8>, u16)> = vec![
            (b"BREW /pot HTTP/1.1\r\n\r\n".to_vec(), 405),
            (b"GET stats HTTP/1.1\r\n\r\n".to_vec(), 400),
            (b"GET /x SPDY/9\r\n\r\n".to_vec(), 505),
            (b"GET / HTTP/1.1 extra\r\n\r\n".to_vec(), 400),
            (b"GET / HTTP/1.1\r\nBad Header\r\n\r\n".to_vec(), 400),
            (
                b"POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n".to_vec(),
                400,
            ),
            (
                b"POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n"
                    .to_vec(),
                501,
            ),
            (
                format!(
                    "POST / HTTP/1.1\r\nContent-Length: {}\r\n\r\n",
                    MAX_BODY_BYTES + 1
                )
                .into_bytes(),
                413,
            ),
            (
                format!("GET /{} HTTP/1.1\r\n\r\n", "a".repeat(MAX_TARGET_BYTES))
                    .into_bytes(),
                414,
            ),
        ];
        for (raw, want) in cases {
            match parse_request(&raw) {
                Err(e) => assert_eq!(
                    e.status,
                    want,
                    "{:?} -> {}",
                    String::from_utf8_lossy(&raw[..raw.len().min(40)]),
                    e.message
                ),
                Ok(_) => panic!(
                    "{:?} should be rejected",
                    String::from_utf8_lossy(&raw[..raw.len().min(40)])
                ),
            }
        }
        // an endless header section trips the size cap instead of hanging
        let mut huge = b"GET / HTTP/1.1\r\n".to_vec();
        while huge.len() <= MAX_HEAD_BYTES {
            huge.extend_from_slice(b"X-Pad: yada yada yada\r\n");
        }
        assert_eq!(parse_request(&huge).unwrap_err().status, 431);
    }
}
