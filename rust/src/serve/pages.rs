//! Paged session-state storage + the LRU session cache behind serve v2.
//!
//! * `KvPages` — an SA layer's K/V cache split into fixed-size pages of
//!   `PAGE_TOKENS` positions each, so a growing context never reallocates
//!   (and never memmoves) the whole cache; positions keep their exact
//!   append order, so iterating pages front-to-back visits the same f32
//!   sequence a flat buffer would — paged attention is *bitwise* the
//!   math of unpaged attention.
//! * `SessionStore` — keeps idle named sessions resident up to
//!   `--max-resident-sessions` / `--max-kv-tokens`, evicting
//!   least-recently-used sessions to a spill directory (bit-exact
//!   little-endian f32 serialization, see `Session::serialize`) and
//!   reloading them transparently on the session's next request.
//!
//! Eviction and reload are invisible to generation output: the serialized
//! form round-trips every f32 bit-exactly, and the invariant suite
//! (`tests/serve_invariants.rs`) pins greedy outputs across
//! resident/evicted/reloaded histories.

use std::collections::{HashMap, HashSet};
use std::path::PathBuf;

use anyhow::{Context, Result};

use crate::serve::engine::{Engine, Session};
use crate::serve::protocol;

/// Positions per KV page. Small enough that short sessions stay cheap,
/// large enough that the per-page bookkeeping is negligible next to the
/// d-wide dot products over its rows.
pub const PAGE_TOKENS: usize = 32;

/// One SA layer's K/V cache as fixed-capacity pages.
pub struct KvPages {
    /// row width (the model d)
    d: usize,
    /// each page holds up to PAGE_TOKENS rows of k and v (row-major)
    pages: Vec<Page>,
    /// total rows stored across pages
    rows: usize,
}

struct Page {
    k: Vec<f32>,
    v: Vec<f32>,
}

impl KvPages {
    pub fn new(d: usize) -> KvPages {
        KvPages { d, pages: Vec::new(), rows: 0 }
    }

    /// Number of cached positions.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Append one position's k/v rows (each exactly `d` floats).
    pub fn push(&mut self, k_row: &[f32], v_row: &[f32]) {
        debug_assert_eq!(k_row.len(), self.d);
        debug_assert_eq!(v_row.len(), self.d);
        let need_new = match self.pages.last() {
            Some(p) => p.k.len() == PAGE_TOKENS * self.d,
            None => true,
        };
        if need_new {
            let cap = PAGE_TOKENS * self.d;
            self.pages.push(Page {
                k: Vec::with_capacity(cap),
                v: Vec::with_capacity(cap),
            });
        }
        let p = self.pages.last_mut().unwrap();
        p.k.extend_from_slice(k_row);
        p.v.extend_from_slice(v_row);
        self.rows += 1;
    }

    /// Visit every cached position in append order as (k_row, v_row).
    /// The iteration order (and therefore every accumulation chain built
    /// over it) is identical to a flat buffer's.
    pub fn for_each_row(&self, mut f: impl FnMut(&[f32], &[f32])) {
        let d = self.d;
        for p in &self.pages {
            let n = p.k.len() / d;
            for r in 0..n {
                f(&p.k[r * d..(r + 1) * d], &p.v[r * d..(r + 1) * d]);
            }
        }
    }

    /// Flatten the k rows (serialization only — the hot path never does
    /// this).
    pub fn flat_k(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.d);
        for p in &self.pages {
            out.extend_from_slice(&p.k);
        }
        out
    }

    /// Flatten the v rows (serialization only).
    pub fn flat_v(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.rows * self.d);
        for p in &self.pages {
            out.extend_from_slice(&p.v);
        }
        out
    }

    /// Rebuild from flat rows (deserialization). Page boundaries are a
    /// pure function of the row count, so an evict→reload cycle
    /// reconstructs the identical page layout.
    pub fn from_flat(d: usize, k: &[f32], v: &[f32]) -> KvPages {
        assert_eq!(k.len(), v.len());
        assert_eq!(k.len() % d.max(1), 0);
        let mut pages = KvPages::new(d);
        let rows = if d == 0 { 0 } else { k.len() / d };
        for r in 0..rows {
            pages.push(&k[r * d..(r + 1) * d], &v[r * d..(r + 1) * d]);
        }
        pages
    }
}

/// Resident/spill policy knobs of the session cache.
#[derive(Clone, Debug, Default)]
pub struct StoreOpts {
    /// max idle sessions kept in memory (0 = unlimited)
    pub max_resident_sessions: usize,
    /// max total KV positions resident across idle sessions (0 = unlimited)
    pub max_kv_tokens: usize,
    /// spill directory; None = a per-process temp dir, removed on drop
    pub spill_dir: Option<PathBuf>,
}

/// The named-session cache: resident map + spill directory + LRU clock.
pub struct SessionStore {
    opts: StoreOpts,
    dir: PathBuf,
    /// true when `dir` was auto-created under temp and should be removed
    own_dir: bool,
    resident: HashMap<String, (Session, u64)>,
    /// ids currently spilled to disk
    spilled: HashSet<String>,
    /// running Σ kv_cost_tokens over `resident` — kept incrementally so
    /// budget checks and gauge reads stay O(1) at thousands of sessions
    resident_kv: usize,
    clock: u64,
    /// cumulative counters (mirrored into ServeStats by the engine loop)
    pub evictions: u64,
    pub reloads: u64,
}

impl SessionStore {
    pub fn new(opts: StoreOpts) -> Result<SessionStore> {
        // auto spill dirs are unique per store instance (pid + counter),
        // so concurrent servers in one process never share or delete
        // each other's spill files
        static STORE_SEQ: std::sync::atomic::AtomicU64 =
            std::sync::atomic::AtomicU64::new(0);
        let (dir, own_dir) = match &opts.spill_dir {
            Some(d) => (d.clone(), false),
            None => {
                let seq = STORE_SEQ
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                (
                    std::env::temp_dir().join(format!(
                        "chon_spill_{}_{seq}",
                        std::process::id()
                    )),
                    true,
                )
            }
        };
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating spill dir {}", dir.display()))?;
        // A user-supplied spill dir may already hold session files from a
        // previous process that died without Drop (SIGKILL, OOM): adopt
        // them, or a named session that was spilled before the crash would
        // silently restart from scratch after the server comes back on the
        // same checkpoint. Only protocol-valid ids are adopted — anything
        // else in the directory is not ours to own. Auto temp dirs are
        // freshly created per store, so there is nothing to scan.
        let mut spilled = HashSet::new();
        if !own_dir {
            for entry in std::fs::read_dir(&dir)
                .with_context(|| format!("scanning spill dir {}", dir.display()))?
            {
                let path = entry?.path();
                if path.extension().and_then(|e| e.to_str()) != Some("sess") {
                    continue;
                }
                let Some(id) = path.file_stem().and_then(|s| s.to_str()) else {
                    continue;
                };
                if protocol::valid_session_id(id) {
                    spilled.insert(id.to_string());
                }
            }
        }
        Ok(SessionStore {
            opts,
            dir,
            own_dir,
            resident: HashMap::new(),
            spilled,
            resident_kv: 0,
            clock: 0,
            evictions: 0,
            reloads: 0,
        })
    }

    fn spill_path(&self, id: &str) -> PathBuf {
        // ids are protocol-validated ([A-Za-z0-9._-], no leading dot), so
        // the join cannot escape the spill dir
        self.dir.join(format!("{id}.sess"))
    }

    /// Check a session out for decoding. Resident sessions are removed
    /// from the cache (the engine loop owns them while active); spilled
    /// ones are reloaded bit-exactly from disk. Unknown ids return None
    /// (the caller starts a fresh session).
    pub fn take(&mut self, id: &str, engine: &Engine) -> Result<Option<Session>> {
        if let Some((sess, _)) = self.resident.remove(id) {
            self.resident_kv -= sess.kv_cost_tokens();
            return Ok(Some(sess));
        }
        if self.spilled.contains(id) {
            // the spill record and file survive until the restore has
            // fully succeeded — a transient read/validation failure must
            // not silently turn the next request into a fresh session
            let path = self.spill_path(id);
            let bytes = std::fs::read(&path)
                .with_context(|| format!("reading spilled session {}", path.display()))?;
            let sess = engine.restore_session(&bytes).with_context(|| {
                format!("restoring spilled session {}", path.display())
            })?;
            self.spilled.remove(id);
            let _ = std::fs::remove_file(&path);
            self.reloads += 1;
            return Ok(Some(sess));
        }
        Ok(None)
    }

    /// Check a session back in after its request finished, then enforce
    /// the residency limits (evicting LRU sessions to disk).
    pub fn put(&mut self, id: &str, sess: Session, engine: &Engine) -> Result<()> {
        self.clock += 1;
        self.resident_kv += sess.kv_cost_tokens();
        if let Some((old, _)) =
            self.resident.insert(id.to_string(), (sess, self.clock))
        {
            // same id checked in twice without a take — cannot happen via
            // the batcher (busy-session rejection), but keep the counter
            // honest anyway
            self.resident_kv -= old.kv_cost_tokens();
        }
        self.enforce(engine)
    }

    fn over_budget(&self) -> bool {
        (self.opts.max_resident_sessions > 0
            && self.resident.len() > self.opts.max_resident_sessions)
            || (self.opts.max_kv_tokens > 0
                && self.resident_kv > self.opts.max_kv_tokens)
    }

    fn enforce(&mut self, engine: &Engine) -> Result<()> {
        while !self.resident.is_empty() && self.over_budget() {
            // LRU victim = smallest clock stamp (ties impossible: the
            // clock is strictly increasing). The scan is O(resident),
            // which the residency limit itself bounds.
            let victim = self
                .resident
                .iter()
                .min_by_key(|(_, (_, stamp))| *stamp)
                .map(|(id, _)| id.clone())
                .expect("non-empty resident map");
            let (sess, stamp) = self.resident.remove(&victim).unwrap();
            self.resident_kv -= sess.kv_cost_tokens();
            let bytes = engine.serialize_session(&sess);
            let path = self.spill_path(&victim);
            if let Err(e) = std::fs::write(&path, bytes) {
                // spill failed (full/lost disk): put the victim back so
                // its state is NOT silently destroyed, and stop evicting
                // — staying over budget beats losing a conversation
                self.resident_kv += sess.kv_cost_tokens();
                self.resident.insert(victim.clone(), (sess, stamp));
                return Err(e).with_context(|| {
                    format!("spilling session {victim} to {}", path.display())
                });
            }
            self.spilled.insert(victim);
            self.evictions += 1;
        }
        Ok(())
    }

    /// Idle sessions currently held in memory.
    pub fn resident_len(&self) -> usize {
        self.resident.len()
    }

    /// Idle sessions currently spilled to disk.
    pub fn spilled_len(&self) -> usize {
        self.spilled.len()
    }

    /// Total KV positions held by resident idle sessions (O(1): kept as
    /// a running counter).
    pub fn resident_kv_tokens(&self) -> usize {
        self.resident_kv
    }
}

impl Drop for SessionStore {
    fn drop(&mut self) {
        if self.own_dir {
            let _ = std::fs::remove_dir_all(&self.dir);
        } else {
            // leave a user-chosen spill dir in place but drop our files
            for id in self.spilled.iter() {
                let _ = std::fs::remove_file(self.dir.join(format!("{id}.sess")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pages_preserve_append_order_and_layout() {
        let d = 3;
        let mut pg = KvPages::new(d);
        let rows = PAGE_TOKENS * 2 + 5; // spans three pages
        for r in 0..rows {
            let k: Vec<f32> = (0..d).map(|j| (r * d + j) as f32).collect();
            let v: Vec<f32> = k.iter().map(|x| -x).collect();
            pg.push(&k, &v);
        }
        assert_eq!(pg.rows(), rows);
        let mut seen = 0usize;
        pg.for_each_row(|k, v| {
            assert_eq!(k[0], (seen * d) as f32);
            assert_eq!(v[0], -((seen * d) as f32));
            seen += 1;
        });
        assert_eq!(seen, rows);
        // flat round-trip rebuilds the identical page layout
        let back = KvPages::from_flat(d, &pg.flat_k(), &pg.flat_v());
        assert_eq!(back.rows(), rows);
        assert_eq!(back.pages.len(), pg.pages.len());
        for (a, b) in back.pages.iter().zip(&pg.pages) {
            assert_eq!(a.k, b.k);
            assert_eq!(a.v, b.v);
        }
    }

    /// A user-supplied spill dir holding files from a SIGKILLed
    /// predecessor: the new store adopts valid `.sess` files (so named
    /// sessions resume after a crash-restart) and leaves foreign files
    /// alone.
    #[test]
    fn new_store_adopts_orphaned_spill_files() {
        let dir = std::env::temp_dir()
            .join(format!("chon_pages_rescan_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("abc.sess"), b"x").unwrap();
        std::fs::write(dir.join(".hidden.sess"), b"x").unwrap(); // invalid id
        std::fs::write(dir.join("notasess.txt"), b"x").unwrap();
        let store = SessionStore::new(StoreOpts {
            spill_dir: Some(dir.clone()),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(store.spilled_len(), 1);
        drop(store); // drops only the adopted file, not the foreign ones
        assert!(!dir.join("abc.sess").exists());
        assert!(dir.join(".hidden.sess").exists());
        assert!(dir.join("notasess.txt").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    /// An auto (temp) spill dir is fresh per store — nothing is scanned.
    #[test]
    fn auto_dir_starts_empty() {
        let store = SessionStore::new(StoreOpts::default()).unwrap();
        assert_eq!(store.spilled_len(), 0);
    }
}
