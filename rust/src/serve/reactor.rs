//! Minimal epoll reactor primitives for the serve front end.
//!
//! The serve layer needs exactly four OS facilities that `std` does not
//! expose: `epoll` readiness notification, an `eventfd` wakeup handle
//! (so the engine threads can nudge the reactor out of `epoll_wait`
//! when tokens arrive), and `getrlimit`/`setrlimit` so the
//! connection-scaling paths can raise the open-file ceiling. Rather
//! than pull in a bindings crate, this module declares the five
//! syscalls it needs directly — the ABI is stable, Linux-only, and the
//! constants are lifted from `<sys/epoll.h>` / `<sys/eventfd.h>` /
//! `<sys/resource.h>`.
//!
//! On top of the raw calls sit three small safe types used by
//! `serve::server`:
//!
//! - [`Poller`]: owns the epoll instance; register/modify/deregister
//!   fds with a `u64` token, and wait for readiness events.
//! - [`WakeFd`]: a nonblocking eventfd; `wake()` from any thread makes
//!   a concurrent or subsequent `Poller::wait` return immediately.
//! - [`TimerWheel`]: coarse bucketed deadlines (1 s granularity) for
//!   idle-connection eviction and generation-stall timeouts. Replaces
//!   the per-thread 200 ms read-timeout busy-poll loops of the
//!   threaded front end: an idle connection now costs zero CPU until
//!   its bucket comes due.

use std::io;
use std::os::fd::RawFd;
use std::time::{Duration, Instant};

pub const EPOLLIN: u32 = 0x1;
pub const EPOLLOUT: u32 = 0x4;
pub const EPOLLERR: u32 = 0x8;
pub const EPOLLHUP: u32 = 0x10;
/// peer shut down its write side — lets us see half-closed sockets
/// without a read() round trip
pub const EPOLLRDHUP: u32 = 0x2000;

const EPOLL_CTL_ADD: i32 = 1;
const EPOLL_CTL_DEL: i32 = 2;
const EPOLL_CTL_MOD: i32 = 3;
const EPOLL_CLOEXEC: i32 = 0x80000;
const EFD_NONBLOCK: i32 = 0x800;
const EFD_CLOEXEC: i32 = 0x80000;
const RLIMIT_NOFILE: i32 = 7;

/// `struct epoll_event`. x86_64 is the one Linux ABI where the kernel
/// expects the struct packed (no padding between `events` and `data`);
/// everywhere else natural alignment matches the kernel layout.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy, Default)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

#[repr(C)]
struct Rlimit {
    cur: u64,
    max: u64,
}

extern "C" {
    fn epoll_create1(flags: i32) -> i32;
    fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
    fn epoll_wait(
        epfd: i32,
        events: *mut EpollEvent,
        maxevents: i32,
        timeout_ms: i32,
    ) -> i32;
    fn eventfd(initval: u32, flags: i32) -> i32;
    fn close(fd: i32) -> i32;
    fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
    fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
    fn setrlimit(resource: i32, rlim: *const Rlimit) -> i32;
}

fn cvt(ret: i32) -> io::Result<i32> {
    if ret < 0 {
        Err(io::Error::last_os_error())
    } else {
        Ok(ret)
    }
}

/// Safe owner of one epoll instance.
pub struct Poller {
    epfd: RawFd,
}

impl Poller {
    pub fn new() -> io::Result<Poller> {
        let epfd = cvt(unsafe { epoll_create1(EPOLL_CLOEXEC) })?;
        Ok(Poller { epfd })
    }

    fn ctl(&self, op: i32, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        let mut ev = EpollEvent { events, data: token };
        let evp: *mut EpollEvent =
            if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
        cvt(unsafe { epoll_ctl(self.epfd, op, fd, evp) })?;
        Ok(())
    }

    /// Start watching `fd`; readiness events carry `token` back.
    pub fn add(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_ADD, fd, token, events)
    }

    /// Change the interest set of an already-watched `fd`.
    pub fn modify(&self, fd: RawFd, token: u64, events: u32) -> io::Result<()> {
        self.ctl(EPOLL_CTL_MOD, fd, token, events)
    }

    /// Stop watching `fd` (must still be open).
    pub fn del(&self, fd: RawFd) -> io::Result<()> {
        self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
    }

    /// Block up to `timeout_ms` (-1 = forever) for readiness events,
    /// filling `buf` from the front; returns how many fired. Retries
    /// transparently when a signal interrupts the wait.
    pub fn wait(&self, buf: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
        loop {
            let n = unsafe {
                epoll_wait(self.epfd, buf.as_mut_ptr(), buf.len() as i32, timeout_ms)
            };
            if n >= 0 {
                return Ok(n as usize);
            }
            let err = io::Error::last_os_error();
            if err.kind() != io::ErrorKind::Interrupted {
                return Err(err);
            }
        }
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        unsafe { close(self.epfd) };
    }
}

/// A nonblocking eventfd: cross-thread wakeup for the reactor. Engine
/// threads call `wake()` after posting into the token mailbox; the
/// reactor has the fd registered with `EPOLLIN` and calls `drain()`
/// when it fires. The eventfd is a counter, so any number of wakes
/// coalesce into one readiness event.
pub struct WakeFd {
    fd: RawFd,
}

impl WakeFd {
    pub fn new() -> io::Result<WakeFd> {
        let fd = cvt(unsafe { eventfd(0, EFD_NONBLOCK | EFD_CLOEXEC) })?;
        Ok(WakeFd { fd })
    }

    pub fn raw(&self) -> RawFd {
        self.fd
    }

    /// Nudge the reactor. Never blocks: if the counter is already
    /// saturated (EAGAIN) a wakeup is pending anyway.
    pub fn wake(&self) {
        let one: u64 = 1;
        unsafe { write(self.fd, &one as *const u64 as *const u8, 8) };
    }

    /// Reset the counter so the next `wake` re-arms readiness.
    pub fn drain(&self) {
        let mut buf = [0u8; 8];
        unsafe { read(self.fd, buf.as_mut_ptr(), 8) };
    }
}

impl Drop for WakeFd {
    fn drop(&mut self) {
        unsafe { close(self.fd) };
    }
}

/// Coarse hashed timer wheel: `SLOTS` buckets of `GRANULARITY` each.
///
/// `insert` drops a `(token, deadline)` into the bucket its deadline
/// falls in (deadlines past the horizon go in the furthest bucket and
/// are lazily re-bucketed when the cursor reaches them). `expire`
/// advances the cursor over elapsed buckets and returns every token
/// whose armed deadline has passed; the caller re-inserts tokens that
/// turn out to still be live (activity since arming), which keeps each
/// live timer present exactly once without needing removal support.
pub struct TimerWheel {
    buckets: Vec<Vec<(u64, Instant)>>,
    cursor: usize,
    /// wall position of the cursor's bucket boundary
    edge: Instant,
}

const SLOTS: usize = 64;
const GRANULARITY: Duration = Duration::from_secs(1);

impl TimerWheel {
    pub fn new(now: Instant) -> TimerWheel {
        TimerWheel {
            buckets: (0..SLOTS).map(|_| Vec::new()).collect(),
            cursor: 0,
            edge: now,
        }
    }

    /// Arm `token` to surface from `expire` once `deadline` passes.
    pub fn insert(&mut self, token: u64, deadline: Instant, now: Instant) {
        let ahead = deadline.saturating_duration_since(now);
        let slots = (ahead.as_secs_f64() / GRANULARITY.as_secs_f64()).ceil() as usize;
        let idx = (self.cursor + slots.min(SLOTS - 1)) % SLOTS;
        self.buckets[idx].push((token, deadline));
    }

    /// Sweep every bucket the cursor has passed; return expired tokens.
    pub fn expire(&mut self, now: Instant) -> Vec<u64> {
        let mut due = Vec::new();
        let mut elapsed = now.saturating_duration_since(self.edge);
        while elapsed >= GRANULARITY {
            let drained: Vec<(u64, Instant)> =
                std::mem::take(&mut self.buckets[self.cursor]);
            self.cursor = (self.cursor + 1) % SLOTS;
            self.edge += GRANULARITY;
            elapsed = now.saturating_duration_since(self.edge);
            for (token, deadline) in drained {
                if deadline <= now {
                    due.push(token);
                } else {
                    // horizon overflow or coarse rounding: re-bucket
                    self.insert(token, deadline, now);
                }
            }
        }
        due
    }

    /// Smallest useful `epoll_wait` timeout: one wheel granularity.
    pub fn tick_ms() -> i32 {
        GRANULARITY.as_millis() as i32
    }
}

/// Raise the process open-file soft limit toward `want` (clamped to the
/// hard limit); returns the resulting soft limit. Used by the
/// connection-scaling bench/smoke paths before opening 1k+ sockets.
pub fn raise_nofile_limit(want: u64) -> io::Result<u64> {
    let mut lim = Rlimit { cur: 0, max: 0 };
    cvt(unsafe { getrlimit(RLIMIT_NOFILE, &mut lim) })?;
    if lim.cur >= want {
        return Ok(lim.cur);
    }
    let new = Rlimit { cur: want.min(lim.max), max: lim.max };
    cvt(unsafe { setrlimit(RLIMIT_NOFILE, &new) })?;
    Ok(new.cur)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;
    use std::net::{TcpListener, TcpStream};
    use std::os::fd::AsRawFd;

    #[test]
    fn wake_fd_fires_and_drains() {
        let poller = Poller::new().unwrap();
        let wake = WakeFd::new().unwrap();
        poller.add(wake.raw(), 7, EPOLLIN).unwrap();
        let mut buf = [EpollEvent::default(); 8];

        // nothing armed yet: times out with no events
        assert_eq!(poller.wait(&mut buf, 0).unwrap(), 0);

        wake.wake();
        wake.wake(); // coalesces
        let n = poller.wait(&mut buf, 1000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(buf[0].data, 7);

        // level-triggered: still ready until drained
        assert_eq!(poller.wait(&mut buf, 0).unwrap(), 1);
        wake.drain();
        assert_eq!(poller.wait(&mut buf, 0).unwrap(), 0);
    }

    #[test]
    fn poller_sees_socket_readability() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = listener.local_addr().unwrap().port();
        let poller = Poller::new().unwrap();
        poller.add(listener.as_raw_fd(), 1, EPOLLIN).unwrap();

        let mut buf = [EpollEvent::default(); 8];
        assert_eq!(poller.wait(&mut buf, 0).unwrap(), 0, "no pending accept");

        let mut client = TcpStream::connect(("127.0.0.1", port)).unwrap();
        let n = poller.wait(&mut buf, 5000).unwrap();
        assert_eq!(n, 1);
        assert_eq!(buf[0].data, 1);

        // watch the accepted socket for data
        let (sock, _) = listener.accept().unwrap();
        sock.set_nonblocking(true).unwrap();
        poller.add(sock.as_raw_fd(), 2, EPOLLIN | EPOLLRDHUP).unwrap();
        client.write_all(b"x").unwrap();
        let n = poller.wait(&mut buf, 5000).unwrap();
        assert!(n >= 1);
        assert!((0..n).any(|i| buf[i].data == 2 && buf[i].events & EPOLLIN != 0));

        poller.del(sock.as_raw_fd()).unwrap();
    }

    #[test]
    fn timer_wheel_expires_and_rearms() {
        let t0 = Instant::now();
        let mut wheel = TimerWheel::new(t0);
        wheel.insert(1, t0 + Duration::from_secs(2), t0);
        wheel.insert(2, t0 + Duration::from_secs(200), t0); // past horizon

        // before anything elapses: nothing due
        assert!(wheel.expire(t0).is_empty());
        // 3 simulated seconds later: token 1 due, token 2 re-bucketed
        let t3 = t0 + Duration::from_secs(3);
        let due = wheel.expire(t3);
        assert_eq!(due, vec![1]);
        // far future: the past-horizon token eventually surfaces
        let t300 = t0 + Duration::from_secs(300);
        let due = wheel.expire(t300);
        assert_eq!(due, vec![2]);
        assert!(wheel.expire(t300 + Duration::from_secs(5)).is_empty());
    }

    #[test]
    fn nofile_limit_is_queryable() {
        // asking for a tiny target must never lower the current limit
        let cur = raise_nofile_limit(64).unwrap();
        assert!(cur >= 64);
    }
}
