//! The line-delimited serve wire protocol.
//!
//! Requests (one line each, LF-terminated):
//!
//! ```text
//! GEN <max_tokens> <temp>\t<escaped prompt>   generate; streams tokens back
//! SGEN <sid> <max_tokens> <temp>\t<prompt>    generate in named session <sid>
//! MODEL <name> GEN|SGEN ...                   route to a registered model
//!                                             (absent = the default model)
//! STATS                                       one-line server statistics
//! PING                                        liveness probe
//! SHUTDOWN                                    drain + stop the server
//! ```
//!
//! Responses:
//!
//! ```text
//! TOK <escaped piece>            one decoded token (streamed, in order)
//! DONE <n_tokens> <gen_ms>       generation finished
//! STATS <k>=<v> ...              statistics snapshot
//! PONG | BYE                     ping / shutdown acks
//! ERR <message>                  request-level failure
//! ERR retry: <reason>            retryable server-side rejection: the
//!                                request never ran (model unloaded /
//!                                reloading / server draining) and can
//!                                be resubmitted verbatim (HTTP: 503)
//! ```
//!
//! Prompt and token text travel escaped so the protocol stays strictly
//! line-delimited: `\\`, `\n`, `\r`, `\t` plus `\xNN` for every other
//! byte outside printable ASCII. Escaped text is pure ASCII; unescaping
//! restores the exact original byte sequence.

/// Hard caps enforced server-side (the tiny models trained at seq 32
/// have no use for book-length contexts; the caps bound per-session
/// KV-state growth). Shared by the TCP line protocol and the HTTP front
/// end so both surfaces reject identically.
pub const MAX_PROMPT_BYTES: usize = 4096;
pub const MAX_GEN_TOKENS: usize = 256;
pub const MAX_TEMP: f32 = 10.0;
/// Total context cap of one named session (prompts + generations across
/// all its requests) — the paged KV cache grows to at most this many
/// positions per session.
pub const MAX_SESSION_TOKENS: usize = 8192;
/// Length cap of a named-session id.
pub const MAX_SESSION_ID_LEN: usize = 64;

/// Marker prefixed to retryable `ERR` lines (`TokenEvent::Retry`): the
/// request never ran, so a client or router may resubmit it verbatim.
/// The HTTP front end maps the same events to status 503. Reasons are
/// plain printable ASCII, so the marker survives line-escaping intact.
pub const RETRY_PREFIX: &str = "retry: ";
/// Canonical retry reason used when a server drain rejects queued work.
pub const RETRY_SHUTDOWN: &str = "server shutting down";

/// Named-session ids double as spill file names, so the charset is
/// restricted: 1..=64 of [A-Za-z0-9._-], not starting with '.' or '-'.
pub fn valid_session_id(id: &str) -> bool {
    if id.is_empty() || id.len() > MAX_SESSION_ID_LEN {
        return false;
    }
    if id.starts_with('.') || id.starts_with('-') {
        return false;
    }
    id.bytes()
        .all(|b| b.is_ascii_alphanumeric() || matches!(b, b'.' | b'_' | b'-'))
}

/// Registry model names share the session-id rule: they appear as spill
/// subdirectory names and in single-token protocol fields, so the same
/// path-safe single-word charset applies.
pub fn valid_model_name(name: &str) -> bool {
    valid_session_id(name)
}

/// The GEN/SGEN request caps, shared with the HTTP front end.
pub fn validate_gen(
    max_tokens: usize,
    temp: f32,
    prompt: &str,
    session: Option<&str>,
) -> Result<(), String> {
    if max_tokens == 0 || max_tokens > MAX_GEN_TOKENS {
        return Err(format!("max_tokens must be in 1..={MAX_GEN_TOKENS}"));
    }
    if !(0.0..=MAX_TEMP).contains(&temp) {
        return Err(format!("temp must be in 0..={MAX_TEMP}"));
    }
    if prompt.len() > MAX_PROMPT_BYTES {
        return Err(format!(
            "prompt is {} bytes (limit {MAX_PROMPT_BYTES})",
            prompt.len()
        ));
    }
    if let Some(id) = session {
        if !valid_session_id(id) {
            return Err(format!(
                "bad session id {id:?} (want 1..={MAX_SESSION_ID_LEN} of \
                 [A-Za-z0-9._-], not starting with '.' or '-')"
            ));
        }
    }
    Ok(())
}

/// Escape arbitrary bytes into a single-line ASCII token. Byte-exact:
/// `unescape_bytes(escape_bytes(b)) == b` for any input, so streamed
/// token pieces survive even when a multi-byte character is split
/// across tokens.
pub fn escape_bytes(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len());
    for &b in bytes {
        match b {
            b'\\' => out.push_str("\\\\"),
            b'\n' => out.push_str("\\n"),
            b'\r' => out.push_str("\\r"),
            b'\t' => out.push_str("\\t"),
            0x20..=0x7e => out.push(b as char),
            _ => out.push_str(&format!("\\x{b:02x}")),
        }
    }
    out
}

/// Escape arbitrary text into a single-line ASCII token.
pub fn escape(s: &str) -> String {
    escape_bytes(s.as_bytes())
}

/// Invert `escape_bytes`. Unknown escapes are an error (a garbled line
/// must not silently decode to something else).
pub fn unescape_bytes(s: &str) -> Result<Vec<u8>, String> {
    let b = s.as_bytes();
    let mut out: Vec<u8> = Vec::with_capacity(b.len());
    let mut i = 0;
    while i < b.len() {
        if b[i] != b'\\' {
            out.push(b[i]);
            i += 1;
            continue;
        }
        let Some(&e) = b.get(i + 1) else {
            return Err("dangling backslash".into());
        };
        match e {
            b'\\' => out.push(b'\\'),
            b'n' => out.push(b'\n'),
            b'r' => out.push(b'\r'),
            b't' => out.push(b'\t'),
            b'x' => {
                let hex = b
                    .get(i + 2..i + 4)
                    .and_then(|h| std::str::from_utf8(h).ok())
                    .ok_or("truncated \\x escape")?;
                let v = u8::from_str_radix(hex, 16)
                    .map_err(|_| format!("bad \\x escape {hex:?}"))?;
                out.push(v);
                i += 2;
            }
            other => return Err(format!("unknown escape \\{}", other as char)),
        }
        i += 2;
    }
    Ok(out)
}

/// Invert `escape` for text payloads (prompts), which must be UTF-8.
pub fn unescape(s: &str) -> Result<String, String> {
    String::from_utf8(unescape_bytes(s)?)
        .map_err(|_| "unescaped text is not UTF-8".into())
}

/// One parsed client request line.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    Gen {
        max_tokens: usize,
        temp: f32,
        prompt: String,
        /// named-session id (SGEN); None for one-shot GEN requests
        session: Option<String>,
        /// registry model name (`MODEL <name>` prefix); None routes to
        /// the server's default model
        model: Option<String>,
    },
    Stats,
    Ping,
    Shutdown,
}

/// Parse one request line (without the trailing newline).
pub fn parse_request(line: &str) -> Result<Request, String> {
    let line = line.trim_end_matches(['\r', '\n']);
    match line {
        "STATS" => return Ok(Request::Stats),
        "PING" => return Ok(Request::Ping),
        "SHUTDOWN" => return Ok(Request::Shutdown),
        _ => {}
    }
    let (model, line) = if let Some(r) = line.strip_prefix("MODEL ") {
        let (name, rest) = r
            .split_once(' ')
            .ok_or("MODEL needs <name> followed by a GEN/SGEN request")?;
        if !valid_model_name(name) {
            return Err(format!(
                "bad model name {name:?} (want 1..={MAX_SESSION_ID_LEN} of \
                 [A-Za-z0-9._-], not starting with '.' or '-')"
            ));
        }
        if !rest.starts_with("GEN ") && !rest.starts_with("SGEN ") {
            return Err("MODEL prefixes a GEN/SGEN request".into());
        }
        (Some(name.to_string()), rest)
    } else {
        (None, line)
    };
    let (session, rest) = if let Some(r) = line.strip_prefix("SGEN ") {
        let (sid, r2) = r
            .split_once(' ')
            .ok_or("SGEN needs <session> <max_tokens> <temp>\\t<prompt>")?;
        (Some(sid.to_string()), r2)
    } else if let Some(r) = line.strip_prefix("GEN ") {
        (None, r)
    } else {
        return Err(format!(
            "unknown command {:?} (expected GEN/SGEN/STATS/PING/SHUTDOWN, \
             optionally behind MODEL <name>)",
            line.split_whitespace().next().unwrap_or("")
        ));
    };
    let (head, prompt_esc) = rest
        .split_once('\t')
        .ok_or("GEN needs a tab between the header and the prompt")?;
    let mut it = head.split_whitespace();
    let max_tokens: usize = it
        .next()
        .ok_or("GEN missing <max_tokens>")?
        .parse()
        .map_err(|e| format!("bad max_tokens: {e}"))?;
    let temp: f32 = it
        .next()
        .ok_or("GEN missing <temp>")?
        .parse()
        .map_err(|e| format!("bad temp: {e}"))?;
    if it.next().is_some() {
        return Err("GEN header has trailing fields".into());
    }
    let prompt = unescape(prompt_esc)?;
    validate_gen(max_tokens, temp, &prompt, session.as_deref())?;
    Ok(Request::Gen { max_tokens, temp, prompt, session, model })
}

/// The `MODEL <name> ` routing prefix (empty for the default model).
fn model_prefix(model: Option<&str>) -> String {
    match model {
        Some(m) => format!("MODEL {m} "),
        None => String::new(),
    }
}

/// Render a GEN request line (client side).
pub fn format_gen(max_tokens: usize, temp: f32, prompt: &str) -> String {
    format_gen_for(None, max_tokens, temp, prompt)
}

/// Render a GEN request line routed to a registry model.
pub fn format_gen_for(
    model: Option<&str>,
    max_tokens: usize,
    temp: f32,
    prompt: &str,
) -> String {
    format!(
        "{}GEN {max_tokens} {temp}\t{}\n",
        model_prefix(model),
        escape(prompt)
    )
}

/// Render an SGEN (named-session) request line (client side).
pub fn format_sgen(
    session: &str,
    max_tokens: usize,
    temp: f32,
    prompt: &str,
) -> String {
    format_sgen_for(None, session, max_tokens, temp, prompt)
}

/// Render an SGEN request line routed to a registry model.
pub fn format_sgen_for(
    model: Option<&str>,
    session: &str,
    max_tokens: usize,
    temp: f32,
    prompt: &str,
) -> String {
    format!(
        "{}SGEN {session} {max_tokens} {temp}\t{}\n",
        model_prefix(model),
        escape(prompt)
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn escape_roundtrips_arbitrary_text() {
        let mut rng = Rng::new(9);
        for _ in 0..200 {
            let n = rng.below(64);
            let s: String = (0..n)
                .map(|_| {
                    char::from_u32(rng.below(0x2500) as u32).unwrap_or('\t')
                })
                .collect();
            let e = escape(&s);
            assert!(e.bytes().all(|b| (0x20..=0x7e).contains(&b)), "{e:?}");
            assert!(!e.contains('\n'));
            assert_eq!(unescape(&e).unwrap(), s);
        }
    }

    #[test]
    fn byte_escape_roundtrips_split_utf8() {
        // a multi-byte char split across two token pieces must survive:
        // é = 0xC3 0xA9 streamed as two single-byte pieces
        let parts: Vec<Vec<u8>> = vec![vec![0xC3], vec![0xA9]];
        let mut reassembled = Vec::new();
        for p in &parts {
            let line = escape_bytes(p);
            assert!(line.bytes().all(|b| (0x20..=0x7e).contains(&b)));
            reassembled.extend(unescape_bytes(&line).unwrap());
        }
        assert_eq!(String::from_utf8(reassembled).unwrap(), "é");
        // and arbitrary non-UTF-8 bytes round-trip exactly
        let junk = vec![0xFF, 0x00, 0x80, b'\\', b'\n'];
        assert_eq!(unescape_bytes(&escape_bytes(&junk)).unwrap(), junk);
    }

    #[test]
    fn gen_line_roundtrips() {
        let line = format_gen(16, 0.5, "hello\tworld\nüber");
        let req = parse_request(line.trim_end()).unwrap();
        assert_eq!(
            req,
            Request::Gen {
                max_tokens: 16,
                temp: 0.5,
                prompt: "hello\tworld\nüber".into(),
                session: None,
                model: None,
            }
        );
    }

    #[test]
    fn sgen_line_roundtrips() {
        let line = format_sgen("conv-7.a", 8, 0.0, "hi there");
        let req = parse_request(line.trim_end()).unwrap();
        assert_eq!(
            req,
            Request::Gen {
                max_tokens: 8,
                temp: 0.0,
                prompt: "hi there".into(),
                session: Some("conv-7.a".into()),
                model: None,
            }
        );
    }

    #[test]
    fn model_prefix_roundtrips_and_validates() {
        let line = format_gen_for(Some("alpha"), 4, 0.0, "hi");
        assert!(line.starts_with("MODEL alpha GEN "));
        let req = parse_request(line.trim_end()).unwrap();
        assert_eq!(
            req,
            Request::Gen {
                max_tokens: 4,
                temp: 0.0,
                prompt: "hi".into(),
                session: None,
                model: Some("alpha".into()),
            }
        );
        let line = format_sgen_for(Some("m.2"), "conv", 4, 0.0, "hi");
        let req = parse_request(line.trim_end()).unwrap();
        assert_eq!(
            req,
            Request::Gen {
                max_tokens: 4,
                temp: 0.0,
                prompt: "hi".into(),
                session: Some("conv".into()),
                model: Some("m.2".into()),
            }
        );
        for bad in [
            "MODEL",                       // bare
            "MODEL x",                     // nothing after the name
            "MODEL ../up GEN 4 0.0\thi",   // path-escape name
            "MODEL has space GEN 4 0.0\thi",
            "MODEL x STATS",               // MODEL only prefixes GEN/SGEN
            "MODEL x PING",
            "MODEL x MODEL y GEN 4 0.0\thi",
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?} should fail");
        }
        assert!(valid_model_name("alpha"));
        assert!(!valid_model_name("a/b"));
        assert!(!valid_model_name(""));
    }

    #[test]
    fn session_ids_validated() {
        assert!(valid_session_id("a"));
        assert!(valid_session_id("conv_7.B-2"));
        assert!(!valid_session_id(""));
        assert!(!valid_session_id(".hidden"));
        assert!(!valid_session_id("-dash"));
        assert!(!valid_session_id("has space"));
        assert!(!valid_session_id("slash/y"));
        assert!(!valid_session_id("dots/../up"));
        assert!(!valid_session_id(&"x".repeat(MAX_SESSION_ID_LEN + 1)));
        for bad in [
            "SGEN 5 0.0\thi",               // missing sid → "0.0\thi" is no header
            "SGEN ../x 5 0.0\thi",          // path-escape id
            "SGEN  5 0.0\thi",              // empty sid
            "SGEN aa\t5 0.0 hi",            // tab before header
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?} should fail");
        }
    }

    #[test]
    fn control_commands_parse() {
        assert_eq!(parse_request("STATS").unwrap(), Request::Stats);
        assert_eq!(parse_request("PING\r\n").unwrap(), Request::Ping);
        assert_eq!(parse_request("SHUTDOWN").unwrap(), Request::Shutdown);
    }

    #[test]
    fn malformed_requests_rejected() {
        for bad in [
            "",
            "NOPE x",
            "GEN",
            "GEN 5",
            "GEN 5 0.0", // no tab
            "GEN 0 0.0\thi",
            "GEN 99999 0.0\thi",
            "GEN 5 -1\thi",
            "GEN 5 99\thi",
            "GEN 5 0.0 extra\thi",
            "GEN 5 0.0\tbad \\q escape",
        ] {
            assert!(parse_request(bad).is_err(), "{bad:?} should fail");
        }
        let huge = format!("GEN 5 0.0\t{}", "a".repeat(MAX_PROMPT_BYTES + 1));
        assert!(parse_request(&huge).is_err());
    }
}
