//! The decode-only inference engine behind `chon serve`.
//!
//! Loads a checkpoint directory (params + tokenizer + metadata, see
//! `runtime::ckptdir`), validates it against the named model/recipe
//! tables, and runs incremental token-at-a-time decoding with per-session
//! recurrent state — no backprop, no Adam buffers, no fixed seq length:
//!
//! * GLA sessions carry the linear-attention recurrent state
//!   `S_t = Σ_{s<=t} k'_s v_sᵀ` (one d×d matrix per layer), so a decode
//!   step is O(d²) regardless of context length.
//! * SA sessions carry a growing K/V cache per layer and recompute the
//!   causal softmax over it each step.
//!
//! Forward GEMMs run through `model::infer_linear_prepared`, which
//! applies the checkpoint's quant recipe (NVFP4/FP8 fake-quant + per-row
//! HCP) in a batch-invariant way: row i of a batched decode is
//! bit-identical to a batch-of-one decode, so greedy outputs do not
//! depend on which requests happen to be coalesced together. Weights are
//! fake-quantized once at load (`prepare_weight`); only activations are
//! quantized per decode step.
//!
//! `--packed-compute` swaps the NVFP4 weight preparation for the real
//! packed path (`prepare_weight_packed`): weights stay resident as
//! packed 4-bit codes decoded in-register by the quantized GEMM kernel,
//! with HCP-persistent hot channels split into an f32 side-GEMM. A new
//! recipe mode — bit-identical within itself across batch sizes, SIMD
//! levels, and thread counts, but gated against the fake-quant path by
//! evalsuite deltas, not bitwise equality (see README).

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::data::tokenizer::Tokenizer;
use crate::obs::outliers::{OpTap, OutlierObs};
use crate::runtime::ckptdir::{self, CheckpointMeta};
use crate::runtime::native::model::{
    self, final_norm_idx, infer_linear_prepared, infer_linear_prepared_obs,
    layer_slots, lm_head_idx, model_cfg, pidx, prepare_weight_cached,
    prepare_weight_packed, rmsnorm, sigmoid, Arch, ModelCfg, PreparedWeight,
};
use crate::runtime::native::recipe::{op_quant, recipe, NativeRecipe, BF16_OP};
use crate::serve::pages::KvPages;
use crate::util::ndarray::Mat;
use crate::util::prng::Rng;

/// Magic + version prefix of the serialized session format.
const SESSION_MAGIC: &[u8; 8] = b"CHONSES1";

/// Per-layer decode state of one session.
enum LayerState {
    /// GLA: the running outer-product sum S = Σ k'_s v_sᵀ (d × d).
    Gla { s: Mat },
    /// SA: the key/value cache, paged in fixed-size blocks of positions.
    Sa { kv: KvPages },
}

/// One generation session (the recurrent state behind one request — or,
/// for named sessions, behind a whole multi-request conversation).
pub struct Session {
    /// tokens consumed so far (prompt + generated)
    pub pos: usize,
    layers: Vec<LayerState>,
}

impl Session {
    /// Resident-memory cost in KV-position units: an SA session holds
    /// `pos` cached positions per layer; a GLA session's d×d state is
    /// charged as d positions (its memory is d rows of d floats no
    /// matter how long the context grew).
    pub fn kv_cost_tokens(&self) -> usize {
        match self.layers.first() {
            Some(LayerState::Gla { s }) => s.rows,
            Some(LayerState::Sa { kv }) => kv.rows(),
            None => 0,
        }
    }
}

/// A loaded, validated model ready to decode.
pub struct Engine {
    pub cfg: ModelCfg,
    pub recipe: NativeRecipe,
    pub tokenizer: Tokenizer,
    pub meta: CheckpointMeta,
    /// embed + norm vectors only — linear slots are emptied after
    /// preparation (decode reads them solely through `prepped`, and the
    /// prepared form already keeps wu plus, on the HCP path, dw = w - wu)
    params: Vec<Mat>,
    /// per-parameter quantized weights, indexed like `params`; `None` for
    /// non-linear slots (embed, norms). Weights are frozen at inference
    /// time, so fake-quantizing them once here keeps the per-token decode
    /// path free of redundant weight re-quantization.
    prepped: Vec<Option<PreparedWeight>>,
    /// total parameter count of the loaded model (reporting)
    n_params: usize,
    /// `--obs-outliers` taps; None (the default) keeps the decode path
    /// free of any telemetry work
    outlier_obs: Option<Arc<OutlierObs>>,
    /// `--packed-compute`: NVFP4 linear weights resident as packed codes
    /// + hot-channel side-matrix instead of dense fake-quantized f32
    packed_compute: bool,
}

/// Forward-op name of a linear weight slot (None for norm vectors).
fn slot_op(slot: &str) -> Option<&'static str> {
    Some(match slot {
        "wq" => "attn.q",
        "wk" => "attn.k",
        "wv" => "attn.v",
        "wgk" => "attn.gk",
        "wg" => "attn.g",
        "wo" => "attn.o",
        "w_up" => "mlp.up",
        "w_gate" => "mlp.gate",
        "w_down" => "mlp.down",
        _ => return None,
    })
}

/// Pre-quantize every linear weight per the recipe's forward config, and
/// pack each quantized operand into GEMM B panels once (the packed-weight
/// cache): serve weights are frozen, so no decode or prefill GEMM ever
/// re-packs them. `matmul_packed` is bitwise `matmul`, so this is purely
/// a load-time-for-runtime trade.
fn prepare_all(
    cfg: &ModelCfg,
    rec: &NativeRecipe,
    params: &[Mat],
    packed_compute: bool,
) -> Vec<Option<PreparedWeight>> {
    let mut out: Vec<Option<PreparedWeight>> = params.iter().map(|_| None).collect();
    for l in 0..cfg.layers {
        for slot in layer_slots(cfg.arch) {
            if let Some(op) = slot_op(slot) {
                let idx = pidx(cfg, l, slot);
                let oq = op_quant(rec, cfg.arch, l, cfg.layers, op);
                out[idx] = Some(if packed_compute {
                    prepare_weight_packed(&params[idx], &oq)
                } else {
                    prepare_weight_cached(&params[idx], &oq)
                });
            }
        }
    }
    // the lm_head scores in full precision in every mode
    let hi = lm_head_idx(cfg);
    out[hi] = Some(prepare_weight_cached(&params[hi], &BF16_OP));
    out
}

/// Resident bytes of one prepared weight — what decode actually keeps in
/// memory for this parameter across the engine's lifetime.
fn prepared_bytes(pw: &PreparedWeight) -> usize {
    if let Some(pc) = &pw.packed {
        return pc.qmat.storage_bytes() + pc.hot.len() * 4 + pc.hot_idx.len() * 8;
    }
    pw.wu.data.len() * 4
        + pw.wu_panels.as_ref().map_or(0, |p| p.packed_len() * 4)
        + pw.dw.as_ref().map_or(0, |d| d.data.len() * 4)
        + pw.wscore.as_ref().map_or(0, |s| s.len() * 8)
}

/// Drop the full-precision copies of weights that decode only ever reads
/// through their PreparedWeight.
fn strip_prepared(mut params: Vec<Mat>, prepped: &[Option<PreparedWeight>]) -> Vec<Mat> {
    for (p, pw) in params.iter_mut().zip(prepped) {
        if pw.is_some() {
            *p = Mat::from_vec(0, 0, Vec::new());
        }
    }
    params
}

impl Engine {
    /// Load from a checkpoint dir (or a parent of checkpoint dirs — the
    /// highest-step one wins). Errors clearly on unknown model/recipe,
    /// tensor name/shape mismatches, vocab drift or corrupt files.
    pub fn load(path: &Path) -> Result<Engine> {
        Self::load_with_mode(path, false)
    }

    /// [`Engine::load`] with the compute mode explicit: `packed_compute`
    /// keeps NVFP4 linear weights resident as packed codes + a
    /// hot-channel f32 side-matrix (`chon serve --packed-compute`).
    pub fn load_with_mode(path: &Path, packed_compute: bool) -> Result<Engine> {
        let dir = ckptdir::resolve(path)?;
        let meta_probe = ckptdir::load_meta(&dir)?;
        let cfg = model_cfg(&meta_probe.model).with_context(|| {
            format!("checkpoint {} names an unknown model", dir.display())
        })?;
        let rec = recipe(&meta_probe.recipe).with_context(|| {
            format!("checkpoint {} names an unknown recipe", dir.display())
        })?;
        let specs: Vec<(String, Vec<usize>)> = model::param_specs(&cfg)
            .into_iter()
            .map(|s| (s.name, s.shape))
            .collect();
        let loaded = ckptdir::load_dir(&dir, &specs)?;
        if loaded.tokenizer.vocab != loaded.meta.vocab {
            bail!(
                "checkpoint {}: meta says vocab {} but tokenizer has {}",
                dir.display(),
                loaded.meta.vocab,
                loaded.tokenizer.vocab
            );
        }
        if loaded.meta.vocab != cfg.vocab {
            bail!(
                "checkpoint {}: vocab {} does not match model {}'s vocab {}",
                dir.display(),
                loaded.meta.vocab,
                cfg.name,
                cfg.vocab
            );
        }
        let params: Vec<Mat> =
            loaded.params.iter().map(|(_, t)| model::to_mat(t)).collect();
        let n_params = params.iter().map(|m| m.data.len()).sum();
        let prepped = prepare_all(&cfg, &rec, &params, packed_compute);
        let params = strip_prepared(params, &prepped);
        let eng = Engine {
            cfg,
            recipe: rec,
            tokenizer: loaded.tokenizer,
            meta: loaded.meta,
            params,
            prepped,
            n_params,
            outlier_obs: None,
            packed_compute,
        };
        crate::info!(
            "loaded {} ({}): {} resident weight bytes (mode {})",
            eng.cfg.name,
            eng.recipe.name,
            eng.weight_bytes(),
            eng.compute_mode()
        );
        Ok(eng)
    }

    /// Build an engine directly from in-memory state (tests / embedding).
    pub fn from_parts(
        cfg: ModelCfg,
        rec: NativeRecipe,
        tokenizer: Tokenizer,
        params: &[crate::runtime::HostTensor],
    ) -> Engine {
        Self::from_parts_mode(cfg, rec, tokenizer, params, false)
    }

    /// [`Engine::from_parts`] with the compute mode explicit.
    pub fn from_parts_mode(
        cfg: ModelCfg,
        rec: NativeRecipe,
        tokenizer: Tokenizer,
        params: &[crate::runtime::HostTensor],
        packed_compute: bool,
    ) -> Engine {
        let meta = CheckpointMeta {
            format_version: ckptdir::FORMAT_VERSION,
            model: cfg.name.clone(),
            recipe: rec.name.clone(),
            seed: 0,
            step: 0,
            vocab: tokenizer.vocab,
            data_batches: 0,
            generation: 0,
        };
        let params = model::params_to_mats(params);
        let n_params = params.iter().map(|m| m.data.len()).sum();
        let prepped = prepare_all(&cfg, &rec, &params, packed_compute);
        let params = strip_prepared(params, &prepped);
        Engine {
            cfg,
            recipe: rec,
            tokenizer,
            meta,
            params,
            prepped,
            n_params,
            outlier_obs: None,
            packed_compute,
        }
    }

    /// Build the `--obs-outliers` taps for this engine: one [`OpTap`] per
    /// forward op, sized to the op's input width, with the layer-mean
    /// per-channel weight score frozen from the prepared weights (zeros
    /// for recipes without HCP — such taps never record anyway, since the
    /// observer fires only on the HCP-compensated path).
    pub fn build_outlier_obs(&self) -> Arc<OutlierObs> {
        let cfg = &self.cfg;
        let mut taps = Vec::new();
        for slot in layer_slots(cfg.arch) {
            let Some(op) = slot_op(slot) else { continue };
            let channels = if op == "mlp.down" { cfg.ff } else { cfg.d };
            let mut wscore = vec![0.0f64; channels];
            let mut layers = 0usize;
            for l in 0..cfg.layers {
                if let Some(ws) = self.prepped[pidx(cfg, l, slot)]
                    .as_ref()
                    .and_then(|p| p.wscore.as_ref())
                {
                    for (acc, v) in wscore.iter_mut().zip(ws) {
                        *acc += v;
                    }
                    layers += 1;
                }
            }
            if layers > 0 {
                for v in wscore.iter_mut() {
                    *v /= layers as f64;
                }
            }
            taps.push(OpTap::new(op, channels, wscore));
        }
        Arc::new(OutlierObs { taps })
    }

    /// Install outlier taps. Passing taps a previous engine of the same
    /// model built keeps hit counters accumulating across hot reloads.
    pub fn attach_outlier_obs(&mut self, obs: Arc<OutlierObs>) {
        self.outlier_obs = Some(obs);
    }

    /// Fresh per-request state.
    pub fn new_session(&self) -> Session {
        let d = self.cfg.d;
        let layers = (0..self.cfg.layers)
            .map(|_| match self.cfg.arch {
                Arch::Gla => LayerState::Gla { s: Mat::zeros(d, d) },
                Arch::Sa => LayerState::Sa { kv: KvPages::new(d) },
            })
            .collect();
        Session { pos: 0, layers }
    }

    /// Feed a prompt through a session (logits discarded except for the
    /// caller's use of the return value: the logits after the *last*
    /// prompt token, i.e. the distribution of the first generated token).
    pub fn prefill(&self, sess: &mut Session, tokens: &[u32]) -> Vec<f32> {
        let mut out = self.prefill_batch(&mut [sess], &[tokens]);
        out.pop().unwrap()
    }

    /// Cross-session prefill: feed `prompts[i]` through `sessions[i]`
    /// with token-steps batched across sessions — step t advances every
    /// prompt that still has a token at position t, so N waiting prompts
    /// cost ~one prefill pass instead of N. Returns, per session, the
    /// logits after its *last* prompt token. Because `decode_step` is
    /// batch-invariant, the returned logits and all session state are
    /// bit-identical to prefilling each session alone.
    pub fn prefill_batch(
        &self,
        sessions: &mut [&mut Session],
        prompts: &[&[u32]],
    ) -> Vec<Vec<f32>> {
        assert_eq!(sessions.len(), prompts.len());
        assert!(
            prompts.iter().all(|p| !p.is_empty()),
            "prefill needs at least one token per prompt"
        );
        // longest-first (stable) order makes each step's active set a
        // prefix of the permuted session list
        let mut order: Vec<usize> = (0..prompts.len()).collect();
        order.sort_by_key(|&i| std::cmp::Reverse(prompts[i].len()));
        let mut slots: Vec<Option<&mut Session>> =
            sessions.iter_mut().map(|s| Some(&mut **s)).collect();
        let mut perm: Vec<&mut Session> =
            order.iter().map(|&i| slots[i].take().unwrap()).collect();
        let max_len = prompts.iter().map(|p| p.len()).max().unwrap_or(0);
        let mut out: Vec<Vec<f32>> = vec![Vec::new(); prompts.len()];
        for t in 0..max_len {
            let active =
                order.iter().take_while(|&&i| t < prompts[i].len()).count();
            let tokens: Vec<u32> =
                order[..active].iter().map(|&i| prompts[i][t]).collect();
            let logits = self.decode_step(&mut perm[..active], &tokens);
            for (row, &i) in order[..active].iter().enumerate() {
                if t + 1 == prompts[i].len() {
                    out[i] = logits.row(row).to_vec();
                }
            }
        }
        out
    }

    /// One decode step for a batch of sessions: feed `tokens[i]` to
    /// `sessions[i]`, return the (batch, vocab) next-token logits.
    pub fn decode_step(&self, sessions: &mut [&mut Session], tokens: &[u32]) -> Mat {
        assert_eq!(sessions.len(), tokens.len());
        let cfg = &self.cfg;
        let (b, d) = (sessions.len(), cfg.d);
        let inv_sqrt_d = 1.0 / (d as f32).sqrt();

        // embed
        let embed = &self.params[0];
        let mut x = Mat::zeros(b, d);
        for (i, &t) in tokens.iter().enumerate() {
            x.row_mut(i).copy_from_slice(embed.row(t as usize % cfg.vocab));
        }

        for l in 0..cfg.layers {
            let p = |slot: &str| &self.params[pidx(cfg, l, slot)];
            // quantized linear over the weight prepared at load time
            let lin = |slot: &str, op: &str, x: &Mat| -> Mat {
                let idx = pidx(cfg, l, slot);
                let oq = op_quant(&self.recipe, cfg.arch, l, cfg.layers, op);
                let pw = self.prepped[idx].as_ref().expect("weight prepared at load");
                match self.outlier_obs.as_deref().and_then(|o| o.tap(op)) {
                    Some(tap) => infer_linear_prepared_obs(
                        x,
                        pw,
                        &oq,
                        Some(&|hot: &[usize], resid: f64, hot_resid: f64| {
                            tap.record_row(hot, resid, hot_resid)
                        }),
                    ),
                    None => infer_linear_prepared(x, pw, &oq),
                }
            };

            let (h, _) = rmsnorm(&x, p("attn_norm"));
            let q = lin("wq", "attn.q", &h);
            let k = lin("wk", "attn.k", &h);
            let v = lin("wv", "attn.v", &h);
            let (gk, g) = match cfg.arch {
                Arch::Gla => (
                    Some(lin("wgk", "attn.gk", &h)),
                    Some(lin("wg", "attn.g", &h)),
                ),
                Arch::Sa => (None, None),
            };

            // per-session attention with recurrent/cached state. Sessions
            // are independent (disjoint state, disjoint output rows), so
            // a batched step fans them out over the persistent worker
            // pool — the same pool the training shards use — instead of
            // walking them serially; per-session math is untouched, so
            // batch-invariance and greedy determinism are preserved.
            let mut o = Mat::zeros(b, d);
            let step_session = |i: usize, sess: &mut Session, orow: &mut [f32]| {
                let t = sess.pos; // 0-based position of this token
                match &mut sess.layers[l] {
                    LayerState::Gla { s } => {
                        let (gkr, gr) =
                            (gk.as_ref().unwrap().row(i), g.as_ref().unwrap().row(i));
                        let (kr, vr, qr) = (k.row(i), v.row(i), q.row(i));
                        // S += k'_t v_tᵀ with k' = k ⊙ σ(gk)
                        for j in 0..d {
                            let kp = kr[j] * sigmoid(gkr[j]);
                            let srow = s.row_mut(j);
                            for c in 0..d {
                                srow[c] += kp * vr[c];
                            }
                        }
                        // o = ct · qᵀS, then the output gate σ(g)
                        let ct = inv_sqrt_d / (t as f32 + 1.0);
                        for j in 0..d {
                            let qj = qr[j];
                            if qj == 0.0 {
                                continue;
                            }
                            let srow = s.row(j);
                            for c in 0..d {
                                orow[c] += qj * srow[c];
                            }
                        }
                        for c in 0..d {
                            orow[c] *= ct * sigmoid(gr[c]);
                        }
                    }
                    LayerState::Sa { kv } => {
                        kv.push(k.row(i), v.row(i));
                        let qr = q.row(i);
                        // causal softmax over the cached positions; pages
                        // iterate in append order, so every accumulation
                        // chain is the one a flat cache would build
                        let n = t + 1;
                        debug_assert_eq!(kv.rows(), n);
                        let mut scores = Vec::with_capacity(n);
                        let mut mx = f32::NEG_INFINITY;
                        kv.for_each_row(|krow, _| {
                            let mut dot = 0.0f32;
                            for j in 0..d {
                                dot += qr[j] * krow[j];
                            }
                            let sc = dot * inv_sqrt_d;
                            mx = mx.max(sc);
                            scores.push(sc);
                        });
                        let mut z = 0.0f32;
                        for sc in scores.iter_mut() {
                            *sc = (*sc - mx).exp();
                            z += *sc;
                        }
                        let mut s = 0usize;
                        kv.for_each_row(|_, vrow| {
                            let w = scores[s] / z;
                            for c in 0..d {
                                orow[c] += w * vrow[c];
                            }
                            s += 1;
                        });
                    }
                }
            };
            if b >= 2 {
                let mut work: Vec<(&mut Session, &mut [f32])> = sessions
                    .iter_mut()
                    .map(|s| &mut **s)
                    .zip(o.data.chunks_mut(d))
                    .collect();
                crate::util::pool::global()
                    .for_each_mut(&mut work, |i, item| {
                        step_session(i, &mut *item.0, &mut *item.1)
                    });
            } else {
                for (i, sess) in sessions.iter_mut().enumerate() {
                    step_session(i, &mut **sess, o.row_mut(i));
                }
            }

            let lo = lin("wo", "attn.o", &o);
            x.add_assign(&lo);

            let (h2, _) = rmsnorm(&x, p("mlp_norm"));
            let up = lin("w_up", "mlp.up", &h2);
            let gate = lin("w_gate", "mlp.gate", &h2);
            let mut act = Mat::zeros(b, cfg.ff);
            for idx in 0..act.data.len() {
                let z = gate.data[idx];
                act.data[idx] = up.data[idx] * z * sigmoid(z);
            }
            let down = lin("w_down", "mlp.down", &act);
            x.add_assign(&down);
        }

        let (hf, _) = rmsnorm(&x, &self.params[final_norm_idx(cfg)]);
        // lm_head scores in full precision, as in the training forward
        let head = self.prepped[lm_head_idx(cfg)]
            .as_ref()
            .expect("lm_head prepared at load");
        let logits = infer_linear_prepared(&hf, head, &BF16_OP);
        for sess in sessions.iter_mut() {
            sess.pos += 1;
        }
        logits
    }

    /// Sample the next token from one logits row. `temp == 0` is greedy
    /// argmax (ties → lowest id, fully deterministic); `temp > 0` is
    /// softmax-temperature sampling driven by the caller's RNG.
    pub fn sample(&self, logits: &[f32], temp: f32, rng: &mut Rng) -> u32 {
        if temp <= 0.0 {
            let mut best = 0usize;
            let mut bestv = f32::NEG_INFINITY;
            for (i, &v) in logits.iter().enumerate() {
                if v > bestv {
                    bestv = v;
                    best = i;
                }
            }
            return best as u32;
        }
        let mx = logits.iter().fold(f32::NEG_INFINITY, |m, &v| m.max(v));
        let weights: Vec<f64> =
            logits.iter().map(|&v| (((v - mx) / temp) as f64).exp()).collect();
        let total: f64 = weights.iter().sum();
        let mut r = rng.uniform() as f64 * total;
        for (i, &w) in weights.iter().enumerate() {
            r -= w;
            if r <= 0.0 {
                return i as u32;
            }
        }
        (logits.len() - 1) as u32
    }

    /// Number of parameters of the loaded model (reporting).
    pub fn param_count(&self) -> usize {
        self.n_params
    }

    /// Resident bytes of all prepared weights — the value behind the
    /// `chon_model_weight_bytes{model,mode}` gauge. In packed mode this
    /// counts codes + scales + the hot side-matrix; in f32 mode the dense
    /// operand, its B panels, and any HCP residual state.
    pub fn weight_bytes(&self) -> usize {
        self.prepped.iter().flatten().map(prepared_bytes).sum()
    }

    /// Compute-mode label for logs and the weight-bytes gauge.
    pub fn compute_mode(&self) -> &'static str {
        if self.packed_compute {
            "packed"
        } else {
            "f32"
        }
    }

    /// Serialize a session's full decode state. Bit-exact: every f32 is
    /// stored as its little-endian bit pattern, so
    /// `restore_session(serialize_session(s))` reproduces `s` exactly
    /// and an evicted-then-reloaded session decodes bitwise identically
    /// to one that stayed resident.
    pub fn serialize_session(&self, sess: &Session) -> Vec<u8> {
        let cfg = &self.cfg;
        let mut out = Vec::new();
        out.extend_from_slice(SESSION_MAGIC);
        out.push(arch_tag(cfg.arch));
        out.extend_from_slice(&(cfg.layers as u32).to_le_bytes());
        out.extend_from_slice(&(cfg.d as u32).to_le_bytes());
        out.extend_from_slice(&(sess.pos as u64).to_le_bytes());
        for ls in &sess.layers {
            match ls {
                LayerState::Gla { s } => put_f32s(&mut out, &s.data),
                LayerState::Sa { kv } => {
                    put_f32s(&mut out, &kv.flat_k());
                    put_f32s(&mut out, &kv.flat_v());
                }
            }
        }
        out
    }

    /// Invert `serialize_session`, validating the header against this
    /// engine's model (arch, layer count, width) and the payload length
    /// against the stored position count.
    pub fn restore_session(&self, bytes: &[u8]) -> Result<Session> {
        let cfg = &self.cfg;
        let d = cfg.d;
        if bytes.len() < SESSION_MAGIC.len() || &bytes[..8] != SESSION_MAGIC {
            bail!("not a serialized session (bad magic)");
        }
        let mut at = 8usize;
        let Some(&tag) = bytes.get(at) else {
            bail!("truncated serialized session");
        };
        at += 1;
        if tag != arch_tag(cfg.arch) {
            bail!("session arch tag {tag} does not match the loaded model");
        }
        let layers = get_u32(bytes, &mut at)? as usize;
        let dd = get_u32(bytes, &mut at)? as usize;
        if layers != cfg.layers || dd != d {
            bail!(
                "session shape (layers {layers}, d {dd}) does not match \
                 model ({}, {})",
                cfg.layers,
                d
            );
        }
        let pos64 = get_u64(bytes, &mut at)?;
        // sanity cap so a corrupt header cannot drive pos*d arithmetic
        // into overflow or a giant allocation before the length checks
        if pos64 > (1 << 24) {
            bail!("serialized session claims an absurd position {pos64}");
        }
        let pos = pos64 as usize;
        let mut states = Vec::with_capacity(layers);
        for _ in 0..layers {
            let ls = match cfg.arch {
                Arch::Gla => {
                    let data = get_f32s(bytes, d * d, &mut at)?;
                    LayerState::Gla { s: Mat::from_vec(d, d, data) }
                }
                Arch::Sa => {
                    let k = get_f32s(bytes, pos * d, &mut at)?;
                    let v = get_f32s(bytes, pos * d, &mut at)?;
                    LayerState::Sa { kv: KvPages::from_flat(d, &k, &v) }
                }
            };
            states.push(ls);
        }
        if at != bytes.len() {
            bail!(
                "serialized session has {} trailing bytes",
                bytes.len() - at
            );
        }
        Ok(Session { pos, layers: states })
    }
}

fn arch_tag(arch: Arch) -> u8 {
    match arch {
        Arch::Gla => 0,
        Arch::Sa => 1,
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_u32(b: &[u8], at: &mut usize) -> Result<u32> {
    let Some(chunk) = b.get(*at..*at + 4) else {
        bail!("truncated serialized session");
    };
    *at += 4;
    Ok(u32::from_le_bytes(chunk.try_into().unwrap()))
}

fn get_u64(b: &[u8], at: &mut usize) -> Result<u64> {
    let Some(chunk) = b.get(*at..*at + 8) else {
        bail!("truncated serialized session");
    };
    *at += 8;
    Ok(u64::from_le_bytes(chunk.try_into().unwrap()))
}

fn get_f32s(b: &[u8], n: usize, at: &mut usize) -> Result<Vec<f32>> {
    let Some(raw) = b.get(*at..*at + 4 * n) else {
        bail!("truncated serialized session payload");
    };
    *at += 4 * n;
    Ok(raw
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::model::{forward_logits, init_params};

    /// init_params zeroes lm_head (flat logits at step 0), which would
    /// make every parity assertion vacuous — give the head random weight.
    fn test_params(cfg: &ModelCfg) -> Vec<crate::runtime::HostTensor> {
        let mut params = init_params(cfg, 5);
        let mut rng = Rng::new(42);
        rng.fill_normal(&mut params[lm_head_idx(cfg)].f32_data, 0.3);
        params
    }

    fn engine(model: &str, rec_name: &str) -> Engine {
        let cfg = model_cfg(model).unwrap();
        let params = test_params(&cfg);
        Engine::from_parts(
            cfg,
            recipe(rec_name).unwrap(),
            Tokenizer::byte_level(),
            &params,
        )
    }

    fn engine_packed(model: &str, rec_name: &str) -> Engine {
        let cfg = model_cfg(model).unwrap();
        let params = test_params(&cfg);
        Engine::from_parts_mode(
            cfg,
            recipe(rec_name).unwrap(),
            Tokenizer::byte_level(),
            &params,
            true,
        )
    }

    /// The recurrent GLA decode must agree with the training parallel
    /// form on the *last* position of a window (same math, different
    /// summation order → compare with tolerance, not bitwise).
    #[test]
    fn gla_decode_matches_parallel_forward() {
        let eng = engine("tiny_gla", "bf16");
        let cfg = &eng.cfg;
        let toks: Vec<u32> = (0..cfg.seq as u32).map(|i| 97 + (i % 13)).collect();
        let mut sess = eng.new_session();
        let dec_logits = eng.prefill(&mut sess, &toks);

        // parallel training forward over one (batch=cfg.batch) window;
        // row seq-1 of batch row 0 is the same position
        let full: Vec<i32> = toks
            .iter()
            .cycle()
            .take(cfg.batch * cfg.seq)
            .map(|&t| t as i32)
            .collect();
        let par = forward_logits(cfg, &recipe("bf16").unwrap(), &test_params(cfg), &full);
        let par_row = par.row(cfg.seq - 1);
        let mut max_abs = 0.0f32;
        for (a, b) in dec_logits.iter().zip(par_row) {
            max_abs = max_abs.max((a - b).abs());
        }
        assert!(max_abs < 1e-3, "decode vs parallel drift {max_abs}");
        // greedy tokens agree whenever the top-2 margin clears the drift
        let mut sorted = par_row.to_vec();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        if sorted[0] - sorted[1] > 2.0 * max_abs {
            let mut rng = Rng::new(0);
            assert_eq!(
                eng.sample(&dec_logits, 0.0, &mut rng),
                eng.sample(par_row, 0.0, &mut rng)
            );
        }
    }

    #[test]
    fn sa_decode_matches_parallel_forward() {
        let eng = engine("tiny_sa", "bf16");
        let cfg = &eng.cfg;
        let toks: Vec<u32> = (0..cfg.seq as u32).map(|i| 100 + (i % 7)).collect();
        let mut sess = eng.new_session();
        let dec_logits = eng.prefill(&mut sess, &toks);
        let full: Vec<i32> = toks
            .iter()
            .cycle()
            .take(cfg.batch * cfg.seq)
            .map(|&t| t as i32)
            .collect();
        let par = forward_logits(cfg, &recipe("bf16").unwrap(), &test_params(cfg), &full);
        let par_row = par.row(cfg.seq - 1);
        let mut max_abs = 0.0f32;
        for (a, b) in dec_logits.iter().zip(par_row) {
            max_abs = max_abs.max((a - b).abs());
        }
        assert!(max_abs < 1e-3, "decode vs parallel drift {max_abs}");
    }

    /// Batched decode must be bit-identical to one-by-one decode, even
    /// under the full chon recipe (NVFP4 + HCP + post-QK protection).
    #[test]
    fn batched_decode_is_bit_identical_to_single() {
        for rec_name in ["bf16", "chon", "nvfp4", "fp8"] {
            let eng = engine("tiny_gla", rec_name);
            let prompts: Vec<Vec<u32>> = (0..4)
                .map(|i| (0..6).map(|j| 97 + ((i * 7 + j) % 20)).collect())
                .collect();

            // one-by-one
            let mut solo_out = Vec::new();
            for p in &prompts {
                let mut s = eng.new_session();
                let logits = eng.prefill(&mut s, p);
                let mut rng = Rng::new(1);
                let mut toks = vec![eng.sample(&logits, 0.0, &mut rng)];
                for _ in 0..5 {
                    let last = *toks.last().unwrap();
                    let l = eng.decode_step(&mut [&mut s], &[last]);
                    toks.push(eng.sample(l.row(0), 0.0, &mut rng));
                }
                solo_out.push(toks);
            }

            // batched: prefill individually, decode as one batch
            let mut sessions: Vec<Session> = Vec::new();
            let mut last_toks: Vec<u32> = Vec::new();
            let mut batched_out: Vec<Vec<u32>> = Vec::new();
            for p in &prompts {
                let mut s = eng.new_session();
                let logits = eng.prefill(&mut s, p);
                let mut rng = Rng::new(1);
                let t = eng.sample(&logits, 0.0, &mut rng);
                batched_out.push(vec![t]);
                last_toks.push(t);
                sessions.push(s);
            }
            for _ in 0..5 {
                let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                let l = eng.decode_step(&mut refs, &last_toks);
                let mut rng = Rng::new(1);
                for i in 0..prompts.len() {
                    let t = eng.sample(l.row(i), 0.0, &mut rng);
                    batched_out[i].push(t);
                    last_toks[i] = t;
                }
            }
            assert_eq!(solo_out, batched_out, "recipe {rec_name}");
        }
    }

    /// Batched prefill must be bit-identical to serial prefill for every
    /// recipe, including ragged prompt lengths (the batcher admits mixed
    /// groups).
    #[test]
    fn prefill_batch_is_bit_identical_to_serial() {
        for model in ["tiny_gla", "tiny_sa"] {
            for rec_name in ["bf16", "chon"] {
                let eng = engine(model, rec_name);
                let prompts: Vec<Vec<u32>> = (0..5)
                    .map(|i| {
                        (0..3 + i * 2).map(|j| 97 + ((i * 11 + j) % 23)).collect()
                    })
                    .collect();
                // serial reference
                let mut ref_logits = Vec::new();
                let mut ref_sessions = Vec::new();
                for p in &prompts {
                    let mut s = eng.new_session();
                    ref_logits.push(eng.prefill(&mut s, p));
                    ref_sessions.push(s);
                }
                // batched
                let mut sessions: Vec<Session> =
                    prompts.iter().map(|_| eng.new_session()).collect();
                let logits = {
                    let mut refs: Vec<&mut Session> =
                        sessions.iter_mut().collect();
                    let ps: Vec<&[u32]> =
                        prompts.iter().map(|p| p.as_slice()).collect();
                    eng.prefill_batch(&mut refs, &ps)
                };
                assert_eq!(logits, ref_logits, "{model}/{rec_name}");
                // the *state* also matches: one more decode step agrees
                for (a, b) in sessions.iter_mut().zip(ref_sessions.iter_mut())
                {
                    assert_eq!(a.pos, b.pos);
                    let la = eng.decode_step(&mut [a], &[101]);
                    let lb = eng.decode_step(&mut [b], &[101]);
                    assert_eq!(la.data, lb.data, "{model}/{rec_name}");
                }
            }
        }
    }

    /// Serialize → restore reproduces decode state bit-exactly, for both
    /// architectures, across page boundaries.
    #[test]
    fn session_serialization_roundtrips_bit_exactly() {
        for model in ["tiny_gla", "tiny_sa"] {
            let eng = engine(model, "chon");
            let long: Vec<u32> = (0..70).map(|i| 97 + (i % 19)).collect();
            let mut sess = eng.new_session();
            eng.prefill(&mut sess, &long);
            let bytes = eng.serialize_session(&sess);
            let mut back = eng.restore_session(&bytes).unwrap();
            assert_eq!(back.pos, sess.pos);
            assert_eq!(back.kv_cost_tokens(), sess.kv_cost_tokens());
            // identical continuation, bit for bit
            let la = eng.decode_step(&mut [&mut sess], &[104]);
            let lb = eng.decode_step(&mut [&mut back], &[104]);
            assert_eq!(la.data, lb.data, "{model}");
            // and the serialized form is stable under a second round-trip
            let again = eng.restore_session(&bytes).unwrap();
            assert_eq!(bytes, eng.serialize_session(&again));
        }
    }

    /// Corrupt session blobs are rejected, not misread.
    #[test]
    fn corrupt_session_blobs_rejected() {
        let eng = engine("tiny_sa", "bf16");
        let mut sess = eng.new_session();
        eng.prefill(&mut sess, &[97, 98, 99]);
        let bytes = eng.serialize_session(&sess);
        assert!(eng.restore_session(&bytes[..bytes.len() - 3]).is_err());
        assert!(eng.restore_session(b"NOTASESS").is_err());
        let mut wrong_arch = bytes.clone();
        wrong_arch[8] ^= 1;
        assert!(eng.restore_session(&wrong_arch).is_err());
        let mut trailing = bytes.clone();
        trailing.push(0);
        assert!(eng.restore_session(&trailing).is_err());
    }

    /// `--obs-outliers` taps observe HCP rows without perturbing decode:
    /// an instrumented engine is bit-identical to an uninstrumented one,
    /// and the taps fill with rows/hits/energy on the HCP-compensated ops.
    #[test]
    fn outlier_taps_record_without_changing_decode() {
        let plain = engine("tiny_gla", "chon");
        let mut tapped = engine("tiny_gla", "chon");
        let taps = tapped.build_outlier_obs();
        tapped.attach_outlier_obs(taps.clone());

        let toks: Vec<u32> = (0..8).map(|i| 97 + i).collect();
        let mut sp = plain.new_session();
        let mut st = tapped.new_session();
        let lp = plain.prefill(&mut sp, &toks);
        let lt = tapped.prefill(&mut st, &toks);
        assert_eq!(lp, lt, "observer must not perturb the forward");

        let q = taps.tap("attn.q").expect("attn.q tap");
        // tiny_gla layer 0 runs attn.q under NVFP4+HCP; 8 prompt tokens
        // → 8 observed rows through that layer
        assert_eq!(q.rows.get(), 8);
        assert!(q.hits.iter().map(|c| c.get()).sum::<u64>() >= 8);
        assert!(q.resid_energy.get() >= q.hot_energy.get());
        assert!(q.hot_energy.get() > 0.0);
        // wscore is frozen at build time from the prepared weights
        assert!(q.wscore.iter().any(|&v| v > 0.0));
        // post-QK-protected ops (attn.gk under GLA) run BF16 → no rows
        let gk = taps.tap("attn.gk").expect("attn.gk tap");
        assert_eq!(gk.rows.get(), 0);
    }

    /// `--packed-compute` greedy decode must be bit-identical between
    /// batch-of-1 and batch-of-8 (the serve contract holds in the new
    /// recipe mode too, for HCP and non-HCP recipes and both archs).
    #[test]
    fn packed_compute_decode_is_bit_identical_across_batch_sizes() {
        for (model, rec_name) in
            [("tiny_gla", "chon"), ("tiny_gla", "nvfp4"), ("tiny_sa", "nvfp4")]
        {
            let eng = engine_packed(model, rec_name);
            let prompts: Vec<Vec<u32>> = (0..8)
                .map(|i| (0..6).map(|j| 97 + ((i * 5 + j) % 20)).collect())
                .collect();
            // one-by-one
            let mut solo_out = Vec::new();
            for p in &prompts {
                let mut s = eng.new_session();
                let logits = eng.prefill(&mut s, p);
                let mut rng = Rng::new(1);
                let mut toks = vec![eng.sample(&logits, 0.0, &mut rng)];
                for _ in 0..5 {
                    let last = *toks.last().unwrap();
                    let l = eng.decode_step(&mut [&mut s], &[last]);
                    toks.push(eng.sample(l.row(0), 0.0, &mut rng));
                }
                solo_out.push(toks);
            }
            // batch of 8
            let mut sessions: Vec<Session> = Vec::new();
            let mut last_toks: Vec<u32> = Vec::new();
            let mut batched_out: Vec<Vec<u32>> = Vec::new();
            for p in &prompts {
                let mut s = eng.new_session();
                let logits = eng.prefill(&mut s, p);
                let mut rng = Rng::new(1);
                let t = eng.sample(&logits, 0.0, &mut rng);
                batched_out.push(vec![t]);
                last_toks.push(t);
                sessions.push(s);
            }
            for _ in 0..5 {
                let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                let l = eng.decode_step(&mut refs, &last_toks);
                let mut rng = Rng::new(1);
                for i in 0..prompts.len() {
                    let t = eng.sample(l.row(i), 0.0, &mut rng);
                    batched_out[i].push(t);
                    last_toks[i] = t;
                }
            }
            assert_eq!(solo_out, batched_out, "{model}/{rec_name}");
        }
    }

    /// Packed mode must actually shrink resident weight memory, and both
    /// modes must report a usable gauge value + mode label.
    #[test]
    fn packed_compute_reports_smaller_weight_bytes() {
        let dense = engine("tiny_gla", "nvfp4");
        let packed = engine_packed("tiny_gla", "nvfp4");
        assert_eq!(dense.compute_mode(), "f32");
        assert_eq!(packed.compute_mode(), "packed");
        assert!(dense.weight_bytes() > 0);
        assert!(
            packed.weight_bytes() * 2 < dense.weight_bytes(),
            "packed {} vs f32 {}",
            packed.weight_bytes(),
            dense.weight_bytes()
        );
        // packed decode still produces sane output
        let mut s = packed.new_session();
        let logits = packed.prefill(&mut s, &[104, 105]);
        assert!(logits.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn temperature_sampling_stays_in_vocab_and_varies() {
        let eng = engine("tiny_gla", "bf16");
        let mut sess = eng.new_session();
        let logits = eng.prefill(&mut sess, &[104, 101, 108]);
        let mut rng = Rng::new(3);
        let draws: Vec<u32> =
            (0..64).map(|_| eng.sample(&logits, 1.5, &mut rng)).collect();
        assert!(draws.iter().all(|&t| (t as usize) < eng.cfg.vocab));
        assert!(
            draws.iter().any(|&t| t != draws[0]),
            "temperature sampling produced a constant"
        );
    }
}
