//! Request batching: coalesce concurrent generation requests into one
//! decode batch and fan the streamed tokens back out per request.
//!
//! A single engine thread owns the model. Incoming requests queue on a
//! channel; the loop admits up to `max_batch` of them (waiting at most
//! `max_wait` to fill a fresh batch — the WIND-style latency/throughput
//! knob), prefills each prompt, then steps all active sessions together.
//! Sessions join and leave the batch independently (continuous batching),
//! so one long generation never blocks short ones behind it. Because the
//! engine's forward path is batch-invariant, coalescing is purely a
//! throughput optimization — it never changes any request's output.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::serve::engine::{Engine, Session};
use crate::util::prng::Rng;

/// One queued generation request.
pub struct GenRequest {
    pub prompt: String,
    pub max_tokens: usize,
    pub temp: f32,
    /// streamed token pieces + terminal event go back through here
    pub reply: Sender<TokenEvent>,
}

/// Events fanned back to the submitting connection.
#[derive(Clone, Debug, PartialEq)]
pub enum TokenEvent {
    /// one decoded token piece as raw bytes, in generation order. Bytes,
    /// not String: a multi-byte character split across byte-level tokens
    /// must reach the client intact, and UTF-8-lossy conversion is only
    /// valid once over the fully assembled sequence.
    Token(Vec<u8>),
    Done {
        n_tokens: usize,
        gen_ms: f64,
    },
    Error(String),
}

/// Lock-free serve counters (read by the STATS command).
#[derive(Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub tokens: AtomicU64,
    pub decode_steps: AtomicU64,
    /// decode steps that ran with more than one session
    pub batched_steps: AtomicU64,
    /// Σ batch size over decode steps (mean = batch_sum / decode_steps)
    pub batch_sum: AtomicU64,
    pub max_batch: AtomicU64,
}

impl ServeStats {
    pub fn mean_batch(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.batch_sum.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// The one-line STATS payload.
    pub fn snapshot_line(&self) -> String {
        format!(
            "requests={} tokens={} decode_steps={} batched_steps={} \
             mean_batch={:.3} max_batch={}",
            self.requests.load(Ordering::Relaxed),
            self.tokens.load(Ordering::Relaxed),
            self.decode_steps.load(Ordering::Relaxed),
            self.batched_steps.load(Ordering::Relaxed),
            self.mean_batch(),
            self.max_batch.load(Ordering::Relaxed),
        )
    }
}

/// One in-flight generation inside the engine loop.
struct Active {
    sess: Session,
    req: GenRequest,
    last: u32,
    produced: usize,
    rng: Rng,
    t0: Instant,
}

/// The engine thread + its submission handle.
pub struct RequestBatcher {
    tx: Sender<GenRequest>,
    pub stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl RequestBatcher {
    /// Spawn the engine loop. `max_wait` bounds how long a fresh batch
    /// waits for companions before decoding starts; `seed` drives
    /// temperature sampling (greedy requests ignore it).
    pub fn spawn(
        engine: Engine,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
    ) -> RequestBatcher {
        let (tx, rx) = channel::<GenRequest>();
        let stats = Arc::new(ServeStats::default());
        let shutdown = Arc::new(AtomicBool::new(false));
        let (stats2, shutdown2) = (stats.clone(), shutdown.clone());
        let handle = std::thread::spawn(move || {
            engine_loop(engine, rx, stats2, shutdown2, max_batch.max(1), max_wait, seed);
        });
        RequestBatcher { tx, stats, shutdown, handle: Some(handle) }
    }

    /// A cloneable submission handle for connection threads.
    pub fn submitter(&self) -> Sender<GenRequest> {
        self.tx.clone()
    }

    /// Signal shutdown and wait for in-flight generations to finish.
    pub fn shutdown(mut self) {
        self.shutdown.store(true, Ordering::SeqCst);
        // drop our sender so the loop's queue can disconnect
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

fn engine_loop(
    engine: Engine,
    rx: Receiver<GenRequest>,
    stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    max_batch: usize,
    max_wait: Duration,
    seed: u64,
) {
    let mut active: Vec<Active> = Vec::new();
    let mut next_id: u64 = 0;

    let admit = |active: &mut Vec<Active>, req: GenRequest, next_id: &mut u64| {
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let toks = engine.tokenizer.encode(&req.prompt);
        if toks.is_empty() {
            let _ = req.reply.send(TokenEvent::Error("empty prompt".into()));
            return;
        }
        let t0 = Instant::now();
        let mut sess = engine.new_session();
        let logits = engine.prefill(&mut sess, &toks);
        let mut rng = Rng::new(seed ^ 0x5E2E).fold_in(*next_id);
        *next_id += 1;
        let first = engine.sample(&logits, req.temp, &mut rng);
        let mut a = Active { sess, req, last: first, produced: 0, rng, t0 };
        emit_token(&engine, &stats, &mut a);
        if a.produced < a.req.max_tokens {
            active.push(a);
        } else {
            finish(a);
        }
    };

    loop {
        // ---- admission ----
        if shutdown.load(Ordering::SeqCst) {
            // drain the queue: reject newcomers, finish what is active
            while let Ok(req) = rx.try_recv() {
                let _ = req
                    .reply
                    .send(TokenEvent::Error("server shutting down".into()));
            }
            if active.is_empty() {
                break;
            }
        } else if active.is_empty() {
            // idle: block (with a poll tick so shutdown is noticed), then
            // hold the batch open for up to max_wait to coalesce arrivals
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(req) => {
                    admit(&mut active, req, &mut next_id);
                    let deadline = Instant::now() + max_wait;
                    while active.len() < max_batch {
                        let left = deadline.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        match rx.recv_timeout(left) {
                            Ok(req) => admit(&mut active, req, &mut next_id),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    if active.is_empty() {
                        break;
                    }
                }
            }
        } else {
            // continuous batching: top up free slots without waiting
            while active.len() < max_batch {
                match rx.try_recv() {
                    Ok(req) => admit(&mut active, req, &mut next_id),
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                        break
                    }
                }
            }
        }
        if active.is_empty() {
            continue;
        }

        // ---- one decode step over the whole batch ----
        let n = active.len() as u64;
        stats.decode_steps.fetch_add(1, Ordering::Relaxed);
        stats.batch_sum.fetch_add(n, Ordering::Relaxed);
        stats.max_batch.fetch_max(n, Ordering::Relaxed);
        if n > 1 {
            stats.batched_steps.fetch_add(1, Ordering::Relaxed);
        }
        let tokens: Vec<u32> = active.iter().map(|a| a.last).collect();
        let logits = {
            let mut refs: Vec<&mut Session> =
                active.iter_mut().map(|a| &mut a.sess).collect();
            engine.decode_step(&mut refs, &tokens)
        };
        for (i, a) in active.iter_mut().enumerate() {
            a.last = engine.sample(logits.row(i), a.req.temp, &mut a.rng);
            emit_token(&engine, &stats, a);
        }
        // retire finished sessions (swap_remove without advancing i)
        let mut i = 0;
        while i < active.len() {
            if active[i].produced >= active[i].req.max_tokens {
                let a = active.swap_remove(i);
                finish(a);
            } else {
                i += 1;
            }
        }
    }
}

/// Send `a.last` to the requester (drops silently if it hung up).
fn emit_token(engine: &Engine, stats: &Arc<ServeStats>, a: &mut Active) {
    let piece = engine.tokenizer.decode_bytes(&[a.last]);
    a.produced += 1;
    stats.tokens.fetch_add(1, Ordering::Relaxed);
    if a.req.reply.send(TokenEvent::Token(piece)).is_err() {
        // requester gone: cut the generation short
        a.produced = a.req.max_tokens;
    }
}

fn finish(a: Active) {
    let _ = a.req.reply.send(TokenEvent::Done {
        n_tokens: a.produced,
        gen_ms: a.t0.elapsed().as_secs_f64() * 1e3,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::Tokenizer;
    use crate::runtime::native::model::{init_params, model_cfg};
    use crate::runtime::native::recipe::recipe;

    fn test_engine() -> Engine {
        let cfg = model_cfg("tiny_gla").unwrap();
        let params = init_params(&cfg, 3);
        Engine::from_parts(cfg, recipe("chon").unwrap(), Tokenizer::byte_level(), &params)
    }

    fn collect(rx: &Receiver<TokenEvent>) -> (Vec<u8>, usize) {
        let mut bytes = Vec::new();
        loop {
            match rx.recv_timeout(Duration::from_secs(30)).expect("event") {
                TokenEvent::Token(p) => bytes.extend(p),
                TokenEvent::Done { n_tokens, .. } => return (bytes, n_tokens),
                TokenEvent::Error(e) => panic!("unexpected error: {e}"),
            }
        }
    }

    #[test]
    fn single_request_completes() {
        let b = RequestBatcher::spawn(
            test_engine(),
            4,
            Duration::from_micros(500),
            0,
        );
        let (tx, rx) = channel();
        b.submitter()
            .send(GenRequest {
                prompt: "hello".into(),
                max_tokens: 8,
                temp: 0.0,
                reply: tx,
            })
            .unwrap();
        let (bytes, n) = collect(&rx);
        assert_eq!(n, 8);
        assert_eq!(bytes.len(), 8, "byte-level tokens are one byte each");
        b.shutdown();
    }

    #[test]
    fn empty_prompt_is_rejected() {
        let b = RequestBatcher::spawn(
            test_engine(),
            4,
            Duration::from_micros(500),
            0,
        );
        let (tx, rx) = channel();
        b.submitter()
            .send(GenRequest {
                prompt: String::new(),
                max_tokens: 4,
                temp: 0.0,
                reply: tx,
            })
            .unwrap();
        match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            TokenEvent::Error(e) => assert!(e.contains("empty"), "{e}"),
            other => panic!("expected error, got {other:?}"),
        }
        b.shutdown();
    }
}
