//! Request batching: coalesce concurrent generation requests into one
//! decode batch and fan the streamed tokens back out per request.
//!
//! A single engine thread owns the model and the named-session cache.
//! Incoming requests queue on a channel; the loop admits up to
//! `max_batch` of them (waiting at most `max_wait` to fill a fresh batch
//! — the WIND-style latency/throughput knob), prefills the whole admitted
//! group in ONE cross-session batched pass (`Engine::prefill_batch`:
//! token-step t advances every waiting prompt at once, so N new requests
//! cost ~one prefill instead of N), then steps all active sessions
//! together. Sessions join and leave the batch independently (continuous
//! batching), so one long generation never blocks short ones behind it.
//! Because the engine's forward path is batch-invariant, coalescing is
//! purely a throughput optimization — it never changes any request's
//! output.
//!
//! Named sessions (`GenRequest::session`) persist across requests in a
//! `SessionStore`: checked out while generating, checked back in when
//! done, LRU-evicted to disk past `--max-resident-sessions` /
//! `--max-kv-tokens` and reloaded bit-exactly on their next request.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::obs::ModelObs;
use crate::serve::engine::{Engine, Session};
use crate::serve::pages::{SessionStore, StoreOpts};
use crate::serve::protocol::MAX_SESSION_TOKENS;
use crate::util::json::Json;
use crate::util::prng::Rng;

/// Receives streamed generation events. The blocking front ends hand
/// each request its own mpsc channel; the epoll reactor shares one
/// mailbox object across every connection (the sink carries the
/// connection/generation ids internally). `Err(())` from `send` means
/// the receiver is gone — the engine loop cuts the generation short,
/// exactly as it does for a hung-up channel.
pub trait EventSink: Send + Sync {
    fn send(&self, ev: TokenEvent) -> Result<(), ()>;
}

/// How a generation's events travel back to whoever submitted it.
#[derive(Clone)]
pub struct ReplySink(SinkImpl);

#[derive(Clone)]
enum SinkImpl {
    Channel(Sender<TokenEvent>),
    Shared(Arc<dyn EventSink>),
}

impl ReplySink {
    /// One dedicated channel per request (blocking front ends, tests).
    pub fn channel(tx: Sender<TokenEvent>) -> ReplySink {
        ReplySink(SinkImpl::Channel(tx))
    }

    /// A shared sink that multiplexes many generations (the reactor's
    /// mailbox): the sink itself knows which generation it belongs to.
    pub fn shared(sink: Arc<dyn EventSink>) -> ReplySink {
        ReplySink(SinkImpl::Shared(sink))
    }

    pub fn send(&self, ev: TokenEvent) -> Result<(), ()> {
        match &self.0 {
            SinkImpl::Channel(tx) => tx.send(ev).map_err(|_| ()),
            SinkImpl::Shared(s) => s.send(ev),
        }
    }
}

/// One queued generation request.
pub struct GenRequest {
    pub prompt: String,
    pub max_tokens: usize,
    pub temp: f32,
    /// named-session id: state persists across requests under this key
    /// (None = ephemeral, state dropped when the generation finishes)
    pub session: Option<String>,
    /// streamed token pieces + terminal event go back through here
    pub reply: ReplySink,
    /// set by the submitting connection when the client gave up (reply
    /// timeout or a failed write back to the socket). A queued request
    /// whose flag is set is *dropped* before admission instead of
    /// executed — so an abandoned request can no longer advance a named
    /// session behind its client's back (counted in `ServeStats::
    /// cancelled`). std's `Sender` cannot probe for a hung-up `Receiver`
    /// without sending, hence the explicit flag.
    pub cancel: Arc<AtomicBool>,
    /// when the request entered the queue (stamped by the submitter) —
    /// admission computes the queue-wait span from it. A request that
    /// waits through a model load/reload correctly charges that wait to
    /// queue time.
    pub queued_at: Instant,
}

/// Events fanned back to the submitting connection.
#[derive(Clone, Debug, PartialEq)]
pub enum TokenEvent {
    /// one decoded token piece as raw bytes, in generation order. Bytes,
    /// not String: a multi-byte character split across byte-level tokens
    /// must reach the client intact, and UTF-8-lossy conversion is only
    /// valid once over the fully assembled sequence.
    Token(Vec<u8>),
    Done {
        n_tokens: usize,
        gen_ms: f64,
    },
    Error(String),
    /// server-initiated retryable rejection: the request was queued but
    /// its model went away before admission (LRU unload, reload race,
    /// shutdown drain). The request never ran, so resubmitting is always
    /// safe — wire contract is `ERR retry: ...` on TCP and HTTP 503.
    Retry(String),
}

/// Lock-free serve counters (read by STATS and `GET /stats`).
#[derive(Default)]
pub struct ServeStats {
    pub requests: AtomicU64,
    pub tokens: AtomicU64,
    pub decode_steps: AtomicU64,
    /// decode steps that ran with more than one session
    pub batched_steps: AtomicU64,
    /// Σ batch size over decode steps (mean = batch_sum / decode_steps)
    pub batch_sum: AtomicU64,
    pub max_batch: AtomicU64,
    /// prefill token-steps (one forward pass each, any batch size)
    pub prefill_steps: AtomicU64,
    /// prefill token-steps that advanced 2+ prompts at once
    pub prefill_batched_steps: AtomicU64,
    /// prompt tokens consumed by prefill
    pub prefill_tokens: AtomicU64,
    /// cumulative sessions spilled to disk
    pub evictions: AtomicU64,
    /// cumulative sessions reloaded from disk
    pub reloads: AtomicU64,
    /// gauge: idle named sessions currently in memory
    pub resident_sessions: AtomicU64,
    /// gauge: idle named sessions currently on disk
    pub spilled_sessions: AtomicU64,
    /// gauge: KV positions held by resident idle sessions
    pub resident_kv_tokens: AtomicU64,
    /// queued requests dropped before admission because the client had
    /// already given up (see `GenRequest::cancel`)
    pub cancelled: AtomicU64,
    /// queued requests completed with `TokenEvent::Retry` because their
    /// model was unloaded / reloaded / drained before admission — they
    /// never ran and are safe to resubmit
    pub retry_rejects: AtomicU64,
    /// Σ µs admitted requests spent queued before admission
    /// (mean = queue_wait_us_total / requests)
    pub queue_wait_us_total: AtomicU64,
    /// Σ µs spent inside `Engine::decode_step`
    /// (mean per step = decode_us_total / decode_steps)
    pub decode_us_total: AtomicU64,
}

impl ServeStats {
    pub fn mean_batch(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.batch_sum.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// Mean µs an admitted request waited in queue.
    pub fn mean_queue_wait_us(&self) -> f64 {
        let reqs = self.requests.load(Ordering::Relaxed);
        if reqs == 0 {
            return 0.0;
        }
        self.queue_wait_us_total.load(Ordering::Relaxed) as f64 / reqs as f64
    }

    /// Mean µs per decode step.
    pub fn mean_decode_us(&self) -> f64 {
        let steps = self.decode_steps.load(Ordering::Relaxed);
        if steps == 0 {
            return 0.0;
        }
        self.decode_us_total.load(Ordering::Relaxed) as f64 / steps as f64
    }

    /// The one-line STATS payload.
    pub fn snapshot_line(&self) -> String {
        let g = |a: &AtomicU64| a.load(Ordering::Relaxed);
        format!(
            "requests={} tokens={} decode_steps={} batched_steps={} \
             mean_batch={:.3} max_batch={} prefill_steps={} \
             prefill_batched_steps={} prefill_tokens={} evictions={} \
             reloads={} resident_sessions={} spilled_sessions={} \
             resident_kv_tokens={} cancelled={} retry_rejects={} \
             queue_wait_us_total={} decode_us_total={}",
            g(&self.requests),
            g(&self.tokens),
            g(&self.decode_steps),
            g(&self.batched_steps),
            self.mean_batch(),
            g(&self.max_batch),
            g(&self.prefill_steps),
            g(&self.prefill_batched_steps),
            g(&self.prefill_tokens),
            g(&self.evictions),
            g(&self.reloads),
            g(&self.resident_sessions),
            g(&self.spilled_sessions),
            g(&self.resident_kv_tokens),
            g(&self.cancelled),
            g(&self.retry_rejects),
            g(&self.queue_wait_us_total),
            g(&self.decode_us_total),
        )
    }

    /// The `GET /stats` payload.
    pub fn snapshot_json(&self) -> Json {
        let n = |a: &AtomicU64| Json::Num(a.load(Ordering::Relaxed) as f64);
        Json::Obj(vec![
            ("requests".into(), n(&self.requests)),
            ("tokens".into(), n(&self.tokens)),
            ("decode_steps".into(), n(&self.decode_steps)),
            ("batched_steps".into(), n(&self.batched_steps)),
            ("mean_batch".into(), Json::Num(self.mean_batch())),
            ("max_batch".into(), n(&self.max_batch)),
            ("prefill_steps".into(), n(&self.prefill_steps)),
            ("prefill_batched_steps".into(), n(&self.prefill_batched_steps)),
            ("prefill_tokens".into(), n(&self.prefill_tokens)),
            ("evictions".into(), n(&self.evictions)),
            ("reloads".into(), n(&self.reloads)),
            ("resident_sessions".into(), n(&self.resident_sessions)),
            ("spilled_sessions".into(), n(&self.spilled_sessions)),
            ("resident_kv_tokens".into(), n(&self.resident_kv_tokens)),
            ("cancelled".into(), n(&self.cancelled)),
            ("retry_rejects".into(), n(&self.retry_rejects)),
            // appended fields (existing fields above stay byte-stable)
            ("queue_wait_us_total".into(), n(&self.queue_wait_us_total)),
            ("decode_us_total".into(), n(&self.decode_us_total)),
            ("queue_wait_us_mean".into(), Json::Num(self.mean_queue_wait_us())),
            ("decode_us_mean".into(), Json::Num(self.mean_decode_us())),
        ])
    }

    /// Sum a set of per-model counters into one aggregate view (gauges
    /// sum; `max_batch` takes the max; `mean_batch` falls out of the
    /// summed numerator/denominator). The registry uses this to keep the
    /// one-line `STATS` payload and the top-level `/stats` fields stable
    /// across the single-model → multi-model transition.
    pub fn merged<'a>(all: impl IntoIterator<Item = &'a ServeStats>) -> ServeStats {
        let m = ServeStats::default();
        for s in all {
            let add = |dst: &AtomicU64, src: &AtomicU64| {
                dst.fetch_add(src.load(Ordering::Relaxed), Ordering::Relaxed);
            };
            add(&m.requests, &s.requests);
            add(&m.tokens, &s.tokens);
            add(&m.decode_steps, &s.decode_steps);
            add(&m.batched_steps, &s.batched_steps);
            add(&m.batch_sum, &s.batch_sum);
            m.max_batch
                .fetch_max(s.max_batch.load(Ordering::Relaxed), Ordering::Relaxed);
            add(&m.prefill_steps, &s.prefill_steps);
            add(&m.prefill_batched_steps, &s.prefill_batched_steps);
            add(&m.prefill_tokens, &s.prefill_tokens);
            add(&m.evictions, &s.evictions);
            add(&m.reloads, &s.reloads);
            add(&m.resident_sessions, &s.resident_sessions);
            add(&m.spilled_sessions, &s.spilled_sessions);
            add(&m.resident_kv_tokens, &s.resident_kv_tokens);
            add(&m.cancelled, &s.cancelled);
            add(&m.retry_rejects, &s.retry_rejects);
            add(&m.queue_wait_us_total, &s.queue_wait_us_total);
            add(&m.decode_us_total, &s.decode_us_total);
        }
        m
    }
}

/// One in-flight generation inside the engine loop.
struct Active {
    sess: Session,
    req: GenRequest,
    last: u32,
    produced: usize,
    rng: Rng,
    t0: Instant,
}

/// Engine-loop knobs bundled so the loop signature stays readable.
struct LoopCfg {
    max_batch: usize,
    max_wait: Duration,
    seed: u64,
}

/// The engine thread + its submission handle.
pub struct RequestBatcher {
    tx: Sender<GenRequest>,
    pub stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<(SessionStore, Vec<GenRequest>)>>,
}

impl RequestBatcher {
    /// Spawn the engine loop. `max_wait` bounds how long a fresh batch
    /// waits for companions before decoding starts; `seed` drives
    /// temperature sampling (greedy requests ignore it); `store_opts`
    /// configures the named-session cache (residency limits + spill dir
    /// — creating the spill dir is the only fallible step).
    pub fn spawn(
        engine: Engine,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
        store_opts: StoreOpts,
    ) -> Result<RequestBatcher> {
        let store = SessionStore::new(store_opts)?;
        Ok(Self::spawn_with(
            engine,
            max_batch,
            max_wait,
            seed,
            store,
            Arc::new(ServeStats::default()),
        ))
    }

    /// Spawn with a caller-owned session store and counter set — the
    /// registry's engine-swap path: the store (and its spilled sessions)
    /// and the cumulative stats both survive a model unload/hot-reload,
    /// only the engine thread is replaced.
    pub fn spawn_with(
        engine: Engine,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
        store: SessionStore,
        stats: Arc<ServeStats>,
    ) -> RequestBatcher {
        Self::spawn_full(engine, max_batch, max_wait, seed, store, stats, None)
    }

    /// `spawn_with` plus the model's stage-latency histograms. `None`
    /// runs the loop with metrics fully off — the off-leg of the
    /// `serve_metrics_overhead` bench and the default for embedders that
    /// never scrape.
    pub fn spawn_full(
        engine: Engine,
        max_batch: usize,
        max_wait: Duration,
        seed: u64,
        store: SessionStore,
        stats: Arc<ServeStats>,
        obs: Option<Arc<ModelObs>>,
    ) -> RequestBatcher {
        let (tx, rx) = channel::<GenRequest>();
        let shutdown = Arc::new(AtomicBool::new(false));
        let (stats2, shutdown2) = (stats.clone(), shutdown.clone());
        let cfg = LoopCfg { max_batch: max_batch.max(1), max_wait, seed };
        let handle = std::thread::spawn(move || {
            engine_loop(engine, rx, stats2, shutdown2, cfg, store, obs)
        });
        RequestBatcher { tx, stats, shutdown, handle: Some(handle) }
    }

    /// A cloneable submission handle for connection threads.
    pub fn submitter(&self) -> Sender<GenRequest> {
        self.tx.clone()
    }

    /// Signal shutdown, wait for in-flight generations to finish, and
    /// hand back the session store plus any requests that were still
    /// queued (never admitted). The caller decides their fate: a final
    /// server drain rejects them with an error; a registry hot-reload
    /// re-submits them to the replacement engine (they had not started,
    /// so "new admissions get the new weights" applies to them too).
    pub fn shutdown(mut self) -> (Option<SessionStore>, Vec<GenRequest>) {
        self.shutdown.store(true, Ordering::SeqCst);
        // drop our sender so the loop's queue can disconnect
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        match self.handle.take().map(|h| h.join()) {
            Some(Ok((store, leftovers))) => (Some(store), leftovers),
            _ => (None, Vec::new()),
        }
    }
}

fn engine_loop(
    engine: Engine,
    rx: Receiver<GenRequest>,
    stats: Arc<ServeStats>,
    shutdown: Arc<AtomicBool>,
    cfg: LoopCfg,
    mut store: SessionStore,
    obs: Option<Arc<ModelObs>>,
) -> (SessionStore, Vec<GenRequest>) {
    let mut active: Vec<Active> = Vec::new();
    let mut leftovers: Vec<GenRequest> = Vec::new();
    let mut next_id: u64 = 0;

    loop {
        // ---- collect a group of newly arrived requests ----
        let mut group: Vec<GenRequest> = Vec::new();
        if shutdown.load(Ordering::SeqCst) {
            // drain: stop admitting, finish what is active, return the
            // still-queued requests to whoever asked us to stop
            while let Ok(req) = rx.try_recv() {
                leftovers.push(req);
            }
            if active.is_empty() {
                break;
            }
        } else if active.is_empty() {
            // idle: block (with a poll tick so shutdown is noticed), then
            // hold the batch open for up to max_wait to coalesce arrivals
            match rx.recv_timeout(Duration::from_millis(20)) {
                Ok(req) => {
                    group.push(req);
                    let deadline = Instant::now() + cfg.max_wait;
                    while group.len() < cfg.max_batch {
                        let left = deadline.saturating_duration_since(Instant::now());
                        if left.is_zero() {
                            break;
                        }
                        match rx.recv_timeout(left) {
                            Ok(req) => group.push(req),
                            Err(RecvTimeoutError::Timeout) => break,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }
                }
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => {
                    if active.is_empty() {
                        break;
                    }
                }
            }
        } else {
            // continuous batching: top up free slots without waiting
            while active.len() + group.len() < cfg.max_batch {
                match rx.try_recv() {
                    Ok(req) => group.push(req),
                    Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => {
                        break
                    }
                }
            }
        }
        if !group.is_empty() {
            admit_group(
                &engine,
                &stats,
                &mut store,
                &mut active,
                group,
                &mut next_id,
                cfg.seed,
                obs.as_deref(),
            );
            sync_gauges(&stats, &store);
        }
        if active.is_empty() {
            continue;
        }

        // ---- one decode step over the whole batch ----
        let n = active.len() as u64;
        stats.decode_steps.fetch_add(1, Ordering::Relaxed);
        stats.batch_sum.fetch_add(n, Ordering::Relaxed);
        stats.max_batch.fetch_max(n, Ordering::Relaxed);
        if n > 1 {
            stats.batched_steps.fetch_add(1, Ordering::Relaxed);
        }
        let tokens: Vec<u32> = active.iter().map(|a| a.last).collect();
        let step_t0 = Instant::now();
        let logits = {
            let mut refs: Vec<&mut Session> =
                active.iter_mut().map(|a| &mut a.sess).collect();
            engine.decode_step(&mut refs, &tokens)
        };
        let step_us = step_t0.elapsed().as_micros() as u64;
        stats.decode_us_total.fetch_add(step_us, Ordering::Relaxed);
        if let Some(o) = &obs {
            o.decode_token.record(step_us);
        }
        for (i, a) in active.iter_mut().enumerate() {
            a.last = engine.sample(logits.row(i), a.req.temp, &mut a.rng);
            emit_token(&engine, &stats, a);
        }
        // retire finished sessions (swap_remove without advancing i)
        let mut i = 0;
        let mut retired = false;
        while i < active.len() {
            if active[i].produced >= active[i].req.max_tokens {
                let a = active.swap_remove(i);
                finish(&engine, &mut store, a);
                retired = true;
            } else {
                i += 1;
            }
        }
        if retired {
            sync_gauges(&stats, &store);
        }
    }
    (store, leftovers)
}

/// Validate, check out session state and batch-prefill one admitted
/// group, pushing the survivors onto the active list.
fn admit_group(
    engine: &Engine,
    stats: &Arc<ServeStats>,
    store: &mut SessionStore,
    active: &mut Vec<Active>,
    group: Vec<GenRequest>,
    next_id: &mut u64,
    seed: u64,
    obs: Option<&ModelObs>,
) {
    let mut reqs: Vec<GenRequest> = Vec::new();
    let mut prompts: Vec<Vec<u32>> = Vec::new();
    let mut sessions: Vec<Session> = Vec::new();
    let admit_now = Instant::now();
    for req in group {
        if req.cancel.load(Ordering::Relaxed) {
            // the client already gave up (timeout / dropped connection):
            // executing would burn a decode slot and — worse — advance a
            // named session nobody is reading. Drop before admission.
            stats.cancelled.fetch_add(1, Ordering::Relaxed);
            continue;
        }
        stats.requests.fetch_add(1, Ordering::Relaxed);
        let waited_us = admit_now
            .saturating_duration_since(req.queued_at)
            .as_micros() as u64;
        stats.queue_wait_us_total.fetch_add(waited_us, Ordering::Relaxed);
        if let Some(o) = obs {
            o.queue_wait.record(waited_us);
        }
        let toks = engine.tokenizer.encode(&req.prompt);
        if toks.is_empty() {
            let _ = req.reply.send(TokenEvent::Error("empty prompt".into()));
            continue;
        }
        let sess = match &req.session {
            Some(id) => {
                let busy = active
                    .iter()
                    .any(|a| a.req.session.as_deref() == Some(id.as_str()))
                    || reqs
                        .iter()
                        .any(|r| r.session.as_deref() == Some(id.as_str()));
                if busy {
                    let _ = req.reply.send(TokenEvent::Error(format!(
                        "session {id} is busy"
                    )));
                    continue;
                }
                match store.take(id, engine) {
                    Ok(Some(s)) => s,
                    Ok(None) => engine.new_session(),
                    Err(e) => {
                        let _ = req.reply.send(TokenEvent::Error(format!(
                            "session {id}: {e:#}"
                        )));
                        continue;
                    }
                }
            }
            None => engine.new_session(),
        };
        if sess.pos + toks.len() + req.max_tokens > MAX_SESSION_TOKENS {
            // hand a named session back untouched before rejecting
            if let Some(id) = &req.session {
                let _ = store.put(id, sess, engine);
            }
            let _ = req.reply.send(TokenEvent::Error(format!(
                "session context would exceed {MAX_SESSION_TOKENS} tokens"
            )));
            continue;
        }
        reqs.push(req);
        prompts.push(toks);
        sessions.push(sess);
    }
    if reqs.is_empty() {
        return;
    }

    // prefill accounting: step t advances every prompt longer than t
    let max_len = prompts.iter().map(|p| p.len()).max().unwrap_or(0);
    for t in 0..max_len {
        let width = prompts.iter().filter(|p| p.len() > t).count();
        stats.prefill_steps.fetch_add(1, Ordering::Relaxed);
        if width >= 2 {
            stats.prefill_batched_steps.fetch_add(1, Ordering::Relaxed);
        }
    }
    let total: u64 = prompts.iter().map(|p| p.len() as u64).sum();
    stats.prefill_tokens.fetch_add(total, Ordering::Relaxed);

    // one cross-session batched prefill pass over the admitted group
    let t0 = Instant::now();
    let logits = {
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        let ps: Vec<&[u32]> = prompts.iter().map(|p| p.as_slice()).collect();
        engine.prefill_batch(&mut refs, &ps)
    };
    if let Some(o) = obs {
        o.prefill.record_elapsed(t0.elapsed());
    }
    for ((req, sess), lg) in reqs.into_iter().zip(sessions).zip(logits) {
        let mut rng = Rng::new(seed ^ 0x5E2E).fold_in(*next_id);
        *next_id += 1;
        let first = engine.sample(&lg, req.temp, &mut rng);
        let mut a = Active { sess, req, last: first, produced: 0, rng, t0 };
        emit_token(engine, stats, &mut a);
        if a.produced < a.req.max_tokens {
            active.push(a);
        } else {
            finish(engine, store, a);
        }
    }
}

/// Mirror the store's counters/gauges into the lock-free stats.
fn sync_gauges(stats: &ServeStats, store: &SessionStore) {
    stats.evictions.store(store.evictions, Ordering::Relaxed);
    stats.reloads.store(store.reloads, Ordering::Relaxed);
    stats
        .resident_sessions
        .store(store.resident_len() as u64, Ordering::Relaxed);
    stats
        .spilled_sessions
        .store(store.spilled_len() as u64, Ordering::Relaxed);
    stats
        .resident_kv_tokens
        .store(store.resident_kv_tokens() as u64, Ordering::Relaxed);
}

/// Send `a.last` to the requester (drops silently if it hung up).
fn emit_token(engine: &Engine, stats: &Arc<ServeStats>, a: &mut Active) {
    let piece = engine.tokenizer.decode_bytes(&[a.last]);
    a.produced += 1;
    stats.tokens.fetch_add(1, Ordering::Relaxed);
    if a.req.reply.send(TokenEvent::Token(piece)).is_err() {
        // requester gone: cut the generation short
        a.produced = a.req.max_tokens;
    }
}

/// Retire one generation: named sessions go back into the store (where
/// the LRU limits may spill them), ephemeral state is dropped.
fn finish(engine: &Engine, store: &mut SessionStore, a: Active) {
    let Active { sess, req, produced, t0, .. } = a;
    if let Some(id) = &req.session {
        if let Err(e) = store.put(id, sess, engine) {
            crate::warn!("failed to retain session {id}: {e:#}");
        }
    }
    let _ = req.reply.send(TokenEvent::Done {
        n_tokens: produced,
        gen_ms: t0.elapsed().as_secs_f64() * 1e3,
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::tokenizer::Tokenizer;
    use crate::runtime::native::model::{init_params, model_cfg};
    use crate::runtime::native::recipe::recipe;

    fn test_engine() -> Engine {
        let cfg = model_cfg("tiny_gla").unwrap();
        let params = init_params(&cfg, 3);
        Engine::from_parts(cfg, recipe("chon").unwrap(), Tokenizer::byte_level(), &params)
    }

    fn spawn_batcher(max_batch: usize) -> RequestBatcher {
        RequestBatcher::spawn(
            test_engine(),
            max_batch,
            Duration::from_micros(500),
            0,
            StoreOpts::default(),
        )
        .unwrap()
    }

    fn gen_req(prompt: &str, max_tokens: usize, session: Option<&str>) -> (GenRequest, Receiver<TokenEvent>) {
        let (tx, rx) = channel();
        (
            GenRequest {
                prompt: prompt.into(),
                max_tokens,
                temp: 0.0,
                session: session.map(|s| s.to_string()),
                reply: ReplySink::channel(tx),
                cancel: Arc::new(AtomicBool::new(false)),
                queued_at: Instant::now(),
            },
            rx,
        )
    }

    fn collect(rx: &Receiver<TokenEvent>) -> (Vec<u8>, usize) {
        let mut bytes = Vec::new();
        loop {
            match rx.recv_timeout(Duration::from_secs(30)).expect("event") {
                TokenEvent::Token(p) => bytes.extend(p),
                TokenEvent::Done { n_tokens, .. } => return (bytes, n_tokens),
                TokenEvent::Error(e) => panic!("unexpected error: {e}"),
                TokenEvent::Retry(e) => panic!("unexpected retry: {e}"),
            }
        }
    }

    /// The three snapshot views (`STATS` line, `/stats` JSON, `merged`)
    /// must agree with each other and with the raw atomics — including
    /// the timing totals and the means the JSON derives from them.
    #[test]
    fn stats_snapshot_consistency() {
        let s = ServeStats::default();
        s.requests.store(4, Ordering::Relaxed);
        s.decode_steps.store(10, Ordering::Relaxed);
        s.batch_sum.store(25, Ordering::Relaxed);
        s.queue_wait_us_total.store(2000, Ordering::Relaxed);
        s.decode_us_total.store(5000, Ordering::Relaxed);
        let line = s.snapshot_line();
        assert!(line.contains("queue_wait_us_total=2000"), "{line}");
        assert!(line.contains("decode_us_total=5000"), "{line}");
        let json = s.snapshot_json();
        let f = |k: &str| json.get(k).and_then(|v| v.as_f64()).unwrap();
        assert_eq!(f("requests"), 4.0);
        assert_eq!(f("queue_wait_us_total"), 2000.0);
        assert_eq!(f("decode_us_total"), 5000.0);
        assert_eq!(f("queue_wait_us_mean"), 500.0);
        assert_eq!(f("decode_us_mean"), 500.0);
        // derived means reconstruct the totals they came from
        assert_eq!(f("queue_wait_us_mean") * f("requests"), 2000.0);
        assert_eq!(f("decode_us_mean") * f("decode_steps"), 5000.0);
        // merged() carries the new totals through aggregation
        let m = ServeStats::merged([&s, &s]);
        assert_eq!(m.queue_wait_us_total.load(Ordering::Relaxed), 4000);
        assert_eq!(m.decode_us_total.load(Ordering::Relaxed), 10000);
        assert_eq!(m.mean_queue_wait_us(), 500.0);
    }

    /// After real traffic the timing totals are live (non-zero) and the
    /// per-request queue-wait mean is internally consistent with the
    /// counters it is derived from.
    #[test]
    fn stats_timing_totals_populate_under_traffic() {
        let b = spawn_batcher(4);
        let (req, rx) = gen_req("hello", 8, None);
        b.submitter().send(req).unwrap();
        collect(&rx);
        assert!(b.stats.decode_us_total.load(Ordering::Relaxed) > 0);
        assert_eq!(b.stats.requests.load(Ordering::Relaxed), 1);
        assert_eq!(
            b.stats.mean_queue_wait_us(),
            b.stats.queue_wait_us_total.load(Ordering::Relaxed) as f64
        );
        b.shutdown();
    }

    /// `spawn_full` with a ModelObs populates every batcher-side stage
    /// histogram; `None` leaves metrics fully off (the bench's off-leg).
    #[test]
    fn stage_histograms_populate_when_obs_attached() {
        let obs = Arc::new(ModelObs::default());
        let b = RequestBatcher::spawn_full(
            test_engine(),
            4,
            Duration::from_micros(500),
            0,
            SessionStore::new(StoreOpts::default()).unwrap(),
            Arc::new(ServeStats::default()),
            Some(obs.clone()),
        );
        let (req, rx) = gen_req("hello", 8, None);
        b.submitter().send(req).unwrap();
        collect(&rx);
        b.shutdown();
        assert_eq!(obs.queue_wait.snapshot().count(), 1);
        assert_eq!(obs.prefill.snapshot().count(), 1);
        // 8 tokens = 1 sampled off prefill logits + 7 decode steps
        assert_eq!(obs.decode_token.snapshot().count(), 7);
        assert_eq!(obs.write_flush.snapshot().count(), 0, "reactor-owned");
    }

    #[test]
    fn single_request_completes() {
        let b = spawn_batcher(4);
        let (req, rx) = gen_req("hello", 8, None);
        b.submitter().send(req).unwrap();
        let (bytes, n) = collect(&rx);
        assert_eq!(n, 8);
        assert_eq!(bytes.len(), 8, "byte-level tokens are one byte each");
        b.shutdown();
    }

    #[test]
    fn empty_prompt_is_rejected() {
        let b = spawn_batcher(4);
        let (req, rx) = gen_req("", 4, None);
        b.submitter().send(req).unwrap();
        match rx.recv_timeout(Duration::from_secs(10)).unwrap() {
            TokenEvent::Error(e) => assert!(e.contains("empty"), "{e}"),
            other => panic!("expected error, got {other:?}"),
        }
        b.shutdown();
    }

    /// A queued request whose client has given up (cancel flag set, reply
    /// receiver dropped) is dropped *before* admission: it never advances
    /// the named session it targeted, so the next real request sees the
    /// session exactly as the abandoning client left it.
    #[test]
    fn cancelled_queued_request_never_advances_a_session() {
        let eng = test_engine();
        // reference: the session's first (and only) turn, computed direct
        let prompt = "hello wor";
        let n = 6usize;
        let reference = {
            let mut sess = eng.new_session();
            let toks = eng.tokenizer.encode(prompt);
            let logits = eng.prefill(&mut sess, &toks);
            let mut rng = Rng::new(0);
            let mut last = eng.sample(&logits, 0.0, &mut rng);
            let mut out = eng.tokenizer.decode_bytes(&[last]);
            for _ in 1..n {
                let l = eng.decode_step(&mut [&mut sess], &[last]);
                last = eng.sample(l.row(0), 0.0, &mut rng);
                out.extend(eng.tokenizer.decode_bytes(&[last]));
            }
            out
        };

        let b = spawn_batcher(1);
        // keep the engine busy so the next two submissions queue up
        let (busy, busy_rx) = gen_req("padding text ", 32, None);
        b.submitter().send(busy).unwrap();
        // an abandoned request against session "conv": flag set, rx gone
        let (dead, dead_rx) = gen_req("poison text ", 8, Some("conv"));
        dead.cancel.store(true, Ordering::Relaxed);
        drop(dead_rx);
        b.submitter().send(dead).unwrap();
        // the real first turn of "conv", queued behind the dead one
        let (real, real_rx) = gen_req(prompt, n, Some("conv"));
        b.submitter().send(real).unwrap();

        let (out, _) = collect(&real_rx);
        assert_eq!(
            out, reference,
            "cancelled request advanced the session before being dropped"
        );
        collect(&busy_rx);
        assert_eq!(b.stats.cancelled.load(Ordering::Relaxed), 1);
        b.shutdown();
    }

    /// A named session continues where it left off: two one-turn requests
    /// against the same id reproduce one two-turn reference generation.
    #[test]
    fn named_session_continues_context() {
        let eng = test_engine();
        // reference: prefill both prompts into one session back to back
        let p1 = "hello wor";
        let p2 = "ld again ";
        let n = 6usize;
        let reference = {
            let mut sess = eng.new_session();
            let toks1 = eng.tokenizer.encode(p1);
            let logits = eng.prefill(&mut sess, &toks1);
            let mut rng = Rng::new(0);
            let mut last = eng.sample(&logits, 0.0, &mut rng);
            let mut out1 = eng.tokenizer.decode_bytes(&[last]);
            for _ in 1..n {
                let l = eng.decode_step(&mut [&mut sess], &[last]);
                last = eng.sample(l.row(0), 0.0, &mut rng);
                out1.extend(eng.tokenizer.decode_bytes(&[last]));
            }
            let toks2 = eng.tokenizer.encode(p2);
            let logits = eng.prefill(&mut sess, &toks2);
            let mut last = eng.sample(&logits, 0.0, &mut rng);
            let mut out2 = eng.tokenizer.decode_bytes(&[last]);
            for _ in 1..n {
                let l = eng.decode_step(&mut [&mut sess], &[last]);
                last = eng.sample(l.row(0), 0.0, &mut rng);
                out2.extend(eng.tokenizer.decode_bytes(&[last]));
            }
            (out1, out2)
        };

        let b = spawn_batcher(4);
        let (r1, rx1) = gen_req(p1, n, Some("conv"));
        b.submitter().send(r1).unwrap();
        let (out1, _) = collect(&rx1);
        let (r2, rx2) = gen_req(p2, n, Some("conv"));
        b.submitter().send(r2).unwrap();
        let (out2, _) = collect(&rx2);
        assert_eq!(out1, reference.0, "first turn diverged");
        assert_eq!(out2, reference.1, "second turn lost session context");
        // the gauge is synced just after the Done event — poll briefly
        let deadline = Instant::now() + Duration::from_secs(5);
        while b.stats.resident_sessions.load(Ordering::Relaxed) != 1
            && Instant::now() < deadline
        {
            std::thread::sleep(Duration::from_millis(5));
        }
        assert_eq!(
            b.stats.resident_sessions.load(Ordering::Relaxed),
            1,
            "named session should stay resident"
        );
        b.shutdown();
    }
}
