//! The serving layer: checkpoint-backed inference with request batching
//! — the first production-shaped workload on top of the native backend.
//!
//! * `engine` — decode-only forward path over a loaded checkpoint:
//!   per-session recurrent state (GLA) / paged KV cache (SA), greedy +
//!   temperature sampling, quant recipe applied batch-invariantly,
//!   cross-session batched prefill, bit-exact session serialization.
//! * `pages` — fixed-size KV pages + the LRU named-session cache with
//!   spill-to-disk eviction (`--max-resident-sessions`,
//!   `--max-kv-tokens`).
//! * `batcher` — coalesces concurrent requests into prefill + decode
//!   batches (max-batch-size + max-wait knobs) and fans tokens back out.
//! * `protocol` — the line-delimited TCP wire format (GEN/SGEN/...).
//! * `http` — the hand-rolled HTTP/1.1 layer (`POST /generate` chunked
//!   streaming, `GET /stats`, `POST /shutdown`).
//! * `server` — `std::net` listeners + worker-thread pool + graceful
//!   shutdown (`chon serve`).
//! * `client` — protocol client / load generator with latency
//!   percentiles (`chon client`).

pub mod batcher;
pub mod client;
pub mod engine;
pub mod http;
pub mod pages;
pub mod protocol;
pub mod server;

pub use batcher::{GenRequest, RequestBatcher, ServeStats, TokenEvent};
pub use client::{ClientOpts, LoadReport};
pub use engine::{Engine, Session};
pub use pages::{KvPages, SessionStore, StoreOpts, PAGE_TOKENS};
pub use server::{ServeOpts, Server};
