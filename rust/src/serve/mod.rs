//! The serving layer: checkpoint-backed inference with request batching
//! — the first production-shaped workload on top of the native backend.
//!
//! * `engine` — decode-only forward path over a loaded checkpoint:
//!   per-session recurrent state (GLA) / KV cache (SA), greedy +
//!   temperature sampling, quant recipe applied batch-invariantly.
//! * `batcher` — coalesces concurrent requests into decode batches
//!   (max-batch-size + max-wait knobs) and fans tokens back out.
//! * `protocol` — the line-delimited TCP wire format.
//! * `server` — `std::net` listener + worker-thread pool + graceful
//!   shutdown (`chon serve`).
//! * `client` — protocol client / load generator with latency
//!   percentiles (`chon client`).

pub mod batcher;
pub mod client;
pub mod engine;
pub mod protocol;
pub mod server;

pub use batcher::{GenRequest, RequestBatcher, ServeStats, TokenEvent};
pub use client::{ClientOpts, LoadReport};
pub use engine::{Engine, Session};
pub use server::{ServeOpts, Server};
