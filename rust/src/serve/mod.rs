//! The serving layer: checkpoint-backed inference with request batching
//! and a multi-model registry — the production-shaped workload on top of
//! the native backend.
//!
//! * `engine` — decode-only forward path over a loaded checkpoint:
//!   per-session recurrent state (GLA) / paged KV cache (SA), greedy +
//!   temperature sampling, quant recipe applied batch-invariantly,
//!   cross-session batched prefill, bit-exact session serialization,
//!   weights quantized once and packed once into GEMM B panels at load
//!   (the packed-weight cache).
//! * `pages` — fixed-size KV pages + the LRU named-session cache with
//!   spill-to-disk eviction (`--max-resident-sessions`,
//!   `--max-kv-tokens`).
//! * `batcher` — coalesces concurrent requests into prefill + decode
//!   batches (max-batch-size + max-wait knobs) and fans tokens back out;
//!   drops queued requests whose client already gave up.
//! * `registry` — many named checkpoints behind one endpoint: lazy load,
//!   LRU unload under `--max-resident-models`, hot reload on a
//!   republished checkpoint's `generation` bump, per-model stats
//!   (`chon serve --model NAME=DIR ...`).
//! * `protocol` — the line-delimited TCP wire format
//!   (GEN/SGEN/`MODEL <name>` routing/...).
//! * `http` — the hand-rolled HTTP/1.1 layer (`POST /generate` chunked
//!   streaming with a `"model"` key, `GET /stats`, `GET /metrics`
//!   Prometheus text, `POST /shutdown`).
//! * `reactor` — thin epoll/eventfd/timerfd-free wrappers over raw
//!   syscalls: `Poller`, `WakeFd`, a coarse timer wheel, and the
//!   RLIMIT_NOFILE raiser the connection-scaling paths need.
//! * `server` — the single-threaded epoll reactor front end: every
//!   socket non-blocking under one event loop, incremental line/HTTP
//!   parsing, keep-alive pipelining, idle eviction off the timer wheel,
//!   graceful shutdown (`chon serve`).
//! * `client` — protocol client / load generator with per-model latency
//!   percentiles, an idle-connection scaling mode, and a
//!   `--metrics-port` scrape-and-assert mode for smokes (`chon client`).
//!
//! Observability rides in `crate::obs`: the batcher and reactor record
//! stage spans (queue-wait, prefill, per-token decode, write-flush,
//! accept, parse) into per-model histograms served at `GET /metrics`,
//! and `--obs-outliers` adds per-op HCP hot-channel taps. Scraping is
//! side-effect-free by contract — `/stats` and `/metrics` never trigger
//! loads or reloads.

pub mod batcher;
pub mod client;
pub mod engine;
pub mod http;
pub mod pages;
pub mod protocol;
pub mod reactor;
pub mod registry;
pub mod server;

pub use batcher::{EventSink, GenRequest, ReplySink, RequestBatcher, ServeStats, TokenEvent};
pub use client::{ClientOpts, LoadReport};
pub use engine::{Engine, Session};
pub use pages::{KvPages, SessionStore, StoreOpts, PAGE_TOKENS};
pub use registry::{ModelRegistry, RegistryOpts, SubmitError};
pub use server::{ServeOpts, Server};
