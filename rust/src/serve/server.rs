//! The TCP front end: a `std::net` listener, a worker-thread pool for
//! connection handling, and graceful shutdown.
//!
//! Connections speak the line protocol of `serve::protocol`. Generation
//! requests are forwarded to the `RequestBatcher`; token events stream
//! back as `TOK` lines as they are produced, so a slow consumer only
//! delays itself. `SHUTDOWN` (from any connection) stops accepting, lets
//! in-flight generations finish, joins the pool and prints final stats.

use std::io::{BufRead, BufReader, ErrorKind, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::info;
use crate::serve::batcher::{GenRequest, RequestBatcher, ServeStats, TokenEvent};
use crate::serve::engine::Engine;
use crate::serve::protocol::{self, Request};

/// Server knobs (CLI flags of `chon serve`).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub host: String,
    /// 0 = pick an ephemeral port (tests); `port()` reports the real one
    pub port: u16,
    pub max_batch: usize,
    pub max_wait_us: u64,
    /// connection-handler threads
    pub workers: usize,
    /// temperature-sampling seed
    pub seed: u64,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            host: "127.0.0.1".into(),
            port: 7411,
            max_batch: 8,
            max_wait_us: 2000,
            workers: 4,
            seed: 0,
        }
    }
}

/// A bound server, ready to `run`.
pub struct Server {
    listener: TcpListener,
    batcher: RequestBatcher,
    shutdown: Arc<AtomicBool>,
    workers: usize,
}

impl Server {
    /// Bind the listener and spawn the engine thread.
    pub fn bind(engine: Engine, opts: &ServeOpts) -> Result<Server> {
        let addr = format!("{}:{}", opts.host, opts.port);
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr}"))?;
        let batcher = RequestBatcher::spawn(
            engine,
            opts.max_batch,
            Duration::from_micros(opts.max_wait_us),
            opts.seed,
        );
        Ok(Server {
            listener,
            batcher,
            shutdown: Arc::new(AtomicBool::new(false)),
            workers: opts.workers.max(1),
        })
    }

    /// The actually-bound port (differs from the request when asking for 0).
    pub fn port(&self) -> u16 {
        self.listener.local_addr().map(|a| a.port()).unwrap_or(0)
    }

    /// A handle that makes `run` return (used by tests and signal glue).
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Serve until a `SHUTDOWN` command (or the shutdown flag) arrives.
    /// Returns the final stats snapshot line.
    pub fn run(self) -> Result<String> {
        self.listener.set_nonblocking(true)?;
        let (conn_tx, conn_rx) = channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let mut pool = Vec::with_capacity(self.workers);
        for _ in 0..self.workers {
            let rx = conn_rx.clone();
            let submit = self.batcher.submitter();
            let stats = self.batcher.stats.clone();
            let stop = self.shutdown.clone();
            pool.push(std::thread::spawn(move || loop {
                let stream = {
                    let guard = rx.lock().expect("conn queue poisoned");
                    guard.recv()
                };
                match stream {
                    Ok(s) => handle_conn(s, &submit, &stats, &stop),
                    Err(_) => break, // accept loop gone: drain done
                }
            }));
        }

        info!("serving on port {} ({} workers)", self.port(), self.workers);
        while !self.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _)) => {
                    let _ = conn_tx.send(stream);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    std::thread::sleep(Duration::from_millis(5));
                }
                Err(e) => {
                    info!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
        }

        // stop feeding the pool, let handlers finish, then drain the engine
        drop(conn_tx);
        for h in pool {
            let _ = h.join();
        }
        let line = self.batcher.stats.snapshot_line();
        self.batcher.shutdown();
        info!("shutdown complete: {line}");
        Ok(line)
    }
}

/// Serve one connection until EOF, error, or shutdown.
fn handle_conn(
    stream: TcpStream,
    submit: &Sender<GenRequest>,
    stats: &Arc<ServeStats>,
    stop: &Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    // poll tick: idle readers notice shutdown instead of pinning the pool
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    // a pooled worker is pinned for the connection's lifetime, so idle
    // connections are evicted after this many consecutive timeout ticks
    // (~60 s) instead of starving the pool forever
    const IDLE_TICKS: u32 = 300;
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    let mut line = String::new();
    let mut idle_ticks = 0u32;
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => idle_ticks = 0,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                // timeout mid-line: bytes read so far stay in `line`;
                // keep accumulating unless shutting down or idled out
                idle_ticks += 1;
                if stop.load(Ordering::SeqCst) || idle_ticks >= IDLE_TICKS {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        let parsed = protocol::parse_request(&line);
        line.clear();
        let reply = match parsed {
            Err(e) => format!("ERR {}\n", protocol::escape(&e)),
            Ok(Request::Ping) => "PONG\n".into(),
            Ok(Request::Stats) => format!("STATS {}\n", stats.snapshot_line()),
            Ok(Request::Shutdown) => {
                let _ = writer.write_all(b"BYE\n");
                stop.store(true, Ordering::SeqCst);
                return;
            }
            Ok(Request::Gen { max_tokens, temp, prompt }) => {
                stream_generation(&mut writer, submit, max_tokens, temp, prompt);
                continue;
            }
        };
        if writer.write_all(reply.as_bytes()).is_err() {
            return;
        }
    }
}

/// Submit one GEN request and stream its events back.
fn stream_generation(
    writer: &mut TcpStream,
    submit: &Sender<GenRequest>,
    max_tokens: usize,
    temp: f32,
    prompt: String,
) {
    let (tx, rx): (Sender<TokenEvent>, Receiver<TokenEvent>) = channel();
    if submit
        .send(GenRequest { prompt, max_tokens, temp, reply: tx })
        .is_err()
    {
        let _ = writer.write_all(b"ERR server stopped\n");
        return;
    }
    loop {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(TokenEvent::Token(piece)) => {
                let line = format!("TOK {}\n", protocol::escape_bytes(&piece));
                if writer.write_all(line.as_bytes()).is_err() {
                    return; // client gone; engine notices on next send
                }
            }
            Ok(TokenEvent::Done { n_tokens, gen_ms }) => {
                let _ = writer
                    .write_all(format!("DONE {n_tokens} {gen_ms:.3}\n").as_bytes());
                return;
            }
            Ok(TokenEvent::Error(e)) => {
                let _ = writer
                    .write_all(format!("ERR {}\n", protocol::escape(&e)).as_bytes());
                return;
            }
            Err(_) => {
                let _ = writer.write_all(b"ERR generation timed out\n");
                return;
            }
        }
    }
}
