//! The network front ends: a `std::net` line-protocol listener, an
//! optional HTTP/1.1 listener, a shared worker-thread pool for connection
//! handling, and graceful shutdown.
//!
//! Both front ends route through the same `ModelRegistry` (and therefore
//! the same per-model request batchers, prefill batching, paged session
//! caches, lazy load / LRU unload / hot reload and drain logic):
//!
//! * line protocol (`serve::protocol`): `GEN`/`SGEN` stream `TOK` lines
//!   back as tokens are produced, so a slow consumer only delays itself;
//!   a `MODEL <name>` prefix routes to a registered model (absent = the
//!   default model).
//! * HTTP (`serve::http`): `POST /generate` streams newline-delimited
//!   JSON over chunked transfer encoding (optional `"model"` key routes
//!   like the MODEL prefix); `GET /stats` returns the aggregate counters
//!   plus a per-model breakdown as JSON; `POST /shutdown` drains and
//!   stops.
//!
//! `SHUTDOWN` (line) or `POST /shutdown` (HTTP) stops accepting, lets
//! in-flight generations finish, joins the pool and prints final stats.
//!
//! When a client gives up on a generation (60 s reply timeout, or its
//! socket write fails), the handler flags the request as cancelled so a
//! still-queued request is dropped instead of executed — an abandoned
//! request can no longer advance a named session behind its client's
//! back.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::info;
use crate::serve::batcher::{GenRequest, TokenEvent};
use crate::serve::http::{self, HttpRequest, Parsed};
use crate::serve::protocol::{self, Request};
use crate::serve::registry::{ModelRegistry, SubmitError};
use crate::util::json::Json;

/// Server knobs (the listener-level CLI flags of `chon serve`; the
/// per-model knobs — batching, session cache, residency, reload poll —
/// live in `registry::RegistryOpts`).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub host: String,
    /// 0 = pick an ephemeral port (tests); `port()` reports the real one
    pub port: u16,
    /// HTTP front-end port (0 = ephemeral); None disables HTTP entirely
    pub http_port: Option<u16>,
    /// connection-handler threads
    pub workers: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            host: "127.0.0.1".into(),
            port: 7411,
            http_port: Some(7412),
            workers: 4,
        }
    }
}

/// Which wire format a pooled connection speaks.
#[derive(Clone, Copy, Debug)]
enum ConnKind {
    Line,
    Http,
}

/// A bound server, ready to `run`.
pub struct Server {
    listener: TcpListener,
    http_listener: Option<TcpListener>,
    registry: Arc<ModelRegistry>,
    shutdown: Arc<AtomicBool>,
    workers: usize,
}

impl Server {
    /// Bind the listener(s) over a populated model registry.
    pub fn bind(registry: ModelRegistry, opts: &ServeOpts) -> Result<Server> {
        let addr = format!("{}:{}", opts.host, opts.port);
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr}"))?;
        let http_listener = match opts.http_port {
            Some(p) => {
                let haddr = format!("{}:{}", opts.host, p);
                Some(
                    TcpListener::bind(&haddr)
                        .with_context(|| format!("binding HTTP {haddr}"))?,
                )
            }
            None => None,
        };
        Ok(Server {
            listener,
            http_listener,
            registry: Arc::new(registry),
            shutdown: Arc::new(AtomicBool::new(false)),
            workers: opts.workers.max(1),
        })
    }

    /// The actually-bound port (differs from the request when asking for 0).
    pub fn port(&self) -> u16 {
        self.listener.local_addr().map(|a| a.port()).unwrap_or(0)
    }

    /// The actually-bound HTTP port (None when HTTP is disabled).
    pub fn http_port(&self) -> Option<u16> {
        self.http_listener
            .as_ref()
            .and_then(|l| l.local_addr().ok())
            .map(|a| a.port())
    }

    /// A handle that makes `run` return (used by tests and signal glue).
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// The model registry behind this server (tests poke generations and
    /// per-model stats through this).
    pub fn registry(&self) -> Arc<ModelRegistry> {
        self.registry.clone()
    }

    /// Serve until a shutdown command (or the shutdown flag) arrives.
    /// Returns the final stats snapshot line.
    pub fn run(self) -> Result<String> {
        self.listener.set_nonblocking(true)?;
        if let Some(hl) = &self.http_listener {
            hl.set_nonblocking(true)?;
        }
        let (conn_tx, conn_rx) = channel::<(TcpStream, ConnKind)>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let mut pool = Vec::with_capacity(self.workers);
        for _ in 0..self.workers {
            let rx = conn_rx.clone();
            let registry = self.registry.clone();
            let stop = self.shutdown.clone();
            pool.push(std::thread::spawn(move || loop {
                let stream = {
                    let guard = rx.lock().expect("conn queue poisoned");
                    guard.recv()
                };
                match stream {
                    Ok((s, ConnKind::Line)) => handle_conn(s, &registry, &stop),
                    Ok((s, ConnKind::Http)) => {
                        handle_http_conn(s, &registry, &stop)
                    }
                    Err(_) => break, // accept loop gone: drain done
                }
            }));
        }

        info!(
            "serving {} model(s) on port {} (http {:?}, {} workers)",
            self.registry.model_names().len(),
            self.port(),
            self.http_port(),
            self.workers
        );
        while !self.shutdown.load(Ordering::SeqCst) {
            let mut accepted = false;
            match self.listener.accept() {
                Ok((stream, _)) => {
                    accepted = true;
                    let _ = conn_tx.send((stream, ConnKind::Line));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) => {
                    info!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
            if let Some(hl) = &self.http_listener {
                match hl.accept() {
                    Ok((stream, _)) => {
                        accepted = true;
                        let _ = conn_tx.send((stream, ConnKind::Http));
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                    Err(e) => {
                        info!("http accept error: {e}");
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
            if !accepted {
                std::thread::sleep(Duration::from_millis(5));
            }
        }

        // stop feeding the pool, let handlers finish, then drain engines
        drop(conn_tx);
        for h in pool {
            let _ = h.join();
        }
        let line = self.registry.stats_line();
        self.registry.shutdown();
        info!("shutdown complete: {line}");
        Ok(line)
    }
}

/// Idle eviction: a pooled worker is pinned per live connection, so idle
/// connections are dropped after this many 200 ms timeout ticks (~60 s).
const IDLE_TICKS: u32 = 300;

/// Serve one line-protocol connection until EOF, error, or shutdown.
fn handle_conn(
    stream: TcpStream,
    registry: &Arc<ModelRegistry>,
    stop: &Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    // poll tick: idle readers notice shutdown instead of pinning the pool
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    let mut line = String::new();
    let mut idle_ticks = 0u32;
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => idle_ticks = 0,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                // timeout mid-line: bytes read so far stay in `line`;
                // keep accumulating unless shutting down or idled out
                idle_ticks += 1;
                if stop.load(Ordering::SeqCst) || idle_ticks >= IDLE_TICKS {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        let parsed = protocol::parse_request(&line);
        line.clear();
        let reply = match parsed {
            Err(e) => format!("ERR {}\n", protocol::escape(&e)),
            Ok(Request::Ping) => "PONG\n".into(),
            Ok(Request::Stats) => {
                format!("STATS {}\n", registry.stats_line())
            }
            Ok(Request::Shutdown) => {
                let _ = writer.write_all(b"BYE\n");
                stop.store(true, Ordering::SeqCst);
                return;
            }
            Ok(Request::Gen { max_tokens, temp, prompt, session, model }) => {
                stream_generation(
                    &mut writer,
                    registry,
                    model,
                    max_tokens,
                    temp,
                    prompt,
                    session,
                );
                continue;
            }
        };
        if writer.write_all(reply.as_bytes()).is_err() {
            return;
        }
    }
}

/// Submit one GEN/SGEN request to the registry and stream its events
/// back. The cancel flag is raised whenever this handler stops reading
/// events (timeout or a dead client socket), so the batcher can drop the
/// request if it had not started yet.
fn stream_generation(
    writer: &mut TcpStream,
    registry: &Arc<ModelRegistry>,
    model: Option<String>,
    max_tokens: usize,
    temp: f32,
    prompt: String,
    session: Option<String>,
) {
    let (tx, rx): (Sender<TokenEvent>, Receiver<TokenEvent>) = channel();
    let cancel = Arc::new(AtomicBool::new(false));
    let req = GenRequest {
        prompt,
        max_tokens,
        temp,
        session,
        reply: tx,
        cancel: cancel.clone(),
    };
    if let Err(e) = registry.submit(model.as_deref(), req) {
        let _ = writer
            .write_all(format!("ERR {}\n", protocol::escape(&e.to_string())).as_bytes());
        return;
    }
    loop {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(TokenEvent::Token(piece)) => {
                let line = format!("TOK {}\n", protocol::escape_bytes(&piece));
                if writer.write_all(line.as_bytes()).is_err() {
                    // client gone; if the generation is still queued the
                    // flag drops it, and a running one is cut short on
                    // the engine's next send
                    cancel.store(true, Ordering::Relaxed);
                    return;
                }
            }
            Ok(TokenEvent::Done { n_tokens, gen_ms }) => {
                let _ = writer
                    .write_all(format!("DONE {n_tokens} {gen_ms:.3}\n").as_bytes());
                return;
            }
            Ok(TokenEvent::Error(e)) => {
                let _ = writer
                    .write_all(format!("ERR {}\n", protocol::escape(&e)).as_bytes());
                return;
            }
            Err(_) => {
                cancel.store(true, Ordering::Relaxed);
                let _ = writer.write_all(b"ERR generation timed out\n");
                return;
            }
        }
    }
}

/// Serve one HTTP connection (keep-alive) until EOF, error, `Connection:
/// close`, or shutdown.
fn handle_http_conn(
    mut stream: TcpStream,
    registry: &Arc<ModelRegistry>,
    stop: &Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut idle_ticks = 0u32;
    loop {
        match http::parse_request(&buf) {
            Ok(Parsed::Complete(req, consumed)) => {
                buf.drain(..consumed);
                let close = req.wants_close();
                let keep = handle_http_request(&mut stream, req, registry, stop);
                if !keep || close {
                    return;
                }
                idle_ticks = 0;
                continue;
            }
            Ok(Parsed::Partial) => {}
            Err(e) => {
                let _ = http::write_response(
                    &mut stream,
                    e.status,
                    "application/json",
                    &json_error(&e.message),
                    false,
                );
                return;
            }
        }
        match stream.read(&mut tmp) {
            Ok(0) => return, // EOF
            Ok(n) => {
                buf.extend_from_slice(&tmp[..n]);
                idle_ticks = 0;
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                idle_ticks += 1;
                if stop.load(Ordering::SeqCst) || idle_ticks >= IDLE_TICKS {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

fn json_error(msg: &str) -> Vec<u8> {
    Json::Obj(vec![("error".into(), Json::Str(msg.to_string()))])
        .render()
        .into_bytes()
}

/// Dispatch one parsed HTTP request. Returns false when the connection
/// must close (write failure or shutdown).
fn handle_http_request(
    stream: &mut TcpStream,
    req: HttpRequest,
    registry: &Arc<ModelRegistry>,
    stop: &Arc<AtomicBool>,
) -> bool {
    let path = req.target.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("GET" | "HEAD", "/stats") => {
            let body = registry.stats_json().render_pretty();
            http::write_response(
                stream,
                200,
                "application/json",
                body.as_bytes(),
                req.method == "HEAD",
            )
            .is_ok()
        }
        ("POST", "/shutdown") => {
            let body = Json::Obj(vec![("ok".into(), Json::Bool(true))]).render();
            let _ = http::write_response(
                stream,
                200,
                "application/json",
                body.as_bytes(),
                false,
            );
            stop.store(true, Ordering::SeqCst);
            false
        }
        ("POST", "/generate") => http_generate(stream, &req, registry),
        (_, "/stats" | "/shutdown" | "/generate") => http::write_response(
            stream,
            405,
            "application/json",
            &json_error("method not allowed for this path"),
            req.method == "HEAD",
        )
        .is_ok(),
        _ => http::write_response(
            stream,
            404,
            "application/json",
            &json_error("no such path (want /generate, /stats, /shutdown)"),
            req.method == "HEAD",
        )
        .is_ok(),
    }
}

/// `POST /generate`: body `{"prompt": "...", "max_tokens"?, "temp"?,
/// "session"?, "model"?}`. Streams newline-delimited JSON via chunked
/// transfer encoding: one `{"piece": "<escaped>"}` object per token
/// (piece is `protocol::escape_bytes`-escaped so split multi-byte
/// characters survive JSON), then `{"done": true, "n_tokens": N,
/// "gen_ms": T}`. An unknown `"model"` is a clean 404.
fn http_generate(
    stream: &mut TcpStream,
    req: &HttpRequest,
    registry: &Arc<ModelRegistry>,
) -> bool {
    let bad = |stream: &mut TcpStream, status: u16, msg: &str| {
        http::write_response(
            stream,
            status,
            "application/json",
            &json_error(msg),
            false,
        )
        .is_ok()
    };
    if req.http10 {
        // chunked transfer encoding does not exist in HTTP/1.0 — a 1.0
        // client would read the chunk framing as body bytes
        return bad(stream, 505, "/generate streams chunked; use HTTP/1.1");
    }
    let Ok(body) = std::str::from_utf8(&req.body) else {
        return bad(stream, 400, "body is not UTF-8");
    };
    let doc = match Json::parse(body) {
        Ok(d) => d,
        Err(e) => return bad(stream, 400, &format!("body is not JSON: {e}")),
    };
    let Some(prompt) = doc.get("prompt").and_then(|v| v.as_str()) else {
        return bad(stream, 400, "missing string field \"prompt\"");
    };
    let max_tokens = match doc.get("max_tokens") {
        None => 32usize,
        Some(v) => match v.as_f64() {
            Some(n) if n >= 0.0 && n.fract() == 0.0 => n as usize,
            _ => return bad(stream, 400, "max_tokens must be an integer"),
        },
    };
    let temp = match doc.get("temp") {
        None => 0.0f32,
        Some(v) => match v.as_f64() {
            Some(n) => n as f32,
            None => return bad(stream, 400, "temp must be a number"),
        },
    };
    let session = match doc.get("session") {
        None => None,
        Some(v) => match v.as_str() {
            Some(s) => Some(s.to_string()),
            None => return bad(stream, 400, "session must be a string"),
        },
    };
    let model = match doc.get("model") {
        None => None,
        Some(v) => match v.as_str() {
            Some(m) if protocol::valid_model_name(m) => Some(m.to_string()),
            Some(_) => return bad(stream, 400, "bad model name"),
            None => return bad(stream, 400, "model must be a string"),
        },
    };
    if let Err(e) =
        protocol::validate_gen(max_tokens, temp, prompt, session.as_deref())
    {
        return bad(stream, 400, &e);
    }

    let (tx, rx): (Sender<TokenEvent>, Receiver<TokenEvent>) = channel();
    let cancel = Arc::new(AtomicBool::new(false));
    let gen_req = GenRequest {
        prompt: prompt.to_string(),
        max_tokens,
        temp,
        session,
        reply: tx,
        cancel: cancel.clone(),
    };
    if let Err(e) = registry.submit(model.as_deref(), gen_req) {
        let status = match e {
            SubmitError::UnknownModel(_) => 404,
            SubmitError::Load(_) => 500,
            SubmitError::Stopped => 503,
        };
        return bad(stream, status, &e.to_string());
    }

    // hold the status line until the first event so request-level errors
    // (busy session, context overflow) become a clean 4xx
    let first = match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(ev) => ev,
        Err(_) => {
            cancel.store(true, Ordering::Relaxed);
            return bad(stream, 503, "generation timed out");
        }
    };
    let mut pending = match first {
        TokenEvent::Error(e) => {
            // most request-level failures are the client's (bad session,
            // context overflow) — but a drain or an LRU model unload is
            // server-initiated and explicitly retryable, so it must not
            // come back as a don't-retry 4xx
            let retryable =
                e.contains("shutting down") || e.contains("unloaded under");
            return bad(stream, if retryable { 503 } else { 400 }, &e);
        }
        ev => Some(ev),
    };
    if http::write_chunked_head(stream, 200, "application/x-ndjson").is_err() {
        cancel.store(true, Ordering::Relaxed);
        return false;
    }
    loop {
        let ev = match pending.take() {
            Some(ev) => ev,
            None => match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(ev) => ev,
                Err(_) => {
                    cancel.store(true, Ordering::Relaxed);
                    let mut line = json_error("generation timed out");
                    line.push(b'\n');
                    let _ = http::write_chunk(stream, &line);
                    let _ = http::finish_chunks(stream);
                    return false;
                }
            },
        };
        let (line, done) = match ev {
            TokenEvent::Token(piece) => (
                Json::Obj(vec![(
                    "piece".into(),
                    Json::Str(protocol::escape_bytes(&piece)),
                )])
                .render(),
                false,
            ),
            TokenEvent::Done { n_tokens, gen_ms } => (
                Json::Obj(vec![
                    ("done".into(), Json::Bool(true)),
                    ("n_tokens".into(), Json::Num(n_tokens as f64)),
                    ("gen_ms".into(), Json::Num(gen_ms)),
                ])
                .render(),
                true,
            ),
            TokenEvent::Error(e) => (
                Json::Obj(vec![("error".into(), Json::Str(e))]).render(),
                true,
            ),
        };
        if http::write_chunk(stream, format!("{line}\n").as_bytes()).is_err() {
            cancel.store(true, Ordering::Relaxed);
            return false;
        }
        if done {
            return http::finish_chunks(stream).is_ok();
        }
    }
}
