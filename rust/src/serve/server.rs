//! The network front ends: a `std::net` line-protocol listener, an
//! optional HTTP/1.1 listener, a shared worker-thread pool for connection
//! handling, and graceful shutdown.
//!
//! Both front ends feed the same `RequestBatcher` (and therefore the same
//! cross-session prefill batching, paged session cache and drain logic):
//!
//! * line protocol (`serve::protocol`): `GEN`/`SGEN` stream `TOK` lines
//!   back as tokens are produced, so a slow consumer only delays itself.
//! * HTTP (`serve::http`): `POST /generate` streams newline-delimited
//!   JSON over chunked transfer encoding; `GET /stats` returns the
//!   counters as JSON; `POST /shutdown` drains and stops.
//!
//! `SHUTDOWN` (line) or `POST /shutdown` (HTTP) stops accepting, lets
//! in-flight generations finish, joins the pool and prints final stats.

use std::io::{BufRead, BufReader, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::info;
use crate::serve::batcher::{GenRequest, RequestBatcher, ServeStats, TokenEvent};
use crate::serve::engine::Engine;
use crate::serve::http::{self, HttpRequest, Parsed};
use crate::serve::pages::StoreOpts;
use crate::serve::protocol::{self, Request};
use crate::util::json::Json;

/// Server knobs (CLI flags of `chon serve`).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub host: String,
    /// 0 = pick an ephemeral port (tests); `port()` reports the real one
    pub port: u16,
    /// HTTP front-end port (0 = ephemeral); None disables HTTP entirely
    pub http_port: Option<u16>,
    pub max_batch: usize,
    pub max_wait_us: u64,
    /// connection-handler threads
    pub workers: usize,
    /// temperature-sampling seed
    pub seed: u64,
    /// max idle named sessions kept in memory (0 = unlimited)
    pub max_resident_sessions: usize,
    /// max KV positions resident across idle sessions (0 = unlimited)
    pub max_kv_tokens: usize,
    /// where evicted sessions spill (None = per-process temp dir)
    pub spill_dir: Option<PathBuf>,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            host: "127.0.0.1".into(),
            port: 7411,
            http_port: Some(7412),
            max_batch: 8,
            max_wait_us: 2000,
            workers: 4,
            seed: 0,
            max_resident_sessions: 0,
            max_kv_tokens: 0,
            spill_dir: None,
        }
    }
}

/// Which wire format a pooled connection speaks.
#[derive(Clone, Copy, Debug)]
enum ConnKind {
    Line,
    Http,
}

/// A bound server, ready to `run`.
pub struct Server {
    listener: TcpListener,
    http_listener: Option<TcpListener>,
    batcher: RequestBatcher,
    shutdown: Arc<AtomicBool>,
    workers: usize,
}

impl Server {
    /// Bind the listener(s) and spawn the engine thread.
    pub fn bind(engine: Engine, opts: &ServeOpts) -> Result<Server> {
        let addr = format!("{}:{}", opts.host, opts.port);
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr}"))?;
        let http_listener = match opts.http_port {
            Some(p) => {
                let haddr = format!("{}:{}", opts.host, p);
                Some(
                    TcpListener::bind(&haddr)
                        .with_context(|| format!("binding HTTP {haddr}"))?,
                )
            }
            None => None,
        };
        let store_opts = StoreOpts {
            max_resident_sessions: opts.max_resident_sessions,
            max_kv_tokens: opts.max_kv_tokens,
            spill_dir: opts.spill_dir.clone(),
        };
        let batcher = RequestBatcher::spawn(
            engine,
            opts.max_batch,
            Duration::from_micros(opts.max_wait_us),
            opts.seed,
            store_opts,
        )?;
        Ok(Server {
            listener,
            http_listener,
            batcher,
            shutdown: Arc::new(AtomicBool::new(false)),
            workers: opts.workers.max(1),
        })
    }

    /// The actually-bound port (differs from the request when asking for 0).
    pub fn port(&self) -> u16 {
        self.listener.local_addr().map(|a| a.port()).unwrap_or(0)
    }

    /// The actually-bound HTTP port (None when HTTP is disabled).
    pub fn http_port(&self) -> Option<u16> {
        self.http_listener
            .as_ref()
            .and_then(|l| l.local_addr().ok())
            .map(|a| a.port())
    }

    /// A handle that makes `run` return (used by tests and signal glue).
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Serve until a shutdown command (or the shutdown flag) arrives.
    /// Returns the final stats snapshot line.
    pub fn run(self) -> Result<String> {
        self.listener.set_nonblocking(true)?;
        if let Some(hl) = &self.http_listener {
            hl.set_nonblocking(true)?;
        }
        let (conn_tx, conn_rx) = channel::<(TcpStream, ConnKind)>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let mut pool = Vec::with_capacity(self.workers);
        for _ in 0..self.workers {
            let rx = conn_rx.clone();
            let submit = self.batcher.submitter();
            let stats = self.batcher.stats.clone();
            let stop = self.shutdown.clone();
            pool.push(std::thread::spawn(move || loop {
                let stream = {
                    let guard = rx.lock().expect("conn queue poisoned");
                    guard.recv()
                };
                match stream {
                    Ok((s, ConnKind::Line)) => {
                        handle_conn(s, &submit, &stats, &stop)
                    }
                    Ok((s, ConnKind::Http)) => {
                        handle_http_conn(s, &submit, &stats, &stop)
                    }
                    Err(_) => break, // accept loop gone: drain done
                }
            }));
        }

        info!(
            "serving on port {} (http {:?}, {} workers)",
            self.port(),
            self.http_port(),
            self.workers
        );
        while !self.shutdown.load(Ordering::SeqCst) {
            let mut accepted = false;
            match self.listener.accept() {
                Ok((stream, _)) => {
                    accepted = true;
                    let _ = conn_tx.send((stream, ConnKind::Line));
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                Err(e) => {
                    info!("accept error: {e}");
                    std::thread::sleep(Duration::from_millis(20));
                }
            }
            if let Some(hl) = &self.http_listener {
                match hl.accept() {
                    Ok((stream, _)) => {
                        accepted = true;
                        let _ = conn_tx.send((stream, ConnKind::Http));
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {}
                    Err(e) => {
                        info!("http accept error: {e}");
                        std::thread::sleep(Duration::from_millis(20));
                    }
                }
            }
            if !accepted {
                std::thread::sleep(Duration::from_millis(5));
            }
        }

        // stop feeding the pool, let handlers finish, then drain the engine
        drop(conn_tx);
        for h in pool {
            let _ = h.join();
        }
        let line = self.batcher.stats.snapshot_line();
        self.batcher.shutdown();
        info!("shutdown complete: {line}");
        Ok(line)
    }
}

/// Idle eviction: a pooled worker is pinned per live connection, so idle
/// connections are dropped after this many 200 ms timeout ticks (~60 s).
const IDLE_TICKS: u32 = 300;

/// Serve one line-protocol connection until EOF, error, or shutdown.
fn handle_conn(
    stream: TcpStream,
    submit: &Sender<GenRequest>,
    stats: &Arc<ServeStats>,
    stop: &Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    // poll tick: idle readers notice shutdown instead of pinning the pool
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut reader = match stream.try_clone() {
        Ok(s) => BufReader::new(s),
        Err(_) => return,
    };
    let mut writer = stream;
    let mut line = String::new();
    let mut idle_ticks = 0u32;
    loop {
        match reader.read_line(&mut line) {
            Ok(0) => return, // EOF
            Ok(_) => idle_ticks = 0,
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                // timeout mid-line: bytes read so far stay in `line`;
                // keep accumulating unless shutting down or idled out
                idle_ticks += 1;
                if stop.load(Ordering::SeqCst) || idle_ticks >= IDLE_TICKS {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        let parsed = protocol::parse_request(&line);
        line.clear();
        let reply = match parsed {
            Err(e) => format!("ERR {}\n", protocol::escape(&e)),
            Ok(Request::Ping) => "PONG\n".into(),
            Ok(Request::Stats) => format!("STATS {}\n", stats.snapshot_line()),
            Ok(Request::Shutdown) => {
                let _ = writer.write_all(b"BYE\n");
                stop.store(true, Ordering::SeqCst);
                return;
            }
            Ok(Request::Gen { max_tokens, temp, prompt, session }) => {
                stream_generation(
                    &mut writer,
                    submit,
                    max_tokens,
                    temp,
                    prompt,
                    session,
                );
                continue;
            }
        };
        if writer.write_all(reply.as_bytes()).is_err() {
            return;
        }
    }
}

/// Submit one GEN/SGEN request and stream its events back.
fn stream_generation(
    writer: &mut TcpStream,
    submit: &Sender<GenRequest>,
    max_tokens: usize,
    temp: f32,
    prompt: String,
    session: Option<String>,
) {
    let (tx, rx): (Sender<TokenEvent>, Receiver<TokenEvent>) = channel();
    if submit
        .send(GenRequest { prompt, max_tokens, temp, session, reply: tx })
        .is_err()
    {
        let _ = writer.write_all(b"ERR server stopped\n");
        return;
    }
    loop {
        match rx.recv_timeout(Duration::from_secs(60)) {
            Ok(TokenEvent::Token(piece)) => {
                let line = format!("TOK {}\n", protocol::escape_bytes(&piece));
                if writer.write_all(line.as_bytes()).is_err() {
                    return; // client gone; engine notices on next send
                }
            }
            Ok(TokenEvent::Done { n_tokens, gen_ms }) => {
                let _ = writer
                    .write_all(format!("DONE {n_tokens} {gen_ms:.3}\n").as_bytes());
                return;
            }
            Ok(TokenEvent::Error(e)) => {
                let _ = writer
                    .write_all(format!("ERR {}\n", protocol::escape(&e)).as_bytes());
                return;
            }
            Err(_) => {
                let _ = writer.write_all(b"ERR generation timed out\n");
                return;
            }
        }
    }
}

/// Serve one HTTP connection (keep-alive) until EOF, error, `Connection:
/// close`, or shutdown.
fn handle_http_conn(
    mut stream: TcpStream,
    submit: &Sender<GenRequest>,
    stats: &Arc<ServeStats>,
    stop: &Arc<AtomicBool>,
) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(200)));
    let mut buf: Vec<u8> = Vec::new();
    let mut tmp = [0u8; 4096];
    let mut idle_ticks = 0u32;
    loop {
        match http::parse_request(&buf) {
            Ok(Parsed::Complete(req, consumed)) => {
                buf.drain(..consumed);
                let close = req.wants_close();
                let keep =
                    handle_http_request(&mut stream, req, submit, stats, stop);
                if !keep || close {
                    return;
                }
                idle_ticks = 0;
                continue;
            }
            Ok(Parsed::Partial) => {}
            Err(e) => {
                let _ = http::write_response(
                    &mut stream,
                    e.status,
                    "application/json",
                    &json_error(&e.message),
                    false,
                );
                return;
            }
        }
        match stream.read(&mut tmp) {
            Ok(0) => return, // EOF
            Ok(n) => {
                buf.extend_from_slice(&tmp[..n]);
                idle_ticks = 0;
            }
            Err(e)
                if e.kind() == ErrorKind::WouldBlock
                    || e.kind() == ErrorKind::TimedOut =>
            {
                idle_ticks += 1;
                if stop.load(Ordering::SeqCst) || idle_ticks >= IDLE_TICKS {
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

fn json_error(msg: &str) -> Vec<u8> {
    Json::Obj(vec![("error".into(), Json::Str(msg.to_string()))])
        .render()
        .into_bytes()
}

/// Dispatch one parsed HTTP request. Returns false when the connection
/// must close (write failure or shutdown).
fn handle_http_request(
    stream: &mut TcpStream,
    req: HttpRequest,
    submit: &Sender<GenRequest>,
    stats: &Arc<ServeStats>,
    stop: &Arc<AtomicBool>,
) -> bool {
    let path = req.target.split('?').next().unwrap_or("");
    match (req.method.as_str(), path) {
        ("GET" | "HEAD", "/stats") => {
            let body = stats.snapshot_json().render_pretty();
            http::write_response(
                stream,
                200,
                "application/json",
                body.as_bytes(),
                req.method == "HEAD",
            )
            .is_ok()
        }
        ("POST", "/shutdown") => {
            let body = Json::Obj(vec![("ok".into(), Json::Bool(true))]).render();
            let _ = http::write_response(
                stream,
                200,
                "application/json",
                body.as_bytes(),
                false,
            );
            stop.store(true, Ordering::SeqCst);
            false
        }
        ("POST", "/generate") => http_generate(stream, &req, submit),
        (_, "/stats" | "/shutdown" | "/generate") => http::write_response(
            stream,
            405,
            "application/json",
            &json_error("method not allowed for this path"),
            req.method == "HEAD",
        )
        .is_ok(),
        _ => http::write_response(
            stream,
            404,
            "application/json",
            &json_error("no such path (want /generate, /stats, /shutdown)"),
            req.method == "HEAD",
        )
        .is_ok(),
    }
}

/// `POST /generate`: body `{"prompt": "...", "max_tokens"?, "temp"?,
/// "session"?}`. Streams newline-delimited JSON via chunked transfer
/// encoding: one `{"piece": "<escaped>"}` object per token (piece is
/// `protocol::escape_bytes`-escaped so split multi-byte characters
/// survive JSON), then `{"done": true, "n_tokens": N, "gen_ms": T}`.
fn http_generate(
    stream: &mut TcpStream,
    req: &HttpRequest,
    submit: &Sender<GenRequest>,
) -> bool {
    let bad = |stream: &mut TcpStream, status: u16, msg: &str| {
        http::write_response(
            stream,
            status,
            "application/json",
            &json_error(msg),
            false,
        )
        .is_ok()
    };
    if req.http10 {
        // chunked transfer encoding does not exist in HTTP/1.0 — a 1.0
        // client would read the chunk framing as body bytes
        return bad(stream, 505, "/generate streams chunked; use HTTP/1.1");
    }
    let Ok(body) = std::str::from_utf8(&req.body) else {
        return bad(stream, 400, "body is not UTF-8");
    };
    let doc = match Json::parse(body) {
        Ok(d) => d,
        Err(e) => return bad(stream, 400, &format!("body is not JSON: {e}")),
    };
    let Some(prompt) = doc.get("prompt").and_then(|v| v.as_str()) else {
        return bad(stream, 400, "missing string field \"prompt\"");
    };
    let max_tokens = match doc.get("max_tokens") {
        None => 32usize,
        Some(v) => match v.as_f64() {
            Some(n) if n >= 0.0 && n.fract() == 0.0 => n as usize,
            _ => return bad(stream, 400, "max_tokens must be an integer"),
        },
    };
    let temp = match doc.get("temp") {
        None => 0.0f32,
        Some(v) => match v.as_f64() {
            Some(n) => n as f32,
            None => return bad(stream, 400, "temp must be a number"),
        },
    };
    let session = match doc.get("session") {
        None => None,
        Some(v) => match v.as_str() {
            Some(s) => Some(s.to_string()),
            None => return bad(stream, 400, "session must be a string"),
        },
    };
    if let Err(e) =
        protocol::validate_gen(max_tokens, temp, prompt, session.as_deref())
    {
        return bad(stream, 400, &e);
    }

    let (tx, rx): (Sender<TokenEvent>, Receiver<TokenEvent>) = channel();
    if submit
        .send(GenRequest {
            prompt: prompt.to_string(),
            max_tokens,
            temp,
            session,
            reply: tx,
        })
        .is_err()
    {
        return bad(stream, 503, "server stopped");
    }

    // hold the status line until the first event so request-level errors
    // (busy session, context overflow) become a clean 4xx
    let first = match rx.recv_timeout(Duration::from_secs(60)) {
        Ok(ev) => ev,
        Err(_) => return bad(stream, 503, "generation timed out"),
    };
    let mut pending = match first {
        TokenEvent::Error(e) => return bad(stream, 400, &e),
        ev => Some(ev),
    };
    if http::write_chunked_head(stream, 200, "application/x-ndjson").is_err() {
        return false;
    }
    loop {
        let ev = match pending.take() {
            Some(ev) => ev,
            None => match rx.recv_timeout(Duration::from_secs(60)) {
                Ok(ev) => ev,
                Err(_) => {
                    let mut line = json_error("generation timed out");
                    line.push(b'\n');
                    let _ = http::write_chunk(stream, &line);
                    let _ = http::finish_chunks(stream);
                    return false;
                }
            },
        };
        let (line, done) = match ev {
            TokenEvent::Token(piece) => (
                Json::Obj(vec![(
                    "piece".into(),
                    Json::Str(protocol::escape_bytes(&piece)),
                )])
                .render(),
                false,
            ),
            TokenEvent::Done { n_tokens, gen_ms } => (
                Json::Obj(vec![
                    ("done".into(), Json::Bool(true)),
                    ("n_tokens".into(), Json::Num(n_tokens as f64)),
                    ("gen_ms".into(), Json::Num(gen_ms)),
                ])
                .render(),
                true,
            ),
            TokenEvent::Error(e) => (
                Json::Obj(vec![("error".into(), Json::Str(e))]).render(),
                true,
            ),
        };
        if http::write_chunk(stream, format!("{line}\n").as_bytes()).is_err() {
            return false;
        }
        if done {
            return http::finish_chunks(stream).is_ok();
        }
    }
}
