//! The network front end: a single-threaded epoll reactor that owns
//! every socket, speaking both the line protocol and HTTP/1.1.
//!
//! One acceptor/reactor thread (the `run` caller) holds all sockets in
//! non-blocking mode behind an epoll instance (`serve::reactor`). It
//! parses both wire formats incrementally off readiness events — the
//! `serve::http` parser already survives any read split, and the line
//! protocol accumulates until `\n` — and hands complete generation
//! requests to the per-model engine threads via the `ModelRegistry`.
//! Engine threads stream `TokenEvent`s back through a shared mailbox
//! (`ReplySink::shared`) and nudge the reactor with an eventfd, so the
//! reactor never blocks on a generation and a connection never pins a
//! thread. Consequences the threaded front end could not offer:
//!
//! * **Connection scaling**: 10k+ idle connections cost one epoll
//!   registration each and zero CPU (no 200 ms read-timeout busy-poll
//!   loops; idle eviction rides the reactor's timer wheel).
//! * **Keep-alive pipelining**: an HTTP connection runs any number of
//!   generations back to back; requests that arrive while one is in
//!   flight wait in the connection's input buffer (strictly sequential
//!   per connection, so responses never interleave).
//! * **No head-of-line blocking across models**: routing is a snapshot
//!   read (`registry::submit`); engine loads run on the registry's
//!   lifecycle thread, so a multi-second load of one model never stalls
//!   the reactor or traffic to resident models.
//!
//! Wire behavior is byte-for-byte that of the threaded front end: the
//! same request grammar, response lines, HTTP statuses and JSON bodies
//! (`tests/serve_invariants.rs` pins several of them bitwise). The
//! retryable rejection contract surfaces as `ERR retry: <reason>` on
//! the line protocol and 503 on HTTP (`TokenEvent::Retry`).
//!
//! When a client gives up on a generation (its socket dies or the 60 s
//! stall deadline passes), the handler flags the request as cancelled so
//! a still-queued request is dropped instead of executed — an abandoned
//! request can no longer advance a named session behind its client's
//! back; a running one is cut short on the engine's next send.

use std::collections::{HashMap, HashSet};
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::info;
use crate::obs::ModelObs;
use crate::serve::batcher::{EventSink, GenRequest, ReplySink, TokenEvent};
use crate::serve::http::{self, HttpRequest, Parsed};
use crate::serve::protocol::{self, Request, RETRY_PREFIX};
use crate::serve::reactor::{
    self, EpollEvent, Poller, TimerWheel, WakeFd, EPOLLERR, EPOLLHUP, EPOLLIN,
    EPOLLOUT,
};
use crate::serve::registry::{ModelRegistry, SubmitError};
use crate::util::json::Json;

/// Server knobs (the listener-level CLI flags of `chon serve`; the
/// per-model knobs — batching, session cache, residency, reload poll —
/// live in `registry::RegistryOpts`).
#[derive(Clone, Debug)]
pub struct ServeOpts {
    pub host: String,
    /// 0 = pick an ephemeral port (tests); `port()` reports the real one
    pub port: u16,
    /// HTTP front-end port (0 = ephemeral); None disables HTTP entirely
    pub http_port: Option<u16>,
    /// drop connections idle longer than this (0 = never)
    pub idle_timeout_ms: u64,
    /// cap on concurrently open connections (0 = unlimited); excess
    /// accepts get a best-effort `ERR busy` / HTTP 503 and are closed
    /// (counted in `chon_conns_rejected_total`)
    pub max_conns: usize,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            host: "127.0.0.1".into(),
            port: 7411,
            http_port: Some(7412),
            idle_timeout_ms: 60_000,
            max_conns: 0,
        }
    }
}

/// Which wire format a connection speaks.
#[derive(Clone, Copy, Debug, PartialEq)]
enum ConnKind {
    Line,
    Http,
}

/// A bound server, ready to `run`.
pub struct Server {
    listener: TcpListener,
    http_listener: Option<TcpListener>,
    registry: Arc<ModelRegistry>,
    shutdown: Arc<AtomicBool>,
    idle_timeout_ms: u64,
    max_conns: usize,
}

impl Server {
    /// Bind the listener(s) over a populated model registry.
    pub fn bind(registry: ModelRegistry, opts: &ServeOpts) -> Result<Server> {
        let addr = format!("{}:{}", opts.host, opts.port);
        let listener =
            TcpListener::bind(&addr).with_context(|| format!("binding {addr}"))?;
        let http_listener = match opts.http_port {
            Some(p) => {
                let haddr = format!("{}:{}", opts.host, p);
                Some(
                    TcpListener::bind(&haddr)
                        .with_context(|| format!("binding HTTP {haddr}"))?,
                )
            }
            None => None,
        };
        Ok(Server {
            listener,
            http_listener,
            registry: Arc::new(registry),
            shutdown: Arc::new(AtomicBool::new(false)),
            idle_timeout_ms: opts.idle_timeout_ms,
            max_conns: opts.max_conns,
        })
    }

    /// The actually-bound port (differs from the request when asking for 0).
    pub fn port(&self) -> u16 {
        self.listener.local_addr().map(|a| a.port()).unwrap_or(0)
    }

    /// The actually-bound HTTP port (None when HTTP is disabled).
    pub fn http_port(&self) -> Option<u16> {
        self.http_listener
            .as_ref()
            .and_then(|l| l.local_addr().ok())
            .map(|a| a.port())
    }

    /// A handle that makes `run` return (used by tests and signal glue).
    pub fn shutdown_flag(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// The model registry behind this server (tests poke generations and
    /// per-model stats through this).
    pub fn registry(&self) -> Arc<ModelRegistry> {
        self.registry.clone()
    }

    /// Serve until a shutdown command (or the shutdown flag) arrives.
    /// Returns the final stats snapshot line.
    pub fn run(self) -> Result<String> {
        // best-effort fd headroom for the 1k+-connection scaling paths;
        // a refusal (container policy) just keeps the inherited limit
        let fds = reactor::raise_nofile_limit(16 * 1024).unwrap_or(0);
        info!(
            "serving {} model(s) on port {} (http {:?}, epoll reactor, \
             nofile {fds})",
            self.registry.model_names().len(),
            self.port(),
            self.http_port(),
        );
        let mut reactor = Reactor::new(
            self.listener,
            self.http_listener,
            self.registry.clone(),
            self.shutdown.clone(),
            self.idle_timeout_ms,
            self.max_conns,
        )?;
        reactor.run()?;
        let line = self.registry.stats_line();
        self.registry.shutdown();
        info!("shutdown complete: {line}");
        Ok(line)
    }
}

/// A generation with no event for this long is abandoned (matches the
/// threaded front end's 60 s `recv_timeout`).
const GEN_STALL: Duration = Duration::from_secs(60);
/// After a shutdown command, in-flight generations get this long to
/// finish streaming before stragglers are cut.
const DRAIN_CAP: Duration = Duration::from_secs(60);
/// Input backlog cap per connection (pipelined requests + partial
/// lines). Honest traffic stays far below this: prompts cap at 4 KiB
/// pre-escaping and HTTP heads/bodies have their own parser caps.
const MAX_INBUF: usize = 256 * 1024;
/// Output backlog cap per connection: a consumer this far behind is
/// treated as dead (the threaded front end applied backpressure by
/// blocking a worker; the reactor must not buffer unboundedly).
const MAX_OUTBUF: usize = 1024 * 1024;

const TOK_LINE: u64 = 0;
const TOK_HTTP: u64 = 1;
const TOK_WAKE: u64 = 2;
const FIRST_CONN_TOKEN: u64 = 3;

/// Engine threads post generation events here; the reactor drains it
/// after each eventfd wake. One mailbox serves every connection.
struct GenMailbox {
    queue: Mutex<Vec<(u64, u64, TokenEvent)>>,
    wake: WakeFd,
}

/// Per-generation sink handed to the engine thread. Knows its
/// connection + generation id, so events route through the shared
/// mailbox; `closed` flips when the reactor abandons the generation,
/// making `send` fail so the engine cuts the generation short.
struct MailboxSink {
    mailbox: Arc<GenMailbox>,
    conn: u64,
    gen: u64,
    closed: Arc<AtomicBool>,
}

impl EventSink for MailboxSink {
    fn send(&self, ev: TokenEvent) -> std::result::Result<(), ()> {
        if self.closed.load(Ordering::Relaxed) {
            return Err(());
        }
        self.mailbox
            .queue
            .lock()
            .expect("mailbox poisoned")
            .push((self.conn, self.gen, ev));
        self.mailbox.wake.wake();
        Ok(())
    }
}

/// One in-flight generation on a connection.
struct Gen {
    id: u64,
    cancel: Arc<AtomicBool>,
    closed: Arc<AtomicBool>,
    /// HTTP: chunked head not yet written (status held for first event)
    started: bool,
    /// HTTP: client sent `Connection: close`
    close_after: bool,
    /// abandoned past this with no event (re-armed per event)
    deadline: Instant,
}

/// Write sink that accumulates unflushed response bytes; drained by
/// readiness events. `start` is a consume cursor compacted lazily.
#[derive(Default)]
struct OutBuf {
    buf: Vec<u8>,
    start: usize,
}

impl OutBuf {
    fn len(&self) -> usize {
        self.buf.len() - self.start
    }
    fn is_empty(&self) -> bool {
        self.start == self.buf.len()
    }
    fn pending(&self) -> &[u8] {
        &self.buf[self.start..]
    }
    fn consume(&mut self, n: usize) {
        self.start += n;
        if self.is_empty() {
            self.buf.clear();
            self.start = 0;
        } else if self.start > 64 * 1024 && self.start * 2 > self.buf.len() {
            self.buf.drain(..self.start);
            self.start = 0;
        }
    }
}

impl Write for OutBuf {
    fn write(&mut self, data: &[u8]) -> std::io::Result<usize> {
        self.buf.extend_from_slice(data);
        Ok(data.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// One connection owned by the reactor.
struct Conn {
    stream: TcpStream,
    kind: ConnKind,
    inbuf: Vec<u8>,
    outbuf: OutBuf,
    gen: Option<Gen>,
    /// stage histograms of the model this connection last generated
    /// against; write-flush spans are recorded here (set at submit, kept
    /// after the generation finishes so the terminal flush is attributed)
    obs: Option<Arc<ModelObs>>,
    /// epoll interest currently registered (avoid redundant epoll_ctl)
    interest: u32,
    /// peer sent EOF: no more requests, but responses may still flush
    peer_closed: bool,
    /// close once the out-buffer drains
    closing: bool,
    last_activity: Instant,
}

struct Reactor {
    poller: Poller,
    listener: Option<TcpListener>,
    http_listener: Option<TcpListener>,
    registry: Arc<ModelRegistry>,
    /// server-level spans and health gauges (shared with `/metrics`)
    obs: Arc<crate::obs::Registry>,
    stop: Arc<AtomicBool>,
    mailbox: Arc<GenMailbox>,
    conns: HashMap<u64, Conn>,
    /// connection tokens with an in-flight generation (stall sweep set)
    gens: HashSet<u64>,
    wheel: TimerWheel,
    next_token: u64,
    next_gen_id: u64,
    idle_timeout: Option<Duration>,
    max_conns: usize,
    draining: bool,
    drain_deadline: Instant,
}

impl Reactor {
    fn new(
        listener: TcpListener,
        http_listener: Option<TcpListener>,
        registry: Arc<ModelRegistry>,
        stop: Arc<AtomicBool>,
        idle_timeout_ms: u64,
        max_conns: usize,
    ) -> Result<Reactor> {
        let poller = Poller::new().context("creating epoll instance")?;
        listener.set_nonblocking(true)?;
        poller.add(listener.as_raw_fd(), TOK_LINE, EPOLLIN)?;
        if let Some(hl) = &http_listener {
            hl.set_nonblocking(true)?;
            poller.add(hl.as_raw_fd(), TOK_HTTP, EPOLLIN)?;
        }
        let wake = WakeFd::new().context("creating wake eventfd")?;
        poller.add(wake.raw(), TOK_WAKE, EPOLLIN)?;
        let now = Instant::now();
        let obs = registry.obs();
        Ok(Reactor {
            poller,
            listener: Some(listener),
            http_listener,
            registry,
            obs,
            stop,
            mailbox: Arc::new(GenMailbox { queue: Mutex::new(Vec::new()), wake }),
            conns: HashMap::new(),
            gens: HashSet::new(),
            wheel: TimerWheel::new(now),
            next_token: FIRST_CONN_TOKEN,
            next_gen_id: 0,
            idle_timeout: (idle_timeout_ms > 0)
                .then(|| Duration::from_millis(idle_timeout_ms)),
            max_conns,
            draining: false,
            drain_deadline: now,
        })
    }

    fn run(&mut self) -> Result<()> {
        let mut events = [EpollEvent::default(); 256];
        let mut next_tick = Instant::now() + Duration::from_secs(1);
        loop {
            if !self.draining && self.stop.load(Ordering::SeqCst) {
                self.begin_drain();
            }
            if self.draining
                && (self.conns.is_empty() || Instant::now() >= self.drain_deadline)
            {
                break;
            }
            let timeout = next_tick
                .saturating_duration_since(Instant::now())
                .as_millis()
                .min(TimerWheel::tick_ms() as u128) as i32;
            let n = self.poller.wait(&mut events, timeout)?;
            for ev in &events[..n] {
                let (token, bits) = (ev.data, ev.events);
                match token {
                    TOK_LINE => self.accept_ready(ConnKind::Line),
                    TOK_HTTP => self.accept_ready(ConnKind::Http),
                    TOK_WAKE => {
                        self.mailbox.wake.drain();
                        self.process_mailbox();
                    }
                    tok => self.conn_ready(tok, bits),
                }
            }
            let now = Instant::now();
            if now >= next_tick {
                // a loaded event loop fires the 1 Hz tick late; the
                // overshoot is the lag a scrape sees as reactor health
                self.obs
                    .server
                    .tick_lag_us
                    .set(now.saturating_duration_since(next_tick).as_micros() as u64);
                self.tick(now);
                next_tick = now + Duration::from_secs(1);
            }
        }
        // cut whatever is left (stragglers past the drain cap)
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for tok in tokens {
            self.close_conn(tok);
        }
        Ok(())
    }

    /// Shutdown observed: stop accepting, flush-and-close everything
    /// idle, and let in-flight generations finish streaming.
    fn begin_drain(&mut self) {
        self.draining = true;
        self.drain_deadline = Instant::now() + DRAIN_CAP;
        if let Some(l) = self.listener.take() {
            let _ = self.poller.del(l.as_raw_fd());
        }
        if let Some(l) = self.http_listener.take() {
            let _ = self.poller.del(l.as_raw_fd());
        }
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for tok in tokens {
            let done = {
                let conn = self.conns.get_mut(&tok).expect("token just listed");
                conn.closing = true;
                conn.gen.is_none() && conn.outbuf.is_empty()
            };
            if done {
                self.close_conn(tok);
            } else {
                self.flush_conn(tok);
            }
        }
    }

    /// Once per second: expire idle connections, time out stalled
    /// generations, and nudge the registry's hot-reload probe so a
    /// republished checkpoint is noticed even with zero traffic.
    fn tick(&mut self, now: Instant) {
        for tok in self.wheel.expire(now) {
            let Some(idle) = self.idle_timeout else { continue };
            let Some((deadline, generating)) = self
                .conns
                .get(&tok)
                .map(|c| (c.last_activity + idle, c.gen.is_some()))
            else {
                continue; // connection already gone; stale wheel entry
            };
            if now >= deadline && !generating {
                self.close_conn(tok);
            } else {
                // still active (or mid-generation): re-arm at the actual
                // deadline (clamped to the wheel granularity) so eviction
                // fires within one tick of idle_timeout, not up to 2x it
                let gran = Duration::from_millis(TimerWheel::tick_ms() as u64);
                self.wheel.insert(tok, deadline.max(now + gran), now);
            }
        }
        let stalled: Vec<u64> = self
            .gens
            .iter()
            .copied()
            .filter(|tok| {
                self.conns
                    .get(tok)
                    .and_then(|c| c.gen.as_ref())
                    .is_some_and(|g| now >= g.deadline)
            })
            .collect();
        for tok in stalled {
            self.timeout_generation(tok);
        }
        self.registry.poll_reloads();
    }

    // ---- accept path ----

    fn accept_ready(&mut self, kind: ConnKind) {
        loop {
            let accepted = match kind {
                ConnKind::Line => self.listener.as_ref().map(|l| l.accept()),
                ConnKind::Http => self.http_listener.as_ref().map(|l| l.accept()),
            };
            let Some(res) = accepted else { return };
            match res {
                Ok((stream, _)) => {
                    if self.max_conns > 0 && self.conns.len() >= self.max_conns {
                        self.reject_busy(stream, kind);
                        continue;
                    }
                    self.adopt(stream, kind);
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return,
                Err(e) => {
                    info!("accept error: {e}");
                    return;
                }
            }
        }
    }

    /// Refuse an over-`--max-conns` accept. A silent close is
    /// indistinguishable from a crash to the client (and to the load
    /// harness), so send one best-effort shed notice first — `ERR busy`
    /// on the line protocol, an HTTP 503 on the web front end — and
    /// count the rejection. The write must not block the reactor: the
    /// socket goes non-blocking and a partial/failed write is simply
    /// abandoned (the close still sheds the load either way).
    fn reject_busy(&mut self, stream: TcpStream, kind: ConnKind) {
        let mut stream = stream;
        if stream.set_nonblocking(true).is_ok() {
            match kind {
                ConnKind::Line => {
                    let _ = stream.write(b"ERR busy: connection limit reached\n");
                }
                ConnKind::Http => {
                    let mut buf = Vec::new();
                    let _ = http::write_response(
                        &mut buf,
                        503,
                        "application/json",
                        &json_error("busy: connection limit reached"),
                        false,
                    );
                    let _ = stream.write(&buf);
                }
            }
        }
        self.obs.server.conns_rejected.inc();
        // dropped here: refuse by closing after the best-effort notice
    }

    fn adopt(&mut self, stream: TcpStream, kind: ConnKind) {
        let t0 = Instant::now();
        if stream.set_nonblocking(true).is_err() {
            return;
        }
        let _ = stream.set_nodelay(true);
        let tok = self.next_token;
        self.next_token += 1;
        if self.poller.add(stream.as_raw_fd(), tok, EPOLLIN).is_err() {
            return;
        }
        let now = Instant::now();
        self.conns.insert(
            tok,
            Conn {
                stream,
                kind,
                inbuf: Vec::new(),
                outbuf: OutBuf::default(),
                gen: None,
                obs: None,
                interest: EPOLLIN,
                peer_closed: false,
                closing: false,
                last_activity: now,
            },
        );
        if let Some(idle) = self.idle_timeout {
            self.wheel.insert(tok, now + idle, now);
        }
        self.obs.server.open_conns.set(self.conns.len() as u64);
        self.obs.server.accept.record_elapsed(t0.elapsed());
    }

    // ---- readiness dispatch ----

    fn conn_ready(&mut self, tok: u64, bits: u32) {
        if !self.conns.contains_key(&tok) {
            return; // closed earlier in this batch
        }
        if bits & (EPOLLERR | EPOLLHUP) != 0 {
            self.close_conn(tok);
            return;
        }
        if bits & EPOLLOUT != 0 && !self.flush_conn(tok) {
            return;
        }
        if bits & EPOLLIN != 0 {
            self.readable(tok);
        }
    }

    fn readable(&mut self, tok: u64) {
        enum ReadEnd {
            Open,
            Eof,
            Dead,
        }
        let mut tmp = [0u8; 16 * 1024];
        let end = {
            let Some(conn) = self.conns.get_mut(&tok) else { return };
            let mut end = ReadEnd::Open;
            loop {
                match conn.stream.read(&mut tmp) {
                    Ok(0) => {
                        end = ReadEnd::Eof;
                        break;
                    }
                    Ok(n) => {
                        conn.inbuf.extend_from_slice(&tmp[..n]);
                        conn.last_activity = Instant::now();
                        if conn.inbuf.len() > MAX_INBUF {
                            end = ReadEnd::Dead;
                            break;
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        end = ReadEnd::Dead;
                        break;
                    }
                }
            }
            end
        };
        if matches!(end, ReadEnd::Dead) {
            self.close_conn(tok);
            return;
        }
        if matches!(end, ReadEnd::Eof) {
            // half-close: every complete request already buffered still
            // gets served (write-all-then-shutdown batch clients rely on
            // it, matching the threaded front end's read_line loop);
            // advance() flips `closing` once the backlog is drained
            let Some(conn) = self.conns.get_mut(&tok) else { return };
            conn.peer_closed = true;
        }
        self.advance(tok);
    }

    /// Parse-and-dispatch loop: strictly one request at a time per
    /// connection; pipelined requests wait in `inbuf` until the current
    /// generation finishes.
    fn advance(&mut self, tok: u64) {
        loop {
            let Some(conn) = self.conns.get_mut(&tok) else { return };
            if conn.closing || conn.gen.is_some() {
                break;
            }
            let t_parse = Instant::now();
            match conn.kind {
                ConnKind::Line => {
                    let Some(nl) = conn.inbuf.iter().position(|&b| b == b'\n')
                    else {
                        if conn.inbuf.len() > 16 * 1024 {
                            // longest legal line is a fully escaped max
                            // prompt (~16 KiB); anything bigger is abuse
                            let _ = conn
                                .outbuf
                                .write_all(b"ERR request line too long\n");
                            conn.closing = true;
                        }
                        break;
                    };
                    let line_bytes: Vec<u8> = conn.inbuf.drain(..=nl).collect();
                    let Ok(line) = String::from_utf8(line_bytes) else {
                        self.close_conn(tok); // matches read_line's Err
                        return;
                    };
                    self.process_line(tok, &line);
                }
                ConnKind::Http => match http::parse_request(&conn.inbuf) {
                    Ok(Parsed::Complete(req, consumed)) => {
                        conn.inbuf.drain(..consumed);
                        // parse span: only complete requests count (a
                        // partial parse re-runs on the next readable)
                        self.obs.server.parse.record_elapsed(t_parse.elapsed());
                        self.process_http(tok, req);
                    }
                    Ok(Parsed::Partial) => break,
                    Err(e) => {
                        let _ = http::write_response(
                            &mut conn.outbuf,
                            e.status,
                            "application/json",
                            &json_error(&e.message),
                            false,
                        );
                        conn.closing = true;
                        break;
                    }
                },
            }
        }
        // peer half-closed and nothing left in flight: the residual
        // inbuf bytes (if any) can never complete into a request, so the
        // connection is done once the outbuf drains
        if let Some(conn) = self.conns.get_mut(&tok) {
            if conn.peer_closed && conn.gen.is_none() {
                conn.closing = true;
            }
        }
        self.flush_conn(tok);
        self.update_interest(tok);
    }

    // ---- line protocol ----

    fn process_line(&mut self, tok: u64, line: &str) {
        let t0 = Instant::now();
        let parsed = protocol::parse_request(line);
        self.obs.server.parse.record_elapsed(t0.elapsed());
        let reply = match parsed {
            Err(e) => format!("ERR {}\n", protocol::escape(&e)),
            Ok(Request::Ping) => "PONG\n".into(),
            // observation is side-effect-free: no reload probe here (the
            // probe rides the reactor's 1 Hz tick only)
            Ok(Request::Stats) => {
                format!("STATS {}\n", self.registry.stats_line())
            }
            Ok(Request::Shutdown) => {
                self.stop.store(true, Ordering::SeqCst);
                if let Some(conn) = self.conns.get_mut(&tok) {
                    let _ = conn.outbuf.write_all(b"BYE\n");
                    conn.closing = true;
                }
                return;
            }
            Ok(Request::Gen { max_tokens, temp, prompt, session, model }) => {
                self.submit_generation(
                    tok, model, max_tokens, temp, prompt, session, None,
                );
                return;
            }
        };
        if let Some(conn) = self.conns.get_mut(&tok) {
            let _ = conn.outbuf.write_all(reply.as_bytes());
        }
    }

    // ---- HTTP ----

    fn process_http(&mut self, tok: u64, req: HttpRequest) {
        let close = req.wants_close();
        let path = req.target.split('?').next().unwrap_or("").to_string();
        match (req.method.as_str(), path.as_str()) {
            ("GET" | "HEAD", "/stats") => {
                // observation is side-effect-free: no reload probe here
                // (the probe rides the reactor's 1 Hz tick only, pinned
                // by `stats_and_metrics_never_initiate_loads`)
                let body = self.registry.stats_json().render_pretty();
                self.respond(
                    tok,
                    200,
                    body.as_bytes(),
                    req.method == "HEAD",
                    close,
                );
            }
            ("GET" | "HEAD", "/metrics") => {
                let body = self.registry.metrics_text();
                let Some(conn) = self.conns.get_mut(&tok) else { return };
                let _ = http::write_response(
                    &mut conn.outbuf,
                    200,
                    crate::obs::expo::CONTENT_TYPE,
                    body.as_bytes(),
                    req.method == "HEAD",
                );
                if close {
                    conn.closing = true;
                }
            }
            ("POST", "/shutdown") => {
                let body =
                    Json::Obj(vec![("ok".into(), Json::Bool(true))]).render();
                self.respond(tok, 200, body.as_bytes(), false, true);
                self.stop.store(true, Ordering::SeqCst);
            }
            ("POST", "/generate") => self.http_generate(tok, &req),
            (_, "/stats" | "/metrics" | "/shutdown" | "/generate") => self
                .respond(
                    tok,
                    405,
                    &json_error("method not allowed for this path"),
                    req.method == "HEAD",
                    close,
                ),
            _ => self.respond(
                tok,
                404,
                &json_error(
                    "no such path (want /generate, /stats, /metrics, /shutdown)",
                ),
                req.method == "HEAD",
                close,
            ),
        }
    }

    /// Queue one fixed-length JSON response; `close` flushes then drops.
    fn respond(&mut self, tok: u64, status: u16, body: &[u8], head_only: bool, close: bool) {
        let Some(conn) = self.conns.get_mut(&tok) else { return };
        let _ = http::write_response(
            &mut conn.outbuf,
            status,
            "application/json",
            body,
            head_only,
        );
        if close {
            conn.closing = true;
        }
    }

    /// `POST /generate`: body `{"prompt": "...", "max_tokens"?, "temp"?,
    /// "session"?, "model"?}`. Streams newline-delimited JSON via chunked
    /// transfer encoding: one `{"piece": "<escaped>"}` object per token
    /// (piece is `protocol::escape_bytes`-escaped so split multi-byte
    /// characters survive JSON), then `{"done": true, "n_tokens": N,
    /// "gen_ms": T}`. An unknown `"model"` is a clean 404. The status
    /// line is held until the first engine event so request-level errors
    /// (busy session, context overflow) become a clean 4xx and
    /// retryable rejections a 503.
    fn http_generate(&mut self, tok: u64, req: &HttpRequest) {
        let close = req.wants_close();
        macro_rules! bad {
            ($status:expr, $msg:expr) => {{
                self.respond(tok, $status, &json_error($msg), false, close);
                return;
            }};
        }
        if req.http10 {
            // chunked transfer encoding does not exist in HTTP/1.0 — a
            // 1.0 client would read the chunk framing as body bytes
            bad!(505, "/generate streams chunked; use HTTP/1.1");
        }
        let Ok(body) = std::str::from_utf8(&req.body) else {
            bad!(400, "body is not UTF-8");
        };
        let doc = match Json::parse(body) {
            Ok(d) => d,
            Err(e) => bad!(400, &format!("body is not JSON: {e}")),
        };
        let Some(prompt) = doc.get("prompt").and_then(|v| v.as_str()) else {
            bad!(400, "missing string field \"prompt\"");
        };
        let max_tokens = match doc.get("max_tokens") {
            None => 32usize,
            Some(v) => match v.as_f64() {
                Some(n) if n >= 0.0 && n.fract() == 0.0 => n as usize,
                _ => bad!(400, "max_tokens must be an integer"),
            },
        };
        let temp = match doc.get("temp") {
            None => 0.0f32,
            Some(v) => match v.as_f64() {
                Some(n) => n as f32,
                None => bad!(400, "temp must be a number"),
            },
        };
        let session = match doc.get("session") {
            None => None,
            Some(v) => match v.as_str() {
                Some(s) => Some(s.to_string()),
                None => bad!(400, "session must be a string"),
            },
        };
        let model = match doc.get("model") {
            None => None,
            Some(v) => match v.as_str() {
                Some(m) if protocol::valid_model_name(m) => Some(m.to_string()),
                Some(_) => bad!(400, "bad model name"),
                None => bad!(400, "model must be a string"),
            },
        };
        if let Err(e) =
            protocol::validate_gen(max_tokens, temp, prompt, session.as_deref())
        {
            bad!(400, &e);
        }
        self.submit_generation(
            tok,
            model,
            max_tokens,
            temp,
            prompt.to_string(),
            session,
            Some(close),
        );
    }

    // ---- generation plumbing ----

    /// Build the mailbox sink, submit to the registry, and park the
    /// connection in "one generation in flight" state. `http` is None
    /// for the line protocol, Some(wants_close) for `POST /generate`.
    fn submit_generation(
        &mut self,
        tok: u64,
        model: Option<String>,
        max_tokens: usize,
        temp: f32,
        prompt: String,
        session: Option<String>,
        http: Option<bool>,
    ) {
        let gen_id = self.next_gen_id;
        self.next_gen_id += 1;
        let cancel = Arc::new(AtomicBool::new(false));
        let closed = Arc::new(AtomicBool::new(false));
        let sink = ReplySink::shared(Arc::new(MailboxSink {
            mailbox: self.mailbox.clone(),
            conn: tok,
            gen: gen_id,
            closed: closed.clone(),
        }));
        let req = GenRequest {
            prompt,
            max_tokens,
            temp,
            session,
            reply: sink,
            cancel: cancel.clone(),
            queued_at: Instant::now(),
        };
        if let Err(e) = self.registry.submit(model.as_deref(), req) {
            match http {
                None => {
                    if let Some(conn) = self.conns.get_mut(&tok) {
                        let _ = conn.outbuf.write_all(
                            format!(
                                "ERR {}\n",
                                protocol::escape(&e.to_string())
                            )
                            .as_bytes(),
                        );
                    }
                }
                Some(close) => {
                    let status = match &e {
                        SubmitError::UnknownModel(_) => 404,
                        SubmitError::Load(_) => 500,
                        SubmitError::Stopped => 503,
                    };
                    self.respond(
                        tok,
                        status,
                        &json_error(&e.to_string()),
                        false,
                        close,
                    );
                }
            }
            return;
        }
        let gen_obs = self.registry.model_obs(model.as_deref());
        let Some(conn) = self.conns.get_mut(&tok) else {
            // connection died between parse and submit: abandon
            cancel.store(true, Ordering::Relaxed);
            closed.store(true, Ordering::Relaxed);
            return;
        };
        conn.obs = gen_obs;
        conn.gen = Some(Gen {
            id: gen_id,
            cancel,
            closed,
            started: http.is_none(), // line protocol has no status hold
            close_after: http.unwrap_or(false),
            deadline: Instant::now() + GEN_STALL,
        });
        self.gens.insert(tok);
    }

    /// Deliver every queued engine event to its connection.
    fn process_mailbox(&mut self) {
        let batch: Vec<(u64, u64, TokenEvent)> = {
            let mut q = self.mailbox.queue.lock().expect("mailbox poisoned");
            std::mem::take(&mut *q)
        };
        self.obs.server.mailbox_depth.set(batch.len() as u64);
        let mut touched: HashSet<u64> = HashSet::new();
        for (tok, gen_id, ev) in batch {
            let stale = !self
                .conns
                .get(&tok)
                .and_then(|c| c.gen.as_ref())
                .is_some_and(|g| g.id == gen_id);
            if stale {
                continue; // connection or generation already gone
            }
            touched.insert(tok);
            let finished = self.deliver_event(tok, ev);
            if finished {
                self.finish_generation(tok);
            }
            let queued = self.conns.get(&tok).map_or(0, |c| c.outbuf.len());
            self.obs.server.outbuf_highwater.record_max(queued as u64);
            let over = queued > MAX_OUTBUF;
            if over {
                // consumer hopelessly behind: treat as dead
                self.close_conn(tok);
                touched.remove(&tok);
            }
        }
        for tok in touched {
            self.flush_conn(tok);
            self.update_interest(tok);
        }
    }

    /// Render one engine event into the connection's out-buffer.
    /// Returns true when the generation is over.
    fn deliver_event(&mut self, tok: u64, ev: TokenEvent) -> bool {
        let kind = self.conns.get(&tok).map(|c| c.kind);
        match kind {
            Some(ConnKind::Line) => self.deliver_line_event(tok, ev),
            Some(ConnKind::Http) => self.deliver_http_event(tok, ev),
            None => true,
        }
    }

    fn deliver_line_event(&mut self, tok: u64, ev: TokenEvent) -> bool {
        let Some(conn) = self.conns.get_mut(&tok) else { return true };
        let g = conn.gen.as_mut().expect("checked by caller");
        g.deadline = Instant::now() + GEN_STALL;
        conn.last_activity = Instant::now();
        let (line, done) = match ev {
            TokenEvent::Token(piece) => {
                (format!("TOK {}\n", protocol::escape_bytes(&piece)), false)
            }
            TokenEvent::Done { n_tokens, gen_ms } => {
                (format!("DONE {n_tokens} {gen_ms:.3}\n"), true)
            }
            TokenEvent::Error(e) => {
                (format!("ERR {}\n", protocol::escape(&e)), true)
            }
            TokenEvent::Retry(e) => (
                format!("ERR {RETRY_PREFIX}{}\n", protocol::escape(&e)),
                true,
            ),
        };
        let _ = conn.outbuf.write_all(line.as_bytes());
        done
    }

    fn deliver_http_event(&mut self, tok: u64, ev: TokenEvent) -> bool {
        let Some(conn) = self.conns.get_mut(&tok) else { return true };
        let g = conn.gen.as_mut().expect("checked by caller");
        g.deadline = Instant::now() + GEN_STALL;
        conn.last_activity = Instant::now();
        if !g.started {
            // status hold: the first event decides between a clean
            // status response and the 200 chunked stream
            match &ev {
                TokenEvent::Error(e) => {
                    let (e, close) = (e.clone(), g.close_after);
                    let _ = http::write_response(
                        &mut conn.outbuf,
                        400,
                        "application/json",
                        &json_error(&e),
                        false,
                    );
                    if close {
                        conn.closing = true;
                    }
                    return true;
                }
                TokenEvent::Retry(e) => {
                    // server-initiated and explicitly retryable (drain,
                    // LRU unload): must not come back as don't-retry 4xx
                    let (e, close) = (e.clone(), g.close_after);
                    let _ = http::write_response(
                        &mut conn.outbuf,
                        503,
                        "application/json",
                        &json_error(&e),
                        false,
                    );
                    if close {
                        conn.closing = true;
                    }
                    return true;
                }
                _ => {
                    g.started = true;
                    let _ = http::write_chunked_head(
                        &mut conn.outbuf,
                        200,
                        "application/x-ndjson",
                    );
                }
            }
        }
        let (line, done) = match ev {
            TokenEvent::Token(piece) => (
                Json::Obj(vec![(
                    "piece".into(),
                    Json::Str(protocol::escape_bytes(&piece)),
                )])
                .render(),
                false,
            ),
            TokenEvent::Done { n_tokens, gen_ms } => (
                Json::Obj(vec![
                    ("done".into(), Json::Bool(true)),
                    ("n_tokens".into(), Json::Num(n_tokens as f64)),
                    ("gen_ms".into(), Json::Num(gen_ms)),
                ])
                .render(),
                true,
            ),
            TokenEvent::Error(e) => (
                Json::Obj(vec![("error".into(), Json::Str(e))]).render(),
                true,
            ),
            TokenEvent::Retry(e) => (
                Json::Obj(vec![(
                    "error".into(),
                    Json::Str(format!("{RETRY_PREFIX}{e}")),
                )])
                .render(),
                true,
            ),
        };
        let _ = http::write_chunk(&mut conn.outbuf, format!("{line}\n").as_bytes());
        if done {
            let _ = http::finish_chunks(&mut conn.outbuf);
            if g.close_after {
                conn.closing = true;
            }
        }
        done
    }

    /// The in-flight generation reached a terminal event: release the
    /// connection for its next pipelined request (or the drain).
    fn finish_generation(&mut self, tok: u64) {
        if let Some(conn) = self.conns.get_mut(&tok) {
            conn.gen = None;
            if self.draining {
                conn.closing = true;
            }
        }
        self.gens.remove(&tok);
        self.advance(tok);
    }

    /// No engine event within `GEN_STALL`: abandon the generation the
    /// same way the threaded front end's 60 s `recv_timeout` did.
    fn timeout_generation(&mut self, tok: u64) {
        let Some(conn) = self.conns.get_mut(&tok) else { return };
        let Some(g) = conn.gen.take() else { return };
        g.cancel.store(true, Ordering::Relaxed);
        g.closed.store(true, Ordering::Relaxed);
        self.gens.remove(&tok);
        match conn.kind {
            ConnKind::Line => {
                let _ = conn.outbuf.write_all(b"ERR generation timed out\n");
            }
            ConnKind::Http if !g.started => {
                let _ = http::write_response(
                    &mut conn.outbuf,
                    503,
                    "application/json",
                    &json_error("generation timed out"),
                    false,
                );
                if g.close_after {
                    conn.closing = true;
                }
            }
            ConnKind::Http => {
                // mid-stream: emit a terminal error object and close
                // (the truncated chunk stream is not reusable)
                let mut line = json_error("generation timed out");
                line.push(b'\n');
                let _ = http::write_chunk(&mut conn.outbuf, &line);
                let _ = http::finish_chunks(&mut conn.outbuf);
                conn.closing = true;
            }
        }
        self.advance(tok);
    }

    // ---- socket plumbing ----

    /// Drain the out-buffer to the socket as far as the kernel accepts.
    /// Returns false when the connection died (and was closed).
    fn flush_conn(&mut self, tok: u64) -> bool {
        let dead = {
            let Some(conn) = self.conns.get_mut(&tok) else { return false };
            let mut dead = false;
            let had = conn.outbuf.len();
            let t0 = Instant::now();
            while !conn.outbuf.is_empty() {
                match conn.stream.write(conn.outbuf.pending()) {
                    Ok(0) => {
                        dead = true;
                        break;
                    }
                    Ok(n) => {
                        conn.outbuf.consume(n);
                        conn.last_activity = Instant::now();
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                    Err(_) => {
                        dead = true;
                        break;
                    }
                }
            }
            // write-flush span: time spent pushing this connection's
            // response bytes into the kernel, attributed to the model of
            // its most recent generation
            if had > conn.outbuf.len() {
                if let Some(o) = &conn.obs {
                    o.write_flush.record_elapsed(t0.elapsed());
                }
            }
            // `closing` only takes effect once nothing is in flight:
            // drain and half-close both let the current generation
            // finish streaming (finish_generation/advance re-flush and
            // close once `gen` clears)
            dead || (conn.outbuf.is_empty() && conn.closing && conn.gen.is_none())
        };
        if dead {
            self.close_conn(tok);
            return false;
        }
        self.update_interest(tok);
        true
    }

    /// Keep the epoll interest set in sync with what the connection can
    /// actually use: EPOLLIN until the peer half-closed, EPOLLOUT only
    /// while the out-buffer has pending bytes.
    fn update_interest(&mut self, tok: u64) {
        let Some(conn) = self.conns.get_mut(&tok) else { return };
        let mut want = 0u32;
        if !conn.peer_closed {
            want |= EPOLLIN;
        }
        if !conn.outbuf.is_empty() {
            want |= EPOLLOUT;
        }
        if want != conn.interest {
            if self
                .poller
                .modify(conn.stream.as_raw_fd(), tok, want)
                .is_ok()
            {
                conn.interest = want;
            }
        }
    }

    /// Drop a connection: abandon its generation (cancel if queued, cut
    /// short if running) and deregister the socket.
    fn close_conn(&mut self, tok: u64) {
        let Some(conn) = self.conns.remove(&tok) else { return };
        if let Some(g) = conn.gen {
            g.cancel.store(true, Ordering::Relaxed);
            g.closed.store(true, Ordering::Relaxed);
            self.gens.remove(&tok);
        }
        let _ = self.poller.del(conn.stream.as_raw_fd());
        self.obs.server.open_conns.set(self.conns.len() as u64);
        // conn.stream drops here, closing the fd
    }
}

fn json_error(msg: &str) -> Vec<u8> {
    Json::Obj(vec![("error".into(), Json::Str(msg.to_string()))])
        .render()
        .into_bytes()
}
