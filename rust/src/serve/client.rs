//! `chon client` — a protocol client doubling as a load generator.
//!
//! One-shot mode sends a single GEN and prints the generation; load mode
//! spreads `requests` across `concurrency` threads (one connection per
//! thread, requests pipelined sequentially on it) and reports throughput
//! plus latency percentiles, then the server's own batching stats.
//! `--model NAME` routes to a registry model; load mode accepts several
//! names (`--model a,b`) and sprays requests across them round-robin,
//! reporting latency percentiles per model on top of the aggregate.
//! `--idle-conns N` additionally parks N idle connections on the server
//! for the whole run (the connection-scaling mode): with the epoll
//! reactor they must all survive a concurrent load run untouched, which
//! the report verifies with a PING round-trip per parked connection.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::serve::protocol;

/// Load-generator knobs (CLI flags of `chon client`).
#[derive(Clone, Debug)]
pub struct ClientOpts {
    pub host: String,
    pub port: u16,
    pub requests: usize,
    pub concurrency: usize,
    pub max_tokens: usize,
    pub temp: f32,
    pub prompt: String,
    /// registry model names to spray across (empty = the server default)
    pub models: Vec<String>,
    /// park this many idle connections for the duration of the load run
    /// (0 = none): exercises the server's connection scaling
    pub idle_conns: usize,
}

impl Default for ClientOpts {
    fn default() -> Self {
        ClientOpts {
            host: "127.0.0.1".into(),
            port: 7411,
            requests: 0,
            concurrency: 4,
            max_tokens: 32,
            temp: 0.0,
            prompt: "the ".into(),
            models: Vec::new(),
            idle_conns: 0,
        }
    }
}

/// Liveness backstop for reads. Dead-socket detection is primarily the
/// server's job now (the reactor closes a connection it gives up on,
/// which surfaces here as EOF mid-read) — the old 200 ms-granularity
/// busy-poll loop is gone — but a server hung without closing the
/// socket (stuck reactor, network drop with no RST) must still fail the
/// client instead of blocking it forever.
const READ_TIMEOUT: Duration = Duration::from_secs(120);

fn connect(host: &str, port: u16) -> Result<TcpStream> {
    let addr = format!("{host}:{port}");
    let s = TcpStream::connect(&addr).with_context(|| format!("connecting {addr}"))?;
    s.set_nodelay(true).ok();
    s.set_read_timeout(Some(READ_TIMEOUT))
        .context("setting read timeout")?;
    Ok(s)
}

/// Open one protocol connection (nodelay + read timeout applied) — the
/// library entry point the loadtest harness drives persistent-connection
/// workloads through.
pub fn open_conn(host: &str, port: u16) -> Result<TcpStream> {
    connect(host, port)
}

/// A fleet of parked idle connections (the connection-scaling mode).
/// The server must keep every one of them open at zero cost while other
/// connections run generations.
pub struct IdleFleet {
    conns: Vec<TcpStream>,
}

impl IdleFleet {
    /// Open `n` connections and leave them idle.
    pub fn open(host: &str, port: u16, n: usize) -> Result<IdleFleet> {
        let mut conns = Vec::with_capacity(n);
        for i in 0..n {
            conns.push(
                connect(host, port).with_context(|| format!("idle conn {i}"))?,
            );
        }
        Ok(IdleFleet { conns })
    }

    pub fn len(&self) -> usize {
        self.conns.len()
    }

    pub fn is_empty(&self) -> bool {
        self.conns.is_empty()
    }

    /// PING every parked connection; returns how many answered PONG
    /// (i.e. survived being idle — were not evicted or leaked).
    pub fn check_alive(&mut self) -> usize {
        let mut alive = 0;
        for s in &mut self.conns {
            if ping(s).is_ok() {
                alive += 1;
            }
        }
        alive
    }
}

/// One PING/PONG round-trip on an open connection.
pub fn ping(stream: &mut TcpStream) -> Result<()> {
    stream.write_all(b"PING\n")?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    if line.trim_end_matches(['\r', '\n']) != "PONG" {
        bail!("unexpected PING response {line:?}");
    }
    Ok(())
}

/// Run one GEN on an open connection; returns (text, n_tokens, latency_ms).
pub fn generate_on(
    stream: &mut TcpStream,
    prompt: &str,
    max_tokens: usize,
    temp: f32,
) -> Result<(String, usize, f64)> {
    generate_on_for(stream, None, prompt, max_tokens, temp)
}

/// `generate_on` routed to a registry model (None = server default).
pub fn generate_on_for(
    stream: &mut TcpStream,
    model: Option<&str>,
    prompt: &str,
    max_tokens: usize,
    temp: f32,
) -> Result<(String, usize, f64)> {
    let line = protocol::format_gen_for(model, max_tokens, temp, prompt);
    generate_line_on(stream, &line)
}

/// Run one SGEN (named-session) request on an open connection; the
/// server keeps the session's decode state under `session` so the next
/// request with the same id continues the context.
pub fn generate_session_on(
    stream: &mut TcpStream,
    session: &str,
    prompt: &str,
    max_tokens: usize,
    temp: f32,
) -> Result<(String, usize, f64)> {
    generate_session_on_for(stream, None, session, prompt, max_tokens, temp)
}

/// `generate_session_on` routed to a registry model.
pub fn generate_session_on_for(
    stream: &mut TcpStream,
    model: Option<&str>,
    session: &str,
    prompt: &str,
    max_tokens: usize,
    temp: f32,
) -> Result<(String, usize, f64)> {
    let line = protocol::format_sgen_for(model, session, max_tokens, temp, prompt);
    generate_line_on(stream, &line)
}

fn generate_line_on(
    stream: &mut TcpStream,
    request_line: &str,
) -> Result<(String, usize, f64)> {
    let t0 = Instant::now();
    stream.write_all(request_line.as_bytes())?;
    let mut reader = BufReader::new(stream.try_clone()?);
    // assemble raw bytes; UTF-8-lossy conversion happens once at the end
    // so characters split across streamed tokens survive
    let mut bytes: Vec<u8> = Vec::new();
    let mut line = String::new();
    loop {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("server closed the connection mid-generation");
        }
        let l = line.trim_end_matches(['\r', '\n']);
        if let Some(piece) = l.strip_prefix("TOK ") {
            bytes.extend(
                protocol::unescape_bytes(piece).map_err(|e| anyhow::anyhow!("{e}"))?,
            );
        } else if let Some(done) = l.strip_prefix("DONE ") {
            // strict: a garbled terminator is a protocol error, not a
            // zero-token success
            let mut it = done.split_whitespace();
            let n: usize = it
                .next()
                .context("DONE missing token count")?
                .parse()
                .with_context(|| format!("bad DONE line {l:?}"))?;
            let _ms: f64 = it
                .next()
                .context("DONE missing gen_ms")?
                .parse()
                .with_context(|| format!("bad DONE line {l:?}"))?;
            let text = String::from_utf8_lossy(&bytes).into_owned();
            return Ok((text, n, t0.elapsed().as_secs_f64() * 1e3));
        } else if let Some(err) = l.strip_prefix("ERR ") {
            bail!("server error: {}", protocol::unescape(err).unwrap_or_else(|_| err.into()));
        } else {
            bail!("unexpected response line {l:?}");
        }
    }
}

/// One-shot generation over a fresh connection.
pub fn generate_once(
    host: &str,
    port: u16,
    prompt: &str,
    max_tokens: usize,
    temp: f32,
) -> Result<(String, usize, f64)> {
    generate_once_for(host, port, None, prompt, max_tokens, temp)
}

/// One-shot generation routed to a registry model.
pub fn generate_once_for(
    host: &str,
    port: u16,
    model: Option<&str>,
    prompt: &str,
    max_tokens: usize,
    temp: f32,
) -> Result<(String, usize, f64)> {
    let mut s = connect(host, port)?;
    generate_on_for(&mut s, model, prompt, max_tokens, temp)
}

/// One-shot named-session generation over a fresh connection.
pub fn generate_session_once(
    host: &str,
    port: u16,
    session: &str,
    prompt: &str,
    max_tokens: usize,
    temp: f32,
) -> Result<(String, usize, f64)> {
    generate_session_once_for(host, port, None, session, prompt, max_tokens, temp)
}

/// One-shot named-session generation routed to a registry model.
pub fn generate_session_once_for(
    host: &str,
    port: u16,
    model: Option<&str>,
    session: &str,
    prompt: &str,
    max_tokens: usize,
    temp: f32,
) -> Result<(String, usize, f64)> {
    let mut s = connect(host, port)?;
    generate_session_on_for(&mut s, model, session, prompt, max_tokens, temp)
}

/// Fetch the server's STATS snapshot line.
pub fn fetch_stats(host: &str, port: u16) -> Result<String> {
    let mut s = connect(host, port)?;
    s.write_all(b"STATS\n")?;
    let mut reader = BufReader::new(s.try_clone()?);
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let l = line.trim_end_matches(['\r', '\n']);
    match l.strip_prefix("STATS ") {
        Some(rest) => Ok(rest.to_string()),
        None => bail!("unexpected STATS response {l:?}"),
    }
}

/// Fetch the body of `GET /metrics` from the HTTP front end (Prometheus
/// text exposition). Speaks just enough HTTP/1.1 for a close-delimited
/// fixed-length response.
pub fn fetch_metrics(host: &str, port: u16) -> Result<String> {
    let mut s = connect(host, port)?;
    let req =
        format!("GET /metrics HTTP/1.1\r\nHost: {host}\r\nConnection: close\r\n\r\n");
    s.write_all(req.as_bytes())?;
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).context("reading /metrics response")?;
    let text = String::from_utf8_lossy(&raw);
    let Some(split) = text.find("\r\n\r\n") else {
        bail!("malformed /metrics response (no header terminator)");
    };
    let (head, body) = text.split_at(split + 4);
    if !head.starts_with("HTTP/1.1 200") {
        bail!(
            "GET /metrics returned {:?}",
            head.lines().next().unwrap_or("")
        );
    }
    Ok(body.to_string())
}

/// The value of one exact series (name plus rendered label set) in a
/// scrape body, e.g. `metric_value(body, "chon_reactor_open_conns")` or
/// `metric_value(body, "chon_requests_total{model=\"default\"}")`.
pub fn metric_value(body: &str, series: &str) -> Option<f64> {
    for line in body.lines() {
        if let Some(v) = line.strip_prefix(series).and_then(|r| r.strip_prefix(' ')) {
            return v.trim().parse().ok();
        }
    }
    None
}

/// Sum of every sample of family `name` across all label sets (None when
/// the family is absent from the scrape).
pub fn metric_total(body: &str, name: &str) -> Option<f64> {
    let mut total = 0.0f64;
    let mut seen = false;
    for line in body.lines() {
        if line.starts_with('#') {
            continue;
        }
        let Some(rest) = line.strip_prefix(name) else { continue };
        let rest = match rest.strip_prefix('{') {
            Some(r) => match r.find('}') {
                Some(i) => &r[i + 1..],
                None => continue,
            },
            None => rest,
        };
        let Some(v) = rest.strip_prefix(' ') else { continue };
        if let Ok(x) = v.trim().parse::<f64>() {
            total += x;
            seen = true;
        }
    }
    seen.then_some(total)
}

/// Scrape-and-assert (`--metrics-port`): given `/metrics` bodies scraped
/// before and after a load run, verify the key series exist in both and
/// moved — request/token/decode counters and the stage-histogram sample
/// counts must strictly increase, and the reactor health gauges must be
/// present.
pub fn assert_metrics_progress(before: &str, after: &str) -> Result<()> {
    for name in [
        "chon_requests_total",
        "chon_tokens_total",
        "chon_decode_steps_total",
        "chon_stage_latency_us_count",
    ] {
        let b = metric_total(before, name)
            .with_context(|| format!("{name} missing from the first scrape"))?;
        let a = metric_total(after, name)
            .with_context(|| format!("{name} missing from the second scrape"))?;
        if a <= b {
            bail!("{name} did not increase across the load run ({b} -> {a})");
        }
    }
    for name in ["chon_reactor_open_conns", "chon_reactor_tick_lag_us"] {
        if metric_total(after, name).is_none() {
            bail!("{name} missing from the /metrics scrape");
        }
    }
    Ok(())
}

/// Ask the server to drain and stop.
pub fn send_shutdown(host: &str, port: u16) -> Result<()> {
    let mut s = connect(host, port)?;
    s.write_all(b"SHUTDOWN\n")?;
    let mut reader = BufReader::new(s.try_clone()?);
    let mut line = String::new();
    let _ = reader.read_line(&mut line);
    Ok(())
}

/// Aggregate results of a load run.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    /// per-request latency in ms, sorted ascending
    pub latencies_ms: Vec<f64>,
    /// per-model latency in ms, sorted ascending (only populated when
    /// the run sprayed across explicit `--model` names)
    pub by_model: BTreeMap<String, Vec<f64>>,
    pub tokens: usize,
    pub failures: usize,
    pub empty_responses: usize,
    pub wall_s: f64,
    /// idle connections parked for the run (connection-scaling mode)
    pub idle_opened: usize,
    /// how many of them still answered PING after the run
    pub idle_alive: usize,
}

/// p-th percentile of an ascending-sorted latency list. Empty input is
/// NaN — callers that serialize (the loadtest summary) must handle the
/// empty case themselves rather than leak NaN into JSON.
pub fn percentile_of(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let n = sorted.len();
    let idx = ((q * n as f64).ceil() as usize).clamp(1, n) - 1;
    sorted[idx]
}

/// Threshold below which a wall-clock measurement is treated as "no
/// elapsed time" for throughput math (avoids inf/NaN from dividing by a
/// duration that rounded to ~0).
const MIN_WALL_S: f64 = 1e-9;

impl LoadReport {
    pub fn percentile(&self, q: f64) -> f64 {
        percentile_of(&self.latencies_ms, q)
    }

    pub fn requests_ok(&self) -> usize {
        self.latencies_ms.len()
    }

    /// Sort all latency lists ascending, NaN-safely (`f64::total_cmp`
    /// orders NaN after every real number instead of panicking the way
    /// `partial_cmp(..).unwrap()` did).
    pub fn sort_latencies(&mut self) {
        self.latencies_ms.sort_by(f64::total_cmp);
        for lats in self.by_model.values_mut() {
            lats.sort_by(f64::total_cmp);
        }
    }

    /// Requests per second, or None for an empty/zero-duration run.
    pub fn throughput_rps(&self) -> Option<f64> {
        (self.requests_ok() > 0 && self.wall_s > MIN_WALL_S)
            .then(|| self.requests_ok() as f64 / self.wall_s)
    }

    /// Tokens per second, or None for an empty/zero-duration run.
    pub fn throughput_tps(&self) -> Option<f64> {
        (self.requests_ok() > 0 && self.wall_s > MIN_WALL_S)
            .then(|| self.tokens as f64 / self.wall_s)
    }

    /// Fold another report into this one (the loadtest harness merges
    /// per-worker reports). Latencies are re-sorted by the caller via
    /// `sort_latencies` once all merges are done.
    pub fn merge(&mut self, other: &LoadReport) {
        self.latencies_ms.extend_from_slice(&other.latencies_ms);
        for (model, lats) in &other.by_model {
            self.by_model
                .entry(model.clone())
                .or_default()
                .extend_from_slice(lats);
        }
        self.tokens += other.tokens;
        self.failures += other.failures;
        self.empty_responses += other.empty_responses;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.idle_opened += other.idle_opened;
        self.idle_alive += other.idle_alive;
    }
}

/// Fire `opts.requests` GENs from `opts.concurrency` threads. With
/// several `opts.models`, requests are sprayed across them round-robin
/// by global request index, so every model sees an even share even when
/// the thread count does not divide the request count.
pub fn run_load(opts: &ClientOpts) -> Result<LoadReport> {
    if opts.requests == 0 {
        bail!("load mode needs --requests > 0");
    }
    let c = opts.concurrency.clamp(1, opts.requests);
    let mut fleet = if opts.idle_conns > 0 {
        Some(IdleFleet::open(&opts.host, opts.port, opts.idle_conns)?)
    } else {
        None
    };
    let t0 = Instant::now();
    // (tokens, latency_ms, model index or usize::MAX for default)
    let mut results: Vec<Result<Vec<(usize, f64, usize)>>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for ti in 0..c {
            // spread the remainder over the first threads
            let share = opts.requests / c + usize::from(ti < opts.requests % c);
            let base = ti * (opts.requests / c) + ti.min(opts.requests % c);
            let opts = opts.clone();
            handles.push(s.spawn(move || -> Result<Vec<(usize, f64, usize)>> {
                let mut stream = connect(&opts.host, opts.port)?;
                let mut out = Vec::with_capacity(share);
                for ri in 0..share {
                    let mi = if opts.models.is_empty() {
                        usize::MAX
                    } else {
                        (base + ri) % opts.models.len()
                    };
                    let model = opts.models.get(mi).map(|m| m.as_str());
                    // vary prompts a little so batches are not degenerate
                    let prompt = format!("{}{ti} {ri} ", opts.prompt);
                    let (text, n, ms) = generate_on_for(
                        &mut stream,
                        model,
                        &prompt,
                        opts.max_tokens,
                        opts.temp,
                    )?;
                    out.push((if text.is_empty() { 0 } else { n.max(1) }, ms, mi));
                }
                Ok(out)
            }));
        }
        for h in handles {
            results.push(h.join().expect("load thread panicked"));
        }
    });

    let mut report = LoadReport { wall_s: t0.elapsed().as_secs_f64(), ..Default::default() };
    if let Some(fleet) = fleet.as_mut() {
        report.idle_opened = fleet.len();
        report.idle_alive = fleet.check_alive();
    }
    for r in results {
        match r {
            Ok(list) => {
                for (n, ms, mi) in list {
                    if n == 0 {
                        report.empty_responses += 1;
                    } else {
                        report.tokens += n;
                        report.latencies_ms.push(ms);
                        if let Some(model) = opts.models.get(mi) {
                            report
                                .by_model
                                .entry(model.clone())
                                .or_default()
                                .push(ms);
                        }
                    }
                }
            }
            Err(e) => {
                crate::warn!("load thread failed: {e:#}");
                report.failures += 1;
            }
        }
    }
    report.sort_latencies();
    Ok(report)
}

/// Human-readable load summary (+ the server's own view of batching).
pub fn print_report(opts: &ClientOpts, report: &LoadReport) {
    println!(
        "requests {} ok / {} empty / {} failed threads  wall {:.2}s",
        report.requests_ok(),
        report.empty_responses,
        report.failures,
        report.wall_s
    );
    if report.requests_ok() > 0 {
        match (report.throughput_rps(), report.throughput_tps()) {
            (Some(rps), Some(tps)) => {
                println!("throughput {rps:.1} req/s  {tps:.0} tok/s")
            }
            // requests completed but the wall clock rounded to ~0: a
            // rate would be inf, so say so instead of printing one
            _ => println!("throughput n/a (wall clock ~0)"),
        }
        println!(
            "latency ms  p50 {:.1}  p90 {:.1}  p99 {:.1}  max {:.1}",
            report.percentile(0.50),
            report.percentile(0.90),
            report.percentile(0.99),
            report.latencies_ms.last().copied().unwrap_or(f64::NAN)
        );
        for (model, lats) in &report.by_model {
            println!(
                "  model {model:<16} {} ok  p50 {:.1}  p90 {:.1}  p99 {:.1}",
                lats.len(),
                percentile_of(lats, 0.50),
                percentile_of(lats, 0.90),
                percentile_of(lats, 0.99),
            );
        }
    }
    if report.idle_opened > 0 {
        println!(
            "idle connections: {}/{} still alive after the run",
            report.idle_alive, report.idle_opened
        );
    }
    match fetch_stats(&opts.host, opts.port) {
        Ok(stats) => println!("server stats: {stats}"),
        Err(e) => println!("server stats unavailable: {e}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_index_correctly() {
        let r = LoadReport {
            latencies_ms: vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0],
            ..Default::default()
        };
        assert_eq!(r.percentile(0.5), 5.0);
        assert_eq!(r.percentile(0.9), 9.0);
        assert_eq!(r.percentile(0.99), 10.0);
        assert_eq!(r.percentile(1.0), 10.0);
        let empty = LoadReport::default();
        assert!(empty.percentile(0.5).is_nan());
    }

    #[test]
    fn metric_parsing_reads_values_and_totals() {
        let body = "\
# HELP chon_requests_total Requests admitted.\n\
# TYPE chon_requests_total counter\n\
chon_requests_total{model=\"a\"} 3\n\
chon_requests_total{model=\"b\"} 4\n\
chon_reactor_open_conns 7\n\
chon_stage_latency_us_count{model=\"a\",stage=\"prefill\"} 2\n";
        assert_eq!(metric_value(body, "chon_requests_total{model=\"a\"}"), Some(3.0));
        assert_eq!(metric_value(body, "chon_reactor_open_conns"), Some(7.0));
        assert_eq!(metric_value(body, "chon_requests_total"), None);
        assert_eq!(metric_total(body, "chon_requests_total"), Some(7.0));
        assert_eq!(metric_total(body, "chon_stage_latency_us_count"), Some(2.0));
        assert_eq!(metric_total(body, "chon_absent"), None);
        // a family name that prefixes another must not alias into it
        assert_eq!(metric_total(body, "chon_requests"), None);
    }

    #[test]
    fn metrics_progress_requires_strict_increase() {
        let scrape = |req: u64, tok: u64| {
            format!(
                "chon_requests_total{{model=\"a\"}} {req}\n\
                 chon_tokens_total{{model=\"a\"}} {tok}\n\
                 chon_decode_steps_total{{model=\"a\"}} {tok}\n\
                 chon_stage_latency_us_count{{model=\"a\",stage=\"prefill\"}} {req}\n\
                 chon_reactor_open_conns 1\n\
                 chon_reactor_tick_lag_us 5\n"
            )
        };
        assert!(assert_metrics_progress(&scrape(1, 8), &scrape(3, 24)).is_ok());
        // flat counters fail
        assert!(assert_metrics_progress(&scrape(1, 8), &scrape(1, 8)).is_err());
        // a missing family fails
        assert!(assert_metrics_progress("", &scrape(3, 24)).is_err());
    }

    #[test]
    fn nan_latency_sorts_without_panicking() {
        let mut r = LoadReport {
            latencies_ms: vec![3.0, f64::NAN, 1.0, 2.0],
            ..Default::default()
        };
        r.by_model.insert("m".into(), vec![f64::NAN, 5.0]);
        r.sort_latencies(); // partial_cmp(..).unwrap() would panic here
        assert_eq!(&r.latencies_ms[..3], &[1.0, 2.0, 3.0]);
        assert!(r.latencies_ms[3].is_nan()); // total_cmp puts NaN last
        assert_eq!(r.by_model["m"][0], 5.0);
        // percentiles below the NaN tail stay finite
        assert_eq!(r.percentile(0.5), 2.0);
    }

    #[test]
    fn throughput_is_none_on_empty_or_instant_runs() {
        let empty = LoadReport { wall_s: 1.0, ..Default::default() };
        assert_eq!(empty.throughput_rps(), None);
        assert_eq!(empty.throughput_tps(), None);
        let instant = LoadReport {
            latencies_ms: vec![1.0],
            tokens: 4,
            wall_s: 0.0,
            ..Default::default()
        };
        assert_eq!(instant.throughput_rps(), None);
        let ok = LoadReport {
            latencies_ms: vec![1.0, 2.0],
            tokens: 10,
            wall_s: 2.0,
            ..Default::default()
        };
        assert_eq!(ok.throughput_rps(), Some(1.0));
        assert_eq!(ok.throughput_tps(), Some(5.0));
    }

    #[test]
    fn merge_accumulates_and_takes_max_wall() {
        let mut a = LoadReport {
            latencies_ms: vec![2.0],
            tokens: 3,
            failures: 1,
            wall_s: 1.0,
            ..Default::default()
        };
        let mut b = LoadReport {
            latencies_ms: vec![1.0],
            tokens: 2,
            empty_responses: 1,
            wall_s: 2.5,
            ..Default::default()
        };
        b.by_model.insert("m".into(), vec![1.0]);
        a.merge(&b);
        a.sort_latencies();
        assert_eq!(a.latencies_ms, vec![1.0, 2.0]);
        assert_eq!(a.tokens, 5);
        assert_eq!(a.failures, 1);
        assert_eq!(a.empty_responses, 1);
        assert_eq!(a.wall_s, 2.5);
        assert_eq!(a.by_model["m"], vec![1.0]);
    }

    /// The per-thread (base + ri) % models indexing partitions the global
    /// request range, so every model gets an even share (±1) regardless
    /// of how requests divide over threads.
    #[test]
    fn model_spray_is_even() {
        for (requests, c, m) in [(32usize, 4usize, 2usize), (10, 3, 3), (7, 4, 2), (9, 8, 4)] {
            let mut counts = vec![0usize; m];
            for ti in 0..c {
                let share = requests / c + usize::from(ti < requests % c);
                let base = ti * (requests / c) + ti.min(requests % c);
                for ri in 0..share {
                    counts[(base + ri) % m] += 1;
                }
            }
            assert_eq!(counts.iter().sum::<usize>(), requests);
            let max = counts.iter().max().unwrap();
            let min = counts.iter().min().unwrap();
            assert!(max - min <= 1, "{requests}/{c}/{m}: {counts:?}");
        }
    }
}
