//! Prometheus text-exposition writer (format version 0.0.4).
//!
//! Renders metric families into the plain-text scrape format:
//! `# HELP` / `# TYPE` header lines once per family, then one sample
//! line per label set. Histograms expand into cumulative `_bucket`
//! series (`le` upper bounds, inclusive, ending in `+Inf`) plus `_sum`
//! and `_count`, exactly as the histogram data model requires. Label
//! values are escaped per the spec (`\` → `\\`, `"` → `\"`, newline →
//! `\n`).
//!
//! Serve the result with content type `text/plain; version=0.0.4`
//! ([`CONTENT_TYPE`]).

use crate::obs::metrics::{bucket_bound, HistSnapshot, N_FINITE};

/// The scrape response content type.
pub const CONTENT_TYPE: &str = "text/plain; version=0.0.4";

/// Escape one label *value* for the text format.
pub fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            _ => out.push(c),
        }
    }
    out
}

fn render_labels(out: &mut String, labels: &[(&str, &str)]) {
    if labels.is_empty() {
        return;
    }
    out.push('{');
    for (i, (k, v)) in labels.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(k);
        out.push_str("=\"");
        out.push_str(&escape_label(v));
        out.push('"');
    }
    out.push('}');
}

/// Incremental builder for one scrape body.
#[derive(Default)]
pub struct Expo {
    out: String,
}

impl Expo {
    pub fn new() -> Expo {
        Expo { out: String::new() }
    }

    /// Start a family: HELP + TYPE lines. Call once per metric name,
    /// before any of its samples. `kind` is `counter`, `gauge` or
    /// `histogram`.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) {
        self.out.push_str("# HELP ");
        self.out.push_str(name);
        self.out.push(' ');
        // HELP text shares the label-value escape set minus the quote
        self.out.push_str(&help.replace('\\', "\\\\").replace('\n', "\\n"));
        self.out.push('\n');
        self.out.push_str("# TYPE ");
        self.out.push_str(name);
        self.out.push(' ');
        self.out.push_str(kind);
        self.out.push('\n');
    }

    /// One integer-valued sample line.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: u64) {
        self.out.push_str(name);
        render_labels(&mut self.out, labels);
        self.out.push(' ');
        self.out.push_str(&value.to_string());
        self.out.push('\n');
    }

    /// One float-valued sample line.
    pub fn sample_f64(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        value: f64,
    ) {
        self.out.push_str(name);
        render_labels(&mut self.out, labels);
        self.out.push(' ');
        self.out.push_str(&format!("{value}"));
        self.out.push('\n');
    }

    /// A full histogram under one label set: cumulative `_bucket` lines
    /// (each `le` counts observations `<=` that bound), `_sum`, `_count`.
    pub fn histogram(
        &mut self,
        name: &str,
        labels: &[(&str, &str)],
        snap: &HistSnapshot,
    ) {
        let bucket_name = format!("{name}_bucket");
        let mut cum = 0u64;
        for (i, &b) in snap.buckets.iter().enumerate() {
            cum += b;
            let le = if i < N_FINITE {
                bucket_bound(i).to_string()
            } else {
                "+Inf".to_string()
            };
            let mut with_le: Vec<(&str, &str)> = labels.to_vec();
            with_le.push(("le", le.as_str()));
            self.sample(&bucket_name, &with_le, cum);
        }
        self.sample(&format!("{name}_sum"), labels, snap.sum);
        self.sample(&format!("{name}_count"), labels, cum);
    }

    pub fn finish(self) -> String {
        self.out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::metrics::Histogram;

    #[test]
    fn label_escaping() {
        assert_eq!(escape_label("plain"), "plain");
        assert_eq!(escape_label("a\"b"), "a\\\"b");
        assert_eq!(escape_label("a\\b"), "a\\\\b");
        assert_eq!(escape_label("a\nb"), "a\\nb");
    }

    #[test]
    fn counter_and_gauge_lines() {
        let mut e = Expo::new();
        e.family("chon_requests_total", "counter", "Requests admitted.");
        e.sample("chon_requests_total", &[("model", "alpha")], 42);
        e.family("chon_open_conns", "gauge", "Open connections.");
        e.sample("chon_open_conns", &[], 3);
        let text = e.finish();
        assert!(text.contains("# HELP chon_requests_total Requests admitted.\n"));
        assert!(text.contains("# TYPE chon_requests_total counter\n"));
        assert!(text.contains("chon_requests_total{model=\"alpha\"} 42\n"));
        assert!(text.contains("# TYPE chon_open_conns gauge\n"));
        assert!(text.contains("chon_open_conns 3\n"));
        // every non-comment line is `name[{labels}] value`
        for line in text.lines().filter(|l| !l.starts_with('#')) {
            let (_, value) = line.rsplit_once(' ').expect("sample line");
            value.parse::<f64>().expect("numeric value");
        }
    }

    #[test]
    fn histogram_is_cumulative_and_consistent() {
        let h = Histogram::new();
        for v in [1u64, 3, 3, 100, 1 << 30] {
            h.record(v);
        }
        let mut e = Expo::new();
        e.family("chon_lat_us", "histogram", "demo");
        e.histogram("chon_lat_us", &[("stage", "decode")], &h.snapshot());
        let text = e.finish();
        // cumulative buckets never decrease and end at the total count
        let mut last = 0u64;
        let mut bucket_lines = 0;
        for line in text.lines() {
            if let Some(rest) = line.strip_prefix("chon_lat_us_bucket{") {
                let v: u64 =
                    rest.rsplit_once(' ').unwrap().1.parse().unwrap();
                assert!(v >= last, "non-monotone cumulative bucket: {line}");
                last = v;
                bucket_lines += 1;
            }
        }
        assert_eq!(bucket_lines, crate::obs::metrics::N_BUCKETS);
        assert!(text.contains("le=\"+Inf\"} 5\n"));
        assert!(text.contains("chon_lat_us_count{stage=\"decode\"} 5\n"));
        let sum = 1 + 3 + 3 + 100 + (1u64 << 30);
        assert!(text.contains(&format!("chon_lat_us_sum{{stage=\"decode\"}} {sum}\n")));
    }

    #[test]
    fn escaped_labels_round_trip_in_lines() {
        let mut e = Expo::new();
        e.family("m", "gauge", "help with \\ and\nnewline");
        e.sample("m", &[("path", "a\"b\\c\nd")], 1);
        let text = e.finish();
        assert!(text.contains("# HELP m help with \\\\ and\\nnewline\n"));
        assert!(text.contains("m{path=\"a\\\"b\\\\c\\nd\"} 1\n"));
    }
}
