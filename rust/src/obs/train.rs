//! Train-path telemetry: lock-free recording for the training hot loop,
//! Prometheus + JSON rendering, and the tiny scrape listener behind
//! `chon train --metrics-port P`.
//!
//! The trainer and the shard engine write into [`PhaseSpans`] /
//! [`TrainObs`] with relaxed atomics only — a concurrent scrape never
//! blocks a step. The listener is one blocking thread reusing the serve
//! HTTP parser, answering `GET /metrics` (Prometheus 0.0.4) and
//! `GET /progress` (a compact JSON snapshot for humans and harnesses).

use std::io::Read;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::obs::expo::{self, Expo};
use crate::obs::metrics::{Counter, Gauge, GaugeF64, Histogram};
use crate::serve::http::{parse_request, write_response, Parsed};
use crate::util::json::Json;

/// Step phases, in within-step execution order. Forward and backward
/// are fused in the engine (`model::loss_and_grads` computes both in
/// one call), so they span as one `fwd_bwd` phase rather than the two
/// the paper's timeline splits them into.
pub const PHASES: &[&str] =
    &["data_wait", "fwd_bwd", "allreduce", "adam", "diag_probe"];
pub const PH_DATA_WAIT: usize = 0;
pub const PH_FWD_BWD: usize = 1;
pub const PH_ALLREDUCE: usize = 2;
pub const PH_ADAM: usize = 3;
pub const PH_DIAG: usize = 4;

/// Per-phase span sink shared between the trainer, the shard engine
/// (which times fwd_bwd/allreduce/adam inside `ShardExec::run`) and the
/// scrape thread: a log₂ histogram for distributions plus the last
/// value for the per-step trace event and `/progress`.
pub struct PhaseSpans {
    hist: Vec<Histogram>,
    last_us: Vec<AtomicU64>,
}

impl Default for PhaseSpans {
    fn default() -> PhaseSpans {
        PhaseSpans::new()
    }
}

impl PhaseSpans {
    pub fn new() -> PhaseSpans {
        PhaseSpans {
            hist: (0..PHASES.len()).map(|_| Histogram::new()).collect(),
            last_us: (0..PHASES.len()).map(|_| AtomicU64::new(0)).collect(),
        }
    }

    /// Record one span for phase `idx` (µs). Lock-free.
    pub fn record(&self, idx: usize, us: u64) {
        self.hist[idx].record(us);
        self.last_us[idx].store(us, Ordering::Relaxed);
    }

    pub fn record_elapsed(&self, idx: usize, d: Duration) {
        self.record(idx, d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Last recorded span for phase `idx` (µs).
    pub fn last(&self, idx: usize) -> u64 {
        self.last_us[idx].load(Ordering::Relaxed)
    }

    pub fn snapshot(&self, idx: usize) -> crate::obs::metrics::HistSnapshot {
        self.hist[idx].snapshot()
    }
}

/// Hot-channel gauges for one diag component (attn_o, mlp_up, …).
#[derive(Default)]
pub struct HotCompObs {
    /// channels currently classified persistent by the lifecycle tracker
    pub persistent: Gauge,
    /// channels in the latest top-k but not (yet) persistent
    pub transient: Gauge,
    pub births: Counter,
    pub deaths: Counter,
    /// Jaccard overlap of the last two probes' top-k sets
    pub persistence: GaugeF64,
}

/// The train-side metric registry. All writes are relaxed atomics; the
/// component list is behind a mutex but only touched at diag cadence
/// (every `--diag-every` steps), never per step.
pub struct TrainObs {
    pub step: Gauge,
    pub total_steps: Gauge,
    pub loss: GaugeF64,
    pub grad_norm: GaugeF64,
    pub lr: GaugeF64,
    pub tokens_total: Counter,
    pub tokens_per_sec: GaugeF64,
    pub resumes_total: Counter,
    pub spans: Arc<PhaseSpans>,
    comps: Mutex<Vec<(String, Arc<HotCompObs>)>>,
    build: Mutex<Option<(String, String)>>,
}

impl TrainObs {
    pub fn new(spans: Arc<PhaseSpans>) -> Arc<TrainObs> {
        Arc::new(TrainObs {
            step: Gauge::new(),
            total_steps: Gauge::new(),
            loss: GaugeF64::new(),
            grad_norm: GaugeF64::new(),
            lr: GaugeF64::new(),
            tokens_total: Counter::new(),
            tokens_per_sec: GaugeF64::new(),
            resumes_total: Counter::new(),
            spans,
            comps: Mutex::new(Vec::new()),
            build: Mutex::new(None),
        })
    }

    /// Stamp the deployment identity exported as `chon_build_info`.
    pub fn set_build_info(&self, backend: &str, recipe: &str) {
        *self.build.lock().unwrap() =
            Some((backend.to_string(), recipe.to_string()));
    }

    /// Get-or-create the gauges for a diag component.
    pub fn comp(&self, name: &str) -> Arc<HotCompObs> {
        let mut comps = self.comps.lock().unwrap();
        if let Some((_, c)) = comps.iter().find(|(n, _)| n == name) {
            return c.clone();
        }
        let c = Arc::new(HotCompObs::default());
        comps.push((name.to_string(), c.clone()));
        c
    }

    /// Per-step update from the trainer.
    pub fn record_step(
        &self,
        step: usize,
        loss: f32,
        grad_norm: f32,
        lr: f32,
        tokens: u64,
        tokens_per_sec: f64,
    ) {
        self.step.set(step as u64);
        self.loss.set(loss as f64);
        self.grad_norm.set(grad_norm as f64);
        self.lr.set(lr as f64);
        self.tokens_total.add(tokens);
        self.tokens_per_sec.set(tokens_per_sec);
    }

    /// Prometheus 0.0.4 exposition.
    pub fn render(&self) -> String {
        let mut w = Expo::new();
        if let Some((backend, recipe)) = self.build.lock().unwrap().clone() {
            w.family(
                "chon_build_info",
                "gauge",
                "build/deployment identity (always 1)",
            );
            w.sample(
                "chon_build_info",
                &[
                    ("version", env!("CARGO_PKG_VERSION")),
                    ("backend", &backend),
                    ("recipe", &recipe),
                ],
                1,
            );
        }
        w.family("chon_train_step", "gauge", "last completed training step");
        w.sample("chon_train_step", &[], self.step.get());
        w.family("chon_train_total_steps", "gauge", "target step count");
        w.sample("chon_train_total_steps", &[], self.total_steps.get());
        w.family("chon_train_loss", "gauge", "training loss at last step");
        w.sample_f64("chon_train_loss", &[], self.loss.get());
        w.family("chon_train_grad_norm", "gauge", "gradient norm at last step");
        w.sample_f64("chon_train_grad_norm", &[], self.grad_norm.get());
        w.family("chon_train_lr", "gauge", "learning rate at last step");
        w.sample_f64("chon_train_lr", &[], self.lr.get());
        w.family("chon_train_tokens_total", "counter", "tokens consumed");
        w.sample("chon_train_tokens_total", &[], self.tokens_total.get());
        w.family(
            "chon_train_tokens_per_sec",
            "gauge",
            "throughput at last step",
        );
        w.sample_f64("chon_train_tokens_per_sec", &[], self.tokens_per_sec.get());
        w.family(
            "chon_train_resumes_total",
            "counter",
            "checkpoint resumes in this process",
        );
        w.sample("chon_train_resumes_total", &[], self.resumes_total.get());
        w.family(
            "chon_train_phase_us",
            "histogram",
            "per-step phase latency (µs), log2 buckets",
        );
        for (i, phase) in PHASES.iter().enumerate() {
            w.histogram(
                "chon_train_phase_us",
                &[("phase", phase)],
                &self.spans.snapshot(i),
            );
        }
        let comps = self.comps.lock().unwrap();
        if !comps.is_empty() {
            w.family(
                "chon_train_hot_channels",
                "gauge",
                "hot channels by lifecycle class",
            );
            for (name, c) in comps.iter() {
                w.sample(
                    "chon_train_hot_channels",
                    &[("comp", name), ("class", "persistent")],
                    c.persistent.get(),
                );
                w.sample(
                    "chon_train_hot_channels",
                    &[("comp", name), ("class", "transient")],
                    c.transient.get(),
                );
            }
            w.family(
                "chon_train_hot_births_total",
                "counter",
                "channels promoted to persistent",
            );
            for (name, c) in comps.iter() {
                w.sample(
                    "chon_train_hot_births_total",
                    &[("comp", name)],
                    c.births.get(),
                );
            }
            w.family(
                "chon_train_hot_deaths_total",
                "counter",
                "persistent channels gone cold",
            );
            for (name, c) in comps.iter() {
                w.sample(
                    "chon_train_hot_deaths_total",
                    &[("comp", name)],
                    c.deaths.get(),
                );
            }
            w.family(
                "chon_train_hot_persistence",
                "gauge",
                "Jaccard overlap of consecutive top-k probes",
            );
            for (name, c) in comps.iter() {
                w.sample_f64(
                    "chon_train_hot_persistence",
                    &[("comp", name)],
                    c.persistence.get(),
                );
            }
        }
        w.finish()
    }

    /// Compact JSON snapshot for `GET /progress`.
    pub fn progress_json(&self) -> Json {
        let phases = PHASES
            .iter()
            .enumerate()
            .map(|(i, p)| {
                (p.to_string(), Json::Num(self.spans.last(i) as f64))
            })
            .collect();
        let hot = self
            .comps
            .lock()
            .unwrap()
            .iter()
            .map(|(name, c)| {
                (
                    name.clone(),
                    Json::Obj(vec![
                        (
                            "persistent".to_string(),
                            Json::Num(c.persistent.get() as f64),
                        ),
                        (
                            "transient".to_string(),
                            Json::Num(c.transient.get() as f64),
                        ),
                        (
                            "persistence".to_string(),
                            Json::Num(c.persistence.get()),
                        ),
                    ]),
                )
            })
            .collect();
        Json::Obj(vec![
            ("step".to_string(), Json::Num(self.step.get() as f64)),
            (
                "total_steps".to_string(),
                Json::Num(self.total_steps.get() as f64),
            ),
            ("loss".to_string(), Json::Num(self.loss.get())),
            ("grad_norm".to_string(), Json::Num(self.grad_norm.get())),
            ("lr".to_string(), Json::Num(self.lr.get())),
            (
                "tokens_total".to_string(),
                Json::Num(self.tokens_total.get() as f64),
            ),
            (
                "tokens_per_sec".to_string(),
                Json::Num(self.tokens_per_sec.get()),
            ),
            ("phases_us".to_string(), Json::Obj(phases)),
            ("hot".to_string(), Json::Obj(hot)),
            (
                "resumes".to_string(),
                Json::Num(self.resumes_total.get() as f64),
            ),
        ])
    }
}

/// The scrape listener: one thread, blocking sockets, keep-alive. Not
/// the serve reactor on purpose — two endpoints at human scrape rates
/// do not need epoll, and the train process must stay simple.
pub struct MetricsServer {
    port: u16,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl MetricsServer {
    /// Bind `host:port` (port 0 picks an ephemeral port — see
    /// [`port`](MetricsServer::port)) and serve until dropped.
    pub fn serve(
        host: &str,
        port: u16,
        obs: Arc<TrainObs>,
    ) -> Result<MetricsServer> {
        let listener = TcpListener::bind((host, port))
            .with_context(|| format!("bind metrics listener {host}:{port}"))?;
        let port = listener.local_addr()?.port();
        // non-blocking accept + 50 ms poll so stop() never hangs on a
        // listener with no final connection to wake it
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let handle = std::thread::Builder::new()
            .name("chon-train-metrics".into())
            .spawn(move || {
                while !stop2.load(Ordering::Relaxed) {
                    match listener.accept() {
                        Ok((stream, _)) => {
                            let _ = stream.set_nonblocking(false);
                            let _ = handle_conn(stream, &obs);
                        }
                        Err(e)
                            if e.kind() == std::io::ErrorKind::WouldBlock =>
                        {
                            std::thread::sleep(Duration::from_millis(50));
                        }
                        Err(_) => {
                            std::thread::sleep(Duration::from_millis(50));
                        }
                    }
                }
            })?;
        Ok(MetricsServer { port, stop, handle: Some(handle) })
    }

    /// The bound port (resolves an ephemeral `--metrics-port 0`).
    pub fn port(&self) -> u16 {
        self.port
    }

    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

impl Drop for MetricsServer {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Serve one keep-alive connection: GET/HEAD `/metrics` and
/// `/progress`, 404 otherwise.
fn handle_conn(mut stream: TcpStream, obs: &TrainObs) -> std::io::Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut buf: Vec<u8> = Vec::new();
    let mut chunk = [0u8; 4096];
    loop {
        let req = loop {
            match parse_request(&buf) {
                Ok(Parsed::Complete(req, consumed)) => {
                    buf.drain(..consumed);
                    break req;
                }
                Ok(Parsed::Partial) => {
                    let n = stream.read(&mut chunk)?;
                    if n == 0 {
                        return Ok(()); // clean EOF between requests
                    }
                    buf.extend_from_slice(&chunk[..n]);
                }
                Err(e) => {
                    write_response(
                        &mut stream,
                        e.status,
                        "text/plain",
                        e.message.as_bytes(),
                        false,
                    )?;
                    return Ok(());
                }
            }
        };
        let head_only = req.method == "HEAD";
        let path = req.target.split('?').next().unwrap_or("");
        match path {
            "/metrics" => write_response(
                &mut stream,
                200,
                expo::CONTENT_TYPE,
                obs.render().as_bytes(),
                head_only,
            )?,
            "/progress" => write_response(
                &mut stream,
                200,
                "application/json",
                obs.progress_json().render().as_bytes(),
                head_only,
            )?,
            _ => write_response(
                &mut stream,
                404,
                "text/plain",
                b"not found\n",
                head_only,
            )?,
        }
        if req.wants_close() || req.http10 {
            return Ok(());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write as _;

    #[test]
    fn phase_spans_record_and_last() {
        let sp = PhaseSpans::new();
        sp.record(PH_FWD_BWD, 1000);
        sp.record(PH_FWD_BWD, 2000);
        assert_eq!(sp.last(PH_FWD_BWD), 2000);
        assert_eq!(sp.snapshot(PH_FWD_BWD).count(), 2);
        assert_eq!(sp.last(PH_ADAM), 0);
    }

    #[test]
    fn render_has_core_families_and_build_info() {
        let obs = TrainObs::new(Arc::new(PhaseSpans::new()));
        obs.record_step(7, 3.5, 1.0, 3e-4, 4096, 1234.5);
        let body = obs.render();
        assert!(!body.contains("chon_build_info"), "unset build info hidden");
        obs.set_build_info("native", "chon");
        let body = obs.render();
        assert!(body.contains("chon_train_step 7"), "{body}");
        assert!(body.contains("chon_train_tokens_total 4096"));
        assert!(body.contains(
            "chon_build_info{version=\"0.1.0\",backend=\"native\",recipe=\"chon\"} 1"
        ), "{body}");
        assert!(body.contains("chon_train_phase_us_bucket"));
        // hot families appear only once a component reported
        assert!(!body.contains("chon_train_hot_channels"));
        obs.comp("attn_o").persistent.set(3);
        let body = obs.render();
        assert!(body.contains(
            "chon_train_hot_channels{comp=\"attn_o\",class=\"persistent\"} 3"
        ));
    }

    #[test]
    fn progress_json_parses_and_carries_step() {
        let obs = TrainObs::new(Arc::new(PhaseSpans::new()));
        obs.record_step(3, 2.5, 0.5, 1e-3, 512, 100.0);
        let j = Json::parse(&obs.progress_json().render()).unwrap();
        assert_eq!(j.get("step").and_then(|v| v.as_f64()), Some(3.0));
        assert!(j.get("phases_us").and_then(|p| p.get("fwd_bwd")).is_some());
    }

    #[test]
    fn metrics_server_serves_and_stops() {
        let obs = TrainObs::new(Arc::new(PhaseSpans::new()));
        obs.record_step(5, 3.0, 1.0, 1e-3, 256, 50.0);
        obs.set_build_info("native", "chon");
        let mut srv = MetricsServer::serve("127.0.0.1", 0, obs).unwrap();
        let port = srv.port();
        let fetch = |path: &str| -> String {
            let mut s = TcpStream::connect(("127.0.0.1", port)).unwrap();
            write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n")
                .unwrap();
            let mut body = String::new();
            s.read_to_string(&mut body).unwrap();
            body
        };
        let m = fetch("/metrics");
        assert!(m.starts_with("HTTP/1.1 200"), "{m}");
        assert!(m.contains("chon_train_step 5"));
        assert!(m.contains("chon_build_info"));
        let p = fetch("/progress");
        assert!(p.contains("application/json"), "{p}");
        let body = p.split("\r\n\r\n").nth(1).unwrap();
        assert!(Json::parse(body).is_ok(), "{body}");
        let nf = fetch("/nope");
        assert!(nf.starts_with("HTTP/1.1 404"), "{nf}");
        srv.stop();
    }
}
