//! Server-side observability: the zero-dependency metrics subsystem
//! behind `GET /metrics`.
//!
//! Structure (the `prometheus`-crate substitute, matching the
//! `logger.rs`-instead-of-`log` convention — no crates beyond std):
//!
//! * [`metrics`] — lock-free counters, gauges, and log₂-bucket latency
//!   histograms with snapshot/merge/quantile.
//! * [`expo`] — the Prometheus text-exposition writer (format 0.0.4).
//! * [`outliers`] — per-op HCP hot-channel taps for `--obs-outliers`.
//! * [`Registry`] — one server's metric tree: reactor-level spans and
//!   health gauges plus per-model stage histograms, rendered into one
//!   scrape body by [`Registry::render`].
//! * [`trace`] — the crash-durable JSONL run trace training writes.
//! * [`train`] — train-path gauges/histograms + the `--metrics-port`
//!   scrape listener (`/metrics`, `/progress`).
//! * [`tail`] — `chon tail`: follow/summarize/Chrome-trace-export a
//!   run trace.
//!
//! Stage spans cover the whole request path —
//! accept → parse → queue-wait → prefill → decode-per-token →
//! write-flush — so server-side p50/p99/p999 exist per stage and per
//! model without client cooperation. The serve front end owns an
//! `Arc<Registry>` (threaded through `RegistryOpts`, so in-process test
//! servers stay isolated); [`global`] provides the process-wide instance
//! the `chon serve` binary uses.

pub mod expo;
pub mod metrics;
pub mod outliers;
pub mod tail;
pub mod trace;
pub mod train;

use std::sync::{Arc, Mutex, OnceLock};

use metrics::{Counter, Gauge, Histogram};
use outliers::OutlierObs;

/// Request-path stage histograms of one served model. Recorded by the
/// batcher (queue-wait, prefill, per-token decode) and the reactor
/// (write-flush); all values in µs.
#[derive(Default)]
pub struct ModelObs {
    /// submit → admission into a prefill group
    pub queue_wait: Histogram,
    /// one batched prefill pass over an admitted group
    pub prefill: Histogram,
    /// one batched decode step (= one token per active session)
    pub decode_token: Histogram,
    /// one reactor flush of this model's generation bytes to the socket
    pub write_flush: Histogram,
    /// HCP outlier taps, installed at engine load under `--obs-outliers`
    pub outliers: OnceLock<Arc<OutlierObs>>,
    /// resident weight bytes of the currently installed engine; set (with
    /// [`ModelObs::weight_mode`]) every time an engine is installed, so a
    /// hot reload that flips compute modes re-labels the gauge
    pub weight_bytes: Gauge,
    /// compute-mode label for `weight_bytes` ("packed" or "f32"); doubles
    /// as the presence marker that turns the family on in `render`
    pub weight_mode: Mutex<Option<&'static str>>,
}

impl ModelObs {
    /// Record the resident weight footprint of a freshly installed
    /// engine. `mode` is the engine's compute mode label.
    pub fn set_weight_bytes(&self, bytes: u64, mode: &'static str) {
        self.weight_bytes.set(bytes);
        *self.weight_mode.lock().unwrap() = Some(mode);
    }
}

/// Reactor/connection-level spans and health gauges (model-independent).
#[derive(Default)]
pub struct ServerObs {
    /// accepting + registering one connection
    pub accept: Histogram,
    /// parsing bytes into one complete request
    pub parse: Histogram,
    /// how late the 1 Hz housekeeping tick fired (µs, last tick)
    pub tick_lag_us: Gauge,
    /// token events drained from the generation mailbox per wake (last)
    pub mailbox_depth: Gauge,
    /// currently open connections
    pub open_conns: Gauge,
    /// largest per-connection out-buffer observed (bytes, high-water)
    pub outbuf_highwater: Gauge,
    /// connections refused at accept because `--max-conns` was reached
    /// (each gets a best-effort `ERR busy` / 503 before the close, so
    /// load-shedding is distinguishable from a crash on both sides)
    pub conns_rejected: Counter,
}

/// One server's metric tree.
#[derive(Default)]
pub struct Registry {
    pub server: ServerObs,
    models: Mutex<Vec<(String, Arc<ModelObs>)>>,
    /// deployment identity (backend, recipe/compute-mode) exported as
    /// `chon_build_info`; unset until the binary stamps it
    build: Mutex<Option<(String, String)>>,
}

/// How many weight-score channels are exposed per op (cardinality cap;
/// hit counters render only channels that actually fired).
const WSCORE_TOP: usize = 8;

// Registry rides inside `RegistryOpts` (which derives Debug); its metric
// tree is not useful debug output, so summarize.
impl std::fmt::Debug for Registry {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let n = self.models.lock().map(|m| m.len()).unwrap_or(0);
        write!(f, "obs::Registry({n} models)")
    }
}

impl Registry {
    pub fn new() -> Arc<Registry> {
        Arc::new(Registry::default())
    }

    /// Get-or-create the stage histograms of `model`.
    pub fn model(&self, model: &str) -> Arc<ModelObs> {
        let mut models = self.models.lock().unwrap();
        if let Some((_, m)) = models.iter().find(|(n, _)| n == model) {
            return m.clone();
        }
        let m = Arc::new(ModelObs::default());
        models.push((model.to_string(), m.clone()));
        m
    }

    /// Render every family owned by this registry into Prometheus text.
    /// (The serve front end appends its `ServeStats`-derived counter
    /// families to this body — see `ModelRegistry::metrics_text`.)
    /// Stamp the deployment identity exported as `chon_build_info`
    /// (same family the train registry exports, so scrapes can tell
    /// deployments apart). `recipe` is the serve compute mode.
    pub fn set_build_info(&self, backend: &str, recipe: &str) {
        *self.build.lock().unwrap() =
            Some((backend.to_string(), recipe.to_string()));
    }

    pub fn render(&self) -> String {
        let mut e = expo::Expo::new();
        if let Some((backend, recipe)) = self.build.lock().unwrap().clone() {
            e.family(
                "chon_build_info",
                "gauge",
                "Build/deployment identity (always 1).",
            );
            e.sample(
                "chon_build_info",
                &[
                    ("version", env!("CARGO_PKG_VERSION")),
                    ("backend", &backend),
                    ("recipe", &recipe),
                ],
                1,
            );
        }
        let s = &self.server;
        e.family(
            "chon_conn_stage_us",
            "histogram",
            "Connection-stage latency in microseconds.",
        );
        e.histogram("chon_conn_stage_us", &[("stage", "accept")], &s.accept.snapshot());
        e.histogram("chon_conn_stage_us", &[("stage", "parse")], &s.parse.snapshot());
        e.family(
            "chon_reactor_tick_lag_us",
            "gauge",
            "Lateness of the last 1 Hz reactor tick in microseconds.",
        );
        e.sample("chon_reactor_tick_lag_us", &[], s.tick_lag_us.get());
        e.family(
            "chon_reactor_mailbox_depth",
            "gauge",
            "Token events drained from the generation mailbox on the last wake.",
        );
        e.sample("chon_reactor_mailbox_depth", &[], s.mailbox_depth.get());
        e.family(
            "chon_reactor_open_conns",
            "gauge",
            "Currently open client connections.",
        );
        e.sample("chon_reactor_open_conns", &[], s.open_conns.get());
        e.family(
            "chon_reactor_outbuf_highwater_bytes",
            "gauge",
            "Largest per-connection out-buffer seen since start.",
        );
        e.sample(
            "chon_reactor_outbuf_highwater_bytes",
            &[],
            s.outbuf_highwater.get(),
        );
        e.family(
            "chon_conns_rejected_total",
            "counter",
            "Connections refused at accept because the --max-conns cap was reached.",
        );
        e.sample("chon_conns_rejected_total", &[], s.conns_rejected.get());

        let mut models: Vec<(String, Arc<ModelObs>)> =
            self.models.lock().unwrap().clone();
        models.sort_by(|a, b| a.0.cmp(&b.0));
        e.family(
            "chon_stage_latency_us",
            "histogram",
            "Request-path stage latency per model in microseconds.",
        );
        for (name, m) in &models {
            for (stage, h) in [
                ("queue_wait", &m.queue_wait),
                ("prefill", &m.prefill),
                ("decode_token", &m.decode_token),
                ("write_flush", &m.write_flush),
            ] {
                e.histogram(
                    "chon_stage_latency_us",
                    &[("model", name), ("stage", stage)],
                    &h.snapshot(),
                );
            }
        }

        if models
            .iter()
            .any(|(_, m)| m.weight_mode.lock().unwrap().is_some())
        {
            e.family(
                "chon_model_weight_bytes",
                "gauge",
                "Resident weight bytes of the installed engine, by compute mode.",
            );
            for (name, m) in &models {
                let Some(mode) = *m.weight_mode.lock().unwrap() else {
                    continue;
                };
                e.sample(
                    "chon_model_weight_bytes",
                    &[("model", name), ("mode", mode)],
                    m.weight_bytes.get(),
                );
            }
        }

        if models.iter().any(|(_, m)| m.outliers.get().is_some()) {
            self.render_outliers(&mut e, &models);
        }
        e.finish()
    }

    fn render_outliers(
        &self,
        e: &mut expo::Expo,
        models: &[(String, Arc<ModelObs>)],
    ) {
        e.family(
            "chon_hcp_rows_total",
            "counter",
            "Activation rows observed through each HCP-compensated op.",
        );
        for (name, m) in models {
            let Some(obs) = m.outliers.get() else { continue };
            for t in &obs.taps {
                e.sample(
                    "chon_hcp_rows_total",
                    &[("model", name), ("op", t.op)],
                    t.rows.get(),
                );
            }
        }
        e.family(
            "chon_hcp_residual_energy_total",
            "counter",
            "Total activation quantization-residual energy (Frobenius, squared).",
        );
        for (name, m) in models {
            let Some(obs) = m.outliers.get() else { continue };
            for t in &obs.taps {
                e.sample_f64(
                    "chon_hcp_residual_energy_total",
                    &[("model", name), ("op", t.op)],
                    t.resid_energy.get(),
                );
            }
        }
        e.family(
            "chon_hcp_hot_energy_total",
            "counter",
            "Residual energy carried by the per-row HCP hot channels.",
        );
        for (name, m) in models {
            let Some(obs) = m.outliers.get() else { continue };
            for t in &obs.taps {
                e.sample_f64(
                    "chon_hcp_hot_energy_total",
                    &[("model", name), ("op", t.op)],
                    t.hot_energy.get(),
                );
            }
        }
        e.family(
            "chon_hcp_hot_channel_hits_total",
            "counter",
            "Rows on which a channel made the per-row HCP top-k (channels with hits only).",
        );
        for (name, m) in models {
            let Some(obs) = m.outliers.get() else { continue };
            for t in &obs.taps {
                for (j, c) in t.hits.iter().enumerate() {
                    let hits = c.get();
                    if hits == 0 {
                        continue;
                    }
                    let ch = j.to_string();
                    e.sample(
                        "chon_hcp_hot_channel_hits_total",
                        &[("model", name), ("op", t.op), ("channel", &ch)],
                        hits,
                    );
                }
            }
        }
        e.family(
            "chon_hcp_weight_score",
            "gauge",
            "Layer-mean per-channel weight score mean|dW| (top channels per op).",
        );
        for (name, m) in models {
            let Some(obs) = m.outliers.get() else { continue };
            for t in &obs.taps {
                for j in OutlierObs::top_wscore(t, WSCORE_TOP) {
                    let ch = j.to_string();
                    e.sample_f64(
                        "chon_hcp_weight_score",
                        &[("model", name), ("op", t.op), ("channel", &ch)],
                        t.wscore[j],
                    );
                }
            }
        }
    }
}

/// The process-wide registry used by the `chon serve` binary. Library
/// embedders and in-process test servers should pass their own
/// `Registry::new()` through `RegistryOpts` instead.
pub fn global() -> Arc<Registry> {
    static GLOBAL: OnceLock<Arc<Registry>> = OnceLock::new();
    GLOBAL.get_or_init(Registry::new).clone()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn model_is_get_or_create() {
        let r = Registry::new();
        let a = r.model("alpha");
        let b = r.model("alpha");
        assert!(Arc::ptr_eq(&a, &b));
        a.queue_wait.record(5);
        assert_eq!(r.model("alpha").queue_wait.snapshot().count(), 1);
    }

    #[test]
    fn render_contains_all_families() {
        let r = Registry::new();
        let m = r.model("m1");
        m.prefill.record(1000);
        m.decode_token.record(250);
        r.server.open_conns.set(2);
        r.server.accept.record(10);
        let text = r.render();
        for family in [
            "chon_conn_stage_us",
            "chon_reactor_tick_lag_us",
            "chon_reactor_mailbox_depth",
            "chon_reactor_open_conns",
            "chon_reactor_outbuf_highwater_bytes",
            "chon_conns_rejected_total",
            "chon_stage_latency_us",
        ] {
            assert!(text.contains(&format!("# TYPE {family}")), "{family}");
        }
        assert!(text.contains("chon_reactor_open_conns 2\n"));
        assert!(text.contains("chon_conns_rejected_total 0\n"));
        assert!(text
            .contains("chon_stage_latency_us_count{model=\"m1\",stage=\"prefill\"} 1\n"));
        // no outlier families unless taps are installed
        assert!(!text.contains("chon_hcp_"));
        // no weight gauge until an engine install records it
        assert!(!text.contains("chon_model_weight_bytes"));
    }

    #[test]
    fn render_build_info_when_stamped() {
        let r = Registry::new();
        assert!(!r.render().contains("chon_build_info"));
        r.set_build_info("native", "packed");
        let text = r.render();
        assert!(text.contains(&format!(
            "chon_build_info{{version=\"{}\",backend=\"native\",recipe=\"packed\"}} 1\n",
            env!("CARGO_PKG_VERSION")
        )), "{text}");
    }

    #[test]
    fn render_weight_bytes_when_set() {
        let r = Registry::new();
        r.model("packed").set_weight_bytes(123_456, "packed");
        r.model("dense").set_weight_bytes(987_654, "f32");
        let text = r.render();
        assert!(text.contains("# TYPE chon_model_weight_bytes gauge"));
        assert!(text
            .contains("chon_model_weight_bytes{model=\"dense\",mode=\"f32\"} 987654\n"));
        assert!(text
            .contains("chon_model_weight_bytes{model=\"packed\",mode=\"packed\"} 123456\n"));
        // a reload that flips modes re-labels the same series
        r.model("packed").set_weight_bytes(400_000, "f32");
        let text = r.render();
        assert!(text
            .contains("chon_model_weight_bytes{model=\"packed\",mode=\"f32\"} 400000\n"));
        assert!(!text.contains("mode=\"packed\""));
    }

    #[test]
    fn render_outlier_families_when_installed() {
        let r = Registry::new();
        let m = r.model("m1");
        let obs = Arc::new(outliers::OutlierObs {
            taps: vec![outliers::OpTap::new("attn.q", 4, vec![0.1, 0.9, 0.2, 0.3])],
        });
        obs.taps[0].record_row(&[1], 4.0, 3.0);
        m.outliers.set(obs).ok().unwrap();
        let text = r.render();
        assert!(text.contains(
            "chon_hcp_hot_channel_hits_total{model=\"m1\",op=\"attn.q\",channel=\"1\"} 1\n"
        ));
        assert!(text.contains("chon_hcp_residual_energy_total{model=\"m1\",op=\"attn.q\"} 4\n"));
        assert!(text.contains("chon_hcp_weight_score{model=\"m1\",op=\"attn.q\",channel=\"1\"} 0.9\n"));
        // zero-hit channels stay out of the scrape
        assert!(!text.contains("channel=\"0\"} 0"));
    }
}
