//! `chon tail RUNDIR` — read a run's crash-durable trace and either
//! follow it live (`--follow`), summarize it offline (loss trajectory,
//! phase-time breakdown, hot-channel lifecycle + persistence series),
//! or export the phase spans as a Chrome trace-event file
//! (`--chrome-trace out.json`, loadable in `chrome://tracing` /
//! `ui.perfetto.dev`). Works on torn traces from SIGKILLed runs — the
//! reader drops the one torn final line and summarizes everything up to
//! the last completed step.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::diagnostics;
use crate::obs::trace::{self, TRACE_FILE};
use crate::obs::train::PHASES;
use crate::util::json::Json;

pub struct TailOpts {
    /// a run dir containing `trace.jsonl`, the file itself, or an
    /// out-dir root holding exactly one run dir
    pub target: PathBuf,
    /// follow mode: poll for appended events and print them live
    pub follow: bool,
    /// write Chrome trace-event JSON of the phase spans here
    pub chrome: Option<PathBuf>,
}

/// Resolve the trace file from a run dir / trace path / out-dir root.
pub fn resolve_trace(target: &Path) -> Result<PathBuf> {
    if target.is_file() {
        return Ok(target.to_path_buf());
    }
    let direct = target.join(TRACE_FILE);
    if direct.is_file() {
        return Ok(direct);
    }
    // an out-dir root: accept it iff exactly one run dir has a trace
    let mut found = Vec::new();
    if let Ok(rd) = std::fs::read_dir(target) {
        for entry in rd.flatten() {
            let p = entry.path().join(TRACE_FILE);
            if p.is_file() {
                found.push(p);
            }
        }
    }
    match found.len() {
        1 => Ok(found.remove(0)),
        0 => bail!("no {TRACE_FILE} under {}", target.display()),
        _ => bail!(
            "{} run dirs with a {TRACE_FILE} under {} — name one: {}",
            found.len(),
            target.display(),
            found
                .iter()
                .filter_map(|p| p.parent())
                .map(|p| p.display().to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    }
}

pub fn run(opts: &TailOpts) -> Result<()> {
    let path = resolve_trace(&opts.target)?;
    if opts.follow {
        return follow(&path);
    }
    let events = trace::read_events(&path)?;
    let view = trace::logical_view(&events);
    print_summary(&path, &view);
    if let Some(out) = &opts.chrome {
        write_chrome_trace(&view, out)?;
        println!(
            "chrome trace -> {} (load in chrome://tracing or ui.perfetto.dev)",
            out.display()
        );
    }
    Ok(())
}

/// Per-component persistence series from the trace's stored top-k sets:
/// Jaccard overlap between consecutive probes, i.e. exactly what
/// `Monitor::hot_channel_persistence` computes from full channel maps
/// (the trace stores the top-k selection itself, so the sets match).
pub fn persistence_series(view: &[Json]) -> Vec<(String, Vec<(u64, f64)>)> {
    let mut comps: Vec<(String, Vec<(u64, Vec<(usize, f32)>)>)> = Vec::new();
    for e in view.iter().filter(|e| trace::kind(e) == Some("diag")) {
        let Some(step) = trace::step(e) else { continue };
        let Some(Json::Obj(topk)) = e.get("topk") else { continue };
        for (comp, arr) in topk {
            let Some(pairs) = arr.as_arr() else { continue };
            let set: Vec<(usize, f32)> = pairs
                .iter()
                .filter_map(|p| {
                    let p = p.as_arr()?;
                    Some((
                        p.first()?.as_f64()? as usize,
                        p.get(1)?.as_f64()? as f32,
                    ))
                })
                .collect();
            match comps.iter_mut().find(|(n, _)| n == comp) {
                Some((_, probes)) => probes.push((step, set)),
                None => comps.push((comp.clone(), vec![(step, set)])),
            }
        }
    }
    comps
        .into_iter()
        .map(|(name, probes)| {
            let series = probes
                .windows(2)
                .map(|w| {
                    (w[1].0, diagnostics::channel_overlap(&w[0].1, &w[1].1))
                })
                .collect();
            (name, series)
        })
        .collect()
}

/// Total µs per phase summed over span + diag events, in PHASES order.
pub fn phase_totals(view: &[Json]) -> Vec<(String, u64)> {
    let mut totals: Vec<(String, u64)> =
        PHASES.iter().map(|p| (p.to_string(), 0)).collect();
    for e in view {
        match trace::kind(e) {
            Some("span") => {
                if let Some(Json::Obj(us)) = e.get("us") {
                    for (phase, v) in us {
                        if let (Some(t), Some(v)) = (
                            totals.iter_mut().find(|(p, _)| p == phase),
                            v.as_f64(),
                        ) {
                            t.1 += v as u64;
                        }
                    }
                }
            }
            Some("diag") => {
                if let Some(us) = e.get("us").and_then(|v| v.as_f64()) {
                    if let Some(t) =
                        totals.iter_mut().find(|(p, _)| p == "diag_probe")
                    {
                        t.1 += us as u64;
                    }
                }
            }
            _ => {}
        }
    }
    totals
}

fn print_summary(path: &Path, view: &[Json]) {
    println!("trace: {}", path.display());
    if let Some(rs) =
        view.iter().find(|e| trace::kind(e) == Some("run_start"))
    {
        let s = |k: &str| {
            rs.get(k).and_then(|v| v.as_str()).unwrap_or("?").to_string()
        };
        let n = |k: &str| rs.get(k).and_then(|v| v.as_f64()).unwrap_or(0.0);
        println!(
            "run: model {} recipe {} backend {} seed {} shards {} \
             batch {}x{} total_steps {}",
            s("model"),
            s("recipe"),
            s("backend"),
            n("seed"),
            n("shards"),
            n("batch"),
            n("seq_len"),
            n("total_steps"),
        );
    }
    let series = trace::loss_series(view);
    let count = |k: &str| {
        view.iter().filter(|e| trace::kind(e) == Some(k)).count()
    };
    let (resumes, ckpts) = (count("resume"), count("ckpt"));
    let ended = count("run_end") > 0;
    match (series.first(), series.last()) {
        (Some(&(s0, l0)), Some(&(s1, l1))) => {
            let min = series
                .iter()
                .map(|&(_, l)| l)
                .fold(f64::INFINITY, f64::min);
            println!(
                "steps: {} ({}..{}), loss {:.4} -> {:.4} (min {:.4}), \
                 {} ckpt(s), {} resume(s){}",
                series.len(),
                s0,
                s1,
                l0,
                l1,
                min,
                ckpts,
                resumes,
                if ended { "" } else { " [no run_end: interrupted]" }
            );
        }
        _ => println!(
            "steps: 0, {} ckpt(s), {} resume(s){}",
            ckpts,
            resumes,
            if ended { "" } else { " [no run_end: interrupted]" }
        ),
    }

    let totals = phase_totals(view);
    let sum: u64 = totals.iter().map(|(_, v)| *v).sum();
    if sum > 0 {
        let line = totals
            .iter()
            .filter(|(_, v)| *v > 0)
            .map(|(p, v)| {
                format!("{p} {:.1}ms ({:.0}%)", *v as f64 / 1e3, *v as f64
                    / sum as f64
                    * 100.0)
            })
            .collect::<Vec<_>>()
            .join("  ");
        println!("phases: {line}");
    }

    let (births, deaths) = (count("hot_birth"), count("hot_death"));
    let pers = persistence_series(view);
    if !pers.is_empty() || births + deaths > 0 {
        println!("hot channels: {births} birth(s), {deaths} death(s)");
        for (comp, series) in &pers {
            let js: Vec<String> =
                series.iter().map(|&(_, j)| format!("{j:.2}")).collect();
            println!(
                "  {comp} persistence (early->late): [{}]",
                js.join(", ")
            );
        }
    }
}

/// One human line per event, shared by follow mode.
fn human_line(e: &Json) -> Option<String> {
    let n = |k: &str| e.get(k).and_then(|v| v.as_f64());
    let s = |k: &str| e.get(k).and_then(|v| v.as_str()).unwrap_or("?");
    match trace::kind(e)? {
        "run_start" => Some(format!(
            "run_start: model {} recipe {} total_steps {}",
            s("model"),
            s("recipe"),
            n("total_steps").unwrap_or(0.0)
        )),
        "step" => Some(format!(
            "step {:>5}  loss {:.4}  lr {:.2e}  {:.0} tok/s",
            n("step").unwrap_or(0.0),
            n("loss").unwrap_or(f64::NAN),
            n("lr").unwrap_or(0.0),
            n("tokens_per_s").unwrap_or(0.0),
        )),
        "diag" => Some(format!(
            "diag @{}: {} metrics",
            n("step").unwrap_or(0.0),
            e.get("values").and_then(|v| v.as_arr()).map(<[Json]>::len).unwrap_or(0)
        )),
        "hot_birth" => Some(format!(
            "hot_birth @{}: {} channel {} (ewma {:.3})",
            n("step").unwrap_or(0.0),
            s("comp"),
            n("channel").unwrap_or(-1.0),
            n("ewma").unwrap_or(0.0)
        )),
        "hot_death" => Some(format!(
            "hot_death @{}: {} channel {} (ewma {:.3})",
            n("step").unwrap_or(0.0),
            s("comp"),
            n("channel").unwrap_or(-1.0),
            n("ewma").unwrap_or(0.0)
        )),
        "ckpt" => Some(format!(
            "ckpt @{}: {}",
            n("step").unwrap_or(0.0),
            s("path")
        )),
        "resume" => Some(format!(
            "resume @{}: from {}",
            n("step").unwrap_or(0.0),
            s("from")
        )),
        "run_end" => Some(format!(
            "run_end @{}: loss {:.4}",
            n("step").unwrap_or(0.0),
            n("loss").unwrap_or(f64::NAN)
        )),
        _ => None,
    }
}

/// Follow mode: poll the file for appended *complete* lines, print one
/// human line per event, stop at `run_end` (or Ctrl-C).
fn follow(path: &Path) -> Result<()> {
    let mut offset = 0usize;
    loop {
        let text = std::fs::read_to_string(path).unwrap_or_default();
        if text.len() < offset {
            // truncated/recreated underneath us: start over
            println!("[trace truncated — following from the top]");
            offset = 0;
        }
        let new = &text[offset..];
        let mut done = false;
        if let Some(last_nl) = new.rfind('\n') {
            for line in new[..=last_nl].lines() {
                if line.trim().is_empty() {
                    continue;
                }
                let Ok(ev) = Json::parse(line) else { continue };
                if let Some(h) = human_line(&ev) {
                    println!("{h}");
                }
                if trace::kind(&ev) == Some("run_end") {
                    done = true;
                }
            }
            offset += last_nl + 1;
        }
        if done {
            return Ok(());
        }
        std::thread::sleep(std::time::Duration::from_millis(500));
    }
}

/// Chrome trace-event export: every span/diag phase as a complete "X"
/// event on one timeline, laid end to end on a cumulative µs cursor
/// (the trace stores durations, not absolute timestamps), plus instant
/// markers for ckpt/resume. pid 1; tid = phase index so the viewer
/// shows one row per phase.
pub fn write_chrome_trace(view: &[Json], out: &Path) -> Result<()> {
    let mut cursor = 0u64;
    let mut evs: Vec<Json> = Vec::new();
    let x_event = |name: &str, ts: u64, dur: u64, tid: usize, step: f64| {
        Json::Obj(vec![
            ("name".into(), Json::Str(name.to_string())),
            ("cat".into(), Json::Str("phase".into())),
            ("ph".into(), Json::Str("X".into())),
            ("ts".into(), Json::Num(ts as f64)),
            ("dur".into(), Json::Num(dur as f64)),
            ("pid".into(), Json::Num(1.0)),
            ("tid".into(), Json::Num(tid as f64 + 1.0)),
            (
                "args".into(),
                Json::Obj(vec![("step".into(), Json::Num(step))]),
            ),
        ])
    };
    for e in view {
        let step = trace::step(e).unwrap_or(0) as f64;
        match trace::kind(e) {
            Some("span") => {
                if let Some(Json::Obj(us)) = e.get("us") {
                    // phases in canonical order, not object order
                    for (i, phase) in PHASES.iter().enumerate() {
                        let Some(dur) = us
                            .iter()
                            .find(|(p, _)| p == phase)
                            .and_then(|(_, v)| v.as_f64())
                        else {
                            continue;
                        };
                        let dur = dur as u64;
                        if dur == 0 {
                            continue;
                        }
                        evs.push(x_event(phase, cursor, dur, i, step));
                        cursor += dur;
                    }
                }
            }
            Some("diag") => {
                if let Some(dur) = e.get("us").and_then(|v| v.as_f64()) {
                    let dur = dur as u64;
                    evs.push(x_event(
                        "diag_probe",
                        cursor,
                        dur,
                        PHASES.len() - 1,
                        step,
                    ));
                    cursor += dur;
                }
            }
            Some(k @ ("ckpt" | "resume")) => {
                evs.push(Json::Obj(vec![
                    ("name".into(), Json::Str(k.to_string())),
                    ("cat".into(), Json::Str("marker".into())),
                    ("ph".into(), Json::Str("i".into())),
                    ("s".into(), Json::Str("g".into())),
                    ("ts".into(), Json::Num(cursor as f64)),
                    ("pid".into(), Json::Num(1.0)),
                    ("tid".into(), Json::Num(1.0)),
                ]));
            }
            _ => {}
        }
    }
    let doc = Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(evs)),
        ("displayTimeUnit".into(), Json::Str("ms".into())),
    ]);
    let mut f = std::fs::File::create(out)
        .with_context(|| format!("create {}", out.display()))?;
    f.write_all(doc.render().as_bytes())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn view_from(text: &str) -> Vec<Json> {
        trace::logical_view(&trace::parse_events(text).unwrap())
    }

    #[test]
    fn persistence_series_matches_overlap_semantics() {
        // probe 1 and 2 share {3}, probe 2 and 3 share {3,5} fully
        let text = concat!(
            "{\"ev\":\"diag\",\"step\":10,\"us\":5,\"values\":[],\"topk\":{\"attn_o\":[[3,2.0],[1,1.0]]}}\n",
            "{\"ev\":\"diag\",\"step\":20,\"us\":5,\"values\":[],\"topk\":{\"attn_o\":[[3,2.1],[5,1.2]]}}\n",
            "{\"ev\":\"diag\",\"step\":30,\"us\":5,\"values\":[],\"topk\":{\"attn_o\":[[5,2.2],[3,1.9]]}}\n",
        );
        let p = persistence_series(&view_from(text));
        assert_eq!(p.len(), 1);
        assert_eq!(p[0].0, "attn_o");
        // {3,1} vs {3,5}: |∩|=1 |∪|=3 -> 1/3; {3,5} vs {5,3} -> 1.0
        assert_eq!(p[0].1.len(), 2);
        assert_eq!(p[0].1[0].0, 20);
        assert!((p[0].1[0].1 - 1.0 / 3.0).abs() < 1e-12);
        assert_eq!(p[0].1[1], (30, 1.0));
    }

    #[test]
    fn phase_totals_sum_span_and_diag() {
        let text = concat!(
            "{\"ev\":\"span\",\"step\":1,\"us\":{\"data_wait\":10,\"fwd_bwd\":100,\"allreduce\":5,\"adam\":7}}\n",
            "{\"ev\":\"span\",\"step\":2,\"us\":{\"data_wait\":20,\"fwd_bwd\":200,\"allreduce\":5,\"adam\":7}}\n",
            "{\"ev\":\"diag\",\"step\":2,\"us\":40,\"values\":[],\"topk\":{}}\n",
        );
        let t = phase_totals(&view_from(text));
        let get = |p: &str| t.iter().find(|(n, _)| n == p).unwrap().1;
        assert_eq!(get("data_wait"), 30);
        assert_eq!(get("fwd_bwd"), 300);
        assert_eq!(get("diag_probe"), 40);
    }

    #[test]
    fn chrome_trace_is_valid_json_with_x_events() {
        let text = concat!(
            "{\"ev\":\"span\",\"step\":1,\"us\":{\"data_wait\":10,\"fwd_bwd\":100,\"allreduce\":5,\"adam\":7}}\n",
            "{\"ev\":\"ckpt\",\"step\":1,\"path\":\"/tmp/x\"}\n",
        );
        let dir = std::env::temp_dir().join("chon_tail_chrome");
        std::fs::create_dir_all(&dir).unwrap();
        let out = dir.join("trace.json");
        write_chrome_trace(&view_from(text), &out).unwrap();
        let doc =
            Json::parse(&std::fs::read_to_string(&out).unwrap()).unwrap();
        let evs = doc.get("traceEvents").and_then(|v| v.as_arr()).unwrap();
        // 4 phase X events + 1 instant marker
        assert_eq!(evs.len(), 5);
        let first = &evs[0];
        assert_eq!(first.get("ph").and_then(|v| v.as_str()), Some("X"));
        assert_eq!(first.get("name").and_then(|v| v.as_str()), Some("data_wait"));
        // spans are laid end to end: second starts where first ends
        assert_eq!(evs[1].get("ts").and_then(|v| v.as_f64()), Some(10.0));
        assert_eq!(
            evs.last().unwrap().get("ph").and_then(|v| v.as_str()),
            Some("i")
        );
    }

    #[test]
    fn resolve_trace_finds_single_run_dir() {
        let root = std::env::temp_dir().join("chon_tail_resolve");
        let _ = std::fs::remove_dir_all(&root);
        let run = root.join("tiny_gla_chon");
        std::fs::create_dir_all(&run).unwrap();
        assert!(resolve_trace(&root).is_err(), "no trace yet");
        std::fs::write(run.join(TRACE_FILE), "").unwrap();
        // all three spellings resolve to the same file
        let direct = resolve_trace(&run.join(TRACE_FILE)).unwrap();
        assert_eq!(resolve_trace(&run).unwrap(), direct);
        assert_eq!(resolve_trace(&root).unwrap(), direct);
        // ambiguity is an error, not a guess
        let run2 = root.join("tiny_gla_bf16");
        std::fs::create_dir_all(&run2).unwrap();
        std::fs::write(run2.join(TRACE_FILE), "").unwrap();
        assert!(resolve_trace(&root).is_err());
    }
}
