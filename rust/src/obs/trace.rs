//! Crash-durable append-only run trace: one JSON object per line in
//! `runs/<run>/trace.jsonl`, written through an unbuffered `write_all`
//! per record so a SIGKILL loses at most the one torn final line the
//! reader is required to tolerate. Everything the in-memory `Monitor`
//! and `MetricLog` hold rides in the trace too — a crashed run's loss
//! series and hot-channel history are recoverable with `chon tail`, and
//! `Monitor::from_trace_events` rebuilds the metric-series view.
//!
//! Event kinds (the `"ev"` key), all carrying `"step"` where it makes
//! sense:
//!
//! | ev          | payload                                                        |
//! |-------------|----------------------------------------------------------------|
//! | `run_start` | model, recipe, seed, shards, batch, seq_len, total_steps, metric_names, version |
//! | `step`      | loss, grad_norm, lr, wall_ms, tokens, tokens_per_s             |
//! | `span`      | us: {phase → µs} for the step's phases                         |
//! | `diag`      | us, values (full metric vector), topk: {comp → [[chan, mag]…]} |
//! | `hot_birth` | comp, channel, ewma — channel classified persistent            |
//! | `hot_death` | comp, channel, ewma — persistent channel went cold             |
//! | `ckpt`      | path — checkpoint written                                      |
//! | `resume`    | from — run resumed at `step` from a checkpoint                 |
//! | `run_end`   | loss — clean completion marker                                 |
//!
//! Resume appends to the existing trace (validated: the resume step must
//! not open a gap past the last traced step). Because resumed training
//! is bit-identical to uninterrupted training, [`logical_view`] can drop
//! the stale post-resume tail of the crashed incarnation and the
//! remaining step series equals an uninterrupted run's exactly.

use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// File name of the trace inside a run directory.
pub const TRACE_FILE: &str = "trace.jsonl";

/// Event kinds that are superseded by a later `resume` at an earlier
/// step (the re-executed steps re-emit them bit-identically). Markers
/// (`run_start`, `ckpt`, `resume`, `run_end`) narrate the run's actual
/// history and are never dropped.
const STEP_KEYED: &[&str] = &["step", "span", "diag", "hot_birth", "hot_death"];

/// Append-only writer. Each [`emit`](TraceWriter::emit) is a single
/// unbuffered `write_all` of `line + "\n"` straight to the kernel: no
/// user-space buffer exists to lose on SIGKILL.
pub struct TraceWriter {
    file: std::fs::File,
    path: PathBuf,
}

impl TraceWriter {
    /// Create (truncate) a fresh trace.
    pub fn create(path: &Path) -> Result<TraceWriter> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("create trace {}", path.display()))?;
        Ok(TraceWriter { file, path: path.to_path_buf() })
    }

    /// Open an existing trace for appending (the `--resume` path).
    pub fn append(path: &Path) -> Result<TraceWriter> {
        let file = std::fs::OpenOptions::new()
            .append(true)
            .open(path)
            .with_context(|| format!("append trace {}", path.display()))?;
        Ok(TraceWriter { file, path: path.to_path_buf() })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Write one event line. `&self` on purpose: `&File` is `Write`, so
    /// emitting from `&self` methods (checkpoint save) needs no `&mut`.
    pub fn emit(&self, ev: &Json) -> Result<()> {
        let mut line = ev.render();
        line.push('\n');
        (&self.file)
            .write_all(line.as_bytes())
            .with_context(|| format!("write trace {}", self.path.display()))
    }
}

/// Build an event object: kind plus fields, `ev` first so the lines are
/// eyeball-greppable.
pub fn event(kind: &str, fields: Vec<(&str, Json)>) -> Json {
    let mut obj = vec![("ev".to_string(), Json::Str(kind.to_string()))];
    obj.extend(fields.into_iter().map(|(k, v)| (k.to_string(), v)));
    Json::Obj(obj)
}

/// Event kind (the `"ev"` value).
pub fn kind(ev: &Json) -> Option<&str> {
    ev.get("ev").and_then(|v| v.as_str())
}

/// Event step, where present.
pub fn step(ev: &Json) -> Option<u64> {
    ev.get("step").and_then(|v| v.as_f64()).map(|n| n as u64)
}

/// Parse a trace's text tolerantly: a torn tail (the final non-empty
/// line failing to parse — what SIGKILL mid-`write` leaves behind) is
/// silently dropped; a malformed line anywhere *before* that is real
/// corruption and errors.
pub fn parse_events(text: &str) -> Result<Vec<Json>> {
    let lines: Vec<&str> = text.split('\n').collect();
    let mut out = Vec::new();
    for (i, line) in lines.iter().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        match Json::parse(line) {
            Ok(v) => out.push(v),
            Err(e) => {
                let torn_tail =
                    lines[i + 1..].iter().all(|l| l.trim().is_empty());
                if torn_tail {
                    break;
                }
                bail!("trace line {}: {e}", i + 1);
            }
        }
    }
    Ok(out)
}

/// Read and tolerantly parse a trace file.
pub fn read_events(path: &Path) -> Result<Vec<Json>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("read trace {}", path.display()))?;
    parse_events(&text).with_context(|| format!("parse trace {}", path.display()))
}

/// The logical (resume-collapsed) view: at each `resume{step: S}`,
/// step-keyed events with step > S from earlier incarnations are
/// dropped — those steps are about to be re-executed bit-identically,
/// so the surviving series is exactly an uninterrupted run's. Marker
/// events always survive.
pub fn logical_view(events: &[Json]) -> Vec<Json> {
    let mut out: Vec<Json> = Vec::new();
    for ev in events {
        if kind(ev) == Some("resume") {
            let s = step(ev).unwrap_or(0);
            out.retain(|e| {
                let k = kind(e).unwrap_or("");
                !(STEP_KEYED.contains(&k) && step(e).unwrap_or(0) > s)
            });
        }
        out.push(ev.clone());
    }
    out
}

/// `(step, loss)` series over `step` events in the given slice (pass a
/// [`logical_view`] for the resume-collapsed series).
pub fn loss_series(events: &[Json]) -> Vec<(u64, f64)> {
    events
        .iter()
        .filter(|e| kind(e) == Some("step"))
        .filter_map(|e| {
            Some((step(e)?, e.get("loss").and_then(|v| v.as_f64())?))
        })
        .collect()
}

/// Highest step among `step` events, if any — what resume-append
/// monotonicity is validated against.
pub fn last_step(events: &[Json]) -> Option<u64> {
    events
        .iter()
        .filter(|e| kind(e) == Some("step"))
        .filter_map(step)
        .max()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step_ev(s: u64, loss: f64) -> Json {
        event(
            "step",
            vec![("step", Json::Num(s as f64)), ("loss", Json::Num(loss))],
        )
    }

    #[test]
    fn round_trip_and_accessors() {
        let dir = std::env::temp_dir().join("chon_trace_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(TRACE_FILE);
        let w = TraceWriter::create(&path).unwrap();
        w.emit(&event("run_start", vec![("step", Json::Num(0.0))])).unwrap();
        w.emit(&step_ev(1, 3.5)).unwrap();
        w.emit(&step_ev(2, 3.25)).unwrap();
        let evs = read_events(&path).unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(kind(&evs[0]), Some("run_start"));
        assert_eq!(step(&evs[2]), Some(2));
        assert_eq!(loss_series(&evs), vec![(1, 3.5), (2, 3.25)]);
        assert_eq!(last_step(&evs), Some(2));
    }

    #[test]
    fn torn_final_line_is_tolerated() {
        let good = format!("{}\n{}\n", step_ev(1, 3.0).render(), step_ev(2, 2.9).render());
        // cut mid-record, no trailing newline — the SIGKILL shape
        let torn = format!("{good}{{\"ev\":\"step\",\"st");
        let evs = parse_events(&torn).unwrap();
        assert_eq!(evs.len(), 2);
        assert_eq!(last_step(&evs), Some(2));
        // even a newline-terminated garbage tail is torn, not corruption
        let torn_nl = format!("{good}{{\"ev\": oops\n");
        assert_eq!(parse_events(&torn_nl).unwrap().len(), 2);
    }

    #[test]
    fn torn_middle_line_is_corruption() {
        let text = format!(
            "{}\nnot json at all\n{}\n",
            step_ev(1, 3.0).render(),
            step_ev(2, 2.9).render()
        );
        let err = parse_events(&text).unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn logical_view_collapses_resume() {
        // incarnation 1 ran steps 1..=4, checkpointed at 2, crashed;
        // incarnation 2 resumed at 2 and re-ran 3..=5
        let mut evs = vec![event("run_start", vec![("step", Json::Num(0.0))])];
        for s in 1..=4 {
            evs.push(step_ev(s, 4.0 - s as f64 * 0.1));
        }
        evs.push(event(
            "resume",
            vec![("step", Json::Num(2.0)), ("from", Json::Str("ck".into()))],
        ));
        for s in 3..=5 {
            evs.push(step_ev(s, 4.0 - s as f64 * 0.1));
        }
        let view = logical_view(&evs);
        let series = loss_series(&view);
        assert_eq!(
            series.iter().map(|(s, _)| *s).collect::<Vec<_>>(),
            vec![1, 2, 3, 4, 5],
            "each step exactly once, in order"
        );
        // markers survive the collapse
        assert!(view.iter().any(|e| kind(e) == Some("resume")));
        assert!(view.iter().any(|e| kind(e) == Some("run_start")));
    }
}
