//! Live outlier telemetry for `chon serve --obs-outliers`.
//!
//! The paper's instrumentation (kurtosis, FTZ, hot-channel maps in
//! `diagnostics/` + `coordinator/monitor.rs`) runs offline at training
//! probes. This module closes the loop at serve time: every quantized
//! linear on the HCP path already selects per-row hot channels
//! (`model::infer_linear_prepared`) — with `--obs-outliers` those
//! selections are sampled into per-op taps, so a `/metrics` scrape shows
//! which channels are hot *under production traffic* and how much
//! quantization-residual energy the HCP compensation is carrying.
//!
//! One [`OpTap`] per forward op (attn.q .. mlp.down), aggregated over
//! layers: per-channel hit counters, activation rows observed, and the
//! Frobenius energy of the activation residual `dx = x - quant(x)` split
//! into its total and its hot-channel share. The per-channel
//! weight-score term (`mean |dW_j,:|`, layer-mean) is frozen at engine
//! load and exposed as a gauge — the static half of the HCP score the
//! dynamic hits can be read against.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::obs::metrics::Counter;

/// Relaxed f64 accumulator over AtomicU64 bit patterns (adds are a CAS
/// loop; this path runs once per quantized-linear call, not per row, so
/// contention is nil).
#[derive(Default)]
pub struct AtomicF64(AtomicU64);

impl AtomicF64 {
    pub fn add(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.0.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => return,
                Err(now) => cur = now,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Telemetry for one forward op (all layers pooled).
pub struct OpTap {
    /// forward-op name ("attn.q" .. "mlp.down")
    pub op: &'static str,
    /// hot-channel selections per input channel (counts of rows where
    /// the channel made the per-row HCP top-k)
    pub hits: Vec<Counter>,
    /// activation rows observed through this op
    pub rows: Counter,
    /// Σ ‖x - quant(x)‖²_F over observed rows (total residual energy)
    pub resid_energy: AtomicF64,
    /// the share of `resid_energy` carried by the selected hot channels
    pub hot_energy: AtomicF64,
    /// layer-mean per-channel weight score `mean |dW_j,:|` (static)
    pub wscore: Vec<f64>,
}

impl OpTap {
    pub fn new(op: &'static str, channels: usize, wscore: Vec<f64>) -> OpTap {
        OpTap {
            op,
            hits: (0..channels).map(|_| Counter::new()).collect(),
            rows: Counter::new(),
            resid_energy: AtomicF64::default(),
            hot_energy: AtomicF64::default(),
            wscore,
        }
    }

    /// Record one activation row's HCP outcome: the selected hot-channel
    /// indices plus the row's total and hot residual energy.
    pub fn record_row(&self, hot: &[usize], resid: f64, hot_resid: f64) {
        for &j in hot {
            if let Some(c) = self.hits.get(j) {
                c.inc();
            }
        }
        self.rows.inc();
        self.resid_energy.add(resid);
        self.hot_energy.add(hot_resid);
    }
}

/// All taps of one engine, looked up by forward-op name.
#[derive(Default)]
pub struct OutlierObs {
    pub taps: Vec<OpTap>,
}

impl OutlierObs {
    pub fn tap(&self, op: &str) -> Option<&OpTap> {
        self.taps.iter().find(|t| t.op == op)
    }

    /// Channel indices of the `n` largest weight scores of `tap`,
    /// descending (ties by lower index). Bounds the gauge cardinality
    /// in exposition.
    pub fn top_wscore(tap: &OpTap, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..tap.wscore.len()).collect();
        idx.sort_by(|&a, &b| {
            tap.wscore[b]
                .partial_cmp(&tap.wscore[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        idx.truncate(n);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn atomic_f64_accumulates() {
        let a = AtomicF64::default();
        a.add(1.5);
        a.add(2.25);
        assert_eq!(a.get(), 3.75);
    }

    #[test]
    fn tap_records_hits_and_energy() {
        let tap = OpTap::new("attn.q", 4, vec![0.1, 0.4, 0.2, 0.3]);
        tap.record_row(&[1, 3], 10.0, 7.0);
        tap.record_row(&[1], 2.0, 1.5);
        assert_eq!(tap.rows.get(), 2);
        let hits: Vec<u64> = tap.hits.iter().map(|c| c.get()).collect();
        assert_eq!(hits, vec![0, 2, 0, 1]);
        assert_eq!(tap.resid_energy.get(), 12.0);
        assert_eq!(tap.hot_energy.get(), 8.5);
        // out-of-range indices are ignored, not a panic
        tap.record_row(&[9], 0.0, 0.0);
        assert_eq!(tap.rows.get(), 3);
    }

    #[test]
    fn top_wscore_orders_descending() {
        let tap = OpTap::new("mlp.up", 4, vec![0.1, 0.4, 0.2, 0.4]);
        assert_eq!(OutlierObs::top_wscore(&tap, 3), vec![1, 3, 2]);
        assert_eq!(OutlierObs::top_wscore(&tap, 10).len(), 4);
    }
}
