//! Lock-free metric primitives: counters, gauges, and log₂-bucket
//! latency histograms (the `prometheus`-crate substitute's core types —
//! the crate itself is not in the offline vendor set, matching the
//! `logger.rs`-instead-of-`log` convention).
//!
//! Everything here is a plain atomic or a fixed array of atomics:
//! `record()` on the hot decode path is one relaxed `fetch_add` per
//! bucket plus one for the sum, no locks, no allocation. Readers take a
//! [`HistSnapshot`] (a plain value type) and derive counts, quantiles
//! and Prometheus cumulative buckets from it; snapshots of live
//! histograms are internally consistent enough for monitoring (each
//! bucket is read once, the derived `count` is exactly the sum of the
//! bucket reads, so `_count == Σ buckets` always holds in exposition).

use std::sync::atomic::{AtomicU64, Ordering};

/// Number of finite histogram buckets: upper bounds 2^0 .. 2^25 µs
/// (1 µs .. ~33.5 s). Values above the last finite bound land in the
/// implicit +Inf bucket at index `N_FINITE`.
pub const N_FINITE: usize = 26;
/// Total buckets including +Inf.
pub const N_BUCKETS: usize = N_FINITE + 1;

/// Upper bound (inclusive, µs) of finite bucket `i`.
pub fn bucket_bound(i: usize) -> u64 {
    1u64 << i
}

/// Index of the bucket a value falls in: the smallest `i` with
/// `v <= 2^i`, clamped to the +Inf bucket. Zero lands in bucket 0
/// (le="1") — sub-microsecond spans are real on the flush path.
pub fn bucket_idx(v: u64) -> usize {
    if v <= 1 {
        return 0;
    }
    let i = (64 - (v - 1).leading_zeros()) as usize;
    i.min(N_FINITE)
}

/// A monotonically increasing counter.
#[derive(Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A gauge: a value that can move both ways (set) or track a high-water
/// mark (`record_max`).
#[derive(Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: u64) {
        self.0.store(v, Ordering::Relaxed);
    }

    pub fn record_max(&self, v: u64) {
        self.0.fetch_max(v, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A float-valued gauge (loss, learning rate, tokens/s): the f64 bits
/// ride in an `AtomicU64`, so set/get stay lock-free like every other
/// primitive here. No arithmetic on the stored value — last write wins.
#[derive(Default)]
pub struct GaugeF64(AtomicU64);

impl GaugeF64 {
    pub fn new() -> GaugeF64 {
        GaugeF64(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Fixed-bucket log₂-spaced latency histogram over µs values.
pub struct Histogram {
    buckets: [AtomicU64; N_BUCKETS],
    /// sum of recorded values (µs) — the Prometheus `_sum` series
    sum: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
        }
    }

    /// Record one observation (µs). Lock-free: two relaxed adds.
    pub fn record(&self, v: u64) {
        self.buckets[bucket_idx(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
    }

    /// Record an elapsed `Duration` in µs.
    pub fn record_elapsed(&self, d: std::time::Duration) {
        self.record(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Point-in-time copy of the bucket counts and sum.
    pub fn snapshot(&self) -> HistSnapshot {
        HistSnapshot {
            buckets: std::array::from_fn(|i| {
                self.buckets[i].load(Ordering::Relaxed)
            }),
            sum: self.sum.load(Ordering::Relaxed),
        }
    }
}

/// A plain-value histogram state: what exposition and quantile math
/// operate on. Obtainable from a live [`Histogram`] or by merging
/// snapshots (per-shard histograms roll up by bucket-wise addition).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct HistSnapshot {
    pub buckets: [u64; N_BUCKETS],
    pub sum: u64,
}

impl HistSnapshot {
    pub fn count(&self) -> u64 {
        self.buckets.iter().sum()
    }

    /// Bucket-wise merge (+= on every bucket and the sum).
    pub fn merge(&mut self, other: &HistSnapshot) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += *b;
        }
        self.sum += other.sum;
    }

    /// Quantile estimate: the upper bound of the bucket holding the
    /// rank-`ceil(q·n)` observation. Because buckets are log₂-spaced the
    /// estimate `u` of a true value `p >= 1` satisfies `p <= u < 2p` —
    /// a factor-of-two latency resolution, which is what p50/p99/p999
    /// dashboards need. Returns 0 on an empty histogram; observations in
    /// the +Inf bucket report twice the last finite bound (saturated).
    pub fn quantile(&self, q: f64) -> u64 {
        let n = self.count();
        if n == 0 {
            return 0;
        }
        let rank = ((q * n as f64).ceil() as u64).clamp(1, n);
        let mut cum = 0u64;
        for (i, &b) in self.buckets.iter().enumerate() {
            cum += b;
            if cum >= rank {
                return if i < N_FINITE {
                    bucket_bound(i)
                } else {
                    bucket_bound(N_FINITE - 1).saturating_mul(2)
                };
            }
        }
        bucket_bound(N_FINITE - 1).saturating_mul(2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_boundaries() {
        assert_eq!(bucket_idx(0), 0);
        assert_eq!(bucket_idx(1), 0);
        assert_eq!(bucket_idx(2), 1);
        assert_eq!(bucket_idx(3), 2);
        assert_eq!(bucket_idx(4), 2);
        assert_eq!(bucket_idx(5), 3);
        // every exact power of two sits in its own bucket (le inclusive)
        for i in 0..N_FINITE {
            assert_eq!(bucket_idx(bucket_bound(i)), i, "2^{i}");
        }
        // one past the last finite bound overflows to +Inf
        assert_eq!(bucket_idx(bucket_bound(N_FINITE - 1) + 1), N_FINITE);
        assert_eq!(bucket_idx(u64::MAX), N_FINITE);
    }

    #[test]
    fn counter_and_gauge() {
        let c = Counter::new();
        c.inc();
        c.add(4);
        assert_eq!(c.get(), 5);
        let g = Gauge::new();
        g.set(7);
        g.record_max(3);
        assert_eq!(g.get(), 7);
        g.record_max(11);
        assert_eq!(g.get(), 11);
        g.set(2);
        assert_eq!(g.get(), 2);
    }

    #[test]
    fn record_snapshot_merge() {
        let h = Histogram::new();
        for v in [0u64, 1, 2, 1000, 1_000_000] {
            h.record(v);
        }
        let s = h.snapshot();
        assert_eq!(s.count(), 5);
        assert_eq!(s.sum, 1_001_003);
        let mut m = s.clone();
        m.merge(&s);
        assert_eq!(m.count(), 10);
        assert_eq!(m.sum, 2 * s.sum);
        for i in 0..N_BUCKETS {
            assert_eq!(m.buckets[i], 2 * s.buckets[i]);
        }
    }

    #[test]
    fn quantile_empty_and_single() {
        assert_eq!(HistSnapshot::default().quantile(0.5), 0);
        let h = Histogram::new();
        h.record(100);
        let s = h.snapshot();
        // a single sample is every quantile, within the 2x bucket bound
        for q in [0.0, 0.5, 0.99, 1.0] {
            let u = s.quantile(q);
            assert!((100..200).contains(&u), "q{q} -> {u}");
        }
    }

    #[test]
    fn quantile_bounds_factor_two() {
        // any recorded value p >= 1 reports an estimate u in [p, 2p)
        let mut v = 1u64;
        while v <= bucket_bound(N_FINITE - 1) {
            let h = Histogram::new();
            h.record(v);
            let u = h.snapshot().quantile(0.5);
            assert!(u >= v && u < 2 * v, "p={v} u={u}");
            v = v * 3 + 1;
        }
    }
}
