//! Bench harness utilities (criterion substitute — DESIGN.md
//! §Substitutions): warmup + repeated timing with median/mean/min stats,
//! and a tiny table printer shared by the per-figure benches.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub min_ms: f64,
}

/// Time `f` with `warmup` throwaway iterations then `iters` measured ones.
pub fn time_fn(warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing {
        iters,
        mean_ms: mean,
        median_ms: samples[samples.len() / 2],
        min_ms: samples[0],
    }
}

/// Adaptive: pick iteration count so total measured time ~ `budget_ms`.
pub fn time_auto(budget_ms: f64, mut f: impl FnMut()) -> Timing {
    let t = Instant::now();
    f();
    let once = t.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / once.max(1e-3)) as usize).clamp(3, 1000);
    time_fn(1, iters, f)
}

/// Simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len().max(10)).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&self.widths) {
                s.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(self.widths.iter().sum::<usize>() + 2 * self.widths.len()));
        for r in &self.rows {
            line(r);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let t = time_fn(1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.min_ms <= t.median_ms);
        assert!(t.min_ms <= t.mean_ms * 1.001);
    }

    #[test]
    fn auto_clamps() {
        let t = time_auto(5.0, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(t.iters >= 3 && t.iters <= 1000);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }
}
