//! Bench harness utilities (criterion substitute — DESIGN.md
//! §Substitutions): warmup + repeated timing with median/mean/min stats,
//! a tiny table printer shared by the per-figure benches, and the
//! versioned JSON result format the CI regression gate diffs
//! (`cargo bench -- perf` writes it, `chon bench-diff` compares it).

use std::path::Path;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

/// Timing statistics over repeated runs.
#[derive(Clone, Copy, Debug)]
pub struct Timing {
    pub iters: usize,
    pub mean_ms: f64,
    pub median_ms: f64,
    pub min_ms: f64,
}

/// Time `f` with `warmup` throwaway iterations then `iters` measured ones.
pub fn time_fn(warmup: usize, iters: usize, mut f: impl FnMut()) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e3);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    Timing {
        iters,
        mean_ms: mean,
        median_ms: samples[samples.len() / 2],
        min_ms: samples[0],
    }
}

/// Adaptive: pick iteration count so total measured time ~ `budget_ms`.
pub fn time_auto(budget_ms: f64, mut f: impl FnMut()) -> Timing {
    let t = Instant::now();
    f();
    let once = t.elapsed().as_secs_f64() * 1e3;
    let iters = ((budget_ms / once.max(1e-3)) as usize).clamp(3, 1000);
    time_fn(1, iters, f)
}

/// Simple fixed-width table printer.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len().max(10)).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (c, w) in cells.iter().zip(&self.widths) {
                s.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{}", s.trim_end());
        };
        line(&self.headers);
        println!("{}", "-".repeat(self.widths.iter().sum::<usize>() + 2 * self.widths.len()));
        for r in &self.rows {
            line(r);
        }
    }
}

// ------------------------------------------------------------------
// Versioned JSON bench reports (the CI perf-regression contract)
// ------------------------------------------------------------------

/// Bumped on incompatible report layout changes. v2 generalized entries
/// from `{name, median_ms}` to `{name, value, unit}` so the table/figure
/// benches (losses, percentages, MSEs) share the same versioned format
/// as the timing microbenches.
pub const REPORT_SCHEMA_VERSION: usize = 2;

/// One benched quantity. `value` is lower-is-better for every unit this
/// crate emits (ms, loss, pct, mse) — the regression gate relies on it.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchEntry {
    pub name: String,
    pub value: f64,
    pub unit: String,
}

impl BenchEntry {
    /// A timing entry (the common case).
    pub fn ms(name: impl Into<String>, median_ms: f64) -> BenchEntry {
        BenchEntry { name: name.into(), value: median_ms, unit: "ms".into() }
    }

    /// A non-timing entry (loss / pct / mse / ...).
    pub fn val(name: impl Into<String>, value: f64, unit: &str) -> BenchEntry {
        BenchEntry { name: name.into(), value, unit: unit.into() }
    }
}

/// Render a report document.
pub fn report_json(bench: &str, entries: &[BenchEntry]) -> Json {
    Json::Obj(vec![
        ("schema_version".into(), Json::Num(REPORT_SCHEMA_VERSION as f64)),
        ("bench".into(), Json::Str(bench.into())),
        (
            "results".into(),
            Json::Arr(
                entries
                    .iter()
                    .map(|e| {
                        Json::Obj(vec![
                            ("name".into(), Json::Str(e.name.clone())),
                            ("value".into(), Json::Num(e.value)),
                            ("unit".into(), Json::Str(e.unit.clone())),
                        ])
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Write a report file (pretty-printed: it gets checked in as a baseline).
pub fn write_report(path: &Path, bench: &str, entries: &[BenchEntry]) -> Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, report_json(bench, entries).render_pretty())
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

/// Read + schema-validate a report file.
pub fn read_report(path: &Path) -> Result<Vec<BenchEntry>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading bench report {}", path.display()))?;
    let doc = Json::parse(&text)
        .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
    let ver = doc
        .get("schema_version")
        .and_then(|v| v.as_f64())
        .map(|v| v as usize);
    if ver != Some(REPORT_SCHEMA_VERSION) {
        bail!(
            "{} has schema_version {ver:?} (this build reads {REPORT_SCHEMA_VERSION})",
            path.display()
        );
    }
    let mut out = Vec::new();
    for item in doc
        .get("results")
        .and_then(|r| r.as_arr())
        .context("report has no results array")?
    {
        let name = item
            .get("name")
            .and_then(|v| v.as_str())
            .context("result entry missing name")?
            .to_string();
        let value = item
            .get("value")
            .and_then(|v| v.as_f64())
            .context("result entry missing value")?;
        let unit = item
            .get("unit")
            .and_then(|v| v.as_str())
            .unwrap_or("ms")
            .to_string();
        out.push(BenchEntry { name, value, unit });
    }
    Ok(out)
}

/// Compare a run against a baseline. Returns the regressed entry names;
/// prints one line per entry. An entry counts as regressed when its
/// median exceeds the baseline by more than `tol_pct` percent; entries
/// missing from the current run regress too (a hot path silently dropped
/// from the bench is exactly what the gate exists to catch).
pub fn diff_reports(
    baseline: &[BenchEntry],
    current: &[BenchEntry],
    tol_pct: f64,
) -> Vec<String> {
    let mut regressed = Vec::new();
    for b in baseline {
        match current.iter().find(|c| c.name == b.name) {
            None => {
                println!("{:<28} MISSING from current run", b.name);
                regressed.push(b.name.clone());
            }
            Some(c) if c.unit != b.unit => {
                println!(
                    "{:<28} unit changed ({} -> {}) — refresh the baseline",
                    b.name, b.unit, c.unit
                );
                regressed.push(b.name.clone());
            }
            Some(c) => {
                let delta = (c.value / b.value.max(1e-9) - 1.0) * 100.0;
                let bad = delta > tol_pct;
                println!(
                    "{:<28} base {:>8.2} {u}  now {:>8.2} {u}  {:>+7.1}% {}",
                    b.name,
                    b.value,
                    c.value,
                    delta,
                    if bad { "REGRESSED" } else { "ok" },
                    u = b.unit,
                );
                if bad {
                    regressed.push(b.name.clone());
                }
            }
        }
    }
    // entries with no baseline never fail the gate, but they must be
    // *visible* in the same per-entry delta format as everything else: a
    // bench that was renamed during a baseline refresh shows up here as
    // "baseline orphaned" (its old name regresses as MISSING above), so
    // the rename stays auditable from the CI log instead of silently
    // passing as a brand-new entry
    let mut fresh = 0usize;
    for c in current {
        if !baseline.iter().any(|b| b.name == c.name) {
            fresh += 1;
            println!(
                "{:<28} baseline orphaned        now {:>8.2} {u}  ok",
                c.name,
                c.value,
                u = c.unit,
            );
        }
    }
    if fresh > 0 {
        println!(
            "{fresh} entr{} without a baseline (new bench or rename) — \
             refresh the baseline to start gating {}",
            if fresh == 1 { "y" } else { "ies" },
            if fresh == 1 { "it" } else { "them" }
        );
    }
    regressed
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_sane() {
        let t = time_fn(1, 5, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert_eq!(t.iters, 5);
        assert!(t.min_ms <= t.median_ms);
        assert!(t.min_ms <= t.mean_ms * 1.001);
    }

    #[test]
    fn auto_clamps() {
        let t = time_auto(5.0, || {
            std::hint::black_box((0..100).sum::<usize>());
        });
        assert!(t.iters >= 3 && t.iters <= 1000);
    }

    #[test]
    fn table_prints() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["1".into(), "2".into()]);
        t.print();
    }

    #[test]
    fn report_roundtrip_and_diff() {
        let dir = std::env::temp_dir().join("chon_bench_report_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("perf.json");
        let entries = vec![
            BenchEntry::ms("matmul", 2.0),
            BenchEntry::ms("quant", 1.0),
            BenchEntry::val("tab2/chon/final_loss", 2.5, "loss"),
        ];
        write_report(&p, "perf", &entries).unwrap();
        let back = read_report(&p).unwrap();
        assert_eq!(back, entries);

        // within tolerance (non-ms entries diff the same way)
        let cur = vec![
            BenchEntry::ms("matmul", 2.2),
            BenchEntry::ms("quant", 0.9),
            BenchEntry::val("tab2/chon/final_loss", 2.6, "loss"),
        ];
        assert!(diff_reports(&entries, &cur, 25.0).is_empty());
        // one regression + two missing entries
        let cur = vec![BenchEntry::ms("matmul", 3.0)];
        let bad = diff_reports(&entries, &cur, 25.0);
        assert_eq!(
            bad,
            vec![
                "matmul".to_string(),
                "quant".to_string(),
                "tab2/chon/final_loss".to_string()
            ]
        );
        // a unit change is never silently compared
        let cur = vec![BenchEntry::val("matmul", 2.0, "loss")];
        let bad = diff_reports(&entries[..1], &cur, 25.0);
        assert_eq!(bad, vec!["matmul".to_string()]);
        // entries with no baseline (new bench, or the new name of a
        // rename) are reported as "baseline orphaned" but never regress
        // the gate
        let cur = vec![BenchEntry::ms("matmul", 2.0), BenchEntry::ms("brand_new", 9.0)];
        assert!(diff_reports(&entries[..1], &cur, 25.0).is_empty());
    }

    #[test]
    fn report_rejects_wrong_schema() {
        let dir = std::env::temp_dir().join("chon_bench_report_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("bad.json");
        std::fs::write(&p, "{\"schema_version\": 99, \"results\": []}").unwrap();
        assert!(read_report(&p).is_err());
        // v1 reports (median_ms, no value field) are rejected, not
        // misread — the baseline refresh path covers migration
        std::fs::write(
            &p,
            "{\"schema_version\": 1, \"results\": [{\"name\": \"m\", \"median_ms\": 2}]}",
        )
        .unwrap();
        assert!(read_report(&p).is_err());
        std::fs::write(&p, "not json").unwrap();
        assert!(read_report(&p).is_err());
    }
}
