//! Pre-fuse vs post-fuse HCP pipelines with per-stage timing — the Tab. 5
//! efficiency experiment.
//!
//! Pre-fuse mirrors the unfused Triton pipeline: dequantize, gather,
//! residual and concat run as separate passes over memory. Post-fuse is
//! the fused kernel: one pass computes residual+gather+concat directly
//! into the expanded operand buffers (the paper's fused Triton kernel).

use std::time::Instant;

use crate::quant::nvfp4::{self, Rounding};
use crate::util::ndarray::Mat;

/// Per-stage wall-clock of one pre-fuse pipeline run (milliseconds).
#[derive(Clone, Copy, Debug, Default)]
pub struct StageTimes {
    pub dequant_ms: f64,
    pub gather_ms: f64,
    pub residual_ms: f64,
    pub concat_ms: f64,
}

impl StageTimes {
    pub fn sum_ms(&self) -> f64 {
        self.dequant_ms + self.gather_ms + self.residual_ms + self.concat_ms
    }
}

fn ms(t: Instant) -> f64 {
    t.elapsed().as_secs_f64() * 1e3
}

/// Pre-fuse: each CHON operation as its own memory pass (Tab. 5 columns
/// Deq. / Gthr. / Resid. / Cat.). Returns expanded operands + timings.
pub fn prefuse(x: &Mat, w: &Mat, idx: &[usize]) -> (Mat, Mat, StageTimes) {
    let mut st = StageTimes::default();

    // Pass 1: quantize + dequantize (storage roundtrip, like the real
    // kernel which reads FP4 codes and expands to compute precision).
    let t = Instant::now();
    let xq_store = nvfp4::quantize(&x.data, Rounding::Rtn, None);
    let wq_store = nvfp4::quantize(&w.data, Rounding::Rtn, None);
    let xq = Mat::from_vec(x.rows, x.cols, nvfp4::dequantize(&xq_store));
    let wq = Mat::from_vec(w.rows, w.cols, nvfp4::dequantize(&wq_store));
    st.dequant_ms = ms(t);

    // Pass 2: residuals
    let t = Instant::now();
    let dx = x.sub(&xq);
    let dw = w.sub(&wq);
    st.residual_ms = ms(t);

    // Pass 3: gathers
    let t = Instant::now();
    let dxg = dx.gather_cols(idx);
    let xqg = xq.gather_cols(idx);
    let wqg = wq.gather_rows(idx);
    let dwg = dw.gather_rows(idx);
    st.gather_ms = ms(t);

    // Pass 4: concat
    let t = Instant::now();
    let x_out = xq.hcat(&dxg).hcat(&xqg);
    let w_out = wq.vcat(&wqg).vcat(&dwg);
    st.concat_ms = ms(t);

    (x_out, w_out, st)
}

/// Post-fuse: one pass writes quantized values, residuals and the gathered
/// patch columns straight into the pre-sized expanded buffers.
pub fn postfuse(x: &Mat, w: &Mat, idx: &[usize]) -> (Mat, Mat, f64) {
    let t = Instant::now();
    let k = idx.len();
    // position of each hot channel in the patch (channel -> patch slot)
    let mut slot = vec![usize::MAX; x.cols];
    for (j, &c) in idx.iter().enumerate() {
        slot[c] = j;
    }

    // X side: [X̂ | ΔX_I | X̂_I] built in one traversal of x.
    let xcols = x.cols + 2 * k;
    let mut x_out = Mat::zeros(x.rows, xcols);
    let xq_flat = nvfp4::fake_quant(&x.data, Rounding::Rtn, None);
    for r in 0..x.rows {
        let src = &x.data[r * x.cols..(r + 1) * x.cols];
        let q = &xq_flat[r * x.cols..(r + 1) * x.cols];
        let dst = x_out.row_mut(r);
        for c in 0..src.len() {
            let qv = q[c];
            dst[c] = qv;
            let s = slot[c];
            if s != usize::MAX {
                dst[x.cols + s] = src[c] - qv; // ΔX_I
                dst[x.cols + k + s] = qv; // X̂_I
            }
        }
    }

    // W side: [Ŵ ; Ŵ_I ; ΔW_I] in one traversal of w.
    let wrows = w.rows + 2 * k;
    let mut w_out = Mat::zeros(wrows, w.cols);
    let wq_flat = nvfp4::fake_quant(&w.data, Rounding::Rtn, None);
    for r in 0..w.rows {
        let src = &w.data[r * w.cols..(r + 1) * w.cols];
        let q = &wq_flat[r * w.cols..(r + 1) * w.cols];
        w_out.row_mut(r).copy_from_slice(q);
        let s = slot[r];
        if s != usize::MAX {
            for c in 0..w.cols {
                *w_out.at_mut(w.rows + s, c) = q[c]; // Ŵ_I
                *w_out.at_mut(w.rows + k + s, c) = src[c] - q[c]; // ΔW_I
            }
        }
    }
    (x_out, w_out, ms(t))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn prefuse_and_postfuse_agree() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(32, 64, |_, _| rng.normal() * 2.0);
        let w = Mat::from_fn(64, 32, |_, _| rng.normal());
        let idx = vec![3usize, 17, 40];
        let (xa, wa, _) = prefuse(&x, &w, &idx);
        let (xb, wb, _) = postfuse(&x, &w, &idx);
        assert_eq!((xa.rows, xa.cols), (xb.rows, xb.cols));
        assert_eq!((wa.rows, wa.cols), (wb.rows, wb.cols));
        for (a, b) in xa.data.iter().zip(&xb.data) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        for (a, b) in wa.data.iter().zip(&wb.data) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn postfuse_faster_or_comparable() {
        // structural check only: both produce the same output; wall-clock
        // assertions live in the bench, not in unit tests.
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(64, 128, |_, _| rng.normal());
        let w = Mat::from_fn(128, 64, |_, _| rng.normal());
        let idx: Vec<usize> = (0..12).map(|i| i * 10).collect();
        let (_, _, st) = prefuse(&x, &w, &idx);
        let (_, _, fused_ms) = postfuse(&x, &w, &idx);
        assert!(st.sum_ms() > 0.0 && fused_ms > 0.0);
    }
}
