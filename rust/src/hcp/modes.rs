//! The Tab. 4 configuration taxonomy: Mode (S/D) × Order (O1/O2) ×
//! Target (W/A/B), with the quantized patched matmul for each.
//!
//! Numerics follow App. A:
//!   baseline : Ŷ = ŴᵀX̂                         (Lemma A.3)
//!   O1-A     : + Ŵ_Iᵀ ΔX_I                      (Lemma A.4, act patch)
//!   O1-W     : + ΔW_Iᵀ X̂_I                      (symmetric weight patch)
//!   O2-B     : + Ŵ_Iᵀ ΔX_I + ΔW_Iᵀ X̂_I          (Lemma A.5 — residual
//!              error collapses to ΔW_IᵀΔX_I on I)
//!
//! S (single-kernel) materializes the concatenated operands and runs ONE
//! GEMM (Alg. 1's concat trick); D (dual-kernel) runs base + correction
//! GEMMs separately. Both produce identical values (property-tested);
//! they differ in kernel structure and therefore in Tab. 5 overhead.

use crate::quant::nvfp4;
use crate::util::ndarray::{matmul, matmul_into, Mat};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Mode {
    Single,
    Dual,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    O1,
    O2,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Target {
    Weight,
    Activation,
    Both,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct HcpConfig {
    pub mode: Mode,
    pub order: Order,
    pub target: Target,
}

impl HcpConfig {
    /// The six named configurations of Tab. 4.
    pub fn taxonomy() -> Vec<(&'static str, HcpConfig)> {
        use Mode::*;
        use Order::*;
        use Target::*;
        vec![
            ("S-O1-W", HcpConfig { mode: Single, order: O1, target: Weight }),
            ("S-O1-A", HcpConfig { mode: Single, order: O1, target: Activation }),
            ("D-O1-W", HcpConfig { mode: Dual, order: O1, target: Weight }),
            ("D-O1-A", HcpConfig { mode: Dual, order: O1, target: Activation }),
            ("S-O2-B", HcpConfig { mode: Single, order: O2, target: Both }),
            ("D-O2-B", HcpConfig { mode: Dual, order: O2, target: Both }),
        ]
    }
}

/// Quantized operands + residuals for one linear (shared by all configs).
pub struct QuantizedPair {
    pub xq: Mat,
    pub wq: Mat,
    pub dx: Mat,
    pub dw: Mat,
}

impl QuantizedPair {
    pub fn new(x: &Mat, w: &Mat) -> Self {
        let xq = nvfp4::fake_quant_mat(x);
        let wq = nvfp4::fake_quant_mat_2d(w, 16);
        QuantizedPair { dx: x.sub(&xq), dw: w.sub(&wq), xq, wq }
    }
}

/// Baseline quantized product ŴᵀX̂ with no compensation.
pub fn baseline(q: &QuantizedPair) -> Mat {
    matmul(&q.xq, &q.wq)
}

/// Apply one HCP configuration over hot channels `idx`.
pub fn apply(cfg: HcpConfig, q: &QuantizedPair, idx: &[usize]) -> Mat {
    let patch_a = matches!(cfg.target, Target::Activation | Target::Both);
    let patch_w = matches!(cfg.target, Target::Weight | Target::Both);
    match cfg.mode {
        Mode::Single => {
            // Concatenate along the contraction dim: one logical GEMM.
            let mut lhs = q.xq.clone();
            let mut rhs = q.wq.clone();
            if patch_a {
                lhs = lhs.hcat(&q.dx.gather_cols(idx));
                rhs = rhs.vcat(&q.wq.gather_rows(idx));
            }
            if patch_w {
                lhs = lhs.hcat(&q.xq.gather_cols(idx));
                rhs = rhs.vcat(&q.dw.gather_rows(idx));
            }
            matmul(&lhs, &rhs)
        }
        Mode::Dual => {
            let mut out = baseline(q);
            if patch_a {
                matmul_into(
                    &q.dx.gather_cols(idx),
                    &q.wq.gather_rows(idx),
                    &mut out,
                    true,
                );
            }
            if patch_w {
                matmul_into(
                    &q.xq.gather_cols(idx),
                    &q.dw.gather_rows(idx),
                    &mut out,
                    true,
                );
            }
            out
        }
    }
}

/// Full patched matmul: quantize, select hot channels, compensate.
/// Returns (output, hot channel indices).
pub fn hcp_matmul(x: &Mat, w: &Mat, k: usize, cfg: HcpConfig) -> (Mat, Vec<usize>) {
    let q = QuantizedPair::new(x, w);
    let idx = super::top_k(&super::scores(&q.dx, &q.dw), k);
    (apply(cfg, &q, &idx), idx)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn pair(m: usize, k: usize, n: usize, seed: u64) -> (Mat, Mat) {
        let mut rng = Rng::new(seed);
        let x = Mat::from_fn(m, k, |_, _| rng.normal() * 2.0);
        let w = Mat::from_fn(k, n, |_, _| rng.normal());
        (x, w)
    }

    #[test]
    fn single_and_dual_agree() {
        let (x, w) = pair(16, 64, 32, 1);
        let q = QuantizedPair::new(&x, &w);
        let idx = crate::hcp::top_k(&crate::hcp::scores(&q.dx, &q.dw), 8);
        for (name, cfg) in HcpConfig::taxonomy() {
            let other = HcpConfig {
                mode: if cfg.mode == Mode::Single { Mode::Dual } else { Mode::Single },
                ..cfg
            };
            let a = apply(cfg, &q, &idx);
            let b = apply(other, &q, &idx);
            for (u, v) in a.data.iter().zip(&b.data) {
                assert!((u - v).abs() < 1e-3, "{name}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn o2_b_beats_baseline_and_single_sided() {
        let (x, w) = pair(32, 128, 64, 2);
        let truth = matmul(&x, &w);
        let q = QuantizedPair::new(&x, &w);
        let idx: Vec<usize> = (0..128).collect(); // full patch -> lemma regime
        let mse = |m: &Mat| m.mse(&truth);
        let base = mse(&baseline(&q));
        let o1a = mse(&apply(
            HcpConfig { mode: Mode::Single, order: Order::O1, target: Target::Activation },
            &q,
            &idx,
        ));
        let o1w = mse(&apply(
            HcpConfig { mode: Mode::Single, order: Order::O1, target: Target::Weight },
            &q,
            &idx,
        ));
        let o2b = mse(&apply(
            HcpConfig { mode: Mode::Single, order: Order::O2, target: Target::Both },
            &q,
            &idx,
        ));
        assert!(o2b < o1a && o2b < o1w, "o2b {o2b} o1a {o1a} o1w {o1w}");
        assert!(o1a < base && o1w < base, "base {base}");
    }

    #[test]
    fn full_patch_equals_second_order_identity() {
        // Eq. (3): full-I patch == WᵀX - ΔWᵀΔX
        let (x, w) = pair(8, 32, 16, 3);
        let q = QuantizedPair::new(&x, &w);
        let idx: Vec<usize> = (0..32).collect();
        let got = apply(
            HcpConfig { mode: Mode::Single, order: Order::O2, target: Target::Both },
            &q,
            &idx,
        );
        let mut want = matmul(&x, &w);
        let corr = matmul(&q.dx, &q.dw);
        for (a, b) in want.data.iter_mut().zip(&corr.data) {
            *a -= b;
        }
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-3, "{a} vs {b}");
        }
    }

    #[test]
    fn mse_monotone_in_patch_size() {
        let (x, w) = pair(32, 128, 32, 4);
        let truth = matmul(&x, &w);
        let cfg = HcpConfig { mode: Mode::Single, order: Order::O2, target: Target::Both };
        let mut prev = f64::INFINITY;
        for k in [0usize, 8, 32, 128] {
            let (y, _) = hcp_matmul(&x, &w, k, cfg);
            let e = y.mse(&truth);
            assert!(e <= prev * 1.001, "k={k}: {e} vs {prev}");
            prev = e;
        }
    }
}
