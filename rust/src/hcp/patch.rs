//! Alg. 1 — Hot-Channel Patch operand expansion.
//!
//! Left panel (normal process): quantize, compute residuals, score,
//! select top-k, gather, concat. Right panel (pre-computed indices):
//! skip scoring/selection, reuse a cached index set — valid because hot
//! channels are persistent in mid/late training (Sec. 3.3).

use crate::quant::nvfp4;
use crate::util::ndarray::Mat;

/// Expanded operands ready for one concatenated GEMM: Y = X_out · W_out.
pub struct Expanded {
    /// (M, K + 2k): [X̂ | ΔX_I | X̂_I]
    pub x_out: Mat,
    /// (K + 2k, N): [Ŵ ; Ŵ_I ; ΔW_I]
    pub w_out: Mat,
    /// the hot-channel index set used
    pub idx: Vec<usize>,
}

/// Alg. 1 left: full pipeline with fresh scoring + selection.
pub fn expand(x: &Mat, w: &Mat, k: usize) -> Expanded {
    // 1. Quantization & Dequantization
    let xq = nvfp4::fake_quant_mat(x);
    let wq = nvfp4::fake_quant_mat_2d(w, 16);
    // 2. Residual computation
    let dx = x.sub(&xq);
    let dw = w.sub(&wq);
    // 3. Scoring & selection (top-k)
    let idx = super::top_k(&super::scores(&dx, &dw), k);
    // 4–5. Gather + concat
    expand_gathered(&xq, &wq, &dx, &dw, idx)
}

/// Alg. 1 right: reuse pre-computed indices (skips scoring entirely).
pub fn expand_with_indices(x: &Mat, w: &Mat, idx: &[usize]) -> Expanded {
    let xq = nvfp4::fake_quant_mat(x);
    let wq = nvfp4::fake_quant_mat_2d(w, 16);
    let dx = x.sub(&xq);
    let dw = w.sub(&wq);
    expand_gathered(&xq, &wq, &dx, &dw, idx.to_vec())
}

fn expand_gathered(xq: &Mat, wq: &Mat, dx: &Mat, dw: &Mat, idx: Vec<usize>) -> Expanded {
    let x_out = xq.hcat(&dx.gather_cols(&idx)).hcat(&xq.gather_cols(&idx));
    let w_out = wq.vcat(&wq.gather_rows(&idx)).vcat(&dw.gather_rows(&idx));
    Expanded { x_out, w_out, idx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hcp::modes::{apply, HcpConfig, Mode, Order, QuantizedPair, Target};
    use crate::util::ndarray::matmul;
    use crate::util::prng::Rng;

    #[test]
    fn expanded_gemm_equals_s_o2_b() {
        let mut rng = Rng::new(1);
        let x = Mat::from_fn(16, 64, |_, _| rng.normal() * 2.0);
        let w = Mat::from_fn(64, 32, |_, _| rng.normal());
        let e = expand(&x, &w, 8);
        let y = matmul(&e.x_out, &e.w_out);
        let q = QuantizedPair::new(&x, &w);
        let want = apply(
            HcpConfig { mode: Mode::Single, order: Order::O2, target: Target::Both },
            &q,
            &e.idx,
        );
        for (a, b) in y.data.iter().zip(&want.data) {
            assert!((a - b).abs() < 1e-3);
        }
    }

    #[test]
    fn precomputed_indices_match_fresh_when_stationary() {
        let mut rng = Rng::new(2);
        let x = Mat::from_fn(16, 64, |_, _| rng.normal());
        let w = Mat::from_fn(64, 16, |_, _| rng.normal());
        let fresh = expand(&x, &w, 6);
        let cached = expand_with_indices(&x, &w, &fresh.idx);
        assert_eq!(fresh.idx, cached.idx);
        assert_eq!(fresh.x_out.data, cached.x_out.data);
        assert_eq!(fresh.w_out.data, cached.w_out.data);
    }

    #[test]
    fn expansion_shapes() {
        let mut rng = Rng::new(3);
        let x = Mat::from_fn(8, 32, |_, _| rng.normal());
        let w = Mat::from_fn(32, 48, |_, _| rng.normal());
        let e = expand(&x, &w, 4);
        assert_eq!((e.x_out.rows, e.x_out.cols), (8, 32 + 8));
        assert_eq!((e.w_out.rows, e.w_out.cols), (32 + 8, 48));
    }
}
