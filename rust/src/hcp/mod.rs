//! Hot-Channel Patch engine (Sec. 4, App. A/B, Alg. 1).
//!
//! * scoring + top-k selection (Eq. 2/6)
//! * the six compensation configurations of Tab. 4 (S/D × O1/O2 × W/A/B)
//! * Alg. 1 both variants: fresh selection vs pre-computed indices
//! * the pre-fuse vs post-fuse kernel pipelines benchmarked in Tab. 5

pub mod modes;
pub mod patch;
pub mod pipeline;

use crate::util::ndarray::Mat;

/// Channel importance score, Eq. (2): s_j = mean|ΔX_:,j| + mean|ΔW_j,:|.
///
/// dx: (M, K) activation residual (channels along columns);
/// dw: (K, N) weight residual (channels along rows). Returns K scores.
pub fn scores(dx: &Mat, dw: &Mat) -> Vec<f64> {
    assert_eq!(dx.cols, dw.rows);
    let k = dx.cols;
    let mut s = vec![0.0f64; k];
    for r in 0..dx.rows {
        let row = dx.row(r);
        for (j, &v) in row.iter().enumerate() {
            s[j] += v.abs() as f64;
        }
    }
    for v in s.iter_mut() {
        *v /= dx.rows as f64;
    }
    for j in 0..k {
        let row = dw.row(j);
        let m: f64 = row.iter().map(|&v| v.abs() as f64).sum::<f64>() / dw.cols as f64;
        s[j] += m;
    }
    s
}

/// Indices of the k largest scores (stable: ties broken by lower index).
pub fn top_k(scores: &[f64], k: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..scores.len()).collect();
    idx.sort_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k.min(scores.len()));
    idx
}

/// Select hot channels from quantization residuals (Alg. 1 steps 1–3).
pub fn select_hot_channels(x: &Mat, w: &Mat, k: usize) -> Vec<usize> {
    let xq = crate::quant::nvfp4::fake_quant_mat(x);
    let wq = crate::quant::nvfp4::fake_quant_mat_2d(w, 16);
    let dx = x.sub(&xq);
    let dw = w.sub(&wq);
    top_k(&scores(&dx, &dw), k)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    #[test]
    fn scores_shape_and_positivity() {
        let mut rng = Rng::new(1);
        let dx = Mat::from_fn(8, 16, |_, _| rng.normal());
        let dw = Mat::from_fn(16, 4, |_, _| rng.normal());
        let s = scores(&dx, &dw);
        assert_eq!(s.len(), 16);
        assert!(s.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn top_k_orders_and_truncates() {
        let s = vec![1.0, 5.0, 3.0, 5.0, 0.0];
        assert_eq!(top_k(&s, 3), vec![1, 3, 2]); // tie 1 vs 3 -> lower first
        assert_eq!(top_k(&s, 99).len(), 5);
    }

    #[test]
    fn finds_planted_channels() {
        let mut rng = Rng::new(2);
        let mut x = Mat::from_fn(64, 128, |_, _| rng.normal());
        let mut w = Mat::from_fn(128, 32, |_, _| rng.normal());
        for r in 0..x.rows {
            *x.at_mut(r, 77) *= 80.0;
        }
        for c in 0..w.cols {
            *w.at_mut(13, c) *= 60.0;
        }
        let idx = select_hot_channels(&x, &w, 4);
        assert!(idx.contains(&77), "activation channel found: {idx:?}");
        assert!(idx.contains(&13), "weight channel found: {idx:?}");
    }
}
