//! The scenario registry: named deterministic / stochastic / chaos
//! workloads, each of which spawns a supervised `chon serve` process,
//! drives a seeded request schedule against it, and reports a
//! [`ScenarioResult`].
//!
//! Reproducibility contract: a schedule is a pure function of the run
//! seed — two runs at the same seed generate byte-identical request
//! lists (pinned by `schedule_digest` in the summary). Stochastic
//! scenarios are stochastic in *shape* (Poisson arrivals, ragged prompt
//! lengths), not in reproducibility.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::loadtest::proc::{run_tool, ServeSpec, ServerProc};
use crate::loadtest::resources::Usage;
use crate::loadtest::scrape;
use crate::loadtest::summary::ScenarioResult;
use crate::obs::metrics::HistSnapshot;
use crate::serve::client::{self, LoadReport};
use crate::serve::protocol;
use crate::util::prng::{splitmix64, Rng};

const HOST: &str = "127.0.0.1";

/// Everything a scenario needs to run.
#[derive(Clone, Debug)]
pub struct Ctx {
    /// the release `chon` binary to spawn servers (and republishes) with
    pub bin: PathBuf,
    /// checkpoint root (parent dir; highest step wins at load)
    pub ckpt: PathBuf,
    /// per-scenario scratch + log directory
    pub out: PathBuf,
    pub seed: u64,
    pub quick: bool,
    /// artificial per-request latency (ms) added client-side — the
    /// SLO-gate validation hook: CI injects this to prove `--check`
    /// actually fails on a regression. 0 in real runs.
    pub inject_latency_ms: u64,
    /// model/recipe names matching the checkpoint (hot-reload republish)
    pub model: String,
    pub recipe: String,
}

impl Ctx {
    /// Scale a workload knob by mode.
    fn n(&self, quick: usize, full: usize) -> usize {
        if self.quick {
            quick
        } else {
            full
        }
    }

    /// Per-scenario seeded stream, independent across scenario names.
    fn rng(&self, name: &str) -> Rng {
        let mut h = 0xcbf2_9ce4_8422_2325u64; // FNV-1a over the name
        for b in name.bytes() {
            h = (h ^ b as u64).wrapping_mul(0x1_0000_01b3);
        }
        Rng::new(self.seed).fold_in(h)
    }
}

/// One scheduled request.
#[derive(Clone, Debug)]
pub struct Req {
    /// when to send, µs after the workload starts
    pub at_us: u64,
    pub prompt: String,
    pub max_tokens: usize,
    /// registry model to route to (None = server default)
    pub model: Option<String>,
    /// named session (SGEN) — pinned to one worker so turns stay ordered
    pub session: Option<String>,
}

/// A full request schedule plus how many workers replay it.
#[derive(Clone, Debug)]
pub struct Schedule {
    pub reqs: Vec<Req>,
    pub workers: usize,
}

/// Order-sensitive 64-bit digest (splitmix64 chaining). Not crypto —
/// just enough to pin "same seed, same schedule" in the summary.
struct Digest(u64);

impl Digest {
    fn new() -> Digest {
        Digest(0x9E37_79B9_7F4A_7C15)
    }

    fn fold(&mut self, x: u64) {
        let mut s = self.0 ^ x.wrapping_mul(0xA076_1D64_78BD_642F);
        self.0 = splitmix64(&mut s);
    }

    fn fold_bytes(&mut self, b: &[u8]) {
        self.fold(b.len() as u64);
        for chunk in b.chunks(8) {
            let mut buf = [0u8; 8];
            buf[..chunk.len()].copy_from_slice(chunk);
            self.fold(u64::from_le_bytes(buf));
        }
    }

    fn fold_opt(&mut self, o: Option<&str>) {
        match o {
            None => self.fold(0),
            Some(s) => {
                self.fold(1);
                self.fold_bytes(s.as_bytes());
            }
        }
    }
}

impl Schedule {
    /// Digest every field of every request (and the worker count):
    /// two schedules digest equal iff they replay identically.
    pub fn digest(&self) -> u64 {
        let mut d = Digest::new();
        d.fold(self.reqs.len() as u64);
        d.fold(self.workers as u64);
        for r in &self.reqs {
            d.fold(r.at_us);
            d.fold(r.max_tokens as u64);
            d.fold_bytes(r.prompt.as_bytes());
            d.fold_opt(r.model.as_deref());
            d.fold_opt(r.session.as_deref());
        }
        d.0
    }
}

/// Small word pool for synthetic prompts (byte-level models only care
/// about length mix, not vocabulary).
const WORDS: &[&str] = &[
    "the", "of", "and", "to", "in", "is", "was", "for", "on", "as", "with",
    "his", "they", "at", "be", "this", "have", "from", "or", "one", "had",
    "by", "word", "but",
];

fn prompt_words(rng: &mut Rng, n: usize) -> String {
    let mut out = String::new();
    for _ in 0..n.max(1) {
        out.push_str(WORDS[rng.below(WORDS.len())]);
        out.push(' ');
        if out.len() + 8 > protocol::MAX_PROMPT_BYTES {
            break;
        }
    }
    out
}

/// Exponential inter-arrival sample in µs (Poisson process of mean
/// `mean_us`), from the full-width uniform (f32 `uniform()` has too few
/// bits for a clean tail).
fn exp_us(rng: &mut Rng, mean_us: f64) -> u64 {
    let u = (rng.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
    (-(1.0 - u).ln() * mean_us) as u64
}

/// Seeded Poisson-arrival GEN schedule — public because the bench suite
/// times schedule generation + digesting, and the harness tests pin its
/// determinism.
pub fn poisson_schedule(seed: u64, n: usize, mean_us: f64, workers: usize) -> Schedule {
    let mut rng = Rng::new(seed).fold_in(0x1077);
    let mut at = 0u64;
    let mut reqs = Vec::with_capacity(n);
    for _ in 0..n {
        at += exp_us(&mut rng, mean_us);
        let words = 1 + rng.below(6);
        reqs.push(Req {
            at_us: at,
            prompt: prompt_words(&mut rng, words),
            max_tokens: 6,
            model: None,
            session: None,
        });
    }
    Schedule { reqs, workers }
}

/// Per-request outcome inside a worker.
enum Outcome {
    Done { tokens: usize, ms: f64 },
    Empty,
    Fail(String),
}

fn session_worker(id: &str, workers: usize) -> usize {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in id.bytes() {
        h = (h ^ b as u64).wrapping_mul(0x1_0000_01b3);
    }
    (h % workers as u64) as usize
}

/// Replay a schedule against a live server: requests partition across
/// workers (sessions pinned by id hash so a session's turns never race
/// the server's busy-session rejection; sessionless requests
/// round-robin), each worker holds one persistent connection and
/// reconnects after a failure. Returns the merged report plus the first
/// error string (diagnostics — per-request failures are already counted
/// in the report).
pub fn run_workload(
    port: u16,
    schedule: &Schedule,
    inject_latency_ms: u64,
) -> (LoadReport, Option<String>) {
    let workers = schedule.workers.clamp(1, schedule.reqs.len().max(1));
    let mut parts: Vec<Vec<&Req>> = vec![Vec::new(); workers];
    let mut rr = 0usize;
    for r in &schedule.reqs {
        let w = match &r.session {
            Some(id) => session_worker(id, workers),
            None => {
                rr += 1;
                (rr - 1) % workers
            }
        };
        parts[w].push(r);
    }

    let t0 = Instant::now();
    let mut per_worker: Vec<Vec<(Option<String>, Outcome)>> = Vec::new();
    std::thread::scope(|s| {
        let mut handles = Vec::new();
        for list in &parts {
            handles.push(s.spawn(move || {
                let mut conn: Option<std::net::TcpStream> = None;
                let mut out = Vec::with_capacity(list.len());
                for req in list {
                    let target = t0 + Duration::from_micros(req.at_us);
                    let now = Instant::now();
                    if target > now {
                        std::thread::sleep(target - now);
                    }
                    if conn.is_none() {
                        conn = client::open_conn(HOST, port).ok();
                    }
                    let Some(stream) = conn.as_mut() else {
                        out.push((
                            req.model.clone(),
                            Outcome::Fail("connect failed".into()),
                        ));
                        continue;
                    };
                    let res = match &req.session {
                        Some(sid) => client::generate_session_on_for(
                            stream,
                            req.model.as_deref(),
                            sid,
                            &req.prompt,
                            req.max_tokens,
                            0.0,
                        ),
                        None => client::generate_on_for(
                            stream,
                            req.model.as_deref(),
                            &req.prompt,
                            req.max_tokens,
                            0.0,
                        ),
                    };
                    let outcome = match res {
                        Ok((text, n, mut ms)) => {
                            if inject_latency_ms > 0 {
                                // gate-validation hook: a real latency
                                // regression, visible end to end
                                std::thread::sleep(Duration::from_millis(
                                    inject_latency_ms,
                                ));
                                ms += inject_latency_ms as f64;
                            }
                            if text.is_empty() || n == 0 {
                                Outcome::Empty
                            } else {
                                Outcome::Done { tokens: n.max(1), ms }
                            }
                        }
                        Err(e) => {
                            conn = None; // poisoned: reconnect next time
                            Outcome::Fail(format!("{e:#}"))
                        }
                    };
                    out.push((req.model.clone(), outcome));
                }
                out
            }));
        }
        for h in handles {
            per_worker.push(h.join().expect("workload worker panicked"));
        }
    });

    let mut report = LoadReport {
        wall_s: t0.elapsed().as_secs_f64(),
        ..Default::default()
    };
    let mut first_err = None;
    for outcomes in per_worker {
        for (model, o) in outcomes {
            match o {
                Outcome::Done { tokens, ms } => {
                    report.tokens += tokens;
                    report.latencies_ms.push(ms);
                    if let Some(m) = model {
                        report.by_model.entry(m).or_default().push(ms);
                    }
                }
                Outcome::Empty => report.empty_responses += 1,
                Outcome::Fail(e) => {
                    report.failures += 1;
                    first_err.get_or_insert(e);
                }
            }
        }
    }
    report.sort_latencies();
    (report, first_err)
}

// ---- shared scenario plumbing ----

fn default_spec(ctx: &Ctx) -> ServeSpec {
    ServeSpec {
        checkpoint: Some(ctx.ckpt.clone()),
        ..Default::default()
    }
}

fn spawn_server(ctx: &Ctx, name: &str, spec: &ServeSpec) -> Result<ServerProc> {
    ServerProc::spawn(&ctx.bin, spec, &ctx.out.join(format!("{name}_serve.log")))
}

/// Raw per-stage histograms off one `/metrics` scrape (models merged).
/// `from_parts` derives the quantiles; the harness keeps the snapshots
/// so `--repeats` can merge them across runs before re-quantiling.
fn stage_snapshots(body: &str) -> BTreeMap<String, HistSnapshot> {
    scrape::stage_histograms(body, "chon_stage_latency_us", "stage")
}

/// Poll a counter family's total until it reaches `min` or the timeout
/// passes; returns the last observed value either way.
fn wait_total(server: &ServerProc, family: &str, min: f64, timeout: Duration) -> f64 {
    let deadline = Instant::now() + timeout;
    let mut last = 0.0;
    loop {
        if let Ok(body) = server.scrape_metrics() {
            last = client::metric_total(&body, family).unwrap_or(0.0);
            if last >= min {
                return last;
            }
        }
        if Instant::now() >= deadline {
            return last;
        }
        std::thread::sleep(Duration::from_millis(100));
    }
}

/// Standard scenario epilogue: scrape stage histograms, stop the server
/// gracefully, collect its resource usage, assemble the result.
fn finish(
    name: &str,
    kind: &str,
    mut server: ServerProc,
    report: &LoadReport,
    digest: u64,
    first_err: Option<String>,
    mut checks: Vec<(String, bool)>,
) -> Result<ScenarioResult> {
    let stages = match server.scrape_metrics() {
        Ok(body) => stage_snapshots(&body),
        Err(_) => BTreeMap::new(),
    };
    if let Some(e) = first_err {
        // surface the first failure's text as a (failed) named check so
        // the summary says *what* broke, not just how many
        checks.push((format!("first-error: {e}"), false));
    }
    server.stop()?;
    let usage = server.usage();
    Ok(ScenarioResult::from_parts(
        name, kind, report, stages, &usage, digest, checks,
    ))
}

fn copy_dir(from: &Path, to: &Path) -> Result<()> {
    std::fs::create_dir_all(to)
        .with_context(|| format!("creating {}", to.display()))?;
    for entry in std::fs::read_dir(from)
        .with_context(|| format!("reading {}", from.display()))?
    {
        let entry = entry?;
        let src = entry.path();
        let dst = to.join(entry.file_name());
        if entry.file_type()?.is_dir() {
            copy_dir(&src, &dst)?;
        } else {
            std::fs::copy(&src, &dst)
                .with_context(|| format!("copying {}", src.display()))?;
        }
    }
    Ok(())
}

// ---- the scenarios ----

/// Deterministic fan-out/fan-in: every worker fires its burst at t=0,
/// all requests race through batching at once, all must come back.
fn run_fanout(ctx: &Ctx) -> Result<ScenarioResult> {
    let mut rng = ctx.rng("fanout");
    let workers = ctx.n(6, 16);
    let per = ctx.n(3, 6);
    let mut reqs = Vec::new();
    for _ in 0..workers * per {
        let words = 1 + rng.below(5);
        reqs.push(Req {
            at_us: 0,
            prompt: prompt_words(&mut rng, words),
            max_tokens: 6,
            model: None,
            session: None,
        });
    }
    let schedule = Schedule { reqs, workers };
    let digest = schedule.digest();
    let total = schedule.reqs.len() as f64;

    let server = spawn_server(ctx, "fanout", &default_spec(ctx))?;
    let (report, first_err) = run_workload(server.port, &schedule, ctx.inject_latency_ms);
    let served = wait_total(&server, "chon_requests_total", total, Duration::from_secs(5));
    let checks = vec![(format!("requests_total>={total}"), served >= total)];
    finish("fanout", "deterministic", server, &report, digest, first_err, checks)
}

/// Deterministic session churn: more named sessions than residency,
/// multiple turns each — the LRU must spill and reload under load.
fn run_churn(ctx: &Ctx) -> Result<ScenarioResult> {
    let mut rng = ctx.rng("churn");
    let sessions = ctx.n(4, 8);
    let turns = ctx.n(2, 3);
    let mut reqs = Vec::new();
    for t in 0..turns {
        for i in 0..sessions {
            let words = 1 + rng.below(4);
            reqs.push(Req {
                at_us: ((t * sessions + i) as u64) * 3_000,
                prompt: prompt_words(&mut rng, words),
                max_tokens: 5,
                model: None,
                session: Some(format!("churn_{i}")),
            });
        }
    }
    let schedule = Schedule { reqs, workers: 4 };
    let digest = schedule.digest();

    let spec = ServeSpec {
        max_resident_sessions: 2,
        spill_dir: Some(ctx.out.join("churn_spill")),
        ..default_spec(ctx)
    };
    let server = spawn_server(ctx, "churn", &spec)?;
    let (report, first_err) = run_workload(server.port, &schedule, ctx.inject_latency_ms);
    let ev = wait_total(&server, "chon_session_evictions_total", 1.0, Duration::from_secs(5));
    let rl = wait_total(&server, "chon_session_reloads_total", 1.0, Duration::from_secs(5));
    let checks = vec![
        ("evictions>0".to_string(), ev > 0.0),
        ("session_reloads>0".to_string(), rl > 0.0),
    ];
    finish("churn", "deterministic", server, &report, digest, first_err, checks)
}

/// Stochastic Poisson arrivals: seeded exponential inter-arrival gaps,
/// open-loop-ish replay across 8 workers.
fn run_poisson(ctx: &Ctx) -> Result<ScenarioResult> {
    let n = ctx.n(24, 96);
    let mean_us = if ctx.quick { 8_000.0 } else { 12_000.0 };
    let schedule = poisson_schedule(ctx.seed, n, mean_us, 8);
    let digest = schedule.digest();

    let server = spawn_server(ctx, "poisson", &default_spec(ctx))?;
    let (report, first_err) = run_workload(server.port, &schedule, ctx.inject_latency_ms);
    let served = wait_total(&server, "chon_requests_total", n as f64, Duration::from_secs(5));
    let checks = vec![(format!("requests_total>={n}"), served >= n as f64)];
    finish("poisson", "stochastic", server, &report, digest, first_err, checks)
}

/// Ragged prompt-length mix: the product-of-uniforms length law gives a
/// long tail (most prompts short, a few 100-word monsters), so prefill
/// group admission sees wildly uneven work.
fn run_ragged(ctx: &Ctx) -> Result<ScenarioResult> {
    let mut rng = ctx.rng("ragged");
    let n = ctx.n(16, 48);
    let mut reqs = Vec::new();
    for i in 0..n {
        let words = 1 + rng.below(11) * rng.below(11);
        let max_tokens = [4usize, 6, 12][rng.below(3)];
        reqs.push(Req {
            at_us: (i as u64) * 2_000,
            prompt: prompt_words(&mut rng, words),
            max_tokens,
            model: None,
            session: None,
        });
    }
    let schedule = Schedule { reqs, workers: 6 };
    let digest = schedule.digest();

    let server = spawn_server(ctx, "ragged", &default_spec(ctx))?;
    let (report, first_err) = run_workload(server.port, &schedule, ctx.inject_latency_ms);
    let served = wait_total(&server, "chon_requests_total", n as f64, Duration::from_secs(5));
    let checks = vec![(format!("requests_total>={n}"), served >= n as f64)];
    finish("ragged", "stochastic", server, &report, digest, first_err, checks)
}

/// Multi-model spray: two registry models (aliases of the same
/// checkpoint) take alternating traffic; per-model accounting must see
/// both.
fn run_spray(ctx: &Ctx) -> Result<ScenarioResult> {
    let mut rng = ctx.rng("spray");
    let n = ctx.n(16, 48);
    let mut reqs = Vec::new();
    for i in 0..n {
        let words = 1 + rng.below(5);
        let model = if i % 2 == 0 { "alpha" } else { "beta" };
        reqs.push(Req {
            at_us: (i as u64) * 1_500,
            prompt: prompt_words(&mut rng, words),
            max_tokens: 5,
            model: Some(model.to_string()),
            session: None,
        });
    }
    let schedule = Schedule { reqs, workers: 4 };
    let digest = schedule.digest();

    let spec = ServeSpec {
        checkpoint: None,
        models: vec![
            ("alpha".to_string(), ctx.ckpt.clone()),
            ("beta".to_string(), ctx.ckpt.clone()),
        ],
        ..Default::default()
    };
    let server = spawn_server(ctx, "spray", &spec)?;
    let (report, first_err) = run_workload(server.port, &schedule, ctx.inject_latency_ms);
    let body = server.scrape_metrics().unwrap_or_default();
    let alpha = client::metric_value(&body, "chon_requests_total{model=\"alpha\"}")
        .unwrap_or(0.0);
    let beta = client::metric_value(&body, "chon_requests_total{model=\"beta\"}")
        .unwrap_or(0.0);
    let checks = vec![
        ("alpha_requests>0".to_string(), alpha > 0.0),
        ("beta_requests>0".to_string(), beta > 0.0),
    ];
    finish("spray", "stochastic", server, &report, digest, first_err, checks)
}

/// Eviction storm: `--max-kv-tokens 1` makes every idle named session
/// over-budget (GLA session cost is its row count), so each turn spills
/// the previous session — disk churn as the steady state.
fn run_evict_storm(ctx: &Ctx) -> Result<ScenarioResult> {
    let mut rng = ctx.rng("evict_storm");
    let sessions = ctx.n(4, 8);
    let turns = ctx.n(2, 3);
    let mut reqs = Vec::new();
    for t in 0..turns {
        for i in 0..sessions {
            let words = 1 + rng.below(4);
            reqs.push(Req {
                at_us: ((t * sessions + i) as u64) * 2_000,
                prompt: prompt_words(&mut rng, words),
                max_tokens: 4,
                model: None,
                session: Some(format!("storm_{i}")),
            });
        }
    }
    let schedule = Schedule { reqs, workers: 4 };
    let digest = schedule.digest();

    let spec = ServeSpec {
        max_kv_tokens: 1,
        spill_dir: Some(ctx.out.join("storm_spill")),
        ..default_spec(ctx)
    };
    let server = spawn_server(ctx, "evict_storm", &spec)?;
    let (report, first_err) = run_workload(server.port, &schedule, ctx.inject_latency_ms);
    let ev = wait_total(
        &server,
        "chon_session_evictions_total",
        sessions as f64,
        Duration::from_secs(5),
    );
    let rl = wait_total(&server, "chon_session_reloads_total", 1.0, Duration::from_secs(5));
    let checks = vec![
        (format!("evictions>={sessions}"), ev >= sessions as f64),
        ("session_reloads>0".to_string(), rl > 0.0),
    ];
    finish("evict_storm", "chaos", server, &report, digest, first_err, checks)
}

/// Hot-reload under load: a republished checkpoint (a resumed `chon
/// train` into the same parent dir bumps the generation) must be picked
/// up by the reload probe while traffic flows, without failing requests.
fn run_reload_under_load(ctx: &Ctx) -> Result<ScenarioResult> {
    let mut rng = ctx.rng("reload");
    // private checkpoint copy: the republish must not touch the shared
    // checkpoint other scenarios serve from. Normalized to
    // parent-with-one-step layout (resolve handles leaf or parent input)
    // so the resumed train's higher-step sibling is what the server's
    // reload probe discovers.
    let leaf = crate::runtime::ckptdir::resolve(&ctx.ckpt)?;
    let ckpt = ctx.out.join("reload_ckpt");
    let leaf_name = leaf
        .file_name()
        .context("checkpoint dir has no basename")?
        .to_owned();
    copy_dir(&leaf, &ckpt.join(leaf_name))?;

    let n = ctx.n(12, 32);
    let mut reqs = Vec::new();
    for i in 0..n {
        let words = 1 + rng.below(4);
        reqs.push(Req {
            at_us: (i as u64) * 25_000, // ~25 ms apart: spans the republish
            prompt: prompt_words(&mut rng, words),
            max_tokens: 5,
            model: None,
            session: None,
        });
    }
    let schedule = Schedule { reqs, workers: 4 };
    let digest = schedule.digest();

    let spec = ServeSpec {
        checkpoint: Some(ckpt.clone()),
        reload_poll_ms: 50,
        ..Default::default()
    };
    let server = spawn_server(ctx, "reload", &spec)?;

    // traffic on a scoped thread while the republish runs in this one
    let port = server.port;
    let inject = ctx.inject_latency_ms;
    let mut report = LoadReport::default();
    let mut first_err = None;
    let mut republish = Ok(());
    std::thread::scope(|s| {
        let load = s.spawn(|| run_workload(port, &schedule, inject));
        republish = run_tool(
            &ctx.bin,
            &[
                "train".into(),
                "--steps".into(),
                "2".into(),
                "--model".into(),
                ctx.model.clone(),
                "--recipe".into(),
                ctx.recipe.clone(),
                "--seed".into(),
                ctx.seed.to_string(),
                "--resume".into(),
                ckpt.display().to_string(),
                "--checkpoint-dir".into(),
                ckpt.display().to_string(),
                "--out-dir".into(),
                ctx.out.join("reload_runs").display().to_string(),
                "--diag-every".into(),
                "0".into(),
                "--eval-every".into(),
                "0".into(),
                "--log-every".into(),
                "0".into(),
            ],
            &ctx.out.join("republish.log"),
        );
        (report, first_err) = load.join().expect("workload thread panicked");
    });
    republish.context("republishing checkpoint during load")?;

    // the 50 ms probe must notice the new generation
    let reloads = wait_total(
        &server,
        "chon_model_reloads_total",
        1.0,
        Duration::from_secs(10),
    );

    // post-reload burst: the reloaded engine answers traffic
    let mut post = Vec::new();
    for i in 0..4u64 {
        let words = 1 + rng.below(4);
        post.push(Req {
            at_us: i * 2_000,
            prompt: prompt_words(&mut rng, words),
            max_tokens: 5,
            model: None,
            session: None,
        });
    }
    let (post_report, post_err) =
        run_workload(server.port, &Schedule { reqs: post, workers: 2 }, inject);
    report.merge(&post_report);
    report.sort_latencies();
    first_err = first_err.or(post_err);

    let checks = vec![("model_reloads>0".to_string(), reloads > 0.0)];
    finish("reload", "chaos", server, &report, digest, first_err, checks)
}

/// Kill-and-resume mid-stream: SIGKILL the server while a generation is
/// streaming, restart it on the same checkpoint + spill dir, and require
/// a named session (spilled before the kill) to continue bit-identically
/// to an uninterrupted reference server.
fn run_kill_resume(ctx: &Ctx) -> Result<ScenarioResult> {
    let spill = ctx.out.join("kr_spill");
    let spec = ServeSpec {
        max_resident_sessions: 1,
        spill_dir: Some(spill),
        ..default_spec(ctx)
    };
    let p1 = "the quick brown ";
    let p2 = "and then the ";
    let (turn_tokens, stream_tokens) = (8, 64);
    let mut latencies: Vec<f64> = Vec::new();
    let mut checks: Vec<(String, bool)> = Vec::new();
    // fixed request sequence — digest it like any other schedule
    let digest = Schedule {
        reqs: vec![
            Req {
                at_us: 0,
                prompt: p1.into(),
                max_tokens: turn_tokens,
                model: None,
                session: Some("kr_a".into()),
            },
            Req {
                at_us: 0,
                prompt: p1.into(),
                max_tokens: turn_tokens,
                model: None,
                session: Some("kr_b".into()),
            },
            Req {
                at_us: 0,
                prompt: p2.into(),
                max_tokens: turn_tokens,
                model: None,
                session: Some("kr_a".into()),
            },
        ],
        workers: 1,
    }
    .digest();

    // --- incarnation 1: seed two sessions, force kr_a to spill ---
    let mut server1 = spawn_server(ctx, "kill_resume_1", &spec)?;
    let mut conn = client::open_conn(HOST, server1.port)?;
    let (a1, _, ms) =
        client::generate_session_on(&mut conn, "kr_a", p1, turn_tokens, 0.0)?;
    latencies.push(ms);
    let (_b1, _, ms) =
        client::generate_session_on(&mut conn, "kr_b", p1, turn_tokens, 0.0)?;
    latencies.push(ms);
    // kr_b's check-in evicts kr_a (residency 1); wait for the spill to
    // be *observable* before killing — a race here would SIGKILL the
    // server with kr_a still only in memory
    let ev = wait_total(
        &server1,
        "chon_session_evictions_total",
        1.0,
        Duration::from_secs(10),
    );
    checks.push(("spilled-before-kill".to_string(), ev >= 1.0));

    // --- SIGKILL mid-generation ---
    let mut raw = client::open_conn(HOST, server1.port)?;
    raw.write_all(
        protocol::format_gen_for(None, stream_tokens, 0.0, "some long stream ")
            .as_bytes(),
    )?;
    let mut reader = BufReader::new(raw.try_clone()?);
    let mut line = String::new();
    let mut toks = 0;
    while toks < 2 {
        line.clear();
        if reader.read_line(&mut line)? == 0 {
            bail!("stream ended before the kill point");
        }
        if line.starts_with("TOK ") {
            toks += 1;
        } else if line.starts_with("ERR ") {
            bail!("mid-stream request failed before kill: {line}");
        }
    }
    server1.kill_hard()?; // generation is provably mid-flight
    let mut usage = server1.usage();
    drop(server1);
    // the killed socket must surface the crash, not hang
    line.clear();
    let dead = reader.read_line(&mut line).map(|n| n == 0).unwrap_or(true);
    checks.push(("client-sees-crash".to_string(), dead));

    // --- incarnation 2: same checkpoint, same spill dir ---
    let mut server2 = spawn_server(ctx, "kill_resume_2", &spec)?;
    let mut conn2 = client::open_conn(HOST, server2.port)?;
    let (a2, _, ms) =
        client::generate_session_on(&mut conn2, "kr_a", p2, turn_tokens, 0.0)?;
    latencies.push(ms);
    let reloads = wait_total(
        &server2,
        "chon_session_reloads_total",
        1.0,
        Duration::from_secs(5),
    );
    checks.push(("session-reloaded-from-spill".to_string(), reloads >= 1.0));

    // --- reference: uninterrupted server, own spill dir ---
    let ref_spec = ServeSpec {
        spill_dir: Some(ctx.out.join("kr_ref_spill")),
        ..default_spec(ctx)
    };
    let mut reference = spawn_server(ctx, "kill_resume_ref", &ref_spec)?;
    let mut rconn = client::open_conn(HOST, reference.port)?;
    let (ra1, _, _) =
        client::generate_session_on(&mut rconn, "kr_a", p1, turn_tokens, 0.0)?;
    let (_rb1, _, _) =
        client::generate_session_on(&mut rconn, "kr_b", p1, turn_tokens, 0.0)?;
    let (ra2, _, _) =
        client::generate_session_on(&mut rconn, "kr_a", p2, turn_tokens, 0.0)?;
    reference.stop()?;
    checks.push(("turn1-identical".to_string(), a1 == ra1));
    checks.push(("resume-bit-identical".to_string(), a2 == ra2));

    // assemble by hand: this scenario's traffic is scripted, not a
    // Schedule replay, but the summary shape is the same
    let stages = match server2.scrape_metrics() {
        Ok(body) => stage_snapshots(&body),
        Err(_) => BTreeMap::new(),
    };
    server2.stop()?;
    usage.merge(&server2.usage());
    let mut report = LoadReport {
        latencies_ms: latencies,
        tokens: 3 * turn_tokens,
        ..Default::default()
    };
    report.wall_s = report.latencies_ms.iter().sum::<f64>() / 1e3;
    report.sort_latencies();
    // usage already merged across both incarnations
    Ok(ScenarioResult::from_parts(
        "kill_resume",
        "chaos",
        &report,
        stages,
        &usage,
        digest,
        checks,
    ))
}

/// One registered scenario.
pub struct Scenario {
    pub name: &'static str,
    /// "deterministic" | "stochastic" | "chaos"
    pub kind: &'static str,
    pub help: &'static str,
    pub run: fn(&Ctx) -> Result<ScenarioResult>,
}

/// Every scenario, in execution order.
pub fn registry() -> &'static [Scenario] {
    &[
        Scenario {
            name: "fanout",
            kind: "deterministic",
            help: "simultaneous burst from N workers, all must fan back in",
            run: run_fanout,
        },
        Scenario {
            name: "churn",
            kind: "deterministic",
            help: "more named sessions than residency: LRU spill + reload under load",
            run: run_churn,
        },
        Scenario {
            name: "poisson",
            kind: "stochastic",
            help: "seeded Poisson arrivals over 8 workers",
            run: run_poisson,
        },
        Scenario {
            name: "ragged",
            kind: "stochastic",
            help: "long-tail prompt-length mix with varied token budgets",
            run: run_ragged,
        },
        Scenario {
            name: "spray",
            kind: "stochastic",
            help: "multi-model spray across two registry models",
            run: run_spray,
        },
        Scenario {
            name: "evict_storm",
            kind: "chaos",
            help: "--max-kv-tokens 1: every idle session spills, every turn reloads",
            run: run_evict_storm,
        },
        Scenario {
            name: "reload",
            kind: "chaos",
            help: "checkpoint republished mid-traffic; hot reload must land",
            run: run_reload_under_load,
        },
        Scenario {
            name: "kill_resume",
            kind: "chaos",
            help: "SIGKILL mid-stream, restart, named session resumes bit-identically",
            run: run_kill_resume,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_schedule_is_seed_deterministic() {
        let a = poisson_schedule(7, 50, 10_000.0, 8);
        let b = poisson_schedule(7, 50, 10_000.0, 8);
        assert_eq!(a.digest(), b.digest());
        assert_eq!(a.reqs.len(), 50);
        for (x, y) in a.reqs.iter().zip(&b.reqs) {
            assert_eq!(x.at_us, y.at_us);
            assert_eq!(x.prompt, y.prompt);
        }
        let c = poisson_schedule(8, 50, 10_000.0, 8);
        assert_ne!(a.digest(), c.digest(), "different seed, different schedule");
        // arrivals move forward
        assert!(a.reqs.windows(2).all(|w| w[0].at_us <= w[1].at_us));
    }

    #[test]
    fn digest_sees_every_field() {
        let base = Schedule {
            reqs: vec![Req {
                at_us: 5,
                prompt: "the ".into(),
                max_tokens: 6,
                model: None,
                session: None,
            }],
            workers: 2,
        };
        let d0 = base.digest();
        let mut m = base.clone();
        m.reqs[0].at_us = 6;
        assert_ne!(m.digest(), d0);
        let mut m = base.clone();
        m.reqs[0].prompt = "the  ".into();
        assert_ne!(m.digest(), d0);
        let mut m = base.clone();
        m.reqs[0].max_tokens = 7;
        assert_ne!(m.digest(), d0);
        let mut m = base.clone();
        m.reqs[0].model = Some("alpha".into());
        assert_ne!(m.digest(), d0);
        let mut m = base.clone();
        m.reqs[0].session = Some("s".into());
        assert_ne!(m.digest(), d0);
        let mut m = base.clone();
        m.workers = 3;
        assert_ne!(m.digest(), d0);
        // None vs empty-string must differ (fold_opt tags presence)
        let mut m = base.clone();
        m.reqs[0].session = Some(String::new());
        assert_ne!(m.digest(), d0);
    }

    #[test]
    fn session_pinning_is_stable_and_in_range() {
        for workers in [1usize, 3, 8] {
            for id in ["churn_0", "churn_7", "kr_a", "x"] {
                let w = session_worker(id, workers);
                assert!(w < workers);
                assert_eq!(w, session_worker(id, workers));
            }
        }
    }

    #[test]
    fn prompts_respect_protocol_budget() {
        let mut rng = Rng::new(1);
        for _ in 0..200 {
            let p = prompt_words(&mut rng, 1 + rng.below(11) * rng.below(11));
            assert!(p.len() <= protocol::MAX_PROMPT_BYTES);
            assert!(!p.is_empty());
        }
    }

    #[test]
    fn exp_us_has_roughly_the_right_mean() {
        let mut rng = Rng::new(2);
        let n = 20_000;
        let mean = 10_000.0;
        let total: u64 = (0..n).map(|_| exp_us(&mut rng, mean)).sum();
        let got = total as f64 / n as f64;
        assert!((got - mean).abs() < mean * 0.05, "mean {got}");
    }

    #[test]
    fn registry_names_are_unique_and_kinds_valid() {
        let names: Vec<&str> = registry().iter().map(|s| s.name).collect();
        let mut dedup = names.clone();
        dedup.sort();
        dedup.dedup();
        assert_eq!(dedup.len(), names.len());
        for s in registry() {
            assert!(
                ["deterministic", "stochastic", "chaos"].contains(&s.kind),
                "{}: {}",
                s.name,
                s.kind
            );
        }
        // the ISSUE-mandated suite is all present
        for want in [
            "fanout", "churn", "poisson", "ragged", "spray", "evict_storm",
            "reload", "kill_resume",
        ] {
            assert!(names.contains(&want), "{want}");
        }
    }
}
