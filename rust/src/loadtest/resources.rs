//! `/proc/<pid>/{statm,stat}` resource sampling for supervised server
//! processes: peak RSS and cumulative CPU ticks, polled by a background
//! thread while a scenario runs.
//!
//! Linux-only by construction (the loadtest harness spawns Linux
//! processes and the CI runners are Linux); on a platform without
//! `/proc` the reads fail soft and the summary reports zeros instead of
//! the harness failing.

use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

/// Kernel page size. `/proc/<pid>/statm` reports pages; 4 KiB is the
/// x86-64/aarch64 default and the only configuration the harness runs
/// on. (sysconf is not reachable without libc bindings — a deliberate
/// zero-dependency tradeoff, documented here.)
const PAGE_BYTES: u64 = 4096;

/// One instantaneous reading of a process's resource usage.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct Reading {
    /// resident set size in bytes (statm field 1 × page size)
    pub rss_bytes: u64,
    /// cumulative CPU ticks, user + system (stat utime + stime)
    pub cpu_ticks: u64,
}

/// Parse the two fields we need out of raw `statm` + `stat` contents.
/// Split out from the `/proc` read so the parsing is unit-testable with
/// fixture strings.
pub fn parse_proc(statm: &str, stat: &str) -> Result<Reading> {
    // statm: "size resident shared text lib data dt" (pages)
    let resident: u64 = statm
        .split_whitespace()
        .nth(1)
        .context("statm missing resident field")?
        .parse()
        .context("statm resident field not a number")?;
    // stat: "pid (comm) state ppid ... utime stime ..." — comm may
    // contain spaces and parentheses, so field counting must start after
    // the LAST ')'. utime/stime are fields 14/15 of the documented
    // layout = whitespace fields 11/12 of the remainder.
    let after_comm = &stat[stat
        .rfind(')')
        .context("stat missing comm terminator")?
        + 1..];
    let fields: Vec<&str> = after_comm.split_whitespace().collect();
    let utime: u64 = fields
        .get(11)
        .context("stat missing utime")?
        .parse()
        .context("stat utime not a number")?;
    let stime: u64 = fields
        .get(12)
        .context("stat missing stime")?
        .parse()
        .context("stat stime not a number")?;
    Ok(Reading {
        rss_bytes: resident * PAGE_BYTES,
        cpu_ticks: utime + stime,
    })
}

/// Read one instantaneous usage snapshot of `pid` from `/proc`.
pub fn read_proc(pid: u32) -> Result<Reading> {
    let base = Path::new("/proc").join(pid.to_string());
    let statm = std::fs::read_to_string(base.join("statm"))
        .with_context(|| format!("reading /proc/{pid}/statm"))?;
    let stat = std::fs::read_to_string(base.join("stat"))
        .with_context(|| format!("reading /proc/{pid}/stat"))?;
    parse_proc(&statm, &stat)
}

/// Aggregated resource usage over one scenario (possibly across several
/// server incarnations — kill-and-resume merges the usage of both).
#[derive(Clone, Copy, Debug, Default)]
pub struct Usage {
    /// high-water resident set across all samples
    pub peak_rss_bytes: u64,
    /// CPU ticks consumed (last reading — ticks are cumulative per
    /// process, so the final sample is the total)
    pub cpu_ticks: u64,
    /// how many samples contributed (0 = /proc was unreadable)
    pub samples: u64,
}

impl Usage {
    /// Combine usage from another process incarnation: peaks take the
    /// max, ticks and sample counts add.
    pub fn merge(&mut self, other: &Usage) {
        self.peak_rss_bytes = self.peak_rss_bytes.max(other.peak_rss_bytes);
        self.cpu_ticks += other.cpu_ticks;
        self.samples += other.samples;
    }
}

/// Background sampler: polls `/proc/<pid>` every `period` and keeps the
/// running peak. `stop()` joins the thread and returns the aggregate.
pub struct Sampler {
    stop: Arc<AtomicBool>,
    acc: Arc<Mutex<Usage>>,
    handle: Option<std::thread::JoinHandle<()>>,
}

/// How often the sampler polls. Coarse enough to be free, fine enough
/// to catch an RSS spike that lasts a few batch cycles.
const SAMPLE_PERIOD: Duration = Duration::from_millis(25);

impl Sampler {
    pub fn spawn(pid: u32) -> Sampler {
        let stop = Arc::new(AtomicBool::new(false));
        let acc = Arc::new(Mutex::new(Usage::default()));
        let (stop2, acc2) = (stop.clone(), acc.clone());
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                if let Ok(r) = read_proc(pid) {
                    let mut u = acc2.lock().unwrap();
                    u.peak_rss_bytes = u.peak_rss_bytes.max(r.rss_bytes);
                    u.cpu_ticks = r.cpu_ticks;
                    u.samples += 1;
                } else {
                    // process gone (SIGKILL scenarios get here): the
                    // readings so far are the answer, stop polling
                    break;
                }
                std::thread::sleep(SAMPLE_PERIOD);
            }
        });
        Sampler { stop, acc, handle: Some(handle) }
    }

    /// Stop polling and return the aggregate usage.
    pub fn stop(mut self) -> Usage {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
        *self.acc.lock().unwrap()
    }
}

impl Drop for Sampler {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_statm_and_stat_fixtures() {
        let statm = "3969 576 436 11 0 353 0\n";
        // comm with spaces and a ')' — the adversarial case
        let stat = "1234 (we ir)d comm) S 1 1 1 0 -1 4194560 112 0 0 0 \
                    7 3 0 0 20 0 1 0 123456 16257024 576 \
                    18446744073709551615 1 1 0 0 0 0 0 0 0 0 0 0 17 0 0 0 \
                    0 0 0 0 0 0 0 0 0 0 0\n";
        let r = parse_proc(statm, stat).unwrap();
        assert_eq!(r.rss_bytes, 576 * 4096);
        assert_eq!(r.cpu_ticks, 7 + 3);
    }

    #[test]
    fn rejects_malformed_inputs() {
        assert!(parse_proc("", "1 (c) S 0").is_err());
        assert!(parse_proc("1 x", "1 (c) S 0").is_err());
        assert!(parse_proc("1 2", "no comm terminator").is_err());
        assert!(parse_proc("1 2", "1 (c) S 1 2 3").is_err()); // too short
    }

    #[test]
    fn reads_own_process() {
        let r = read_proc(std::process::id()).unwrap();
        assert!(r.rss_bytes > 0, "a running process has resident pages");
    }

    #[test]
    fn usage_merge_takes_peak_and_sums() {
        let mut a = Usage { peak_rss_bytes: 100, cpu_ticks: 5, samples: 2 };
        let b = Usage { peak_rss_bytes: 80, cpu_ticks: 7, samples: 3 };
        a.merge(&b);
        assert_eq!(a.peak_rss_bytes, 100);
        assert_eq!(a.cpu_ticks, 12);
        assert_eq!(a.samples, 5);
    }

    #[test]
    fn sampler_collects_samples() {
        let s = Sampler::spawn(std::process::id());
        std::thread::sleep(Duration::from_millis(80));
        let u = s.stop();
        assert!(u.samples >= 1);
        assert!(u.peak_rss_bytes > 0);
    }
}
