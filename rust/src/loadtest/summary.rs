//! The machine-readable output of a loadtest run (`summary.json`) and
//! the SLO gate that diffs two of them (`chon loadtest --check`), the
//! way `bench-diff` gates microbench medians.
//!
//! Schema (v1): a top-level object with `schema`, `seed`, `quick`, and
//! a `scenarios` array. Each scenario carries client-side latency
//! percentiles (ms), server-side stage quantiles (µs, scraped from
//! `/metrics`, factor-of-two bucket resolution), peak RSS + CPU ticks
//! from `/proc`, the deterministic schedule digest (hex — u64 does not
//! survive a f64 JSON number), and named pass/fail checks.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::obs::metrics::HistSnapshot;
use crate::serve::client::{percentile_of, LoadReport};
use crate::util::json::Json;

/// Client-side latency percentiles of one scenario, in milliseconds.
/// An empty run reports zeros (JSON cannot carry NaN; `requests_ok == 0`
/// is the signal that these are vacuous).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LatencySummary {
    pub p50_ms: f64,
    pub p90_ms: f64,
    pub p99_ms: f64,
    pub p999_ms: f64,
    pub max_ms: f64,
}

impl LatencySummary {
    /// Summarize an ascending-sorted latency list.
    pub fn of(sorted: &[f64]) -> LatencySummary {
        if sorted.is_empty() {
            return LatencySummary::default();
        }
        LatencySummary {
            p50_ms: percentile_of(sorted, 0.50),
            p90_ms: percentile_of(sorted, 0.90),
            p99_ms: percentile_of(sorted, 0.99),
            p999_ms: percentile_of(sorted, 0.999),
            max_ms: sorted[sorted.len() - 1],
        }
    }
}

/// Server-side quantiles of one request-path stage, in microseconds
/// (scraped; log₂-bucket resolution, so values are upper bounds within
/// 2× of the truth).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StageQuantiles {
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub count: u64,
}

impl StageQuantiles {
    pub fn of(snap: &HistSnapshot) -> StageQuantiles {
        StageQuantiles {
            p50_us: snap.quantile(0.50),
            p99_us: snap.quantile(0.99),
            p999_us: snap.quantile(0.999),
            count: snap.count(),
        }
    }
}

/// Everything one scenario reports into `summary.json`.
#[derive(Clone, Debug, Default)]
pub struct ScenarioResult {
    pub name: String,
    /// "deterministic" | "stochastic" | "chaos"
    pub kind: String,
    /// overall verdict: no failures, no empty responses, at least one
    /// completed request, every named check true
    pub ok: bool,
    pub requests_ok: u64,
    pub empty: u64,
    pub failures: u64,
    pub wall_s: f64,
    /// 0.0 when the run was empty or instantaneous (see LoadReport)
    pub throughput_rps: f64,
    pub latency: LatencySummary,
    /// per-stage server-side quantiles, merged across models
    pub stages: BTreeMap<String, StageQuantiles>,
    pub peak_rss_bytes: u64,
    pub cpu_ticks: u64,
    /// digest of the generated request schedule — two runs at the same
    /// seed must produce the same value (the determinism contract)
    pub schedule_digest: u64,
    /// named scenario-specific assertions, e.g. ("evictions>0", true)
    pub checks: Vec<(String, bool)>,
    /// how many times the scenario ran (`--repeats N`; 1 for single runs)
    pub repeats: u64,
    /// stage quantiles over the histograms merged across all repeats —
    /// only present (and only meaningful) when `repeats > 1`; a merged
    /// N×-sample histogram gives tail quantiles a single repeat cannot
    pub stages_merged: BTreeMap<String, StageQuantiles>,
    /// the raw scraped stage histograms backing `stages` — kept so the
    /// harness can merge across repeats; never serialized
    pub stage_snaps: BTreeMap<String, HistSnapshot>,
}

impl ScenarioResult {
    /// Assemble from the pieces a scenario run produces.
    pub fn from_parts(
        name: &str,
        kind: &str,
        report: &LoadReport,
        stage_snaps: BTreeMap<String, HistSnapshot>,
        usage: &super::resources::Usage,
        schedule_digest: u64,
        checks: Vec<(String, bool)>,
    ) -> ScenarioResult {
        let ok = report.failures == 0
            && report.empty_responses == 0
            && report.requests_ok() > 0
            && checks.iter().all(|(_, pass)| *pass);
        let stages = stage_snaps
            .iter()
            .map(|(stage, snap)| (stage.clone(), StageQuantiles::of(snap)))
            .collect();
        ScenarioResult {
            name: name.to_string(),
            kind: kind.to_string(),
            ok,
            requests_ok: report.requests_ok() as u64,
            empty: report.empty_responses as u64,
            failures: report.failures as u64,
            wall_s: report.wall_s,
            throughput_rps: report.throughput_rps().unwrap_or(0.0),
            latency: LatencySummary::of(&report.latencies_ms),
            stages,
            peak_rss_bytes: usage.peak_rss_bytes,
            cpu_ticks: usage.cpu_ticks,
            schedule_digest,
            checks,
            repeats: 1,
            stages_merged: BTreeMap::new(),
            stage_snaps,
        }
    }

    /// A scenario that died before producing a report (spawn failure,
    /// supervisor error): recorded as not-ok with the error as a failed
    /// check, so one broken scenario cannot hide from the summary.
    pub fn infra_failure(name: &str, kind: &str, err: &str) -> ScenarioResult {
        ScenarioResult {
            name: name.to_string(),
            kind: kind.to_string(),
            ok: false,
            checks: vec![(format!("infra: {err}"), false)],
            repeats: 1,
            ..Default::default()
        }
    }

    fn to_json(&self) -> Json {
        let stage_obj = |m: &BTreeMap<String, StageQuantiles>| -> Vec<(String, Json)> {
            m.iter()
                .map(|(stage, q)| {
                    (
                        stage.clone(),
                        Json::Obj(vec![
                            ("p50_us".into(), Json::Num(q.p50_us as f64)),
                            ("p99_us".into(), Json::Num(q.p99_us as f64)),
                            ("p999_us".into(), Json::Num(q.p999_us as f64)),
                            ("count".into(), Json::Num(q.count as f64)),
                        ]),
                    )
                })
                .collect()
        };
        let stages = stage_obj(&self.stages);
        let checks = self
            .checks
            .iter()
            .map(|(name, pass)| {
                Json::Obj(vec![
                    ("name".into(), Json::Str(name.clone())),
                    ("pass".into(), Json::Bool(*pass)),
                ])
            })
            .collect();
        let mut fields = vec![
            ("name".into(), Json::Str(self.name.clone())),
            ("kind".into(), Json::Str(self.kind.clone())),
            ("ok".into(), Json::Bool(self.ok)),
            ("requests_ok".into(), Json::Num(self.requests_ok as f64)),
            ("empty".into(), Json::Num(self.empty as f64)),
            ("failures".into(), Json::Num(self.failures as f64)),
            ("wall_s".into(), Json::Num(self.wall_s)),
            ("throughput_rps".into(), Json::Num(self.throughput_rps)),
            (
                "latency_ms".into(),
                Json::Obj(vec![
                    ("p50".into(), Json::Num(self.latency.p50_ms)),
                    ("p90".into(), Json::Num(self.latency.p90_ms)),
                    ("p99".into(), Json::Num(self.latency.p99_ms)),
                    ("p999".into(), Json::Num(self.latency.p999_ms)),
                    ("max".into(), Json::Num(self.latency.max_ms)),
                ]),
            ),
            ("stages".into(), Json::Obj(stages)),
            ("peak_rss_bytes".into(), Json::Num(self.peak_rss_bytes as f64)),
            ("cpu_ticks".into(), Json::Num(self.cpu_ticks as f64)),
            (
                "schedule_digest".into(),
                Json::Str(format!("{:016x}", self.schedule_digest)),
            ),
            ("checks".into(), Json::Arr(checks)),
            ("repeats".into(), Json::Num(self.repeats.max(1) as f64)),
        ];
        // schema-append, not schema-change: readers that predate repeats
        // ignore these keys, and single runs omit stages_merged entirely
        if !self.stages_merged.is_empty() {
            fields.push((
                "stages_merged".into(),
                Json::Obj(stage_obj(&self.stages_merged)),
            ));
        }
        Json::Obj(fields)
    }

    fn from_json(v: &Json) -> Result<ScenarioResult> {
        let str_field = |key: &str| -> Result<String> {
            Ok(v.get(key)
                .and_then(|x| x.as_str())
                .with_context(|| format!("scenario missing {key}"))?
                .to_string())
        };
        let num_field = |key: &str| -> Result<f64> {
            v.get(key)
                .and_then(|x| x.as_f64())
                .with_context(|| format!("scenario missing {key}"))
        };
        let lat = v.get("latency_ms").context("scenario missing latency_ms")?;
        let lat_field = |key: &str| -> Result<f64> {
            lat.get(key)
                .and_then(|x| x.as_f64())
                .with_context(|| format!("latency_ms missing {key}"))
        };
        let parse_stage_map = |key: &str| -> Result<BTreeMap<String, StageQuantiles>> {
            let mut out = BTreeMap::new();
            if let Some(Json::Obj(fields)) = v.get(key) {
                for (stage, q) in fields {
                    let f = |key: &str| -> Result<u64> {
                        Ok(q.get(key)
                            .and_then(|x| x.as_f64())
                            .with_context(|| format!("stage {stage} missing {key}"))?
                            as u64)
                    };
                    out.insert(
                        stage.clone(),
                        StageQuantiles {
                            p50_us: f("p50_us")?,
                            p99_us: f("p99_us")?,
                            p999_us: f("p999_us")?,
                            count: f("count")?,
                        },
                    );
                }
            }
            Ok(out)
        };
        let stages = parse_stage_map("stages")?;
        let stages_merged = parse_stage_map("stages_merged")?;
        let mut checks = Vec::new();
        if let Some(Json::Arr(items)) = v.get("checks") {
            for c in items {
                let name = c
                    .get("name")
                    .and_then(|x| x.as_str())
                    .context("check missing name")?;
                let pass = match c.get("pass") {
                    Some(Json::Bool(b)) => *b,
                    _ => bail!("check missing pass"),
                };
                checks.push((name.to_string(), pass));
            }
        }
        let ok = match v.get("ok") {
            Some(Json::Bool(b)) => *b,
            _ => bail!("scenario missing ok"),
        };
        let digest_hex = str_field("schedule_digest")?;
        let schedule_digest = u64::from_str_radix(&digest_hex, 16)
            .with_context(|| format!("bad schedule_digest {digest_hex:?}"))?;
        Ok(ScenarioResult {
            name: str_field("name")?,
            kind: str_field("kind")?,
            ok,
            requests_ok: num_field("requests_ok")? as u64,
            empty: num_field("empty")? as u64,
            failures: num_field("failures")? as u64,
            wall_s: num_field("wall_s")?,
            throughput_rps: num_field("throughput_rps")?,
            latency: LatencySummary {
                p50_ms: lat_field("p50")?,
                p90_ms: lat_field("p90")?,
                p99_ms: lat_field("p99")?,
                p999_ms: lat_field("p999")?,
                max_ms: lat_field("max")?,
            },
            stages,
            peak_rss_bytes: num_field("peak_rss_bytes")? as u64,
            cpu_ticks: num_field("cpu_ticks")? as u64,
            schedule_digest,
            checks,
            // absent in pre-repeats summaries: a single run
            repeats: v
                .get("repeats")
                .and_then(|x| x.as_f64())
                .map_or(1, |n| (n as u64).max(1)),
            stages_merged,
            stage_snaps: BTreeMap::new(),
        })
    }
}

/// One whole loadtest run.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub seed: u64,
    pub quick: bool,
    pub scenarios: Vec<ScenarioResult>,
}

/// Bump when the JSON layout changes incompatibly.
const SCHEMA_VERSION: u64 = 1;

impl Summary {
    pub fn all_ok(&self) -> bool {
        !self.scenarios.is_empty() && self.scenarios.iter().all(|s| s.ok)
    }

    pub fn get(&self, name: &str) -> Option<&ScenarioResult> {
        self.scenarios.iter().find(|s| s.name == name)
    }

    pub fn render(&self) -> String {
        Json::Obj(vec![
            ("schema".into(), Json::Num(SCHEMA_VERSION as f64)),
            ("seed".into(), Json::Num(self.seed as f64)),
            ("quick".into(), Json::Bool(self.quick)),
            (
                "scenarios".into(),
                Json::Arr(self.scenarios.iter().map(|s| s.to_json()).collect()),
            ),
        ])
        .render_pretty()
    }

    pub fn parse(text: &str) -> Result<Summary> {
        let v = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let schema = v
            .get("schema")
            .and_then(|x| x.as_f64())
            .context("summary missing schema")? as u64;
        if schema != SCHEMA_VERSION {
            bail!("summary schema {schema} != supported {SCHEMA_VERSION}");
        }
        let seed = v.get("seed").and_then(|x| x.as_f64()).unwrap_or(0.0) as u64;
        let quick = matches!(v.get("quick"), Some(Json::Bool(true)));
        let mut scenarios = Vec::new();
        for s in v
            .get("scenarios")
            .and_then(|x| x.as_arr())
            .context("summary missing scenarios")?
        {
            scenarios.push(ScenarioResult::from_json(s)?);
        }
        Ok(Summary { seed, quick, scenarios })
    }

    pub fn write(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        std::fs::write(path, self.render())
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn read(path: &Path) -> Result<Summary> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Summary::parse(&text).with_context(|| format!("parsing {}", path.display()))
    }
}

/// RSS regressions smaller than this are noise (allocator round-off,
/// page-cache luck), whatever the percentage says.
const RSS_SLACK_BYTES: u64 = 16 << 20;

/// Diff `current` against `baseline`: every returned string is one SLO
/// violation. Latency percentiles (p50/p99/p999) regress when they
/// exceed the baseline by more than `tol_pct` percent AND more than
/// `abs_ms` milliseconds — the absolute floor keeps micro-latency
/// scenarios (2 ms p50) from failing on scheduler jitter that a
/// percentage alone would flag. Peak RSS gates on `tol_pct` with a
/// 16 MiB floor. CPU ticks are reported in the summary but not gated
/// (tick totals scale with runner core speed, not with regressions).
pub fn check(
    baseline: &Summary,
    current: &Summary,
    tol_pct: f64,
    abs_ms: f64,
) -> Vec<String> {
    let mut violations = Vec::new();
    for base in &baseline.scenarios {
        let Some(cur) = current.get(&base.name) else {
            violations.push(format!("scenario {} missing from current run", base.name));
            continue;
        };
        if !cur.ok {
            let failed: Vec<&str> = cur
                .checks
                .iter()
                .filter(|(_, pass)| !pass)
                .map(|(name, _)| name.as_str())
                .collect();
            violations.push(format!(
                "scenario {} not ok ({} failures, {} empty, failed checks: [{}])",
                cur.name,
                cur.failures,
                cur.empty,
                failed.join(", ")
            ));
            continue;
        }
        for (what, b, c) in [
            ("p50", base.latency.p50_ms, cur.latency.p50_ms),
            ("p99", base.latency.p99_ms, cur.latency.p99_ms),
            ("p999", base.latency.p999_ms, cur.latency.p999_ms),
        ] {
            let over_pct = c > b * (1.0 + tol_pct / 100.0);
            let over_abs = c - b > abs_ms;
            if over_pct && over_abs {
                violations.push(format!(
                    "{}: latency {what} regressed {b:.2} -> {c:.2} ms \
                     (>{tol_pct}% and >{abs_ms} ms)",
                    cur.name
                ));
            }
        }
        let rss_limit = (base.peak_rss_bytes as f64 * (1.0 + tol_pct / 100.0)) as u64;
        if cur.peak_rss_bytes > rss_limit
            && cur.peak_rss_bytes - base.peak_rss_bytes > RSS_SLACK_BYTES
        {
            violations.push(format!(
                "{}: peak RSS regressed {} -> {} bytes (>{tol_pct}% and >16 MiB)",
                cur.name, base.peak_rss_bytes, cur.peak_rss_bytes
            ));
        }
    }
    violations
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_result(name: &str, p99: f64) -> ScenarioResult {
        let mut stages = BTreeMap::new();
        stages.insert(
            "prefill".to_string(),
            StageQuantiles { p50_us: 512, p99_us: 2048, p999_us: 4096, count: 24 },
        );
        ScenarioResult {
            name: name.into(),
            kind: "deterministic".into(),
            ok: true,
            requests_ok: 24,
            empty: 0,
            failures: 0,
            wall_s: 1.5,
            throughput_rps: 16.0,
            latency: LatencySummary {
                p50_ms: 4.0,
                p90_ms: 9.0,
                p99_ms: p99,
                p999_ms: p99 * 1.5,
                max_ms: p99 * 2.0,
            },
            stages,
            peak_rss_bytes: 64 << 20,
            cpu_ticks: 120,
            schedule_digest: 0xDEAD_BEEF_0123_4567,
            checks: vec![("requests>=total".into(), true)],
            repeats: 1,
            stages_merged: BTreeMap::new(),
            stage_snaps: BTreeMap::new(),
        }
    }

    fn sample_summary(p99: f64) -> Summary {
        Summary {
            seed: 42,
            quick: true,
            scenarios: vec![sample_result("fanout", p99), sample_result("poisson", p99)],
        }
    }

    #[test]
    fn summary_json_roundtrip() {
        let s = sample_summary(12.0);
        let back = Summary::parse(&s.render()).unwrap();
        assert_eq!(back.seed, 42);
        assert!(back.quick);
        assert_eq!(back.scenarios.len(), 2);
        let f = back.get("fanout").unwrap();
        assert_eq!(f.latency, s.scenarios[0].latency);
        assert_eq!(f.stages, s.scenarios[0].stages);
        assert_eq!(f.schedule_digest, 0xDEAD_BEEF_0123_4567);
        assert_eq!(f.checks, s.scenarios[0].checks);
        assert_eq!(f.repeats, 1);
        assert!(f.stages_merged.is_empty());
        assert!(back.all_ok());
    }

    #[test]
    fn repeats_and_merged_stages_roundtrip() {
        let mut r = sample_result("fanout", 12.0);
        r.repeats = 3;
        r.stages_merged.insert(
            "prefill".to_string(),
            StageQuantiles { p50_us: 512, p99_us: 4096, p999_us: 8192, count: 72 },
        );
        let s = Summary { scenarios: vec![r.clone()], ..Default::default() };
        let back = Summary::parse(&s.render()).unwrap();
        let f = back.get("fanout").unwrap();
        assert_eq!(f.repeats, 3);
        assert_eq!(f.stages_merged, r.stages_merged);
        // and a pre-repeats summary (no such keys) still parses: defaults
        let old_json = format!(
            "{{\"schema\":1,\"seed\":1,\"quick\":false,\"scenarios\":[{}]}}",
            r#"{"name":"fanout","kind":"deterministic","ok":true,
                "requests_ok":1,"empty":0,"failures":0,"wall_s":1,
                "throughput_rps":1,
                "latency_ms":{"p50":1,"p90":1,"p99":1,"p999":1,"max":1},
                "stages":{},"peak_rss_bytes":0,"cpu_ticks":0,
                "schedule_digest":"00000000000000aa","checks":[]}"#
        );
        let old = Summary::parse(&old_json).unwrap();
        assert_eq!(old.get("fanout").unwrap().repeats, 1);
        assert!(old.get("fanout").unwrap().stages_merged.is_empty());
    }

    #[test]
    fn empty_latency_summary_is_zero_not_nan() {
        let l = LatencySummary::of(&[]);
        assert_eq!(l, LatencySummary::default());
        // and it must render to valid JSON
        let mut r = sample_result("x", 1.0);
        r.latency = l;
        let s = Summary { scenarios: vec![r], ..Default::default() };
        assert!(Summary::parse(&s.render()).is_ok());
    }

    #[test]
    fn check_passes_on_identical_runs() {
        let s = sample_summary(12.0);
        assert!(check(&s, &s, 50.0, 5.0).is_empty());
    }

    #[test]
    fn check_fails_on_latency_regression() {
        let base = sample_summary(12.0);
        let cur = sample_summary(120.0); // 10x p99
        let v = check(&base, &cur, 50.0, 5.0);
        assert!(!v.is_empty());
        assert!(v.iter().any(|m| m.contains("p99")), "{v:?}");
    }

    #[test]
    fn check_allows_small_absolute_jitter() {
        let base = sample_summary(2.0);
        // 2 -> 3.5 ms p99 is +75% but only +1.5 ms: under the 5 ms floor
        let cur = sample_summary(3.5);
        assert!(check(&base, &cur, 50.0, 5.0).is_empty());
    }

    #[test]
    fn check_fails_on_missing_or_broken_scenario() {
        let base = sample_summary(12.0);
        let mut cur = sample_summary(12.0);
        cur.scenarios.remove(1);
        let v = check(&base, &cur, 50.0, 5.0);
        assert!(v.iter().any(|m| m.contains("missing")), "{v:?}");

        let mut broken = sample_summary(12.0);
        broken.scenarios[0].ok = false;
        broken.scenarios[0].checks.push(("resume-bit-identical".into(), false));
        let v = check(&base, &broken, 50.0, 5.0);
        assert!(
            v.iter().any(|m| m.contains("not ok") && m.contains("resume")),
            "{v:?}"
        );
    }

    #[test]
    fn check_gates_rss_with_floor() {
        let base = sample_summary(12.0);
        let mut cur = sample_summary(12.0);
        // +10 MiB at +15%: above 0% tolerance? pct yes at tol 10, but
        // under the 16 MiB floor -> pass
        cur.scenarios[0].peak_rss_bytes = (64 << 20) + (10 << 20);
        assert!(check(&base, &cur, 10.0, 5.0).is_empty());
        // +64 MiB (2x): both pct and floor exceeded -> violation
        cur.scenarios[0].peak_rss_bytes = 128 << 20;
        let v = check(&base, &cur, 10.0, 5.0);
        assert!(v.iter().any(|m| m.contains("RSS")), "{v:?}");
    }

    #[test]
    fn infra_failure_is_never_ok() {
        let r = ScenarioResult::infra_failure("evict_storm", "chaos", "spawn failed");
        assert!(!r.ok);
        let s = Summary { scenarios: vec![r], ..Default::default() };
        assert!(!s.all_ok());
        let back = Summary::parse(&s.render()).unwrap();
        assert!(!back.scenarios[0].ok);
        assert!(back.scenarios[0].checks[0].0.contains("spawn failed"));
    }
}
