//! Process supervision for the loadtest harness: spawn a real `chon
//! serve` binary, discover its ephemeral ports from the startup banner,
//! wait for readiness, sample its `/proc` usage while it runs, and take
//! it down — gracefully (SHUTDOWN) or violently (SIGKILL, for the
//! kill-and-resume chaos scenario).
//!
//! Port discovery rides the server's own stdout contract: `chon serve
//! --port 0` prints `listening on <host>:<port>` (and `http front end on
//! <host>:<port>`) after binding, and Rust's stdout is line-buffered, so
//! scanning the redirected log file is race-free — no port file, no
//! retry-until-connect scan of the port space.

use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::loadtest::resources::{Sampler, Usage};
use crate::serve::client;

/// Everything configurable about one supervised `chon serve` process.
/// Mirrors the CLI flags so a scenario reads like a command line.
#[derive(Clone, Debug)]
pub struct ServeSpec {
    /// `--checkpoint DIR` (registers model "default")
    pub checkpoint: Option<PathBuf>,
    /// `--model NAME=DIR` entries
    pub models: Vec<(String, PathBuf)>,
    pub max_batch: usize,
    pub max_wait_us: u64,
    /// 0 = unlimited
    pub max_conns: usize,
    pub max_resident_sessions: usize,
    pub max_kv_tokens: usize,
    pub spill_dir: Option<PathBuf>,
    pub max_resident_models: usize,
    pub reload_poll_ms: u64,
}

impl Default for ServeSpec {
    fn default() -> Self {
        ServeSpec {
            checkpoint: None,
            models: Vec::new(),
            max_batch: 8,
            max_wait_us: 2000,
            max_conns: 0,
            max_resident_sessions: 0,
            max_kv_tokens: 0,
            spill_dir: None,
            max_resident_models: 0,
            reload_poll_ms: 500,
        }
    }
}

impl ServeSpec {
    fn to_args(&self) -> Vec<String> {
        let mut args: Vec<String> = vec![
            "serve".into(),
            "--port".into(),
            "0".into(),
            "--http-port".into(),
            "0".into(),
            "--max-batch".into(),
            self.max_batch.to_string(),
            "--max-wait-us".into(),
            self.max_wait_us.to_string(),
            "--max-conns".into(),
            self.max_conns.to_string(),
            "--max-resident-sessions".into(),
            self.max_resident_sessions.to_string(),
            "--max-kv-tokens".into(),
            self.max_kv_tokens.to_string(),
            "--max-resident-models".into(),
            self.max_resident_models.to_string(),
            "--reload-poll-ms".into(),
            self.reload_poll_ms.to_string(),
        ];
        if let Some(ckpt) = &self.checkpoint {
            args.push("--checkpoint".into());
            args.push(ckpt.display().to_string());
        }
        for (name, dir) in &self.models {
            args.push("--model".into());
            args.push(format!("{name}={}", dir.display()));
        }
        if let Some(dir) = &self.spill_dir {
            args.push("--spill-dir".into());
            args.push(dir.display().to_string());
        }
        args
    }
}

/// One supervised server process.
pub struct ServerProc {
    child: Child,
    /// TCP line-protocol port (banner-discovered)
    pub port: u16,
    /// HTTP front-end port (banner-discovered; scrape target)
    pub http_port: u16,
    log_path: PathBuf,
    sampler: Option<Sampler>,
    usage_done: Usage,
}

/// How long spawn waits for the startup banner + PING readiness. Cold
/// checkpoint loads (engine deserialize + B-panel packing) dominate.
const READY_DEADLINE: Duration = Duration::from_secs(60);

/// Scan a log for `<marker><host>:<port>` and return the port.
fn scan_port(log: &str, marker: &str) -> Option<u16> {
    for line in log.lines() {
        if let Some(rest) = line.strip_prefix(marker) {
            if let Some((_, port)) = rest.trim().rsplit_once(':') {
                if let Ok(p) = port.parse::<u16>() {
                    if p != 0 {
                        return Some(p);
                    }
                }
            }
        }
    }
    None
}

impl ServerProc {
    /// Spawn `bin serve ...` per the spec, redirect stdout+stderr to
    /// `log_path`, wait for both port banners and a PING round-trip,
    /// then start the resource sampler.
    pub fn spawn(bin: &Path, spec: &ServeSpec, log_path: &Path) -> Result<ServerProc> {
        if let Some(parent) = log_path.parent() {
            std::fs::create_dir_all(parent)
                .with_context(|| format!("creating {}", parent.display()))?;
        }
        let log_file = std::fs::File::create(log_path)
            .with_context(|| format!("creating {}", log_path.display()))?;
        let log_err = log_file
            .try_clone()
            .context("cloning log handle for stderr")?;
        let mut child = Command::new(bin)
            .args(spec.to_args())
            .stdin(Stdio::null())
            .stdout(Stdio::from(log_file))
            .stderr(Stdio::from(log_err))
            .spawn()
            .with_context(|| format!("spawning {} serve", bin.display()))?;

        // banner scan: the server prints its real ports after binding
        let deadline = Instant::now() + READY_DEADLINE;
        let (port, http_port) = loop {
            let log = std::fs::read_to_string(log_path).unwrap_or_default();
            if let (Some(p), Some(hp)) = (
                scan_port(&log, "listening on "),
                scan_port(&log, "http front end on "),
            ) {
                break (p, hp);
            }
            if let Some(status) = child.try_wait().context("polling server")? {
                bail!(
                    "server exited {status} before printing its ports; log tail:\n{}",
                    tail_of(&log)
                );
            }
            if Instant::now() >= deadline {
                let _ = child.kill();
                bail!(
                    "server never printed its ports within {READY_DEADLINE:?}; \
                     log tail:\n{}",
                    tail_of(&log)
                );
            }
            std::thread::sleep(Duration::from_millis(20));
        };

        // readiness: the reactor answers PING once the event loop runs
        let mut ready = false;
        while Instant::now() < deadline {
            if client::open_conn("127.0.0.1", port)
                .and_then(|mut s| client::ping(&mut s))
                .is_ok()
            {
                ready = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        if !ready {
            let _ = child.kill();
            bail!("server on port {port} never answered PING");
        }

        let sampler = Some(Sampler::spawn(child.id()));
        Ok(ServerProc {
            child,
            port,
            http_port,
            log_path: log_path.to_path_buf(),
            sampler,
            usage_done: Usage::default(),
        })
    }

    pub fn pid(&self) -> u32 {
        self.child.id()
    }

    /// SIGKILL, no drain, no Drop handlers server-side — the chaos
    /// primitive. Spill files and checkpoints must survive this.
    pub fn kill_hard(&mut self) -> Result<()> {
        self.freeze_usage();
        self.child.kill().context("killing server")?;
        self.child.wait().context("reaping killed server")?;
        Ok(())
    }

    /// Graceful stop: SHUTDOWN over the protocol, then wait (bounded).
    pub fn stop(&mut self) -> Result<()> {
        self.freeze_usage();
        client::send_shutdown("127.0.0.1", self.port)
            .context("sending SHUTDOWN")?;
        let deadline = Instant::now() + Duration::from_secs(30);
        loop {
            if self.child.try_wait().context("polling server")?.is_some() {
                return Ok(());
            }
            if Instant::now() >= deadline {
                let _ = self.child.kill();
                let _ = self.child.wait();
                bail!(
                    "server ignored SHUTDOWN for 30s; killed. log tail:\n{}",
                    self.log_tail()
                );
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }

    fn freeze_usage(&mut self) {
        if let Some(s) = self.sampler.take() {
            self.usage_done = s.stop();
        }
    }

    /// The process's aggregate resource usage (stops the sampler on
    /// first call; idempotent).
    pub fn usage(&mut self) -> Usage {
        self.freeze_usage();
        self.usage_done
    }

    /// Fetch the `/metrics` body from the HTTP front end.
    pub fn scrape_metrics(&self) -> Result<String> {
        client::fetch_metrics("127.0.0.1", self.http_port)
    }

    /// Last lines of the server log (diagnostics on failure).
    pub fn log_tail(&self) -> String {
        tail_of(&std::fs::read_to_string(&self.log_path).unwrap_or_default())
    }
}

impl Drop for ServerProc {
    fn drop(&mut self) {
        // a scenario that errored out mid-flight must not leak a server
        if self.child.try_wait().map(|s| s.is_none()).unwrap_or(false) {
            let _ = self.child.kill();
            let _ = self.child.wait();
        }
    }
}

fn tail_of(log: &str) -> String {
    let lines: Vec<&str> = log.lines().collect();
    let start = lines.len().saturating_sub(15);
    lines[start..].join("\n")
}

/// Run a one-shot `bin <args>` subprocess to completion (the harness
/// uses this for `chon train` republishes in the hot-reload scenario),
/// appending its output to `log_path`. Non-zero exit is an error
/// carrying the log tail.
pub fn run_tool(bin: &Path, args: &[String], log_path: &Path) -> Result<()> {
    if let Some(parent) = log_path.parent() {
        std::fs::create_dir_all(parent)
            .with_context(|| format!("creating {}", parent.display()))?;
    }
    let log_file = std::fs::OpenOptions::new()
        .create(true)
        .append(true)
        .open(log_path)
        .with_context(|| format!("opening {}", log_path.display()))?;
    let log_err = log_file.try_clone().context("cloning log handle")?;
    let status = Command::new(bin)
        .args(args)
        .stdin(Stdio::null())
        .stdout(Stdio::from(log_file))
        .stderr(Stdio::from(log_err))
        .status()
        .with_context(|| format!("running {} {}", bin.display(), args.join(" ")))?;
    if !status.success() {
        bail!(
            "{} {} exited {status}; log tail:\n{}",
            bin.display(),
            args.join(" "),
            tail_of(&std::fs::read_to_string(log_path).unwrap_or_default())
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn port_scan_finds_banner_lines() {
        let log = "registered model default -> /tmp/ckpt\n\
                   listening on 127.0.0.1:43211\n\
                   http front end on 127.0.0.1:43212\n";
        assert_eq!(scan_port(log, "listening on "), Some(43211));
        assert_eq!(scan_port(log, "http front end on "), Some(43212));
        assert_eq!(scan_port(log, "router on "), None);
        // an unparsed or zero port is not readiness
        assert_eq!(scan_port("listening on 127.0.0.1:0\n", "listening on "), None);
        assert_eq!(scan_port("listening on nope\n", "listening on "), None);
    }

    #[test]
    fn spec_args_cover_all_knobs() {
        let spec = ServeSpec {
            checkpoint: Some(PathBuf::from("/ck")),
            models: vec![("alpha".into(), PathBuf::from("/a"))],
            max_conns: 3,
            max_resident_sessions: 1,
            max_kv_tokens: 7,
            spill_dir: Some(PathBuf::from("/sp")),
            max_resident_models: 2,
            reload_poll_ms: 50,
            ..Default::default()
        };
        let args = spec.to_args();
        let joined = args.join(" ");
        assert!(joined.starts_with("serve --port 0 --http-port 0"));
        for want in [
            "--max-conns 3",
            "--max-resident-sessions 1",
            "--max-kv-tokens 7",
            "--checkpoint /ck",
            "--model alpha=/a",
            "--spill-dir /sp",
            "--max-resident-models 2",
            "--reload-poll-ms 50",
        ] {
            assert!(joined.contains(want), "{want} missing from {joined}");
        }
    }

    #[test]
    fn tail_is_bounded() {
        let long: String = (0..100).map(|i| format!("line {i}\n")).collect();
        let t = tail_of(&long);
        assert!(t.lines().count() <= 15);
        assert!(t.contains("line 99"));
    }
}
