//! `chon loadtest` — the scenario + chaos load harness.
//!
//! One binary, no external tooling: the harness trains (or takes) a
//! checkpoint, spawns the release `chon serve` binary per scenario,
//! drives seeded request schedules against it (deterministic bursts,
//! Poisson arrivals, session churn, eviction storms, hot reloads,
//! SIGKILL-and-resume), samples the server's `/proc` usage while it
//! runs, scrapes its `/metrics` stage histograms, and writes one
//! `summary.json` with per-scenario p50/p99/p999, peak RSS and CPU
//! ticks. `chon loadtest --check BASELINE` turns the summary into an
//! SLO gate, the same shape as `chon bench-diff`.
//!
//! Harness lineage: the scenario-registry + supervisor + SLO-gate
//! split follows the WIND bench harness (SNIPPETS §3), adapted to a
//! single self-contained binary.

pub mod proc;
pub mod resources;
pub mod scenarios;
pub mod scrape;
pub mod summary;

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::config::RunConfig;
use crate::coordinator::Trainer;
use crate::obs::metrics::HistSnapshot;
use scenarios::{registry, Ctx};
use summary::{ScenarioResult, StageQuantiles, Summary};

/// Everything the `loadtest` subcommand configures.
#[derive(Clone, Debug)]
pub struct LoadtestOpts {
    /// scenario names to run (empty = the whole registry, in order)
    pub scenarios: Vec<String>,
    /// smaller workloads, same coverage — CI smoke mode
    pub quick: bool,
    pub seed: u64,
    /// all scratch, logs and summary.json land under here
    pub out_root: PathBuf,
    /// serve this checkpoint instead of training a fresh one
    pub checkpoint: Option<PathBuf>,
    /// the binary to spawn servers with (None = this very binary)
    pub bin: Option<PathBuf>,
    /// artificial client-side latency per request — the gate-validation
    /// hook used by CI's negative test (0 in real runs)
    pub inject_latency_ms: u64,
    /// model/recipe for the self-trained checkpoint (and republishes)
    pub model: String,
    pub recipe: String,
    /// run every scenario this many times (min 1); stage histograms are
    /// merged across repeats into `stages_merged`, and the seeded
    /// schedule digests must agree across repeats (a named check)
    pub repeats: usize,
}

impl Default for LoadtestOpts {
    fn default() -> Self {
        LoadtestOpts {
            scenarios: Vec::new(),
            quick: false,
            seed: 7,
            out_root: PathBuf::from("runs/loadtest"),
            checkpoint: None,
            bin: None,
            inject_latency_ms: 0,
            model: "tiny_gla".to_string(),
            recipe: "chon".to_string(),
            repeats: 1,
        }
    }
}

/// Train a small checkpoint for the harness to serve, under
/// `out_root/ckpt` (parent-dir layout: serve/resume pick the highest
/// step inside).
fn train_checkpoint(opts: &LoadtestOpts) -> Result<PathBuf> {
    let root = opts.out_root.join("ckpt");
    let _ = std::fs::remove_dir_all(&root);
    let mut cfg = RunConfig::default();
    cfg.backend = "native".into();
    cfg.artifacts = PathBuf::from("/nonexistent/chon_artifacts");
    cfg.model = opts.model.clone();
    cfg.recipe = opts.recipe.clone();
    cfg.diag_every = 0;
    cfg.eval_every = 0;
    cfg.log_every = 0;
    cfg.seed = opts.seed;
    cfg.out_dir = opts.out_root.join("train_runs");
    let steps = if opts.quick { 12 } else { 30 };
    let mut tr = Trainer::new(cfg).context("building trainer for the harness checkpoint")?;
    tr.train(steps).context("training the harness checkpoint")?;
    tr.save_checkpoint_to(&root)
        .context("writing the harness checkpoint")?;
    Ok(root)
}

/// Resolve requested scenario names against the registry (empty = all).
fn select(names: &[String]) -> Result<Vec<&'static scenarios::Scenario>> {
    let all = registry();
    if names.is_empty() {
        return Ok(all.iter().collect());
    }
    let mut picked = Vec::new();
    for want in names {
        match all.iter().find(|s| s.name == want.as_str()) {
            Some(s) => picked.push(s),
            None => {
                let known: Vec<&str> = all.iter().map(|s| s.name).collect();
                bail!("unknown scenario {want:?}; known: {}", known.join(", "));
            }
        }
    }
    Ok(picked)
}

/// Run the selected scenarios and write `out_root/summary.json`.
/// A scenario that errors out (infrastructure failure, not SLO failure)
/// is recorded as a failed result and the remaining scenarios still run
/// — one bad scenario must not hide the others' numbers.
pub fn run(opts: &LoadtestOpts) -> Result<Summary> {
    let picked = select(&opts.scenarios)?;
    std::fs::create_dir_all(&opts.out_root)
        .with_context(|| format!("creating {}", opts.out_root.display()))?;
    let bin = match &opts.bin {
        Some(b) => b.clone(),
        None => std::env::current_exe().context("locating the chon binary")?,
    };
    let ckpt = match &opts.checkpoint {
        Some(c) => c.clone(),
        None => train_checkpoint(opts)?,
    };

    let mut out = Summary {
        seed: opts.seed,
        quick: opts.quick,
        scenarios: Vec::new(),
    };
    let repeats = opts.repeats.max(1);
    for sc in picked {
        // --repeats N: run the scenario N times (fresh scratch dir and
        // server per repeat), keep the first run as the reported result,
        // AND the verdicts, and merge the scraped stage histograms so
        // `stages_merged` quantiles rest on N runs' worth of samples
        let mut base: Option<ScenarioResult> = None;
        let mut merged: BTreeMap<String, HistSnapshot> = BTreeMap::new();
        let mut digests: Vec<u64> = Vec::new();
        for rep in 0..repeats {
            let sub = if rep == 0 {
                sc.name.to_string()
            } else {
                format!("{}_r{rep}", sc.name)
            };
            let dir = opts.out_root.join(&sub);
            let _ = std::fs::remove_dir_all(&dir);
            std::fs::create_dir_all(&dir)
                .with_context(|| format!("creating {}", dir.display()))?;
            let ctx = Ctx {
                bin: bin.clone(),
                ckpt: ckpt.clone(),
                out: dir,
                seed: opts.seed,
                quick: opts.quick,
                inject_latency_ms: opts.inject_latency_ms,
                model: opts.model.clone(),
                recipe: opts.recipe.clone(),
            };
            let t0 = std::time::Instant::now();
            let result = match (sc.run)(&ctx) {
                Ok(r) => r,
                Err(e) => {
                    ScenarioResult::infra_failure(sc.name, sc.kind, &format!("{e:#}"))
                }
            };
            println!(
                "loadtest {:<12} [{}] {}{} in {:.1}s  (p99 {:.1} ms, {} ok / {} failed, \
                 rss {:.1} MiB)",
                result.name,
                result.kind,
                if result.ok { "ok" } else { "FAILED" },
                if repeats > 1 { format!(" (r{rep})") } else { String::new() },
                t0.elapsed().as_secs_f64(),
                result.latency.p99_ms,
                result.requests_ok,
                result.failures,
                result.peak_rss_bytes as f64 / (1024.0 * 1024.0),
            );
            if !result.ok {
                for (name, pass) in &result.checks {
                    if !pass {
                        println!("    check failed: {name}");
                    }
                }
            }
            for (stage, snap) in &result.stage_snaps {
                merged.entry(stage.clone()).or_default().merge(snap);
            }
            digests.push(result.schedule_digest);
            match &mut base {
                None => base = Some(result),
                Some(b) => {
                    b.ok &= result.ok;
                    for (name, pass) in result.checks {
                        if !pass {
                            b.checks.push((format!("r{rep}: {name}"), false));
                        }
                    }
                }
            }
        }
        let mut result = base.expect("repeats >= 1 ran");
        result.repeats = repeats as u64;
        if repeats > 1 {
            // the determinism contract, now cross-checked for real: same
            // seed, same generated schedule, every repeat
            let identical = digests.iter().all(|&d| d == digests[0]);
            result.ok &= identical;
            result.checks.push(("repeats-digest-identical".to_string(), identical));
            result.stages_merged = merged
                .iter()
                .map(|(stage, snap)| (stage.clone(), StageQuantiles::of(snap)))
                .collect();
        }
        out.scenarios.push(result);
    }

    let path = opts.out_root.join("summary.json");
    out.write(&path)?;
    println!("loadtest summary written to {}", path.display());
    Ok(out)
}

/// `chon loadtest --check BASELINE [--current CURRENT]`: gate a summary
/// against a baseline, `bench-diff`-style. Prints each violation and
/// errors if any exist.
pub fn check_files(
    baseline: &std::path::Path,
    current: &std::path::Path,
    tol_pct: f64,
    abs_ms: f64,
) -> Result<()> {
    let base = Summary::read(baseline)
        .with_context(|| format!("reading baseline {}", baseline.display()))?;
    let cur = Summary::read(current)
        .with_context(|| format!("reading current {}", current.display()))?;
    let violations = summary::check(&base, &cur, tol_pct, abs_ms);
    if violations.is_empty() {
        println!(
            "loadtest SLO gate passed: {} scenario(s) within {tol_pct}% (+{abs_ms} ms) \
             of baseline",
            cur.scenarios.len()
        );
        return Ok(());
    }
    for v in &violations {
        println!("SLO violation: {v}");
    }
    bail!("{} SLO violation(s) against {}", violations.len(), baseline.display());
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn select_resolves_names_and_rejects_unknown() {
        assert_eq!(select(&[]).unwrap().len(), registry().len());
        let one = select(&["poisson".to_string()]).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0].name, "poisson");
        let err = select(&["nope".to_string()]).unwrap_err().to_string();
        assert!(err.contains("unknown scenario"), "{err}");
        assert!(err.contains("kill_resume"), "lists known names: {err}");
    }
}
