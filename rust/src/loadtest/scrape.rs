//! Parse `/metrics` scrape bodies back into [`HistSnapshot`]s — the
//! server-side half of a scenario's latency picture. The harness never
//! re-derives stage timings: the obs subsystem (PR 7) already measures
//! queue-wait/prefill/decode/flush per model, so the harness scrapes the
//! exposition text and de-cumulates the `_bucket` series. Summing
//! cumulative counts across label sets (models) and then de-cumulating
//! is exactly a bucket-wise snapshot merge, so per-stage histograms roll
//! up across models for the summary.

use std::collections::BTreeMap;

use crate::obs::metrics::{bucket_bound, HistSnapshot, N_BUCKETS, N_FINITE};

/// Split one rendered label blob (the text between `{` and `}`) into
/// (name, value) pairs, honoring the exposition escapes inside values
/// (`\\`, `\"`, `\n`).
pub fn parse_labels(blob: &str) -> Option<Vec<(String, String)>> {
    let mut out = Vec::new();
    let mut chars = blob.chars().peekable();
    loop {
        // label name up to '='
        let mut name = String::new();
        while let Some(&c) = chars.peek() {
            if c == '=' {
                break;
            }
            chars.next();
            if c == ',' || c == '"' {
                return None;
            }
            name.push(c);
        }
        if chars.next() != Some('=') || chars.next() != Some('"') {
            return None;
        }
        let mut value = String::new();
        loop {
            match chars.next()? {
                '"' => break,
                '\\' => match chars.next()? {
                    '\\' => value.push('\\'),
                    '"' => value.push('"'),
                    'n' => value.push('\n'),
                    other => {
                        // unknown escape: keep both chars, like Prometheus
                        value.push('\\');
                        value.push(other);
                    }
                },
                c => value.push(c),
            }
        }
        out.push((name.trim().to_string(), value));
        match chars.next() {
            None => return Some(out),
            Some(',') => continue,
            Some(_) => return None,
        }
    }
}

/// Map a rendered `le` value back to its bucket index (None for a bound
/// that is not one of ours — a scrape from an incompatible server).
fn le_index(le: &str) -> Option<usize> {
    if le == "+Inf" {
        return Some(N_FINITE);
    }
    let bound: u64 = le.parse().ok()?;
    (0..N_FINITE).find(|&i| bucket_bound(i) == bound)
}

/// Reassemble the histograms of `family` from a scrape body, keyed by
/// the value of `key_label` (e.g. `"stage"`), with all other label sets
/// (models) merged together. `_sum` series roll up into the snapshot
/// sums; cumulative `_bucket` counts are summed across label sets first
/// and de-cumulated once at the end, which equals merging the underlying
/// snapshots bucket-wise.
pub fn stage_histograms(
    body: &str,
    family: &str,
    key_label: &str,
) -> BTreeMap<String, HistSnapshot> {
    let bucket_prefix = format!("{family}_bucket{{");
    let sum_prefix = format!("{family}_sum{{");
    // per key: cumulative count per bucket index
    let mut cum: BTreeMap<String, [u64; N_BUCKETS]> = BTreeMap::new();
    let mut sums: BTreeMap<String, u64> = BTreeMap::new();
    for line in body.lines() {
        if line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix(&bucket_prefix) {
            let Some((blob, value)) = rest.rsplit_once("} ") else { continue };
            let Some(labels) = parse_labels(blob) else { continue };
            let Some(key) =
                labels.iter().find(|(k, _)| k == key_label).map(|(_, v)| v.clone())
            else {
                continue;
            };
            let Some(le) = labels.iter().find(|(k, _)| k == "le") else { continue };
            let Some(i) = le_index(&le.1) else { continue };
            let Ok(v) = value.trim().parse::<u64>() else { continue };
            cum.entry(key).or_insert([0; N_BUCKETS])[i] += v;
        } else if let Some(rest) = line.strip_prefix(&sum_prefix) {
            let Some((blob, value)) = rest.rsplit_once("} ") else { continue };
            let Some(labels) = parse_labels(blob) else { continue };
            let Some(key) =
                labels.iter().find(|(k, _)| k == key_label).map(|(_, v)| v.clone())
            else {
                continue;
            };
            if let Ok(v) = value.trim().parse::<u64>() {
                *sums.entry(key).or_insert(0) += v;
            }
        }
    }
    let mut out = BTreeMap::new();
    for (key, cum_buckets) in cum {
        let mut snap = HistSnapshot {
            sum: sums.get(&key).copied().unwrap_or(0),
            ..Default::default()
        };
        let mut prev = 0u64;
        for (i, &c) in cum_buckets.iter().enumerate() {
            snap.buckets[i] = c.saturating_sub(prev);
            prev = c;
        }
        out.insert(key, snap);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::expo::Expo;
    use crate::obs::metrics::Histogram;

    #[test]
    fn labels_parse_with_escapes() {
        let l = parse_labels(r#"model="a\"b\\c",stage="prefill""#).unwrap();
        assert_eq!(l[0], ("model".into(), "a\"b\\c".into()));
        assert_eq!(l[1], ("stage".into(), "prefill".into()));
        assert!(parse_labels("noequals").is_none());
        assert!(parse_labels(r#"k="unterminated"#).is_none());
    }

    /// Render two models' stage histograms through the real exposition
    /// writer, scrape them back, and check the result equals merging the
    /// snapshots directly — the round-trip contract the harness rests on.
    #[test]
    fn scrape_roundtrips_through_expo() {
        let (pa, pb) = (Histogram::new(), Histogram::new());
        let da = Histogram::new();
        for v in [3u64, 90, 4000] {
            pa.record(v);
        }
        for v in [5u64, 5, 1 << 30] {
            pb.record(v);
        }
        da.record(250);
        let mut e = Expo::new();
        e.family("chon_stage_latency_us", "histogram", "stages");
        e.histogram(
            "chon_stage_latency_us",
            &[("model", "a"), ("stage", "prefill")],
            &pa.snapshot(),
        );
        e.histogram(
            "chon_stage_latency_us",
            &[("model", "b"), ("stage", "prefill")],
            &pb.snapshot(),
        );
        e.histogram(
            "chon_stage_latency_us",
            &[("model", "a"), ("stage", "decode_token")],
            &da.snapshot(),
        );
        let body = e.finish();

        let got = stage_histograms(&body, "chon_stage_latency_us", "stage");
        let mut want_prefill = pa.snapshot();
        want_prefill.merge(&pb.snapshot());
        assert_eq!(got["prefill"], want_prefill);
        assert_eq!(got["decode_token"], da.snapshot());
        assert_eq!(got.len(), 2);
        // quantiles work on the reassembled snapshot
        assert!(got["prefill"].quantile(0.5) >= 5);
    }

    #[test]
    fn scrape_ignores_foreign_and_malformed_lines() {
        let body = "\
# TYPE chon_stage_latency_us histogram\n\
chon_stage_latency_us_bucket{model=\"a\",stage=\"prefill\",le=\"1\"} 2\n\
chon_stage_latency_us_bucket{model=\"a\",stage=\"prefill\",le=\"+Inf\"} 2\n\
chon_stage_latency_us_bucket{model=\"a\",stage=\"prefill\",le=\"7\"} 9\n\
chon_stage_latency_us_bucket{model=\"a\",le=\"1\"} 5\n\
chon_other_bucket{stage=\"x\",le=\"1\"} 5\n\
chon_stage_latency_us_sum{model=\"a\",stage=\"prefill\"} 2\n\
garbage\n";
        let got = stage_histograms(body, "chon_stage_latency_us", "stage");
        // le="7" is not a log2 bound and the keyless line has no stage:
        // both ignored; the two valid lines give 2 obs in bucket 0
        assert_eq!(got.len(), 1);
        assert_eq!(got["prefill"].buckets[0], 2);
        assert_eq!(got["prefill"].count(), 2);
        assert_eq!(got["prefill"].sum, 2);
    }
}
