//! Config system: typed run configuration, loadable from a TOML-subset
//! file with CLI `--key value` overrides (the clap/serde substitution).

pub mod toml;

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

/// Top-level run configuration for the coordinator.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// execution engine: "native" (pure Rust, no artifacts needed) or
    /// "pjrt" (AOT HLO via the XLA PJRT C API; needs `--features pjrt`)
    pub backend: String,
    /// directory with *.hlo.txt + *.manifest.txt artifacts (pjrt backend)
    pub artifacts: PathBuf,
    /// model config name, e.g. "tiny_gla" (must exist in artifacts)
    pub model: String,
    /// recipe name, e.g. "chon" / "nvfp4" / "bf16"
    pub recipe: String,
    /// training steps (0 = use the artifact's total_steps)
    pub steps: usize,
    /// run diagnostics every N steps (0 = never)
    pub diag_every: usize,
    /// evaluate every N steps (0 = never)
    pub eval_every: usize,
    /// checkpoint directory (empty = no checkpoints)
    pub checkpoint_dir: Option<PathBuf>,
    /// master seed
    pub seed: u64,
    /// output directory for metric CSVs
    pub out_dir: PathBuf,
    /// worker threads for rust-side compute (sizes the global pool)
    pub threads: usize,
    /// data-parallel shards for native training (clamped to the batch
    /// size; every value produces bit-identical trajectories)
    pub shards: usize,
    /// resume a full training state (params + Adam + step) from this
    /// checkpoint dir before training
    pub resume: Option<PathBuf>,
    /// log training loss every N steps
    pub log_every: usize,
    /// serve/client: TCP host
    pub host: String,
    /// serve/client: TCP port (serve accepts 0 = ephemeral)
    pub port: u16,
    /// serve: max sessions coalesced into one decode batch
    pub max_batch: usize,
    /// serve: how long a fresh batch waits for companions (microseconds)
    pub max_wait_us: u64,
    /// serve: HTTP front-end port (0 = ephemeral; None = HTTP disabled)
    pub http_port: Option<u16>,
    /// serve: max idle named sessions kept in memory (0 = unlimited)
    pub max_resident_sessions: usize,
    /// serve: max KV positions resident across idle sessions (0 = unlimited)
    pub max_kv_tokens: usize,
    /// serve: directory evicted sessions spill to (None = temp dir)
    pub spill_dir: Option<PathBuf>,
    /// serve: registry entries from repeated `--model NAME=DIR` flags
    pub serve_models: Vec<(String, PathBuf)>,
    /// serve: max models with a loaded engine at once (0 = unlimited)
    pub max_resident_models: usize,
    /// serve: min ms between checkpoint generation probes per model
    pub reload_poll_ms: u64,
    /// serve: drop connections idle longer than this (0 = never)
    pub idle_timeout_ms: u64,
    /// serve: cap on concurrently open connections (0 = unlimited)
    pub max_conns: usize,
    /// client: park this many idle connections during a load run
    pub idle_conns: usize,
    /// client: registry model names from `--model NAME[,NAME...]` (load
    /// mode sprays across them; one-shot uses the first)
    pub client_models: Vec<String>,
    /// client: named-session id for one-shot requests (SGEN)
    pub session: Option<String>,
    /// client: total requests in load mode (0 = single-shot)
    pub requests: usize,
    /// client: concurrent load threads
    pub concurrency: usize,
    /// serve/client: per-request generation budget
    pub max_tokens: usize,
    /// client: sampling temperature (0 = greedy)
    pub temp: f32,
    /// client: prompt text
    pub prompt: String,
    /// client: send SHUTDOWN instead of generating
    pub shutdown: bool,
    /// serve: sample per-request HCP hot-channel hits and residual
    /// energy into `/metrics` (small per-token overhead; off by default)
    pub obs_outliers: bool,
    /// serve: keep NVFP4 weights resident as packed 4-bit codes decoded
    /// in-register by the GEMM, with hot channels split into an f32
    /// side-GEMM — a distinct recipe mode vs the fake-quant default
    pub packed_compute: bool,
    /// client: scrape `GET /metrics` on this port before and after the
    /// load run and assert key series exist and increase (0 = off).
    /// train: serve live `GET /metrics` + `GET /progress` from a
    /// listener thread on this port during training (0 = off)
    pub metrics_port: u16,
    /// train/diag: write the crash-durable JSONL run trace
    /// (`<run_dir>/trace.jsonl`); `--no-trace` turns it off
    pub trace: bool,
    /// loadtest: run each scenario this many times and merge the
    /// per-stage latency histograms across repeats (min 1)
    pub repeats: usize,
    /// loadtest: scenario names from repeated `--scenario NAME` flags
    /// (empty = the whole registry)
    pub loadtest_scenarios: Vec<String>,
    /// loadtest: smaller workloads, same scenario coverage (CI smoke)
    pub quick: bool,
    /// loadtest: gate mode — diff a summary against this baseline
    pub loadtest_check: Option<PathBuf>,
    /// loadtest: summary to gate (default: OUT_DIR/loadtest/summary.json)
    pub loadtest_current: Option<PathBuf>,
    /// loadtest gate: latency/RSS tolerance in percent
    pub slo_tolerance: f64,
    /// loadtest gate: absolute latency floor in ms (jitter guard)
    pub slo_abs_ms: f64,
    /// loadtest: artificial client-side per-request latency (ms) — the
    /// gate-validation hook CI uses to prove `--check` catches
    /// regressions; 0 in real runs
    pub inject_latency_ms: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            backend: "native".into(),
            artifacts: PathBuf::from("artifacts"),
            model: "tiny_gla".into(),
            recipe: "chon".into(),
            steps: 0,
            diag_every: 20,
            eval_every: 50,
            checkpoint_dir: None,
            seed: 0,
            out_dir: PathBuf::from("runs"),
            threads: std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4),
            shards: 1,
            resume: None,
            log_every: 10,
            host: "127.0.0.1".into(),
            port: 7411,
            max_batch: 8,
            max_wait_us: 2000,
            http_port: Some(7412),
            max_resident_sessions: 0,
            max_kv_tokens: 0,
            spill_dir: None,
            serve_models: Vec::new(),
            max_resident_models: 0,
            reload_poll_ms: 500,
            idle_timeout_ms: 60_000,
            max_conns: 0,
            idle_conns: 0,
            client_models: Vec::new(),
            session: None,
            requests: 0,
            concurrency: 4,
            max_tokens: 32,
            temp: 0.0,
            prompt: "the ".into(),
            shutdown: false,
            obs_outliers: false,
            packed_compute: false,
            metrics_port: 0,
            trace: true,
            repeats: 1,
            loadtest_scenarios: Vec::new(),
            quick: false,
            loadtest_check: None,
            loadtest_current: None,
            slo_tolerance: 50.0,
            slo_abs_ms: 20.0,
            inject_latency_ms: 0,
        }
    }
}

impl RunConfig {
    /// Load from a TOML file (sections: root + [run]) if it exists.
    pub fn from_file(path: &std::path::Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let doc = toml::parse(&text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let mut cfg = RunConfig::default();
        for section in ["", "run"] {
            cfg.backend = doc.str_or(section, "backend", &cfg.backend).to_string();
            cfg.artifacts = PathBuf::from(doc.str_or(
                section,
                "artifacts",
                cfg.artifacts.to_str().unwrap(),
            ));
            cfg.model = doc.str_or(section, "model", &cfg.model).to_string();
            cfg.recipe = doc.str_or(section, "recipe", &cfg.recipe).to_string();
            cfg.steps = doc.int_or(section, "steps", cfg.steps as i64) as usize;
            cfg.diag_every =
                doc.int_or(section, "diag_every", cfg.diag_every as i64) as usize;
            cfg.eval_every =
                doc.int_or(section, "eval_every", cfg.eval_every as i64) as usize;
            cfg.seed = doc.int_or(section, "seed", cfg.seed as i64) as u64;
            cfg.out_dir = PathBuf::from(doc.str_or(
                section,
                "out_dir",
                cfg.out_dir.to_str().unwrap(),
            ));
            cfg.threads = doc.int_or(section, "threads", cfg.threads as i64) as usize;
            cfg.shards = doc.int_or(section, "shards", cfg.shards as i64) as usize;
            cfg.log_every =
                doc.int_or(section, "log_every", cfg.log_every as i64) as usize;
            if let Some(v) = doc.get(section, "checkpoint_dir").and_then(|v| v.as_str())
            {
                cfg.checkpoint_dir = Some(PathBuf::from(v));
            }
            if let Some(v) = doc.get(section, "resume").and_then(|v| v.as_str()) {
                cfg.resume = Some(PathBuf::from(v));
            }
        }
        Ok(cfg)
    }

    /// Apply `--key value` style overrides (the CLI surface).
    pub fn apply_args(&mut self, args: &[String]) -> Result<()> {
        let mut it = args.iter();
        while let Some(arg) = it.next() {
            let Some(key) = arg.strip_prefix("--") else {
                bail!("unexpected argument {arg:?} (expected --key value)");
            };
            let mut next = || {
                it.next()
                    .cloned()
                    .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))
            };
            match key {
                "backend" => self.backend = next()?,
                "artifacts" => self.artifacts = PathBuf::from(next()?),
                // --model is overloaded by subcommand: `NAME=DIR`
                // registers a serve model; a plain value is the train
                // model-config name and doubles as the client's routing
                // name list (comma-separated for load-mode spraying)
                "model" => {
                    let v = next()?;
                    if let Some((name, dir)) = v.split_once('=') {
                        if name.is_empty() || dir.is_empty() {
                            bail!("--model NAME=DIR needs both parts, got {v:?}");
                        }
                        if !crate::serve::protocol::valid_model_name(name) {
                            bail!(
                                "bad model name {name:?} in --model (want \
                                 1..=64 of [A-Za-z0-9._-], not starting \
                                 with '.' or '-')"
                            );
                        }
                        self.serve_models
                            .push((name.to_string(), PathBuf::from(dir)));
                    } else {
                        // a typo like "alpha," or "a,,b" would otherwise
                        // spray requests at an empty model name and only
                        // surface as per-request failures server-side
                        let names: Vec<String> =
                            v.split(',').map(|s| s.to_string()).collect();
                        for n in &names {
                            if !crate::serve::protocol::valid_model_name(n) {
                                bail!(
                                    "bad model name {n:?} in --model {v:?} \
                                     (empty entries and [^A-Za-z0-9._-] are \
                                     rejected)"
                                );
                            }
                        }
                        self.model = v.clone();
                        self.client_models = names;
                    }
                }
                "recipe" => self.recipe = next()?,
                "steps" => self.steps = next()?.parse()?,
                "diag-every" => self.diag_every = next()?.parse()?,
                "eval-every" => self.eval_every = next()?.parse()?,
                "seed" => self.seed = next()?.parse()?,
                "out-dir" => self.out_dir = PathBuf::from(next()?),
                "threads" => self.threads = next()?.parse()?,
                "shards" => self.shards = next()?.parse()?,
                "resume" => self.resume = Some(PathBuf::from(next()?)),
                "log-every" => self.log_every = next()?.parse()?,
                // --checkpoint is the serve-side spelling of the same dir
                "checkpoint-dir" | "checkpoint" => {
                    self.checkpoint_dir = Some(PathBuf::from(next()?))
                }
                "host" => self.host = next()?,
                "port" => self.port = next()?.parse()?,
                "max-batch" => self.max_batch = next()?.parse()?,
                "max-wait-us" => self.max_wait_us = next()?.parse()?,
                // "off"/"none" disables the HTTP front end entirely
                "http-port" => {
                    let v = next()?;
                    self.http_port = match v.as_str() {
                        "off" | "none" => None,
                        p => Some(p.parse()?),
                    };
                }
                "max-resident-sessions" => {
                    self.max_resident_sessions = next()?.parse()?
                }
                "max-kv-tokens" => self.max_kv_tokens = next()?.parse()?,
                "spill-dir" => self.spill_dir = Some(PathBuf::from(next()?)),
                "max-resident-models" => {
                    self.max_resident_models = next()?.parse()?
                }
                "reload-poll-ms" => self.reload_poll_ms = next()?.parse()?,
                "idle-timeout-ms" => self.idle_timeout_ms = next()?.parse()?,
                "max-conns" => self.max_conns = next()?.parse()?,
                "idle-conns" => self.idle_conns = next()?.parse()?,
                "session" => self.session = Some(next()?),
                "requests" => self.requests = next()?.parse()?,
                "concurrency" => self.concurrency = next()?.parse()?,
                "max-tokens" => self.max_tokens = next()?.parse()?,
                "temp" => self.temp = next()?.parse()?,
                "prompt" => self.prompt = next()?,
                // value-less flag: nothing to consume
                "shutdown" => self.shutdown = true,
                // value-less flag: nothing to consume
                "obs-outliers" => self.obs_outliers = true,
                // value-less flag: nothing to consume
                "packed-compute" => self.packed_compute = true,
                "metrics-port" => self.metrics_port = next()?.parse()?,
                // value-less flag: nothing to consume
                "no-trace" => self.trace = false,
                "repeats" => self.repeats = next()?.parse::<usize>()?.max(1),
                "scenario" => self.loadtest_scenarios.push(next()?),
                // value-less flag: nothing to consume
                "quick" => self.quick = true,
                "check" => self.loadtest_check = Some(PathBuf::from(next()?)),
                "current" => self.loadtest_current = Some(PathBuf::from(next()?)),
                "tolerance" => self.slo_tolerance = next()?.parse()?,
                "abs-ms" => self.slo_abs_ms = next()?.parse()?,
                "inject-latency-ms" => self.inject_latency_ms = next()?.parse()?,
                "config" => {
                    let loaded = RunConfig::from_file(&PathBuf::from(next()?))?;
                    *self = loaded;
                }
                _ => bail!("unknown flag --{key}"),
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_sane() {
        let c = RunConfig::default();
        assert_eq!(c.model, "tiny_gla");
        assert_eq!(c.backend, "native");
        assert!(c.threads >= 1);
    }

    #[test]
    fn backend_flag_parses() {
        let mut c = RunConfig::default();
        c.apply_args(&["--backend".into(), "pjrt".into()]).unwrap();
        assert_eq!(c.backend, "pjrt");
    }

    #[test]
    fn cli_overrides() {
        let mut c = RunConfig::default();
        c.apply_args(&[
            "--model".into(),
            "tiny_sa".into(),
            "--steps".into(),
            "123".into(),
            "--recipe".into(),
            "nvfp4".into(),
        ])
        .unwrap();
        assert_eq!(c.model, "tiny_sa");
        assert_eq!(c.steps, 123);
        assert_eq!(c.recipe, "nvfp4");
    }

    #[test]
    fn serve_flags_parse() {
        let mut c = RunConfig::default();
        c.apply_args(&[
            "--checkpoint".into(),
            "ckpts".into(),
            "--port".into(),
            "0".into(),
            "--max-batch".into(),
            "16".into(),
            "--max-wait-us".into(),
            "500".into(),
            "--requests".into(),
            "32".into(),
            "--concurrency".into(),
            "8".into(),
            "--temp".into(),
            "0.7".into(),
            "--shutdown".into(),
        ])
        .unwrap();
        assert_eq!(c.checkpoint_dir.as_deref(), Some(std::path::Path::new("ckpts")));
        assert_eq!(c.port, 0);
        assert_eq!(c.max_batch, 16);
        assert_eq!(c.max_wait_us, 500);
        assert_eq!(c.requests, 32);
        assert_eq!(c.concurrency, 8);
        assert_eq!(c.temp, 0.7);
        assert!(c.shutdown);
    }

    #[test]
    fn serve_v2_flags_parse() {
        let mut c = RunConfig::default();
        assert_eq!(c.http_port, Some(7412));
        c.apply_args(&[
            "--http-port".into(),
            "0".into(),
            "--max-resident-sessions".into(),
            "2".into(),
            "--max-kv-tokens".into(),
            "4096".into(),
            "--spill-dir".into(),
            "/tmp/spill".into(),
            "--session".into(),
            "conv1".into(),
        ])
        .unwrap();
        assert_eq!(c.http_port, Some(0));
        assert_eq!(c.max_resident_sessions, 2);
        assert_eq!(c.max_kv_tokens, 4096);
        assert_eq!(c.spill_dir.as_deref(), Some(std::path::Path::new("/tmp/spill")));
        assert_eq!(c.session.as_deref(), Some("conv1"));
        c.apply_args(&["--http-port".into(), "off".into()]).unwrap();
        assert_eq!(c.http_port, None);
    }

    #[test]
    fn registry_flags_parse() {
        let mut c = RunConfig::default();
        c.apply_args(&[
            "--model".into(),
            "alpha=/ckpts/a".into(),
            "--model".into(),
            "beta=/ckpts/b".into(),
            "--max-resident-models".into(),
            "1".into(),
            "--reload-poll-ms".into(),
            "0".into(),
        ])
        .unwrap();
        assert_eq!(
            c.serve_models,
            vec![
                ("alpha".to_string(), PathBuf::from("/ckpts/a")),
                ("beta".to_string(), PathBuf::from("/ckpts/b")),
            ]
        );
        assert_eq!(c.max_resident_models, 1);
        assert_eq!(c.reload_poll_ms, 0);
        // train-style plain value still lands in cfg.model, and doubles
        // as the client's (comma-separated) routing list
        assert_eq!(c.model, "tiny_gla");
        c.apply_args(&["--model".into(), "alpha,beta".into()]).unwrap();
        assert_eq!(c.model, "alpha,beta");
        assert_eq!(c.client_models, vec!["alpha", "beta"]);
        // both halves of NAME=DIR are required
        assert!(c.apply_args(&["--model".into(), "=dir".into()]).is_err());
        assert!(c.apply_args(&["--model".into(), "name=".into()]).is_err());
        // names are validated at parse time: a trailing comma (empty
        // entry) or a path-unsafe registry name is an immediate CLI
        // error, not a fraction of failed requests later
        assert!(c.apply_args(&["--model".into(), "alpha,".into()]).is_err());
        assert!(c.apply_args(&["--model".into(), "a,,b".into()]).is_err());
        assert!(c.apply_args(&["--model".into(), "bad/name=/x".into()]).is_err());
    }

    #[test]
    fn reactor_flags_parse() {
        let mut c = RunConfig::default();
        assert_eq!(c.idle_timeout_ms, 60_000);
        assert_eq!(c.max_conns, 0);
        assert_eq!(c.idle_conns, 0);
        c.apply_args(&[
            "--idle-timeout-ms".into(),
            "5000".into(),
            "--max-conns".into(),
            "2048".into(),
            "--idle-conns".into(),
            "1000".into(),
        ])
        .unwrap();
        assert_eq!(c.idle_timeout_ms, 5000);
        assert_eq!(c.max_conns, 2048);
        assert_eq!(c.idle_conns, 1000);
    }

    #[test]
    fn shards_and_resume_flags_parse() {
        let mut c = RunConfig::default();
        assert_eq!(c.shards, 1);
        c.apply_args(&[
            "--shards".into(),
            "4".into(),
            "--resume".into(),
            "ckpts/run".into(),
        ])
        .unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.resume.as_deref(), Some(std::path::Path::new("ckpts/run")));
    }

    #[test]
    fn obs_flags_parse() {
        let mut c = RunConfig::default();
        assert!(!c.obs_outliers);
        assert_eq!(c.metrics_port, 0);
        c.apply_args(&[
            "--obs-outliers".into(),
            "--metrics-port".into(),
            "7412".into(),
        ])
        .unwrap();
        assert!(c.obs_outliers);
        assert_eq!(c.metrics_port, 7412);
    }

    #[test]
    fn trace_flag_parses() {
        let mut c = RunConfig::default();
        assert!(c.trace, "tracing is on by default");
        c.apply_args(&["--no-trace".into()]).unwrap();
        assert!(!c.trace);
    }

    #[test]
    fn packed_compute_flag_parses() {
        let mut c = RunConfig::default();
        assert!(!c.packed_compute);
        c.apply_args(&["--packed-compute".into()]).unwrap();
        assert!(c.packed_compute);
    }

    #[test]
    fn loadtest_flags_parse() {
        let mut c = RunConfig::default();
        assert!(c.loadtest_scenarios.is_empty());
        assert!(!c.quick);
        assert_eq!(c.repeats, 1);
        assert_eq!(c.slo_tolerance, 50.0);
        assert_eq!(c.slo_abs_ms, 20.0);
        assert_eq!(c.inject_latency_ms, 0);
        c.apply_args(&[
            "--scenario".into(),
            "fanout".into(),
            "--scenario".into(),
            "poisson".into(),
            "--quick".into(),
            "--check".into(),
            "base/summary.json".into(),
            "--current".into(),
            "cur/summary.json".into(),
            "--tolerance".into(),
            "35".into(),
            "--abs-ms".into(),
            "10".into(),
            "--inject-latency-ms".into(),
            "150".into(),
            "--repeats".into(),
            "3".into(),
        ])
        .unwrap();
        assert_eq!(c.loadtest_scenarios, vec!["fanout", "poisson"]);
        assert_eq!(c.repeats, 3);
        // 0 would silently skip every scenario — clamp to 1 at parse
        c.apply_args(&["--repeats".into(), "0".into()]).unwrap();
        assert_eq!(c.repeats, 1);
        assert!(c.quick);
        assert_eq!(
            c.loadtest_check.as_deref(),
            Some(std::path::Path::new("base/summary.json"))
        );
        assert_eq!(
            c.loadtest_current.as_deref(),
            Some(std::path::Path::new("cur/summary.json"))
        );
        assert_eq!(c.slo_tolerance, 35.0);
        assert_eq!(c.slo_abs_ms, 10.0);
        assert_eq!(c.inject_latency_ms, 150);
    }

    #[test]
    fn rejects_unknown_flags() {
        let mut c = RunConfig::default();
        assert!(c.apply_args(&["--bogus".into(), "1".into()]).is_err());
        assert!(c.apply_args(&["positional".into()]).is_err());
    }

    #[test]
    fn from_file_roundtrip() {
        let dir = std::env::temp_dir().join("chon_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("run.toml");
        std::fs::write(
            &p,
            "[run]\nmodel = \"tiny_sa\"\nsteps = 42\nrecipe = \"bf16\"\n",
        )
        .unwrap();
        let c = RunConfig::from_file(&p).unwrap();
        assert_eq!(c.model, "tiny_sa");
        assert_eq!(c.steps, 42);
        assert_eq!(c.recipe, "bf16");
    }
}
