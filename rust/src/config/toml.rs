//! Hand-rolled TOML-subset parser (the offline vendor set has no serde /
//! toml crates — DESIGN.md §Substitutions).
//!
//! Supported: `[section]` headers, `key = value` with string ("..."),
//! integer, float, boolean and flat string/number arrays, `#` comments.
//! Enough for run configs; nested tables are spelled [a.b].

use std::collections::BTreeMap;

/// Parse failure with its 1-based line number (hand-rolled; `thiserror`
/// is not in the offline vendor set).
#[derive(Debug)]
pub enum TomlError {
    Parse(usize, String),
}

impl std::fmt::Display for TomlError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            TomlError::Parse(line, msg) => write!(f, "line {line}: {msg}"),
        }
    }
}

impl std::error::Error for TomlError {}

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }
    pub fn as_float(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// A parsed document: section -> key -> value. Root keys live in "".
#[derive(Clone, Debug, Default)]
pub struct Doc {
    pub sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Doc {
    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section)?.get(key)
    }

    pub fn str_or<'a>(&'a self, section: &str, key: &str, default: &'a str) -> &'a str {
        self.get(section, key).and_then(Value::as_str).unwrap_or(default)
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        self.get(section, key).and_then(Value::as_int).unwrap_or(default)
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        self.get(section, key).and_then(Value::as_float).unwrap_or(default)
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        self.get(section, key).and_then(Value::as_bool).unwrap_or(default)
    }
}

fn parse_scalar(s: &str, lineno: usize) -> Result<Value, TomlError> {
    let s = s.trim();
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| TomlError::Parse(lineno, "unterminated string".into()))?;
        return Ok(Value::Str(inner.replace("\\\"", "\"").replace("\\\\", "\\")));
    }
    if s == "true" {
        return Ok(Value::Bool(true));
    }
    if s == "false" {
        return Ok(Value::Bool(false));
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    Err(TomlError::Parse(lineno, format!("cannot parse value {s:?}")))
}

fn strip_comment(line: &str) -> &str {
    // '#' starts a comment unless inside a string
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

pub fn parse(text: &str) -> Result<Doc, TomlError> {
    let mut doc = Doc::default();
    let mut section = String::new();
    doc.sections.entry(section.clone()).or_default();
    for (i, raw) in text.lines().enumerate() {
        let lineno = i + 1;
        let line = strip_comment(raw).trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[') {
            let name = name
                .strip_suffix(']')
                .ok_or_else(|| TomlError::Parse(lineno, "bad section header".into()))?;
            section = name.trim().to_string();
            doc.sections.entry(section.clone()).or_default();
            continue;
        }
        let (key, val) = line
            .split_once('=')
            .ok_or_else(|| TomlError::Parse(lineno, "expected key = value".into()))?;
        let key = key.trim().to_string();
        let val = val.trim();
        let value = if let Some(body) = val.strip_prefix('[') {
            let body = body
                .strip_suffix(']')
                .ok_or_else(|| TomlError::Parse(lineno, "unterminated array".into()))?;
            let items: Result<Vec<Value>, _> = body
                .split(',')
                .map(str::trim)
                .filter(|s| !s.is_empty())
                .map(|s| parse_scalar(s, lineno))
                .collect();
            Value::Array(items?)
        } else {
            parse_scalar(val, lineno)?
        };
        doc.sections.get_mut(&section).unwrap().insert(key, value);
    }
    Ok(doc)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = parse(
            r#"
# run config
name = "tab2"        # inline comment
steps = 300
lr = 1.0e-3
verbose = true

[model]
arch = "gla"
dims = [64, 128]
"#,
        )
        .unwrap();
        assert_eq!(doc.str_or("", "name", ""), "tab2");
        assert_eq!(doc.int_or("", "steps", 0), 300);
        assert!((doc.float_or("", "lr", 0.0) - 1e-3).abs() < 1e-12);
        assert!(doc.bool_or("", "verbose", false));
        assert_eq!(doc.str_or("model", "arch", ""), "gla");
        match doc.get("model", "dims").unwrap() {
            Value::Array(v) => assert_eq!(v.len(), 2),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn defaults_on_missing() {
        let doc = parse("").unwrap();
        assert_eq!(doc.int_or("x", "y", 7), 7);
        assert_eq!(doc.str_or("", "nope", "d"), "d");
    }

    #[test]
    fn string_with_hash() {
        let doc = parse("k = \"a # b\"").unwrap();
        assert_eq!(doc.str_or("", "k", ""), "a # b");
    }

    #[test]
    fn errors_are_reported_with_lines() {
        assert!(parse("nonsense").is_err());
        assert!(parse("k = @@").is_err());
        assert!(parse("[unclosed").is_err());
    }

    #[test]
    fn int_promotes_to_float() {
        let doc = parse("x = 3").unwrap();
        assert_eq!(doc.float_or("", "x", 0.0), 3.0);
    }
}
