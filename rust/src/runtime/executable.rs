//! PJRT executables (`--features pjrt`): HLO text -> PJRT executable +
//! manifest, with a shape-checked execute. One global CPU client per
//! thread (PJRT clients are heavy).
//!
//! This module is the `xla::*`-touching half of the runtime and is gated
//! behind the `pjrt` cargo feature; the offline default build runs the
//! native engine only (runtime::native).

use std::cell::RefCell;
use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::rc::Rc;

use anyhow::{bail, Context, Result};

use crate::runtime::artifact::Manifest;
use crate::runtime::backend::{check_inputs, Backend, Executable};
use crate::runtime::tensor::HostTensor;

thread_local! {
    // PjRtClient is Rc-backed (not Sync): one client per thread. The
    // coordinator drives all PJRT work from a single thread; rust-side
    // compute threads never touch the client.
    static CLIENT: std::cell::RefCell<Option<xla::PjRtClient>> =
        const { std::cell::RefCell::new(None) };
}

/// The thread's PJRT CPU client (created on first use).
pub fn client() -> Result<xla::PjRtClient> {
    CLIENT.with(|slot| {
        let mut slot = slot.borrow_mut();
        if slot.is_none() {
            *slot = Some(xla::PjRtClient::cpu().context("creating PJRT CPU client")?);
        }
        Ok(slot.as_ref().unwrap().clone())
    })
}

thread_local! {
    // Compiled-executable memo: XLA compiles are expensive (seconds to
    // minutes on a single core); ablation/bench flows reuse artifacts.
    static EXE_CACHE: RefCell<HashMap<(PathBuf, String), Rc<LoadedArtifact>>> =
        RefCell::new(HashMap::new());
}

/// A compiled artifact ready to execute.
pub struct LoadedArtifact {
    pub manifest: Manifest,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedArtifact {
    /// Like `load`, but memoized per (dir, name) for this thread.
    pub fn load_cached(dir: &Path, name: &str) -> Result<Rc<LoadedArtifact>> {
        let key = (dir.to_path_buf(), name.to_string());
        if let Some(hit) = EXE_CACHE.with(|c| c.borrow().get(&key).cloned()) {
            return Ok(hit);
        }
        let loaded = Rc::new(Self::load(dir, name)?);
        EXE_CACHE.with(|c| c.borrow_mut().insert(key, loaded.clone()));
        Ok(loaded)
    }

    /// Load `<dir>/<name>.hlo.txt` (+ manifest), compile on the CPU client.
    pub fn load(dir: &Path, name: &str) -> Result<LoadedArtifact> {
        let manifest = Manifest::load(dir, name)?;
        let hlo = manifest.hlo_path(dir);
        let proto = xla::HloModuleProto::from_text_file(&hlo)
            .with_context(|| format!("parsing HLO text {}", hlo.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client()?
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        Ok(LoadedArtifact { manifest, exe })
    }

    /// Execute with host tensors; returns outputs in manifest order.
    ///
    /// Inputs are validated against the manifest (count, dtype, shape) so
    /// coordinator bugs surface as errors, not XLA crashes.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        check_inputs(&self.manifest, inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .map(HostTensor::to_literal)
            .collect::<Result<_>>()?;
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        // aot.py lowers with return_tuple=True: single tuple of all outputs.
        let parts = tuple.to_tuple().context("untupling result")?;
        if parts.len() != self.manifest.outputs.len() {
            bail!(
                "{}: got {} outputs, manifest expects {}",
                self.manifest.name,
                parts.len(),
                self.manifest.outputs.len()
            );
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

impl Executable for LoadedArtifact {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        LoadedArtifact::run(self, inputs)
    }
}

/// The PJRT execution engine.
pub struct PjrtBackend;

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn manifest(&self, dir: &Path, name: &str) -> Result<Manifest> {
        Manifest::load(dir, name)
    }

    fn load(&self, dir: &Path, name: &str) -> Result<Rc<dyn Executable>> {
        Ok(LoadedArtifact::load_cached(dir, name)?)
    }
}
