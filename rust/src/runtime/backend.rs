//! The pluggable execution engine behind the coordinator.
//!
//! `Executable` mirrors `LoadedArtifact`'s surface (manifest + shape-checked
//! run), `Backend` resolves artifact names to executables. Two engines:
//!
//! * `NativeBackend` (runtime::native) — the tiny GLA/SA training step in
//!   pure Rust over the util::ndarray + quant + hcp substrates. Needs no
//!   artifacts directory, no libxla, works on a fresh offline checkout.
//! * `PjrtBackend` (`--features pjrt`) — the original AOT-HLO path through
//!   the XLA PJRT C API.
//!
//! Both validate inputs against the manifest via `check_inputs`, so
//! coordinator bugs surface as errors regardless of engine.

use std::path::Path;
use std::rc::Rc;

use anyhow::{bail, Result};

use crate::runtime::artifact::Manifest;
use crate::runtime::tensor::HostTensor;

/// One loaded artifact: self-describing metadata + execute.
pub trait Executable {
    /// The artifact's manifest (shapes, meta, metric names).
    fn manifest(&self) -> &Manifest;

    /// Execute with host tensors; returns outputs in manifest order.
    fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>>;
}

/// An execution engine that resolves artifact names.
pub trait Backend {
    /// Engine name ("native" / "pjrt").
    fn name(&self) -> &'static str;

    /// Cheap manifest-only lookup — no model build, no XLA compile.
    fn manifest(&self, dir: &Path, name: &str) -> Result<Manifest>;

    /// Load (and for PJRT: compile) the named artifact.
    fn load(&self, dir: &Path, name: &str) -> Result<Rc<dyn Executable>>;
}

/// Resolve a backend by name (the `--backend` CLI flag).
pub fn backend_for(kind: &str) -> Result<Box<dyn Backend>> {
    match kind {
        "native" => Ok(Box::new(crate::runtime::native::NativeBackend)),
        #[cfg(feature = "pjrt")]
        "pjrt" => Ok(Box::new(crate::runtime::executable::PjrtBackend)),
        #[cfg(not(feature = "pjrt"))]
        "pjrt" => bail!(
            "backend \"pjrt\" requires building with --features pjrt \
             (see rust/README.md); the default build is native-only"
        ),
        other => bail!("unknown backend {other:?} (expected native|pjrt)"),
    }
}

/// Validate inputs against the manifest (count, dtype, shape).
pub fn check_inputs(man: &Manifest, inputs: &[HostTensor]) -> Result<()> {
    if inputs.len() != man.inputs.len() {
        bail!(
            "{}: got {} inputs, manifest expects {}",
            man.name,
            inputs.len(),
            man.inputs.len()
        );
    }
    for (t, slot) in inputs.iter().zip(&man.inputs) {
        if t.shape != slot.shape || t.dtype != slot.dtype {
            bail!(
                "{}: input {} ({}) expects {:?}{:?}, got {:?}{:?}",
                man.name,
                slot.index,
                slot.name,
                slot.dtype,
                slot.shape,
                t.dtype,
                t.shape
            );
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::tensor::DType;

    #[test]
    fn backend_factory_resolves_native() {
        let b = backend_for("native").unwrap();
        assert_eq!(b.name(), "native");
    }

    #[test]
    fn backend_factory_rejects_unknown() {
        assert!(backend_for("tpu").is_err());
    }

    #[cfg(not(feature = "pjrt"))]
    #[test]
    fn pjrt_unavailable_without_feature() {
        let err = backend_for("pjrt").unwrap_err().to_string();
        assert!(err.contains("--features pjrt"), "{err}");
    }

    #[test]
    fn check_inputs_catches_count_and_shape() {
        let man = Manifest::parse(
            "artifact t\ninput 0 a f32 2,2\ninput 1 b i32 scalar\noutput 0 y f32 scalar\n",
        )
        .unwrap();
        let good = vec![
            HostTensor::f32(vec![2, 2], vec![0.0; 4]),
            HostTensor::scalar_i32(1),
        ];
        assert!(check_inputs(&man, &good).is_ok());
        assert!(check_inputs(&man, &good[..1]).is_err());
        let bad_shape = vec![
            HostTensor::f32(vec![4], vec![0.0; 4]),
            HostTensor::scalar_i32(1),
        ];
        assert!(check_inputs(&man, &bad_shape).is_err());
        let bad_dtype = vec![
            HostTensor::i32(vec![2, 2], vec![0; 4]),
            HostTensor::scalar_i32(1),
        ];
        assert!(check_inputs(&man, &bad_dtype).is_err());
        let _ = DType::F32;
    }
}
