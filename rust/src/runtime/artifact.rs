//! Artifact manifests: the self-describing metadata emitted next to each
//! HLO text file by python/compile/aot.py.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::runtime::tensor::DType;

/// One positional input/output slot of an artifact.
#[derive(Clone, Debug)]
pub struct Slot {
    pub index: usize,
    pub name: String,
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl Slot {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// Parsed <name>.manifest.txt.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub name: String,
    pub meta: BTreeMap<String, String>,
    pub inputs: Vec<Slot>,
    pub outputs: Vec<Slot>,
    /// diag metric slot names (empty for non-diag artifacts)
    pub metrics: Vec<String>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut name = String::new();
        let mut meta = BTreeMap::new();
        let mut inputs = Vec::new();
        let mut outputs = Vec::new();
        let mut metrics = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut parts = line.splitn(2, ' ');
            let key = parts.next().unwrap();
            let rest = parts.next().unwrap_or("");
            match key {
                "artifact" => name = rest.to_string(),
                "input" | "output" => {
                    let fields: Vec<&str> = rest.split(' ').collect();
                    if fields.len() != 4 {
                        bail!("manifest line {}: bad slot: {line}", lineno + 1);
                    }
                    let slot = Slot {
                        index: fields[0].parse()?,
                        name: fields[1].to_string(),
                        dtype: DType::parse(fields[2])?,
                        shape: if fields[3] == "scalar" {
                            vec![]
                        } else {
                            fields[3]
                                .split(',')
                                .map(|d| d.parse::<usize>().map_err(Into::into))
                                .collect::<Result<Vec<_>>>()?
                        },
                    };
                    if key == "input" {
                        inputs.push(slot);
                    } else {
                        outputs.push(slot);
                    }
                }
                "metric" => metrics.push(rest.to_string()),
                _ => {
                    meta.insert(key.to_string(), rest.to_string());
                }
            }
        }
        if name.is_empty() {
            bail!("manifest missing 'artifact' line");
        }
        Ok(Manifest { name, meta, inputs, outputs, metrics })
    }

    pub fn load(dir: &Path, name: &str) -> Result<Manifest> {
        let p = dir.join(format!("{name}.manifest.txt"));
        let text = std::fs::read_to_string(&p)
            .with_context(|| format!("reading manifest {}", p.display()))?;
        Manifest::parse(&text)
    }

    pub fn hlo_path(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.hlo.txt", self.name))
    }

    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .with_context(|| format!("manifest {} missing meta {key}", self.name))?
            .parse()
            .with_context(|| format!("meta {key} not an integer"))
    }

    pub fn meta_str(&self, key: &str) -> &str {
        self.meta.get(key).map(String::as_str).unwrap_or("")
    }

    /// Input slots whose names start with `prefix` (e.g. "params").
    pub fn inputs_with_prefix(&self, prefix: &str) -> Vec<&Slot> {
        self.inputs
            .iter()
            .filter(|s| s.name.starts_with(prefix))
            .collect()
    }

    /// Find an output slot index by exact name.
    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
artifact train_tiny_gla_chon
kind train
model tiny_gla
vocab 256
input 0 params['embed'] f32 256,64
input 1 step i32 scalar
output 0 out[0]['embed'] f32 256,64
output 1 out[3] f32 scalar
metric L0.attn.q.act.kurt
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "train_tiny_gla_chon");
        assert_eq!(m.meta_str("kind"), "train");
        assert_eq!(m.meta_usize("vocab").unwrap(), 256);
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.inputs[0].shape, vec![256, 64]);
        assert_eq!(m.inputs[1].shape, Vec::<usize>::new());
        assert_eq!(m.inputs[1].dtype, DType::I32);
        assert_eq!(m.outputs.len(), 2);
        assert_eq!(m.metrics, vec!["L0.attn.q.act.kurt"]);
        assert_eq!(m.inputs_with_prefix("params").len(), 1);
        assert_eq!(m.output_index("out[3]"), Some(1));
    }

    #[test]
    fn rejects_missing_name() {
        assert!(Manifest::parse("kind train\n").is_err());
    }

    #[test]
    fn rejects_malformed_slot() {
        assert!(Manifest::parse("artifact x\ninput 0 y f32\n").is_err());
    }
}
