//! Host tensors and the checkpoint binary format (magic + dtype + shape +
//! raw data per tensor). The xla::Literal conversions are gated behind
//! the `pjrt` feature — the default offline build never touches XLA.

use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element type of a host tensor.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }
}

/// A dense host tensor (row-major).
#[derive(Clone, Debug)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    pub f32_data: Vec<f32>,
    pub i32_data: Vec<i32>,
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { dtype: DType::F32, shape, f32_data: data, i32_data: Vec::new() }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        HostTensor { dtype: DType::I32, shape, f32_data: Vec::new(), i32_data: data }
    }

    pub fn scalar_i32(v: i32) -> Self {
        HostTensor::i32(vec![], vec![v])
    }

    pub fn scalar_f32(v: f32) -> Self {
        HostTensor::f32(vec![], vec![v])
    }

    pub fn zeros(dtype: DType, shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        match dtype {
            DType::F32 => HostTensor::f32(shape, vec![0.0; n]),
            DType::I32 => HostTensor::i32(shape, vec![0; n]),
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Convert into an xla Literal (reshaped to the tensor's shape).
    #[cfg(feature = "pjrt")]
    pub fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match self.dtype {
            DType::F32 => xla::Literal::vec1(&self.f32_data),
            DType::I32 => xla::Literal::vec1(&self.i32_data),
        };
        if self.shape.is_empty() {
            // scalar: vec1 of len 1 -> reshape to rank 0
            Ok(lit.reshape(&[])?)
        } else {
            Ok(lit.reshape(&dims)?)
        }
    }

    /// Read a Literal back into a host tensor.
    #[cfg(feature = "pjrt")]
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal has no array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                Ok(HostTensor::f32(dims, lit.to_vec::<f32>()?))
            }
            xla::ElementType::S32 => {
                Ok(HostTensor::i32(dims, lit.to_vec::<i32>()?))
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

/// Write a list of named tensors to a checkpoint file.
pub fn save_checkpoint(path: &Path, tensors: &[(String, HostTensor)]) -> Result<()> {
    let mut f = std::io::BufWriter::new(
        std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?,
    );
    f.write_all(b"CHONCKPT")?;
    f.write_all(&(tensors.len() as u32).to_le_bytes())?;
    for (name, t) in tensors {
        let nb = name.as_bytes();
        f.write_all(&(nb.len() as u32).to_le_bytes())?;
        f.write_all(nb)?;
        f.write_all(&[match t.dtype {
            DType::F32 => 0u8,
            DType::I32 => 1u8,
        }])?;
        f.write_all(&(t.shape.len() as u32).to_le_bytes())?;
        for &d in &t.shape {
            f.write_all(&(d as u64).to_le_bytes())?;
        }
        match t.dtype {
            DType::F32 => {
                for &v in &t.f32_data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
            DType::I32 => {
                for &v in &t.i32_data {
                    f.write_all(&v.to_le_bytes())?;
                }
            }
        }
    }
    Ok(())
}

/// Load a checkpoint file.
pub fn load_checkpoint(path: &Path) -> Result<Vec<(String, HostTensor)>> {
    let mut f = std::io::BufReader::new(
        std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?,
    );
    let mut magic = [0u8; 8];
    f.read_exact(&mut magic)?;
    if &magic != b"CHONCKPT" {
        bail!("bad checkpoint magic in {}", path.display());
    }
    let mut u32buf = [0u8; 4];
    f.read_exact(&mut u32buf)?;
    let count = u32::from_le_bytes(u32buf) as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        f.read_exact(&mut u32buf)?;
        let nlen = u32::from_le_bytes(u32buf) as usize;
        let mut nbuf = vec![0u8; nlen];
        f.read_exact(&mut nbuf)?;
        let name = String::from_utf8(nbuf)?;
        let mut tag = [0u8; 1];
        f.read_exact(&mut tag)?;
        f.read_exact(&mut u32buf)?;
        let rank = u32::from_le_bytes(u32buf) as usize;
        let mut shape = Vec::with_capacity(rank);
        let mut u64buf = [0u8; 8];
        for _ in 0..rank {
            f.read_exact(&mut u64buf)?;
            shape.push(u64::from_le_bytes(u64buf) as usize);
        }
        let n: usize = shape.iter().product();
        let t = match tag[0] {
            0 => {
                let mut data = vec![0f32; n];
                for v in data.iter_mut() {
                    f.read_exact(&mut u32buf)?;
                    *v = f32::from_le_bytes(u32buf);
                }
                HostTensor::f32(shape, data)
            }
            1 => {
                let mut data = vec![0i32; n];
                for v in data.iter_mut() {
                    f.read_exact(&mut u32buf)?;
                    *v = i32::from_le_bytes(u32buf);
                }
                HostTensor::i32(shape, data)
            }
            other => bail!("bad dtype tag {other}"),
        };
        out.push((name, t));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checkpoint_roundtrip() {
        let dir = std::env::temp_dir().join("chon_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("test.ckpt");
        let tensors = vec![
            ("a".to_string(), HostTensor::f32(vec![2, 3], vec![1., 2., 3., 4., 5., 6.])),
            ("b".to_string(), HostTensor::i32(vec![4], vec![7, 8, 9, 10])),
            ("s".to_string(), HostTensor::scalar_f32(3.25)),
        ];
        save_checkpoint(&p, &tensors).unwrap();
        let back = load_checkpoint(&p).unwrap();
        assert_eq!(back.len(), 3);
        assert_eq!(back[0].0, "a");
        assert_eq!(back[0].1.f32_data, tensors[0].1.f32_data);
        assert_eq!(back[1].1.i32_data, tensors[1].1.i32_data);
        assert_eq!(back[2].1.shape, Vec::<usize>::new());
    }

    #[test]
    fn bad_magic_rejected() {
        let dir = std::env::temp_dir().join("chon_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("junk.ckpt");
        std::fs::write(&p, b"NOTACKPTxxxx").unwrap();
        assert!(load_checkpoint(&p).is_err());
    }
}
